"""Production training launcher: mesh-sharded, fault-tolerant, config-driven.

On real hardware this is the per-host entrypoint (jax.distributed initializes
from the cluster env); in this container it runs single-process and the same
code paths compile under the production mesh via ``--dry-run``.

    python -m repro.launch.train --arch granite-20b --steps 100          # CPU smoke
    python -m repro.launch.train --arch granite-20b --full --mesh single # on a pod
"""

from __future__ import annotations

import argparse
import functools

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.checkpoint.store import CheckpointManager
from repro.data.tokens import TokenStream
from repro.distributed.sharding import make_rules, mesh_context
from repro.launch import specs as S
from repro.core.topology import make_production_mesh
from repro.models.config import ARCH_IDS, get_config
from repro.models.model import Model
from repro.train.loop import run_training
from repro.train.step import TrainConfig, init_train_state, train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-20b")
    ap.add_argument("--full", action="store_true", help="full (non-smoke) config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", choices=("none", "single", "multi"), default="none",
                    help="'none' = host devices as-is (CPU smoke)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quantize-moments", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    model = Model(cfg)
    tc = TrainConfig(
        learning_rate=args.lr,
        microbatches=args.microbatches,
        quantize_moments=args.quantize_moments,
        compress_grads=args.compress_grads,
    )
    print(f"[train] {cfg.name}: {model.n_params()/1e6:.1f}M params, "
          f"devices={jax.device_count()}")

    stream = TokenStream(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        host_index=jax.process_index(), n_hosts=jax.process_count(),
    )
    ckpt = CheckpointManager(args.ckpt_dir, save_every=args.save_every, keep=3)

    def init_state():
        return init_train_state(model, model.init(jax.random.PRNGKey(0)), tc)

    if args.mesh == "none":
        step_fn = functools.partial(train_step, model, tc)
        report = run_training(
            step_fn=step_fn, init_state=init_state,
            data=lambda start: stream.iterate(start), ckpt=ckpt,
            total_steps=args.steps,
        )
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        rules = make_rules()
        with mesh_context(mesh, rules):
            state_abs = S.train_state_abstract(model, tc)
            state_ps = S.train_state_pspecs(model, state_abs, mesh, rules)
            batch_ps = {"tokens": P(("pod", "data") if args.mesh == "multi"
                                    else "data"),
                        "labels": P(("pod", "data") if args.mesh == "multi"
                                    else "data")}
            jitted = jax.jit(
                functools.partial(train_step, model, tc),
                in_shardings=(state_ps, batch_ps),
                out_shardings=(state_ps, P()),
                donate_argnums=(0,),
            )
            report = run_training(
                step_fn=jitted, init_state=init_state,
                data=lambda start: stream.iterate(start), ckpt=ckpt,
                total_steps=args.steps,
            )
    print(f"[train] final step {report.final_step}, "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}, "
          f"restarts {report.restarts}")


if __name__ == "__main__":
    main()
