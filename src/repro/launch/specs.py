"""Dry-run input specs: ShapeDtypeStruct stand-ins + PartitionSpec trees for
every (arch x shape x mesh) cell — weak-type-correct, shardable, zero
allocation.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.data.tokens import make_batch_specs
from repro.distributed.sharding import ShardingRules, spec as axis_spec
from repro.models.config import Family, ModelConfig, ShapeCell
from repro.models.decode import init_cache
from repro.models.model import Model
from repro.optim import AdamWConfig, adamw_init
from repro.train.step import TrainConfig, TrainState, init_train_state

BATCH_AXES = {"tokens": ("batch", "seq"), "labels": ("batch", "seq"),
              "encoder_frames": ("batch", "frames", "embed_act")}

CACHE_AXES = {
    "k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "cross_k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "cross_v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "conv": ("layers", "batch", "conv", "ssm_inner"),
    "ssm": ("layers", "batch", "ssm_heads", None, "ssm_state"),
    "index": (),
}


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict[str, Any]:
    """Model-input ShapeDtypeStructs for one cell (tokens/labels/frames)."""
    return make_batch_specs(cfg, cell)


def batch_pspecs(
    specs: dict[str, Any], mesh: Mesh, rules: ShardingRules
) -> dict[str, P]:
    return {
        k: axis_spec(v.shape, BATCH_AXES[k], mesh, rules) for k, v in specs.items()
    }


def cache_abstract(cfg: ModelConfig, cell: ShapeCell) -> dict[str, Any]:
    """Abstract KV/SSM cache for decode cells (eval_shape — no allocation)."""
    return jax.eval_shape(
        lambda: init_cache(cfg, cell.global_batch, cell.seq_len, jnp.bfloat16)
    )


def cache_pspecs(
    cache_abs: dict[str, Any], mesh: Mesh, rules: ShardingRules
) -> dict[str, P]:
    out = {}
    for k, v in cache_abs.items():
        axes = CACHE_AXES[k]
        out[k] = axis_spec(v.shape, axes, mesh, rules) if v.shape else P()
    return out


def train_state_abstract(model: Model, tc: TrainConfig) -> TrainState:
    """Abstract TrainState (params + optimizer moments, bf16/fp32)."""
    return jax.eval_shape(
        lambda: init_train_state(
            model, model.init(jax.random.PRNGKey(0)), tc
        )
    )


def train_state_pspecs(
    model: Model, state_abs: TrainState, mesh: Mesh, rules: ShardingRules
) -> TrainState:
    """PartitionSpecs for TrainState: moments follow the param layout."""
    p_specs = model.param_pspecs(mesh, rules)
    flat_p, p_treedef = jax.tree.flatten(p_specs)
    n_data = mesh.shape.get("data", 1)

    def like_params(tree_abs):
        # fp32 moments mirror the params tree exactly -> reuse param specs
        flat_t = jax.tree.leaves(tree_abs)
        if len(flat_t) == len(flat_p):
            return jax.tree.unflatten(p_treedef, flat_p)

        # quantized moments: _Q8(q (nblocks, 128) int8, scale (nblocks, 1))
        # per param — shard the block axis on "data" (FSDP-style) when it
        # divides, else replicate
        def leaf_spec(x):
            if x.ndim >= 1 and x.shape[0] % n_data == 0 and x.shape[0] >= n_data:
                return P("data", *([None] * (x.ndim - 1)))
            return P(*([None] * x.ndim))

        return jax.tree.map(leaf_spec, tree_abs)

    return TrainState(
        step=P(),
        params=p_specs,
        opt=type(state_abs.opt)(
            step=P(),
            m=like_params(state_abs.opt.m),
            v=like_params(state_abs.opt.v),
        ),
        ef=None
        if state_abs.ef is None
        else type(state_abs.ef)(residual=jax.tree.unflatten(p_treedef, flat_p)),
    )
