"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
the jitted step is ``.lower()``ed with ShapeDtypeStruct inputs and
``.compile()``d against the production mesh; ``memory_analysis`` proves the
per-device footprint, ``cost_analysis`` + HLO collective parsing feed the
§Roofline terms.

Usage:
  python -m repro.launch.dryrun --arch granite-20b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--jobs 4] [--out EXPERIMENTS_dryrun.json]

Single-cell invocations print one JSON record to stdout; ``--all`` fans the
cells out over subprocesses (isolation: one cell's compiler OOM cannot take
down the sweep) and aggregates.
"""

# The dry-run (and ONLY the dry-run) needs 512 placeholder devices so
# jax.make_mesh can build the production mesh.  Must run before ANY other
# import — jax locks the device count on first init.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import functools
import json
import re
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import make_rules, mesh_context
from repro.launch import specs as S
from repro.core.topology import make_production_mesh
from repro.models import Model, get_config, shapes_for
from repro.models.config import ALL_SHAPES, ARCH_IDS
from repro.train.step import TrainConfig, train_step

# -- HLO collective accounting ---------------------------------------------------

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO shape literal, e.g. 'bf16[256,4096]' (tuples summed)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes per collective kind from (SPMD-partitioned) HLO.

    Operand sizes are read from each collective instruction's operand type
    annotations (HLO prints callee types inline); output-only fallbacks use
    the instruction's own shape.
    """
    out = {k: 0 for k in _COLLECTIVES}
    # instruction name -> shape string, for operand lookup
    defs: dict[str, str] = {}
    for m in re.finditer(r"%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\][^\s]*))",
                         hlo_text):
        defs[m.group(1)] = m.group(2)
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r"%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\][^\s]*))\s*"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
            r"(?:-start|-done)?\(([^)]*)\)",
            line,
        )
        if not m:
            continue
        _, out_type, kind, operands = m.groups()
        if "-done(" in line:
            continue  # avoid double counting start/done pairs
        op_bytes = 0
        for op in operands.split(","):
            op = op.strip().lstrip("%")
            name = op.split(" ")[-1].lstrip("%")
            if name in defs:
                op_bytes += _shape_bytes(defs[name])
        if op_bytes == 0:
            op_bytes = _shape_bytes(out_type)
        out[kind] += op_bytes
    return out


# -- cell lowering ------------------------------------------------------------------


def calib_layer_counts(cfg) -> tuple[dict, dict, int, int]:
    """Two reduced-layer overrides with identical per-layer math + (k1, k2).

    XLA's cost model counts a ``while`` body once, so scanned-layer cells
    under-report per-layer costs by ~n_layers.  Lowering two small *unrolled*
    stacks recovers the exact per-layer slope; the caller extrapolates
    ``corrected = f(k1) + (L - k1) * (f(k2) - f(k1)) / (k2 - k1)``.
    The pairs respect each family's structural period (gemma2 local/global
    pairs, zamba2 shared-attn groups, MoE dense prefixes, enc-dec stacks).
    """
    from repro.models.config import Family

    if cfg.family is Family.ENC_DEC:
        return ({"n_layers": 2, "n_encoder_layers": 2},
                {"n_layers": 4, "n_encoder_layers": 4}, 2, 4)
    if cfg.local_global_pattern:
        return ({"n_layers": 2}, {"n_layers": 4}, 2, 4)
    if cfg.family is Family.HYBRID and cfg.attn_every:
        p = cfg.attn_every
        return ({"n_layers": p}, {"n_layers": 2 * p}, p, 2 * p)
    if cfg.family is Family.MOE and cfg.moe.first_k_dense:
        f = cfg.moe.first_k_dense
        return ({"n_layers": f + 1}, {"n_layers": f + 2}, f + 1, f + 2)
    return ({"n_layers": 1}, {"n_layers": 2}, 1, 2)


_EXTRAPOLATED_KEYS = ("flops_per_device", "bytes_accessed_per_device")


def calibrate_cell(arch: str, shape_name: str, mesh_kind: str,
                   rules_preset: str = "baseline") -> dict:
    """Scan-corrected roofline terms via two unrolled reduced-layer lowerings."""
    cfg = get_config(arch)
    ov1, ov2, k1, k2 = calib_layer_counts(cfg)
    r1 = lower_cell(arch, shape_name, mesh_kind,
                    config_overrides={**ov1, "scan_layers": False},
                    rules_preset=rules_preset)
    r2 = lower_cell(arch, shape_name, mesh_kind,
                    config_overrides={**ov2, "scan_layers": False},
                    rules_preset=rules_preset)
    if r1["status"] != "ok" or r2["status"] != "ok":
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "calib_failed"}
    L = cfg.n_layers
    scale = (L - k1) / (k2 - k1)

    def extrap(a, b):
        return a + scale * (b - a)

    out = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "status": "ok", "calibrated": True, "k1": k1, "k2": k2,
           "n_chips": r1["n_chips"],
           "n_params": int(cfg.n_params()),
           "n_active_params": int(cfg.n_active_params())}
    for key in _EXTRAPOLATED_KEYS:
        out[key] = float(extrap(r1[key], r2[key]))
    coll = {}
    for kind in _COLLECTIVES:
        coll[kind] = int(max(extrap(
            r1["collective_bytes_per_device"][kind],
            r2["collective_bytes_per_device"][kind]), 0))
    out["collective_bytes_per_device"] = coll
    out["collective_bytes_total"] = int(sum(coll.values()))
    return out


# Named perf presets: sharding-rule overrides + config overrides
# (EXPERIMENTS.md 'Perf' iterations).
RULE_PRESETS: dict[str, dict] = {
    "baseline": {"rules": {}, "config": {}},
    # flash-decoding: shard the KV cache (and decode attention) over the
    # model axis along kv_seq instead of replicating indivisible kv_heads
    "seqkv": {"rules": {"kv_seq": "model", "kv_heads": None}, "config": {}},
    # + drop activation checkpointing at inference (remat is training-only;
    # in a decode step it only inserts recompute and extra HBM passes)
    "seqkv_noremat": {"rules": {"kv_seq": "model", "kv_heads": None},
                      "config": {"remat": "none"}},
    "noremat": {"rules": {}, "config": {"remat": "none"}},
    # mixed-precision attention: bf16 score/weight tensors, f32 row sums —
    # halves the dominant S^2 HBM traffic of unfused train attention
    "bf16attn": {"rules": {}, "config": {"attn_scores_bf16": True}},
    # + mixed-precision norms: f32 only for the (...,1) variance statistics,
    # killing the per-layer full-tensor f32 round-trips of the residual path
    "bf16stream": {"rules": {},
                   "config": {"attn_scores_bf16": True, "norms_bf16": True}},
}


def lower_cell(arch: str, shape_name: str, mesh_kind: str, *,
               extra: dict | None = None, config_overrides: dict | None = None,
               rules_preset: str = "baseline"):
    """Lower+compile one cell; returns the dry-run record dict."""
    cfg = get_config(arch)
    preset = RULE_PRESETS[rules_preset]
    if preset["config"]:
        cfg = cfg.with_(**preset["config"])
    if config_overrides:
        cfg = cfg.with_(**config_overrides)
    cell = {c.name: c for c in ALL_SHAPES}[shape_name]
    if cell not in shapes_for(cfg):
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "status": "skipped",
            "reason": "long_500k requires sub-quadratic attention "
                      "(DESIGN.md Shape skips)",
        }
    model = Model(cfg)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = make_rules(RULE_PRESETS[rules_preset]["rules"])
    n_chips = mesh.devices.size
    t0 = time.time()

    with mesh_context(mesh, rules):
        batch_specs = S.input_specs(cfg, cell)
        batch_ps = S.batch_pspecs(batch_specs, mesh, rules)

        if cell.kind == "train":
            tc = TrainConfig(**(extra or {}))
            state_abs = S.train_state_abstract(model, tc)
            state_ps = S.train_state_pspecs(model, state_abs, mesh, rules)
            fn = functools.partial(train_step, model, tc)
            jitted = jax.jit(
                fn,
                in_shardings=(state_ps, batch_ps),
                out_shardings=(state_ps, P()),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_abs, batch_specs)
        elif cell.kind == "prefill":
            cache_abs = S.cache_abstract(cfg, cell)
            cache_ps = S.cache_pspecs(cache_abs, mesh, rules)
            params_abs = model.abstract_params()
            params_ps = model.param_pspecs(mesh, rules)

            def prefill_fn(params, batch, cache):
                return model.prefill(
                    params, batch["tokens"], cache,
                    **({"encoder_frames": batch["encoder_frames"]}
                       if "encoder_frames" in batch else {}),
                )

            jitted = jax.jit(
                prefill_fn,
                in_shardings=(params_ps, batch_ps, cache_ps),
                out_shardings=(P(), cache_ps),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params_abs, batch_specs, cache_abs)
        else:  # decode
            cache_abs = S.cache_abstract(cfg, cell)
            cache_ps = S.cache_pspecs(cache_abs, mesh, rules)
            params_abs = model.abstract_params()
            params_ps = model.param_pspecs(mesh, rules)

            def decode_fn(params, batch, cache):
                return model.decode_step(params, batch["tokens"], cache)

            jitted = jax.jit(
                decode_fn,
                in_shardings=(params_ps, batch_ps, cache_ps),
                out_shardings=(P(), cache_ps),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params_abs, batch_specs, cache_abs)

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "status": "ok",
        "n_chips": int(n_chips),
        "compile_s": round(time.time() - t0, 1),
        "n_params": int(cfg.n_params()),
        "n_active_params": int(cfg.n_active_params()),
        # per-device numbers (post-SPMD-partitioning module)
        "flops_per_device": float(cost.get("flops", -1)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", -1)),
        "argument_bytes_per_device": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes_per_device": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes_per_device": int(getattr(mem, "temp_size_in_bytes", 0)),
        "peak_hbm_per_device": int(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
        ),
        "collective_bytes_per_device": coll,
        "collective_bytes_total": int(sum(coll.values())),
    }
    return record


# -- orchestration --------------------------------------------------------------------


def iter_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for cell in ALL_SHAPES:
            yield arch, cell.name, (cell in shapes_for(cfg))


def run_all(jobs: int, out_path: str, meshes=("single", "multi")) -> list[dict]:
    tasks = []
    for arch, shape, eligible in iter_cells():
        for mesh_kind in meshes:
            tasks.append((arch, shape, mesh_kind, eligible))

    def run_one(task):
        arch, shape, mesh_kind, eligible = task
        if not eligible:
            return {
                "arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "skipped",
                "reason": "long_500k requires sub-quadratic attention",
            }
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
        ]
        env = dict(os.environ)
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        try:
            proc = subprocess.run(
                cmd, capture_output=True, text=True, timeout=3600, env=env
            )
            if proc.returncode != 0:
                return {
                    "arch": arch, "shape": shape, "mesh": mesh_kind,
                    "status": "error",
                    "error": proc.stderr.strip().splitlines()[-12:],
                }
            return json.loads(proc.stdout.strip().splitlines()[-1])
        except subprocess.TimeoutExpired:
            return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                    "status": "timeout"}

    results = []
    with ThreadPoolExecutor(max_workers=jobs) as ex:
        for rec in ex.map(run_one, tasks):
            results.append(rec)
            status = rec["status"]
            tag = f"{rec['arch']} x {rec['shape']} x {rec['mesh']}"
            print(f"[dryrun] {tag:60s} {status}", flush=True)
            if out_path:
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1)
    return results


def calibrate_all(jobs: int, out_path: str, mesh_kind: str = "single") -> list[dict]:
    """Scan-corrected terms for every eligible cell (subprocess-isolated)."""
    tasks = [(arch, shape) for arch, shape, eligible in iter_cells() if eligible]

    def run_one(task):
        arch, shape = task
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--calibrate",
               "--arch", arch, "--shape", shape, "--mesh", mesh_kind]
        env = dict(os.environ)
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=3600, env=env)
            if proc.returncode != 0:
                return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                        "status": "error",
                        "error": proc.stderr.strip().splitlines()[-8:]}
            return json.loads(proc.stdout.strip().splitlines()[-1])
        except subprocess.TimeoutExpired:
            return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                    "status": "timeout"}

    results = []
    with ThreadPoolExecutor(max_workers=jobs) as ex:
        for rec in ex.map(run_one, tasks):
            results.append(rec)
            print(f"[calib] {rec['arch']} x {rec['shape']}: {rec['status']}",
                  flush=True)
            if out_path:
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=[c.name for c in ALL_SHAPES])
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--calibrate", action="store_true",
                    help="scan-corrected terms for one cell")
    ap.add_argument("--calibrate-all", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--rules", default="baseline", choices=sorted(RULE_PRESETS))
    args = ap.parse_args()

    if args.all:
        results = run_all(args.jobs, args.out)
        ok = sum(r["status"] == "ok" for r in results)
        skipped = sum(r["status"] == "skipped" for r in results)
        bad = [r for r in results if r["status"] not in ("ok", "skipped")]
        print(f"[dryrun] ok={ok} skipped={skipped} failed={len(bad)}")
        for r in bad:
            print("  FAILED:", r["arch"], r["shape"], r["mesh"])
        sys.exit(1 if bad else 0)

    if args.calibrate_all:
        results = calibrate_all(args.jobs, args.out, args.mesh)
        bad = [r for r in results if r["status"] != "ok"]
        sys.exit(1 if bad else 0)

    if args.calibrate:
        record = calibrate_cell(args.arch, args.shape, args.mesh, args.rules)
    else:
        record = lower_cell(args.arch, args.shape, args.mesh,
                            rules_preset=args.rules)
    record["rules"] = args.rules
    print(json.dumps(record))


if __name__ == "__main__":
    main()
