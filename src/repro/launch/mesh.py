"""Deprecated shim: the mesh factories moved to ``repro.core.topology``.

The production-mesh helpers were orphaned here (and used a
``jax.sharding.AxisType`` API this jax version does not ship); the campaign
topology layer is their real home now — it adds the 1-D UE mesh
(``make_ue_mesh``) the sharded multi-cell engine runs on.  Import from
``repro.core.topology`` directly.
"""

from repro.core.topology import (  # noqa: F401
    make_cpu_mesh,
    make_production_mesh,
    make_ue_mesh,
)
