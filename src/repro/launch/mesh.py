"""Production meshes (multi-pod dry-run spec).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any jax
initialization).

  single-pod: (16, 16)    = 256 chips, axes ("data", "model")
  multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model")

Physical mapping on the v5e target: "model" follows the ICI torus minor
dimension (TP collectives stay on-chip-neighbour links), "data" the major
dimension, "pod" crosses the inter-pod DCN — which is why the default
sharding rules put only pure-DP gradient reductions on the pod axis
(DESIGN.md, distributed/sharding.py).
"""

from __future__ import annotations

import jax


def _auto(n: int):
    # pin Auto axis types: jax 0.9 flips the default to Explicit
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_cpu_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh for CPU integration tests (requires forced host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"), axis_types=_auto(2))
