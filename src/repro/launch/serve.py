"""Serving launcher: batched decode with optional ARCHES expert switching.

    python -m repro.launch.serve --arch granite-20b --steps 32
    python -m repro.launch.serve --arch granite-20b --switched
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.models.config import ARCH_IDS, Family, get_config
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.switched import SwitchedDecodeConfig, SwitchedDecoder


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-20b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--switched", action="store_true",
                    help="ARCHES expert bank over decode attention")
    ap.add_argument("--window", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] {cfg.name}: {model.n_params()/1e6:.1f}M params")

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    enc_kw = {}
    if cfg.family is Family.ENC_DEC:
        enc_kw["encoder_frames"] = jnp.zeros(
            (args.batch, 8, cfg.d_model), cfg.param_dtype()
        )

    if not args.switched:
        eng = ServingEngine(model, params, max_seq=args.max_seq)
        t0 = time.time()
        res = eng.generate(prompts, args.steps, **enc_kw)
        dt = time.time() - t0
        print(f"[serve] {args.batch}x{args.steps} tokens in {dt:.1f}s "
              f"({args.batch*args.steps/dt:.1f} tok/s)")
        print("[serve] first sequence:", res.tokens[0][:16], "...")
        return

    dec = SwitchedDecoder(model, SwitchedDecodeConfig(window=args.window))
    cache = model.init_cache(args.batch, args.max_seq)
    _, cache = model.prefill(params, prompts, cache, **enc_kw)
    tok = prompts[:, -1:]
    t0 = time.time()
    for step in range(args.steps):
        mode = 0 if step % 8 < 4 else 1  # scripted switching demo
        logits, cache, kpms = dec.step(mode, params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        if step % 8 == 0:
            print(f"[serve] step {step}: expert={'exact' if mode == 0 else 'win'} "
                  f"kl={kpms['expert_kl']:.4f} occ={kpms['cache_occupancy']:.2f}")
    dt = time.time() - t0
    print(f"[serve] switched decode: {args.batch*args.steps/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
