"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler mitigation hooks.

The loop is deliberately host-driven (the step itself is one jitted call):
fault tolerance is a *control-plane* property, mirroring the ARCHES split
between the real-time pipeline and the dApp (DESIGN.md 6).

Mechanisms, mapped to the 1000+-node deployment:

* **checkpoint/restart** — CheckpointManager.save_every + restore_latest;
  any crash (or injected ``FailureInjector`` fault) resumes from the newest
  complete checkpoint.  Tested end-to-end (tests/test_train_loop.py):
  kill the loop mid-run, restart, bit-identical continuation.
* **straggler mitigation** — per-step deadline watchdog: a step slower than
  ``straggler_factor`` x the trailing-median records a straggler event and
  (at scale) would trigger the runner's re-shard/replace protocol; here the
  event log + the policy hook are the implementable part on one host, and
  the hook is pluggable (``on_straggler``).
* **elastic scaling** — the loop snapshots at ``scale_events`` and rebuilds
  the data iterator with the new DP degree; on real hardware this is a
  restart with a different mesh (JAX re-jits), which the dry-run covers by
  compiling the same step on both production meshes.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Iterator

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.train.step import TrainConfig, TrainState


class InjectedFailure(RuntimeError):
    """Simulated node failure (tests / examples)."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at the given global steps (once each)."""

    fail_at_steps: tuple[int, ...] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise InjectedFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class LoopReport:
    steps_run: int
    final_step: int
    losses: list[float]
    straggler_events: list[int]
    restarts: int


def run_training(
    *,
    step_fn: Callable[[TrainState, dict], tuple[TrainState, dict]],
    init_state: Callable[[], TrainState],
    data: Callable[[int], Iterator[dict]],
    ckpt: CheckpointManager,
    total_steps: int,
    failure_injector: FailureInjector | None = None,
    max_restarts: int = 3,
    straggler_factor: float = 3.0,
    on_straggler: Callable[[int, float], None] | None = None,
    log_every: int = 10,
    log: Callable[[str], None] = print,
) -> LoopReport:
    """Run to ``total_steps`` with restart-on-failure semantics.

    ``data(start_step)`` must return an iterator positioned at that step
    (deterministic, so restarts replay the exact stream).
    """
    losses: list[float] = []
    stragglers: list[int] = []
    restarts = 0

    while True:
        # -- (re)start: restore newest complete checkpoint or fresh init --
        state = init_state()
        restored = ckpt.restore_latest(state)
        if restored is not None:
            start_step, state = restored
            log(f"[loop] restored checkpoint at step {start_step}")
        else:
            start_step = 0
        it = data(start_step)
        step_times: list[float] = []

        try:
            for step in range(start_step, total_steps):
                batch = next(it)
                if failure_injector is not None:
                    failure_injector.check(step)
                t0 = time.perf_counter()
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                losses.append(loss)

                # straggler watchdog
                if len(step_times) >= 5:
                    med = float(np.median(step_times[-20:]))
                    if dt > straggler_factor * med:
                        stragglers.append(step)
                        if on_straggler is not None:
                            on_straggler(step, dt / med)
                step_times.append(dt)

                ckpt.maybe_save(step + 1, state)
                if (step + 1) % log_every == 0:
                    log(f"[loop] step {step + 1}/{total_steps} loss {loss:.4f}")
            # clean finish
            ckpt.maybe_save(total_steps, state, force=True)
            return LoopReport(
                steps_run=len(losses),
                final_step=total_steps,
                losses=losses,
                straggler_events=stragglers,
                restarts=restarts,
            )
        except InjectedFailure as e:
            restarts += 1
            log(f"[loop] {e} -> restart {restarts}/{max_restarts}")
            if restarts > max_restarts:
                raise
