from repro.train.loop import FailureInjector, InjectedFailure, LoopReport, run_training
from repro.train.step import TrainConfig, TrainState, init_train_state, train_step

__all__ = [
    "FailureInjector",
    "InjectedFailure",
    "LoopReport",
    "run_training",
    "TrainConfig",
    "TrainState",
    "init_train_state",
    "train_step",
]
