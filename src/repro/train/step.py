"""The jitted training step: loss -> grads -> clip -> (compress) -> AdamW.

Built for the production meshes: params/opt-state enter pre-sharded (FSDP on
"data" x TP on "model"), the batch is sharded on ("pod", "data"), and the
whole state is donated so the update is in-place in HBM.  Gradient
accumulation (microbatching) runs as a ``lax.scan`` over microbatches so the
peak activation footprint is one microbatch.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.compression import (
    ErrorFeedbackState,
    compress_decompress,
    init_error_feedback,
)
from repro.models.model import Model
from repro.optim import AdamWConfig, AdamWState, adamw_init, adamw_update, global_norm_clip


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt: AdamWState
    ef: ErrorFeedbackState | None  # gradient-compression error feedback


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    max_grad_norm: float = 1.0
    quantize_moments: bool = False  # int8 optimizer states
    compress_grads: bool = False  # int8 + error feedback
    microbatches: int = 1  # gradient accumulation

    def adamw(self) -> AdamWConfig:
        return AdamWConfig(
            learning_rate=self.learning_rate,
            b1=self.b1,
            b2=self.b2,
            weight_decay=self.weight_decay,
            quantize_moments=self.quantize_moments,
        )


def init_train_state(model: Model, params: Any, tc: TrainConfig) -> TrainState:
    ef = init_error_feedback(params) if tc.compress_grads else None
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt=adamw_init(params, tc.adamw()),
        ef=ef,
    )


def _grads(model: Model, params, batch) -> tuple[jax.Array, Any]:
    def loss_fn(p):
        return model.loss(
            p,
            batch["tokens"],
            batch["labels"],
            encoder_frames=batch.get("encoder_frames"),
        )

    return jax.value_and_grad(loss_fn)(params)


def train_step(
    model: Model, tc: TrainConfig, state: TrainState, batch: dict
) -> tuple[TrainState, dict[str, jax.Array]]:
    """One optimizer step (jit + donate under the launcher)."""
    if tc.microbatches > 1:
        mb = tc.microbatches

        def split(x):
            b = x.shape[0]
            assert b % mb == 0, (b, mb)
            return x.reshape(mb, b // mb, *x.shape[1:])

        micro = {k: split(v) for k, v in batch.items() if v is not None}

        def acc_fn(carry, mbatch):
            loss_acc, g_acc = carry
            loss, g = _grads(model, state.params, mbatch)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / mb, g_acc, g
            )
            return (loss_acc + loss / mb, g_acc), None

        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params
        )
        (loss, grads), _ = jax.lax.scan(acc_fn, (jnp.zeros(()), zero_g), micro)
    else:
        loss, grads = _grads(model, state.params, batch)

    grads, gnorm = global_norm_clip(grads, tc.max_grad_norm)

    ef = state.ef
    if tc.compress_grads:
        grads, ef = compress_decompress(grads, ef)

    params, opt = adamw_update(grads, state.opt, state.params, tc.adamw())
    new_state = TrainState(step=state.step + 1, params=params, opt=opt, ef=ef)
    metrics = {"loss": loss, "grad_norm": gnorm, "step": new_state.step}
    return new_state, metrics
