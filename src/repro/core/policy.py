"""Switching policies (paper 5.3) + trainer + Table-1 metrics.

``fit_decision_tree`` is a self-contained greedy Gini trainer (depth-limited,
complete-tree layout) so the whole policy-design loop runs inside this
framework with no sklearn dependency.  ``DecisionTreePolicy`` evaluates
either through the Pallas ``tree_infer`` kernel (batched, MXU path) or the
literal tree walk (scalar host path); both are tested against each other.

``ThresholdPolicy`` implements the paper's proposed-future-work comparison
("threshold-based gating"), extended with hysteresis so the policy cannot
flap across a noisy boundary — a beyond-paper robustness addition.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tree_infer import pack_tree, tree_infer, tree_infer_ref


# -- trainer -----------------------------------------------------------------


def _gini(y: np.ndarray) -> float:
    if y.size == 0:
        return 0.0
    p = np.bincount(y, minlength=2) / y.size
    return float(1.0 - np.sum(p**2))


def _best_split(x: np.ndarray, y: np.ndarray):
    """Best (feature, threshold, impurity_decrease) for one node."""
    n, f = x.shape
    base = _gini(y)
    best = (0, np.inf, 0.0)  # feature, threshold, decrease
    for j in range(f):
        order = np.argsort(x[:, j], kind="stable")
        xs, ys = x[order, j], y[order]
        # candidate thresholds: midpoints between distinct consecutive values
        distinct = np.nonzero(np.diff(xs) > 0)[0]
        for i in distinct:
            t = 0.5 * (xs[i] + xs[i + 1])
            left, right = ys[: i + 1], ys[i + 1 :]
            w = (left.size * _gini(left) + right.size * _gini(right)) / n
            dec = base - w
            if dec > best[2] + 1e-12:
                best = (j, float(t), float(dec))
    return best


@dataclasses.dataclass
class FittedTree:
    feature: np.ndarray  # (2**d - 1,) int32, level order
    threshold: np.ndarray  # (2**d - 1,) float32 (+inf for pass-through nodes)
    leaf_values: np.ndarray  # (2**d,) float32
    depth: int
    n_features: int
    importances: np.ndarray  # (n_features,) normalized impurity decrease


def fit_decision_tree(
    x: np.ndarray, y: np.ndarray, *, depth: int = 2, min_samples: int = 2
) -> FittedTree:
    """Greedy Gini trainer producing a complete (padded) binary tree.

    Unreached/pure nodes become pass-through (threshold=+inf -> always left)
    with the majority label propagated to all their descendant leaves.
    """
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.int64)
    n_nodes = 2**depth - 1
    n_leaves = 2**depth
    feature = np.zeros(n_nodes, np.int32)
    threshold = np.full(n_nodes, np.inf, np.float32)
    leaf_values = np.zeros(n_leaves, np.float32)
    importances = np.zeros(x.shape[1], np.float64)
    n_total = max(len(y), 1)

    def majority(yy):
        return float(np.bincount(yy, minlength=2).argmax()) if yy.size else 0.0

    # level-order recursion over the complete tree
    node_data = {0: (x, y)}
    for node in range(n_nodes):
        xx, yy = node_data.get(node, (x[:0], y[:0]))
        left_child, right_child = 2 * node + 1, 2 * node + 2
        split = None
        if yy.size >= min_samples and _gini(yy) > 0:
            j, t, dec = _best_split(xx, yy)
            if np.isfinite(t) and dec > 0:
                split = (j, t, dec)
        if split is None:
            # pass-through: everything goes left
            node_data[left_child] = (xx, yy)
            node_data[right_child] = (xx[:0], yy[:0])
        else:
            j, t, dec = split
            feature[node] = j
            threshold[node] = t
            importances[j] += dec * yy.size / n_total
            mask = xx[:, j] > t
            node_data[left_child] = (xx[~mask], yy[~mask])
            node_data[right_child] = (xx[mask], yy[mask])

    # leaves occupy level-order ids [n_nodes, n_nodes + n_leaves)
    for leaf in range(n_leaves):
        xx, yy = node_data.get(n_nodes + leaf, (x[:0], y[:0]))
        if yy.size == 0:
            # inherit from nearest populated ancestor
            anc = (n_nodes + leaf - 1) // 2
            while anc > 0 and node_data.get(anc, (None, y[:0]))[1].size == 0:
                anc = (anc - 1) // 2
            yy = node_data.get(anc, (x, y))[1]
        leaf_values[leaf] = majority(yy)

    total = importances.sum()
    if total > 0:
        importances = importances / total
    return FittedTree(
        feature=feature,
        threshold=threshold,
        leaf_values=leaf_values,
        depth=depth,
        n_features=x.shape[1],
        importances=importances.astype(np.float32),
    )


# -- policies ----------------------------------------------------------------


class DecisionTreePolicy:
    """The paper's switching policy: depth-2 Gini tree over 10 KPMs."""

    def __init__(self, tree: FittedTree, feature_names: Sequence[str]):
        if len(feature_names) != tree.n_features:
            raise ValueError("feature_names/tree mismatch")
        self.tree = tree
        self.feature_names = tuple(feature_names)
        self.packed = pack_tree(
            tree.feature, tree.threshold, tree.leaf_values, tree.n_features, tree.depth
        )

    def __call__(self, x: jax.Array) -> jax.Array:
        """Single KPM vector ``(F,)`` -> int32 mode (literal walk, host path)."""
        out = tree_infer_ref(
            jnp.asarray(x, jnp.float32)[None, :],
            jnp.asarray(self.tree.feature),
            jnp.asarray(self.tree.threshold),
            jnp.asarray(self.tree.leaf_values),
            self.tree.depth,
        )
        return out[0].astype(jnp.int32)

    def batch(self, x: jax.Array) -> jax.Array:
        """Batched ``(B, F)`` inference through the Pallas kernel."""
        return tree_infer(jnp.asarray(x, jnp.float32), self.packed).astype(jnp.int32)

    def predict_from_kpms(self, kpms: Mapping[str, float]) -> int:
        vec = jnp.asarray([float(kpms[n]) for n in self.feature_names], jnp.float32)
        return int(self(vec))

    def to_device(self):
        """Export to flat device tables for in-scan closed-loop inference."""
        from repro.core.closed_loop import export_tree_tables

        return export_tree_tables(
            self.tree.feature,
            self.tree.threshold,
            self.tree.leaf_values,
            self.tree.n_features,
            self.tree.depth,
        )


@dataclasses.dataclass
class ThresholdPolicy:
    """Single-KPM gate with hysteresis (paper 9 'threshold-based gating')."""

    feature_idx: int
    threshold: float
    hysteresis: float = 0.0
    mode_above: int = 1  # e.g. good conditions -> MMSE
    mode_below: int = 0  # e.g. degraded -> AI

    def __call__(self, x: jax.Array, prev_mode: jax.Array | int = 1) -> jax.Array:
        v = jnp.asarray(x)[self.feature_idx]
        prev = jnp.asarray(prev_mode, jnp.int32)
        hi = self.threshold + self.hysteresis
        lo = self.threshold - self.hysteresis
        above = v > hi
        below = v < lo
        keep = jnp.logical_not(jnp.logical_or(above, below))
        return jnp.where(
            keep,
            prev,
            jnp.where(above, jnp.int32(self.mode_above), jnp.int32(self.mode_below)),
        )

    def to_device(self):
        """Export to flat device scalars for in-scan closed-loop inference."""
        from repro.core.closed_loop import DeviceThresholdPolicy

        return DeviceThresholdPolicy(
            feature_idx=jnp.int32(self.feature_idx),
            lo=jnp.float32(self.threshold - self.hysteresis),
            hi=jnp.float32(self.threshold + self.hysteresis),
            mode_above=jnp.int32(self.mode_above),
            mode_below=jnp.int32(self.mode_below),
        )


# -- policy design from profiled campaigns ------------------------------------


def profile_and_fit_tree(
    engine,
    schedule,
    *,
    n_slots: int,
    n_ues: int,
    depth: int = 2,
    feature_names: Sequence[str] | None = None,
) -> DecisionTreePolicy:
    """Profile both experts on the batched engine and fit the switching tree.

    Runs the labelled ``schedule`` once per expert mode (paper 5.3: every
    slot under interference is labelled mode 0 / AI), stacks each campaign's
    per-(slot, UE) KPMs into feature rows, and fits the depth-``depth`` Gini
    tree.  Shared by the quickstart, the closed-loop benchmark and the
    equivalence tests so they all train the same policy the same way.
    """
    from repro.core.telemetry import SELECTED_KPMS, trajectory_kpm_matrix

    names = tuple(feature_names) if feature_names is not None else SELECTED_KPMS
    labels = np.asarray(
        [0 if schedule(s).interference else 1 for s in range(n_slots)]
    )
    X, y = [], []
    for mode in (0, 1):
        _, traj = engine.run(schedule, mode, n_slots=n_slots, n_ues=n_ues)
        feats = np.asarray(trajectory_kpm_matrix(traj["kpms"], names))
        X.append(feats.reshape(-1, feats.shape[-1]))
        y.append(np.repeat(labels, n_ues))
    tree = fit_decision_tree(
        np.concatenate(X).astype(np.float32),
        np.concatenate(y).astype(np.int32),
        depth=depth,
    )
    return DecisionTreePolicy(tree, names)


# -- Table-1 metrics -----------------------------------------------------------


def classification_metrics(y_true: np.ndarray, y_pred: np.ndarray) -> dict:
    """Accuracy / precision / specificity / F1 for the positive class 0 (AI).

    The paper labels interference slots mode=0 (AI).  We treat mode=0 as the
    positive class, matching Table 1.
    """
    y_true = np.asarray(y_true).astype(int)
    y_pred = np.asarray(y_pred).astype(int)
    pos = 0
    tp = int(np.sum((y_pred == pos) & (y_true == pos)))
    fp = int(np.sum((y_pred == pos) & (y_true != pos)))
    tn = int(np.sum((y_pred != pos) & (y_true != pos)))
    fn = int(np.sum((y_pred != pos) & (y_true == pos)))
    acc = (tp + tn) / max(tp + tn + fp + fn, 1)
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    spec = tn / max(tn + fp, 1)
    f1 = 2 * prec * rec / max(prec + rec, 1e-12)
    return {
        "accuracy": acc,
        "precision": prec,
        "recall": rec,
        "specificity": spec,
        "f1": f1,
    }
