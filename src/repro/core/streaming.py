"""Epoch-chunked streaming campaigns: UE attach/detach under churn.

Every execution path in the repo compiles a fixed ``(n_slots, n_ues)`` grid;
a live gNB serves a *churning* population.  This module closes that gap with
the ROADMAP's streaming driver: the compiled scan executes in fixed-length
**segments** over a max-capacity UE *bank* (``CampaignSpec.n_ues`` bank
slots), an **active mask** rides the scan so detached bank slots are masked
out of KPM windows, throughput, executed-FLOPs and gated compaction demand,
and a host-side **admission pass** at each segment boundary re-packs the
resident UE set into bank slots (stable partition — the same discipline as
the gated compaction path — cell-block-aligned under a sharded topology).

The correctness currency is the repo's standing one, extended to churn:

* **identity is the stable UE id, not the bank slot** — per-UE PRNG streams
  derive from ``fold_in(key, ue_id)`` and per-slot keys fold the *global*
  slot index (the scan carry starts at the segment's ``slot0``), so a
  resident UE's trajectory is bitwise-identical whether it was re-packed
  zero or five times;
* **a zero-churn segmented run is bitwise-equal to the monolithic run** —
  with every bank slot attached the mask selects are identities and the
  boundary re-pack is the identity gather;
* **detach discards, attach cold-starts** — a reattached UE gets fresh
  ``DeviceLinkState`` / ``DeviceSwitchState`` rows at the boundary, so no
  stale telemetry leaks into its first post-attach decision.

``ChurnSchedule`` is the declarative (JSON-round-trippable) form, hashed
into ``CampaignSpec`` like ``TopologySpec``; ``run_streaming`` is the
driver ``ArchesSession.run_streaming`` dispatches to.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

_EVENT_KINDS = ("attach", "detach")

#: closed-loop trajectory leaves that are not campaign outputs
_CLOSED_EXTRAS = ("active_mode", "raw_decision", "pending_mode", "kpms")


@dataclasses.dataclass(frozen=True)
class ChurnSchedule:
    """Declarative attach/detach schedule over a stable UE-id universe.

    ``n_ue_ids`` sizes the id universe (ids ``0..n_ue_ids-1`` — history and
    PRNG identity live on this axis; it may exceed the bank capacity as
    long as concurrent residency never does).  ``segment_slots`` is the
    epoch length: the compiled scan runs in segments of this many slots and
    churn takes effect only at segment boundaries — an event at slot ``t``
    becomes effective at the first segment start ``>= t`` (events whose
    boundary lies past the campaign horizon never take effect).

    ``initial`` lists the ids attached at slot 0; ``events`` is a tuple of
    ``(slot, ue_id, "attach" | "detach")`` triples.  Attaching an attached
    id or detaching an absent one is a validation error (the admission pass
    is declarative, not idempotent), as is residency exceeding the bank
    capacity — all surfaced at spec time, never as a scan-shape error.
    """

    n_ue_ids: int
    segment_slots: int
    initial: tuple = ()
    events: tuple = ()

    def __post_init__(self):
        if self.n_ue_ids < 1:
            raise ValueError(f"n_ue_ids {self.n_ue_ids} must be >= 1")
        if self.segment_slots < 1:
            raise ValueError(
                f"segment_slots {self.segment_slots} must be >= 1"
            )
        initial = tuple(int(u) for u in self.initial)
        if len(set(initial)) != len(initial):
            raise ValueError(f"initial {initial} repeats UE ids")
        object.__setattr__(self, "initial", initial)
        events = []
        for ev in self.events:
            slot, ue, kind = ev
            if str(kind) not in _EVENT_KINDS:
                raise ValueError(
                    f"event kind {kind!r}; one of {_EVENT_KINDS}"
                )
            if int(slot) < 0:
                raise ValueError(f"event slot {slot} must be >= 0")
            events.append((int(slot), int(ue), str(kind)))
        object.__setattr__(self, "events", tuple(events))
        for u in self.initial + tuple(u for _, u, _ in self.events):
            if not 0 <= u < self.n_ue_ids:
                raise ValueError(
                    f"UE id {u} outside [0, {self.n_ue_ids})"
                )

    def residency(self, n_slots: int) -> np.ndarray:
        """Per-slot attachment matrix ``(n_slots, n_ue_ids)`` (bool).

        Piecewise constant per segment by construction.  Raises on an
        inconsistent event stream (attach-while-attached /
        detach-while-absent among the events that take effect within the
        horizon).
        """
        seg = self.segment_slots
        if n_slots < 1:
            raise ValueError(f"n_slots {n_slots} must be >= 1")
        if n_slots % seg:
            raise ValueError(
                f"segment_slots={seg} does not divide n_slots={n_slots}: "
                "the streaming scan compiles one fixed segment length"
            )
        attached = np.zeros(self.n_ue_ids, bool)
        attached[list(self.initial)] = True
        by_boundary: dict[int, list] = {}
        for slot, ue, kind in self.events:
            eff = ((slot + seg - 1) // seg) * seg
            if eff >= n_slots:
                continue  # boundary past the horizon: never effective
            by_boundary.setdefault(eff, []).append((slot, ue, kind))
        out = np.zeros((n_slots, self.n_ue_ids), bool)
        for t0 in range(0, n_slots, seg):
            for slot, ue, kind in by_boundary.get(t0, ()):
                if kind == "attach":
                    if attached[ue]:
                        raise ValueError(
                            f"attach of UE {ue} at slot {slot}: already "
                            "attached at its effective boundary "
                            f"(segment start {t0})"
                        )
                    attached[ue] = True
                else:
                    if not attached[ue]:
                        raise ValueError(
                            f"detach of UE {ue} at slot {slot}: not "
                            "attached at its effective boundary "
                            f"(segment start {t0})"
                        )
                    attached[ue] = False
            out[t0:t0 + seg] = attached
        return out

    def validate(
        self, n_slots: int, capacity: int, *, n_cells: int = 1
    ) -> np.ndarray:
        """Check the schedule against a campaign shape; return residency.

        ``capacity`` is the bank width (``CampaignSpec.n_ues``).  Under a
        multi-cell topology the bank is partitioned into ``n_cells`` equal
        contiguous blocks and each id's home cell is
        ``ue_id // (n_ue_ids / n_cells)`` — per-cell residency must fit the
        cell's block so the admission pass can stay cell-block-aligned
        (which is what keeps re-packing free of cross-shard movement).
        """
        res = self.residency(n_slots)
        if n_cells < 1:
            raise ValueError(f"n_cells {n_cells} must be >= 1")
        if n_cells == 1:
            worst = int(res.sum(axis=1).max(initial=0))
            if worst > capacity:
                raise ValueError(
                    f"churn residency peaks at {worst} UEs but the bank "
                    f"holds {capacity}: raise n_ues or thin the schedule"
                )
            return res
        if self.n_ue_ids % n_cells:
            raise ValueError(
                f"n_cells={n_cells} does not divide n_ue_ids="
                f"{self.n_ue_ids}: ids map to home cells in equal blocks"
            )
        if capacity % n_cells:
            raise ValueError(
                f"n_cells={n_cells} does not divide the bank capacity "
                f"{capacity}"
            )
        block = capacity // n_cells
        cells = home_cells(self.n_ue_ids, n_cells)
        for c in range(n_cells):
            worst = int(res[:, cells == c].sum(axis=1).max(initial=0))
            if worst > block:
                raise ValueError(
                    f"cell {c} residency peaks at {worst} UEs but its "
                    f"bank block holds {block}"
                )
        return res


def home_cells(n_ue_ids: int, n_cells: int) -> np.ndarray:
    """Stable-id -> home-cell map ((n_ue_ids,) int32, contiguous blocks)."""
    return (np.arange(n_ue_ids) // (n_ue_ids // n_cells)).astype(np.int32)


def repack_bank(
    prev_occupant: np.ndarray,
    resident: np.ndarray,
    *,
    n_cells: int = 1,
) -> np.ndarray:
    """Admission pass: stable-partition the resident set into bank slots.

    ``prev_occupant (B,)`` holds the previous segment's occupant id per
    bank slot (-1 empty); ``resident (n_ue_ids,)`` is the new segment's
    attachment vector.  Surviving occupants compact to the front of their
    (cell-block) slot range *preserving pack order* — the same stable
    partition the gated compaction path uses — and newly attached ids
    append in ascending id order; remaining slots are empty (-1).

    Deterministic, so the whole occupancy timeline is a pure function of
    the ``ChurnSchedule``.
    """
    prev_occupant = np.asarray(prev_occupant)
    resident = np.asarray(resident, bool)
    capacity = prev_occupant.shape[0]
    if capacity % n_cells:
        raise ValueError(
            f"n_cells={n_cells} does not divide capacity={capacity}"
        )
    cells = home_cells(resident.shape[0], n_cells)
    block = capacity // n_cells
    occ = np.full(capacity, -1, prev_occupant.dtype)
    for c in range(n_cells):
        lo = c * block
        prev_block = [int(u) for u in prev_occupant[lo:lo + block] if u >= 0]
        survivors = [u for u in prev_block if resident[u]]
        newcomers = sorted(
            int(u) for u in np.nonzero(resident & (cells == c))[0]
            if u not in set(prev_block)
        )
        packed = survivors + newcomers
        if len(packed) > block:
            raise ValueError(
                f"cell {c}: {len(packed)} resident UEs for a {block}-slot "
                "bank block (validate the churn schedule first)"
            )
        occ[lo:lo + len(packed)] = packed
    return occ


def gather_permutation(
    prev_occupant: np.ndarray, new_occupant: np.ndarray
) -> np.ndarray:
    """Per-bank-slot source index into the previous bank (-1 == cold start).

    Slot ``b``'s new occupant either survived from previous slot
    ``perm[b]`` (its device state rows are gathered from there) or is a
    fresh attach / empty slot (``perm[b] == -1`` — cold-init rows).
    """
    prev_pos = {int(u): j for j, u in enumerate(prev_occupant) if u >= 0}
    return np.asarray(
        [
            prev_pos.get(int(u), -1) if u >= 0 else -1
            for u in new_occupant
        ],
        np.int64,
    )


def gather_state_rows(state, perm: np.ndarray, cold_state):
    """Re-pack a per-UE device-state pytree along its leading bank axis.

    Survivor rows gather from their previous slot; ``perm < 0`` rows take
    the cold-start value from ``cold_state``.  An identity permutation with
    no cold rows returns every leaf value bitwise-unchanged (the zero-churn
    contract rides on this).
    """
    take = jnp.asarray(np.maximum(perm, 0))
    cold = jnp.asarray(perm < 0)

    def one(prev_leaf, cold_leaf):
        g = jnp.take(prev_leaf, take, axis=0)
        m = cold.reshape(cold.shape + (1,) * (g.ndim - 1))
        return jnp.where(m, cold_leaf, g)

    return jax.tree.map(one, state, cold_state)


def _scatter_segment(full, seg_arr, t0, ids, slots):
    """full[t0:t0+seg, ids] = seg_arr[:, slots] (host-side assembly)."""
    full[t0:t0 + seg_arr.shape[0], ids] = np.asarray(seg_arr)[:, slots]


def _streaming_ckpt_state(
    *, next_seg, spec_fp, occupant, link, sw, modes_full, bank_slot_full,
    decisions_full, n_switches_id, kpms_full, outputs_full,
):
    """The crash-resume snapshot as an all-dict pytree (checkpoint-stable).

    Everything the segment loop carries across a boundary: the device scan
    carry (link + switch state as plain dicts of their NamedTuple fields),
    the UE bank occupancy, and the host-side accumulators.  All-dict so the
    templateless ``load_pytree`` rebuilds it exactly from the manifest.
    """
    state = {
        "meta": {
            # x64 is off, so 64-bit leaves would silently truncate on the
            # jnp round-trip — the fingerprint ships as two uint32 halves
            "next_seg": np.int32(next_seg),
            "spec_fp_hi": np.uint32(spec_fp >> 32),
            "spec_fp_lo": np.uint32(spec_fp & 0xFFFFFFFF),
        },
        "occupant": np.asarray(occupant),
        "link": dict(link._asdict()),
        "modes_full": modes_full,
        "bank_slot_full": bank_slot_full,
        "kpms_full": dict(kpms_full),
        "outputs_full": dict(outputs_full),
    }
    if sw is not None:
        sw_d = dict(sw._asdict())
        # the telemetry ring is itself a NamedTuple — expand it so the
        # snapshot stays an all-dict tree (templateless reload rebuilds
        # nested dicts, not NamedTuples)
        sw_d["rings"] = dict(sw.rings._asdict())
        state["sw"] = sw_d
        state["decisions_full"] = decisions_full
        state["n_switches_id"] = n_switches_id
    return state


def _spec_fingerprint(spec) -> int:
    """64-bit view of ``spec_hash`` (checkpointable as a uint64 leaf)."""
    from repro.core.session import spec_hash

    return int(spec_hash(spec), 16) & 0xFFFFFFFFFFFFFFFF


@dataclasses.dataclass(frozen=True)
class SegmentEvent:
    """What ``run_streaming`` hands to ``on_segment`` after each segment.

    Fired once per *completed* segment, after the checkpoint (when armed)
    has been durably written — so anything the callback observes is also
    recoverable.  ``history`` is a ``BatchedRunHistory`` view over the
    driver's live accumulators: slots ``[0, t1)`` are populated, later
    slots still carry their detached fill values.  The arrays are reused
    by subsequent segments — consumers that retain data past the callback
    must copy (``repro.core.telemetry.segment_telemetry`` reduces the
    ``[t0, t1)`` span to plain floats, which is the intended use).
    """

    seg_idx: int  # 0-based index of the segment that just completed
    n_segments: int  # total segments in the campaign horizon
    t0: int  # first slot of the segment
    t1: int  # one past the segment's last slot
    occupant: np.ndarray  # (capacity,) bank occupancy after this segment
    history: "object"  # BatchedRunHistory view (see above)


def run_streaming(
    session,
    *,
    checkpoint_dir: str | None = None,
    resume_from: str | None = None,
    max_segments: int | None = None,
    on_segment=None,
) -> "object":
    """Execute an epoch-chunked streaming campaign; one compiled segment.

    The driver: validate churn -> resolve the scenario over the *stable-id*
    axis -> loop segments (admission re-pack, state gather/cold-init,
    per-occupant param/mode/key gather, one cached scan call with the
    active mask and the global ``slot0``) -> assemble the full
    ``BatchedRunHistory`` on the id axis (detached slot-UEs carry the
    ``-1`` mode sentinel, zeroed KPMs/outputs, ``attached=False`` and
    ``bank_slot=-1``).

    Because segment shapes are fixed and ``slot0``/``active`` are traced,
    every segment reuses one compiled program per execution path.

    Crash resumability: with ``checkpoint_dir`` the driver snapshots the
    scan carry + UE bank + host accumulators through the atomic
    ``repro.checkpoint.store`` after *every completed segment*;
    ``resume_from`` restarts from the latest complete checkpoint in that
    directory and — because each segment is a pure function of the
    checkpointed state and the (deterministic) schedule — the resumed run
    is bitwise-equal to the uninterrupted one on every history leaf.
    ``max_segments`` stops after that many segments this call (the
    deterministic kill hook: the returned history covers only the slots
    run so far; later segments keep their detached fill values).

    ``on_segment`` is the long-running-service hook: called with a
    ``SegmentEvent`` after every completed segment (after its checkpoint,
    when one is armed, has been durably written).  A truthy return stops
    the drive loop there — the graceful-drain primitive: the segment in
    flight finishes, its checkpoint lands, and a later ``resume_from``
    continues bitwise from exactly that boundary.
    """
    from repro.core.closed_loop import init_device_switch
    from repro.core.runtime import BatchedRunHistory
    from repro.core.session import ExecutionPath
    from repro.core.telemetry import flatten_kpm_sources
    from repro.phy.channel import broadcast_params_to_ues
    from repro.phy.pipeline import (
        init_device_link,
        normalize_modes,
        resolve_schedule,
    )

    spec = session.spec
    churn = spec.churn
    if churn is None:
        raise ValueError("run_streaming needs spec.churn (a ChurnSchedule)")
    path = spec.execution_path
    if path not in (
        ExecutionPath.BATCHED, ExecutionPath.GATED, ExecutionPath.CLOSED_LOOP
    ):
        raise ValueError(
            f"streaming supports batched/gated/closed_loop, not "
            f"{spec.path!r} (the host loop serves one pinned UE and the "
            "perturbed sweep has no notion of churn)"
        )
    closed = path is ExecutionPath.CLOSED_LOOP

    topo = session.cell_topology
    n_cells = 1 if topo is None else topo.n_cells
    capacity = spec.n_ues  # bank width == the compiled batch width
    n_ids, n_slots = churn.n_ue_ids, spec.n_slots
    seg = churn.segment_slots
    res = churn.validate(n_slots, capacity, n_cells=n_cells)

    # fault masks live on the stable-id axis (a UE's fault stream follows
    # its identity through re-packs); segments column-gather by occupant
    faults = spec.faults
    rf = None if faults is None else faults.resolve(n_slots, n_ids)

    engine = session.engine
    profile, params = resolve_schedule(
        engine.cfg, session.schedule, n_slots, n_ids
    )
    per_ue_params = jnp.ndim(params.noise_var) == 2
    if topo is not None and not per_ue_params:
        params = broadcast_params_to_ues(params, n_ids)
        per_ue_params = True

    key = jax.random.PRNGKey(spec.seed)
    id_keys = jax.vmap(lambda u: jax.random.fold_in(key, u))(
        jnp.arange(n_ids)
    )

    modes_grid = None
    sw_cfg = policy = None
    if closed:
        sw_cfg = spec.switch.to_config(spec.feature_names)
        policy = session.device_policy
    else:
        modes_grid = np.asarray(
            normalize_modes(
                np.asarray(spec.modes, np.int32), n_slots, n_ids
            )
        )

    if topo is not None:
        from repro.core.topology import (
            _cached_jit,
            streaming_closed_loop_fn,
            streaming_open_loop_fn,
        )

        if closed:
            scan_fn = _cached_jit(
                topo,
                (engine, "streaming_closed", profile, sw_cfg,
                 jax.tree.structure(policy), faults),
                lambda: streaming_closed_loop_fn(
                    engine, topo, profile, sw_cfg, policy, faults=faults
                ),
            )
        else:
            scan_fn = _cached_jit(
                topo, (engine, "streaming_open", profile, faults),
                lambda: streaming_open_loop_fn(
                    engine, topo, profile, faults=faults
                ),
            )
        cell_of_slot = jnp.asarray(topo.cell_of_ue)
        cell_params = topo.cell_params

    def cold_switch():
        return init_device_switch(
            capacity, len(sw_cfg.feature_names), sw_cfg, faults
        )

    # bank state
    occupant = np.full(capacity, -1, np.int64)
    link = init_device_link(capacity)
    sw = cold_switch() if closed else None

    # full-campaign accumulators on the stable-id axis
    modes_full = np.full((n_slots, n_ids), -1, np.int32)
    bank_slot_full = np.full((n_slots, n_ids), -1, np.int32)
    decisions_full = (
        np.full((n_slots, n_ids), -1, np.int32) if closed else None
    )
    n_switches_id = np.zeros(n_ids, np.int32) if closed else None
    kpms_full: dict[str, np.ndarray] = {}
    outputs_full: dict[str, np.ndarray] = {}

    # -- crash resume: restore the whole loop state from the latest
    # complete checkpoint, then continue exactly where it left off -------
    spec_fp = _spec_fingerprint(spec)
    start_seg = 0
    mgr = None
    if checkpoint_dir is not None or resume_from is not None:
        from repro.checkpoint.store import (
            CheckpointManager,
            CheckpointMismatchError,
            latest_step,
            load_pytree,
        )
    if resume_from is not None:
        step = latest_step(resume_from)
        if step is None:
            raise FileNotFoundError(
                f"resume_from={resume_from!r} holds no complete checkpoint"
            )
        saved = load_pytree(
            CheckpointManager(resume_from, save_every=1).dir_for(step)
        )
        saved_fp = (int(saved["meta"]["spec_fp_hi"]) << 32) | int(
            saved["meta"]["spec_fp_lo"]
        )
        if saved_fp != spec_fp:
            raise CheckpointMismatchError(
                f"checkpoint in {resume_from!r} was written by a different "
                "campaign spec — refusing to resume"
            )
        start_seg = int(saved["meta"]["next_seg"])
        occupant = np.asarray(saved["occupant"])
        link = type(link)(
            **{k: jnp.asarray(v) for k, v in saved["link"].items()}
        )
        if closed:
            sw_saved = dict(saved["sw"])
            rings = type(sw.rings)(
                **{k: jnp.asarray(v) for k, v in sw_saved.pop("rings").items()}
            )
            sw = type(sw)(
                rings=rings,
                **{k: jnp.asarray(v) for k, v in sw_saved.items()},
            )
            decisions_full = np.array(saved["decisions_full"])
            n_switches_id = np.array(saved["n_switches_id"])
        modes_full = np.array(saved["modes_full"])
        bank_slot_full = np.array(saved["bank_slot_full"])
        kpms_full = {k: np.array(v) for k, v in saved["kpms_full"].items()}
        outputs_full = {
            k: np.array(v) for k, v in saved["outputs_full"].items()
        }
    if checkpoint_dir is not None:
        mgr = CheckpointManager(checkpoint_dir, save_every=1)

    segs_run = 0
    for t0 in range(start_seg * seg, n_slots, seg):
        new_occupant = repack_bank(occupant, res[t0], n_cells=n_cells)
        perm = gather_permutation(occupant, new_occupant)
        link = gather_state_rows(link, perm, init_device_link(capacity))
        if closed:
            sw = gather_state_rows(sw, perm, cold_switch())
            nsw_base = np.asarray(sw.n_switches)
        occupant = new_occupant
        occ_c = np.maximum(occupant, 0)
        occupied = occupant >= 0
        slots_b = np.nonzero(occupied)[0]
        ids_b = occupant[slots_b]

        keys_seg = jnp.take(id_keys, jnp.asarray(occ_c), axis=0)
        params_seg = jax.tree.map(
            (lambda x: jnp.take(x[t0:t0 + seg], jnp.asarray(occ_c), axis=1))
            if per_ue_params
            else (lambda x: x[t0:t0 + seg]),
            params,
        )
        active = jnp.asarray(occupied)
        slot0 = jnp.int32(t0)
        if rf is not None:
            # a segment's fault masks follow occupant identity into slots
            fault_seg = tuple(
                jnp.asarray(m[t0:t0 + seg][:, occ_c])
                for m in (rf.decision_valid, rf.corrupt, rf.telemetry_valid)
            )
            corrupt_seg = fault_seg[1]

        if closed:
            if topo is None:
                link, sw, traj = engine._run_closed_scan(
                    profile, sw_cfg, link, sw, keys_seg, params_seg,
                    policy, slot0=slot0, active=active,
                    faults=faults,
                    fault_masks=None if rf is None else fault_seg,
                )
            elif rf is None:
                link, sw, traj = scan_fn(
                    link, sw, keys_seg, params_seg, policy,
                    cell_of_slot, cell_params, slot0, active,
                )
            else:
                link, sw, traj = scan_fn(
                    link, sw, keys_seg, params_seg, policy,
                    cell_of_slot, cell_params, slot0, active, fault_seg,
                )
        else:
            modes_seg = jnp.asarray(modes_grid[t0:t0 + seg][:, occ_c])
            if topo is None:
                link, traj = engine._run_scan(
                    profile, link, keys_seg, modes_seg, params_seg,
                    slot0=slot0, active=active,
                    faults=faults,
                    corrupt=None if rf is None else corrupt_seg,
                )
            elif rf is None:
                link, traj = scan_fn(
                    link, keys_seg, modes_seg, params_seg,
                    cell_of_slot, cell_params, slot0, active,
                )
            else:
                link, traj = scan_fn(
                    link, keys_seg, modes_seg, params_seg,
                    cell_of_slot, cell_params, slot0, active, corrupt_seg,
                )

        # -- host-side assembly on the stable-id axis ---------------------
        flat_kpms = {
            k: np.asarray(v)
            for k, v in flatten_kpm_sources(traj["kpms"]).items()
        }
        if not kpms_full:
            kpms_full.update({
                k: np.zeros((n_slots, n_ids), v.dtype)
                for k, v in flat_kpms.items()
            })
            outputs_full.update({
                k: np.zeros((n_slots, n_ids), np.asarray(v).dtype)
                for k, v in traj.items() if k not in _CLOSED_EXTRAS
            })
        for k, v in flat_kpms.items():
            _scatter_segment(kpms_full[k], v, t0, ids_b, slots_b)
        for k in outputs_full:
            _scatter_segment(outputs_full[k], traj[k], t0, ids_b, slots_b)
        if closed:
            _scatter_segment(
                modes_full, traj["active_mode"], t0, ids_b, slots_b
            )
            _scatter_segment(
                decisions_full, traj["raw_decision"], t0, ids_b, slots_b
            )
            delta = np.asarray(sw.n_switches) - nsw_base
            n_switches_id[ids_b] += delta[slots_b]
        else:
            _scatter_segment(modes_full, modes_seg, t0, ids_b, slots_b)
        bank_slot_full[t0:t0 + seg, ids_b] = slots_b[None, :]

        seg_idx = t0 // seg
        if mgr is not None:
            mgr.maybe_save(
                seg_idx + 1,
                _streaming_ckpt_state(
                    next_seg=seg_idx + 1,
                    spec_fp=spec_fp,
                    occupant=occupant,
                    link=link,
                    sw=sw,
                    modes_full=modes_full,
                    bank_slot_full=bank_slot_full,
                    decisions_full=decisions_full,
                    n_switches_id=n_switches_id,
                    kpms_full=kpms_full,
                    outputs_full=outputs_full,
                ),
                force=True,
            )
        segs_run += 1
        if on_segment is not None:
            stop = on_segment(SegmentEvent(
                seg_idx=seg_idx,
                n_segments=n_slots // seg,
                t0=t0,
                t1=t0 + seg,
                occupant=occupant.copy(),
                history=BatchedRunHistory(
                    modes=modes_full,
                    kpms=kpms_full,
                    outputs=outputs_full,
                    decisions=decisions_full,
                    n_switches=n_switches_id,
                    cell_of_ue=(
                        None if topo is None else home_cells(n_ids, n_cells)
                    ),
                    attached=res,
                    bank_slot=bank_slot_full,
                ),
            ))
            if stop:
                break
        if max_segments is not None and segs_run >= max_segments:
            break

    return BatchedRunHistory(
        modes=modes_full,
        kpms=kpms_full,
        outputs=outputs_full,
        decisions=decisions_full,
        n_switches=n_switches_id,
        cell_of_ue=(
            None if topo is None else home_cells(n_ids, n_cells)
        ),
        attached=res.copy(),
        bank_slot=bank_slot_full,
    )
