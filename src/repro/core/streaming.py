"""Epoch-chunked streaming campaigns: UE attach/detach under churn.

Every execution path in the repo compiles a fixed ``(n_slots, n_ues)`` grid;
a live gNB serves a *churning* population.  This module closes that gap with
the ROADMAP's streaming driver: the compiled scan executes in fixed-length
**segments** over a max-capacity UE *bank* (``CampaignSpec.n_ues`` bank
slots), an **active mask** rides the scan so detached bank slots are masked
out of KPM windows, throughput, executed-FLOPs and gated compaction demand,
and a host-side **admission pass** at each segment boundary re-packs the
resident UE set into bank slots (stable partition — the same discipline as
the gated compaction path — cell-block-aligned under a sharded topology).

The correctness currency is the repo's standing one, extended to churn:

* **identity is the stable UE id, not the bank slot** — per-UE PRNG streams
  derive from ``fold_in(key, ue_id)`` and per-slot keys fold the *global*
  slot index (the scan carry starts at the segment's ``slot0``), so a
  resident UE's trajectory is bitwise-identical whether it was re-packed
  zero or five times;
* **a zero-churn segmented run is bitwise-equal to the monolithic run** —
  with every bank slot attached the mask selects are identities and the
  boundary re-pack is the identity gather;
* **detach discards, attach cold-starts** — a reattached UE gets fresh
  ``DeviceLinkState`` / ``DeviceSwitchState`` rows at the boundary, so no
  stale telemetry leaks into its first post-attach decision.

``ChurnSchedule`` is the declarative (JSON-round-trippable) form, hashed
into ``CampaignSpec`` like ``TopologySpec``; ``run_streaming`` is the
driver ``ArchesSession.run_streaming`` dispatches to.

**Pipelined execution** (the default): JAX dispatch is asynchronous, so the
driver's main thread only *launches* segment scans — gather the carry,
enqueue the compiled program, hand the un-materialized trajectory to a
single assembly worker — while the worker synchronizes segment k
(``block_until_ready``), scatters it into the id-axis accumulators, writes
its checkpoint and fires ``on_segment``, all under segment k+1's device
compute.  The scan carries are *donated*
(``jax.jit(..., donate_argnums=...)``) so the steady-state loop re-uses one
carry allocation; anything the worker still needs past the donation point
(the carry snapshot for checkpointing, the pre/post switch counters) is
explicitly ``jnp.copy``'d before the next launch.  Segments are assembled
strictly in order, and a stop (``on_segment`` truthy / worker exception)
discards any speculatively launched segments un-assembled and
un-checkpointed — so the pipelined driver is observably identical
(bitwise, on every history leaf and every checkpoint) to ``pipeline=False``.

**Incremental checkpoints** (the default ``checkpoint_format="delta"``):
instead of re-writing the whole campaign history each boundary
(O(n_slots x n_ids) bytes per segment, quadratic total I/O), each segment
persists only its own ``[t0, t1)`` history rows plus the O(capacity) scan
carry, manifest-chained via ``repro.checkpoint.store.STREAMING_DELTA_KIND``;
``resume_from=`` replays the chain (anchored on a legacy monolithic
checkpoint when one starts it), bitwise-equal to the uninterrupted run.
"""

from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

_EVENT_KINDS = ("attach", "detach")

#: closed-loop trajectory leaves that are not campaign outputs
_CLOSED_EXTRAS = ("active_mode", "raw_decision", "pending_mode", "kpms")


@dataclasses.dataclass(frozen=True)
class ChurnSchedule:
    """Declarative attach/detach schedule over a stable UE-id universe.

    ``n_ue_ids`` sizes the id universe (ids ``0..n_ue_ids-1`` — history and
    PRNG identity live on this axis; it may exceed the bank capacity as
    long as concurrent residency never does).  ``segment_slots`` is the
    epoch length: the compiled scan runs in segments of this many slots and
    churn takes effect only at segment boundaries — an event at slot ``t``
    becomes effective at the first segment start ``>= t`` (events whose
    boundary lies past the campaign horizon never take effect).

    ``initial`` lists the ids attached at slot 0; ``events`` is a tuple of
    ``(slot, ue_id, "attach" | "detach")`` triples.  Attaching an attached
    id or detaching an absent one is a validation error (the admission pass
    is declarative, not idempotent), as is residency exceeding the bank
    capacity — all surfaced at spec time, never as a scan-shape error.
    """

    n_ue_ids: int
    segment_slots: int
    initial: tuple = ()
    events: tuple = ()

    def __post_init__(self):
        if self.n_ue_ids < 1:
            raise ValueError(f"n_ue_ids {self.n_ue_ids} must be >= 1")
        if self.segment_slots < 1:
            raise ValueError(
                f"segment_slots {self.segment_slots} must be >= 1"
            )
        initial = tuple(int(u) for u in self.initial)
        if len(set(initial)) != len(initial):
            raise ValueError(f"initial {initial} repeats UE ids")
        object.__setattr__(self, "initial", initial)
        events = []
        for ev in self.events:
            slot, ue, kind = ev
            if str(kind) not in _EVENT_KINDS:
                raise ValueError(
                    f"event kind {kind!r}; one of {_EVENT_KINDS}"
                )
            if int(slot) < 0:
                raise ValueError(f"event slot {slot} must be >= 0")
            events.append((int(slot), int(ue), str(kind)))
        object.__setattr__(self, "events", tuple(events))
        for u in self.initial + tuple(u for _, u, _ in self.events):
            if not 0 <= u < self.n_ue_ids:
                raise ValueError(
                    f"UE id {u} outside [0, {self.n_ue_ids})"
                )

    def residency(self, n_slots: int) -> np.ndarray:
        """Per-slot attachment matrix ``(n_slots, n_ue_ids)`` (bool).

        Piecewise constant per segment by construction.  Raises on an
        inconsistent event stream (attach-while-attached /
        detach-while-absent among the events that take effect within the
        horizon).
        """
        seg = self.segment_slots
        if n_slots < 1:
            raise ValueError(f"n_slots {n_slots} must be >= 1")
        if n_slots % seg:
            raise ValueError(
                f"segment_slots={seg} does not divide n_slots={n_slots}: "
                "the streaming scan compiles one fixed segment length"
            )
        attached = np.zeros(self.n_ue_ids, bool)
        attached[list(self.initial)] = True
        by_boundary: dict[int, list] = {}
        for slot, ue, kind in self.events:
            eff = ((slot + seg - 1) // seg) * seg
            if eff >= n_slots:
                continue  # boundary past the horizon: never effective
            by_boundary.setdefault(eff, []).append((slot, ue, kind))
        out = np.zeros((n_slots, self.n_ue_ids), bool)
        for t0 in range(0, n_slots, seg):
            for slot, ue, kind in by_boundary.get(t0, ()):
                if kind == "attach":
                    if attached[ue]:
                        raise ValueError(
                            f"attach of UE {ue} at slot {slot}: already "
                            "attached at its effective boundary "
                            f"(segment start {t0})"
                        )
                    attached[ue] = True
                else:
                    if not attached[ue]:
                        raise ValueError(
                            f"detach of UE {ue} at slot {slot}: not "
                            "attached at its effective boundary "
                            f"(segment start {t0})"
                        )
                    attached[ue] = False
            out[t0:t0 + seg] = attached
        return out

    def validate(
        self, n_slots: int, capacity: int, *, n_cells: int = 1
    ) -> np.ndarray:
        """Check the schedule against a campaign shape; return residency.

        ``capacity`` is the bank width (``CampaignSpec.n_ues``).  Under a
        multi-cell topology the bank is partitioned into ``n_cells`` equal
        contiguous blocks and each id's home cell is
        ``ue_id // (n_ue_ids / n_cells)`` — per-cell residency must fit the
        cell's block so the admission pass can stay cell-block-aligned
        (which is what keeps re-packing free of cross-shard movement).
        """
        res = self.residency(n_slots)
        if n_cells < 1:
            raise ValueError(f"n_cells {n_cells} must be >= 1")
        if n_cells == 1:
            worst = int(res.sum(axis=1).max(initial=0))
            if worst > capacity:
                raise ValueError(
                    f"churn residency peaks at {worst} UEs but the bank "
                    f"holds {capacity}: raise n_ues or thin the schedule"
                )
            return res
        if self.n_ue_ids % n_cells:
            raise ValueError(
                f"n_cells={n_cells} does not divide n_ue_ids="
                f"{self.n_ue_ids}: ids map to home cells in equal blocks"
            )
        if capacity % n_cells:
            raise ValueError(
                f"n_cells={n_cells} does not divide the bank capacity "
                f"{capacity}"
            )
        block = capacity // n_cells
        cells = home_cells(self.n_ue_ids, n_cells)
        for c in range(n_cells):
            worst = int(res[:, cells == c].sum(axis=1).max(initial=0))
            if worst > block:
                raise ValueError(
                    f"cell {c} residency peaks at {worst} UEs but its "
                    f"bank block holds {block}"
                )
        return res


def home_cells(n_ue_ids: int, n_cells: int) -> np.ndarray:
    """Stable-id -> home-cell map ((n_ue_ids,) int32, contiguous blocks)."""
    return (np.arange(n_ue_ids) // (n_ue_ids // n_cells)).astype(np.int32)


def repack_bank(
    prev_occupant: np.ndarray,
    resident: np.ndarray,
    *,
    n_cells: int = 1,
) -> np.ndarray:
    """Admission pass: stable-partition the resident set into bank slots.

    ``prev_occupant (B,)`` holds the previous segment's occupant id per
    bank slot (-1 empty); ``resident (n_ue_ids,)`` is the new segment's
    attachment vector.  Surviving occupants compact to the front of their
    (cell-block) slot range *preserving pack order* — the same stable
    partition the gated compaction path uses — and newly attached ids
    append in ascending id order; remaining slots are empty (-1).

    Deterministic, so the whole occupancy timeline is a pure function of
    the ``ChurnSchedule``.
    """
    prev_occupant = np.asarray(prev_occupant)
    resident = np.asarray(resident, bool)
    capacity = prev_occupant.shape[0]
    if capacity % n_cells:
        raise ValueError(
            f"n_cells={n_cells} does not divide capacity={capacity}"
        )
    cells = home_cells(resident.shape[0], n_cells)
    block = capacity // n_cells
    occ = np.full(capacity, -1, prev_occupant.dtype)
    for c in range(n_cells):
        lo = c * block
        prev_block = [int(u) for u in prev_occupant[lo:lo + block] if u >= 0]
        survivors = [u for u in prev_block if resident[u]]
        newcomers = sorted(
            int(u) for u in np.nonzero(resident & (cells == c))[0]
            if u not in set(prev_block)
        )
        packed = survivors + newcomers
        if len(packed) > block:
            raise ValueError(
                f"cell {c}: {len(packed)} resident UEs for a {block}-slot "
                "bank block (validate the churn schedule first)"
            )
        occ[lo:lo + len(packed)] = packed
    return occ


def gather_permutation(
    prev_occupant: np.ndarray, new_occupant: np.ndarray
) -> np.ndarray:
    """Per-bank-slot source index into the previous bank (-1 == cold start).

    Slot ``b``'s new occupant either survived from previous slot
    ``perm[b]`` (its device state rows are gathered from there) or is a
    fresh attach / empty slot (``perm[b] == -1`` — cold-init rows).
    """
    prev_pos = {int(u): j for j, u in enumerate(prev_occupant) if u >= 0}
    return np.asarray(
        [
            prev_pos.get(int(u), -1) if u >= 0 else -1
            for u in new_occupant
        ],
        np.int64,
    )


#: test hook — set True to disable the identity fast path so the gathered
#: path can be asserted bitwise-equal to it (tests/test_streaming.py)
_FORCE_GATHER = False


def is_identity_permutation(perm: np.ndarray) -> bool:
    """True iff every bank slot keeps its occupant (no cold rows, no moves).

    This is the zero-churn boundary: ``gather_state_rows`` is then the
    identity and can be skipped entirely.
    """
    perm = np.asarray(perm)
    return perm.size > 0 and bool(
        np.array_equal(perm, np.arange(perm.shape[0]))
    )


def gather_state_rows(state, perm: np.ndarray, cold_state):
    """Re-pack a per-UE device-state pytree along its leading bank axis.

    Survivor rows gather from their previous slot; ``perm < 0`` rows take
    the cold-start value from ``cold_state``.  An identity permutation with
    no cold rows returns every leaf value bitwise-unchanged (the zero-churn
    contract rides on this) — and is detected up front so a zero-churn
    boundary pays no gather at all: ``state`` is returned as-is, which is
    also what lets the donated carry buffer flow straight into the next
    segment's scan.
    """
    if not _FORCE_GATHER and is_identity_permutation(perm):
        return state
    take = jnp.asarray(np.maximum(perm, 0))
    cold = jnp.asarray(perm < 0)

    def one(prev_leaf, cold_leaf):
        g = jnp.take(prev_leaf, take, axis=0)
        m = cold.reshape(cold.shape + (1,) * (g.ndim - 1))
        return jnp.where(m, cold_leaf, g)

    return jax.tree.map(one, state, cold_state)


def _scatter_segment(full, seg_arr, t0, ids, slots):
    """full[t0:t0+seg, ids] = seg_arr[:, slots] (host-side assembly)."""
    full[t0:t0 + seg_arr.shape[0], ids] = np.asarray(seg_arr)[:, slots]


def _streaming_ckpt_state(
    *, next_seg, spec_fp, occupant, link, sw, modes_full, bank_slot_full,
    decisions_full, n_switches_id, kpms_full, outputs_full,
):
    """The crash-resume snapshot as an all-dict pytree (checkpoint-stable).

    Everything the segment loop carries across a boundary: the device scan
    carry (link + switch state as plain dicts of their NamedTuple fields),
    the UE bank occupancy, and the host-side accumulators.  All-dict so the
    templateless ``load_pytree`` rebuilds it exactly from the manifest.
    """
    state = {
        "meta": {
            # x64 is off, so 64-bit leaves would silently truncate on the
            # jnp round-trip — the fingerprint ships as two uint32 halves
            "next_seg": np.int32(next_seg),
            "spec_fp_hi": np.uint32(spec_fp >> 32),
            "spec_fp_lo": np.uint32(spec_fp & 0xFFFFFFFF),
        },
        "occupant": np.asarray(occupant),
        "link": dict(link._asdict()),
        "modes_full": modes_full,
        "bank_slot_full": bank_slot_full,
        "kpms_full": dict(kpms_full),
        "outputs_full": dict(outputs_full),
    }
    if sw is not None:
        sw_d = dict(sw._asdict())
        # the telemetry ring is itself a NamedTuple — expand it so the
        # snapshot stays an all-dict tree (templateless reload rebuilds
        # nested dicts, not NamedTuples)
        sw_d["rings"] = dict(sw.rings._asdict())
        state["sw"] = sw_d
        state["decisions_full"] = decisions_full
        state["n_switches_id"] = n_switches_id
    return state


def _delta_ckpt_state(
    *, next_seg, spec_fp, t0, t1, occupant, link, sw, modes_full,
    bank_slot_full, decisions_full, n_switches_id, kpms_full, outputs_full,
):
    """One segment's incremental snapshot (all-dict, checkpoint-stable).

    O(seg x n_ids) bytes — the segment's own ``[t0, t1)`` history rows —
    plus the O(capacity) scan carry and bank occupancy, independent of how
    long the campaign has been running.  ``resume_from`` rebuilds the full
    accumulators by replaying every delta's row band in chain order; the
    carry/occupancy/counters in the *last* delta are the live loop state.
    """
    state = {
        "meta": {
            "next_seg": np.int32(next_seg),
            "spec_fp_hi": np.uint32(spec_fp >> 32),
            "spec_fp_lo": np.uint32(spec_fp & 0xFFFFFFFF),
            "t0": np.int32(t0),
            "t1": np.int32(t1),
        },
        "occupant": np.asarray(occupant),
        "link": dict(link._asdict()),
        "rows": {
            "modes": modes_full[t0:t1],
            "bank_slot": bank_slot_full[t0:t1],
            "kpms": {k: v[t0:t1] for k, v in kpms_full.items()},
            "outputs": {k: v[t0:t1] for k, v in outputs_full.items()},
        },
    }
    if sw is not None:
        sw_d = dict(sw._asdict())
        sw_d["rings"] = dict(sw.rings._asdict())
        state["sw"] = sw_d
        state["rows"]["decisions"] = decisions_full[t0:t1]
        # cumulative per-id counter: O(n_ids), cheap enough to ship whole
        state["n_switches_id"] = n_switches_id
    return state


def _dir_bytes(directory: str) -> int:
    """Total payload bytes of one checkpoint directory (bench/stats)."""
    total = 0
    for name in os.listdir(directory):
        p = os.path.join(directory, name)
        if os.path.isfile(p):
            total += os.path.getsize(p)
    return total


def _spec_fingerprint(spec) -> int:
    """64-bit view of ``spec_hash`` (checkpointable as a uint64 leaf)."""
    from repro.core.session import spec_hash

    return int(spec_hash(spec), 16) & 0xFFFFFFFFFFFFFFFF


@dataclasses.dataclass(frozen=True)
class SegmentEvent:
    """What ``run_streaming`` hands to ``on_segment`` after each segment.

    Fired once per *completed* segment, after the checkpoint (when armed)
    has been durably written — so anything the callback observes is also
    recoverable.  Under the pipelined executor the callback runs on the
    assembly worker thread, still strictly in segment order.

    ``history`` is a ``BatchedRunHistory`` view over the driver's live
    full-campaign accumulators: slots ``[0, t1)`` are populated, later
    slots still carry their detached fill values.  ``segment_history`` is
    the O(segment) view of the same accumulators restricted to this
    segment's ``[t0, t1)`` rows (every 2-D leaf has leading dim
    ``t1 - t0``; the cumulative ``n_switches`` stays per-id) — telemetry
    consumers should reduce *it*, so per-boundary cost never grows with
    ``t0``.  Both are views into reused arrays — consumers that retain
    data past the callback must copy
    (``repro.core.telemetry.segment_telemetry`` reduces the span to plain
    floats, which is the intended use).
    """

    seg_idx: int  # 0-based index of the segment that just completed
    n_segments: int  # total segments in the campaign horizon
    t0: int  # first slot of the segment
    t1: int  # one past the segment's last slot
    occupant: np.ndarray  # (capacity,) bank occupancy after this segment
    history: "object"  # full-campaign BatchedRunHistory view (see above)
    segment_history: "object" = None  # [t0, t1) span view (see above)


def run_streaming(
    session,
    *,
    checkpoint_dir: str | None = None,
    resume_from: str | None = None,
    max_segments: int | None = None,
    on_segment=None,
    pipeline: bool = True,
    checkpoint_format: str = "delta",
    stats: dict | None = None,
) -> "object":
    """Execute an epoch-chunked streaming campaign; one compiled segment.

    The driver: validate churn -> resolve the scenario over the *stable-id*
    axis -> loop segments (admission re-pack, state gather/cold-init —
    skipped entirely at zero-churn boundaries via the identity fast path —
    per-occupant param/mode/key gather, one cached scan call with the
    active mask and the global ``slot0``, carries donated) -> assemble the
    full ``BatchedRunHistory`` on the id axis (detached slot-UEs carry the
    ``-1`` mode sentinel, zeroed KPMs/outputs, ``attached=False`` and
    ``bank_slot=-1``).

    Because segment shapes are fixed and ``slot0``/``active`` are traced,
    every segment reuses one compiled program per execution path.

    ``pipeline=True`` (default) overlaps segment k's host-side assembly,
    telemetry and checkpoint write with segment k+1's device scan: the
    main thread only launches async scans, a single worker thread
    synchronizes and assembles strictly in order behind a bounded
    double-buffer queue (see the module docstring).  ``pipeline=False``
    is the serial reference; both produce bitwise-identical histories,
    checkpoints and event streams.

    Crash resumability: with ``checkpoint_dir`` the driver snapshots the
    loop state through the atomic ``repro.checkpoint.store`` after *every
    completed segment* — as an O(segment) incremental delta chained in the
    manifest (``checkpoint_format="delta"``, default) or the legacy
    O(campaign) full snapshot (``"monolithic"``).  ``resume_from``
    restarts from the latest complete checkpoint in that directory (delta
    chains are replayed, anchored on a monolithic step when one starts
    them — so legacy directories resume unchanged and a legacy directory
    continued in delta format stays resumable) and — because each segment
    is a pure function of the checkpointed state and the (deterministic)
    schedule — the resumed run is bitwise-equal to the uninterrupted one
    on every history leaf.  ``max_segments`` stops after that many
    segments this call (the deterministic kill hook: the returned history
    covers only the slots run so far; later segments keep their detached
    fill values).

    ``on_segment`` is the long-running-service hook: called with a
    ``SegmentEvent`` after every completed segment (after its checkpoint,
    when one is armed, has been durably written).  A truthy return stops
    the drive loop there — the graceful-drain primitive: the segment in
    flight finishes, its checkpoint lands, speculatively launched segments
    are discarded un-assembled, and a later ``resume_from`` continues
    bitwise from exactly that boundary.

    ``stats`` (optional dict) is filled with the per-phase wall-time
    breakdown — ``dispatch_s`` (main-thread launch work), ``wait_s``
    (assembly blocked on device compute), ``assembly_s`` (host scatter),
    ``checkpoint_s`` (durable writes), ``checkpoint_bytes`` (per-segment
    checkpoint payload sizes) — which is what
    ``benchmarks/bench_streaming.py`` reports.
    """
    from repro.core.closed_loop import init_device_switch
    from repro.core.runtime import BatchedRunHistory
    from repro.core.session import ExecutionPath
    from repro.core.telemetry import flatten_kpm_sources
    from repro.phy.channel import broadcast_params_to_ues
    from repro.phy.pipeline import (
        init_device_link,
        normalize_modes,
        resolve_schedule,
    )

    if checkpoint_format not in ("delta", "monolithic"):
        raise ValueError(
            f"checkpoint_format {checkpoint_format!r}: expected 'delta' "
            "or 'monolithic'"
        )

    spec = session.spec
    churn = spec.churn
    if churn is None:
        raise ValueError("run_streaming needs spec.churn (a ChurnSchedule)")
    path = spec.execution_path
    if path not in (
        ExecutionPath.BATCHED, ExecutionPath.GATED, ExecutionPath.CLOSED_LOOP
    ):
        raise ValueError(
            f"streaming supports batched/gated/closed_loop, not "
            f"{spec.path!r} (the host loop serves one pinned UE and the "
            "perturbed sweep has no notion of churn)"
        )
    closed = path is ExecutionPath.CLOSED_LOOP

    topo = session.cell_topology
    n_cells = 1 if topo is None else topo.n_cells
    capacity = spec.n_ues  # bank width == the compiled batch width
    n_ids, n_slots = churn.n_ue_ids, spec.n_slots
    seg = churn.segment_slots
    res = churn.validate(n_slots, capacity, n_cells=n_cells)

    # fault masks live on the stable-id axis (a UE's fault stream follows
    # its identity through re-packs); segments column-gather by occupant
    faults = spec.faults
    rf = None if faults is None else faults.resolve(n_slots, n_ids)

    engine = session.engine
    profile, params = resolve_schedule(
        engine.cfg, session.schedule, n_slots, n_ids
    )
    per_ue_params = jnp.ndim(params.noise_var) == 2
    if topo is not None and not per_ue_params:
        params = broadcast_params_to_ues(params, n_ids)
        per_ue_params = True

    key = jax.random.PRNGKey(spec.seed)
    id_keys = jax.vmap(lambda u: jax.random.fold_in(key, u))(
        jnp.arange(n_ids)
    )

    modes_grid = None
    sw_cfg = policy = None
    if closed:
        sw_cfg = spec.switch.to_config(spec.feature_names)
        policy = session.device_policy
    else:
        modes_grid = np.asarray(
            normalize_modes(
                np.asarray(spec.modes, np.int32), n_slots, n_ids
            )
        )

    if topo is not None:
        from repro.core.topology import (
            _cached_jit,
            streaming_closed_loop_fn,
            streaming_open_loop_fn,
        )

        # the streaming programs donate their carry args (link0 [, sw0]) —
        # the "donate" key marker keeps them cached apart from any
        # non-donating build of the same program
        if closed:
            scan_fn = _cached_jit(
                topo,
                (engine, "streaming_closed", profile, sw_cfg,
                 jax.tree.structure(policy), faults, "donate"),
                lambda: streaming_closed_loop_fn(
                    engine, topo, profile, sw_cfg, policy, faults=faults
                ),
                donate_argnums=(0, 1),
            )
        else:
            scan_fn = _cached_jit(
                topo,
                (engine, "streaming_open", profile, faults, "donate"),
                lambda: streaming_open_loop_fn(
                    engine, topo, profile, faults=faults
                ),
                donate_argnums=(0,),
            )
        cell_of_slot = jnp.asarray(topo.cell_of_ue)
        cell_params = topo.cell_params

    def cold_switch():
        return init_device_switch(
            capacity, len(sw_cfg.feature_names), sw_cfg, faults
        )

    # bank state
    occupant = np.full(capacity, -1, np.int64)
    link = init_device_link(capacity)
    sw = cold_switch() if closed else None

    # full-campaign accumulators on the stable-id axis
    modes_full = np.full((n_slots, n_ids), -1, np.int32)
    bank_slot_full = np.full((n_slots, n_ids), -1, np.int32)
    decisions_full = (
        np.full((n_slots, n_ids), -1, np.int32) if closed else None
    )
    n_switches_id = np.zeros(n_ids, np.int32) if closed else None
    kpms_full: dict[str, np.ndarray] = {}
    outputs_full: dict[str, np.ndarray] = {}

    # -- crash resume: restore the whole loop state from the latest
    # complete checkpoint, then continue exactly where it left off.
    # ``resume_chain`` resolves the restore path: a monolithic anchor
    # (possibly legacy PR-8/9 format) plus the ascending delta steps
    # layered on top of it -----------------------------------------------
    spec_fp = _spec_fingerprint(spec)
    start_seg = 0
    mgr = None
    if checkpoint_dir is not None or resume_from is not None:
        from repro.checkpoint.store import (
            STREAMING_DELTA_KIND,
            CheckpointManager,
            CheckpointMismatchError,
            load_pytree,
            resume_chain,
        )

    def _restore_carry(saved):
        nonlocal occupant, link, sw
        occupant = np.asarray(saved["occupant"])
        link = type(link)(
            **{k: jnp.asarray(v) for k, v in saved["link"].items()}
        )
        if closed:
            sw_saved = dict(saved["sw"])
            rings = type(sw.rings)(
                **{k: jnp.asarray(v) for k, v in sw_saved.pop("rings").items()}
            )
            sw = type(sw)(
                rings=rings,
                **{k: jnp.asarray(v) for k, v in sw_saved.items()},
            )

    def _check_fp(saved, step):
        saved_fp = (int(saved["meta"]["spec_fp_hi"]) << 32) | int(
            saved["meta"]["spec_fp_lo"]
        )
        if saved_fp != spec_fp:
            raise CheckpointMismatchError(
                f"checkpoint step {step} in {resume_from!r} was written by "
                "a different campaign spec — refusing to resume"
            )

    if resume_from is not None:
        anchor, delta_steps = resume_chain(resume_from)
        if anchor is None and not delta_steps:
            raise FileNotFoundError(
                f"resume_from={resume_from!r} holds no complete checkpoint"
            )
        rmgr = CheckpointManager(resume_from, save_every=1, keep=None)
        if anchor is not None:
            saved = load_pytree(rmgr.dir_for(anchor))
            _check_fp(saved, anchor)
            start_seg = int(saved["meta"]["next_seg"])
            _restore_carry(saved)
            if closed:
                decisions_full = np.array(saved["decisions_full"])
                n_switches_id = np.array(saved["n_switches_id"])
            modes_full = np.array(saved["modes_full"])
            bank_slot_full = np.array(saved["bank_slot_full"])
            kpms_full = {
                k: np.array(v) for k, v in saved["kpms_full"].items()
            }
            outputs_full = {
                k: np.array(v) for k, v in saved["outputs_full"].items()
            }
        for dstep in delta_steps:
            d = load_pytree(rmgr.dir_for(dstep))
            _check_fp(d, dstep)
            td0 = int(d["meta"]["t0"])
            td1 = int(d["meta"]["t1"])
            rows = d["rows"]
            if not kpms_full:
                kpms_full.update({
                    k: np.zeros((n_slots, n_ids), np.asarray(v).dtype)
                    for k, v in rows["kpms"].items()
                })
                outputs_full.update({
                    k: np.zeros((n_slots, n_ids), np.asarray(v).dtype)
                    for k, v in rows["outputs"].items()
                })
            modes_full[td0:td1] = np.asarray(rows["modes"])
            bank_slot_full[td0:td1] = np.asarray(rows["bank_slot"])
            for k in kpms_full:
                kpms_full[k][td0:td1] = np.asarray(rows["kpms"][k])
            for k in outputs_full:
                outputs_full[k][td0:td1] = np.asarray(rows["outputs"][k])
            if closed:
                decisions_full[td0:td1] = np.asarray(rows["decisions"])
        if delta_steps:
            # the last delta holds the live loop state
            start_seg = int(d["meta"]["next_seg"])
            _restore_carry(d)
            if closed:
                n_switches_id = np.array(d["n_switches_id"])
    if checkpoint_dir is not None:
        mgr = CheckpointManager(
            checkpoint_dir,
            save_every=1,
            # delta chains need every predecessor on disk; the legacy
            # monolithic format keeps its bounded keep-k policy
            keep=None if checkpoint_format == "delta" else 3,
        )

    # -- pipelined segment executor ---------------------------------------
    # The main thread below only *launches* work: admission re-pack, carry
    # gather (identity fast path at zero-churn boundaries), async scan
    # dispatch.  ``_assemble_segment`` — run strictly in segment order on
    # the worker thread (``pipeline=True``) or inline (``pipeline=False``)
    # — synchronizes, scatters into the id-axis accumulators, writes the
    # durable checkpoint and fires ``on_segment``.  The bounded queue is
    # the double buffer: at most 2 segments are ever in flight beyond the
    # one being assembled, bounding speculative device/trajectory memory.
    n_segments = n_slots // seg
    home = None if topo is None else home_cells(n_ids, n_cells)
    st = {
        "dispatch_s": 0.0,
        "wait_s": 0.0,
        "assembly_s": 0.0,
        "checkpoint_s": 0.0,
        "checkpoint_bytes": [],
    }
    n_assembled = [0]  # worker-owned; read by the main thread post-join

    # a delta must chain to its predecessor *on disk*: when this call
    # resumes into a directory that lacks step ``start_seg`` (e.g. resumed
    # from elsewhere), its first checkpoint is written monolithic so the
    # chain stays anchored
    need_anchor = (
        mgr is not None
        and checkpoint_format == "delta"
        and start_seg > 0
        and start_seg not in set(mgr.steps())
    )

    def _full_history(attached):
        return BatchedRunHistory(
            modes=modes_full,
            kpms=kpms_full,
            outputs=outputs_full,
            decisions=decisions_full,
            n_switches=n_switches_id,
            cell_of_ue=home,
            attached=attached,
            bank_slot=bank_slot_full,
        )

    def _assemble_segment(item) -> bool:
        """Sync + scatter + checkpoint + notify for one completed segment."""
        seg_idx, t0 = item["seg_idx"], item["t0"]
        t1 = t0 + seg
        ids_b, slots_b = item["ids_b"], item["slots_b"]
        t_a = time.perf_counter()
        traj = jax.block_until_ready(item["traj"])
        t_b = time.perf_counter()
        st["wait_s"] += t_b - t_a

        flat_kpms = {
            k: np.asarray(v)
            for k, v in flatten_kpm_sources(traj["kpms"]).items()
        }
        if not kpms_full:
            kpms_full.update({
                k: np.zeros((n_slots, n_ids), v.dtype)
                for k, v in flat_kpms.items()
            })
            outputs_full.update({
                k: np.zeros((n_slots, n_ids), np.asarray(v).dtype)
                for k, v in traj.items() if k not in _CLOSED_EXTRAS
            })
        for k, v in flat_kpms.items():
            _scatter_segment(kpms_full[k], v, t0, ids_b, slots_b)
        for k in outputs_full:
            _scatter_segment(outputs_full[k], traj[k], t0, ids_b, slots_b)
        if closed:
            _scatter_segment(
                modes_full, traj["active_mode"], t0, ids_b, slots_b
            )
            _scatter_segment(
                decisions_full, traj["raw_decision"], t0, ids_b, slots_b
            )
            delta = np.asarray(item["nsw_after"]) - np.asarray(
                item["nsw_base"]
            )
            n_switches_id[ids_b] += delta[slots_b]
        else:
            _scatter_segment(modes_full, item["modes_seg"], t0, ids_b, slots_b)
        bank_slot_full[t0:t1, ids_b] = slots_b[None, :]
        t_c = time.perf_counter()
        st["assembly_s"] += t_c - t_b

        if mgr is not None:
            step = seg_idx + 1
            as_delta = checkpoint_format == "delta" and not (
                need_anchor and seg_idx == start_seg
            )
            common = dict(
                next_seg=step,
                spec_fp=spec_fp,
                occupant=item["occupant"],
                link=item["ck_link"],
                sw=item["ck_sw"],
                modes_full=modes_full,
                bank_slot_full=bank_slot_full,
                decisions_full=decisions_full,
                n_switches_id=n_switches_id,
                kpms_full=kpms_full,
                outputs_full=outputs_full,
            )
            if as_delta:
                mgr.maybe_save(
                    step,
                    _delta_ckpt_state(t0=t0, t1=t1, **common),
                    force=True,
                    manifest_extra={
                        "kind": STREAMING_DELTA_KIND,
                        "prev_step": step - 1,
                    },
                )
            else:
                mgr.maybe_save(
                    step, _streaming_ckpt_state(**common), force=True
                )
            st["checkpoint_s"] += time.perf_counter() - t_c
            st["checkpoint_bytes"].append(_dir_bytes(mgr.dir_for(step)))
        n_assembled[0] += 1

        if on_segment is not None:
            return bool(on_segment(SegmentEvent(
                seg_idx=seg_idx,
                n_segments=n_segments,
                t0=t0,
                t1=t1,
                occupant=item["occupant"].copy(),
                history=_full_history(res),
                segment_history=BatchedRunHistory(
                    modes=modes_full[t0:t1],
                    kpms={k: v[t0:t1] for k, v in kpms_full.items()},
                    outputs={k: v[t0:t1] for k, v in outputs_full.items()},
                    decisions=(
                        None if decisions_full is None
                        else decisions_full[t0:t1]
                    ),
                    n_switches=n_switches_id,
                    cell_of_ue=home,
                    attached=res[t0:t1],
                    bank_slot=bank_slot_full[t0:t1],
                ),
            )))
        return False

    _done = object()
    stop_event = threading.Event()
    worker_error: list = [None]
    work_q: queue.Queue = queue.Queue(maxsize=2)

    def _assembly_worker():
        while True:
            item = work_q.get()
            if item is _done:
                return
            if stop_event.is_set():
                continue  # speculative launch after a stop: never assembled
            try:
                if _assemble_segment(item):
                    stop_event.set()
            except BaseException as e:  # re-raised in the caller post-join
                worker_error[0] = e
                stop_event.set()

    worker = None
    if pipeline:
        worker = threading.Thread(
            target=_assembly_worker,
            name="arches-streaming-assembly",
            daemon=True,
        )
        worker.start()

    dispatched = 0
    try:
        for t0 in range(start_seg * seg, n_slots, seg):
            if stop_event.is_set():
                break
            t_d = time.perf_counter()
            new_occupant = repack_bank(occupant, res[t0], n_cells=n_cells)
            perm = gather_permutation(occupant, new_occupant)
            link = gather_state_rows(link, perm, init_device_link(capacity))
            if closed:
                sw = gather_state_rows(sw, perm, cold_switch())
                # the carry is donated into the scan below — copy the
                # pre-segment switch counter out first
                nsw_base = jnp.copy(sw.n_switches)
            occupant = new_occupant
            occ_c = np.maximum(occupant, 0)
            occupied = occupant >= 0
            slots_b = np.nonzero(occupied)[0]
            ids_b = occupant[slots_b]

            keys_seg = jnp.take(id_keys, jnp.asarray(occ_c), axis=0)
            params_seg = jax.tree.map(
                (lambda x: jnp.take(
                    x[t0:t0 + seg], jnp.asarray(occ_c), axis=1
                ))
                if per_ue_params
                else (lambda x: x[t0:t0 + seg]),
                params,
            )
            active = jnp.asarray(occupied)
            slot0 = jnp.int32(t0)
            if rf is not None:
                # a segment's fault masks follow occupant identity into slots
                fault_seg = tuple(
                    jnp.asarray(m[t0:t0 + seg][:, occ_c])
                    for m in (
                        rf.decision_valid, rf.corrupt, rf.telemetry_valid
                    )
                )
                corrupt_seg = fault_seg[1]

            modes_seg = None
            if closed:
                if topo is None:
                    link, sw, traj = engine._run_closed_scan_streaming(
                        profile, sw_cfg, link, sw, keys_seg, params_seg,
                        policy, slot0=slot0, active=active,
                        faults=faults,
                        fault_masks=None if rf is None else fault_seg,
                    )
                elif rf is None:
                    link, sw, traj = scan_fn(
                        link, sw, keys_seg, params_seg, policy,
                        cell_of_slot, cell_params, slot0, active,
                    )
                else:
                    link, sw, traj = scan_fn(
                        link, sw, keys_seg, params_seg, policy,
                        cell_of_slot, cell_params, slot0, active, fault_seg,
                    )
            else:
                modes_seg = jnp.asarray(modes_grid[t0:t0 + seg][:, occ_c])
                if topo is None:
                    link, traj = engine._run_scan_streaming(
                        profile, link, keys_seg, modes_seg, params_seg,
                        slot0=slot0, active=active,
                        faults=faults,
                        corrupt=None if rf is None else corrupt_seg,
                    )
                elif rf is None:
                    link, traj = scan_fn(
                        link, keys_seg, modes_seg, params_seg,
                        cell_of_slot, cell_params, slot0, active,
                    )
                else:
                    link, traj = scan_fn(
                        link, keys_seg, modes_seg, params_seg,
                        cell_of_slot, cell_params, slot0, active,
                        corrupt_seg,
                    )

            item = {
                "seg_idx": t0 // seg,
                "t0": t0,
                "traj": traj,
                "ids_b": ids_b,
                "slots_b": slots_b,
                "occupant": occupant,
                "modes_seg": modes_seg,
            }
            if closed:
                item["nsw_base"] = nsw_base
                # post-segment counter: copied because the carry may be
                # donated into the *next* scan before assembly reads it
                item["nsw_after"] = jnp.copy(sw.n_switches)
            if mgr is not None:
                # checkpoint snapshot of the carry — same donation-liveness
                # rule; O(capacity), dispatched async like everything else
                item["ck_link"] = jax.tree.map(jnp.copy, link)
                item["ck_sw"] = (
                    None if sw is None else jax.tree.map(jnp.copy, sw)
                )
            st["dispatch_s"] += time.perf_counter() - t_d
            dispatched += 1

            if pipeline:
                while True:
                    try:
                        work_q.put(item, timeout=0.05)
                        break
                    except queue.Full:
                        if stop_event.is_set():
                            break  # stop landed mid-wait: discard the launch
            else:
                if _assemble_segment(item):
                    break
            if max_segments is not None and dispatched >= max_segments:
                break
    finally:
        if pipeline:
            work_q.put(_done)
            worker.join()
    if worker_error[0] is not None:
        raise worker_error[0]

    if stats is not None:
        stats.update(st)
        stats["segments"] = n_assembled[0]
        stats["pipeline"] = pipeline
        stats["checkpoint_format"] = (
            checkpoint_format if mgr is not None else None
        )

    return _full_history(res.copy())
