"""ArchesSession: one declarative entry point for every campaign shape.

The repo grew four ways to run the switched PHY — ``PuschPipeline.run_slot``
host loops, ``BatchedPuschPipeline.run`` / ``run_closed_loop`` /
``run_perturbed``, and an ``ArchesRuntime`` whose constructor wanted a
different kwarg bundle per mode.  This module replaces that sprawl with a
single declarative surface:

    spec = CampaignSpec(path="closed_loop", scenario="good_poor_good",
                        n_ues=4, n_slots=30,
                        policies=(PolicySpec(kind="tree"),))
    hist = ArchesSession(spec).run()          # -> BatchedRunHistory

``CampaignSpec`` is a frozen dataclass tree (scenario name + args, campaign
shape, expert-bank config, execution path, switch/policy config, seeds)
that round-trips to/from JSON (``to_json`` / ``from_json``; ``spec_hash``
fingerprints it) so benchmark snapshots carry full provenance.
``ArchesSession`` compiles the spec — AI params, expert bank, scenario
schedules from the registry (``repro.phy.scenario``), trained/exported
policies — and dispatches ``run()`` to one of five execution paths:

* ``host`` — the seed architecture: per-slot Python loop, decisions travel
  E3 agent -> dApp -> control inbox (single UE).
* ``batched`` — open-loop multi-UE scan with a declared mode plan.
* ``closed_loop`` — the decision path compiled into the scan
  (``ArchesRuntime.from_spec``); supports per-UE policy heterogeneity via
  ``policies`` + ``policy_assignment`` (a ``PerUEPolicy`` table bank).
* ``gated`` — open-loop batched with compaction-gated expert execution.
* ``perturbed`` — the methodology stage-1 sweep (``rho`` rides the UE axis).

A spec with a ``topology`` (``repro.core.topology.TopologySpec``) runs the
same campaign as ``n_cells`` cells sharded over a 1-D UE device mesh: the
batched/gated/closed-loop/perturbed paths dispatch to the ``shard_map``
entries (per-shard gated compaction, per-cell channel offsets + inter-cell
coupling), and the history gains the per-cell reductions.  On a 1-device
mesh the sharded program is bitwise-equal to the unsharded one.

Every path returns the same ``BatchedRunHistory`` result type, and each is
bitwise-equal on mode trajectories to its legacy entry point (the session
builds the identical program; the test suite asserts it).
"""

from __future__ import annotations

import dataclasses
import enum
import hashlib
import json
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.closed_loop import SwitchConfig, per_ue_policy
from repro.core.expert_bank import ExecutionMode, coerce_enum
from repro.core.faults import FaultSpec
from repro.core.runtime import (
    ArchesRuntime,
    BatchedRunHistory,
    suggest_gated_capacity,
)
from repro.core.streaming import ChurnSchedule
from repro.core.telemetry import SELECTED_KPMS
from repro.core.topology import CellTopology, TopologySpec, per_shard_capacity

# -- execution paths -----------------------------------------------------------


class ExecutionPath(enum.Enum):
    """The campaign shapes ``ArchesSession.run`` dispatches over."""

    HOST = "host"
    BATCHED = "batched"
    CLOSED_LOOP = "closed_loop"
    GATED = "gated"
    PERTURBED = "perturbed"

    @classmethod
    def coerce(cls, value: "ExecutionPath | str") -> "ExecutionPath":
        return coerce_enum(cls, value, "execution path")


# -- spec tree -----------------------------------------------------------------


def _tuplify(x):
    """Recursively normalize to the spec's JSON-stable form: lists/arrays
    become tuples, numpy scalars become Python scalars."""
    if isinstance(x, (list, tuple)):
        return tuple(_tuplify(v) for v in x)
    if isinstance(x, (np.ndarray, jax.Array)):
        return _tuplify(np.asarray(x).tolist())
    if isinstance(x, np.generic):
        return x.item()
    return x


@dataclasses.dataclass(frozen=True)
class ExpertBankSpec:
    """Expert-bank + AI-estimator configuration (one bank per campaign).

    ``execution_mode`` is the bank's ``ExecutionMode`` value
    (``concurrent`` / ``gated`` / ``selected_only``); ``gated_capacity``
    sizes the compacted sub-batch (``None`` == full batch).  The AI expert
    is the paper's ResNet estimator with ``channels`` / ``n_res_blocks``
    and freshly initialized parameters from ``params_seed`` (campaigns
    study switching, not estimator quality; pass trained params to
    ``ArchesSession(ai_params=...)`` to override).

    ``fused=True`` (gated banks) runs the compact -> folded-GEMM -> scatter
    hot path as one kernel (``repro.kernels.gated_expert``) — bitwise-equal
    to the unfused triple, just fewer launches and no materialized
    sub-batch.  ``dtype`` selects the AI expert's GEMM operand precision
    (``"float32"`` — bitwise baseline — or ``"bfloat16"``), and
    ``audit_nmse_threshold`` arms the in-scan accuracy audit: a served
    UE whose gated output diverges from the fail-safe baseline by more
    than this NMSE (or goes NaN) reverts to the baseline and is flagged in
    the trajectory's ``audit_tripped`` leaf — the guard rail that makes
    reduced precision deployable.
    """

    execution_mode: str = "concurrent"
    gated_capacity: int | None = None
    use_pallas_switch: bool = True
    channels: int = 8
    n_res_blocks: int = 1
    params_seed: int = 0
    fused: bool = False
    dtype: str = "float32"
    audit_nmse_threshold: float | None = None

    def __post_init__(self):
        # normalize enum members to their JSON-stable string value
        object.__setattr__(
            self,
            "execution_mode",
            ExecutionMode.coerce(self.execution_mode).value,
        )
        if self.dtype not in ("float32", "bfloat16"):
            raise ValueError(
                f"dtype {self.dtype!r}; one of 'float32', 'bfloat16'"
            )
        mode = ExecutionMode.coerce(self.execution_mode)
        if self.fused and mode is not ExecutionMode.GATED:
            raise ValueError("fused=True requires execution_mode='gated'")
        if self.audit_nmse_threshold is not None:
            if mode is not ExecutionMode.GATED:
                raise ValueError(
                    "audit_nmse_threshold requires execution_mode='gated'"
                )
            if not self.audit_nmse_threshold > 0:
                raise ValueError(
                    f"audit_nmse_threshold {self.audit_nmse_threshold} "
                    "must be > 0"
                )


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """One switching policy, declaratively.

    ``kind="tree"`` — the paper's Gini decision tree, trained by profiling
    both experts on ``train_scenario`` + ``train_scenario_args`` for
    ``train_slots`` x ``train_ues`` slots per expert; deterministic given
    the spec (the profiling campaign uses the engine's fixed key
    derivation).  ``train_scenario=None`` defaults to the campaign
    scenario when that is homogeneous; for per-UE campaigns it falls back
    to ``good_poor_good`` with its poor window scaled into the training
    horizon (so short campaigns still see both labels — training on a
    single condition class yields a constant, never-switching tree).

    ``kind="threshold"`` — the single-KPM gate with hysteresis: ``feature``
    compared against ``threshold`` +- ``hysteresis``.
    """

    kind: str = "tree"
    depth: int = 2
    train_slots: int | None = None  # default: the campaign's n_slots
    train_ues: int = 2
    train_scenario: str | None = None
    train_scenario_args: tuple = ()
    feature: str = "snr"
    threshold: float = 18.0
    hysteresis: float = 0.0
    mode_above: int = 1
    mode_below: int = 0

    def __post_init__(self):
        if self.kind not in ("tree", "threshold"):
            raise ValueError(f"unknown policy kind {self.kind!r}")
        object.__setattr__(
            self, "train_scenario_args", _tuplify(self.train_scenario_args)
        )


@dataclasses.dataclass(frozen=True)
class SwitchSpec:
    """Declarative form of ``SwitchConfig`` (+ the host loop's TTL).

    ``backend`` selects the in-scan tree evaluator (device paths only; the
    host dApp calls the policy object directly).  ``hysteresis_slots`` is
    an in-scan capability: the host path rejects values > 1 rather than
    silently ignoring them.
    """

    window_slots: int = 8
    hysteresis_slots: int = 1
    period_slots: int = 1
    default_mode: int = 1
    backend: str = "auto"
    # fail-safe decay horizon: the host loop's SlotSwitchState TTL, and —
    # under a FaultSpec — the device decision-age counter's decay threshold
    ttl_slots: int = 16

    def to_config(self, feature_names: Sequence[str]) -> SwitchConfig:
        return SwitchConfig(
            feature_names=tuple(feature_names),
            window_slots=self.window_slots,
            hysteresis_slots=self.hysteresis_slots,
            period_slots=self.period_slots,
            default_mode=self.default_mode,
            backend=self.backend,
            ttl_slots=self.ttl_slots,
        )


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """A whole campaign as data: serialize it, hash it, run it.

    ``scenario`` names a registry entry (``repro.phy.scenario``);
    ``scenario_args`` are its factory kwargs as ``(key, value)`` pairs
    (kept as pairs so the spec stays hashable and JSON-stable).  ``modes``
    is the open-loop mode plan for the batched/gated paths — a scalar or a
    nested tuple accepted by ``normalize_modes``.  ``policies`` +
    ``policy_assignment`` declare the decision side: one entry == every UE
    runs it; several + an ``(n_ues,)`` assignment == per-UE heterogeneity
    in the closed loop.  ``rho`` is the perturbation grid of the
    methodology path (it rides the UE axis, so ``n_ues == len(rho)``).
    ``topology`` (a ``TopologySpec`` or its dict form) shards the campaign
    as a multi-cell layout over the UE device mesh.

    ``churn`` (a ``repro.core.streaming.ChurnSchedule`` or its dict form)
    turns the campaign into an epoch-chunked *streaming* run: ``n_ues``
    becomes the bank capacity, the UE axis of the history becomes the
    schedule's stable-id universe, and ``run()`` dispatches to
    ``ArchesSession.run_streaming``.

    ``faults`` (a ``repro.core.faults.FaultSpec`` or its dict form) injects
    control-plane decision loss, expert-output corruption and telemetry
    loss into the device paths (batched / gated / closed loop, monolithic
    or streaming), arming the in-scan degradation ladder: TTL fail-safe
    decay, ``isfinite`` health screen + circuit breaker, and rolling-window
    masking.  A zero-fault spec is bitwise-identical to ``faults=None``.
    """

    path: str = "batched"
    scenario: str = "good_poor_good"
    scenario_args: tuple = ()
    n_ues: int = 4
    n_slots: int = 30
    n_prb: int = 24
    seed: int = 0
    modes: Any = 1
    bank: ExpertBankSpec = dataclasses.field(default_factory=ExpertBankSpec)
    policies: tuple = ()
    policy_assignment: tuple | None = None
    switch: SwitchSpec = dataclasses.field(default_factory=SwitchSpec)
    feature_names: tuple = SELECTED_KPMS
    rho: tuple | None = None
    # multi-cell sharded layout (None == single cell on one device)
    topology: TopologySpec | None = None
    # attach/detach schedule (None == monolithic fixed-grid campaign)
    churn: ChurnSchedule | None = None
    # fault-injection campaign (None == happy path, no fault machinery)
    faults: FaultSpec | None = None

    def __post_init__(self):
        # normalize an enum member to its JSON-stable string value
        object.__setattr__(self, "path", ExecutionPath.coerce(self.path).value)
        if self.topology is not None and not isinstance(
            self.topology, TopologySpec
        ):
            object.__setattr__(
                self, "topology", TopologySpec(**dict(self.topology))
            )
        if self.churn is not None and not isinstance(
            self.churn, ChurnSchedule
        ):
            object.__setattr__(
                self, "churn", ChurnSchedule(**dict(self.churn))
            )
        if self.faults is not None and not isinstance(
            self.faults, FaultSpec
        ):
            object.__setattr__(
                self, "faults", FaultSpec(**dict(self.faults))
            )
        for name in ("scenario_args", "policies", "feature_names"):
            object.__setattr__(self, name, _tuplify(getattr(self, name)))
        object.__setattr__(self, "modes", _tuplify(self.modes))
        for name in ("policy_assignment", "rho"):
            v = getattr(self, name)
            if v is not None:
                object.__setattr__(self, name, _tuplify(v))
        if self.n_ues < 1 or self.n_slots < 1:
            raise ValueError("n_ues and n_slots must be >= 1")
        for k, _ in self.scenario_args:
            if not isinstance(k, str):
                raise ValueError("scenario_args must be (name, value) pairs")
        if self.policy_assignment is not None:
            if not self.policies:
                raise ValueError(
                    "policy_assignment indexes spec.policies, which is empty"
                )
            if len(self.policy_assignment) != self.n_ues:
                raise ValueError(
                    f"policy_assignment has {len(self.policy_assignment)} "
                    f"entries for n_ues={self.n_ues}"
                )
            if not all(
                0 <= int(i) < len(self.policies)
                for i in self.policy_assignment
            ):
                raise ValueError("policy_assignment indexes out of range")
        # path/bank mismatches fail at spec construction (so also at
        # ``from_json``) with a clear message instead of a trace-time shape
        # error or a silently mispriced campaign
        bank_mode = ExecutionMode.coerce(self.bank.execution_mode)
        path = self.execution_path
        if path is ExecutionPath.GATED and bank_mode is (
            ExecutionMode.SELECTED_ONLY
        ):
            raise ValueError(
                "path='gated' with a 'selected_only' bank would silently "
                "run un-gated at the concurrent cost envelope; declare the "
                "bank 'gated' (or 'concurrent', which the path normalizes)"
            )
        if path is ExecutionPath.PERTURBED and bank_mode is not (
            ExecutionMode.CONCURRENT
        ):
            raise ValueError(
                f"path='perturbed' ignores the expert bank (stage 1 is "
                f"MMSE-only by construction); a {bank_mode.value!r} bank "
                "spec would never take effect — drop it"
            )
        if path is ExecutionPath.HOST and bank_mode is ExecutionMode.GATED:
            raise ValueError(
                "gated execution is the batched path: the host loop serves "
                "one UE and has no sub-batch to compact"
            )
        if self.topology is not None:
            if path is ExecutionPath.HOST:
                raise ValueError(
                    "a sharded topology needs a batched path: the host "
                    "loop serves one UE on one device"
                )
            if self.n_ues % self.topology.n_cells:
                raise ValueError(
                    f"topology n_cells={self.topology.n_cells} does not "
                    f"divide n_ues={self.n_ues}"
                )
        if self.churn is not None:
            if path not in (
                ExecutionPath.BATCHED,
                ExecutionPath.GATED,
                ExecutionPath.CLOSED_LOOP,
            ):
                raise ValueError(
                    f"churn campaigns stream the batched scan; "
                    f"path={self.path!r} has no segmented form (the host "
                    "loop serves one pinned UE, the perturbed sweep has no "
                    "notion of churn)"
                )
            if self.policy_assignment is not None:
                raise ValueError(
                    "policy_assignment is bank-slot-indexed; a churn "
                    "campaign re-packs bank slots, so per-UE policy "
                    "heterogeneity under churn is not supported — declare "
                    "one shared policy"
                )
            # capacity/divisibility/consistency all fail at spec-compile
            # time, never as a scan shape error mid-campaign
            self.churn.validate(
                self.n_slots,
                self.n_ues,
                n_cells=(
                    1 if self.topology is None else self.topology.n_cells
                ),
            )
        if self.faults is not None and path not in (
            ExecutionPath.BATCHED,
            ExecutionPath.GATED,
            ExecutionPath.CLOSED_LOOP,
        ):
            raise ValueError(
                f"fault injection targets the device scan; "
                f"path={self.path!r} has no in-scan fault machinery (the "
                "host loop models dApp failure via DApp.fail(), the "
                "perturbed sweep is MMSE-only)"
            )

    # -- derived views --------------------------------------------------------

    @property
    def execution_path(self) -> ExecutionPath:
        return ExecutionPath.coerce(self.path)

    @property
    def scenario_kwargs(self) -> dict:
        return dict(self.scenario_args)

    # -- JSON round trip -------------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "CampaignSpec":
        d = dict(d)
        if "bank" in d and not isinstance(d["bank"], ExpertBankSpec):
            d["bank"] = ExpertBankSpec(**d["bank"])
        if "switch" in d and not isinstance(d["switch"], SwitchSpec):
            d["switch"] = SwitchSpec(**d["switch"])
        if d.get("topology") is not None and not isinstance(
            d["topology"], TopologySpec
        ):
            d["topology"] = TopologySpec(**d["topology"])
        if d.get("churn") is not None and not isinstance(
            d["churn"], ChurnSchedule
        ):
            d["churn"] = ChurnSchedule(**d["churn"])
        if d.get("faults") is not None and not isinstance(
            d["faults"], FaultSpec
        ):
            d["faults"] = FaultSpec.from_dict(d["faults"])
        if "policies" in d:
            d["policies"] = tuple(
                p if isinstance(p, PolicySpec) else PolicySpec(**p)
                for p in d["policies"]
            )
        return cls(**d)

    def to_json(self) -> str:
        """Canonical JSON (sorted keys) — the provenance string."""
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "CampaignSpec":
        return cls.from_dict(json.loads(s))


def spec_hash(spec: CampaignSpec) -> str:
    """Short stable fingerprint of a spec's canonical JSON."""
    return hashlib.sha256(spec.to_json().encode()).hexdigest()[:16]


def as_streaming_spec(
    spec: CampaignSpec, *, max_segment_slots: int = 8
) -> CampaignSpec:
    """Lift a monolithic campaign spec into its streaming form.

    A spec that already declares ``churn`` is returned unchanged.  A
    churn-free batched/gated/closed-loop spec gains a synthesized
    full-residency ``ChurnSchedule`` (every bank slot attached at slot 0,
    no events) whose segment length is the largest divisor of ``n_slots``
    that is ``<= max_segment_slots`` — so the epoch-chunked driver can
    execute it in checkpointable segments while staying bitwise-equal to
    the monolithic ``ArchesSession.run()`` on every leaf (the zero-churn
    contract).  This is how ``repro.service.CampaignService`` makes every
    submitted campaign crash-resumable, churn or not.
    """
    if spec.churn is not None:
        return spec
    if spec.execution_path not in (
        ExecutionPath.BATCHED, ExecutionPath.GATED, ExecutionPath.CLOSED_LOOP
    ):
        raise ValueError(
            f"path={spec.path!r} has no streaming form (the host loop "
            "serves one pinned UE, the perturbed sweep has no segmented "
            "driver)"
        )
    if max_segment_slots < 1:
        raise ValueError(f"max_segment_slots {max_segment_slots} must be >= 1")
    seg = max(
        d for d in range(1, min(max_segment_slots, spec.n_slots) + 1)
        if spec.n_slots % d == 0
    )
    return dataclasses.replace(
        spec,
        churn=ChurnSchedule(
            n_ue_ids=spec.n_ues,
            segment_slots=seg,
            initial=tuple(range(spec.n_ues)),
        ),
    )


# -- the session façade --------------------------------------------------------


class ArchesSession:
    """Compile a ``CampaignSpec`` into runnable components and run it.

    Construction is lazy-but-cached: the slot config and scenario resolve
    immediately (cheap, and validation fails fast); AI params, engines and
    trained policies build on first use and are reused across ``run()``
    calls.  ``run()`` always returns a ``BatchedRunHistory`` — host-loop
    campaigns are lifted to the ``(n_slots, 1)`` shape — so downstream
    tooling (KPM series, ``suggest_gated_capacity``, benchmark snapshots)
    is path-agnostic.
    """

    def __init__(
        self,
        spec: CampaignSpec,
        *,
        ai_params: Any = None,
        host_policies: Sequence | None = None,
        engine: Any = None,
    ):
        """Overrides (all optional) let a caller reuse pre-built components:
        trained ``ai_params``, already-fitted ``host_policies``, or a
        compiled ``engine`` (which must match the spec's bank — the session
        trusts it)."""
        from repro.phy.nr import SlotConfig
        from repro.phy.scenario import get_scenario

        self.spec = spec
        self.path = spec.execution_path
        #: resolved sharded layout (None == single-device, single-cell)
        self.cell_topology = (
            CellTopology.build(spec.topology, spec.n_ues)
            if spec.topology is not None
            else None
        )
        self._validate()
        self.cfg = SlotConfig(n_prb=spec.n_prb)
        scenario = get_scenario(spec.scenario)
        # streaming campaigns instantiate per-UE scenarios over the
        # *stable-id* universe: channel conditions follow the UE identity,
        # not the bank slot it happens to be packed into
        n_scenario_ues = (
            spec.churn.n_ue_ids if spec.churn is not None else spec.n_ues
        )
        self.schedule = scenario.schedule(
            n_ues=n_scenario_ues if scenario.per_ue else None,
            **spec.scenario_kwargs,
        )
        self._ai_params = ai_params
        self._host_policies = (
            tuple(host_policies) if host_policies is not None else None
        )
        self._engine = engine
        self._train_engine = None
        self._pipeline = None
        self._device_policy = None

    # -- validation ------------------------------------------------------------

    def _validate(self) -> None:
        from repro.phy.scenario import get_scenario

        spec, path = self.spec, self.path
        bank_mode = ExecutionMode.coerce(spec.bank.execution_mode)
        if len(spec.policies) > 1 and spec.policy_assignment is None:
            raise ValueError(
                "several policies need an explicit policy_assignment "
                "(which UE runs which table)"
            )
        if path is ExecutionPath.HOST:
            if spec.n_ues != 1:
                raise ValueError("the host loop serves one UE: n_ues must be 1")
            if not spec.policies:
                raise ValueError("the host loop needs one PolicySpec")
            if get_scenario(spec.scenario).per_ue:
                raise ValueError(
                    f"scenario {spec.scenario!r} is per-UE; the host path "
                    "needs a homogeneous scenario"
                )
            if spec.switch.hysteresis_slots != 1:
                raise ValueError(
                    "the host E3/dApp loop has no hysteresis streak; "
                    "hysteresis_slots > 1 needs the closed_loop path"
                )
        if path is ExecutionPath.CLOSED_LOOP and not spec.policies:
            raise ValueError("closed_loop needs at least one PolicySpec")
        if path is ExecutionPath.PERTURBED:
            if spec.rho is None:
                raise ValueError("perturbed needs a rho grid")
            if len(spec.rho) != spec.n_ues:
                raise ValueError(
                    f"rho rides the UE axis: len(rho)={len(spec.rho)} "
                    f"must equal n_ues={spec.n_ues}"
                )
        # the path name is the declaration: "gated" implies a gated bank
        # (normalized on the session, never mutating the user's spec)
        self.bank_spec = (
            dataclasses.replace(spec.bank, execution_mode="gated")
            if path is ExecutionPath.GATED
            and bank_mode is ExecutionMode.CONCURRENT
            else spec.bank
        )
        # path='gated' + selected_only already raised in CampaignSpec
        # __post_init__, so after normalization the gated path always
        # carries a gated bank
        assert (
            path is not ExecutionPath.GATED
            or ExecutionMode.coerce(self.bank_spec.execution_mode)
            is ExecutionMode.GATED
        )
        if self.cell_topology is not None:
            topo = self.cell_topology
            if (
                ExecutionMode.coerce(self.bank_spec.execution_mode)
                is ExecutionMode.GATED
                and self.bank_spec.gated_capacity is not None
            ):
                # fail at spec-compile time, not as a scan shape error
                per_shard_capacity(
                    self.bank_spec.gated_capacity, topo.n_shards
                )
            declared_cells = spec.scenario_kwargs.get("n_cells")
            if declared_cells is None:
                # a cell-aware scenario factory not passed n_cells uses its
                # own default — that count must agree with the topology too
                import inspect

                p = inspect.signature(
                    get_scenario(spec.scenario).factory
                ).parameters.get("n_cells")
                if p is not None and p.default is not inspect.Parameter.empty:
                    declared_cells = p.default
            if declared_cells is not None and declared_cells != topo.n_cells:
                raise ValueError(
                    f"scenario lays out n_cells={declared_cells} but the "
                    f"topology lays out {topo.n_cells} cells — one cell "
                    "count per campaign (pass n_cells in scenario_args)"
                )

    # -- compiled components ---------------------------------------------------

    @property
    def net(self):
        from repro.phy.ai_estimator import AiEstimatorConfig

        return AiEstimatorConfig(
            channels=self.bank_spec.channels,
            n_res_blocks=self.bank_spec.n_res_blocks,
        )

    @property
    def ai_params(self):
        if self._ai_params is None:
            from repro.phy.ai_estimator import init_params

            self._ai_params = init_params(
                jax.random.PRNGKey(self.bank_spec.params_seed), self.cfg, self.net
            )
        return self._ai_params

    def _engine_capacity(self, campaign_capacity: int | None) -> int | None:
        """The engine-level gated capacity for a campaign-wide one.

        Compaction is shard-local under a topology, so the engine's
        capacity is the per-shard share of the campaign capacity.
        """
        if (
            campaign_capacity is None
            or self.cell_topology is None
            or ExecutionMode.coerce(self.bank_spec.execution_mode)
            is not ExecutionMode.GATED
        ):
            return campaign_capacity
        return per_shard_capacity(
            campaign_capacity, self.cell_topology.n_shards
        )

    def _build_engine(self, campaign_capacity: int | None):
        from repro.phy.pipeline import BatchedPuschPipeline

        bank = self.bank_spec
        return BatchedPuschPipeline(
            self.cfg,
            self.ai_params,
            net=self.net,
            execution_mode=ExecutionMode.coerce(bank.execution_mode),
            use_pallas_switch=bank.use_pallas_switch,
            gated_capacity=self._engine_capacity(campaign_capacity),
            fused_gated=bank.fused,
            expert_dtype=bank.dtype,
            audit_nmse_threshold=bank.audit_nmse_threshold,
        )

    @property
    def engine(self):
        """The batched multi-UE engine configured per the bank spec."""
        if self._engine is None:
            self._engine = self._build_engine(self.bank_spec.gated_capacity)
        return self._engine

    @property
    def pipeline(self):
        """The single-UE host pipeline (host path only)."""
        if self._pipeline is None:
            from repro.phy.pipeline import PuschPipeline

            bank = self.bank_spec
            self._pipeline = PuschPipeline(
                self.cfg,
                self.ai_params,
                net=self.net,
                execution_mode=ExecutionMode.coerce(bank.execution_mode),
                use_pallas_switch=bank.use_pallas_switch,
            )
        return self._pipeline

    def _training_engine(self):
        """A concurrent engine for expert profiling (shared when possible)."""
        mode = ExecutionMode.coerce(self.bank_spec.execution_mode)
        if mode is ExecutionMode.CONCURRENT:
            return self.engine
        if self._train_engine is None:
            from repro.phy.pipeline import BatchedPuschPipeline

            self._train_engine = BatchedPuschPipeline(
                self.cfg,
                self.ai_params,
                net=self.net,
                execution_mode=ExecutionMode.CONCURRENT,
                use_pallas_switch=self.bank_spec.use_pallas_switch,
            )
        return self._train_engine

    def _train_schedule(self, ps: PolicySpec):
        from repro.phy.scenario import get_scenario, good_poor_good_schedule

        if ps.train_scenario is not None:
            sc = get_scenario(ps.train_scenario)
            if sc.per_ue:
                raise ValueError(
                    f"train_scenario {ps.train_scenario!r} is per-UE; "
                    "policies train on one labelled condition stream"
                )
            return sc.schedule(**dict(ps.train_scenario_args))
        if callable(self.schedule):  # homogeneous campaign scenario
            return self.schedule
        # heterogeneous campaign: fall back to the paper's Fig. 9 stream
        # with the poor window scaled into the training horizon — the
        # default 100..200 window would sit past a short campaign's end and
        # label every slot 'good', training a constant tree
        n = ps.train_slots or self.spec.n_slots
        return good_poor_good_schedule(poor_start=n // 3, poor_end=2 * n // 3)

    @property
    def host_policies(self) -> tuple:
        """The host policy objects, trained/built per ``spec.policies``."""
        if self._host_policies is None:
            from repro.core.policy import ThresholdPolicy, profile_and_fit_tree

            built = []
            for ps in self.spec.policies:
                if ps.kind == "threshold":
                    built.append(
                        ThresholdPolicy(
                            feature_idx=self.spec.feature_names.index(ps.feature),
                            threshold=ps.threshold,
                            hysteresis=ps.hysteresis,
                            mode_above=ps.mode_above,
                            mode_below=ps.mode_below,
                        )
                    )
                else:
                    built.append(
                        profile_and_fit_tree(
                            self._training_engine(),
                            self._train_schedule(ps),
                            n_slots=ps.train_slots or self.spec.n_slots,
                            n_ues=ps.train_ues,
                            depth=ps.depth,
                            feature_names=self.spec.feature_names,
                        )
                    )
            self._host_policies = tuple(built)
        return self._host_policies

    @property
    def device_policy(self):
        """Exported device tables: one table, or a per-UE ``PerUEPolicy``."""
        if self._device_policy is None:
            spec = self.spec
            tables = tuple(p.to_device() for p in self.host_policies)
            if len(tables) == 1 and spec.policy_assignment is None:
                self._device_policy = tables[0]
            else:
                if spec.policy_assignment is None:
                    # only reachable via a host_policies override longer
                    # than spec.policies (spec-level specs validate earlier)
                    raise ValueError(
                        "several policies need an explicit policy_assignment"
                    )
                self._device_policy = per_ue_policy(
                    tables, spec.policy_assignment
                )
        return self._device_policy

    def host_replay(self, hist: BatchedRunHistory) -> dict:
        """Replay a closed-loop history through the host policy objects.

        The equivalence oracle, packaged with the session's own feature
        order, switch config and per-UE assignment so callers (quickstart,
        benchmarks) cannot drift from the in-scan stacking: returns
        ``host_replay_closed_loop``'s dict; compare ``hist.modes`` against
        ``result["active_mode"]`` for the bitwise contract.
        """
        from repro.core.closed_loop import host_replay_closed_loop

        spec = self.spec
        feats = np.stack(
            [hist.kpms[n] for n in spec.feature_names], axis=-1
        ).astype(np.float32)
        sw_cfg = spec.switch.to_config(spec.feature_names)
        trips = None
        if spec.faults is not None:
            # the device's recorded health/audit trips feed the oracle's
            # circuit breaker — the trip *predicate* runs on device (it
            # needs the expert outputs); the breaker state machine replays
            # on the host from the recorded trip record
            trips = np.zeros(hist.modes.shape, bool)
            for k in ("health_tripped", "audit_tripped"):
                if k in hist.outputs:
                    trips |= np.asarray(hist.outputs[k]) > 0
        attached = getattr(hist, "attached", None)
        if len(self.host_policies) == 1 and spec.policy_assignment is None:
            return host_replay_closed_loop(
                self.host_policies[0], feats, sw_cfg,
                faults=spec.faults, trips=trips, attached=attached,
            )
        assignment = (
            spec.policy_assignment
            if spec.policy_assignment is not None
            else (0,) * spec.n_ues
        )
        return host_replay_closed_loop(
            list(self.host_policies), feats, sw_cfg, policy_idx=assignment,
            faults=spec.faults, trips=trips, attached=attached,
        )

    # -- execution -------------------------------------------------------------

    def run(self, *, auto_capacity: bool = False) -> BatchedRunHistory:
        """Execute the campaign; one result type for every path.

        ``auto_capacity=True`` (gated banks only) sizes ``gated_capacity``
        from the campaign's own demand before the main run instead of
        trusting the declared knob: open-loop paths read peak demand
        straight off the declared mode plan (no extra compile); the closed
        loop runs a full-capacity pre-pass and feeds its realized demand to
        ``suggest_gated_capacity`` (two compiles, both host-driven).  The
        gated bank is re-provisioned with the chosen campaign-wide capacity
        ``K`` (rounded up to a per-shard-equal split under a topology) and
        the history records it in ``provisioned_capacity``.
        """
        if auto_capacity:
            return self._run_auto_capacity()
        if self.spec.churn is not None:
            return self.run_streaming()
        runner = {
            ExecutionPath.HOST: self._run_host,
            ExecutionPath.BATCHED: self._run_open_loop,
            ExecutionPath.GATED: self._run_open_loop,
            ExecutionPath.CLOSED_LOOP: self._run_closed_loop,
            ExecutionPath.PERTURBED: self._run_perturbed,
        }[self.path]
        return runner()

    def _run_auto_capacity(self) -> BatchedRunHistory:
        spec = self.spec
        if ExecutionMode.coerce(self.bank_spec.execution_mode) is not (
            ExecutionMode.GATED
        ):
            raise ValueError(
                "auto_capacity sizes a gated bank; this campaign's bank is "
                f"{self.bank_spec.execution_mode!r}"
            )
        if self.path in (ExecutionPath.GATED, ExecutionPath.BATCHED):
            # open loop: demand is the declared plan — no pre-pass needed.
            # A churn campaign's plan lives on the stable-id axis and only
            # *resident* slot-UEs claim capacity: the residency leaf rides
            # the demand history so suggest_gated_capacity counts resident
            # demand, not the (possibly much wider) id universe.
            from repro.phy.pipeline import normalize_modes

            n_axis = (
                spec.churn.n_ue_ids if spec.churn is not None else spec.n_ues
            )
            demand_hist = BatchedRunHistory(
                modes=np.asarray(
                    normalize_modes(
                        np.asarray(spec.modes, np.int32),
                        spec.n_slots, n_axis,
                    )
                ),
                kpms={}, outputs={},
                attached=(
                    None
                    if spec.churn is None
                    else spec.churn.residency(spec.n_slots)
                ),
            )
        elif self.path is ExecutionPath.CLOSED_LOOP:
            # pre-pass at full capacity (overflow impossible), then size
            # from the demand the decisions actually realized
            pre_spec = dataclasses.replace(
                spec,
                bank=dataclasses.replace(spec.bank, gated_capacity=None),
            )
            pre = ArchesSession(
                pre_spec,
                ai_params=self.ai_params,
                host_policies=self.host_policies,
            )
            demand_hist = pre.run()
        else:
            raise ValueError(
                f"auto_capacity does not apply to path={spec.path!r}"
            )
        n_shards = (
            1 if self.cell_topology is None else self.cell_topology.n_shards
        )
        if spec.churn is not None:
            # streaming: the demand axis is the stable-id universe, whose
            # width need not split across bank shards — size from the
            # campaign-wide *resident* demand, round up to a
            # per-shard-equal split and clip to the bank.  A shard-local
            # spike beyond its split overflows to the fail-safe expert,
            # the gated path's standing safe degradation.
            cap = suggest_gated_capacity(demand_hist)
            cap = min(
                max(-(-cap // n_shards), 1) * n_shards, spec.n_ues
            )
        else:
            # compaction is shard-local: provisioning covers the worst
            # *shard's* peak demand (a shard-local spike overflows even
            # when the campaign-wide count would fit), with >= 1 slot per
            # shard
            cap = max(
                suggest_gated_capacity(demand_hist, n_shards=n_shards),
                n_shards,
            )
        self._engine = self._build_engine(cap)
        if spec.churn is not None:
            runner = self.run_streaming
        elif self.path is ExecutionPath.CLOSED_LOOP:
            runner = self._run_closed_loop
        else:
            runner = self._run_open_loop
        return dataclasses.replace(runner(), provisioned_capacity=cap)

    def run_streaming(
        self,
        churn=None,
        *,
        checkpoint_dir=None,
        resume_from=None,
        max_segments=None,
        on_segment=None,
        pipeline=True,
        checkpoint_format="delta",
        stats=None,
    ) -> BatchedRunHistory:
        """Epoch-chunked streaming campaign: attach/detach under churn.

        Executes the compiled scan in fixed-length segments over the
        ``n_ues``-slot bank with a host-side admission pass at segment
        boundaries (``repro.core.streaming``).  ``churn`` overrides the
        spec's schedule for this run (a ``ChurnSchedule`` or its dict
        form); with a different schedule the campaign is re-validated and
        re-instantiated against it while reusing this session's compiled
        components (AI params, engine, trained policies) — the compiled
        segment program depends only on shapes, not on the schedule.

        Crash resumability: ``checkpoint_dir`` snapshots the loop state
        atomically after every completed segment — as O(segment)
        manifest-chained deltas by default, or the legacy O(campaign)
        full snapshot with ``checkpoint_format="monolithic"``;
        ``resume_from`` restarts from the latest complete checkpoint in
        that directory (delta chains replayed, legacy monolithic
        directories loadable unchanged), bitwise-equal to the
        uninterrupted run.  ``max_segments`` stops early after that many
        segments (the deterministic kill hook the resume tests use).
        ``on_segment`` receives a ``repro.core.streaming.SegmentEvent``
        after every completed (and, when armed, checkpointed) segment;
        returning truthy stops the drive loop at that boundary — the
        graceful-drain primitive ``repro.service.CampaignService`` builds
        on.  ``pipeline=False`` selects the serial reference executor
        (default: device scans overlap host assembly/checkpointing,
        bitwise-identical either way); ``stats`` (a dict) receives the
        per-phase wall-time breakdown.

        Returns a ``BatchedRunHistory`` on the *stable-id* axis: detached
        slot-UEs carry the ``-1`` mode sentinel and zeroed KPMs/outputs,
        and the ``attached`` / ``bank_slot`` leaves record residency and
        the serving bank slot per (slot, id).
        """
        from repro.core import streaming

        kw = dict(
            checkpoint_dir=checkpoint_dir,
            resume_from=resume_from,
            max_segments=max_segments,
            on_segment=on_segment,
            pipeline=pipeline,
            checkpoint_format=checkpoint_format,
            stats=stats,
        )
        if churn is not None:
            if not isinstance(churn, streaming.ChurnSchedule):
                churn = streaming.ChurnSchedule(**dict(churn))
            if churn != self.spec.churn:
                spec = dataclasses.replace(self.spec, churn=churn)
                fresh = ArchesSession(
                    spec,
                    ai_params=self._ai_params,
                    host_policies=self._host_policies,
                    engine=self._engine,
                )
                return streaming.run_streaming(fresh, **kw)
        if self.spec.churn is None:
            raise ValueError(
                "run_streaming needs a ChurnSchedule: set spec.churn or "
                "pass churn=..."
            )
        return streaming.run_streaming(self, **kw)

    def _run_host(self) -> BatchedRunHistory:
        from repro.core.dapp import DApp, connect_dapp
        from repro.core.e3 import E3Agent

        spec = self.spec
        agent = E3Agent()
        # the single UE may still be assigned any declared policy table
        pol = spec.policy_assignment[0] if spec.policy_assignment else 0
        dapp = DApp(
            self.host_policies[pol],
            spec.feature_names,
            window_slots=spec.switch.window_slots,
            period_slots=spec.switch.period_slots,
        )
        connect_dapp(agent, dapp)
        runtime = ArchesRuntime(
            self.pipeline.make_slot_fn(self.schedule),
            agent,
            default_mode=spec.switch.default_mode,
            fail_safe_mode=spec.switch.default_mode,
            ttl_slots=spec.switch.ttl_slots,
            keep_outputs=True,
        )
        return BatchedRunHistory.from_host(runtime.run(range(spec.n_slots)))

    @property
    def _cells(self):
        return (
            None
            if self.cell_topology is None
            else self.cell_topology.cell_of_ue
        )

    def _run_open_loop(self) -> BatchedRunHistory:
        from repro.phy.pipeline import normalize_modes

        spec = self.spec
        modes = normalize_modes(
            np.asarray(spec.modes, np.int32), spec.n_slots, spec.n_ues
        )
        if self.cell_topology is not None:
            from repro.core.topology import run_sharded

            _, traj = run_sharded(
                self.engine,
                self.cell_topology,
                self.schedule,
                modes,
                n_slots=spec.n_slots,
                key=jax.random.PRNGKey(spec.seed),
                faults=spec.faults,
            )
        else:
            _, traj = self.engine.run(
                self.schedule,
                modes,
                n_slots=spec.n_slots,
                n_ues=spec.n_ues,
                key=jax.random.PRNGKey(spec.seed),
                faults=spec.faults,
            )
        return BatchedRunHistory.from_trajectory(
            modes, traj, cell_of_ue=self._cells
        )

    def _run_closed_loop(self) -> BatchedRunHistory:
        spec = self.spec
        if self.cell_topology is not None:
            from repro.core.topology import run_closed_loop_sharded

            _, final_switch, traj = run_closed_loop_sharded(
                self.engine,
                self.cell_topology,
                self.schedule,
                self.device_policy,
                spec.switch.to_config(spec.feature_names),
                n_slots=spec.n_slots,
                key=jax.random.PRNGKey(spec.seed),
                faults=spec.faults,
            )
            return BatchedRunHistory.from_closed_loop(
                traj, final_switch, cell_of_ue=self._cells
            )
        runtime = ArchesRuntime.from_spec(
            spec, engine=self.engine, device_policy=self.device_policy
        )
        return runtime.run_batched(
            self.schedule,
            n_slots=spec.n_slots,
            n_ues=spec.n_ues,
            key=jax.random.PRNGKey(spec.seed),
            faults=spec.faults,
        )

    def _run_perturbed(self) -> BatchedRunHistory:
        spec = self.spec
        rho = jnp.asarray(spec.rho, jnp.float32)
        if self.cell_topology is not None:
            from repro.core.topology import run_perturbed_sharded

            _, traj = run_perturbed_sharded(
                self.engine,
                self.cell_topology,
                self.schedule,
                rho,
                n_slots=spec.n_slots,
                key=jax.random.PRNGKey(spec.seed),
            )
        else:
            _, traj = self.engine.run_perturbed(
                self.schedule,
                rho,
                n_slots=spec.n_slots,
                key=jax.random.PRNGKey(spec.seed),
            )
        # stage 1 is MMSE-only by construction: the mode grid is all-1
        modes = np.ones((spec.n_slots, spec.n_ues), np.int32)
        return BatchedRunHistory.from_trajectory(
            modes, traj, cell_of_ue=self._cells
        )
