"""In-scan closed-loop expert switching: the E3/dApp decision path on device.

The host control loop (``ArchesRuntime`` + ``DApp``) bounces every slot's
KPMs through Python and pays the paper's ~135 us framework overhead per
decision.  This module compiles the *whole* loop — telemetry window, policy
inference, hysteresis, switch register — into the slot scan, so the mode a
UE runs in slot ``n+1`` is derived on device from slot ``n``'s telemetry
with zero host involvement.

Pieces:

* ``DeviceTreePolicy`` / ``DeviceThresholdPolicy`` — host policies exported
  to flat device arrays (feature index / threshold / leaf-mode tables, plus
  the ``PackedTree`` MXU operands for the Pallas ``tree_infer`` kernel).
* ``PerUEPolicy`` — a stacked bank of exported tables with a ``(U,)``
  policy-index axis: UE ``u`` runs table ``policy_idx[u]`` inside the same
  scan (per-UE policy heterogeneity; ``per_ue_policy`` builds one).
* ``DeviceSwitchState`` — the scan-carry pytree: a per-UE rolling KPM window
  (``KPMRing`` vmapped over the UE axis), hysteresis streak counters, and
  the switch register (``pending_mode``) holding the mode that takes effect
  at the next slot boundary.
* ``switch_update`` / ``switch_boundary`` — the two phases of the paper's
  timing contract (3.3): a decision made *during* slot ``n`` is committed to
  the register; only the boundary into slot ``n+1`` copies it to
  ``active_mode``.  Mid-slot flips are impossible by construction.
* ``host_replay_closed_loop`` — the equivalence oracle: a slot-by-slot host
  loop feeding the same KPM window through the literal host policy
  (``DecisionTreePolicy.__call__`` -> ``tree_infer_ref`` walk).  Device and
  host mode trajectories must match bitwise; the test suite asserts it.

Policy *training* (Gini tree fitting) and the clustering methodology stay
offline/host-side, exactly as in the paper — only *inference* moves into
the scan.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.telemetry import KPMRing, ring_push, ring_window_mean
from repro.kernels.tree_infer import (
    PackedTree,
    pack_tree,
    tree_infer,
    tree_infer_ref,
)

# -- device policy tables -----------------------------------------------------


class DeviceTreePolicy(NamedTuple):
    """A fitted decision tree as flat device arrays.

    ``feature``/``threshold`` are the level-order internal-node tables
    (children of node ``n`` are ``2n+1``/``2n+2``; go right if
    ``x[feature] > threshold``); ``leaf_modes`` holds the int mode each of
    the ``2**depth`` leaves decides.  ``packed`` carries the same tree as
    the MXU operands ``repro.kernels.tree_infer`` consumes.  Depth is not
    stored: it is recovered statically from ``feature.shape``.
    """

    feature: jax.Array  # (2**d - 1,) int32
    threshold: jax.Array  # (2**d - 1,) float32
    leaf_modes: jax.Array  # (2**d,) float32
    packed: PackedTree

    @property
    def depth(self) -> int:
        return int(self.feature.shape[0] + 1).bit_length() - 1


class DeviceThresholdPolicy(NamedTuple):
    """``ThresholdPolicy`` as flat device scalars (single-KPM gate + band)."""

    feature_idx: jax.Array  # int32
    lo: jax.Array  # float32 — threshold - hysteresis
    hi: jax.Array  # float32 — threshold + hysteresis
    mode_above: jax.Array  # int32
    mode_below: jax.Array  # int32


class PerUEPolicy(NamedTuple):
    """Per-UE policy heterogeneity: a bank of exported tables + assignment.

    ``tables`` stacks the exported device policies (trees and/or threshold
    gates, any mix); ``policy_idx (U,)`` assigns each UE its table.
    ``policy_infer`` evaluates every table on the full ``(U, F)`` feature
    matrix and selects along the policy-index axis — all shapes static, so
    the heterogeneous decision path compiles into the slot scan unchanged,
    and each table's evaluation stays bitwise-identical to running that
    table alone.  Retires the ROADMAP open item: different UEs in one
    closed-loop campaign now run different exported policies.
    """

    tables: tuple  # tuple[DeviceTreePolicy | DeviceThresholdPolicy, ...]
    policy_idx: jax.Array  # (U,) int32 — table index per UE


def per_ue_policy(tables: "Sequence", assignment) -> PerUEPolicy:
    """Build a validated ``PerUEPolicy`` from tables + per-UE assignment."""
    tables = tuple(tables)
    if not tables:
        raise ValueError("per-UE policy needs at least one table")
    idx = np.asarray(assignment, np.int32)
    if idx.ndim != 1:
        raise ValueError(f"assignment must be (n_ues,), got {idx.shape}")
    if idx.min() < 0 or idx.max() >= len(tables):
        raise ValueError(
            f"assignment references tables outside [0, {len(tables)})"
        )
    return PerUEPolicy(tables=tables, policy_idx=jnp.asarray(idx))


DevicePolicy = DeviceTreePolicy | DeviceThresholdPolicy | PerUEPolicy


def export_tree_tables(
    feature: np.ndarray,
    threshold: np.ndarray,
    leaf_values: np.ndarray,
    n_features: int,
    depth: int,
) -> DeviceTreePolicy:
    """Densify level-order tree arrays into a ``DeviceTreePolicy``."""
    return DeviceTreePolicy(
        feature=jnp.asarray(feature, jnp.int32),
        threshold=jnp.asarray(threshold, jnp.float32),
        leaf_modes=jnp.asarray(leaf_values, jnp.float32),
        packed=pack_tree(
            np.asarray(feature), np.asarray(threshold), np.asarray(leaf_values),
            n_features, depth,
        ),
    )


def policy_infer(
    policy: DevicePolicy,
    x: jax.Array,
    prev_mode: jax.Array,
    *,
    backend: str = "auto",
) -> jax.Array:
    """Evaluate a device policy on ``x (U, F)`` -> int32 modes ``(U,)``.

    ``backend`` selects the tree evaluator: ``"pallas"`` runs the
    ``tree_infer`` MXU kernel, ``"ref"`` the vectorized literal walk, and
    ``"auto"`` picks pallas on TPU with the ref path as the CPU fallback.
    Both are bitwise-equivalent (the kernel's one-hot feature gather is an
    exact matmul); the kernel tests assert it.  ``prev_mode`` only matters
    for the threshold policy's keep-band.

    A ``PerUEPolicy`` evaluates each stacked table on the full batch and
    gathers along its ``(U,)`` policy-index axis — UE ``u`` gets table
    ``policy_idx[u]``'s decision, bitwise-equal to evaluating that table
    alone (selection never touches the per-table arithmetic).
    """
    if isinstance(policy, PerUEPolicy):
        outs = jnp.stack(
            [
                policy_infer(t, x, prev_mode, backend=backend)
                for t in policy.tables
            ],
            axis=0,
        )  # (P, U)
        return jnp.take_along_axis(
            outs, policy.policy_idx[None, :], axis=0
        )[0].astype(jnp.int32)
    if isinstance(policy, DeviceThresholdPolicy):
        v = x[:, policy.feature_idx]
        above = v > policy.hi
        below = v < policy.lo
        keep = jnp.logical_not(jnp.logical_or(above, below))
        return jnp.where(
            keep,
            jnp.asarray(prev_mode, jnp.int32),
            jnp.where(above, policy.mode_above, policy.mode_below),
        ).astype(jnp.int32)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "pallas":
        out = tree_infer(x.astype(jnp.float32), policy.packed)
    elif backend == "ref":
        out = tree_infer_ref(
            x.astype(jnp.float32),
            policy.feature,
            policy.threshold,
            policy.leaf_modes,
            policy.depth,
        )
    else:
        raise ValueError(f"unknown policy backend {backend!r}")
    return out.astype(jnp.int32)


# -- switch-register state ----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SwitchConfig:
    """Static configuration of the in-scan control loop.

    ``window_slots`` mirrors the dApp's telemetry window (decision input is
    the mean over the last ``window_slots`` slots, partial at cold start);
    ``hysteresis_slots`` is the number of *consecutive* disagreeing raw
    decisions required before the register is rewritten (1 == every
    decision commits, the paper's behaviour).  ``period_slots`` mirrors the
    dApp's decision periodicity: the policy is evaluated on slots where
    ``slot % period_slots == 0`` and the register holds its value in
    between (telemetry keeps accumulating every slot).  The register defers
    application to the next boundary regardless.

    ``ttl_slots`` is the fail-safe decay horizon under fault injection: a
    UE whose decision age (slots since the last *valid* decision slot)
    reaches it is forced to ``default_mode`` at the boundary, mirroring the
    host ``slot_boundary`` TTL exactly.  Only enforced when the campaign
    carries a ``FaultSpec``; a healthy loop needs ``ttl_slots >=
    period_slots`` to never age out (the zero-fault identity contract).
    """

    feature_names: tuple[str, ...]
    window_slots: int = 8
    hysteresis_slots: int = 1
    period_slots: int = 1
    default_mode: int = 1
    backend: str = "auto"  # "auto" | "pallas" | "ref"
    ttl_slots: int = 16

    def __post_init__(self):
        object.__setattr__(self, "feature_names", tuple(self.feature_names))
        if self.window_slots < 1:
            raise ValueError("window_slots must be >= 1")
        if self.hysteresis_slots < 1:
            raise ValueError("hysteresis_slots must be >= 1")
        if self.period_slots < 1:
            raise ValueError("period_slots must be >= 1")
        if self.ttl_slots < 1:
            raise ValueError("ttl_slots must be >= 1")


class DeviceSwitchState(NamedTuple):
    """Per-UE control-loop state riding the slot scan's carry.

    ``rings`` is a ``KPMRing`` with every leaf vmapped over a leading UE
    axis (all UEs push in lockstep, one slot per push).  ``active_mode`` is
    what the pipeline consumes this slot; ``pending_mode`` is the switch
    register (the mode that takes effect at the next boundary);
    ``streak`` counts consecutive raw decisions disagreeing with the
    register (hysteresis); ``n_switches`` counts boundary transitions.

    The three fault-path leaves ride along even without a ``FaultSpec``
    (untouched then, so XLA dead-code-eliminates them): ``decision_age``
    counts slots since the last valid decision slot (the device twin of the
    host ``SlotSwitchState.slots_since_decision``), ``trip_ring`` is the
    circuit breaker's per-UE rolling trip window (width
    ``FaultSpec.breaker_window``; 1 when no faults), and ``quarantine`` is
    the per-UE cooldown countdown (``> 0`` == the AI expert is quarantined
    and the UE is served by the default expert).
    """

    rings: KPMRing  # buf (U, W, F) / idx (U,) / count (U,)
    active_mode: jax.Array  # (U,) int32
    pending_mode: jax.Array  # (U,) int32
    streak: jax.Array  # (U,) int32
    n_switches: jax.Array  # (U,) int32
    decision_age: jax.Array  # (U,) int32
    trip_ring: jax.Array  # (U, breaker_window) int32
    quarantine: jax.Array  # (U,) int32


def init_device_switch(
    n_ues: int, n_features: int, cfg: SwitchConfig, faults=None
) -> DeviceSwitchState:
    d = jnp.full((n_ues,), cfg.default_mode, jnp.int32)
    z = jnp.zeros((n_ues,), jnp.int32)
    breaker_window = 1 if faults is None else faults.breaker_window
    return DeviceSwitchState(
        rings=KPMRing(
            buf=jnp.zeros((n_ues, cfg.window_slots, n_features), jnp.float32),
            idx=z,
            count=z,
        ),
        active_mode=d,
        pending_mode=d,
        streak=z,
        n_switches=z,
        decision_age=z,
        trip_ring=jnp.zeros((n_ues, breaker_window), jnp.int32),
        quarantine=z,
    )


def switch_update(
    state: DeviceSwitchState,
    kpm_vecs: jax.Array,
    policy: DevicePolicy,
    cfg: SwitchConfig,
    *,
    decide: jax.Array | bool = True,
    decision_valid: jax.Array | None = None,
    telemetry_valid: jax.Array | None = None,
) -> tuple[DeviceSwitchState, jax.Array]:
    """Decision phase of slot ``n``: window push -> policy -> register.

    ``kpm_vecs (U, F)`` is slot ``n``'s telemetry in ``cfg.feature_names``
    order.  Returns the updated state (register possibly rewritten — but
    ``active_mode`` untouched: application waits for ``switch_boundary``)
    and the raw per-UE policy decision.

    ``decide`` implements ``SwitchConfig.period_slots``: on hold slots
    (``decide`` false) the telemetry still enters the window but the policy
    is not consulted — register *and* hysteresis streak are frozen (a hold
    slot neither advances nor resets the streak, so ``hysteresis_slots``
    counts disagreeing *decision* slots) and the raw decision reported is
    the held register.

    The fault masks (``(U,)`` bool, both-or-neither) inject the
    ``FaultSpec`` failure classes: where ``telemetry_valid`` is False the
    slot's KPM sample never enters the rolling window (the ring simply
    does not advance for that UE), and where ``decision_valid`` is False
    the control plane lost this slot's decision — register, streak and raw
    decision freeze exactly like a hold slot, and the decision age is not
    reset.  ``decision_age`` resets on every decision slot that actually
    arrived (valid + decide), regardless of hysteresis: a heard "stay"
    refreshes the TTL just like the host loop's ``commit_decision``.
    """
    pushed = jax.vmap(ring_push)(state.rings, kpm_vecs)
    if telemetry_valid is not None:
        tv = telemetry_valid
        rings = jax.tree.map(
            lambda n, o: jnp.where(
                tv.reshape(tv.shape + (1,) * (n.ndim - 1)), n, o
            ),
            pushed,
            state.rings,
        )
    else:
        rings = pushed
    window = jax.vmap(lambda r: ring_window_mean(r, cfg.window_slots))(rings)
    raw = policy_infer(policy, window, state.pending_mode, backend=cfg.backend)
    agree = raw == state.pending_mode
    streak = jnp.where(agree, 0, state.streak + 1)
    commit = streak >= jnp.int32(cfg.hysteresis_slots)
    pending = jnp.where(commit, raw, state.pending_mode)
    streak = jnp.where(commit, 0, streak)
    if decide is not True:  # periodic decisions: freeze between decision slots
        raw = jnp.where(decide, raw, state.pending_mode)
        pending = jnp.where(decide, pending, state.pending_mode)
        streak = jnp.where(decide, streak, state.streak)
    age = state.decision_age
    if decision_valid is not None:
        dv = decision_valid
        raw = jnp.where(dv, raw, state.pending_mode)
        pending = jnp.where(dv, pending, state.pending_mode)
        streak = jnp.where(dv, streak, state.streak)
        received = dv if decide is True else jnp.logical_and(dv, decide)
        age = jnp.where(received, 0, age)
    return (
        state._replace(
            rings=rings, pending_mode=pending, streak=streak,
            decision_age=age,
        ),
        raw,
    )


def switch_boundary(
    state: DeviceSwitchState,
    *,
    ttl_slots: int | None = None,
    fail_safe_mode: int | None = None,
) -> DeviceSwitchState:
    """Boundary into slot ``n+1``: the register becomes the active mode.

    With ``ttl_slots`` (fault campaigns only) the boundary also runs the
    fail-safe TTL decay, mirroring the host ``slot_boundary`` exactly: a
    UE whose decision age has *reached* ``ttl_slots`` (checked before the
    age increments) has both its active mode and its register forced to
    ``fail_safe_mode``; the age then advances one slot for everyone.
    """
    pending = state.pending_mode
    age = state.decision_age
    if ttl_slots is not None:
        stale = age >= jnp.int32(ttl_slots)
        pending = jnp.where(stale, jnp.int32(fail_safe_mode), pending)
        age = age + 1
    switched = (pending != state.active_mode).astype(jnp.int32)
    return state._replace(
        active_mode=pending,
        pending_mode=pending,
        decision_age=age,
        n_switches=state.n_switches + switched,
    )


def breaker_update(
    state: DeviceSwitchState,
    trip: jax.Array,
    slot_idx: jax.Array,
    faults,
) -> DeviceSwitchState:
    """Circuit breaker: M trips in a window quarantine the AI expert.

    ``trip (U,)`` bool flags this slot's health-screen / audit trips.  The
    per-UE trip window is a rolling ring written at ``slot_idx %
    breaker_window``; when a UE not already quarantined accumulates
    ``breaker_trips`` trips inside the window, it enters quarantine for
    ``breaker_cooldown`` slots *with a cleared trip window* — so the
    hysteresis re-probe after cooldown starts from a clean slate rather
    than instantly re-tripping on stale history.  While quarantined the
    countdown decrements; the AI expert is re-probed the first slot the
    countdown hits zero.
    """
    window = state.trip_ring.shape[1]
    onehot = jnp.arange(window) == (slot_idx % jnp.int32(window))
    ring = jnp.where(
        onehot[None, :], trip.astype(jnp.int32)[:, None], state.trip_ring
    )
    count = ring.sum(axis=1)
    in_quar = state.quarantine > 0
    newly = jnp.logical_and(
        jnp.logical_not(in_quar), count >= jnp.int32(faults.breaker_trips)
    )
    ring = jnp.where(newly[:, None], 0, ring)
    quar = jnp.where(
        newly,
        jnp.int32(faults.breaker_cooldown),
        jnp.maximum(state.quarantine - 1, 0),
    )
    return state._replace(trip_ring=ring, quarantine=quar)


# -- host equivalence oracle ---------------------------------------------------


def host_replay_closed_loop(
    host_policy,
    features: np.ndarray,
    cfg: SwitchConfig,
    *,
    policy_idx=None,
    attached: np.ndarray | None = None,
    faults=None,
    trips: np.ndarray | None = None,
) -> dict[str, np.ndarray]:
    """Replay the closed loop on host, slot by slot, per UE.

    ``host_policy`` is the *host* object (``DecisionTreePolicy`` — called
    per KPM vector, i.e. the literal ``tree_infer_ref`` walk — or
    ``ThresholdPolicy``); ``features (S, U, F)`` is the device trajectory's
    telemetry in ``cfg.feature_names`` order.  Windowing reuses the same
    ``KPMRing`` arithmetic the scan carries (eagerly, one slot at a time),
    so any float matches bitwise; the control flow (hysteresis streak,
    switch register, boundary application) is plain Python ints.

    Per-UE heterogeneous campaigns (device side: ``PerUEPolicy``) replay by
    passing a *sequence* of host policies plus ``policy_idx`` — the same
    ``(n_ues,)`` table assignment the device ran; UE ``u`` is replayed
    through ``host_policy[policy_idx[u]]``.

    Streaming (churn) campaigns replay by passing ``attached (S, U)`` — the
    history's residency leaf.  While detached a UE is skipped entirely (no
    ring push, no decision, no boundary transition) and its history entries
    carry the ``-1`` sentinel; at every (re)attach boundary the UE
    cold-starts exactly like the device admission pass: fresh ``KPMRing``,
    register and active mode back at ``default_mode``, hysteresis streak
    cleared.  No stale pre-detach telemetry can leak into the first
    post-attach decision — the churn-boundary tests pin this at ring,
    ``DeviceSwitchState`` and host-replay layers.

    Fault campaigns replay by passing the same ``FaultSpec`` the device
    ran (``faults``): the spec is re-resolved here, producing the *same*
    mask arrays the scan consumed (the resolution is a pure function of
    the spec and the shape), and the oracle mirrors the device ordering —
    drop the KPM push where telemetry is invalid, hold the register where
    the decision was lost, reset the decision age on heard decision slots,
    run the TTL decay and the circuit breaker at the boundary.  ``trips``
    optionally supplies the device history's per-(slot, UE) health/audit
    trip flags (``health_tripped + audit_tripped``) to drive the breaker;
    without it the oracle derives trips from the corruption masks (exact
    for the NaN/Inf kinds, which always trip the in-scan health screen).

    Returns ``{"active_mode", "raw_decision", "pending_mode",
    "quarantined", "n_switches"}`` with ``(S, U)`` int arrays
    (``n_switches``: ``(U,)``).
    """
    from repro.core.policy import ThresholdPolicy
    from repro.core.telemetry import ring_init

    features = np.asarray(features, np.float32)
    n_slots, n_ues, n_feat = features.shape
    if n_feat != len(cfg.feature_names):
        raise ValueError(
            f"features carry {n_feat} KPMs, config names {len(cfg.feature_names)}"
        )
    if isinstance(host_policy, (list, tuple)):
        if policy_idx is None:
            raise ValueError("a per-UE policy sequence needs policy_idx")
        idx = np.asarray(policy_idx, int)
        if idx.shape != (n_ues,):
            raise ValueError(f"policy_idx {idx.shape} vs n_ues {n_ues}")
        if idx.size and (idx.min() < 0 or idx.max() >= len(host_policy)):
            # mirror per_ue_policy: negatives would silently wrap here
            raise ValueError(
                f"policy_idx references policies outside [0, {len(host_policy)})"
            )
        policy_for_ue = [host_policy[int(i)] for i in idx]
    else:
        if policy_idx is not None:
            raise ValueError(
                "policy_idx given but host_policy is not a sequence — pass "
                "the per-UE policy list the device campaign ran"
            )
        policy_for_ue = [host_policy] * n_ues

    if attached is not None:
        attached = np.asarray(attached, bool)
        if attached.shape != (n_slots, n_ues):
            raise ValueError(
                f"attached {attached.shape} vs features {(n_slots, n_ues)}"
            )

    resolved = None
    if faults is not None:
        resolved = faults.resolve(n_slots, n_ues)
    if trips is not None:
        trips = np.asarray(trips).astype(bool)
        if trips.shape != (n_slots, n_ues):
            raise ValueError(
                f"trips {trips.shape} vs features {(n_slots, n_ues)}"
            )

    rings = [ring_init(cfg.window_slots, n_feat) for _ in range(n_ues)]
    active = [cfg.default_mode] * n_ues
    pending = [cfg.default_mode] * n_ues
    streak = [0] * n_ues
    n_switches = [0] * n_ues
    age = [0] * n_ues
    trip_ring = (
        np.zeros((n_ues, faults.breaker_window), np.int32)
        if faults is not None
        else None
    )
    quarantine = [0] * n_ues
    active_hist = np.zeros((n_slots, n_ues), np.int32)
    raw_hist = np.zeros((n_slots, n_ues), np.int32)
    pending_hist = np.zeros((n_slots, n_ues), np.int32)
    quar_hist = np.zeros((n_slots, n_ues), np.int32)

    for s in range(n_slots):
        for u in range(n_ues):
            if attached is not None:
                if not attached[s, u]:
                    # detached: no telemetry, no decision, no boundary —
                    # the streaming history's sentinel marks the gap
                    active_hist[s, u] = -1
                    raw_hist[s, u] = -1
                    pending_hist[s, u] = -1
                    quar_hist[s, u] = -1
                    continue
                if s == 0 or not attached[s - 1, u]:
                    # (re)attach cold start, mirroring the device
                    # admission pass: fresh ring, default register,
                    # cleared hysteresis streak — and a clean fault
                    # state (age, trip window, quarantine)
                    rings[u] = ring_init(cfg.window_slots, n_feat)
                    active[u] = cfg.default_mode
                    pending[u] = cfg.default_mode
                    streak[u] = 0
                    age[u] = 0
                    quarantine[u] = 0
                    if trip_ring is not None:
                        trip_ring[u] = 0
            in_quar = quarantine[u] > 0
            active_hist[s, u] = active[u]
            quar_hist[s, u] = 1 if in_quar else 0
            if resolved is None or resolved.telemetry_valid[s, u]:
                rings[u] = ring_push(rings[u], jnp.asarray(features[s, u]))
            window = ring_window_mean(rings[u], cfg.window_slots)
            decide = s % cfg.period_slots == 0
            heard = decide and (
                resolved is None or resolved.decision_valid[s, u]
            )
            if not heard:
                # hold / lost-decision slot: register and streak frozen,
                # held raw reported, decision age keeps aging
                raw = pending[u]
            else:
                pol = policy_for_ue[u]
                if isinstance(pol, ThresholdPolicy):
                    raw = int(pol(window, prev_mode=pending[u]))
                else:
                    raw = int(pol(window))
                if raw == pending[u]:
                    streak[u] = 0
                else:
                    streak[u] += 1
                    if streak[u] >= cfg.hysteresis_slots:
                        pending[u] = raw
                        streak[u] = 0
                if resolved is not None:
                    age[u] = 0  # a heard decision refreshes the TTL
            raw_hist[s, u] = raw
            pending_hist[s, u] = pending[u]
            # boundary into slot s+1 (with the TTL decay under faults)
            nxt = pending[u]
            if resolved is not None:
                if age[u] >= cfg.ttl_slots:
                    nxt = cfg.default_mode
                    pending[u] = cfg.default_mode
                age[u] += 1
            if nxt != active[u]:
                n_switches[u] += 1
            active[u] = nxt
            if resolved is not None:
                # circuit breaker: this slot's health/audit trip enters
                # the rolling window; M trips quarantine the AI expert
                if trips is not None:
                    trip = bool(trips[s, u])
                else:
                    exec_mode = cfg.default_mode if in_quar else (
                        active_hist[s, u]
                    )
                    trip = bool(
                        resolved.corrupt[s, u]
                        and exec_mode == 0
                        and faults.corruption_kind in ("nan", "inf")
                    )
                trip_ring[u, s % faults.breaker_window] = int(trip)
                newly = (
                    not in_quar
                    and int(trip_ring[u].sum()) >= faults.breaker_trips
                )
                if newly:
                    trip_ring[u] = 0
                    quarantine[u] = faults.breaker_cooldown
                else:
                    quarantine[u] = max(quarantine[u] - 1, 0)

    return {
        "active_mode": active_hist,
        "raw_decision": raw_hist,
        "pending_mode": pending_hist,
        "quarantined": quar_hist,
        "n_switches": np.asarray(n_switches, np.int32),
    }
