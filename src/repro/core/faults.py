"""Declarative fault injection: decision loss, expert corruption, telemetry loss.

ARCHES's safety story (paper 3.3, 5) is that switching *degrades* instead of
crashing: a dead dApp decays every UE to the conventional expert after
``ttl_slots``, a sick AI expert is caught and reverted the same slot, and
missing telemetry never poisons a decision window.  ``FaultSpec`` makes those
failure modes a first-class, JSON-round-trippable campaign input — hashed into
``CampaignSpec.faults`` like the topology and churn specs — covering three
classes:

* **control-plane decision loss** — scheduled outage spans (all UEs) plus a
  seeded per-slot Bernoulli drop; the device engine grows a decision-age
  counter that mirrors the host ``slot_boundary`` TTL decay bitwise;
* **expert-output corruption bursts** — NaN/Inf or scaled-error injection
  into the AI estimator output, caught by an in-scan ``isfinite`` health
  screen and fed into a per-UE circuit breaker (M trips in a window
  quarantines the AI expert until a cooldown re-probe);
* **telemetry loss** — invalidated KPM samples are masked out of the rolling
  window (the ring simply does not advance for that UE that slot).

``FaultSpec.resolve`` lowers the declarative spec to dense per-(slot, UE)
mask arrays with a *fixed* numpy draw order, so the device scan and the host
replay oracle consume literally the same arrays — fault mirroring is by
construction, not by re-implementation.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

_CORRUPTION_KINDS = ("nan", "inf", "scale")


def _tuplify_spans(spans) -> tuple:
    out = []
    for span in spans:
        start, end = span
        start, end = int(start), int(end)
        if not 0 <= start < end:
            raise ValueError(
                f"fault span ({start}, {end}) must satisfy 0 <= start < end"
            )
        out.append((start, end))
    return tuple(out)


def _check_prob(name: str, p: float) -> float:
    p = float(p)
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"{name} {p} outside [0, 1]")
    return p


class ResolvedFaults(NamedTuple):
    """Dense per-(slot, UE) fault masks — the scan's extra ``xs`` leaves.

    ``decision_valid``: False where the control plane lost this slot's
    decision.  ``corrupt``: True where the AI expert output is corrupted.
    ``telemetry_valid``: False where the KPM sample is invalidated (masked
    out of the rolling window).  All ``(n_slots, n_ues)`` bool.
    """

    decision_valid: np.ndarray
    corrupt: np.ndarray
    telemetry_valid: np.ndarray


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Frozen, hashable, JSON-round-trippable fault-injection campaign.

    Spans are ``(start, end)`` half-open slot intervals applying to every
    UE; Bernoulli probabilities apply per (slot, UE) with the spec's own
    ``seed`` (independent of the campaign seed, so the same channel
    realization can be replayed under different fault draws).

    A default-constructed ``FaultSpec()`` injects nothing — but still
    compiles the fault machinery in, and is bitwise-identical to a
    ``faults=None`` run on every trajectory leaf (the zero-fault identity
    contract; requires ``ttl_slots >= period_slots`` so a healthy loop
    never ages out).

    The circuit breaker: ``breaker_trips`` health/audit trips inside the
    last ``breaker_window`` slots quarantines the AI expert for that UE
    (it is served by the default expert and claims no gated capacity) for
    ``breaker_cooldown`` slots, after which a hysteresis re-probe starts
    from a cleared trip window.
    """

    seed: int = 0
    decision_outages: tuple = ()
    decision_drop_prob: float = 0.0
    corruption_spans: tuple = ()
    corruption_kind: str = "nan"
    corruption_scale: float = 1000.0
    corruption_prob: float = 1.0
    telemetry_spans: tuple = ()
    telemetry_drop_prob: float = 0.0
    breaker_trips: int = 3
    breaker_window: int = 8
    breaker_cooldown: int = 16

    def __post_init__(self):
        object.__setattr__(self, "seed", int(self.seed))
        object.__setattr__(
            self, "decision_outages", _tuplify_spans(self.decision_outages)
        )
        object.__setattr__(
            self, "corruption_spans", _tuplify_spans(self.corruption_spans)
        )
        object.__setattr__(
            self, "telemetry_spans", _tuplify_spans(self.telemetry_spans)
        )
        object.__setattr__(
            self,
            "decision_drop_prob",
            _check_prob("decision_drop_prob", self.decision_drop_prob),
        )
        object.__setattr__(
            self,
            "corruption_prob",
            _check_prob("corruption_prob", self.corruption_prob),
        )
        object.__setattr__(
            self,
            "telemetry_drop_prob",
            _check_prob("telemetry_drop_prob", self.telemetry_drop_prob),
        )
        if str(self.corruption_kind) not in _CORRUPTION_KINDS:
            raise ValueError(
                f"corruption_kind {self.corruption_kind!r}; "
                f"one of {_CORRUPTION_KINDS}"
            )
        object.__setattr__(
            self, "corruption_kind", str(self.corruption_kind)
        )
        scale = float(self.corruption_scale)
        if not scale > 0:
            raise ValueError(f"corruption_scale {scale} must be > 0")
        object.__setattr__(self, "corruption_scale", scale)
        for name in ("breaker_trips", "breaker_window", "breaker_cooldown"):
            val = int(getattr(self, name))
            if val < 1:
                raise ValueError(f"{name} {val} must be >= 1")
            object.__setattr__(self, name, val)

    @property
    def injects_nothing(self) -> bool:
        """True when no fault can ever fire (masks are all-pass)."""
        return (
            not self.decision_outages
            and self.decision_drop_prob == 0.0
            and not self.corruption_spans
            and not self.telemetry_spans
            and self.telemetry_drop_prob == 0.0
        )

    @classmethod
    def from_dict(cls, d) -> "FaultSpec":
        return cls(**dict(d))

    def _span_mask(self, spans: tuple, n_slots: int) -> np.ndarray:
        mask = np.zeros(n_slots, bool)
        for start, end in spans:
            mask[start:min(end, n_slots)] = True
        return mask

    def resolve(self, n_slots: int, n_ues: int) -> ResolvedFaults:
        """Lower to dense ``(n_slots, n_ues)`` masks.

        The numpy draw order is fixed (decision, corruption, telemetry —
        each a full ``(n_slots, n_ues)`` uniform draw regardless of its
        probability) so any two resolutions of the same spec over the same
        shape are identical arrays: the device scan and the host oracle
        consume the *same* masks.  Streaming resolves over the stable-id
        axis and column-gathers per segment, so a UE's fault stream is
        tied to its identity, not its bank slot.
        """
        rng = np.random.default_rng(self.seed)
        dec_span = self._span_mask(self.decision_outages, n_slots)
        dec_drop = rng.random((n_slots, n_ues)) < self.decision_drop_prob
        decision_valid = ~(dec_span[:, None] | dec_drop)
        cor_span = self._span_mask(self.corruption_spans, n_slots)
        cor_draw = rng.random((n_slots, n_ues)) < self.corruption_prob
        corrupt = cor_span[:, None] & cor_draw
        tel_span = self._span_mask(self.telemetry_spans, n_slots)
        tel_drop = rng.random((n_slots, n_ues)) < self.telemetry_drop_prob
        telemetry_valid = ~(tel_span[:, None] | tel_drop)
        return ResolvedFaults(
            decision_valid=decision_valid,
            corrupt=corrupt,
            telemetry_valid=telemetry_valid,
        )
