"""The ARCHES slot loop: pipeline on device, control plane on host (Fig. 1).

Generic over the switched function: the channel-estimation case study and the
LM serving integration both provide a ``slot_fn`` and reuse this loop.

Per slot n (paper timing semantics, 2/3.3):
  1. *slot setup*: poll the E3 control inbox; a decision generated during
     slot n-1 is committed and becomes active now (slot boundary).  Stale
     control planes decay to the fail-safe mode after ``ttl_slots``.
  2. the pipeline executes with the active mode (ExpertBank + switch kernel
     inside ``slot_fn``).
  3. per-slot KPMs are indicated to the dApp via E3; any resulting decision
     lands in the control inbox for slot n+1.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Iterable, Mapping

import jax
import numpy as np

from repro.core.e3 import E3Agent, E3IndicationMessage
from repro.core.switch import (
    SlotSwitchState,
    commit_decision,
    init_switch_state,
    slot_boundary,
)


@dataclasses.dataclass
class SlotRecord:
    slot: int
    active_mode: int
    kpms: dict[str, float]
    output: Any = None


@dataclasses.dataclass
class RunHistory:
    records: list[SlotRecord]
    final_state: SlotSwitchState

    @property
    def modes(self) -> np.ndarray:
        return np.asarray([r.active_mode for r in self.records])

    def kpm_series(self, name: str) -> np.ndarray:
        return np.asarray([r.kpms.get(name, np.nan) for r in self.records])


@dataclasses.dataclass
class BatchedRunHistory:
    """Trajectory of a scan-compiled multi-UE campaign.

    Every array carries a leading ``(n_slots, n_ues)`` shape; KPM names are
    flattened across sources exactly like ``SlotRecord.kpms``.  The batched
    engine produces this in one device round-trip instead of one per slot.
    """

    modes: np.ndarray  # (S, U) int32 — per-UE active mode each slot
    kpms: dict[str, np.ndarray]  # name -> (S, U)
    outputs: dict[str, np.ndarray]  # tb_ok / mcs / tbs / phy_bits_per_s
    # closed-loop extras (device-decided campaigns only)
    decisions: np.ndarray | None = None  # (S, U) raw per-slot policy output
    n_switches: np.ndarray | None = None  # (U,) boundary transitions
    # multi-cell extras (sharded-topology campaigns only)
    cell_of_ue: np.ndarray | None = None  # (U,) int32 global cell ids
    # gated capacity the campaign actually provisioned (auto-capacity runs
    # record the chosen K here; None == not a capacity-provisioned run)
    provisioned_capacity: int | None = None
    # streaming extras (epoch-chunked churn campaigns only) — the UE axis
    # is then the *stable-id* axis, which may exceed the bank capacity:
    attached: np.ndarray | None = None  # (S, U) bool — residency per slot
    bank_slot: np.ndarray | None = None  # (S, U) int32 — serving slot, -1 off

    @classmethod
    def from_trajectory(
        cls, modes, traj, *, cell_of_ue=None, provisioned_capacity=None
    ) -> "BatchedRunHistory":
        """Build from ``BatchedPuschPipeline.run`` output."""
        from repro.core.telemetry import flatten_kpm_sources

        kpms = {
            k: np.asarray(v) for k, v in flatten_kpm_sources(traj["kpms"]).items()
        }
        outputs = {
            k: np.asarray(v) for k, v in traj.items() if k != "kpms"
        }
        return cls(
            modes=np.asarray(modes), kpms=kpms, outputs=outputs,
            cell_of_ue=None if cell_of_ue is None else np.asarray(cell_of_ue),
            provisioned_capacity=provisioned_capacity,
        )

    @classmethod
    def from_closed_loop(
        cls, traj, final_switch=None, *, cell_of_ue=None,
        provisioned_capacity=None,
    ) -> "BatchedRunHistory":
        """Build from ``BatchedPuschPipeline.run_closed_loop`` output.

        ``modes`` are the *device-decided* per-slot active modes; the raw
        per-slot policy decisions ride along (a decision at slot ``n``
        surfaces as the active mode no earlier than slot ``n+1``).
        """
        from repro.core.telemetry import flatten_kpm_sources

        extras = ("active_mode", "raw_decision", "pending_mode", "kpms")
        kpms = {
            k: np.asarray(v) for k, v in flatten_kpm_sources(traj["kpms"]).items()
        }
        outputs = {k: np.asarray(v) for k, v in traj.items() if k not in extras}
        return cls(
            modes=np.asarray(traj["active_mode"]),
            kpms=kpms,
            outputs=outputs,
            decisions=np.asarray(traj["raw_decision"]),
            n_switches=(
                None
                if final_switch is None
                else np.asarray(final_switch.n_switches)
            ),
            cell_of_ue=None if cell_of_ue is None else np.asarray(cell_of_ue),
            provisioned_capacity=provisioned_capacity,
        )

    @classmethod
    def from_host(cls, hist: "RunHistory") -> "BatchedRunHistory":
        """Lift a host-loop ``RunHistory`` into the batched result type.

        The host loop serves one UE, so every array gets an ``(n_slots, 1)``
        shape.  Scalar numeric outputs (``tb_ok`` / ``tbs`` / ``mcs`` /
        ``phy_bits_per_s``) ride along when the run kept outputs — this is
        what lets ``ArchesSession`` hand back one result type regardless of
        the execution path.
        """
        modes = hist.modes[:, None].astype(np.int32)
        names = list(hist.records[0].kpms) if hist.records else []
        kpms = {
            k: np.asarray([[r.kpms.get(k, np.nan)] for r in hist.records])
            for k in names
        }
        outputs: dict[str, np.ndarray] = {}
        if hist.records and isinstance(hist.records[0].output, Mapping):
            for k in ("tb_ok", "tbs", "mcs", "phy_bits_per_s"):
                if k in hist.records[0].output:
                    outputs[k] = np.asarray(
                        [[float(r.output[k])] for r in hist.records]
                    )
        return cls(modes=modes, kpms=kpms, outputs=outputs)

    @property
    def n_slots(self) -> int:
        return self.modes.shape[0]

    @property
    def n_ues(self) -> int:
        return self.modes.shape[1]

    def modes_for(self, ue: int) -> np.ndarray:
        return self.modes[:, ue]

    @property
    def ai_share(self) -> float:
        """Fraction of slot-UEs actually *served* by the designated (AI)
        expert — capacity-overflow and audit-tripped slot-UEs fell back to
        the fail-safe expert and do not count, keeping this consistent with
        the served-by accounting.

        Streaming histories reduce over *resident* slot-UEs only: detached
        entries (mode sentinel ``-1``) are neither served nor offered
        service, so they belong in neither numerator nor denominator."""
        served = self.modes == 0
        for fell_back in ("gated_overflow", "audit_tripped",
                          "health_tripped", "quarantined"):
            if fell_back in self.outputs:
                served = served & (np.asarray(self.outputs[fell_back]) == 0)
        if self.attached is not None:
            att = np.asarray(self.attached, bool)
            return float(served[att].mean()) if att.any() else 0.0
        return float(np.mean(served))

    def executed_flops_per_slot(self) -> np.ndarray:
        """Per-slot realized compute, summed over UEs ((S,) float64)."""
        return np.asarray(
            self.outputs["executed_flops"], np.float64
        ).sum(axis=1)

    @property
    def overflow_slot_ues(self) -> int:
        """Total capacity-overflow events (gated execution only; else 0)."""
        if "gated_overflow" not in self.outputs:
            return 0
        return int(np.asarray(self.outputs["gated_overflow"]).sum())

    @property
    def audit_tripped_slot_ues(self) -> int:
        """Total accuracy-audit fail-safe events (audited gated runs; else
        0): slot-UEs whose gated-expert output failed the in-scan NMSE
        audit and were served by the fail-safe baseline instead."""
        if "audit_tripped" not in self.outputs:
            return 0
        return int(np.asarray(self.outputs["audit_tripped"]).sum())

    @property
    def health_tripped_slot_ues(self) -> int:
        """Total ``isfinite`` health-screen fail-safe events (fault-injected
        runs; else 0): slot-UEs whose AI-expert output went non-finite and
        was reverted to the fail-safe baseline that slot."""
        if "health_tripped" not in self.outputs:
            return 0
        return int(np.asarray(self.outputs["health_tripped"]).sum())

    @property
    def quarantined_slot_ues(self) -> int:
        """Total circuit-breaker quarantine slot-UEs (fault-injected runs;
        else 0): slot-UEs that started the slot under quarantine and were
        served by the default expert regardless of their committed mode."""
        if "quarantined" not in self.outputs:
            return 0
        return int((np.asarray(self.outputs["quarantined"]) > 0).sum())

    def resident_ues_per_slot(self) -> np.ndarray:
        """Per-slot resident UE count ((S,) int64; full bank if no churn)."""
        if self.attached is None:
            return np.full(self.n_slots, self.n_ues, np.int64)
        return np.asarray(self.attached, bool).sum(axis=1)

    def kpm_series(self, name: str, ue: int = 0) -> np.ndarray:
        return self.kpms[name][:, ue]

    def cell_kpm_series(self, name: str) -> np.ndarray:
        """Cell-level aggregate: per-slot mean over UEs."""
        return self.kpms[name].mean(axis=1)

    # -- per-cell reductions (sharded multi-cell campaigns) -----------------

    def _cells(self) -> np.ndarray:
        if self.cell_of_ue is None:
            raise ValueError(
                "this history has no cell layout — per-cell reductions need "
                "a campaign run under a TopologySpec"
            )
        return np.asarray(self.cell_of_ue)

    @property
    def n_cells(self) -> int:
        return int(self._cells().max()) + 1

    @property
    def per_cell_ai_share(self) -> np.ndarray:
        """Per-cell fraction of slot-UEs *served* by the AI expert ((C,)).

        Same served-not-selected semantics as ``ai_share`` (capacity
        overflow falls back and does not count), reduced over each cell's
        member UEs.
        """
        cells = self._cells()
        served = self.modes == 0
        for fell_back in ("gated_overflow", "audit_tripped",
                          "health_tripped", "quarantined"):
            if fell_back in self.outputs:
                served = served & (np.asarray(self.outputs[fell_back]) == 0)
        if self.attached is not None:
            att = np.asarray(self.attached, bool)
            return np.asarray([
                served[:, cells == c][att[:, cells == c]].mean()
                if att[:, cells == c].any() else 0.0
                for c in range(self.n_cells)
            ])
        return np.asarray([
            served[:, cells == c].mean() for c in range(self.n_cells)
        ])

    def per_cell_kpm(self, name: str) -> np.ndarray:
        """Per-slot per-cell mean of one KPM ((S, C))."""
        cells = self._cells()
        v = self.kpms[name]
        return np.stack(
            [v[:, cells == c].mean(axis=1) for c in range(self.n_cells)],
            axis=1,
        )

    @property
    def per_cell_throughput(self) -> np.ndarray:
        """Per-cell mean PHY throughput over the campaign ((C,) bit/s)."""
        return self.per_cell_kpm("phy_throughput").mean(axis=0)

    def per_ue(self, ue: int) -> list[SlotRecord]:
        """One UE's trajectory as host-loop-style slot records."""
        return [
            SlotRecord(
                slot=s,
                active_mode=int(self.modes[s, ue]),
                kpms={k: float(v[s, ue]) for k, v in self.kpms.items()},
                output={k: v[s, ue] for k, v in self.outputs.items()},
            )
            for s in range(self.n_slots)
        ]


def replay_batched_telemetry(agent: E3Agent, traj, *, n_slots: int | None = None) -> int:
    """Replay a batched trajectory's KPMs as per-slot E3 indications.

    The scan-compiled engine produces the whole campaign in one device
    round-trip, so telemetry indication happens post-run: each slot's KPMs
    are aggregated across UEs (cell-level mean, matching the per-cell KPM
    framing of the paper's Data Lake queries) and pushed through the same
    E3 path the host loop uses — dApp subscriptions, windowing and policy
    tooling consume batched campaigns unchanged.

    Returns the number of slots replayed.
    """
    # device->host transfer once per array, not once per (slot, array)
    host = {
        source: {k: np.asarray(v) for k, v in kpms.items()}
        for source, kpms in traj["kpms"].items()
    }
    first = next(iter(next(iter(host.values())).values()))
    n = int(first.shape[0]) if n_slots is None else n_slots
    for s in range(n):
        for source, kpms in host.items():
            vals = {k: float(np.mean(v[s])) for k, v in kpms.items()}
            agent.indicate(E3IndicationMessage(slot=s, source=source, kpms=vals))
    return n


def suggest_gated_capacity(
    history: BatchedRunHistory,
    *,
    quantile: float = 1.0,
    headroom: int = 0,
    n_shards: int = 1,
) -> int:
    """Pick ``gated_capacity`` from a recorded campaign's telemetry.

    Dynamic capacity provisioning (ROADMAP): instead of a static knob, size
    the gated sub-batch from the realized per-slot AI demand.  Demand at
    slot ``s`` counts the UEs whose *committed* mode selected the designated
    expert — including capacity-overflow UEs (flagged in ``gated_overflow``:
    they selected AI but fell back), so an under-provisioned campaign
    suggests a larger capacity than the one it ran with, not the cap it was
    stuck at.

    Streaming (churn) histories carry an ``attached`` residency leaf; demand
    then counts only *resident* slot-UEs — a detached UE's declared mode
    plan claims no gated capacity, so a churn campaign is sized from the
    concurrent resident demand rather than the full stable-id axis (which
    may be far wider than the bank and would over-provision the gated
    sub-batch).

    ``quantile`` trades provisioned FLOPs against overflow risk: ``1.0``
    (default) covers the peak demand observed (a rerun of the same
    trajectory overflows zero slot-UEs); ``0.95`` sheds the top 5% of
    demand slots to the fail-safe expert.  ``headroom`` adds UEs of margin
    on top.  The result is clamped to ``[0, n_ues]``.

    Under a sharded topology compaction is *shard-local*, so pass the
    campaign's ``n_shards``: demand is then measured per contiguous
    UE-slice shard (the ``shard_map`` partitioning of the axis) and the
    returned campaign-wide capacity is ``n_shards`` times the worst
    shard's quantile demand (+ per-shard headroom) — covering a
    shard-local spike that a campaign-wide count would hide.
    """
    if not 0.0 <= quantile <= 1.0:
        raise ValueError(f"quantile {quantile} outside [0, 1]")
    modes = np.asarray(history.modes)
    n_ues = modes.shape[1]
    if n_shards < 1 or n_ues % n_shards:
        raise ValueError(
            f"n_shards={n_shards} does not divide n_ues={n_ues}"
        )
    demand = modes == 0
    if history.attached is not None:
        demand = demand & np.asarray(history.attached, bool)
    per = n_ues // n_shards
    cap_shard = max(
        int(np.ceil(np.quantile(
            demand[:, s * per:(s + 1) * per].sum(axis=1), quantile
        )))
        for s in range(n_shards)
    ) + int(headroom)
    if n_shards > 1:
        # ``per_shard_capacity`` validation: a sharded engine needs a
        # capacity that splits into >= 1 slot per shard, so the suggestion
        # must never round a low-demand (or zero-demand) campaign down to
        # an unbuildable value.  ``cap_shard * n_shards`` and ``n_ues`` are
        # both multiples of ``n_shards``, so the min stays divisible.
        cap_shard = max(cap_shard, 1)
        return int(min(cap_shard * n_shards, n_ues))
    return int(np.clip(cap_shard * n_shards, 0, n_ues))


class ArchesRuntime:
    """Slot loop wiring pipeline, E3 agent and switch register.

    Two operating points, same policy:

    * **host loop** (``run``) — the seed architecture: per-slot Python loop,
      decisions travel E3 agent -> dApp -> control inbox and commit at the
      next slot boundary (``SlotSwitchState``).
    * **closed loop** (``closed_loop=True`` + ``run_batched``) — the
      decision path is compiled *into* the batched engine's slot scan: the
      exported policy tables evaluate on device, the switch register rides
      the scan carry, and the whole campaign is one device round-trip.  The
      E3 agent (if any) receives the telemetry post-run for dApp-side
      observability; it is no longer in the decision path.  Device and host
      loops are the same policy — the equivalence tests assert the mode
      trajectories match bitwise.
    """

    def __init__(
        self,
        slot_fn: Callable[..., tuple[Any, Any, Mapping[str, Mapping[str, float]]]]
        | None = None,
        agent: E3Agent | None = None,
        *,
        default_mode: int | None = None,
        fail_safe_mode: int | None = None,
        ttl_slots: int = 16,
        keep_outputs: bool = False,
        closed_loop: bool = False,
        engine: Any = None,
        device_policy: Any = None,
        switch_config: Any = None,
    ):
        """``slot_fn(active_mode, carry, slot_input) ->
        (carry, output, {source: {kpm: value}})``.

        With ``closed_loop=True``, ``engine`` (a ``BatchedPuschPipeline``),
        ``device_policy`` (exported via ``DecisionTreePolicy.to_device`` /
        ``ThresholdPolicy.to_device``) and ``switch_config`` (a
        ``SwitchConfig``) replace ``slot_fn`` for the batched path.

        ``default_mode`` / ``fail_safe_mode`` default to the switch
        config's ``default_mode`` when a closed-loop config is present
        (matching what ``from_spec`` constructs — the deprecation shim and
        the spec entry point must be equivalent for the same kwargs) and to
        mode 1 for the host loop.

        .. deprecated::
            The ``closed_loop=True`` kwarg bundle is the legacy entry
            point.  Build closed-loop runtimes declaratively with
            ``ArchesRuntime.from_spec(spec)`` (or run the whole campaign
            through ``repro.core.session.ArchesSession``).
        """
        if closed_loop:
            warnings.warn(
                "ArchesRuntime(closed_loop=True, engine=..., "
                "device_policy=..., switch_config=...) is deprecated; use "
                "ArchesRuntime.from_spec(spec) or ArchesSession(spec)",
                DeprecationWarning,
                stacklevel=2,
            )
            if engine is None or device_policy is None or switch_config is None:
                raise ValueError(
                    "closed_loop=True needs engine, device_policy and "
                    "switch_config"
                )
        if default_mode is None:
            # forward the config's default like from_spec does (getattr:
            # tests pass bare sentinel objects through the legacy shim)
            default_mode = (
                int(getattr(switch_config, "default_mode", 1))
                if closed_loop and switch_config is not None
                else 1
            )
        if fail_safe_mode is None:
            fail_safe_mode = default_mode
        self.slot_fn = slot_fn
        self.agent = agent
        self.default_mode = default_mode
        self.fail_safe_mode = fail_safe_mode
        self.ttl_slots = ttl_slots
        self.keep_outputs = keep_outputs
        self.closed_loop = closed_loop
        self.engine = engine
        self.device_policy = device_policy
        self.switch_config = switch_config

    @classmethod
    def from_spec(
        cls,
        spec,
        *,
        engine: Any = None,
        device_policy: Any = None,
        agent: E3Agent | None = None,
    ) -> "ArchesRuntime":
        """Build a closed-loop runtime from a ``CampaignSpec``.

        The spec-driven replacement for the deprecated ``closed_loop=True``
        kwarg bundle: the switch configuration comes from ``spec.switch`` /
        ``spec.feature_names``, and — unless pre-built components are
        passed — the engine and exported device policy are compiled from
        the spec by ``ArchesSession`` (one source of truth for both entry
        points).
        """
        if engine is None or device_policy is None:
            from repro.core.session import ArchesSession

            # a pre-built engine is reused for policy training too — the
            # session only constructs what was not passed in
            session = ArchesSession(spec, engine=engine)
            engine = engine if engine is not None else session.engine
            device_policy = (
                device_policy
                if device_policy is not None
                else session.device_policy
            )
        sw_cfg = spec.switch.to_config(spec.feature_names)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return cls(
                agent=agent,
                default_mode=sw_cfg.default_mode,
                fail_safe_mode=sw_cfg.default_mode,
                ttl_slots=spec.switch.ttl_slots,
                closed_loop=True,
                engine=engine,
                device_policy=device_policy,
                switch_config=sw_cfg,
            )

    def run_batched(
        self,
        schedule,
        *,
        n_slots: int,
        n_ues: int,
        key=None,
        ue_keys=None,
        replay_telemetry: bool = False,
        faults=None,
    ) -> BatchedRunHistory:
        """Closed-loop batched campaign: device-decided modes, one scan.

        Requires ``closed_loop=True``.  Records the device-decided per-slot
        mode grid (plus raw decisions and per-UE switch counts) into a
        ``BatchedRunHistory``; with ``replay_telemetry=True`` the campaign's
        KPMs are pushed through the E3 agent post-run so host-side dApp
        subscriptions observe the campaign unchanged.  ``faults`` (a
        ``FaultSpec``) arms the in-scan degradation ladder.
        """
        if not self.closed_loop:
            raise RuntimeError("run_batched requires closed_loop=True")
        _, final_switch, traj = self.engine.run_closed_loop(
            schedule,
            self.device_policy,
            self.switch_config,
            n_slots=n_slots,
            n_ues=n_ues,
            key=key,
            ue_keys=ue_keys,
            faults=faults,
        )
        if replay_telemetry and self.agent is not None:
            replay_batched_telemetry(self.agent, traj)
        return BatchedRunHistory.from_closed_loop(traj, final_switch)

    def run(self, inputs: Iterable[Any], carry: Any = None) -> RunHistory:
        if self.slot_fn is None or self.agent is None:
            raise RuntimeError("the host loop needs slot_fn and agent")
        state = init_switch_state(self.default_mode)
        records: list[SlotRecord] = []
        for slot, x in enumerate(inputs):
            # -- slot setup phase --
            ctrl = self.agent.poll_control()
            if ctrl is not None:
                state = commit_decision(state, ctrl.mode)
            state = slot_boundary(
                state, fail_safe_mode=self.fail_safe_mode, ttl_slots=self.ttl_slots
            )
            active = int(state.active_mode)
            # -- pipeline execution --
            carry, output, kpms_by_source = self.slot_fn(state.active_mode, carry, x)
            # -- telemetry indication --
            flat: dict[str, float] = {}
            for source, kpms in kpms_by_source.items():
                kpms_f = {k: float(v) for k, v in kpms.items()}
                flat.update(kpms_f)
                self.agent.indicate(
                    E3IndicationMessage(slot=slot, source=source, kpms=kpms_f)
                )
            records.append(
                SlotRecord(
                    slot=slot,
                    active_mode=active,
                    kpms=flat,
                    output=output if self.keep_outputs else None,
                )
            )
        return RunHistory(records=records, final_state=state)
