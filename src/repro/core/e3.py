"""E3 interface emulation (paper 3.3).

On the X5G testbed the E3 Agent (RAN side) exposes telemetry via shared
memory + ZeroMQ indication messages and the E3 Manager (dApp side) handles
setup/subscription/delivery.  This container has no SHM/NIC fabric, so the
*transport* is an in-process queue while the *protocol logic* — setup,
subscription with periodicity, indication delivery, control replies, failure
detection — is implemented faithfully.  Transport cost is carried by the
latency model (paper: ~135 us framework overhead per loop).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Mapping


@dataclasses.dataclass(frozen=True)
class E3IndicationMessage:
    """Telemetry push: one source's KPMs for one slot."""

    slot: int
    source: str  # "aerial" | "oai"
    kpms: Mapping[str, float]


@dataclasses.dataclass(frozen=True)
class E3ControlMessage:
    """dApp -> RAN reply: the mode variable (a single scalar, paper 2)."""

    slot: int
    mode: int


@dataclasses.dataclass
class E3Subscription:
    callback: Callable[[E3IndicationMessage], None]
    period_slots: int = 1
    sources: tuple[str, ...] = ("aerial", "oai")


class E3Agent:
    """RAN-side endpoint: publishes KPMs, receives control messages."""

    def __init__(self):
        self._subs: list[E3Subscription] = []
        self._control_inbox: deque[E3ControlMessage] = deque()
        self.indications_sent = 0
        self.controls_received = 0

    def subscribe(self, sub: E3Subscription) -> None:
        self._subs.append(sub)

    def indicate(self, msg: E3IndicationMessage) -> None:
        for sub in self._subs:
            if msg.source in sub.sources and msg.slot % sub.period_slots == 0:
                sub.callback(msg)
                self.indications_sent += 1

    def send_control(self, msg: E3ControlMessage) -> None:
        self._control_inbox.append(msg)
        self.controls_received += 1

    def poll_control(self) -> E3ControlMessage | None:
        """RAN slot-setup phase: drain the newest pending control message."""
        latest = None
        while self._control_inbox:
            latest = self._control_inbox.popleft()
        return latest


class E3Manager:
    """dApp-side endpoint: wires the dApp logic to an agent."""

    def __init__(self, agent: E3Agent):
        self.agent = agent

    def setup(
        self,
        on_indication: Callable[[E3IndicationMessage], None],
        *,
        period_slots: int = 1,
        sources: tuple[str, ...] = ("aerial", "oai"),
    ) -> None:
        self.agent.subscribe(
            E3Subscription(
                callback=on_indication, period_slots=period_slots, sources=sources
            )
        )

    def send_mode(self, slot: int, mode: int) -> None:
        self.agent.send_control(E3ControlMessage(slot=slot, mode=mode))
