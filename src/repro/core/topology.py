"""Sharded multi-cell campaign topology: the UE axis across devices.

The batched engine (`repro.phy.pipeline.BatchedPuschPipeline`) runs one
cell's UE batch on one device.  This module lays a ``(n_slots, n_ues)``
campaign out as ``n_cells`` cells partitioned over a 1-D ``ues`` device
mesh and runs every batched execution path — open-loop, closed-loop, gated
and the perturbation sweep — under ``shard_map``:

* **Layout** — ``TopologySpec`` is the declarative (JSON-stable) form:
  cell count, shard count, per-cell channel offsets, inter-cell coupling.
  ``CellTopology.build`` resolves it against a concrete UE count and the
  available devices (``make_ue_mesh`` degrades gracefully to a 1-device
  mesh on a single-device container, so the sharded entry is always
  runnable).
* **Per-shard compaction** — each shard gates its own capacity-K sub-batch:
  the bank's cumsum partition / stable argsort / ``switch_scatter`` all see
  only the shard-local UE slice, so gated execution never performs a
  cross-device gather inside the scan body.  The engine's
  ``gated_capacity`` is therefore the *per-shard* capacity when the engine
  runs under a multi-shard topology (``ArchesSession`` divides a campaign
  capacity by the shard count).
* **Cell coupling** — per-cell noise/interference offsets plus inter-cell
  leakage enter the channel layer through ``repro.phy.channel.CellParams``;
  the per-cell mean load is the scan's *only* cross-shard collective (one
  ``psum`` of exact {0,1} counts, so the value — and hence the whole
  trajectory — is independent of the sharding).

The tested contract extends the repo's standing one: on a 1-device mesh
every sharded path is bitwise-equal on all physical trajectory leaves to
the unsharded engine, and on a forced multi-device CPU mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``) closed-loop mode
trajectories replay bitwise through ``host_replay_closed_loop``.

The production training meshes (``make_production_mesh`` /
``make_cpu_mesh``) are consolidated here from the orphaned
``repro.launch.mesh`` (which now re-exports them).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

UE_AXIS = "ues"


# -- mesh factories ------------------------------------------------------------


def make_ue_mesh(n_shards: int | None = None, *, n_ues: int | None = None):
    """A 1-D ``("ues",)`` mesh over the local devices.

    ``n_shards=None`` (auto) takes every available device; an explicit
    request is capped at the available device count — the CI container has
    one CPU device, so every topology degrades to a 1-device mesh there
    (force more with ``XLA_FLAGS=--xla_force_host_platform_device_count``).
    With ``n_ues`` given, the shard count is additionally reduced to the
    largest divisor of the UE count so every shard carries the same number
    of UEs (the static-shape discipline the scan engine requires).
    """
    devices = jax.devices()
    n = len(devices) if n_shards is None else max(1, min(n_shards, len(devices)))
    if n_ues is not None:
        while n_ues % n:
            n -= 1
    return jax.make_mesh((n,), (UE_AXIS,), devices=devices[:n])


def make_production_mesh(*, multi_pod: bool = False):
    """Production training meshes (multi-pod dry-run spec).

      single-pod: (16, 16)    = 256 chips, axes ("data", "model")
      multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model")

    Physical mapping on the v5e target: "model" follows the ICI torus minor
    dimension (TP collectives stay on-chip-neighbour links), "data" the
    major dimension, "pod" crosses the inter-pod DCN — which is why the
    default sharding rules put only pure-DP gradient reductions on the pod
    axis (DESIGN.md, distributed/sharding.py).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh for CPU integration tests (requires forced host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# -- declarative topology ------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """A campaign's cell/shard layout as data (JSON-stable, hashed).

    ``n_cells`` partitions the UE axis into equal contiguous cells (UE
    ``u`` belongs to cell ``u // (n_ues / n_cells)``); ``n_shards`` is the
    device-mesh request (``None`` == every local device; always degraded to
    what the host offers and to a divisor of ``n_ues``).
    ``cell_noise_offsets_db`` / ``cell_inr_offsets_db`` shift each cell's
    thermal noise / interference power (empty == no offset; else one entry
    per cell), and ``coupling`` sets the inter-cell leakage coefficient —
    see ``repro.phy.channel.CellParams``.
    """

    n_cells: int = 1
    n_shards: int | None = None
    coupling: float = 0.0
    cell_noise_offsets_db: tuple = ()
    cell_inr_offsets_db: tuple = ()

    def __post_init__(self):
        for name in ("cell_noise_offsets_db", "cell_inr_offsets_db"):
            v = getattr(self, name)
            object.__setattr__(
                self, name, tuple(float(x) for x in v)
            )
            v = getattr(self, name)
            if v and len(v) != self.n_cells:
                raise ValueError(
                    f"{name} has {len(v)} entries for n_cells={self.n_cells}"
                )
        if self.n_cells < 1:
            raise ValueError(f"n_cells {self.n_cells} must be >= 1")
        if self.n_shards is not None and self.n_shards < 1:
            raise ValueError(f"n_shards {self.n_shards} must be >= 1")


@dataclasses.dataclass(frozen=True)
class CellTopology:
    """A ``TopologySpec`` resolved against a UE count and the local devices.

    Carries everything the sharded entries need: the 1-D UE mesh, the
    global cell-id vector, and the traced ``CellParams`` pytree.
    """

    spec: TopologySpec
    n_ues: int
    n_shards: int
    mesh: Any
    cell_of_ue: np.ndarray  # (n_ues,) int32 global cell ids
    cell_params: Any  # repro.phy.channel.CellParams
    # jitted scan callables, keyed by (engine, kind, statics): jax's jit
    # cache is keyed on function identity, so re-wrapping a fresh closure
    # per run() call would recompile the whole scan every time
    _fn_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )

    @classmethod
    def build(
        cls, spec: TopologySpec, n_ues: int, *, mesh=None
    ) -> "CellTopology":
        from repro.phy.channel import cell_params

        if n_ues % spec.n_cells:
            raise ValueError(
                f"n_cells={spec.n_cells} does not divide n_ues={n_ues}: "
                "cells partition the UE axis into equal sub-batches"
            )
        if spec.n_shards is not None and n_ues % spec.n_shards:
            raise ValueError(
                f"n_shards={spec.n_shards} does not divide n_ues={n_ues}: "
                "every shard must carry the same number of UEs"
            )
        if mesh is None:
            mesh = make_ue_mesh(spec.n_shards, n_ues=n_ues)
        ues_per_cell = n_ues // spec.n_cells
        return cls(
            spec=spec,
            n_ues=n_ues,
            n_shards=mesh.shape[UE_AXIS],
            mesh=mesh,
            cell_of_ue=(np.arange(n_ues) // ues_per_cell).astype(np.int32),
            cell_params=cell_params(
                spec.n_cells,
                ues_per_cell,
                noise_offsets_db=spec.cell_noise_offsets_db,
                inr_offsets_db=spec.cell_inr_offsets_db,
                coupling=spec.coupling,
            ),
        )

    @property
    def n_cells(self) -> int:
        return self.spec.n_cells

    @property
    def ues_per_shard(self) -> int:
        return self.n_ues // self.n_shards


def per_shard_capacity(capacity: int, n_shards: int) -> int:
    """Split a campaign-wide gated capacity across shards.

    Compaction is shard-local, so the engine's ``gated_capacity`` under a
    sharded topology is the per-shard sub-batch size.  The campaign
    capacity must split evenly and leave at least one slot per shard —
    misconfiguration raises here (spec-compile time) instead of surfacing
    as a shape error deep in the scan.
    """
    if capacity % n_shards:
        raise ValueError(
            f"gated_capacity={capacity} does not divide across "
            f"n_shards={n_shards}: per-shard compaction needs an equal "
            "capacity-K sub-batch on every shard"
        )
    per_shard = capacity // n_shards
    if per_shard < 1:
        raise ValueError(
            f"gated_capacity={capacity} is < 1 per shard on "
            f"n_shards={n_shards}: every shard needs capacity for at "
            "least one UE (raise the capacity or lower the shard count)"
        )
    return per_shard


# -- sharded execution entries -------------------------------------------------
#
# Each entry mirrors the corresponding ``BatchedPuschPipeline`` method: the
# host-side preparation (schedule lowering, PRNG derivation, state init) is
# identical — the same per-UE fold_in keys regardless of the sharding — and
# the compiled scan is wrapped in ``shard_map`` over the UE mesh axis.  With
# ``sharded=False`` the same cell-coupled program runs unpartitioned (the
# bitwise reference the 1-device contract is tested against).


def _prepare(engine, topo: CellTopology, schedule, n_slots: int, key, ue_keys):
    from repro.phy.channel import broadcast_params_to_ues
    from repro.phy.pipeline import init_device_link, resolve_schedule

    if key is None:
        key = jax.random.PRNGKey(0)
    profile, params = resolve_schedule(
        engine.cfg, schedule, n_slots, topo.n_ues
    )
    params = broadcast_params_to_ues(params, topo.n_ues)
    if ue_keys is None:
        ue_keys = jax.vmap(lambda u: jax.random.fold_in(key, u))(
            jnp.arange(topo.n_ues)
        )
    elif ue_keys.shape[0] != topo.n_ues:
        raise ValueError(f"ue_keys {ue_keys.shape} vs n_ues {topo.n_ues}")
    link0 = init_device_link(topo.n_ues)
    return profile, params, ue_keys, link0


def _cached_jit(
    topo: CellTopology, key: tuple, build, *, donate_argnums: tuple = ()
) -> Any:
    """One jitted callable per (engine, program kind, statics) per topology.

    ``donate_argnums`` configures carry donation on the cached executable
    (streaming drivers donate their scan carries); callers that donate must
    put a marker in ``key`` so donating and non-donating programs cache
    separately.
    """
    fn = topo._fn_cache.get(key)
    if fn is None:
        fn = topo._fn_cache[key] = jax.jit(
            build(), donate_argnums=tuple(donate_argnums)
        )
    return fn


def _policy_spec(policy):
    """Per-leaf partition specs for a device policy pytree.

    Exported tables are replicated onto every shard; a ``PerUEPolicy``'s
    per-UE assignment vector is the one policy leaf that shards with its
    UEs.
    """
    from repro.core.closed_loop import PerUEPolicy

    if isinstance(policy, PerUEPolicy):
        return PerUEPolicy(
            tables=jax.tree.map(lambda _: P(), policy.tables),
            policy_idx=P(UE_AXIS),
        )
    return jax.tree.map(lambda _: P(), policy)


def open_loop_fn(
    engine, topo: CellTopology, profile, *, sharded: bool = True, faults=None
):
    """The (shard_map-wrapped) open-loop scan callable.

    Exposed separately from ``run_sharded`` so tests can inspect its jaxpr
    / lowered HLO for the collective contract (one psum for the cell mean,
    no gathers in the compaction path).  With a ``FaultSpec`` the callable
    grows a ``corrupt`` mask operand (``(S, U)``, sharded over its UEs —
    fault masking is element-local, no new collective).
    """
    axis = UE_AXIS if sharded else None

    if faults is None:
        def call(link0, ue_keys, modes, params, cell_of_ue, cell_params):
            return engine._run_scan(
                profile, link0, ue_keys, modes, params,
                cell_of_ue, cell_params, cell_axis=axis,
            )

        extra_specs = ()
    else:
        def call(link0, ue_keys, modes, params, cell_of_ue, cell_params,
                 corrupt):
            return engine._run_scan(
                profile, link0, ue_keys, modes, params,
                cell_of_ue, cell_params, cell_axis=axis,
                faults=faults, corrupt=corrupt,
            )

        extra_specs = (P(None, UE_AXIS),)

    if not sharded:
        return call
    return shard_map(
        call,
        mesh=topo.mesh,
        in_specs=(P(UE_AXIS), P(UE_AXIS), P(None, UE_AXIS), P(None, UE_AXIS),
                  P(UE_AXIS), P()) + extra_specs,
        out_specs=(P(UE_AXIS), P(None, UE_AXIS)),
        check_rep=False,
    )


def run_sharded(
    engine,
    topo: CellTopology,
    schedule,
    modes,
    *,
    n_slots: int,
    key=None,
    ue_keys=None,
    sharded: bool = True,
    faults=None,
):
    """Open-loop campaign over the sharded topology.

    The sharded analogue of ``BatchedPuschPipeline.run`` (scan path): same
    schedule/modes/key/faults semantics; ``(final_link, trajectory)`` out.
    """
    from repro.phy.pipeline import normalize_modes

    profile, params, ue_keys, link0 = _prepare(
        engine, topo, schedule, n_slots, key, ue_keys
    )
    modes = normalize_modes(modes, n_slots, topo.n_ues)
    fn = _cached_jit(
        topo, (engine, "open_loop", profile, sharded, faults),
        lambda: open_loop_fn(
            engine, topo, profile, sharded=sharded, faults=faults
        ),
    )
    args = (
        link0, ue_keys, modes, params,
        jnp.asarray(topo.cell_of_ue), topo.cell_params,
    )
    if faults is not None:
        corrupt = jnp.asarray(faults.resolve(n_slots, topo.n_ues).corrupt)
        args = args + (corrupt,)
    return fn(*args)


def closed_loop_fn(
    engine, topo: CellTopology, profile, sw_cfg, policy,
    *, sharded: bool = True, faults=None,
):
    """The (shard_map-wrapped) closed-loop scan callable (jaxpr-inspectable).

    With a ``FaultSpec`` the callable grows a ``fault_masks`` operand (the
    ``(decision_valid, corrupt, telemetry_valid)`` triple of ``(S, U)``
    masks, each sharded over its UEs) — the degradation ladder is
    UE-element-local, so the single cell-mean ``psum`` stays the scan's
    only cross-shard collective.
    """
    axis = UE_AXIS if sharded else None

    if faults is None:
        def call(link0, sw0, ue_keys, params, policy, cell_of_ue,
                 cell_params):
            return engine._run_closed_scan(
                profile, sw_cfg, link0, sw0, ue_keys, params, policy,
                cell_of_ue, cell_params, cell_axis=axis,
            )

        extra_specs = ()
    else:
        def call(link0, sw0, ue_keys, params, policy, cell_of_ue,
                 cell_params, fault_masks):
            return engine._run_closed_scan(
                profile, sw_cfg, link0, sw0, ue_keys, params, policy,
                cell_of_ue, cell_params, cell_axis=axis,
                faults=faults, fault_masks=fault_masks,
            )

        extra_specs = (P(None, UE_AXIS),)

    if not sharded:
        return call
    return shard_map(
        call,
        mesh=topo.mesh,
        in_specs=(P(UE_AXIS), P(UE_AXIS), P(UE_AXIS), P(None, UE_AXIS),
                  _policy_spec(policy), P(UE_AXIS), P()) + extra_specs,
        out_specs=(P(UE_AXIS), P(UE_AXIS), P(None, UE_AXIS)),
        check_rep=False,
    )


def streaming_open_loop_fn(
    engine, topo: CellTopology, profile, *, sharded: bool = True, faults=None
):
    """Streaming-segment open-loop scan callable (jaxpr/HLO-inspectable).

    The sharded entry the epoch-chunked driver calls once per segment: the
    same program as ``open_loop_fn`` plus the two streaming operands —
    the replicated global segment start ``slot0`` (so per-slot PRNG folds
    stay keyed by the *campaign* slot index across segments) and the
    per-bank-slot ``active`` mask, which shards with its UEs.  The
    collective contract is unchanged through re-packs: the cell-mean
    ``psum`` stays the scan's only cross-shard collective (detached lanes
    are masked out of the summed load before it), and admission re-packing
    happens host-side *between* segments, cell-block-aligned, so no gather
    ever enters the compiled program.
    """
    axis = UE_AXIS if sharded else None

    if faults is None:
        def call(link0, ue_keys, modes, params, cell_of_ue, cell_params,
                 slot0, active):
            return engine._run_scan(
                profile, link0, ue_keys, modes, params,
                cell_of_ue, cell_params, cell_axis=axis,
                slot0=slot0, active=active,
            )

        extra_specs = ()
    else:
        def call(link0, ue_keys, modes, params, cell_of_ue, cell_params,
                 slot0, active, corrupt):
            return engine._run_scan(
                profile, link0, ue_keys, modes, params,
                cell_of_ue, cell_params, cell_axis=axis,
                slot0=slot0, active=active,
                faults=faults, corrupt=corrupt,
            )

        extra_specs = (P(None, UE_AXIS),)

    if not sharded:
        return call
    return shard_map(
        call,
        mesh=topo.mesh,
        in_specs=(P(UE_AXIS), P(UE_AXIS), P(None, UE_AXIS), P(None, UE_AXIS),
                  P(UE_AXIS), P(), P(), P(UE_AXIS)) + extra_specs,
        out_specs=(P(UE_AXIS), P(None, UE_AXIS)),
        check_rep=False,
    )


def streaming_closed_loop_fn(
    engine, topo: CellTopology, profile, sw_cfg, policy,
    *, sharded: bool = True, faults=None,
):
    """Streaming-segment closed-loop scan callable.

    ``closed_loop_fn`` plus the streaming operands (see
    ``streaming_open_loop_fn``); the per-UE switch state shards with its
    UEs and is gathered/cold-started host-side at segment boundaries.
    """
    axis = UE_AXIS if sharded else None

    if faults is None:
        def call(link0, sw0, ue_keys, params, policy, cell_of_ue,
                 cell_params, slot0, active):
            return engine._run_closed_scan(
                profile, sw_cfg, link0, sw0, ue_keys, params, policy,
                cell_of_ue, cell_params, cell_axis=axis,
                slot0=slot0, active=active,
            )

        extra_specs = ()
    else:
        def call(link0, sw0, ue_keys, params, policy, cell_of_ue,
                 cell_params, slot0, active, fault_masks):
            return engine._run_closed_scan(
                profile, sw_cfg, link0, sw0, ue_keys, params, policy,
                cell_of_ue, cell_params, cell_axis=axis,
                slot0=slot0, active=active,
                faults=faults, fault_masks=fault_masks,
            )

        extra_specs = (P(None, UE_AXIS),)

    if not sharded:
        return call
    return shard_map(
        call,
        mesh=topo.mesh,
        in_specs=(P(UE_AXIS), P(UE_AXIS), P(UE_AXIS), P(None, UE_AXIS),
                  _policy_spec(policy), P(UE_AXIS), P(), P(), P(UE_AXIS))
                 + extra_specs,
        out_specs=(P(UE_AXIS), P(UE_AXIS), P(None, UE_AXIS)),
        check_rep=False,
    )


def run_closed_loop_sharded(
    engine,
    topo: CellTopology,
    schedule,
    policy,
    sw_cfg,
    *,
    n_slots: int,
    key=None,
    ue_keys=None,
    sharded: bool = True,
    faults=None,
):
    """Closed-loop campaign over the sharded topology.

    Mirrors ``BatchedPuschPipeline.run_closed_loop`` (scan path): the
    per-UE decision state shards with its UEs, exported policy tables are
    replicated, and the whole loop stays one compiled program per shard.
    Returns ``(final_link, final_switch_state, trajectory)``.
    """
    from repro.core.closed_loop import init_device_switch

    profile, params, ue_keys, link0 = _prepare(
        engine, topo, schedule, n_slots, key, ue_keys
    )
    sw0 = init_device_switch(
        topo.n_ues, len(sw_cfg.feature_names), sw_cfg, faults
    )
    fn = _cached_jit(
        topo,
        (engine, "closed_loop", profile, sw_cfg,
         jax.tree.structure(policy), sharded, faults),
        lambda: closed_loop_fn(
            engine, topo, profile, sw_cfg, policy, sharded=sharded,
            faults=faults,
        ),
    )
    args = (
        link0, sw0, ue_keys, params, policy,
        jnp.asarray(topo.cell_of_ue), topo.cell_params,
    )
    if faults is not None:
        rf = faults.resolve(n_slots, topo.n_ues)
        args = args + ((
            jnp.asarray(rf.decision_valid),
            jnp.asarray(rf.corrupt),
            jnp.asarray(rf.telemetry_valid),
        ),)
    return fn(*args)


def run_perturbed_sharded(
    engine,
    topo: CellTopology,
    schedule,
    rho,
    *,
    n_slots: int,
    key=None,
    ue_keys=None,
    sharded: bool = True,
):
    """Methodology stage-1 sweep over the sharded topology.

    Mirrors ``BatchedPuschPipeline.run_perturbed``: the rho grid rides the
    UE axis, so it shards with the UEs.
    """
    axis = UE_AXIS if sharded else None
    rho = jnp.asarray(rho, jnp.float32)
    if rho.shape[0] != topo.n_ues:
        raise ValueError(f"rho {rho.shape} vs topology n_ues {topo.n_ues}")
    profile, params, ue_keys, link0 = _prepare(
        engine, topo, schedule, n_slots, key, ue_keys
    )

    def build():
        def call(link0, ue_keys, rho, params, cell_of_ue, cell_params):
            return engine._run_perturbed_scan(
                profile, link0, ue_keys, rho, params,
                cell_of_ue, cell_params, cell_axis=axis,
            )

        if not sharded:
            return call
        return shard_map(
            call,
            mesh=topo.mesh,
            in_specs=(P(UE_AXIS), P(UE_AXIS), P(UE_AXIS), P(None, UE_AXIS),
                      P(UE_AXIS), P()),
            out_specs=(P(UE_AXIS), P(None, UE_AXIS)),
            check_rep=False,
        )

    fn = _cached_jit(topo, (engine, "perturbed", profile, sharded), build)
    return fn(
        link0, ue_keys, rho, params,
        jnp.asarray(topo.cell_of_ue), topo.cell_params,
    )
