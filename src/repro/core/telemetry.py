"""Cross-layer KPM telemetry (paper 2, 4.3, 6).

KPM names and layer attribution follow the paper exactly:

* **Aerial Data Lake** (PHY, per-slot): code rate, SINR, QAM order, MCS
  index, TB size, #code blocks, PDU length, NDI, RSRP — plus PHY throughput,
  which is *cumulative* and therefore excluded from the correlation analysis
  (paper 4.3) but retained as a policy input.
* **OAI** (L2+): SNR, MAC throughput, LCID4 throughput, MAC RX bytes, LCID4
  RX bytes.

The final selected set (paper 4.3) is reproduced by the methodology in
``repro.core.methodology``; ``SELECTED_KPMS`` records the paper's outcome and
is validated against the methodology's output in the tests.

``KPMRing`` is a fixed-capacity functional ring buffer so telemetry windows
can live inside jitted slot loops.
"""

from __future__ import annotations

from typing import Mapping, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# -- registry ---------------------------------------------------------------

AERIAL_CANDIDATE_KPMS: tuple[str, ...] = (
    "code_rate",
    "sinr",
    "qam_order",
    "mcs_index",
    "tb_size",
    "num_cbs",
    "pdu_length",
    "ndi",
    "rsrp",
)
AERIAL_CUMULATIVE_KPMS: tuple[str, ...] = ("phy_throughput",)
OAI_CANDIDATE_KPMS: tuple[str, ...] = (
    "snr",
    "mac_throughput",
    "lcid4_throughput",
    "mac_rx_bytes",
    "lcid4_rx_bytes",
)

#: The paper's final policy input set (4.3): 5 Aerial + 5 OAI KPMs.
SELECTED_KPMS: tuple[str, ...] = (
    "phy_throughput",
    "mcs_index",
    "pdu_length",
    "ndi",
    "rsrp",
    "snr",
    "mac_throughput",
    "lcid4_throughput",
    "mac_rx_bytes",
    "lcid4_rx_bytes",
)

ALL_CANDIDATE_KPMS: tuple[str, ...] = AERIAL_CANDIDATE_KPMS + OAI_CANDIDATE_KPMS

#: Execution-cost leaves the batched engine adds to every trajectory.  They
#: are *accounting*, not channel KPMs: excluded from policy feature vectors
#: and from gated-vs-concurrent equivalence checks (the two paths agree on
#: every physical output but deliberately differ in realized compute).
#: ``BatchedRunHistory.executed_flops_per_slot()`` / ``overflow_slot_ues``
#: are the aggregate views.
EXECUTION_COST_KPMS: tuple[str, ...] = (
    "executed_flops",
    "gated_overflow",
    "audit_tripped",
    "health_tripped",
    "quarantined",
)


def physical_trajectory(traj: Mapping[str, jax.Array]) -> dict[str, jax.Array]:
    """A trajectory's physical leaves: everything but the cost accounting.

    This is the domain of the gated-vs-concurrent equivalence contract —
    the two execution paths must agree bitwise on every leaf returned here
    and are expected to differ on the ``EXECUTION_COST_KPMS`` leaves.
    """
    return {k: v for k, v in traj.items() if k not in EXECUTION_COST_KPMS}


def kpm_vector(kpms: Mapping[str, jax.Array | float], names: Sequence[str]):
    """Order a KPM mapping into a dense feature vector."""
    return jnp.stack([jnp.asarray(kpms[n], jnp.float32) for n in names])


def flatten_kpm_sources(
    kpms_by_source: Mapping[str, Mapping[str, jax.Array]],
) -> dict[str, jax.Array]:
    """Merge ``{source: {kpm: value}}`` into one flat ``{kpm: value}`` map.

    Mirrors what ``ArchesRuntime`` does per slot, for whole batched
    trajectories at once (values may carry any leading shape).
    """
    flat: dict[str, jax.Array] = {}
    for kpms in kpms_by_source.values():
        flat.update(kpms)
    return flat


def trajectory_kpm_matrix(
    kpms_by_source: Mapping[str, Mapping[str, jax.Array]],
    names: Sequence[str] = SELECTED_KPMS,
) -> jax.Array:
    """Stack a batched trajectory into a policy feature tensor.

    Input values are ``(n_slots, n_ues)`` (the batched engine's KPM leaves);
    output is ``(n_slots, n_ues, len(names))`` float32 — ready to reshape
    into per-sample rows for decision-tree fitting or batched inference.

    Leaves may carry any leading shape: the closed-loop scan calls this on
    a single slot's ``(n_ues,)`` leaves to build the ``(n_ues, F)`` feature
    matrix the device policy consumes — guaranteeing the in-scan features
    and the post-hoc host-replay features are the same stacking of the same
    arrays.
    """
    flat = flatten_kpm_sources(kpms_by_source)
    return jnp.stack(
        [jnp.asarray(flat[n], jnp.float32) for n in names], axis=-1
    )


# -- segment-boundary aggregation (service telemetry export) ------------------

#: fall-back accounting leaves folded into the served-by-AI reduction and
#: exported as per-segment counters when present
_FALLBACK_LEAVES = (
    "gated_overflow",
    "audit_tripped",
    "health_tripped",
    "quarantined",
)


def segment_telemetry(history, t0: int, t1: int, *, local: bool = False) -> dict:
    """Reduce one slot span of a ``BatchedRunHistory`` to flat scalars.

    The campaign service calls this at segment boundaries (slots
    ``[t0, t1)``) to feed its export ring: per-segment mean throughput and
    AI share over *resident* slot-UEs (served-not-selected semantics, like
    ``BatchedRunHistory.ai_share``), executed FLOPs, the degradation-ladder
    counters, and — under a multi-cell topology — the per-cell throughput
    vector.  Everything is copied out as plain Python scalars/lists, so the
    result stays valid after the driver reuses its accumulators for the
    next segment (and serializes straight to JSON).

    ``local=True`` says ``history`` is a span-local view — every 2-D
    leaf's rows are already exactly ``[t0, t1)``, as in the streaming
    driver's ``SegmentEvent.segment_history`` — the O(segment) input that
    keeps per-boundary telemetry cost independent of how deep into the
    campaign the segment sits.  ``t0``/``t1`` always name the *global*
    slot span and are echoed in the result either way.
    """
    if t0 < 0 or t1 <= t0:
        raise ValueError(f"slot span [{t0}, {t1}) is empty or negative")
    n_rows = int(np.shape(history.modes)[0])
    if local:
        if n_rows != t1 - t0:
            raise ValueError(
                f"local span view holds {n_rows} slot rows but the span "
                f"[{t0}, {t1}) covers {t1 - t0}"
            )
        lo, hi = 0, n_rows
    elif t1 <= n_rows:
        lo, hi = t0, t1
    else:
        raise ValueError(
            f"slot span [{t0}, {t1}) outside the campaign horizon "
            f"[0, {n_rows})"
        )
    modes = np.asarray(history.modes)[lo:hi]
    resident = (
        np.ones(modes.shape, bool)
        if history.attached is None
        else np.asarray(history.attached, bool)[lo:hi]
    )
    served = (modes == 0) & resident
    for k in _FALLBACK_LEAVES:
        if k in history.outputs:
            served &= np.asarray(history.outputs[k])[lo:hi] == 0
    n_resident = int(resident.sum())
    out: dict = {
        "t0": int(t0),
        "t1": int(t1),
        "resident_slot_ues": n_resident,
        "ai_share": (
            float(served[resident].mean()) if n_resident else 0.0
        ),
    }
    if "phy_throughput" in history.kpms:
        tput = np.asarray(history.kpms["phy_throughput"])[lo:hi]
        out["throughput_bps"] = (
            float(tput[resident].mean()) if n_resident else 0.0
        )
        if history.cell_of_ue is not None:
            cells = np.asarray(history.cell_of_ue)
            per_cell = []
            for c in range(int(cells.max()) + 1):
                sel = resident[:, cells == c]
                per_cell.append(
                    float(tput[:, cells == c][sel].mean()) if sel.any()
                    else 0.0
                )
            out["per_cell_throughput_bps"] = per_cell
    if "executed_flops" in history.outputs:
        out["executed_flops"] = float(
            np.asarray(history.outputs["executed_flops"], np.float64)
            [lo:hi].sum()
        )
    for k in _FALLBACK_LEAVES:
        if k in history.outputs:
            out[f"{k}_slot_ues"] = int(
                (np.asarray(history.outputs[k])[lo:hi] > 0).sum()
            )
    return out


# -- functional ring buffer ---------------------------------------------------


class KPMRing(NamedTuple):
    buf: jax.Array  # (capacity, n_kpms) float32
    idx: jax.Array  # int32 — next write position
    count: jax.Array  # int32 — total pushes (saturates at capacity for reads)


def ring_init(capacity: int, n_kpms: int) -> KPMRing:
    return KPMRing(
        buf=jnp.zeros((capacity, n_kpms), jnp.float32),
        idx=jnp.int32(0),
        count=jnp.int32(0),
    )


def ring_push(ring: KPMRing, vec: jax.Array) -> KPMRing:
    cap = ring.buf.shape[0]
    buf = jax.lax.dynamic_update_slice(ring.buf, vec[None, :], (ring.idx, 0))
    return KPMRing(
        buf=buf,
        idx=(ring.idx + 1) % cap,
        count=jnp.minimum(ring.count + 1, jnp.int32(2**30)),
    )


def ring_window_mean(ring: KPMRing, window: int) -> jax.Array:
    """Mean over the most recent ``min(window, count)`` entries."""
    cap, n = ring.buf.shape
    window = min(window, cap)
    # positions of the last `window` writes, newest first
    offsets = jnp.arange(1, window + 1, dtype=jnp.int32)
    pos = (ring.idx - offsets) % cap
    rows = ring.buf[pos]  # (window, n)
    valid = (offsets <= ring.count)[:, None].astype(jnp.float32)
    denom = jnp.maximum(valid.sum(), 1.0)
    return (rows * valid).sum(axis=0) / denom


def ring_matrix(ring: KPMRing) -> tuple[jax.Array, jax.Array]:
    """All valid rows (oldest->newest order not guaranteed) + validity mask."""
    cap = ring.buf.shape[0]
    valid = jnp.arange(cap) < ring.count
    return ring.buf, valid
