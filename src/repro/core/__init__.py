"""ARCHES core: the paper's contribution as composable JAX modules."""

from repro.core.closed_loop import (
    DeviceSwitchState,
    DeviceThresholdPolicy,
    DeviceTreePolicy,
    PerUEPolicy,
    SwitchConfig,
    export_tree_tables,
    host_replay_closed_loop,
    init_device_switch,
    per_ue_policy,
    policy_infer,
    switch_boundary,
    switch_update,
)
from repro.core.dapp import ControlLoopLatency, DApp, Decision, connect_dapp
from repro.core.e3 import (
    E3Agent,
    E3ControlMessage,
    E3IndicationMessage,
    E3Manager,
    E3Subscription,
)
from repro.core.expert_bank import BankOutput, ExecutionMode, Expert, ExpertBank
from repro.core.methodology import (
    ClusterResult,
    SweepResult,
    design_policy_inputs,
    monotonicity_filter,
    perturb_estimate,
    redundancy_reduction,
    sensitivity_sweep,
)
from repro.core.policy import (
    DecisionTreePolicy,
    FittedTree,
    ThresholdPolicy,
    classification_metrics,
    fit_decision_tree,
    profile_and_fit_tree,
)
from repro.core.runtime import (
    ArchesRuntime,
    BatchedRunHistory,
    RunHistory,
    SlotRecord,
    replay_batched_telemetry,
    suggest_gated_capacity,
)
from repro.core.session import (
    ArchesSession,
    CampaignSpec,
    ExecutionPath,
    ExpertBankSpec,
    PolicySpec,
    SwitchSpec,
    spec_hash,
)
from repro.core.switch import (
    SlotSwitchState,
    commit_decision,
    init_switch_state,
    slot_boundary,
)
from repro.core.topology import (
    CellTopology,
    TopologySpec,
    make_cpu_mesh,
    make_production_mesh,
    make_ue_mesh,
    per_shard_capacity,
    run_closed_loop_sharded,
    run_perturbed_sharded,
    run_sharded,
)
from repro.core.telemetry import (
    AERIAL_CANDIDATE_KPMS,
    AERIAL_CUMULATIVE_KPMS,
    ALL_CANDIDATE_KPMS,
    OAI_CANDIDATE_KPMS,
    SELECTED_KPMS,
    KPMRing,
    kpm_vector,
    ring_init,
    ring_matrix,
    ring_push,
    ring_window_mean,
)
