"""Switchable expert bank (paper 2, 3.1).

A bank of N experts executes on the same input; a switch selects the
designated output.  Two execution modes:

* ``CONCURRENT`` — every expert runs each slot and the Pallas switch kernel
  (``repro.kernels.switch_select``) selects the output.  Zero switching
  latency; exposes all expert outputs for online benchmarking (this is the
  mode the paper uses for all experiments).
* ``SELECTED_ONLY`` — only the active expert executes, via ``jax.lax.switch``
  (XLA conditional: exactly one branch runs).  Saves compute/energy at the
  cost of at least a one-slot activation delay — quantified by the
  ``cost_model`` below.

Mode numbering follows the paper: the bank is constructed with the
*designated* expert first (mode 0 == its output is already in the downstream
buffer; for the channel-estimation case study that is the AI estimator) and
the fail-safe conventional expert is whatever index the caller passes as
``default_mode`` (mode 1 == MMSE in the case study).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.kernels.switch_select import switch_select


class ExecutionMode(enum.Enum):
    CONCURRENT = "concurrent"
    SELECTED_ONLY = "selected_only"


@dataclasses.dataclass(frozen=True)
class Expert:
    """One entry of the bank.

    ``fn(params, *inputs) -> output`` must return structurally identical
    pytrees across all experts in a bank (the uniform downstream interface).
    ``flops``/``bytes_hbm`` are static per-call costs used by the
    energy/utilization proxy (DESIGN.md 2).
    """

    name: str
    fn: Callable[..., Any]
    params: Any = None
    flops: float = 0.0
    bytes_hbm: float = 0.0


@dataclasses.dataclass(frozen=True)
class BankOutput:
    selected: Any  # pytree — contents of the designated buffer post-switch
    all_outputs: tuple | None  # per-expert outputs (concurrent mode only)
    mode: jax.Array


class ExpertBank:
    """N-expert switchable bank with a uniform downstream interface."""

    def __init__(
        self,
        experts: Sequence[Expert],
        *,
        default_mode: int = 1,
        execution_mode: ExecutionMode = ExecutionMode.CONCURRENT,
        use_pallas_switch: bool = True,
    ):
        if len(experts) < 2:
            raise ValueError("an expert bank needs at least 2 experts")
        if not 0 <= default_mode < len(experts):
            raise ValueError(f"default_mode {default_mode} out of range")
        self.experts = tuple(experts)
        self.default_mode = default_mode
        self.execution_mode = execution_mode
        self.use_pallas_switch = use_pallas_switch

    @property
    def n_experts(self) -> int:
        return len(self.experts)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(e.name for e in self.experts)

    def __call__(self, mode: jax.Array, *inputs) -> BankOutput:
        """Run the bank.

        ``mode`` is an int32 scalar, or an ``(n_ues,)`` vector for the
        batched multi-UE engine — in which case every expert output must
        carry a leading UE axis and UE ``u`` receives expert ``mode[u]``'s
        output (different UEs can run different experts in the same slot).
        """
        mode = jnp.asarray(mode, jnp.int32)
        if self.execution_mode is ExecutionMode.CONCURRENT:
            return self._run_concurrent(mode, *inputs)
        return self._run_selected(mode, *inputs)

    def _run_concurrent(self, mode: jax.Array, *inputs) -> BankOutput:
        outputs = tuple(e.fn(e.params, *inputs) for e in self.experts)
        if self.use_pallas_switch:
            selected = switch_select(mode, list(outputs))
        elif mode.ndim == 1:  # batched oracle path
            from repro.kernels.switch_select.ref import (
                switch_select_batched_tree_ref,
            )

            selected = switch_select_batched_tree_ref(mode, list(outputs))
        else:  # oracle path (used by the property tests)
            stacked = jax.tree.map(lambda *ls: jnp.stack(ls, 0), *outputs)
            selected = jax.tree.map(lambda s: jnp.take(s, mode, axis=0), stacked)
        return BankOutput(selected=selected, all_outputs=outputs, mode=mode)

    def _run_selected(self, mode: jax.Array, *inputs) -> BankOutput:
        if mode.ndim == 1:
            # Per-UE modes make "run only the selected expert" ill-posed:
            # any expert some UE selects must execute.  Degenerate to the
            # concurrent cost envelope and gather per UE (predication), but
            # keep the SELECTED_ONLY interface (no all_outputs exposure).
            from repro.kernels.switch_select.ref import (
                switch_select_batched_tree_ref,
            )

            outputs = [e.fn(e.params, *inputs) for e in self.experts]
            selected = switch_select_batched_tree_ref(mode, outputs)
            return BankOutput(selected=selected, all_outputs=None, mode=mode)
        branches = [
            (lambda e: (lambda *xs: e.fn(e.params, *xs)))(e) for e in self.experts
        ]
        selected = jax.lax.switch(mode, branches, *inputs)
        return BankOutput(selected=selected, all_outputs=None, mode=mode)

    # ---- static cost model (drives the energy/utilization proxy) ----
    def flops_for(self, mode: int | None = None) -> float:
        """FLOPs per slot: all experts (concurrent) or one (selected-only)."""
        if self.execution_mode is ExecutionMode.CONCURRENT:
            return float(sum(e.flops for e in self.experts))
        assert mode is not None
        return float(self.experts[mode].flops)

    def bytes_for(self, mode: int | None = None) -> float:
        if self.execution_mode is ExecutionMode.CONCURRENT:
            return float(sum(e.bytes_hbm for e in self.experts))
        assert mode is not None
        return float(self.experts[mode].bytes_hbm)
