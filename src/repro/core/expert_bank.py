"""Switchable expert bank (paper 2, 3.1).

A bank of N experts executes on the same input; a switch selects the
designated output.  Three execution modes:

* ``CONCURRENT`` — every expert runs each slot and the Pallas switch kernel
  (``repro.kernels.switch_select``) selects the output.  Zero switching
  latency; exposes all expert outputs for online benchmarking (this is the
  mode the paper uses for all experiments).
* ``SELECTED_ONLY`` — only the active expert executes, via ``jax.lax.switch``
  (XLA conditional: exactly one branch runs).  Saves compute/energy at the
  cost of at least a one-slot activation delay — quantified by the
  ``cost_model`` below.
* ``GATED`` — the batched multi-UE compromise between the two: the cheap
  non-designated experts run densely on every UE, while the designated
  (expensive) expert runs only on the UEs whose mode selects it, compacted
  into a dense capacity-``K`` sub-batch (stable cumsum partition, static
  shapes), then scattered back over the cheap baseline by the fused
  ``switch_scatter`` pass.  Compute scales with the *selected* expert mix —
  the performance-per-watt posture the paper's Fig. 11 argues for — and the
  output is bitwise-equal to ``CONCURRENT`` on the same mode vector as long
  as no UE overflows the capacity.  UEs past capacity fall back to the
  fail-safe ``default_mode`` expert for that slot (the real-time analogue of
  the paper's slot-boundary guarantee) and are flagged in
  ``BankOutput.overflow``.

Mode numbering follows the paper: the bank is constructed with the
*designated* expert first (mode 0 == its output is already in the downstream
buffer; for the channel-estimation case study that is the AI estimator) and
the fail-safe conventional expert is whatever index the caller passes as
``default_mode`` (mode 1 == MMSE in the case study).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.kernels.switch_select import switch_scatter, switch_select


def coerce_enum(cls: type, value, noun: str):
    """Accept an enum member or its string value (the spec/JSON form).

    Shared by the spec-facing enums (``ExecutionMode`` here,
    ``ExecutionPath`` in ``repro.core.session``) so their coercion and
    error shape cannot drift apart.
    """
    if isinstance(value, cls):
        return value
    try:
        return cls(str(value).lower())
    except ValueError:
        raise ValueError(
            f"unknown {noun} {value!r}; one of {[m.value for m in cls]}"
        ) from None


class ExecutionMode(enum.Enum):
    CONCURRENT = "concurrent"
    SELECTED_ONLY = "selected_only"
    GATED = "gated"

    @classmethod
    def coerce(cls, value: "ExecutionMode | str") -> "ExecutionMode":
        return coerce_enum(cls, value, "execution mode")


@dataclasses.dataclass(frozen=True)
class Expert:
    """One entry of the bank.

    ``fn(params, *inputs) -> output`` must return structurally identical
    pytrees across all experts in a bank (the uniform downstream interface).
    ``flops``/``bytes_hbm`` are static per-call costs used by the
    energy/utilization proxy (DESIGN.md 2).  In the batched multi-UE engine
    a "call" serves one UE-slot, so these are per-UE-slot costs and the
    executed-cost accounting below multiplies by served-UE counts.
    """

    name: str
    fn: Callable[..., Any]
    params: Any = None
    flops: float = 0.0
    bytes_hbm: float = 0.0


def _batched_nmse(selected, baseline) -> jax.Array:
    """Per-UE NMSE of ``selected`` vs ``baseline`` across all leaves.

    Both are pytrees of ``(n_ues, ...)`` leaves; returns ``(n_ues,)`` f32
    ``sum |sel - base|^2 / sum |base|^2`` (sums over every non-UE axis and
    every leaf).  The in-scan accuracy audit for reduced-precision gated
    experts: no ground truth exists inside the scan, so divergence is
    measured against the always-computed fail-safe baseline.
    """

    def powers(s, b):
        d = s - b
        axes = tuple(range(1, d.ndim))
        err = jnp.sum(jnp.abs(d).astype(jnp.float32) ** 2, axis=axes)
        ref = jnp.sum(jnp.abs(b).astype(jnp.float32) ** 2, axis=axes)
        return err, ref

    pairs = jax.tree.leaves(jax.tree.map(powers, selected, baseline),
                            is_leaf=lambda x: isinstance(x, tuple))
    err = sum(p[0] for p in pairs)
    ref = sum(p[1] for p in pairs)
    return err / jnp.maximum(ref, jnp.float32(1e-30))


@dataclasses.dataclass(frozen=True)
class BankOutput:
    selected: Any  # pytree — contents of the designated buffer post-switch
    all_outputs: tuple | None  # per-expert outputs (concurrent mode only)
    mode: jax.Array
    # -- executed-cost accounting (traced; ride the slot scan) --------------
    # UEs each expert actually served this call ((n_experts,) int32).  In
    # CONCURRENT mode every expert serves every UE; in GATED mode the
    # designated expert serves only the compacted (capacity-capped) UEs.
    executed_ue: jax.Array | None = None
    # expert index that produced each UE's output ((n_ues,) int32; batched
    # calls only).  Differs from ``mode`` exactly on capacity overflow.
    served_by: jax.Array | None = None
    # capacity-overflow flags ((n_ues,) bool; GATED only): UE selected the
    # gated expert but fell back to ``default_mode`` this slot.
    overflow: jax.Array | None = None
    # accuracy-audit flags ((n_ues,) bool; GATED + audit_threshold only):
    # the gated expert served this UE but its output failed the in-scan
    # NMSE audit vs the dense fail-safe baseline, so the baseline was kept.
    # The expert still *executed* for the UE (cost accounting counts it).
    audit_tripped: jax.Array | None = None
    # fail-safe baseline output (pytree of (n_ues, ...) leaves; batched calls
    # only): the densely-run default expert's output, the revert target for
    # the in-scan health screen (fault injection) and the NMSE audit.
    baseline: Any = None


class ExpertBank:
    """N-expert switchable bank with a uniform downstream interface."""

    def __init__(
        self,
        experts: Sequence[Expert],
        *,
        default_mode: int = 1,
        execution_mode: ExecutionMode = ExecutionMode.CONCURRENT,
        use_pallas_switch: bool = True,
        gated_capacity: int | None = None,
        gated_fused_apply: Callable[..., Any] | None = None,
        audit_threshold: float | None = None,
    ):
        if len(experts) < 2:
            raise ValueError("an expert bank needs at least 2 experts")
        if not 0 <= default_mode < len(experts):
            raise ValueError(f"default_mode {default_mode} out of range")
        if execution_mode is ExecutionMode.GATED and default_mode == 0:
            raise ValueError(
                "GATED gates the designated expert (mode 0); the fail-safe "
                "default_mode must be a different, cheap expert"
            )
        if gated_capacity is not None and gated_capacity < 0:
            raise ValueError(f"gated_capacity {gated_capacity} must be >= 0")
        if gated_fused_apply is not None and (
            execution_mode is not ExecutionMode.GATED
        ):
            raise ValueError("gated_fused_apply requires GATED execution")
        if audit_threshold is not None:
            if execution_mode is not ExecutionMode.GATED:
                raise ValueError(
                    "audit_threshold requires GATED execution (the audit "
                    "compares against the densely-run fail-safe baseline)"
                )
            if not audit_threshold > 0:
                raise ValueError(
                    f"audit_threshold {audit_threshold} must be > 0"
                )
        self.experts = tuple(experts)
        self.default_mode = default_mode
        self.execution_mode = execution_mode
        self.use_pallas_switch = use_pallas_switch
        #: dense sub-batch size for GATED execution; ``None`` == full batch
        #: (no overflow possible), ``0`` == gated expert never runs.
        self.gated_capacity = gated_capacity
        #: optional fused hot path for GATED: ``(idx, src, base, *inputs) ->
        #: selected`` replaces the gather / expert-fn / scatter triple with
        #: one kernel (``repro.kernels.gated_expert``).  Must be
        #: bitwise-equal to the unfused composition.
        self.gated_fused_apply = gated_fused_apply
        #: optional in-scan accuracy audit for GATED: per-UE NMSE of the
        #: gated expert's output vs the fail-safe baseline; UEs whose NMSE
        #: exceeds the threshold (or is NaN/inf) revert to the baseline and
        #: are flagged in ``BankOutput.audit_tripped``.
        self.audit_threshold = audit_threshold

    @property
    def n_experts(self) -> int:
        return len(self.experts)

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(e.name for e in self.experts)

    def __call__(self, mode: jax.Array, *inputs) -> BankOutput:
        """Run the bank.

        ``mode`` is an int32 scalar, or an ``(n_ues,)`` vector for the
        batched multi-UE engine — in which case every expert output must
        carry a leading UE axis and UE ``u`` receives expert ``mode[u]``'s
        output (different UEs can run different experts in the same slot).
        """
        mode = jnp.asarray(mode, jnp.int32)
        if self.execution_mode is ExecutionMode.GATED:
            if mode.ndim != 1:
                raise ValueError(
                    "GATED execution is the batched path: mode must be an "
                    "(n_ues,) vector (use SELECTED_ONLY for scalar gating)"
                )
            return self._run_gated(mode, *inputs)
        if self.execution_mode is ExecutionMode.CONCURRENT:
            return self._run_concurrent(mode, *inputs)
        return self._run_selected(mode, *inputs)

    def _run_concurrent(self, mode: jax.Array, *inputs) -> BankOutput:
        outputs = tuple(e.fn(e.params, *inputs) for e in self.experts)
        if self.use_pallas_switch:
            selected = switch_select(mode, list(outputs))
        elif mode.ndim == 1:  # batched oracle path
            from repro.kernels.switch_select.ref import (
                switch_select_batched_tree_ref,
            )

            selected = switch_select_batched_tree_ref(mode, list(outputs))
        else:  # oracle path (used by the property tests)
            stacked = jax.tree.map(lambda *ls: jnp.stack(ls, 0), *outputs)
            selected = jax.tree.map(lambda s: jnp.take(s, mode, axis=0), stacked)
        n_served = (
            jnp.full((self.n_experts,), mode.shape[0], jnp.int32)
            if mode.ndim == 1
            else jnp.ones((self.n_experts,), jnp.int32)
        )
        return BankOutput(
            selected=selected,
            all_outputs=outputs,
            mode=mode,
            executed_ue=n_served,
            served_by=mode if mode.ndim == 1 else None,
            baseline=outputs[self.default_mode] if mode.ndim == 1 else None,
        )

    def _run_selected(self, mode: jax.Array, *inputs) -> BankOutput:
        if mode.ndim == 1:
            # Per-UE modes make "run only the selected expert" ill-posed:
            # any expert some UE selects must execute.  Degenerate to the
            # concurrent cost envelope and gather per UE (predication), but
            # keep the SELECTED_ONLY interface (no all_outputs exposure).
            # GATED execution is the cost-scaling alternative.
            from repro.kernels.switch_select.ref import (
                switch_select_batched_tree_ref,
            )

            outputs = [e.fn(e.params, *inputs) for e in self.experts]
            selected = switch_select_batched_tree_ref(mode, outputs)
            return BankOutput(
                selected=selected,
                all_outputs=None,
                mode=mode,
                executed_ue=jnp.full((self.n_experts,), mode.shape[0], jnp.int32),
                served_by=mode,
                baseline=outputs[self.default_mode],
            )
        branches = [
            (lambda e: (lambda *xs: e.fn(e.params, *xs)))(e) for e in self.experts
        ]
        selected = jax.lax.switch(mode, branches, *inputs)
        return BankOutput(
            selected=selected,
            all_outputs=None,
            mode=mode,
            executed_ue=(jnp.arange(self.n_experts) == mode).astype(jnp.int32),
        )

    def _run_gated(self, mode: jax.Array, *inputs) -> BankOutput:
        """Compaction-gated execution: pay only for selected experts.

        Every input leaf must carry a leading ``(n_ues,)`` axis.  The
        cumsum-based stable partition and the static ``[:K]`` slice keep all
        shapes static, so this path compiles inside a ``lax.scan`` body.
        """
        n_ues = mode.shape[0]
        capacity = self.gated_capacity
        capacity = n_ues if capacity is None else min(capacity, n_ues)

        is_gated = mode == 0
        # stable partition: each selected UE's row in the compact sub-batch
        pos = jnp.cumsum(is_gated.astype(jnp.int32)) - 1
        within = jnp.logical_and(is_gated, pos < capacity)
        overflow = jnp.logical_and(is_gated, jnp.logical_not(within))
        src = jnp.where(within, pos, -1).astype(jnp.int32)
        # overflow UEs fall back to the fail-safe expert for this slot
        eff_mode = jnp.where(overflow, jnp.int32(self.default_mode), mode)

        # cheap experts run densely on all UEs
        alt_outputs = [e.fn(e.params, *inputs) for e in self.experts[1:]]
        if len(alt_outputs) == 1:
            base = alt_outputs[0]
        else:
            from repro.kernels.switch_select.ref import (
                switch_select_batched_tree_ref,
            )

            # values at gated UEs are placeholders (overwritten below)
            base = switch_select_batched_tree_ref(
                jnp.maximum(eff_mode, 1) - 1, alt_outputs
            )

        if capacity > 0:
            # gather the selected UEs' inputs to the front, stable order
            order = jnp.argsort(jnp.logical_not(is_gated).astype(jnp.int32),
                                stable=True)
            idx = order[:capacity]
            if self.gated_fused_apply is not None:
                # fused hot path: one kernel does gather + expert + scatter
                selected = self.gated_fused_apply(idx, src, base, *inputs)
            else:
                compact_inputs = jax.tree.map(
                    lambda x: jnp.take(x, idx, axis=0), inputs
                )
                gated = self.experts[0]
                compact_out = gated.fn(gated.params, *compact_inputs)
                selected = switch_scatter(
                    src, compact_out, base,
                    backend="auto" if self.use_pallas_switch else "ref",
                )
        else:
            selected = base

        served_by = jnp.where(within, 0, eff_mode).astype(jnp.int32)
        audit_tripped = None
        if self.audit_threshold is not None and capacity > 0:
            nmse = _batched_nmse(selected, base)
            # NaN/inf-safe trip: anything NOT provably within the threshold
            # trips (a diverged bf16 forward must not pass the audit)
            tripped = jnp.logical_and(
                within, jnp.logical_not(nmse <= self.audit_threshold)
            )
            selected = jax.tree.map(
                lambda s, b: jnp.where(
                    tripped.reshape((-1,) + (1,) * (s.ndim - 1)), b, s
                ),
                selected,
                base,
            )
            served_by = jnp.where(
                tripped, jnp.int32(self.default_mode), served_by
            )
            audit_tripped = tripped

        n_gated = jnp.sum(within.astype(jnp.int32))
        executed = jnp.concatenate(
            [n_gated[None], jnp.full((self.n_experts - 1,), n_ues, jnp.int32)]
        )
        return BankOutput(
            selected=selected,
            all_outputs=None,
            mode=mode,
            executed_ue=executed,
            served_by=served_by,
            overflow=overflow,
            audit_tripped=audit_tripped,
            baseline=base,
        )

    # ---- static cost model (drives the energy/utilization proxy) ----
    def flops_for(self, mode: int | None = None) -> float:
        """FLOPs per slot: all experts (concurrent) or one (selected-only)."""
        if self.execution_mode is ExecutionMode.CONCURRENT:
            return float(sum(e.flops for e in self.experts))
        if self.execution_mode is ExecutionMode.GATED:
            raise ValueError(
                "GATED cost depends on the realized mode mix: use "
                "executed_flops(out) / executed_flops_per_ue(out)"
            )
        assert mode is not None
        return float(self.experts[mode].flops)

    def bytes_for(self, mode: int | None = None) -> float:
        if self.execution_mode is ExecutionMode.CONCURRENT:
            return float(sum(e.bytes_hbm for e in self.experts))
        if self.execution_mode is ExecutionMode.GATED:
            raise ValueError(
                "GATED cost depends on the realized mode mix: use "
                "executed_bytes(out)"
            )
        assert mode is not None
        return float(self.experts[mode].bytes_hbm)

    # ---- executed cost model (scales with the realized expert mix) ----

    def _executed(self, out: BankOutput, costs: jax.Array) -> jax.Array:
        if out.executed_ue is None:
            raise ValueError("BankOutput carries no executed_ue counts")
        return jnp.sum(out.executed_ue.astype(jnp.float32) * costs)

    def executed_flops(self, out: BankOutput) -> jax.Array:
        """FLOPs this call actually executed (traced scalar).

        ``sum_e served_ues[e] * flops[e]`` — in CONCURRENT mode this equals
        ``n_ues * flops_for()``; in GATED mode the designated expert
        contributes only its capacity-capped served count, so the total
        scales linearly with the realized AI share.
        """
        return self._executed(
            out, jnp.asarray([e.flops for e in self.experts], jnp.float32)
        )

    def executed_bytes(self, out: BankOutput) -> jax.Array:
        """HBM bytes this call actually moved (traced scalar)."""
        return self._executed(
            out, jnp.asarray([e.bytes_hbm for e in self.experts], jnp.float32)
        )

    def provisioned_flops(self, n_ues: int) -> float:
        """Static per-slot FLOPs the hardware is provisioned for (GATED).

        The compact sub-batch has static capacity ``K``, so the gated
        expert's GEMMs always process ``K`` rows — ``executed_flops`` counts
        the *served* rows (the useful fraction); the difference is padding
        waste when fewer UEs select the gated expert than ``K``.
        """
        if self.execution_mode is ExecutionMode.CONCURRENT:
            return float(n_ues * sum(e.flops for e in self.experts))
        if self.execution_mode is not ExecutionMode.GATED:
            raise ValueError("provisioned cost is per-mode in SELECTED_ONLY: "
                             "use n_ues * flops_for(mode)")
        cap = n_ues if self.gated_capacity is None else min(
            self.gated_capacity, n_ues
        )
        return float(
            cap * self.experts[0].flops
            + n_ues * sum(e.flops for e in self.experts[1:])
        )

    def executed_flops_per_ue(self, out: BankOutput) -> jax.Array:
        """Per-UE executed FLOPs ((n_ues,) float32; batched calls only).

        A UE's slot cost is every densely-run expert plus — under gating —
        the designated expert only if it actually served this UE.  Summing
        over UEs reproduces ``executed_flops``.
        """
        if out.served_by is None:
            raise ValueError("per-UE accounting needs a batched (vector) call")
        flops = jnp.asarray([e.flops for e in self.experts], jnp.float32)
        if self.execution_mode is ExecutionMode.GATED:
            dense = jnp.sum(flops[1:])
            ai_ran = out.served_by == 0
            if out.audit_tripped is not None:
                # audit-tripped UEs were *served* by the fail-safe but the
                # gated expert still executed for them — the cost is real
                ai_ran = jnp.logical_or(ai_ran, out.audit_tripped)
            return dense + flops[0] * ai_ran.astype(jnp.float32)
        # concurrent / degenerate selected-only: every expert ran every UE
        return jnp.full(out.served_by.shape, jnp.sum(flops), jnp.float32)
