"""The reusable 3-stage telemetry-selection / policy-design process (paper 4).

Stage 1 — *controlled perturbation*: inject calibrated complex AWGN into one
expert's output (Eq. 3) and record downstream KPMs as a function of the
intensity rho in [0, 2] (steps of 0.1 by default, as in the paper).

Stage 2 — *monotonicity filtering*: keep KPMs whose mean response is
consistently monotonic in rho (Spearman rank correlation against rho).

Stage 3 — *redundancy reduction*: Pearson correlation across the surviving
KPMs, average-linkage hierarchical clustering on ``1 - |r|``, cut at the
paper's 0.8 threshold, one representative per cluster (the paper keeps MCS
index for the link-adaptation cluster; priorities are configurable).

All three stages are function-agnostic: the channel-estimation case study
plugs in its own ``eval_fn``, the same code drives any other expert bank.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from scipy.cluster.hierarchy import fcluster, linkage
from scipy.spatial.distance import squareform
from scipy.stats import spearmanr

# -- Stage 1: controlled perturbation -----------------------------------------


def perturb_estimate(h_est: jax.Array, rho: jax.Array | float, key: jax.Array):
    """Paper Eq. (3): ``h + rho * E[|h|] * CN(0, 1)``."""
    kr, ki = jax.random.split(key)
    scale = jnp.mean(jnp.abs(h_est))
    # CN(0,1): unit-variance complex normal -> each component var 1/2
    noise = (
        jax.random.normal(kr, h_est.shape) + 1j * jax.random.normal(ki, h_est.shape)
    ) / jnp.sqrt(2.0)
    return h_est + jnp.asarray(rho) * scale * noise.astype(h_est.dtype)


DEFAULT_RHOS = tuple(np.round(np.arange(0.0, 2.0 + 1e-9, 0.1), 3))


@dataclasses.dataclass
class SweepResult:
    rhos: np.ndarray  # (R,)
    kpm_names: tuple[str, ...]
    means: np.ndarray  # (R, K)
    ci95: np.ndarray  # (R, K)
    samples: np.ndarray  # (R, trials, K) raw per-trial values


def sensitivity_sweep(
    eval_fn: Callable[[float, jax.Array], Mapping[str, float]],
    *,
    rhos: Sequence[float] = DEFAULT_RHOS,
    n_trials: int = 8,
    key: jax.Array | None = None,
) -> SweepResult:
    """Run ``eval_fn(rho, key) -> {kpm: value}`` over the rho grid."""
    if key is None:
        key = jax.random.PRNGKey(0)
    names: tuple[str, ...] | None = None
    all_vals = []
    for rho in rhos:
        trial_vals = []
        for t in range(n_trials):
            key, sub = jax.random.split(key)
            kpms = eval_fn(float(rho), sub)
            if names is None:
                names = tuple(kpms.keys())
            trial_vals.append([float(kpms[n]) for n in names])
        all_vals.append(trial_vals)
    samples = np.asarray(all_vals)  # (R, T, K)
    means = samples.mean(axis=1)
    std = samples.std(axis=1, ddof=1) if n_trials > 1 else np.zeros_like(means)
    ci95 = 1.96 * std / np.sqrt(max(n_trials, 1))
    assert names is not None
    return SweepResult(
        rhos=np.asarray(rhos), kpm_names=names, means=means, ci95=ci95, samples=samples
    )


def sensitivity_sweep_batched(
    engine,
    schedule,
    *,
    rhos: Sequence[float] = DEFAULT_RHOS,
    n_trials: int = 8,
    slots_per_trial: int = 8,
    key: jax.Array | None = None,
) -> SweepResult:
    """Stage 1 on the batched slot engine: the rho grid rides the UE axis.

    The host harness (``sensitivity_sweep``) dispatches one pipeline call
    per ``(rho, trial)`` — O(R*T) host round-trips.  Here every
    ``(rho, trial)`` pair becomes one UE of a single
    ``slots_per_trial x (R*T)`` campaign (``BatchedPuschPipeline.
    run_perturbed``): each UE runs the MMSE-only pipeline with AWGN
    injected at its rho every slot, and the whole sweep is one compiled
    scan.  The sample for a trial is its UE's final-slot KPM vector, after
    ``slots_per_trial - 1`` slots of link-adaptation warm-up — the same
    "perturb a settled link" regime the host harness reaches by carrying
    ``LinkState`` across evaluations.

    Returns a ``SweepResult`` shaped exactly like the host harness's, so
    stages 2/3 (monotonicity filter, clustering) consume it unchanged.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    rhos_arr = np.asarray(list(rhos), np.float32)
    n_rhos = rhos_arr.shape[0]
    rho_per_ue = jnp.asarray(np.repeat(rhos_arr, n_trials))  # (R*T,)
    _, traj = engine.run_perturbed(
        schedule, rho_per_ue, n_slots=slots_per_trial, key=key
    )
    from repro.core.telemetry import flatten_kpm_sources

    flat = flatten_kpm_sources(traj["kpms"])  # name -> (S, R*T)
    names = tuple(flat.keys())
    # final slot of each UE, regrouped to (R, T, K)
    samples = np.stack(
        [np.asarray(flat[n][-1], np.float64).reshape(n_rhos, n_trials)
         for n in names],
        axis=-1,
    )
    means = samples.mean(axis=1)
    std = samples.std(axis=1, ddof=1) if n_trials > 1 else np.zeros_like(means)
    ci95 = 1.96 * std / np.sqrt(max(n_trials, 1))
    return SweepResult(
        rhos=rhos_arr, kpm_names=names, means=means, ci95=ci95, samples=samples
    )


# -- Stage 2: monotonicity filtering -------------------------------------------


def monotonicity_filter(
    sweep: SweepResult, *, min_abs_spearman: float = 0.8
) -> dict[str, float]:
    """KPM -> Spearman(rho, mean response); keeps ``|r| >= threshold``."""
    kept = {}
    for k, name in enumerate(sweep.kpm_names):
        r, _ = spearmanr(sweep.rhos, sweep.means[:, k])
        if np.isfinite(r) and abs(r) >= min_abs_spearman:
            kept[name] = float(r)
    return kept


# -- Stage 3: redundancy reduction ---------------------------------------------


@dataclasses.dataclass
class ClusterResult:
    names: tuple[str, ...]
    corr: np.ndarray  # (K, K) Pearson matrix
    labels: np.ndarray  # (K,) cluster ids
    representatives: tuple[str, ...]
    order: np.ndarray  # leaf order for block-diagonal display (paper Fig. 5)


def redundancy_reduction(
    samples: Mapping[str, np.ndarray],
    *,
    threshold: float = 0.8,
    representative_priority: Sequence[str] = ("mcs_index",),
) -> ClusterResult:
    """Pearson + average-linkage clustering at ``1 - threshold`` distance.

    ``samples`` maps KPM name -> 1-D array of per-slot observations (all the
    same length).  Within each cluster the representative is the first match
    in ``representative_priority``; otherwise the member with the largest
    mean |correlation| to its cluster (the most central one).
    """
    names = tuple(samples.keys())
    mat = np.stack([np.asarray(samples[n], np.float64) for n in names], axis=0)
    # guard: zero-variance KPMs correlate as 0 with everything
    std = mat.std(axis=1)
    std_safe = np.where(std > 0, std, 1.0)
    centered = (mat - mat.mean(axis=1, keepdims=True)) / std_safe[:, None]
    corr = centered @ centered.T / mat.shape[1]
    corr[std == 0, :] = 0.0
    corr[:, std == 0] = 0.0
    np.fill_diagonal(corr, 1.0)

    # sanitize: zero-variance / degenerate KPMs can leave non-finite entries
    corr = np.clip(np.nan_to_num(corr, nan=0.0, posinf=1.0, neginf=-1.0), -1.0, 1.0)
    np.fill_diagonal(corr, 1.0)

    dist = 1.0 - np.abs(corr)
    np.fill_diagonal(dist, 0.0)
    dist = np.clip((dist + dist.T) / 2, 0.0, 1.0)  # numerical symmetry
    z = linkage(squareform(dist, checks=False), method="average")
    labels = fcluster(z, t=1.0 - threshold, criterion="distance")

    # display order: traverse the dendrogram (block structure of Fig. 5)
    from scipy.cluster.hierarchy import leaves_list

    order = leaves_list(z)

    reps = []
    for c in sorted(set(labels)):
        members = [i for i in range(len(names)) if labels[i] == c]
        rep = None
        for p in representative_priority:
            if p in (names[i] for i in members):
                rep = p
                break
        if rep is None:
            centrality = [np.mean(np.abs(corr[i, members])) for i in members]
            rep = names[members[int(np.argmax(centrality))]]
        reps.append(rep)
    return ClusterResult(
        names=names,
        corr=corr,
        labels=labels,
        representatives=tuple(reps),
        order=order,
    )


def design_policy_inputs(
    aerial_samples: Mapping[str, np.ndarray],
    oai_samples: Mapping[str, np.ndarray],
    *,
    threshold: float = 0.8,
    always_include: Sequence[str] = ("phy_throughput",),
) -> tuple[tuple[str, ...], ClusterResult, ClusterResult]:
    """Full Stage-3 as the paper runs it: Aerial and OAI clustered separately,
    PHY throughput re-added afterwards (it is excluded from correlation due to
    its cumulative computation)."""
    aerial = redundancy_reduction(aerial_samples, threshold=threshold)
    oai = redundancy_reduction(oai_samples, threshold=threshold)
    selected = tuple(always_include) + aerial.representatives + oai.representatives
    # stable de-dup
    seen, final = set(), []
    for s in selected:
        if s not in seen:
            seen.add(s)
            final.append(s)
    return tuple(final), aerial, oai
