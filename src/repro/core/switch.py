"""Slot-boundary switching semantics and fail-safe defaults (paper 2, 3.3).

The mode register is pure functional state threaded through the slot loop:

* ``commit_decision`` — the dApp commits a decision *during* slot n.
* ``slot_boundary``   — at the setup phase of slot n+1 the pending decision
  becomes active.  Mid-slot updates are therefore deferred by construction.
* **Fail-safe**: if no valid decision has been committed for ``ttl_slots``
  slots (dApp crash, E3 stall), the active mode decays to the conventional
  default — the system never depends on the control plane for baseline
  operation.

Everything is ``jnp.where``-based so the register can live inside a jitted
slot step (the TPU analogue of the paper's host-to-device mode propagation:
the register rides the step's donated carry).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SlotSwitchState(NamedTuple):
    active_mode: jax.Array  # int32 — consumed by the pipeline this slot
    pending_mode: jax.Array  # int32 — latest committed decision
    slots_since_decision: jax.Array  # int32 — staleness counter
    slot_index: jax.Array  # int32
    n_switches: jax.Array  # int32 — observability: boundary transitions


def init_switch_state(default_mode: int) -> SlotSwitchState:
    d = jnp.int32(default_mode)
    z = jnp.int32(0)
    return SlotSwitchState(
        active_mode=d,
        pending_mode=d,
        slots_since_decision=z,
        slot_index=z,
        n_switches=z,
    )


def commit_decision(
    state: SlotSwitchState, mode: jax.Array, valid: jax.Array | bool = True
) -> SlotSwitchState:
    """dApp commits ``mode`` during the current slot (takes effect next slot)."""
    mode = jnp.asarray(mode, jnp.int32)
    valid = jnp.asarray(valid, jnp.bool_)
    return state._replace(
        pending_mode=jnp.where(valid, mode, state.pending_mode),
        slots_since_decision=jnp.where(valid, 0, state.slots_since_decision),
    )


def slot_boundary(
    state: SlotSwitchState, *, fail_safe_mode: int, ttl_slots: int
) -> SlotSwitchState:
    """Advance to slot n+1: apply the pending decision, enforce fail-safe."""
    stale = state.slots_since_decision >= jnp.int32(ttl_slots)
    new_active = jnp.where(stale, jnp.int32(fail_safe_mode), state.pending_mode)
    switched = (new_active != state.active_mode).astype(jnp.int32)
    return SlotSwitchState(
        active_mode=new_active,
        pending_mode=jnp.where(stale, jnp.int32(fail_safe_mode), state.pending_mode),
        slots_since_decision=state.slots_since_decision + 1,
        slot_index=state.slot_index + 1,
        n_switches=state.n_switches + switched,
    )
