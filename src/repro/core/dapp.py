"""The ARCHES dApp: telemetry windows, policy inference, mode decisions
(paper 3.3, 6.1).

The dApp accumulates cross-layer KPMs from E3 indications, runs the switching
policy at a configurable periodicity, and replies with the single scalar
``mode``.  The latency model carries the paper's measured constants so every
decision is annotated with an end-to-end control-loop estimate
(~135 us framework + 0.41 us tree + 3.36/4.89 us switch ~= 140 us).

Failure injection (``fail()``) lets the tests exercise the fail-safe path:
a failed dApp simply stops producing decisions and the RAN-side
``SlotSwitchState`` decays to the conventional expert after ``ttl_slots``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Sequence

import numpy as np

from repro.core.e3 import E3Agent, E3IndicationMessage, E3Manager


@dataclasses.dataclass(frozen=True)
class ControlLoopLatency:
    """End-to-end control-loop latency model (paper 6.1)."""

    framework_overhead_us: float = 135.0  # shm copies + ZeroMQ messaging
    policy_inference_us: float = 0.41  # decision tree on GH200
    switch_kernel_us: Mapping[int, float] = dataclasses.field(
        default_factory=lambda: {0: 3.36, 1: 4.89}  # AI no-op vs MMSE copy
    )

    def end_to_end_us(self, mode: int, measured_policy_us: float | None = None) -> float:
        policy = (
            measured_policy_us
            if measured_policy_us is not None
            else self.policy_inference_us
        )
        switch = self.switch_kernel_us.get(int(mode), max(self.switch_kernel_us.values()))
        return self.framework_overhead_us + policy + switch


@dataclasses.dataclass(frozen=True)
class Decision:
    slot: int
    mode: int
    policy_us: float  # measured host inference time
    end_to_end_us: float  # modeled control-loop latency


class DApp:
    """Processing layer of the dApp (paper Fig. 1/2)."""

    def __init__(
        self,
        policy,
        feature_names: Sequence[str],
        *,
        window_slots: int = 8,
        period_slots: int = 1,
        latency: ControlLoopLatency | None = None,
    ):
        self.policy = policy
        self.feature_names = tuple(feature_names)
        self.window_slots = window_slots
        self.period_slots = period_slots
        self.latency = latency or ControlLoopLatency()
        self._window: list[dict[str, float]] = []
        self._pending: dict[int, dict[str, float]] = {}
        self._failed = False
        self.decisions: list[Decision] = []

    # -- lifecycle (client interface) --
    def fail(self) -> None:
        self._failed = True

    def recover(self) -> None:
        self._failed = False

    # -- processing layer --
    def on_indication(self, msg: E3IndicationMessage) -> Decision | None:
        if self._failed:
            return None
        slot_kpms = self._pending.setdefault(msg.slot, {})
        slot_kpms.update({k: float(v) for k, v in msg.kpms.items()})
        if not all(n in slot_kpms for n in self.feature_names):
            return None  # waiting for the other layer's indication
        self._pending.pop(msg.slot)
        self._window.append(slot_kpms)
        if len(self._window) > self.window_slots:
            self._window.pop(0)
        if msg.slot % self.period_slots != 0:
            return None
        x = np.asarray(
            [
                np.mean([w[n] for w in self._window])
                for n in self.feature_names
            ],
            np.float32,
        )
        t0 = time.perf_counter()
        mode = int(self.policy(x))
        policy_us = (time.perf_counter() - t0) * 1e6
        decision = Decision(
            slot=msg.slot,
            mode=mode,
            policy_us=policy_us,
            end_to_end_us=self.latency.end_to_end_us(mode, policy_us),
        )
        self.decisions.append(decision)
        return decision


def connect_dapp(agent: E3Agent, dapp: DApp) -> E3Manager:
    """Wire a dApp to a RAN-side E3 agent; decisions flow back as controls."""
    manager = E3Manager(agent)

    def on_indication(msg: E3IndicationMessage) -> None:
        decision = dapp.on_indication(msg)
        if decision is not None:
            manager.send_mode(decision.slot, decision.mode)

    manager.setup(on_indication)
    return manager
