"""Deterministic synthetic token pipeline (sharded, restart-exact).

The stream is a pure function of (seed, step), so a restarted training loop
replays exactly the batches it would have seen — the data-side requirement
for the checkpoint/restart fault-tolerance test to assert bit-identical
continuation.  Tokens follow a Zipfian draw over the vocab (softmax losses
see a realistic non-uniform distribution, which matters for the loss curve
sanity checks) with a shifted-copy label structure so models can actually
learn next-token prediction.

At multi-host scale each host draws only its data-parallel shard
(``host_slice``); in this container there is one host, so the slice is the
identity — the API is the multi-host one.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import numpy as np

from repro.models.config import Family, ModelConfig, ShapeCell


@dataclasses.dataclass
class TokenStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    host_index: int = 0
    n_hosts: int = 1

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        p = ranks**-self.zipf_a
        self._probs = p / p.sum()
        self._perm = rng.permutation(self.vocab)  # break rank/id correlation

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """The (host-sliced) batch for a given global step.

        The full global batch is drawn from the shared (seed, step) stream and
        each host takes its contiguous row slice — hosts therefore see
        *disjoint* shards whose union is exactly the global batch.
        """
        rng = np.random.default_rng((self.seed, step))
        raw = rng.choice(
            self.vocab, size=(self.global_batch, self.seq_len + 1), p=self._probs
        )
        b = self.global_batch // self.n_hosts
        lo = self.host_index * b
        toks = self._perm[raw[lo : lo + b]]
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def iterate(self, start_step: int = 0) -> Iterator[dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_specs(
    cfg: ModelConfig, cell: ShapeCell, dtype=None
) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one cell's inputs (dry-run input_specs).

    ``train``   {tokens, labels} (B, S)          [+ encoder_frames for enc-dec]
    ``prefill`` {tokens} (B, S)                  [+ encoder_frames]
    ``decode``  {tokens} (B, 1) + the cache is supplied by the launcher
    """
    import jax.numpy as jnp

    dtype = dtype or cfg.param_dtype()
    b, s = cell.global_batch, cell.seq_len
    itok = jnp.int32
    specs: dict[str, jax.ShapeDtypeStruct] = {}
    if cell.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), itok)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), itok)
    elif cell.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), itok)
    elif cell.kind == "decode":
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), itok)
    if cfg.family is Family.ENC_DEC and cell.kind in ("train", "prefill"):
        specs["encoder_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), dtype
        )
    return specs
