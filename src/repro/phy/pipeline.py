"""The GPU-accelerated PUSCH RX pipeline with the ARCHES expert bank
(paper Fig. 2, nodes 2a-2e).

Per slot:
  TX   link adaptation (prev slot's SNR -> MCS/TBS) -> bits -> QAM -> grid+DMRS
  CH   TDL fading + optional interference + AWGN
  RX   LS (2b) -> expert bank {MMSE (2c), AI (2d)} -> switch kernel (2e)
       -> time-interp + MMSE equalizer -> max-log LLRs -> TB CRC (MIESM)
  KPM  Aerial Data Lake (PHY, per-slot) + OAI (L2+) telemetry

Mode numbering follows the paper: ``mode=0`` selects AI (designated buffer —
switch is a no-op), ``mode=1`` selects MMSE (copy path).

The pipeline is generic infrastructure: every stage is jitted; the per-slot
host loop only carries link-adaptation state and cumulative counters —
exactly the split the paper's cuBB/L2 boundary imposes.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.expert_bank import ExecutionMode, Expert, ExpertBank
from repro.core.methodology import perturb_estimate
from repro.phy import dmrs as dmrs_mod
from repro.phy import qam
from repro.phy.ai_estimator import AiEstimatorConfig, ai_estimate_from_ls
from repro.phy.channel import ChannelConfig, apply_channel, simulate_slot_channel
from repro.phy.equalizer import effective_noise_var, mmse_equalize, mmse_irc_equalize
from repro.phy.estimators import (
    WienerInterpolator,
    estimator_flops,
    ls_estimate,
    mmse_estimate,
)
from repro.phy.link import count_bit_errors, effective_mi, tb_success, throughput_bits
from repro.phy.mcs import McsEntry, mcs_entry, n_code_blocks, select_mcs, transport_block_size
from repro.phy.nr import SlotConfig

# MAC overheads (bytes) for the PHY->MAC KPM coupling
_MAC_HEADER_BYTES = 3
_RLC_HEADER_BYTES = 2
_LCID4_FRACTION = 0.95  # share of MAC SDU carrying user-plane LCID 4 traffic


@dataclasses.dataclass
class LinkState:
    """Host-side link-adaptation + cumulative-counter state."""

    reported_snr_db: float = 20.0
    ndi: int = 1
    cum_phy_bits: float = 0.0
    cum_mac_bytes: float = 0.0
    cum_lcid4_bytes: float = 0.0
    slots: int = 0
    # outer-loop link adaptation: HARQ ACK/NACK-driven SINR offset.  The
    # decision-directed SINR measurement is biased at low SINR (wrong hard
    # decisions snap part of the error away, and more so for a worse channel
    # estimate); OLLA closes the loop on *realized* BLER, so estimator
    # quality surfaces in the MCS the scheduler actually grants — exactly
    # how production gNBs (incl. the paper's OAI L2) absorb measurement bias.
    olla_offset_db: float = 0.0


# OLLA steps: steady-state BLER target = up / (up + down) ~= 10 %
_OLLA_UP_DB = 0.15
_OLLA_DOWN_DB = 1.35
_OLLA_CLAMP_DB = 10.0


class PuschPipeline:
    """One UE's UL PUSCH receive chain with a switchable estimator bank."""

    def __init__(
        self,
        cfg: SlotConfig,
        ai_params: Any,
        *,
        net: AiEstimatorConfig = AiEstimatorConfig(),
        execution_mode: ExecutionMode = ExecutionMode.CONCURRENT,
        use_pallas_switch: bool = True,
        rms_delay_spread_s: float = 100e-9,
    ):
        self.cfg = cfg
        self.ai_params = ai_params
        self.interpolator = WienerInterpolator.build(
            cfg, rms_delay_spread_s=rms_delay_spread_s
        )
        # Bank order: designated expert FIRST (mode 0 == AI, paper 5.2).
        self.bank = ExpertBank(
            [
                Expert(
                    name="ai",
                    fn=lambda p, h_ls: ai_estimate_from_ls(p, h_ls),
                    params=ai_params,
                    flops=net.flops(cfg),
                ),
                Expert(
                    name="mmse",
                    fn=lambda p, h_ls: self._mmse_from_ls(h_ls),
                    params=None,
                    flops=estimator_flops(cfg),
                ),
            ],
            default_mode=1,
            execution_mode=execution_mode,
            use_pallas_switch=use_pallas_switch,
        )

    # -- expert wrappers ------------------------------------------------------

    def _mmse_from_ls(self, h_ls: jax.Array) -> jax.Array:
        from repro.kernels.mmse_interp import mmse_interp

        h_full = mmse_interp(h_ls, self.interpolator.w)
        return jnp.moveaxis(h_full, -2, -1)[:, None]

    # -- jitted slot stages ----------------------------------------------------

    @partial(jax.jit, static_argnames=("self", "qm", "tbs_bits"))
    def _tx_slot(self, key: jax.Array, qm: int, tbs_bits: int):
        """bits -> QAM symbols -> resource grid (+ pilots)."""
        cfg = self.cfg
        n_coded = cfg.n_data_re() * qm
        bits = jax.random.bernoulli(key, 0.5, (n_coded,)).astype(jnp.uint8)
        syms = qam.modulate(bits, qm)
        pilots = dmrs_mod.dmrs_sequence(cfg)
        grid = dmrs_mod.map_slot_grid(cfg, syms, pilots)
        return bits, grid, pilots

    @partial(jax.jit, static_argnames=("self", "qm", "perturb"))
    def _rx_slot(
        self,
        mode: jax.Array,
        rx_grid: jax.Array,
        pilots: jax.Array,
        tx_data_syms: jax.Array,
        noise_var: jax.Array,
        qm: int,
        *,
        perturb: bool = False,
        rho: jax.Array | float = 0.0,
        perturb_key: jax.Array | None = None,
    ):
        """LS -> expert bank -> switch -> equalize -> demap. Returns a dict.

        Two quality signals, deliberately separated:
        * *measured SINR* — decision-directed data-RE EVM, receiver-side
          (what Aerial reports and what drives link adaptation + LLR
          scaling).  Pilot-RE EVM is deliberately NOT used: estimates are
          derived from those same pilots, so their post-equalization EVM is
          self-referentially optimistic for LS-like estimators and blind to
          interpolation error on the data REs, which is exactly the error an
          expert estimator reduces.  Decision-directed EVM (against the
          nearest constellation point) is the standard receiver-side proxy
          and degrades when the channel estimate is bad — which is what
          makes the paper's Fig. 4 KPM trends monotonic in rho.
        * *genie per-RE SINR* — data-RE EVM against the known TX symbols
          (simulator-only), drives the MIESM TB-CRC model.
        """
        cfg = self.cfg
        h_ls = ls_estimate(cfg, rx_grid, pilots)
        if perturb:
            # Methodology stage 1 (paper Fig. 3): MMSE only, AWGN injected at
            # node 2c — no switching, no AI in the loop.
            h_sel = self._mmse_from_ls(h_ls)
            h_sel = perturb_estimate(h_sel, rho, perturb_key)
            all_outputs = None
        else:
            out = self.bank(mode, h_ls)
            h_sel = out.selected
            all_outputs = out.all_outputs
        x_hat, _ = mmse_equalize(cfg, rx_grid, h_sel, noise_var)

        # measured SINR: decision-directed EVM on data REs (receiver-side)
        data_hat = dmrs_mod.extract_data_re(cfg, x_hat[None])[0]
        points = qam.constellation(qm)
        nearest = points[
            jnp.argmin(jnp.abs(data_hat[:, None] - points[None, :]), axis=1)
        ]
        dd_err = jnp.mean(jnp.abs(data_hat - nearest) ** 2)
        sig_pow = jnp.mean(jnp.abs(nearest) ** 2)
        sinr_meas = sig_pow / jnp.maximum(dd_err, 1e-9)

        # genie per-RE SINR on data REs (TB-success model only)
        data_x = dmrs_mod.extract_data_re(cfg, x_hat[None])[0]
        genie_err = jnp.abs(data_x - tx_data_syms) ** 2
        # smooth over PRB-sized windows: LDPC averages error bursts
        n = genie_err.shape[0] - genie_err.shape[0] % 12
        smoothed = jnp.mean(genie_err[:n].reshape(-1, 12), axis=1)
        genie_sinr = 1.0 / jnp.maximum(smoothed, 1e-9)

        llr = qam.demap_llr(data_x, 1.0 / sinr_meas, qm)
        rsrp = jnp.mean(jnp.abs(h_sel) ** 2)
        return {
            "h_selected": h_sel,
            "all_outputs": all_outputs,
            "llr": llr,
            "genie_sinr": genie_sinr,
            "rsrp": rsrp,
            "post_snr_lin": sinr_meas,
        }

    # -- full slot -------------------------------------------------------------

    def run_slot(
        self,
        key: jax.Array,
        mode: int | jax.Array,
        link: LinkState,
        channel_cfg: ChannelConfig,
        *,
        perturb_rho: float | None = None,
    ) -> tuple[LinkState, dict[str, Any], dict[str, Mapping[str, float]]]:
        """Execute one slot; returns (new link state, outputs, KPMs-by-source)."""
        cfg = self.cfg
        k_tx, k_ch, k_n, k_crc, k_p = jax.random.split(key, 5)

        # link adaptation from last slot's report + OLLA offset (L2 behaviour)
        mcs = select_mcs(link.reported_snr_db + link.olla_offset_db)
        tbs = transport_block_size(cfg.n_data_re(), mcs)
        bits, tx_grid, pilots = self._tx_slot(k_tx, mcs.qm, tbs)

        fields = simulate_slot_channel(k_ch, cfg, channel_cfg)
        rx_grid = apply_channel(k_n, tx_grid, fields)

        tx_syms = dmrs_mod.extract_data_re(cfg, tx_grid[0][None])[0]
        rx = self._rx_slot(
            jnp.asarray(mode, jnp.int32),
            rx_grid,
            pilots,
            tx_syms,
            fields["noise_var"],
            mcs.qm,
            perturb=perturb_rho is not None,
            rho=0.0 if perturb_rho is None else perturb_rho,
            perturb_key=k_p,
        )

        ok = tb_success(rx["genie_sinr"], mcs, key=k_crc)
        phy_bits = throughput_bits(tbs, ok, cfg.slot_duration_s)

        # -- host-side KPM assembly (Aerial Data Lake + OAI, paper 4.3/6) --
        ok_f = float(ok)
        tb_bytes = tbs / 8.0
        mac_sdu_bytes = max(tb_bytes - _MAC_HEADER_BYTES, 0.0) * ok_f
        lcid4_bytes = max(mac_sdu_bytes - _RLC_HEADER_BYTES, 0.0) * _LCID4_FRACTION

        olla = link.olla_offset_db + (_OLLA_UP_DB if ok_f else -_OLLA_DOWN_DB)
        olla = float(np.clip(olla, -_OLLA_CLAMP_DB, _OLLA_CLAMP_DB))
        new_link = LinkState(
            reported_snr_db=float(10.0 * np.log10(float(rx["post_snr_lin"]) + 1e-9)),
            ndi=1 if ok_f else 0,  # NDI toggles on new data; retx keeps it
            cum_phy_bits=link.cum_phy_bits + float(phy_bits) * cfg.slot_duration_s,
            cum_mac_bytes=link.cum_mac_bytes + mac_sdu_bytes,
            cum_lcid4_bytes=link.cum_lcid4_bytes + lcid4_bytes,
            slots=link.slots + 1,
            olla_offset_db=olla,
        )
        elapsed = new_link.slots * cfg.slot_duration_s
        kpms = {
            "aerial": {
                "code_rate": mcs.code_rate,
                "sinr": float(10.0 * np.log10(float(rx["post_snr_lin"]) + 1e-9)),
                "qam_order": float(mcs.qm),
                "mcs_index": float(mcs.index),
                "tb_size": float(tbs) * ok_f,
                "n_code_blocks": float(n_code_blocks(tbs)) * ok_f,
                "pdu_length": tb_bytes * ok_f,
                "ndi": float(new_link.ndi),
                "rsrp": float(rx["rsrp"]),
                "phy_throughput": new_link.cum_phy_bits / elapsed,  # cumulative
            },
            "oai": {
                "snr": float(10.0 * np.log10(float(rx["post_snr_lin"]) + 1e-9)),
                "mac_throughput": new_link.cum_mac_bytes * 8.0 / elapsed,
                "lcid4_throughput": new_link.cum_lcid4_bytes * 8.0 / elapsed,
                "mac_rx_bytes": mac_sdu_bytes,
                "lcid4_rx_bytes": lcid4_bytes,
            },
        }
        outputs = {
            "tb_ok": ok_f,
            "tbs": tbs,
            "mcs": mcs.index,
            "phy_bits_per_s": float(phy_bits),
            "bits": bits,
            "llr": rx["llr"],
            "rx": rx,
        }
        return new_link, outputs, kpms

    # -- adapters ----------------------------------------------------------------

    def make_slot_fn(self, channel_schedule):
        """Adapter for ``ArchesRuntime``: carry = LinkState, input = slot idx.

        ``channel_schedule(slot) -> ChannelConfig`` defines the scenario
        (good/poor phases, paper Fig. 9).
        """

        def slot_fn(active_mode, carry, slot_idx):
            link = carry if carry is not None else LinkState()
            key = jax.random.PRNGKey(np.uint32(slot_idx * 2654435761 % (2**31)))
            ch = channel_schedule(int(slot_idx))
            link, outputs, kpms = self.run_slot(key, active_mode, link, ch)
            return link, outputs, kpms

        return slot_fn
