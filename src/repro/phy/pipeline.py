"""The GPU-accelerated PUSCH RX pipeline with the ARCHES expert bank
(paper Fig. 2, nodes 2a-2e).

Per slot:
  TX   link adaptation (prev slot's SNR -> MCS/TBS) -> bits -> QAM -> grid+DMRS
  CH   TDL fading + optional interference + AWGN
  RX   LS (2b) -> expert bank {MMSE (2c), AI (2d)} -> switch kernel (2e)
       -> time-interp + MMSE equalizer -> max-log LLRs -> TB CRC (MIESM)
  KPM  Aerial Data Lake (PHY, per-slot) + OAI (L2+) telemetry

Mode numbering follows the paper: ``mode=0`` selects AI (designated buffer —
switch is a no-op), ``mode=1`` selects MMSE (copy path).

The pipeline is generic infrastructure: every stage is jitted; the per-slot
host loop only carries link-adaptation state and cumulative counters —
exactly the split the paper's cuBB/L2 boundary imposes.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Mapping, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.closed_loop import (
    DevicePolicy,
    SwitchConfig,
    breaker_update,
    init_device_switch,
    switch_boundary,
    switch_update,
)
from repro.core.expert_bank import ExecutionMode, Expert, ExpertBank
from repro.core.methodology import perturb_estimate
from repro.core.telemetry import trajectory_kpm_matrix
from repro.phy import dmrs as dmrs_mod
from repro.phy import qam
from repro.phy.ai_estimator import AiEstimatorConfig, ai_estimate_from_ls
from repro.phy.channel import (
    CellParams,
    ChannelConfig,
    ChannelParams,
    TdlProfile,
    apply_cell_coupling,
    apply_channel,
    channel_params_schedule,
    channel_params_ue_schedule,
    simulate_slot_channel,
    simulate_slot_channel_traced,
)
from repro.phy.equalizer import effective_noise_var, mmse_equalize, mmse_irc_equalize
from repro.phy.estimators import (
    WienerInterpolator,
    estimator_flops,
    ls_estimate,
    mmse_estimate,
)
from repro.phy.link import (
    count_bit_errors,
    effective_mi,
    tb_success,
    tb_success_dynamic,
    throughput_bits,
)
from repro.phy.mcs import (
    MAX_MCS,
    McsEntry,
    QM_BY_MCS,
    QM_INDEX_BY_MCS,
    QM_VALUES,
    RATE_BY_MCS,
    mcs_entry,
    n_code_blocks,
    n_code_blocks_table,
    select_mcs,
    select_mcs_index,
    tbs_table,
    transport_block_size,
)
from repro.phy.nr import SlotConfig

# MAC overheads (bytes) for the PHY->MAC KPM coupling
_MAC_HEADER_BYTES = 3
_RLC_HEADER_BYTES = 2
_LCID4_FRACTION = 0.95  # share of MAC SDU carrying user-plane LCID 4 traffic


@dataclasses.dataclass
class LinkState:
    """Host-side link-adaptation + cumulative-counter state."""

    reported_snr_db: float = 20.0
    ndi: int = 1
    cum_phy_bits: float = 0.0
    cum_mac_bytes: float = 0.0
    cum_lcid4_bytes: float = 0.0
    slots: int = 0
    # outer-loop link adaptation: HARQ ACK/NACK-driven SINR offset.  The
    # decision-directed SINR measurement is biased at low SINR (wrong hard
    # decisions snap part of the error away, and more so for a worse channel
    # estimate); OLLA closes the loop on *realized* BLER, so estimator
    # quality surfaces in the MCS the scheduler actually grants — exactly
    # how production gNBs (incl. the paper's OAI L2) absorb measurement bias.
    olla_offset_db: float = 0.0


# OLLA steps: steady-state BLER target = up / (up + down) ~= 10 %
_OLLA_UP_DB = 0.15
_OLLA_DOWN_DB = 1.35
_OLLA_CLAMP_DB = 10.0


class PuschPipeline:
    """One UE's UL PUSCH receive chain with a switchable estimator bank."""

    def __init__(
        self,
        cfg: SlotConfig,
        ai_params: Any,
        *,
        net: AiEstimatorConfig = AiEstimatorConfig(),
        execution_mode: ExecutionMode = ExecutionMode.CONCURRENT,
        use_pallas_switch: bool = True,
        rms_delay_spread_s: float = 100e-9,
    ):
        self.cfg = cfg
        self.ai_params = ai_params
        self.interpolator = WienerInterpolator.build(
            cfg, rms_delay_spread_s=rms_delay_spread_s
        )
        # Bank order: designated expert FIRST (mode 0 == AI, paper 5.2).
        self.bank = ExpertBank(
            [
                Expert(
                    name="ai",
                    fn=lambda p, h_ls: ai_estimate_from_ls(p, h_ls),
                    params=ai_params,
                    flops=net.flops(cfg),
                ),
                Expert(
                    name="mmse",
                    fn=lambda p, h_ls: self._mmse_from_ls(h_ls),
                    params=None,
                    flops=estimator_flops(cfg),
                ),
            ],
            default_mode=1,
            execution_mode=execution_mode,
            use_pallas_switch=use_pallas_switch,
        )

    # -- expert wrappers ------------------------------------------------------

    def _mmse_from_ls(self, h_ls: jax.Array) -> jax.Array:
        from repro.kernels.mmse_interp import mmse_interp

        h_full = mmse_interp(h_ls, self.interpolator.w)
        return jnp.moveaxis(h_full, -2, -1)[:, None]

    # -- jitted slot stages ----------------------------------------------------

    @partial(jax.jit, static_argnames=("self", "qm", "tbs_bits"))
    def _tx_slot(self, key: jax.Array, qm: int, tbs_bits: int):
        """bits -> QAM symbols -> resource grid (+ pilots)."""
        cfg = self.cfg
        n_coded = cfg.n_data_re() * qm
        bits = jax.random.bernoulli(key, 0.5, (n_coded,)).astype(jnp.uint8)
        syms = qam.modulate(bits, qm)
        pilots = dmrs_mod.dmrs_sequence(cfg)
        grid = dmrs_mod.map_slot_grid(cfg, syms, pilots)
        return bits, grid, pilots

    @partial(jax.jit, static_argnames=("self", "qm", "perturb"))
    def _rx_slot(
        self,
        mode: jax.Array,
        rx_grid: jax.Array,
        pilots: jax.Array,
        tx_data_syms: jax.Array,
        noise_var: jax.Array,
        qm: int,
        *,
        perturb: bool = False,
        rho: jax.Array | float = 0.0,
        perturb_key: jax.Array | None = None,
    ):
        """LS -> expert bank -> switch -> equalize -> demap. Returns a dict.

        Two quality signals, deliberately separated:
        * *measured SINR* — decision-directed data-RE EVM, receiver-side
          (what Aerial reports and what drives link adaptation + LLR
          scaling).  Pilot-RE EVM is deliberately NOT used: estimates are
          derived from those same pilots, so their post-equalization EVM is
          self-referentially optimistic for LS-like estimators and blind to
          interpolation error on the data REs, which is exactly the error an
          expert estimator reduces.  Decision-directed EVM (against the
          nearest constellation point) is the standard receiver-side proxy
          and degrades when the channel estimate is bad — which is what
          makes the paper's Fig. 4 KPM trends monotonic in rho.
        * *genie per-RE SINR* — data-RE EVM against the known TX symbols
          (simulator-only), drives the MIESM TB-CRC model.
        """
        cfg = self.cfg
        h_ls = ls_estimate(cfg, rx_grid, pilots)
        if perturb:
            # Methodology stage 1 (paper Fig. 3): MMSE only, AWGN injected at
            # node 2c — no switching, no AI in the loop.
            h_sel = self._mmse_from_ls(h_ls)
            h_sel = perturb_estimate(h_sel, rho, perturb_key)
            all_outputs = None
        else:
            out = self.bank(mode, h_ls)
            h_sel = out.selected
            all_outputs = out.all_outputs
        x_hat, _ = mmse_equalize(cfg, rx_grid, h_sel, noise_var)

        # measured SINR: decision-directed EVM on data REs (receiver-side)
        data_hat = dmrs_mod.extract_data_re(cfg, x_hat[None])[0]
        points = qam.constellation(qm)
        nearest = points[
            jnp.argmin(jnp.abs(data_hat[:, None] - points[None, :]), axis=1)
        ]
        dd_err = jnp.mean(jnp.abs(data_hat - nearest) ** 2)
        sig_pow = jnp.mean(jnp.abs(nearest) ** 2)
        sinr_meas = sig_pow / jnp.maximum(dd_err, 1e-9)

        # genie per-RE SINR on data REs (TB-success model only)
        data_x = dmrs_mod.extract_data_re(cfg, x_hat[None])[0]
        genie_err = jnp.abs(data_x - tx_data_syms) ** 2
        # smooth over PRB-sized windows: LDPC averages error bursts
        n = genie_err.shape[0] - genie_err.shape[0] % 12
        smoothed = jnp.mean(genie_err[:n].reshape(-1, 12), axis=1)
        genie_sinr = 1.0 / jnp.maximum(smoothed, 1e-9)

        llr = qam.demap_llr(data_x, 1.0 / sinr_meas, qm)
        rsrp = jnp.mean(jnp.abs(h_sel) ** 2)
        return {
            "h_selected": h_sel,
            "all_outputs": all_outputs,
            "llr": llr,
            "genie_sinr": genie_sinr,
            "rsrp": rsrp,
            "post_snr_lin": sinr_meas,
        }

    # -- full slot -------------------------------------------------------------

    def run_slot(
        self,
        key: jax.Array,
        mode: int | jax.Array,
        link: LinkState,
        channel_cfg: ChannelConfig,
        *,
        perturb_rho: float | None = None,
    ) -> tuple[LinkState, dict[str, Any], dict[str, Mapping[str, float]]]:
        """Execute one slot; returns (new link state, outputs, KPMs-by-source)."""
        cfg = self.cfg
        k_tx, k_ch, k_n, k_crc, k_p = jax.random.split(key, 5)

        # link adaptation from last slot's report + OLLA offset (L2 behaviour)
        mcs = select_mcs(link.reported_snr_db + link.olla_offset_db)
        tbs = transport_block_size(cfg.n_data_re(), mcs)
        bits, tx_grid, pilots = self._tx_slot(k_tx, mcs.qm, tbs)

        fields = simulate_slot_channel(k_ch, cfg, channel_cfg)
        rx_grid = apply_channel(k_n, tx_grid, fields)

        tx_syms = dmrs_mod.extract_data_re(cfg, tx_grid[0][None])[0]
        rx = self._rx_slot(
            jnp.asarray(mode, jnp.int32),
            rx_grid,
            pilots,
            tx_syms,
            fields["noise_var"],
            mcs.qm,
            perturb=perturb_rho is not None,
            rho=0.0 if perturb_rho is None else perturb_rho,
            perturb_key=k_p,
        )

        ok = tb_success(rx["genie_sinr"], mcs, key=k_crc)
        phy_bits = throughput_bits(tbs, ok, cfg.slot_duration_s)

        # -- host-side KPM assembly (Aerial Data Lake + OAI, paper 4.3/6) --
        ok_f = float(ok)
        tb_bytes = tbs / 8.0
        mac_sdu_bytes = max(tb_bytes - _MAC_HEADER_BYTES, 0.0) * ok_f
        lcid4_bytes = max(mac_sdu_bytes - _RLC_HEADER_BYTES, 0.0) * _LCID4_FRACTION

        olla = link.olla_offset_db + (_OLLA_UP_DB if ok_f else -_OLLA_DOWN_DB)
        olla = float(np.clip(olla, -_OLLA_CLAMP_DB, _OLLA_CLAMP_DB))
        new_link = LinkState(
            reported_snr_db=float(10.0 * np.log10(float(rx["post_snr_lin"]) + 1e-9)),
            ndi=1 if ok_f else 0,  # NDI toggles on new data; retx keeps it
            cum_phy_bits=link.cum_phy_bits + float(phy_bits) * cfg.slot_duration_s,
            cum_mac_bytes=link.cum_mac_bytes + mac_sdu_bytes,
            cum_lcid4_bytes=link.cum_lcid4_bytes + lcid4_bytes,
            slots=link.slots + 1,
            olla_offset_db=olla,
        )
        elapsed = new_link.slots * cfg.slot_duration_s
        kpms = {
            "aerial": {
                "code_rate": mcs.code_rate,
                "sinr": float(10.0 * np.log10(float(rx["post_snr_lin"]) + 1e-9)),
                "qam_order": float(mcs.qm),
                "mcs_index": float(mcs.index),
                "tb_size": float(tbs) * ok_f,
                "n_code_blocks": float(n_code_blocks(tbs)) * ok_f,
                "pdu_length": tb_bytes * ok_f,
                "ndi": float(new_link.ndi),
                "rsrp": float(rx["rsrp"]),
                "phy_throughput": new_link.cum_phy_bits / elapsed,  # cumulative
            },
            "oai": {
                "snr": float(10.0 * np.log10(float(rx["post_snr_lin"]) + 1e-9)),
                "mac_throughput": new_link.cum_mac_bytes * 8.0 / elapsed,
                "lcid4_throughput": new_link.cum_lcid4_bytes * 8.0 / elapsed,
                "mac_rx_bytes": mac_sdu_bytes,
                "lcid4_rx_bytes": lcid4_bytes,
            },
        }
        outputs = {
            "tb_ok": ok_f,
            "tbs": tbs,
            "mcs": mcs.index,
            "phy_bits_per_s": float(phy_bits),
            "bits": bits,
            "llr": rx["llr"],
            "rx": rx,
        }
        return new_link, outputs, kpms

    # -- adapters ----------------------------------------------------------------

    def make_slot_fn(self, channel_schedule):
        """Adapter for ``ArchesRuntime``: carry = LinkState, input = slot idx.

        ``channel_schedule(slot) -> ChannelConfig`` defines the scenario
        (good/poor phases, paper Fig. 9).
        """

        def slot_fn(active_mode, carry, slot_idx):
            link = carry if carry is not None else LinkState()
            key = jax.random.PRNGKey(np.uint32(slot_idx * 2654435761 % (2**31)))
            ch = channel_schedule(int(slot_idx))
            link, outputs, kpms = self.run_slot(key, active_mode, link, ch)
            return link, outputs, kpms

        return slot_fn


# ---------------------------------------------------------------------------
# Batched multi-UE slot engine
# ---------------------------------------------------------------------------


class DeviceLinkState(NamedTuple):
    """Device-resident per-UE link state (the ``lax.scan`` carry).

    The host-loop ``LinkState`` keeps Python floats and pays a host
    round-trip per slot; this pytree keeps OLLA, link adaptation and the
    cumulative KPM counters on device so the whole slot loop compiles.  All
    leaves carry a leading ``(n_ues,)`` axis.
    """

    reported_snr_db: jax.Array  # (U,) float32
    olla_offset_db: jax.Array  # (U,) float32
    ndi: jax.Array  # (U,) int32
    cum_phy_bits: jax.Array  # (U,) float32 — delivered bits
    cum_mac_bytes: jax.Array  # (U,) float32
    cum_lcid4_bytes: jax.Array  # (U,) float32
    slots: jax.Array  # (U,) int32


def init_device_link(n_ues: int) -> DeviceLinkState:
    """Cold-start state matching ``LinkState()`` defaults, per UE."""
    f = lambda v: jnp.full((n_ues,), v, jnp.float32)
    return DeviceLinkState(
        reported_snr_db=f(20.0),
        olla_offset_db=f(0.0),
        ndi=jnp.ones((n_ues,), jnp.int32),
        cum_phy_bits=f(0.0),
        cum_mac_bytes=f(0.0),
        cum_lcid4_bytes=f(0.0),
        slots=jnp.zeros((n_ues,), jnp.int32),
    )


def normalize_modes(modes, n_slots: int, n_ues: int) -> jax.Array:
    """Broadcast any of {scalar, (S,), (U,), (S, U)} to an (S, U) int32 grid.

    A 1-D vector is per-slot when its length matches ``n_slots`` and per-UE
    when it matches ``n_ues``; when ``n_slots == n_ues`` that is ambiguous
    (the two broadcasts route experts differently), so a 1-D vector is
    rejected — pass the explicit ``(S, U)`` grid instead.
    """
    m = jnp.asarray(modes, jnp.int32)
    if m.ndim == 0:
        return jnp.full((n_slots, n_ues), m, jnp.int32)
    if m.ndim == 1:
        if n_slots == n_ues and m.shape[0] == n_slots:
            raise ValueError(
                f"1-D modes of length {m.shape[0]} are ambiguous when "
                f"n_slots == n_ues == {n_slots}: pass modes[:, None] "
                "(per-slot) or modes[None, :] (per-UE) explicitly"
            )
        if m.shape[0] == n_slots:
            return jnp.broadcast_to(m[:, None], (n_slots, n_ues))
        if m.shape[0] == n_ues:
            return jnp.broadcast_to(m[None, :], (n_slots, n_ues))
    elif m.ndim == 2:
        try:  # exact (S, U) or explicit (S, 1) / (1, U) broadcasts
            return jnp.broadcast_to(m, (n_slots, n_ues))
        except ValueError:
            pass
    raise ValueError(f"modes shape {m.shape} vs (n_slots={n_slots}, n_ues={n_ues})")


def resolve_schedule(
    cfg: SlotConfig, schedule, n_slots: int, n_ues: int
) -> tuple[TdlProfile, ChannelParams]:
    """Lower a scenario to traced per-slot channel params.

    ``schedule`` is either one ``schedule(slot) -> ChannelConfig`` callable
    (all UEs share the conditions; params leaves ``(n_slots, ...)``) or a
    per-UE sequence of them (heterogeneous cell; leaves
    ``(n_slots, n_ues, ...)``).
    """
    if callable(schedule):
        return channel_params_schedule(cfg, schedule, n_slots)
    schedules = list(schedule)
    if len(schedules) != n_ues:
        raise ValueError(
            f"per-UE schedule list has {len(schedules)} entries for "
            f"n_ues={n_ues}"
        )
    return channel_params_ue_schedule(cfg, schedules, n_slots)


class BatchedPuschPipeline:
    """Multi-UE PUSCH slot engine: vmapped stages + scan-compiled slot loop.

    The single-UE ``PuschPipeline`` dispatches O(slots x UEs) host-level
    stage calls and bounces link state through Python floats every slot.
    This engine vmaps TX / channel / RX over a leading UE axis, keeps
    ``DeviceLinkState`` on device, and rolls the slot loop into one
    ``jax.lax.scan`` — the whole campaign becomes a single compiled program.

    Link adaptation goes fully traced: MCS index, modulation order, code
    rate, TBS and code-block counts are device table lookups
    (``repro.phy.mcs``), and the modulation-order-dependent TX/EVM paths are
    computed for every supported QAM order and selected per UE (four cheap
    variants instead of a retrace per MCS).

    The expert bank receives a per-UE ``mode`` vector: different UEs run
    different experts in the same slot, selected by the batched Pallas
    switch kernel (``switch_select_batched_2d``).

    With ``execution_mode=ExecutionMode.GATED`` the AI expert runs only on
    the UEs whose committed mode selects it, compacted into a dense
    capacity-``gated_capacity`` sub-batch inside the scan body (MMSE still
    runs densely as the fail-safe baseline; the fused ``switch_scatter``
    pass un-compacts the AI results over it).  Compute then scales with the
    realized AI share instead of the concurrent cost envelope; UEs past
    capacity fall back to MMSE for that slot and surface in the trajectory's
    ``gated_overflow`` leaf.  Every trajectory additionally carries a per-UE
    ``executed_flops`` leaf (the slot's realized compute, from the bank's
    executed-cost accounting) so campaigns report the compute/energy proxy
    as a function of the expert mix.

    Bit-level outputs (LLRs, TX bits) are a per-``qm`` dynamic shape and are
    deliberately not emitted — the engine produces per-slot-per-UE KPMs and
    TB outcomes (what campaigns and policies consume); use ``PuschPipeline``
    for bit-exact single-link inspection.
    """

    def __init__(
        self,
        cfg: SlotConfig,
        ai_params: Any,
        *,
        net: AiEstimatorConfig = AiEstimatorConfig(),
        execution_mode: ExecutionMode = ExecutionMode.CONCURRENT,
        use_pallas_switch: bool = True,
        gated_capacity: int | None = None,
        fused_gated: bool = False,
        expert_dtype: str = "float32",
        audit_nmse_threshold: float | None = None,
        rms_delay_spread_s: float = 100e-9,
    ):
        self.cfg = cfg
        self.ai_params = ai_params
        self.interpolator = WienerInterpolator.build(
            cfg, rms_delay_spread_s=rms_delay_spread_s
        )
        self._pilots = dmrs_mod.dmrs_sequence(cfg)
        self._tbs_table = jnp.asarray(tbs_table(cfg.n_data_re()))
        self._ncb_table = jnp.asarray(n_code_blocks_table(cfg.n_data_re()))
        self._qm_by_mcs = jnp.asarray(QM_BY_MCS)
        self._qm_idx_by_mcs = jnp.asarray(QM_INDEX_BY_MCS)
        self._rate_by_mcs = jnp.asarray(RATE_BY_MCS)

        from repro.phy.ai_estimator import ai_estimate_folded, fold_ai_params

        if expert_dtype not in ("float32", "bfloat16"):
            raise ValueError(
                f"expert_dtype {expert_dtype!r}; one of 'float32', 'bfloat16'"
            )
        # None keeps the f32 path bitwise-identical to pre-dtype engines
        compute_dtype = (
            jnp.bfloat16 if expert_dtype == "bfloat16" else None
        )
        folded = fold_ai_params(ai_params, cfg.n_dmrs_sym)

        def ai_fn(_p, h_ls):
            return ai_estimate_folded(
                folded, h_ls, compute_dtype=compute_dtype
            )

        def mmse_fn(_p, h_ls):
            return self._mmse_from_ls_batched(h_ls)

        gated_fused_apply = None
        if fused_gated:
            if execution_mode is not ExecutionMode.GATED:
                raise ValueError("fused_gated requires GATED execution")
            from repro.kernels.gated_expert import gated_expert_apply

            def gated_fused_apply(idx, src, base, h_ls):
                return gated_expert_apply(
                    idx, src, h_ls, base, folded,
                    compute_dtype=compute_dtype,
                    backend="auto" if use_pallas_switch else "ref",
                )

        self.bank = ExpertBank(
            [
                Expert(name="ai", fn=ai_fn, params=ai_params, flops=net.flops(cfg)),
                Expert(name="mmse", fn=mmse_fn, params=None,
                       flops=estimator_flops(cfg)),
            ],
            default_mode=1,
            execution_mode=execution_mode,
            use_pallas_switch=use_pallas_switch,
            gated_capacity=gated_capacity,
            gated_fused_apply=gated_fused_apply,
            audit_threshold=audit_nmse_threshold,
        )

    def _mmse_from_ls_batched(self, h_ls: jax.Array) -> jax.Array:
        """(U, ant, dmrs_sym, pilot_sc) -> (U, ant, 1, n_sc, dmrs_sym)."""
        from repro.kernels.mmse_interp import mmse_interp

        h_full = mmse_interp(h_ls, self.interpolator.w)
        return jnp.moveaxis(h_full, -2, -1)[:, :, None]

    # -- per-UE stages (vmapped inside slot_step) -----------------------------

    def _ue_pre(self, profile: TdlProfile, p: ChannelParams, snr_db, olla_db, key):
        """Link adaptation + TX + channel + LS for one UE (traced MCS)."""
        cfg = self.cfg
        k_tx, k_ch, k_n, k_crc = jax.random.split(key, 4)

        mcs_idx = select_mcs_index(snr_db + olla_db)
        qm_idx = jnp.take(self._qm_idx_by_mcs, mcs_idx)
        qm = jnp.take(self._qm_by_mcs, mcs_idx).astype(jnp.float32)
        code_rate = jnp.take(self._rate_by_mcs, mcs_idx)
        tbs = jnp.take(self._tbs_table, mcs_idx).astype(jnp.float32)

        # TX for every supported modulation order; select per UE.  Bits are
        # drawn once at the widest order and prefix-sliced, so the payload
        # for a given (key, qm) is deterministic.
        n_re = cfg.n_data_re()
        bits = jax.random.bernoulli(k_tx, 0.5, (n_re * max(QM_VALUES),)).astype(
            jnp.uint8
        )
        syms_all = jnp.stack(
            [qam.modulate(bits[: n_re * q], q) for q in QM_VALUES], axis=0
        )
        syms = jnp.take(syms_all, qm_idx, axis=0)

        tx_grid = dmrs_mod.map_slot_grid(cfg, syms, self._pilots)
        fields = simulate_slot_channel_traced(k_ch, cfg, profile, p)
        rx_grid = apply_channel(k_n, tx_grid, fields)
        h_ls = ls_estimate(cfg, rx_grid, self._pilots)
        return {
            "mcs_idx": mcs_idx,
            "qm_idx": qm_idx,
            "qm": qm,
            "code_rate": code_rate,
            "tbs": tbs,
            "syms": syms,
            "rx_grid": rx_grid,
            "h_ls": h_ls,
            "noise_var": fields["noise_var"],
            "k_crc": k_crc,
        }

    def _ue_post(self, link: DeviceLinkState, pre: dict, h_sel: jax.Array):
        """Equalize + KPMs + OLLA for one UE (scalar link-state leaves)."""
        cfg = self.cfg
        x_hat, _ = mmse_equalize(cfg, pre["rx_grid"], h_sel, pre["noise_var"])
        data_hat = dmrs_mod.extract_data_re(cfg, x_hat[None])[0]

        # decision-directed EVM per modulation order, selected by qm_idx
        # (per-axis PAM nearest — equivalent to the host pipeline's
        # constellation argmin on square Gray QAM, O(1) per symbol)
        dd_errs, sig_pows = [], []
        for q in QM_VALUES:
            nearest = qam.nearest_point(data_hat, q)
            dd_errs.append(jnp.mean(jnp.abs(data_hat - nearest) ** 2))
            sig_pows.append(jnp.mean(jnp.abs(nearest) ** 2))
        dd_err = jnp.take(jnp.stack(dd_errs), pre["qm_idx"])
        sig_pow = jnp.take(jnp.stack(sig_pows), pre["qm_idx"])
        sinr_meas = sig_pow / jnp.maximum(dd_err, 1e-9)

        # genie per-RE SINR (MIESM TB model), as in the host pipeline
        genie_err = jnp.abs(data_hat - pre["syms"]) ** 2
        n = genie_err.shape[0] - genie_err.shape[0] % 12
        smoothed = jnp.mean(genie_err[:n].reshape(-1, 12), axis=1)
        genie_sinr = 1.0 / jnp.maximum(smoothed, 1e-9)

        ok = tb_success_dynamic(
            genie_sinr, pre["qm"], pre["code_rate"], key=pre["k_crc"]
        )
        ok_f = ok.astype(jnp.float32)
        tbs = pre["tbs"]
        slot_dur = cfg.slot_duration_s
        phy_bits = jnp.where(ok, tbs / slot_dur, 0.0)
        rsrp = jnp.mean(jnp.abs(h_sel) ** 2)

        tb_bytes = tbs / 8.0
        mac_sdu_bytes = jnp.maximum(tb_bytes - _MAC_HEADER_BYTES, 0.0) * ok_f
        lcid4_bytes = (
            jnp.maximum(mac_sdu_bytes - _RLC_HEADER_BYTES, 0.0) * _LCID4_FRACTION
        )

        olla = link.olla_offset_db + jnp.where(ok, _OLLA_UP_DB, -_OLLA_DOWN_DB)
        olla = jnp.clip(olla, -_OLLA_CLAMP_DB, _OLLA_CLAMP_DB)
        snr_db = 10.0 * jnp.log10(sinr_meas + 1e-9)

        new_link = DeviceLinkState(
            reported_snr_db=snr_db,
            olla_offset_db=olla,
            ndi=ok.astype(jnp.int32),
            cum_phy_bits=link.cum_phy_bits + phy_bits * slot_dur,
            cum_mac_bytes=link.cum_mac_bytes + mac_sdu_bytes,
            cum_lcid4_bytes=link.cum_lcid4_bytes + lcid4_bytes,
            slots=link.slots + 1,
        )
        elapsed = new_link.slots.astype(jnp.float32) * slot_dur
        kpms = {
            "aerial": {
                "code_rate": pre["code_rate"],
                "sinr": snr_db,
                "qam_order": pre["qm"],
                "mcs_index": pre["mcs_idx"].astype(jnp.float32),
                "tb_size": tbs * ok_f,
                "n_code_blocks": jnp.take(self._ncb_table, pre["mcs_idx"]).astype(
                    jnp.float32
                )
                * ok_f,
                "pdu_length": tb_bytes * ok_f,
                "ndi": ok_f,
                "rsrp": rsrp,
                "phy_throughput": new_link.cum_phy_bits / elapsed,
            },
            "oai": {
                "snr": snr_db,
                "mac_throughput": new_link.cum_mac_bytes * 8.0 / elapsed,
                "lcid4_throughput": new_link.cum_lcid4_bytes * 8.0 / elapsed,
                "mac_rx_bytes": mac_sdu_bytes,
                "lcid4_rx_bytes": lcid4_bytes,
            },
        }
        outputs = {
            "tb_ok": ok_f,
            "tbs": tbs,
            "mcs": pre["mcs_idx"],
            "phy_bits_per_s": phy_bits,
            "kpms": kpms,
        }
        return new_link, outputs

    def _corrupt_and_screen(self, out, h_sel, modes, corrupt, faults):
        """Fault injection + in-scan health screen on the selected estimate.

        ``corrupt (U,)`` flags this slot's expert-output corruption burst;
        it lands only on UEs actually *served* by the AI expert (mode 0 —
        overflow/audit-reverted UEs already hold the fail-safe output).
        The injected error is NaN, Inf, or a scaled copy per
        ``FaultSpec.corruption_kind``.  The screen then checks every
        AI-served UE's output for finiteness — independently of the
        injection, so a naturally diverged expert trips it too — and
        reverts tripped UEs to the densely-computed fail-safe baseline for
        this slot, returning the per-UE trip flags.  A scaled-error
        corruption stays finite by design: it flows downstream and is the
        breaker's blind spot unless the NMSE audit catches it.

        With an all-False ``corrupt`` mask and finite expert outputs every
        select here is the identity — the zero-fault bitwise contract.
        """
        srv = (
            out.served_by
            if out.served_by is not None
            else jnp.asarray(modes, jnp.int32)
        )
        hit = jnp.logical_and(jnp.asarray(corrupt), srv == 0)

        def inject(x):
            if faults.corruption_kind == "nan":
                bad = jnp.full_like(x, jnp.nan)
            elif faults.corruption_kind == "inf":
                bad = jnp.full_like(x, jnp.inf)
            else:
                bad = x * jnp.asarray(faults.corruption_scale, x.dtype)
            return jnp.where(
                hit.reshape(hit.shape + (1,) * (x.ndim - 1)), bad, x
            )

        h_sel = jax.tree.map(inject, h_sel)
        finite = None
        for leaf in jax.tree.leaves(h_sel):
            f = jnp.all(jnp.isfinite(leaf).reshape(leaf.shape[0], -1), axis=1)
            finite = f if finite is None else jnp.logical_and(finite, f)
        tripped = jnp.logical_and(srv == 0, jnp.logical_not(finite))
        if out.baseline is None:
            raise ValueError(
                "fault injection needs a batched bank output carrying the "
                "fail-safe baseline (BankOutput.baseline)"
            )
        h_sel = jax.tree.map(
            lambda s, b: jnp.where(
                tripped.reshape(tripped.shape + (1,) * (s.ndim - 1)), b, s
            ),
            h_sel,
            out.baseline,
        )
        return h_sel, tripped.astype(jnp.int32)

    # -- one batched slot ------------------------------------------------------

    def _slot_core(
        self,
        profile: TdlProfile,
        link: DeviceLinkState,
        modes: jax.Array,
        keys: jax.Array,
        p: ChannelParams,
        rho: jax.Array | None = None,
        cell_of_ue: jax.Array | None = None,
        cell_params: CellParams | None = None,
        cell_axis: str | None = None,
        active: jax.Array | None = None,
        faults=None,
        corrupt: jax.Array | None = None,
    ):
        if active is not None:
            # streaming bank-slot mask: detached lanes run the fail-safe
            # expert (so they never claim gated compaction capacity), their
            # link state freezes and their outputs/KPMs/executed-FLOPs zero
            # below.  With an all-ones mask every select is the identity, so
            # a fully-attached slot is bitwise-equal to the unmasked path.
            act = jnp.asarray(active)
            modes = jnp.where(
                act, jnp.asarray(modes, jnp.int32),
                jnp.int32(self.bank.default_mode),
            )
            if cell_of_ue is not None:
                # empty lanes must not contribute to the per-cell mean load
                p = p._replace(interf_on=jnp.where(act, p.interf_on, 0.0))
        if cell_of_ue is not None:
            # multi-cell topology: fold per-cell offsets + inter-cell
            # coupling into this slot's per-UE knobs.  Under shard_map,
            # ``cell_axis`` names the UE mesh axis and the per-cell mean is
            # the scan's only cross-device collective.
            if jnp.ndim(p.noise_var) != 1:
                raise ValueError(
                    "cell coupling needs per-UE ChannelParams leaves; "
                    "broadcast_params_to_ues the schedule first"
                )
            p = apply_cell_coupling(
                p, cell_of_ue, cell_params, axis_name=cell_axis
            )
        if jnp.ndim(p.noise_var) == 1:
            # per-UE heterogeneous conditions: params carry a (U,) axis
            pre = jax.vmap(
                lambda snr, olla, key, pu: self._ue_pre(profile, pu, snr, olla, key)
            )(link.reported_snr_db, link.olla_offset_db, keys, p)
        else:
            pre = jax.vmap(
                lambda snr, olla, key: self._ue_pre(profile, p, snr, olla, key)
            )(link.reported_snr_db, link.olla_offset_db, keys)
        n_ues = keys.shape[0]
        if rho is None:
            out = self.bank(jnp.asarray(modes, jnp.int32), pre["h_ls"])
            h_sel = out.selected
            exec_flops = self.bank.executed_flops_per_ue(out)
            overflow = (
                out.overflow.astype(jnp.int32)
                if out.overflow is not None
                else jnp.zeros((n_ues,), jnp.int32)
            )
            audit_tripped = (
                out.audit_tripped.astype(jnp.int32)
                if out.audit_tripped is not None
                else jnp.zeros((n_ues,), jnp.int32)
            )
            health_tripped = jnp.zeros((n_ues,), jnp.int32)
            if faults is not None:
                h_sel, health_tripped = self._corrupt_and_screen(
                    out, h_sel, modes, corrupt, faults
                )
        else:
            # methodology stage 1 (paper Fig. 3): MMSE only, AWGN injected
            # at node 2c — no switching, no AI in the loop.  ``rho`` is a
            # per-UE intensity vector, so one batched slot evaluates a whole
            # rho grid at once.
            h_mmse = self._mmse_from_ls_batched(pre["h_ls"])
            pkeys = jax.vmap(lambda k: jax.random.fold_in(k, 0x9e7))(keys)
            h_sel = jax.vmap(perturb_estimate)(
                h_mmse, jnp.asarray(rho, jnp.float32), pkeys
            )
            exec_flops = jnp.full(
                (n_ues,), self.bank.experts[self.bank.default_mode].flops,
                jnp.float32,
            )
            overflow = jnp.zeros((n_ues,), jnp.int32)
            audit_tripped = jnp.zeros((n_ues,), jnp.int32)
            health_tripped = jnp.zeros((n_ues,), jnp.int32)
        new_link, outputs = jax.vmap(self._ue_post)(link, pre, h_sel)
        outputs["executed_flops"] = exec_flops
        outputs["gated_overflow"] = overflow
        outputs["audit_tripped"] = audit_tripped
        outputs["health_tripped"] = health_tripped
        if active is not None:
            # detached lanes: state frozen, every output/KPM leaf zeroed —
            # they carry no throughput, no cost, no overflow, no telemetry
            new_link = jax.tree.map(
                lambda n, o: jnp.where(act, n, o), new_link, link
            )
            outputs = jax.tree.map(
                lambda x: jnp.where(
                    act.reshape(act.shape + (1,) * (x.ndim - 1)),
                    x, jnp.zeros_like(x),
                ),
                outputs,
            )
        return new_link, outputs

    @partial(jax.jit, static_argnames=("self", "profile"))
    def slot_step(
        self,
        profile: TdlProfile,
        link: DeviceLinkState,
        modes: jax.Array,
        keys: jax.Array,
        p: ChannelParams,
    ):
        """One compiled multi-UE slot. ``modes``/``keys`` carry the UE axis."""
        return self._slot_core(profile, link, modes, keys, p)

    @partial(jax.jit, static_argnames=("self", "profile", "cell_axis", "faults"))
    def _run_scan(
        self, profile, link0, ue_keys, modes, params,
        cell_of_ue=None, cell_params=None, *, cell_axis=None,
        slot0=None, active=None, faults=None, corrupt=None,
    ):
        # ``slot0`` (traced) starts the carry's slot counter at a global
        # slot index, so an epoch-chunked streaming campaign folds the same
        # per-(UE, slot) PRNG stream a monolithic run folds; ``active`` is
        # the streaming bank-slot mask (see ``_slot_core``).  Both default
        # to the monolithic behaviour.  ``faults`` (static) + ``corrupt``
        # ((S, U), traced, an extra scan operand) enable the open-loop
        # slice of fault injection: expert-output corruption + health
        # screen (decision/telemetry faults only exist in the closed loop).
        start = jnp.int32(0) if slot0 is None else jnp.asarray(slot0, jnp.int32)

        def step(carry, xs):
            link, slot_idx = carry
            if corrupt is None:
                (modes_s, p), cor_s = xs, None
            else:
                modes_s, p, cor_s = xs
            keys = jax.vmap(lambda k: jax.random.fold_in(k, slot_idx))(ue_keys)
            link, out = self._slot_core(
                profile, link, modes_s, keys, p,
                cell_of_ue=cell_of_ue, cell_params=cell_params,
                cell_axis=cell_axis, active=active,
                faults=faults, corrupt=cor_s,
            )
            return (link, slot_idx + 1), out

        xs = (modes, params) if corrupt is None else (modes, params, corrupt)
        (link, _), traj = jax.lax.scan(step, (link0, start), xs)
        return link, traj

    @partial(
        jax.jit,
        static_argnames=("self", "profile", "cell_axis", "faults"),
        donate_argnames=("link0",),
    )
    def _run_scan_streaming(
        self, profile, link0, ue_keys, modes, params,
        cell_of_ue=None, cell_params=None, *, cell_axis=None,
        slot0=None, active=None, faults=None, corrupt=None,
    ):
        # Streaming-only entry: identical program to ``_run_scan`` but the
        # carry buffer is donated — segment k's post-scan link state is dead
        # the moment it has been (copied for checkpointing and) gathered
        # into segment k+1's carry, so the steady-state loop reuses one
        # allocation instead of growing one per segment.  Callers that need
        # the pre-donation value must ``jnp.copy`` it first.
        return self._run_scan(
            profile, link0, ue_keys, modes, params,
            cell_of_ue, cell_params, cell_axis=cell_axis,
            slot0=slot0, active=active, faults=faults, corrupt=corrupt,
        )

    @partial(jax.jit, static_argnames=("self", "profile", "cell_axis"))
    def _run_perturbed_scan(
        self, profile, link0, ue_keys, rho, params,
        cell_of_ue=None, cell_params=None, *, cell_axis=None,
    ):
        def step(carry, p):
            link, slot_idx = carry
            keys = jax.vmap(lambda k: jax.random.fold_in(k, slot_idx))(ue_keys)
            modes = jnp.ones((ue_keys.shape[0],), jnp.int32)  # MMSE-only stage
            link, out = self._slot_core(
                profile, link, modes, keys, p, rho=rho,
                cell_of_ue=cell_of_ue, cell_params=cell_params,
                cell_axis=cell_axis,
            )
            return (link, slot_idx + 1), out

        (link, _), traj = jax.lax.scan(step, (link0, jnp.int32(0)), params)
        return link, traj

    def run_perturbed(
        self,
        schedule: Callable[[int], ChannelConfig],
        rho: jax.Array,
        *,
        n_slots: int,
        key: jax.Array | None = None,
        ue_keys: jax.Array | None = None,
    ) -> tuple[DeviceLinkState, dict[str, Any]]:
        """Methodology stage-1 campaign: per-UE perturbation intensities.

        The host harness loops rho values one slot at a time; here the whole
        rho grid rides the UE axis — UE ``u`` runs the MMSE-only pipeline
        with AWGN injected at intensity ``rho[u]`` every slot, and the whole
        ``n_slots x len(rho)`` sweep is one compiled scan.  PRNG derivation
        matches ``run`` (per-UE fold_in), with an independent stream for the
        injected noise.
        """
        rho = jnp.asarray(rho, jnp.float32)
        n_ues = rho.shape[0]
        if key is None:
            key = jax.random.PRNGKey(0)
        profile, params = resolve_schedule(self.cfg, schedule, n_slots, n_ues)
        if ue_keys is None:
            ue_keys = jax.vmap(lambda u: jax.random.fold_in(key, u))(
                jnp.arange(n_ues)
            )
        elif ue_keys.shape[0] != n_ues:
            raise ValueError(f"ue_keys {ue_keys.shape} vs rho {rho.shape}")
        link = init_device_link(n_ues)
        return self._run_perturbed_scan(profile, link, ue_keys, rho, params)

    # -- closed-loop scan ------------------------------------------------------

    def _closed_step(
        self, profile, sw_cfg, policy, ue_keys, link, sw, slot_idx, p,
        cell_of_ue=None, cell_params=None, cell_axis=None, active=None,
        faults=None, fault_s=None,
    ):
        """One closed-loop slot: boundary-committed modes in, decision out.

        ``sw.active_mode`` (committed at the previous boundary) drives the
        expert bank; this slot's KPMs are pushed into the device window, the
        policy decides, and the register/boundary update prepares slot
        ``slot_idx + 1``.  Shared verbatim by the scan body and the
        python-loop debug path so the two are the same program per slot.

        ``active`` (streaming bank-slot mask) freezes a detached lane's
        whole control-loop state — KPM ring, register, hysteresis streak
        and switch counter — so no telemetry accumulates while detached
        (reattachment cold-starts the row at the segment boundary; the
        streaming driver owns that re-pack).

        ``faults`` (static ``FaultSpec``) + ``fault_s`` (this slot's
        ``(decision_valid, corrupt, telemetry_valid)`` ``(U,)`` masks)
        inject the degradation ladder: quarantined UEs execute the
        fail-safe expert (never claiming gated capacity) while the control
        register keeps deciding, the expert output is corrupted/screened in
        ``_slot_core``, the switch update drops lost decisions and masked
        telemetry, the boundary runs the TTL decay, and the trip flags
        feed the circuit breaker last.  The ``quarantined`` leaf records
        the overlay as of the *start* of the slot.
        """
        keys = jax.vmap(lambda k: jax.random.fold_in(k, slot_idx))(ue_keys)
        committed = sw.active_mode
        if faults is not None:
            quarantined = (sw.quarantine > 0)
            exec_modes = jnp.where(
                quarantined, jnp.int32(sw_cfg.default_mode), committed
            )
            dv_s, cor_s, tv_s = fault_s
        else:
            quarantined = jnp.zeros_like(committed, bool)
            exec_modes = committed
            dv_s = cor_s = tv_s = None
        link, out = self._slot_core(
            profile, link, exec_modes, keys, p,
            cell_of_ue=cell_of_ue, cell_params=cell_params,
            cell_axis=cell_axis, active=active,
            faults=faults, corrupt=cor_s,
        )
        vecs = trajectory_kpm_matrix(out["kpms"], sw_cfg.feature_names)
        decide = (
            True
            if sw_cfg.period_slots == 1
            else (slot_idx % jnp.int32(sw_cfg.period_slots)) == 0
        )
        new_sw, raw = switch_update(
            sw, vecs, policy, sw_cfg, decide=decide,
            decision_valid=dv_s, telemetry_valid=tv_s,
        )
        out = dict(
            out,
            active_mode=committed,
            raw_decision=raw,
            pending_mode=new_sw.pending_mode,
            quarantined=quarantined.astype(jnp.int32),
        )
        if faults is not None:
            new_sw = switch_boundary(
                new_sw, ttl_slots=sw_cfg.ttl_slots,
                fail_safe_mode=sw_cfg.default_mode,
            )
            trip = jnp.logical_or(
                out["health_tripped"] > 0, out["audit_tripped"] > 0
            )
            new_sw = breaker_update(new_sw, trip, slot_idx, faults)
        else:
            new_sw = switch_boundary(new_sw)
        if active is not None:
            act = jnp.asarray(active)
            new_sw = jax.tree.map(
                lambda n, o: jnp.where(
                    act.reshape(act.shape + (1,) * (n.ndim - 1)), n, o
                ),
                new_sw, sw,
            )
            out = dict(
                out,
                active_mode=jnp.where(act, committed, 0),
                raw_decision=jnp.where(act, raw, 0),
                pending_mode=jnp.where(act, out["pending_mode"], 0),
                quarantined=jnp.where(act, out["quarantined"], 0),
            )
        return link, new_sw, out

    @partial(jax.jit, static_argnames=(
        "self", "profile", "sw_cfg", "cell_axis", "faults"
    ))
    def _run_closed_scan(
        self, profile, sw_cfg, link0, sw0, ue_keys, params, policy,
        cell_of_ue=None, cell_params=None, *, cell_axis=None,
        slot0=None, active=None, faults=None, fault_masks=None,
    ):
        # ``faults`` (static) + ``fault_masks`` (the resolved
        # ``(decision_valid, corrupt, telemetry_valid)`` triple of (S, U)
        # arrays, extra scan operands) enable the full degradation ladder.
        start = jnp.int32(0) if slot0 is None else jnp.asarray(slot0, jnp.int32)

        def step(carry, xs):
            link, sw, slot_idx = carry
            if fault_masks is None:
                p, fs = xs, None
            else:
                p, fs = xs
            link, sw, out = self._closed_step(
                profile, sw_cfg, policy, ue_keys, link, sw, slot_idx, p,
                cell_of_ue, cell_params, cell_axis, active,
                faults, fs,
            )
            return (link, sw, slot_idx + 1), out

        xs = params if fault_masks is None else (params, fault_masks)
        (link, sw, _), traj = jax.lax.scan(step, (link0, sw0, start), xs)
        return link, sw, traj

    @partial(
        jax.jit,
        static_argnames=("self", "profile", "sw_cfg", "cell_axis", "faults"),
        donate_argnames=("link0", "sw0"),
    )
    def _run_closed_scan_streaming(
        self, profile, sw_cfg, link0, sw0, ue_keys, params, policy,
        cell_of_ue=None, cell_params=None, *, cell_axis=None,
        slot0=None, active=None, faults=None, fault_masks=None,
    ):
        # Streaming-only entry mirroring ``_run_scan_streaming``: donates
        # both carries (link + switch state).  See that method's note on
        # liveness — copy before donating if the old value is still needed.
        return self._run_closed_scan(
            profile, sw_cfg, link0, sw0, ue_keys, params, policy,
            cell_of_ue, cell_params, cell_axis=cell_axis,
            slot0=slot0, active=active, faults=faults,
            fault_masks=fault_masks,
        )

    @partial(jax.jit, static_argnames=("self", "profile", "sw_cfg", "faults"))
    def _closed_slot_step(
        self, profile, sw_cfg, link, sw, slot_idx, ue_keys, p, policy,
        fault_s=None, *, faults=None,
    ):
        """One compiled closed-loop slot (python-loop debug/benchmark path)."""
        return self._closed_step(
            profile, sw_cfg, policy, ue_keys, link, sw, slot_idx, p,
            faults=faults, fault_s=fault_s,
        )

    def run_closed_loop(
        self,
        schedule: Callable[[int], ChannelConfig],
        policy: DevicePolicy,
        sw_cfg: SwitchConfig,
        *,
        n_slots: int,
        n_ues: int,
        key: jax.Array | None = None,
        ue_keys: jax.Array | None = None,
        use_scan: bool = True,
        faults=None,
    ):
        """Run a campaign with the switching decision inside the scan.

        Instead of an open-loop mode schedule, each slot's ``(n_ues,)`` mode
        vector comes from a ``DeviceSwitchState`` riding the scan carry: the
        previous slot's KPMs (rolling window mean over
        ``sw_cfg.window_slots`` slots) feed the exported ``policy`` tables,
        and the decision is committed to the switch register, taking effect
        at the next slot boundary — the whole loop is one ``lax.scan`` with
        zero host involvement.  ``sw_cfg.period_slots`` sets the dApp-style
        decision periodicity: the policy is consulted every ``period_slots``
        slots and the register holds in between.  PRNG derivation matches ``run`` exactly, so
        a closed-loop campaign whose decided modes happen to equal an
        open-loop grid produces the identical trajectory.

        Returns ``(final_link, final_switch_state, trajectory)``;
        the trajectory adds ``active_mode`` / ``raw_decision`` /
        ``pending_mode`` / ``quarantined`` leaves (all ``(n_slots, n_ues)``
        int32) to the leaves ``run`` emits.

        ``faults`` (a ``FaultSpec``) injects the full degradation ladder:
        decision loss -> TTL decay, expert corruption -> health screen ->
        circuit breaker, telemetry loss -> window masking.  The spec is
        resolved to dense masks here so the host oracle's own resolution
        consumes identical arrays.
        """
        if key is None:
            key = jax.random.PRNGKey(0)
        profile, params = resolve_schedule(self.cfg, schedule, n_slots, n_ues)
        if ue_keys is None:
            ue_keys = jax.vmap(lambda u: jax.random.fold_in(key, u))(
                jnp.arange(n_ues)
            )
        elif ue_keys.shape[0] != n_ues:
            raise ValueError(f"ue_keys {ue_keys.shape} vs n_ues {n_ues}")
        fault_masks = None
        if faults is not None:
            rf = faults.resolve(n_slots, n_ues)
            fault_masks = (
                jnp.asarray(rf.decision_valid),
                jnp.asarray(rf.corrupt),
                jnp.asarray(rf.telemetry_valid),
            )
        link = init_device_link(n_ues)
        sw = init_device_switch(
            n_ues, len(sw_cfg.feature_names), sw_cfg, faults
        )
        if use_scan:
            return self._run_closed_scan(
                profile, sw_cfg, link, sw, ue_keys, params, policy,
                faults=faults, fault_masks=fault_masks,
            )

        outs = []
        for s in range(n_slots):
            p = jax.tree.map(lambda x: x[s], params)
            fs = (
                None
                if fault_masks is None
                else tuple(m[s] for m in fault_masks)
            )
            link, sw, out = self._closed_slot_step(
                profile, sw_cfg, link, sw, jnp.int32(s), ue_keys, p, policy,
                fs, faults=faults,
            )
            outs.append(out)
        traj = jax.tree.map(lambda *ls: jnp.stack(ls, 0), *outs)
        return link, sw, traj

    # -- campaign driver -------------------------------------------------------

    def run(
        self,
        schedule: Callable[[int], ChannelConfig],
        modes,
        *,
        n_slots: int,
        n_ues: int,
        key: jax.Array | None = None,
        ue_keys: jax.Array | None = None,
        use_scan: bool = True,
        faults=None,
    ) -> tuple[DeviceLinkState, dict[str, Any]]:
        """Run an ``n_slots x n_ues`` campaign.

        Args:
          schedule: ``schedule(slot) -> ChannelConfig`` scenario (one TDL
            profile across the run; conditions may change per slot), or a
            per-UE sequence of such schedules (heterogeneous cell — UE
            ``u`` follows ``schedule[u]``; all share one TDL profile).
          modes: expert selection — scalar, per-slot ``(S,)``, per-UE
            ``(U,)`` or full ``(S, U)`` grid.
          key: root PRNG key; UE ``u`` in slot ``s`` consumes
            ``fold_in(fold_in(key, u), s)``, so per-UE streams are
            independent of the batch composition (a UE's trajectory is
            identical whether it runs alone or in a batch).
          ue_keys: explicit ``(n_ues,)`` per-UE base keys, overriding the
            ``fold_in(key, u)`` derivation — lets a batched run be compared
            against independent single-UE runs with the same keys.
          use_scan: compiled ``lax.scan`` loop (default) or a per-slot
            Python loop over the same jitted step (debug/benchmark baseline).
          faults: optional ``FaultSpec`` — the open-loop slice of fault
            injection (expert-output corruption + in-scan health screen;
            decision/telemetry faults only exist in the closed loop).
            Requires ``use_scan=True``.

        Returns:
          ``(final_link, trajectory)`` where every trajectory leaf is
          ``(n_slots, n_ues)``.
        """
        if key is None:
            key = jax.random.PRNGKey(0)
        profile, params = resolve_schedule(self.cfg, schedule, n_slots, n_ues)
        modes = normalize_modes(modes, n_slots, n_ues)
        if ue_keys is None:
            ue_keys = jax.vmap(lambda u: jax.random.fold_in(key, u))(
                jnp.arange(n_ues)
            )
        elif ue_keys.shape[0] != n_ues:
            raise ValueError(f"ue_keys {ue_keys.shape} vs n_ues {n_ues}")
        corrupt = None
        if faults is not None:
            if not use_scan:
                raise ValueError("fault injection needs use_scan=True")
            corrupt = jnp.asarray(faults.resolve(n_slots, n_ues).corrupt)
        link = init_device_link(n_ues)
        if use_scan:
            return self._run_scan(
                profile, link, ue_keys, modes, params,
                faults=faults, corrupt=corrupt,
            )

        outs = []
        for s in range(n_slots):
            keys = jax.vmap(lambda k: jax.random.fold_in(k, s))(ue_keys)
            p = jax.tree.map(lambda x: x[s], params)
            link, out = self.slot_step(profile, link, modes[s], keys, p)
            outs.append(out)
        traj = jax.tree.map(lambda *ls: jnp.stack(ls, 0), *outs)
        return link, traj
