"""MMSE equalizer with time-domain interpolation (paper 5.1).

The estimator experts produce full-band estimates at the N_sym^DMRS pilot
symbols only; the equalizer (i) interpolates across all 14 OFDM symbols in
time — the division of labour the paper describes for Aerial — then
(ii) performs per-RE MRC/MMSE combining across receive antennas and
(iii) reports post-equalization SINR, which feeds the SNR KPM.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.phy.nr import SlotConfig


def time_interpolate(cfg: SlotConfig, h_dmrs: jax.Array) -> jax.Array:
    """Linear interpolation across OFDM symbols.

    ``h_dmrs`` (..., n_sc, n_dmrs_sym) at symbols ``cfg.dmrs_symbols``
    -> (..., n_sc, n_sym) over the whole slot (edge symbols clamped).
    """
    sym = np.arange(cfg.n_sym, dtype=np.float64)
    anchors = np.asarray(cfg.dmrs_symbols, np.float64)
    # piecewise-linear weights, host-precomputed: (n_sym, n_dmrs_sym)
    w = np.zeros((cfg.n_sym, cfg.n_dmrs_sym))
    for i, s in enumerate(sym):
        j = int(np.clip(np.searchsorted(anchors, s) - 1, 0, len(anchors) - 2))
        t0, t1 = anchors[j], anchors[j + 1]
        a = np.clip((s - t0) / (t1 - t0), 0.0, 1.0)
        w[i, j] = 1.0 - a
        w[i, j + 1] = a
    wj = jnp.asarray(w, jnp.float32)
    return jnp.einsum("...sd,md->...sm", h_dmrs, wj.astype(h_dmrs.dtype))


@partial(jax.jit, static_argnames=("cfg",))
def mmse_equalize(
    cfg: SlotConfig,
    rx_grid: jax.Array,
    h_est_dmrs: jax.Array,
    noise_var: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Equalize one slot.

    Args:
      rx_grid: (n_ant, n_sc, n_sym) received grid.
      h_est_dmrs: (n_ant, n_layers, n_sc, n_dmrs_sym) expert output.
      noise_var: scalar noise variance.

    Returns:
      ``(x_hat, sinr)`` — (n_sc, n_sym) equalized symbols for layer 0 and
      (n_sc, n_sym) per-RE post-equalization SINR (linear).
    """
    h = time_interpolate(cfg, h_est_dmrs)[:, 0]  # (ant, sc, sym)
    num = jnp.sum(jnp.conj(h) * rx_grid, axis=0)  # MRC combine
    den = jnp.sum(jnp.abs(h) ** 2, axis=0)  # (sc, sym)
    x_hat = num / (den + noise_var)
    # nominal post-MRC SINR assuming a perfect estimate; the pipeline layers
    # an EVM-based *measured* SINR on top (see pipeline._rx_slot), which is
    # what degrades when the estimate is bad
    sinr = den / jnp.maximum(noise_var, 1e-12)
    return x_hat, sinr


def effective_noise_var(sinr: jax.Array) -> jax.Array:
    """Per-RE effective noise variance for the LLR demapper (unit signal)."""
    return 1.0 / jnp.maximum(sinr, 1e-9)


@partial(jax.jit, static_argnames=("cfg", "prb_per_subband"))
def mmse_irc_equalize(
    cfg: SlotConfig,
    rx_grid: jax.Array,
    h_est_dmrs: jax.Array,
    pilots: jax.Array,
    noise_var: jax.Array,
    *,
    prb_per_subband: int = 2,
) -> tuple[jax.Array, jax.Array]:
    """MMSE-IRC: interference-rejection combining (Aerial's UL combiner).

    The interference-plus-noise covariance ``R`` is estimated per frequency
    subband from DMRS residuals ``e = rx_pilot - h_est * pilot`` — i.e. from
    whatever the *selected expert's* channel estimate leaves unexplained at
    the pilots.  The combiner ``w = R^{-1} h / (h^H R^{-1} h + 1)`` then
    spatially nulls in-band interference.  This is the stage where channel-
    estimate quality pays off under interference: a worse estimate leaks
    desired signal into ``e``, biasing ``R`` and mis-steering the null —
    exactly the coupling that makes the paper's AI expert win in *poor*
    conditions (paper 6.2).

    Args:
      rx_grid: (n_ant, n_sc, n_sym).
      h_est_dmrs: (n_ant, n_layers, n_sc, n_dmrs_sym) expert output.
      pilots: (n_dmrs_sym, n_pilot_sc) transmitted DMRS.
      noise_var: scalar thermal-noise variance (diagonal loading).
      prb_per_subband: covariance-averaging granularity (frequency-selective
        interference needs narrow subbands; estimation stability wants wide).

    Returns:
      ``(x_hat, sinr)`` — (n_sc, n_sym) layer-0 symbol estimates and per-RE
      post-IRC SINR ``h^H R^{-1} h`` (linear).
    """
    n_ant, n_sc = cfg.n_ant, cfg.n_sc
    h_full = time_interpolate(cfg, h_est_dmrs)[:, 0]  # (ant, sc, sym)

    # -- residuals at pilot REs --------------------------------------------------
    pilot_sc = jnp.asarray(cfg.pilot_sc_indices)
    dmrs_sym = jnp.asarray(cfg.dmrs_symbols)
    rx_p = rx_grid[:, pilot_sc][:, :, dmrs_sym]  # (ant, n_pilot, n_dmrs)
    h_p = h_full[:, pilot_sc][:, :, dmrs_sym]  # (ant, n_pilot, n_dmrs)
    e = rx_p - h_p * jnp.swapaxes(pilots, 0, 1)[None]  # (ant, n_pilot, n_dmrs)

    # -- per-subband covariance ---------------------------------------------------
    sb_pilots = prb_per_subband * 6  # comb-2: 6 pilots per PRB
    n_sb = cfg.n_pilot_sc // sb_pilots
    e_sb = e[:, : n_sb * sb_pilots].reshape(n_ant, n_sb, sb_pilots, -1)
    # R_sb: (n_sb, ant, ant), averaged over pilots x dmrs symbols
    r = jnp.einsum("aspd,bspd->sab", e_sb, jnp.conj(e_sb)) / (
        sb_pilots * cfg.n_dmrs_sym
    )
    r = r + (noise_var * 0.1 + 1e-6) * jnp.eye(n_ant, dtype=r.dtype)[None]

    # map every subcarrier to its subband
    sc_to_sb = jnp.clip(jnp.arange(n_sc) // (12 * prb_per_subband), 0, n_sb - 1)

    # -- IRC combine per RE ----------------------------------------------------------
    h_t = jnp.moveaxis(h_full, 0, -1)  # (sc, sym, ant)
    r_sc = r[sc_to_sb]  # (sc, ant, ant)
    rinv_h = jnp.linalg.solve(r_sc[:, None], h_t[..., None])[..., 0]  # (sc,sym,ant)
    hrh = jnp.real(jnp.sum(jnp.conj(h_t) * rinv_h, axis=-1))  # (sc, sym)
    rx_t = jnp.moveaxis(rx_grid, 0, -1)  # (sc, sym, ant)
    num = jnp.sum(jnp.conj(rinv_h) * rx_t, axis=-1)  # (R^-1 h)^H y
    x_hat = num / jnp.maximum(hrh, 1e-9)  # unbiased MMSE-IRC estimate
    sinr = hrh
    return x_hat, sinr
