"""TDL multipath channel + interference simulator (paper 6, Fig. 7).

Generates the frequency-domain CSI tensor H in C^{N_ant x N_l x N_sc x N_sym}
(paper 4.1) from a tapped-delay-line power-delay profile with per-slot
Rayleigh block fading and Jakes-model time selectivity across the 14 OFDM
symbols of a slot.

Interference follows the paper's setup (Fig. 7b): a neighbouring UE2->gNB2
UL transmission creates frequency-selective in-band interference, whose
occupied bandwidth is controlled by a PRB-allocation mask (the paper's MAC
scheduler control knob).  *good* = no interference, *poor* = interference on.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.phy.nr import SlotConfig


@dataclasses.dataclass(frozen=True)
class TdlProfile:
    """Tapped-delay-line PDP (delays in seconds, powers in dB)."""

    delays_s: tuple[float, ...]
    powers_db: tuple[float, ...]
    doppler_hz: float = 10.0  # pedestrian-scale; paper is indoor LOS

    @property
    def rms_delay_spread_s(self) -> float:
        p = 10.0 ** (np.asarray(self.powers_db) / 10.0)
        p = p / p.sum()
        d = np.asarray(self.delays_s)
        mean = float((p * d).sum())
        return float(np.sqrt((p * (d - mean) ** 2).sum()))


# TDL-A-like short profile (indoor open space, LOS dominant first tap).
INDOOR_LOS = TdlProfile(
    delays_s=(0.0, 30e-9, 70e-9, 150e-9, 310e-9),
    powers_db=(0.0, -6.0, -9.0, -12.0, -18.0),
    doppler_hz=5.0,
)

# Richer NLOS-ish profile used for the "poor" stress variants.
INDOOR_NLOS = TdlProfile(
    delays_s=(0.0, 50e-9, 120e-9, 200e-9, 430e-9, 700e-9),
    powers_db=(-1.0, 0.0, -3.0, -6.0, -9.0, -14.0),
    doppler_hz=15.0,
)


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    profile: TdlProfile = INDOOR_LOS
    snr_db: float = 25.0  # thermal SNR at the gNB
    # interference (paper Fig. 7b): UE2 UL leaking into gNB1's band
    interference: bool = False
    inr_db: float = 12.0  # interference-to-noise ratio when on
    interference_prb_frac: float = 0.5  # fraction of band hit (PRB control)
    interference_prb_start: float = 0.25  # where the hit band starts
    # time selectivity: fraction of OFDM symbols the interferer occupies
    # (TDM-scheduled neighbour traffic).  With ``dmrs_collision`` the
    # neighbour's slot is frame-aligned (both cells follow the same NR
    # numerology), so its DMRS symbols collide with ours — the classic
    # pilot-contamination regime where channel-estimation quality, not raw
    # data-RE SINR, limits throughput.
    interference_symbol_duty: float = 1.0
    dmrs_collision: bool = False


def _freq_response(
    key: jax.Array, cfg: SlotConfig, profile: TdlProfile
) -> jax.Array:
    """One slot's CSI: (n_ant, n_layers, n_sc, n_sym) complex64.

    Per-tap Rayleigh gains, time-evolved across symbols with a Jakes-like
    AR(1) process, transformed to frequency via the tap delay steering
    vectors.
    """
    n_taps = len(profile.delays_s)
    powers = 10.0 ** (jnp.asarray(profile.powers_db) / 10.0)
    powers = powers / jnp.sum(powers)
    amps = jnp.sqrt(powers)  # (T,)

    k_init, k_evo = jax.random.split(key)
    shape0 = (cfg.n_ant, cfg.n_layers, n_taps)
    g0 = (
        jax.random.normal(k_init, shape0)
        + 1j * jax.random.normal(k_init + 1, shape0)
    ) / jnp.sqrt(2.0)

    # AR(1) time evolution: rho from Jakes autocorrelation J0(2 pi fD Ts)
    sym_duration = cfg.slot_duration_s / cfg.n_sym
    x = 2.0 * jnp.pi * profile.doppler_hz * sym_duration
    rho = 1.0 - (x**2) / 4.0  # J0 small-argument expansion
    rho = jnp.clip(rho, 0.0, 1.0)

    innov = (
        jax.random.normal(k_evo, (cfg.n_sym,) + shape0)
        + 1j * jax.random.normal(k_evo + 1, (cfg.n_sym,) + shape0)
    ) / jnp.sqrt(2.0)

    def step(g, eps):
        g_next = rho * g + jnp.sqrt(1.0 - rho**2) * eps
        return g_next, g_next

    _, g_t = jax.lax.scan(step, g0, innov)  # (n_sym, n_ant, n_l, T)
    g_t = jnp.moveaxis(g_t, 0, -1)  # (n_ant, n_l, T, n_sym)
    g_t = g_t * amps[None, None, :, None]

    # Frequency response: sum_t g_t * exp(-j 2 pi f_k tau_t)
    df = cfg.scs_khz * 1e3
    f = jnp.arange(cfg.n_sc) * df  # (n_sc,)
    tau = jnp.asarray(profile.delays_s)  # (T,)
    steering = jnp.exp(-2j * jnp.pi * f[:, None] * tau[None, :])  # (n_sc, T)
    h = jnp.einsum("st,altm->alsm", steering, g_t)  # (ant, l, sc, sym)
    return h.astype(jnp.complex64)


def _interference_mask(cfg: SlotConfig, ch: ChannelConfig) -> jax.Array:
    """Frequency-selective occupied-PRB mask, (n_sc,) in {0,1}."""
    start_prb = int(round(ch.interference_prb_start * cfg.n_prb))
    n_hit = int(round(ch.interference_prb_frac * cfg.n_prb))
    sc = np.zeros(cfg.n_sc, np.float32)
    lo = start_prb * 12
    hi = min((start_prb + n_hit) * 12, cfg.n_sc)
    sc[lo:hi] = 1.0
    return jnp.asarray(sc)


def _interference_symbol_mask(
    key: jax.Array, cfg: SlotConfig, ch: ChannelConfig
) -> jax.Array:
    """Time-selective occupied-symbol mask, (n_sym,) in {0,1}.

    ``dmrs_collision``: the frame-aligned neighbour always occupies our DMRS
    symbols (its own DMRS collides there); remaining duty is spread randomly
    over the data symbols.  Without collision the duty spreads uniformly.
    """
    duty = float(ch.interference_symbol_duty)
    if duty >= 1.0:
        return jnp.ones(cfg.n_sym, jnp.float32)
    if not ch.dmrs_collision:
        return (jax.random.uniform(key, (cfg.n_sym,)) < duty).astype(jnp.float32)
    dmrs = np.zeros(cfg.n_sym, np.float32)
    dmrs[list(cfg.dmrs_symbols)] = 1.0
    n_target = duty * cfg.n_sym
    n_rest = cfg.n_sym - cfg.n_dmrs_sym
    p_rest = max(n_target - cfg.n_dmrs_sym, 0.0) / n_rest
    rest = (jax.random.uniform(key, (cfg.n_sym,)) < p_rest).astype(jnp.float32)
    return jnp.maximum(jnp.asarray(dmrs), rest)


@partial(jax.jit, static_argnames=("cfg", "ch"))
def simulate_slot_channel(
    key: jax.Array, cfg: SlotConfig, ch: ChannelConfig
) -> dict[str, jax.Array]:
    """Simulate one slot: true CSI + noise + interference fields.

    Returns a dict:
      ``h``        (n_ant, n_l, n_sc, n_sym) true CSI
      ``noise_var``  scalar thermal-noise variance (signal power == 1)
      ``interference`` (n_ant, n_sc, n_sym) additive interference samples
    """
    k_h, k_i, k_hi = jax.random.split(key, 3)
    h = _freq_response(k_h, cfg, ch.profile)
    # normalize mean RX power to 1 so snr_db sets noise directly
    h = h / jnp.sqrt(jnp.mean(jnp.abs(h) ** 2) + 1e-12)
    noise_var = jnp.asarray(10.0 ** (-ch.snr_db / 10.0), jnp.float32)

    if ch.interference:
        mask = _interference_mask(cfg, ch)  # (n_sc,)
        sym_mask = _interference_symbol_mask(
            jax.random.fold_in(k_i, 7), cfg, ch
        )  # (n_sym,)
        # interference propagates through its own (flat-ish) channel
        hi = _freq_response(k_hi, cfg, ch.profile)[:, 0]  # (ant, sc, sym)
        hi = hi / jnp.sqrt(jnp.mean(jnp.abs(hi) ** 2) + 1e-12)
        sym = (
            jax.random.normal(k_i, (cfg.n_sc, cfg.n_sym))
            + 1j * jax.random.normal(k_i + 1, (cfg.n_sc, cfg.n_sym))
        ) / jnp.sqrt(2.0)
        amp = jnp.sqrt(noise_var * 10.0 ** (ch.inr_db / 10.0))
        re_mask = mask[None, :, None] * sym_mask[None, None, :]
        interference = amp * hi * (re_mask * sym[None]).astype(jnp.complex64)
    else:
        interference = jnp.zeros(
            (cfg.n_ant, cfg.n_sc, cfg.n_sym), jnp.complex64
        )
    return {"h": h, "noise_var": noise_var, "interference": interference}


# -- traced-parameter variant (batched scan engine) ---------------------------
#
# ``simulate_slot_channel`` treats the whole ``ChannelConfig`` as static,
# which retraces per condition and cannot ride a ``lax.scan`` whose channel
# conditions change per slot.  ``ChannelParams`` lowers the per-slot knobs
# (SNR, interference on/off, INR, masks) to device values so one compiled
# slot step covers every scenario phase; the TDL profile stays static (the
# paper's good/poor phases share the propagation environment and differ in
# interference, Fig. 7).


class ChannelParams(NamedTuple):
    """Traced per-slot channel knobs (pytree; stackable over slots).

    ``noise_var`` and ``inr_lin`` are pre-converted on the host (float64 ->
    float32, exactly as the static path's constant folding rounds them) so
    the traced simulation matches ``simulate_slot_channel``: ``h`` and
    ``noise_var`` bitwise, the interference field to ~1e-7 relative (XLA
    fuses the two programs differently, reassociating the last bit).
    """

    noise_var: jax.Array  # () float32 thermal-noise variance
    interf_on: jax.Array  # () float32 in {0, 1}
    inr_lin: jax.Array  # () float32 linear interference-to-noise ratio
    sc_mask: jax.Array  # (n_sc,) float32 occupied-PRB mask
    duty_full: jax.Array  # () float32 in {0, 1} — interferer always on
    base_sym_mask: jax.Array  # (n_sym,) float32 — DMRS-collision symbols
    p_rest: jax.Array  # () float32 — duty probability on remaining symbols


def channel_params(cfg: SlotConfig, ch: ChannelConfig) -> ChannelParams:
    """Lower one ``ChannelConfig`` to traced per-slot parameters."""
    duty = float(ch.interference_symbol_duty)
    if ch.dmrs_collision:
        base = np.zeros(cfg.n_sym, np.float32)
        base[list(cfg.dmrs_symbols)] = 1.0
        n_rest = cfg.n_sym - cfg.n_dmrs_sym
        p_rest = max(duty * cfg.n_sym - cfg.n_dmrs_sym, 0.0) / n_rest
    else:
        base = np.zeros(cfg.n_sym, np.float32)
        p_rest = duty
    return ChannelParams(
        noise_var=jnp.float32(10.0 ** (-ch.snr_db / 10.0)),
        interf_on=jnp.float32(1.0 if ch.interference else 0.0),
        inr_lin=jnp.float32(10.0 ** (ch.inr_db / 10.0)),
        sc_mask=_interference_mask(cfg, ch),
        duty_full=jnp.float32(1.0 if duty >= 1.0 else 0.0),
        base_sym_mask=jnp.asarray(base),
        p_rest=jnp.float32(p_rest),
    )


def channel_params_schedule(
    cfg: SlotConfig, schedule, n_slots: int
) -> tuple[TdlProfile, ChannelParams]:
    """Stack a slot schedule into (static profile, slot-stacked params).

    ``schedule(slot) -> ChannelConfig``; all slots must share one TDL
    profile (the traced path keeps propagation static — see module note).
    Returns params whose leaves carry a leading ``(n_slots,)`` axis, ready
    to be consumed as ``lax.scan`` inputs.
    """
    cfgs = [schedule(i) for i in range(n_slots)]
    profiles = {c.profile for c in cfgs}
    if len(profiles) > 1:
        raise ValueError(
            "traced channel schedule requires a single TDL profile; got "
            f"{len(profiles)}"
        )
    params = [channel_params(cfg, c) for c in cfgs]
    return cfgs[0].profile, jax.tree.map(lambda *ls: jnp.stack(ls, 0), *params)


def channel_params_ue_schedule(
    cfg: SlotConfig, schedules, n_slots: int
) -> tuple[TdlProfile, ChannelParams]:
    """Per-UE heterogeneous schedules -> one stacked ``ChannelParams``.

    ``schedules`` is one slot schedule per UE; every leaf of the result
    carries a leading ``(n_slots, n_ues)`` shape (slot axis first so the
    stack rides ``lax.scan`` unchanged; the engine vmaps the UE axis).  All
    schedules must share one TDL profile — the per-UE axis varies the
    *conditions* (SNR, interference), not the propagation environment,
    mirroring a single cell with heterogeneous users.
    """
    pairs = [channel_params_schedule(cfg, s, n_slots) for s in schedules]
    profiles = {profile for profile, _ in pairs}
    if len(profiles) > 1:
        raise ValueError(
            "per-UE traced schedules require a single shared TDL profile; "
            f"got {len(profiles)}"
        )
    params = jax.tree.map(
        lambda *ls: jnp.stack(ls, 1), *[p for _, p in pairs]
    )
    return pairs[0][0], params


# -- multi-cell coupling (sharded topology layer) ------------------------------
#
# A campaign laid out as ``n_cells`` cells on the UE axis couples cells
# through the channel: a cell whose members see interference raises the
# effective noise floor of *other* cells (neighbour-cell UL leakage, the
# same physics as ``ChannelConfig.interference`` but at cell granularity).
# ``CellParams`` carries the per-cell knobs; ``apply_cell_coupling`` folds
# them into a slot's per-UE ``ChannelParams``.  The per-cell mean load is
# computed from exact {0,1} counts (segment-sum of ``interf_on``), so its
# value is independent of how the UE axis is partitioned across devices —
# the property that makes sharded and unsharded campaigns bitwise-equal.
# Under ``shard_map`` the count reduction is one ``psum`` over the UE mesh
# axis: the only cross-shard collective in the whole slot scan.


class CellParams(NamedTuple):
    """Per-cell channel offsets + inter-cell coupling (pytree; replicated).

    ``noise_scale``/``inr_scale`` are *linear* per-cell multipliers applied
    to every member UE's thermal noise / interference power (host-converted
    from dB offsets, like ``ChannelParams``).  ``coupling`` scales the
    inter-cell leakage term: cell ``c``'s noise floor is multiplied by
    ``1 + coupling * mean_load_of_other_cells(c)`` where a cell's load is
    the fraction of its member UEs with interference active this slot.
    ``ues_per_cell`` rides along as a traced scalar so shard-local code
    never needs the global UE count.
    """

    noise_scale: jax.Array  # (n_cells,) float32 linear
    inr_scale: jax.Array  # (n_cells,) float32 linear
    coupling: jax.Array  # () float32 — inter-cell leakage coefficient
    ues_per_cell: jax.Array  # () float32 — global UEs per cell


def cell_params(
    n_cells: int,
    ues_per_cell: int,
    *,
    noise_offsets_db=(),
    inr_offsets_db=(),
    coupling: float = 0.0,
) -> CellParams:
    """Lower per-cell dB offsets to the traced ``CellParams`` pytree.

    Empty offset tuples mean "no offset" (all-ones scales); otherwise one
    entry per cell is required.
    """
    def lin(offs, noun):
        if not len(offs):
            return jnp.ones((n_cells,), jnp.float32)
        if len(offs) != n_cells:
            raise ValueError(
                f"{noun} has {len(offs)} entries for n_cells={n_cells}"
            )
        return jnp.asarray(
            10.0 ** (np.asarray(offs, np.float64) / 10.0), jnp.float32
        )

    return CellParams(
        noise_scale=lin(noise_offsets_db, "noise_offsets_db"),
        inr_scale=lin(inr_offsets_db, "inr_offsets_db"),
        coupling=jnp.float32(coupling),
        ues_per_cell=jnp.float32(ues_per_cell),
    )


def apply_cell_coupling(
    p: ChannelParams,
    cell_of_ue: jax.Array,
    cells: CellParams,
    *,
    axis_name: str | None = None,
) -> ChannelParams:
    """Fold per-cell offsets + inter-cell leakage into one slot's params.

    ``p`` carries per-UE leaves (``noise_var`` etc. shaped ``(U,)`` — the
    local shard's UEs under ``shard_map``); ``cell_of_ue (U,)`` maps them to
    global cell ids.  The per-cell interference load is a mean of {0,1}
    activity flags, so partial sums are exact integers and the reduction
    commutes across any sharding — with ``axis_name`` set, shard-local
    partial counts are combined with a single ``lax.psum`` (the scan's only
    cross-device collective; compaction and scatter stay shard-local).
    """
    n_cells = cells.noise_scale.shape[0]
    interf = jnp.broadcast_to(p.interf_on, cell_of_ue.shape)
    load = jax.ops.segment_sum(interf, cell_of_ue, num_segments=n_cells)
    if axis_name is not None:
        load = jax.lax.psum(load, axis_name)
    mean_load = load / cells.ues_per_cell  # (C,) exact counts / exact count
    if n_cells > 1:
        other = (jnp.sum(mean_load) - mean_load) / (n_cells - 1)
    else:
        other = jnp.zeros_like(mean_load)
    noise_mult = cells.noise_scale * (1.0 + cells.coupling * other)  # (C,)
    noise_scale_ue = jnp.take(noise_mult, cell_of_ue)  # (U,)
    inr_scale_ue = jnp.take(cells.inr_scale, cell_of_ue)
    return p._replace(
        noise_var=p.noise_var * noise_scale_ue,
        inr_lin=p.inr_lin * inr_scale_ue,
    )


def broadcast_params_to_ues(params: ChannelParams, n_ues: int) -> ChannelParams:
    """Give homogeneous ``(S, ...)`` params an explicit ``(S, U, ...)`` UE
    axis (already-per-UE params pass through).  The sharded engine always
    runs the per-UE path so every leaf can be partitioned along UEs."""
    if params.noise_var.ndim == 2:
        return params
    return jax.tree.map(
        lambda x: jnp.broadcast_to(
            x[:, None], (x.shape[0], n_ues) + x.shape[1:]
        ),
        params,
    )


def _interference_symbol_mask_traced(
    key: jax.Array, cfg: SlotConfig, p: ChannelParams
) -> jax.Array:
    """Traced analogue of ``_interference_symbol_mask`` (same key semantics)."""
    rest = (jax.random.uniform(key, (cfg.n_sym,)) < p.p_rest).astype(jnp.float32)
    mask = jnp.maximum(p.base_sym_mask, rest)
    return jnp.where(p.duty_full > 0, jnp.ones(cfg.n_sym, jnp.float32), mask)


@partial(jax.jit, static_argnames=("cfg", "profile"))
def simulate_slot_channel_traced(
    key: jax.Array, cfg: SlotConfig, profile: TdlProfile, p: ChannelParams
) -> dict[str, jax.Array]:
    """``simulate_slot_channel`` with traced per-slot knobs.

    Matches the static version for the same key and an equivalent
    ``ChannelConfig``: ``h``/``noise_var`` bitwise, interference to ~1e-7
    relative (the branch is computed unconditionally and zeroed by
    ``interf_on`` — same math, scan-compatible control flow, last-bit
    fusion differences).
    """
    k_h, k_i, k_hi = jax.random.split(key, 3)
    h = _freq_response(k_h, cfg, profile)
    h = h / jnp.sqrt(jnp.mean(jnp.abs(h) ** 2) + 1e-12)
    noise_var = p.noise_var

    sym_mask = _interference_symbol_mask_traced(
        jax.random.fold_in(k_i, 7), cfg, p
    )
    hi = _freq_response(k_hi, cfg, profile)[:, 0]
    hi = hi / jnp.sqrt(jnp.mean(jnp.abs(hi) ** 2) + 1e-12)
    sym = (
        jax.random.normal(k_i, (cfg.n_sc, cfg.n_sym))
        + 1j * jax.random.normal(k_i + 1, (cfg.n_sc, cfg.n_sym))
    ) / jnp.sqrt(2.0)
    amp = jnp.sqrt(noise_var * p.inr_lin) * p.interf_on
    re_mask = p.sc_mask[None, :, None] * sym_mask[None, None, :]
    interference = amp * hi * (re_mask * sym[None]).astype(jnp.complex64)
    return {"h": h, "noise_var": noise_var, "interference": interference}


def apply_channel(
    key: jax.Array,
    tx_grid: jax.Array,
    fields: dict[str, jax.Array],
) -> jax.Array:
    """RX grid: y = H x + interference + AWGN.

    ``tx_grid`` (n_l, n_sc, n_sym) -> returns (n_ant, n_sc, n_sym).
    """
    h = fields["h"]  # (ant, l, sc, sym)
    y = jnp.einsum("alsm,lsm->asm", h, tx_grid)
    y = y + fields["interference"]
    noise = (
        jax.random.normal(key, y.shape) + 1j * jax.random.normal(key + 1, y.shape)
    ) / jnp.sqrt(2.0)
    return y + jnp.sqrt(fields["noise_var"]) * noise.astype(jnp.complex64)
