"""5G NR PUSCH substrate: the paper's case-study domain (paper 5/6)."""

from repro.phy.nr import DEFAULT_SLOT, SlotConfig
from repro.phy.channel import (
    INDOOR_LOS,
    INDOOR_NLOS,
    ChannelConfig,
    TdlProfile,
    apply_channel,
    simulate_slot_channel,
)
from repro.phy.estimators import WienerInterpolator, ls_estimate, mmse_estimate
from repro.phy.ai_estimator import (
    AiEstimatorConfig,
    ai_estimate_from_ls,
    init_params,
    train_ai_estimator,
)
from repro.phy.equalizer import mmse_equalize, time_interpolate
from repro.phy.pipeline import LinkState, PuschPipeline
from repro.phy.scenario import (
    GOOD,
    POOR,
    POOR_WINDOW,
    PoorWindow,
    Scenario,
    bursty_interference_schedule,
    condition_label,
    constant_schedule,
    get_scenario,
    good_poor_good_schedule,
    make_schedule,
    register_scenario,
    scenario_names,
    scenario_params,
    snr_ramp_schedule,
)

__all__ = [
    "DEFAULT_SLOT",
    "SlotConfig",
    "ChannelConfig",
    "TdlProfile",
    "INDOOR_LOS",
    "INDOOR_NLOS",
    "apply_channel",
    "simulate_slot_channel",
    "WienerInterpolator",
    "ls_estimate",
    "mmse_estimate",
    "AiEstimatorConfig",
    "ai_estimate_from_ls",
    "init_params",
    "train_ai_estimator",
    "mmse_equalize",
    "time_interpolate",
    "LinkState",
    "PuschPipeline",
    "GOOD",
    "POOR",
    "POOR_WINDOW",
    "PoorWindow",
    "Scenario",
    "bursty_interference_schedule",
    "condition_label",
    "constant_schedule",
    "get_scenario",
    "good_poor_good_schedule",
    "make_schedule",
    "register_scenario",
    "scenario_names",
    "scenario_params",
    "snr_ramp_schedule",
]
