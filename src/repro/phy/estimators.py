"""Channel estimators: LS and MMSE/Wiener with PDP approximation (paper 5.1-5.2).

Expert A (conventional, fail-safe default) is the MMSE estimator native to
the Aerial PUSCH pipeline: DMRS-based LS at pilot positions followed by
frequency-domain Wiener interpolation built from a power-delay-profile
approximation (paper ref [16]).  Time-domain interpolation across OFDM
symbols is deliberately NOT performed here — the paper notes Aerial leaves
it to the equalizer (5.1), and so do we.

The Wiener matmul is the estimator's compute hot-spot and runs through the
Pallas ``mmse_interp`` kernel (MXU path); the pure-jnp reference is used by
the tests.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.mmse_interp import mmse_interp, mmse_interp_ref
from repro.phy import dmrs as dmrs_mod
from repro.phy.nr import SlotConfig


def ls_estimate(
    cfg: SlotConfig, rx_grid: jax.Array, pilots: jax.Array
) -> jax.Array:
    """Least-squares estimates at DMRS REs.

    ``rx_grid`` (n_ant, n_sc, n_sym), ``pilots`` (n_dmrs_sym, n_pilot_sc)
    -> (n_ant, n_dmrs_sym, n_pilot_sc).
    """
    rx_pilots = dmrs_mod.extract_pilot_re(cfg, rx_grid)
    return rx_pilots * jnp.conj(pilots) / (jnp.abs(pilots) ** 2 + 1e-12)


def exponential_pdp_correlation(
    cfg: SlotConfig, rms_delay_spread_s: float
) -> np.ndarray:
    """Frequency-correlation r(dk) for an exponential PDP approximation.

    r(delta_f) = 1 / (1 + j 2 pi tau_rms delta_f)  (paper ref [16]).
    Returns the (n_sc, n_sc) correlation matrix (host-side, cached per cfg).
    """
    df = cfg.scs_khz * 1e3
    k = np.arange(cfg.n_sc)
    dk = (k[:, None] - k[None, :]) * df
    return 1.0 / (1.0 + 2j * np.pi * rms_delay_spread_s * dk)


@dataclasses.dataclass(frozen=True)
class WienerInterpolator:
    """Precomputed W = R_fp (R_pp + sigma^2 I)^-1, pilot -> full band."""

    w: jax.Array  # (n_pilot_sc, n_sc) complex64 — matches kernel layout

    @classmethod
    def build(
        cls,
        cfg: SlotConfig,
        *,
        rms_delay_spread_s: float = 100e-9,
        noise_var: float = 1e-2,
    ) -> "WienerInterpolator":
        r = exponential_pdp_correlation(cfg, rms_delay_spread_s)
        p = cfg.pilot_sc_indices
        r_fp = r[:, p]  # (n_sc, n_pilot)
        r_pp = r[np.ix_(p, p)]  # (n_pilot, n_pilot)
        w = r_fp @ np.linalg.inv(r_pp + noise_var * np.eye(len(p)))
        return cls(w=jnp.asarray(w.T, jnp.complex64))  # (n_pilot, n_sc)


def mmse_estimate(
    cfg: SlotConfig,
    rx_grid: jax.Array,
    pilots: jax.Array,
    interpolator: WienerInterpolator,
    *,
    use_kernel: bool = True,
) -> jax.Array:
    """Expert A: LS at pilots + Wiener frequency interpolation.

    Returns hat{H}_MMSE (n_ant, n_layers, n_sc, n_dmrs_sym) — estimates at
    the N_sym^DMRS pilot symbols, full band (paper 4.1).
    """
    h_ls = ls_estimate(cfg, rx_grid, pilots)  # (ant, dmrs_sym, pilot_sc)
    interp = mmse_interp if use_kernel else mmse_interp_ref
    h_full = interp(h_ls, interpolator.w)  # (ant, dmrs_sym, n_sc)
    return jnp.moveaxis(h_full, -2, -1)[:, None]  # (ant, 1, n_sc, dmrs_sym)


def estimator_flops(cfg: SlotConfig) -> float:
    """Complex-matmul FLOPs for the Wiener interpolation (cost model)."""
    b = cfg.n_ant * cfg.n_dmrs_sym
    return 8.0 * b * cfg.n_pilot_sc * cfg.n_sc
