"""5G NR UL slot numerology and PUSCH dimensioning (paper 4.1, 5.1).

Defaults match the paper's X5G configuration: 30 kHz subcarrier spacing
(500 us slots), 14 OFDM symbols per slot, DMRS type-1 on symbols {0, 5, 10}
with comb-2 frequency interleaving, N_ant = 4 receive antenna ports,
N_l = 1 transmission layer.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# 3GPP TS 38.211 constants
N_SC_PER_PRB = 12
N_SYM_PER_SLOT = 14


@dataclasses.dataclass(frozen=True)
class SlotConfig:
    """Dimensions of one UL PUSCH slot (paper 4.1)."""

    n_prb: int = 106  # PRBs allocated for UL transmission
    n_ant: int = 4  # receive antenna ports (N_ant)
    n_layers: int = 1  # transmission layers (N_l)
    dmrs_symbols: tuple[int, ...] = (0, 5, 10)  # DMRS type-1, paper Fig. 6
    dmrs_comb_offset: int = 0  # comb-2: pilots on subcarriers 2k + offset
    scs_khz: int = 30  # subcarrier spacing -> 500 us slots

    @property
    def n_sc(self) -> int:
        """Total subcarriers N_sc = 12 * N_PRB."""
        return N_SC_PER_PRB * self.n_prb

    @property
    def n_sym(self) -> int:
        return N_SYM_PER_SLOT

    @property
    def n_dmrs_sym(self) -> int:
        """N_sym^DMRS (= 3 in the paper)."""
        return len(self.dmrs_symbols)

    @property
    def n_pilot_sc(self) -> int:
        """Comb-2 pilots: every other subcarrier."""
        return self.n_sc // 2

    @property
    def slot_duration_s(self) -> float:
        return 1e-3 / (self.scs_khz // 15)

    @property
    def pilot_sc_indices(self) -> np.ndarray:
        """Subcarrier indices carrying DMRS (comb-2 interleave)."""
        return np.arange(self.dmrs_comb_offset, self.n_sc, 2)

    @property
    def data_sc_indices(self) -> np.ndarray:
        """Subcarrier indices carrying PUSCH data on DMRS symbols."""
        return np.arange(1 - self.dmrs_comb_offset, self.n_sc, 2)

    @property
    def data_symbols(self) -> np.ndarray:
        """OFDM symbol indices carrying only data."""
        return np.asarray(
            [s for s in range(N_SYM_PER_SLOT) if s not in self.dmrs_symbols]
        )

    def n_data_re(self) -> int:
        """Resource elements available for PUSCH data in one slot/layer.

        Data symbols carry all subcarriers; DMRS symbols carry data on the
        other comb (interleaved frequency-domain CDM, paper Fig. 6).
        """
        full = (N_SYM_PER_SLOT - self.n_dmrs_sym) * self.n_sc
        on_dmrs = self.n_dmrs_sym * (self.n_sc - self.n_pilot_sc)
        return full + on_dmrs


DEFAULT_SLOT = SlotConfig()
