"""MCS table, TBS computation and link adaptation (TS 38.214 5.1.3).

Provides the PHY->MAC coupling that makes the paper's link-adaptation KPM
cluster (code rate, SINR, QAM order, MCS index, TB size, #CBs) move in
lockstep — exactly the redundancy structure Fig. 5a discovers.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# TS 38.214 Table 5.1.3.1-2 (MCS index table 2, 256QAM), entries 0..27:
# (modulation order Qm, target code rate x1024).  The 256QAM table is the
# X5G configuration; its higher ceiling (SE 7.4) keeps good-condition link
# adaptation un-saturated at the testbed operating point.
_MCS_TABLE: tuple[tuple[int, float], ...] = (
    (2, 120), (2, 193), (2, 308), (2, 449), (2, 602), (4, 378), (4, 434),
    (4, 490), (4, 553), (4, 616), (4, 658), (6, 466), (6, 517), (6, 567),
    (6, 616), (6, 666), (6, 719), (6, 772), (6, 822), (6, 873), (8, 682.5),
    (8, 711), (8, 754), (8, 797), (8, 841), (8, 885), (8, 916.5), (8, 948),
)

MAX_MCS = len(_MCS_TABLE) - 1
_CB_MAX_BITS = 8448  # LDPC base-graph-1 max code-block size


@dataclasses.dataclass(frozen=True)
class McsEntry:
    index: int
    qm: int  # modulation order (bits/symbol)
    code_rate: float  # info bits / coded bits

    @property
    def spectral_efficiency(self) -> float:
        return self.qm * self.code_rate


def mcs_entry(index: int) -> McsEntry:
    index = int(np.clip(index, 0, MAX_MCS))
    qm, r1024 = _MCS_TABLE[index]
    return McsEntry(index=index, qm=qm, code_rate=r1024 / 1024.0)


def transport_block_size(n_data_re: int, mcs: McsEntry, n_layers: int = 1) -> int:
    """Simplified TS 38.214 5.1.3.2 TBS (byte-aligned, CRC excluded)."""
    n_info = n_data_re * mcs.qm * mcs.code_rate * n_layers
    tbs = int(max(24, np.floor(n_info / 8.0) * 8 - 24))  # strip TB CRC24
    return tbs


def n_code_blocks(tbs_bits: int) -> int:
    """Code-block segmentation count (TS 38.212 5.2.2)."""
    b = tbs_bits + 24  # TB CRC
    if b <= _CB_MAX_BITS:
        return 1
    return int(np.ceil(b / (_CB_MAX_BITS - 24)))


# -- link adaptation ----------------------------------------------------------

# SNR (dB) thresholds at which each MCS reaches ~10% BLER (standard AWGN
# link curves, linearized: each MCS needs ~1 dB per 0.1 b/s/Hz efficiency).
def _snr_threshold_db(mcs: McsEntry) -> float:
    se = mcs.spectral_efficiency
    return float(10.0 * np.log10(2.0**se - 1.0) + 1.0)  # Shannon gap ~1 dB


SNR_THRESHOLDS_DB = np.asarray([_snr_threshold_db(mcs_entry(i)) for i in
                                range(MAX_MCS + 1)])


def select_mcs(snr_db: float, *, backoff_db: float = 1.0) -> McsEntry:
    """Outer-loop-free link adaptation: highest MCS whose threshold fits."""
    eligible = np.nonzero(SNR_THRESHOLDS_DB <= snr_db - backoff_db)[0]
    idx = int(eligible[-1]) if eligible.size else 0
    return mcs_entry(idx)


# -- device-side tables (batched scan engine) ---------------------------------
#
# The batched multi-UE slot engine keeps link adaptation on device: MCS
# selection and its derived quantities become table lookups indexed by a
# traced MCS index, so the whole slot loop compiles into one ``lax.scan``.

#: per-MCS modulation order / code rate as device-ready arrays, index-aligned
#: with ``mcs_entry``.
QM_BY_MCS = np.asarray([q for q, _ in _MCS_TABLE], np.int32)
RATE_BY_MCS = np.asarray([r / 1024.0 for _, r in _MCS_TABLE], np.float32)

#: supported modulation orders, index-aligned with ``qm_index_by_mcs``.
QM_VALUES = (2, 4, 6, 8)
QM_INDEX_BY_MCS = np.asarray(
    [QM_VALUES.index(q) for q, _ in _MCS_TABLE], np.int32
)


def tbs_table(n_data_re: int, n_layers: int = 1) -> np.ndarray:
    """Transport block size for every MCS index, (MAX_MCS+1,) int32.

    The TBS is a pure function of (n_data_re, MCS), so the batched engine
    precomputes it per slot config and looks it up with the traced index.
    """
    return np.asarray(
        [
            transport_block_size(n_data_re, mcs_entry(i), n_layers)
            for i in range(MAX_MCS + 1)
        ],
        np.int32,
    )


def n_code_blocks_table(n_data_re: int, n_layers: int = 1) -> np.ndarray:
    """Code-block count for every MCS index, (MAX_MCS+1,) int32."""
    return np.asarray(
        [int(n_code_blocks(int(t))) for t in tbs_table(n_data_re, n_layers)],
        np.int32,
    )


def select_mcs_index(snr_db: jax.Array, *, backoff_db: float = 1.0) -> jax.Array:
    """Traced link adaptation: elementwise device analogue of ``select_mcs``.

    ``SNR_THRESHOLDS_DB`` is monotonically increasing (the table's spectral
    efficiency is), so the highest eligible index is a threshold count.
    """
    th = jnp.asarray(SNR_THRESHOLDS_DB, jnp.float32)
    snr = jnp.asarray(snr_db, jnp.float32)
    n_eligible = jnp.sum(
        (th <= (snr[..., None] - backoff_db)).astype(jnp.int32), axis=-1
    )
    return jnp.maximum(n_eligible - 1, 0)
