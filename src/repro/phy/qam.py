"""Gray-coded QAM modulation and max-log LLR demapping (TS 38.211 5.1)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def _gray_pam_levels(bits_per_axis: int) -> np.ndarray:
    """Gray-mapped PAM levels indexed by the per-axis bit group."""
    m = 1 << bits_per_axis
    # natural-order levels: -(m-1), ..., (m-1) step 2
    levels = np.arange(-(m - 1), m, 2, dtype=np.float64)
    out = np.zeros(m)
    for code in range(m):
        gray = code ^ (code >> 1)
        out[code] = levels[gray]
    return out


_NORM = {2: np.sqrt(2.0), 4: np.sqrt(10.0), 6: np.sqrt(42.0), 8: np.sqrt(170.0)}


def constellation(qm: int) -> jax.Array:
    """All 2**qm points in bit-label order (MSB first, I bits then Q bits)."""
    half = qm // 2
    pam = _gray_pam_levels(half)
    pts = np.zeros(1 << qm, np.complex128)
    for label in range(1 << qm):
        i_bits = label >> half
        q_bits = label & ((1 << half) - 1)
        pts[label] = pam[i_bits] + 1j * pam[q_bits]
    return jnp.asarray(pts / _NORM[qm], jnp.complex64)


@partial(jax.jit, static_argnames=("qm",))
def modulate(bits: jax.Array, qm: int) -> jax.Array:
    """(..., n*qm) bits in {0,1} -> (..., n) unit-energy QAM symbols."""
    shape = bits.shape[:-1]
    groups = bits.reshape(shape + (-1, qm))
    weights = jnp.asarray([1 << (qm - 1 - i) for i in range(qm)], jnp.int32)
    labels = jnp.sum(groups.astype(jnp.int32) * weights, axis=-1)
    return jnp.take(constellation(qm), labels)


@partial(jax.jit, static_argnames=("qm",))
def demap_llr(y: jax.Array, noise_var: jax.Array, qm: int) -> jax.Array:
    """Max-log LLRs. ``y`` (..., n) equalized symbols -> (..., n*qm) LLRs.

    Positive LLR => bit 0 more likely (LLR = log P(b=0)/P(b=1)).
    """
    pts = constellation(qm)  # (M,)
    d2 = jnp.abs(y[..., None] - pts) ** 2  # (..., n, M)
    nv = jnp.maximum(jnp.asarray(noise_var), 1e-9)
    if nv.ndim:  # per-RE noise variance -> broadcast over constellation
        nv = nv[..., None]
    metric = -d2 / nv
    labels = np.arange(1 << qm)
    llrs = []
    for b in range(qm):
        bit = (labels >> (qm - 1 - b)) & 1
        m0 = jnp.max(jnp.where(jnp.asarray(bit == 0), metric, -jnp.inf), axis=-1)
        m1 = jnp.max(jnp.where(jnp.asarray(bit == 1), metric, -jnp.inf), axis=-1)
        llrs.append(m0 - m1)
    out = jnp.stack(llrs, axis=-1)  # (..., n, qm)
    return out.reshape(y.shape[:-1] + (-1,))


def hard_bits(llr: jax.Array) -> jax.Array:
    """LLR -> hard decisions (bit = 1 when LLR < 0)."""
    return (llr < 0).astype(jnp.uint8)


def _gray_inverse(bits_per_axis: int) -> np.ndarray:
    """Natural PAM-level index -> per-axis bit code (inverse Gray map)."""
    m = 1 << bits_per_axis
    inv = np.zeros(m, np.int32)
    for code in range(m):
        inv[code ^ (code >> 1)] = code
    return inv


@partial(jax.jit, static_argnames=("qm",))
def nearest_point(y: jax.Array, qm: int) -> jax.Array:
    """Nearest constellation point to each symbol in ``y``.

    Square Gray-mapped QAM factorizes: the closest point is the closest PAM
    level per I/Q axis, so this is O(1) per symbol instead of the O(2^qm)
    distance argmin — same point (up to measure-zero midpoint ties), gathered
    from the exact ``constellation`` table.  Used by the batched engine's
    decision-directed EVM, which evaluates every supported modulation order
    each slot.
    """
    half = qm // 2
    m = 1 << half
    pts = constellation(qm)
    inv = jnp.asarray(_gray_inverse(half))
    scaled = y * _NORM[qm]

    def level_idx(x):
        return jnp.clip(jnp.round((x + (m - 1)) / 2.0), 0, m - 1).astype(
            jnp.int32
        )

    code_i = jnp.take(inv, level_idx(jnp.real(scaled)))
    code_q = jnp.take(inv, level_idx(jnp.imag(scaled)))
    return jnp.take(pts, code_i * m + code_q)
