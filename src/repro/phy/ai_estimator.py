"""Expert B: residual-CNN channel estimator in pure JAX (paper 5.2).

Mirrors the architectures the paper cites for OFDM channel estimation
(residual CNNs treating the time-frequency response as a 2-D image, paper
refs [3, 17]): LS estimates at DMRS locations in, frequency-interpolated
full-band estimates out.  The TensorRT engine of the paper becomes a jitted
JAX apply function; training happens in-framework (``train_ai_estimator``)
on simulated OTA slots, per the build-everything rule (DESIGN.md 2).

Structure (per antenna, vmapped) — EDSR-style: signed regression, so blocks
keep linear outputs and the network predicts a *correction* on top of a
naive linear-interpolation baseline (global skip):
  input    (2, n_pilot_sc, n_dmrs_sym)    re/im as channels
  baseline naive comb-2 -> full-band linear interpolation of the LS input
  stem     3x3 conv -> C channels (linear)
  body     R residual blocks (conv-relu-conv + skip, linear output)
  upsample frequency x2 via sub-pixel shuffle
  head     3x3 conv -> 2 channels (linear)
  output   baseline + head                 (2, n_sc, n_dmrs_sym)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.phy.nr import SlotConfig


@dataclasses.dataclass(frozen=True)
class AiEstimatorConfig:
    channels: int = 32
    n_res_blocks: int = 4
    kernel_hw: tuple[int, int] = (3, 3)

    def flops(self, cfg: SlotConfig) -> float:
        """Conv MACs x2, all blocks, all antennas (cost-model input)."""
        kh, kw = self.kernel_hw
        hw_in = cfg.n_pilot_sc * cfg.n_dmrs_sym
        hw_out = cfg.n_sc * cfg.n_dmrs_sym
        c = self.channels
        per_ant = (
            2 * kh * kw * 2 * c * hw_in  # stem
            + self.n_res_blocks * 2 * (2 * kh * kw * c * c * hw_in)  # body
            + 2 * kh * kw * c * (2 * c) * hw_in  # up-projection
            + 2 * kh * kw * (2 * c) * 2 * hw_out  # head (on upsampled grid)
        )
        return float(cfg.n_ant * per_ant)


def _conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """NCHW 'same' conv. x (C,H,W), w (O,I,kh,kw), b (O,)."""
    y = jax.lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    return y + b[:, None, None]


def init_params(
    key: jax.Array, cfg: SlotConfig, net: AiEstimatorConfig = AiEstimatorConfig()
) -> dict[str, Any]:
    kh, kw = net.kernel_hw
    c = net.channels
    keys = jax.random.split(key, 3 + 2 * net.n_res_blocks)

    def he(k, o, i, scale=2.0):
        s = jnp.sqrt(scale / (i * kh * kw))
        return jax.random.normal(k, (o, i, kh, kw), jnp.float32) * s

    params = {
        "stem_w": he(keys[0], c, 2),
        "stem_b": jnp.zeros(c),
        "up_w": he(keys[1], 2 * c, c),  # sub-pixel: 2x along frequency
        "up_b": jnp.zeros(2 * c),
        # near-zero head so the net starts at the baseline interpolation
        "head_w": he(keys[2], 2, c, scale=1e-4),
        "head_b": jnp.zeros(2),
        "res": [],
    }
    for r in range(net.n_res_blocks):
        params["res"].append(
            {
                "w1": he(keys[3 + 2 * r], c, c),
                "b1": jnp.zeros(c),
                "w2": he(keys[4 + 2 * r], c, c, scale=0.2),
                "b2": jnp.zeros(c),
            }
        )
    return params


def _baseline_interp(x: jax.Array) -> jax.Array:
    """Naive comb-2 -> full-band interpolation, (2, Np, S) -> (2, 2*Np, S).

    Even output subcarriers take the pilot value; odd ones the midpoint of
    the two neighbouring pilots (edge clamped).
    """
    nxt = jnp.concatenate([x[:, 1:], x[:, -1:]], axis=1)
    mid = 0.5 * (x + nxt)
    out = jnp.stack([x, mid], axis=2)  # (2, Np, 2, S)
    return out.reshape(x.shape[0], 2 * x.shape[1], x.shape[2])


def _forward_one_antenna(params: dict[str, Any], x: jax.Array) -> jax.Array:
    """(2, n_pilot_sc, n_dmrs_sym) -> (2, n_sc, n_dmrs_sym)."""
    base = _baseline_interp(x)
    h = _conv(x, params["stem_w"], params["stem_b"])
    for blk in params["res"]:
        y = jax.nn.relu(_conv(h, blk["w1"], blk["b1"]))
        y = _conv(y, blk["w2"], blk["b2"])
        h = h + y  # linear block output (signed regression)
    # sub-pixel upsample x2 in frequency (comb-2 -> full band)
    u = _conv(h, params["up_w"], params["up_b"])  # (2C, Np, S)
    c = u.shape[0] // 2
    u = u.reshape(2, c, u.shape[1], u.shape[2])  # (2, C, Np, S)
    u = jnp.moveaxis(u, 0, 2).reshape(c, 2 * u.shape[2], u.shape[3])
    corr = _conv(u, params["head_w"], params["head_b"])
    return base + corr


@jax.jit
def ai_estimate_from_ls(params: dict[str, Any], h_ls: jax.Array) -> jax.Array:
    """(n_ant, n_dmrs_sym, n_pilot_sc) complex LS -> hat{H}_AI
    (n_ant, 1, n_sc, n_dmrs_sym) complex (same contract as Expert A)."""
    # to image layout (ant, 2, pilot_sc, dmrs_sym)
    x = jnp.stack([h_ls.real, h_ls.imag], axis=1).astype(jnp.float32)
    x = jnp.swapaxes(x, -1, -2)
    out = jax.vmap(_forward_one_antenna, in_axes=(None, 0))(params, x)
    h = (out[:, 0] + 1j * out[:, 1]).astype(jnp.complex64)  # (ant, n_sc, sym)
    return h[:, None]  # (ant, 1, n_sc, dmrs_sym)


# -- in-framework training ----------------------------------------------------


def _loss(params, h_ls, h_true):
    """Task-aligned loss: the estimator's post-MRC EVM contribution.

    Plain per-element MSE is the wrong objective for a receiver: the MRC
    combiner cancels estimation error *parallel* to the channel vector
    (num/den both scale) and is hurt by the component that rotates the
    combining direction.  First-order, the symbol error an estimate
    contributes at RE (sc, sym) is

        |sum_a conj(delta_a) h_a|^2 / (sum_a |h_a|^2)^2,

    so that is exactly what we train on, with a small plain-MSE anchor for
    early-training stability.
    """
    pred = ai_estimate_from_ls(params, h_ls)
    err = pred - h_true  # (ant, 1, sc, sym)
    # MRC-aligned term
    num = jnp.abs(jnp.sum(jnp.conj(err) * h_true, axis=0)) ** 2  # (1, sc, sym)
    den = jnp.sum(jnp.abs(h_true) ** 2, axis=0) + 1e-3
    e2e = jnp.mean(num / den**2)
    mse = jnp.mean(err.real**2 + err.imag**2)
    return e2e + 0.1 * mse


@partial(jax.jit, static_argnames=("opt_cfg",))
def _train_step(params, opt_state, h_ls, h_true, lr, opt_cfg):
    loss, grads = jax.value_and_grad(_loss)(params, h_ls, h_true)
    params, opt_state = adamw_update(
        grads, opt_state, params, opt_cfg, learning_rate=lr
    )
    return params, opt_state, loss


def train_ai_estimator(
    key: jax.Array,
    cfg: SlotConfig,
    sample_fn,
    *,
    net: AiEstimatorConfig = AiEstimatorConfig(),
    steps: int = 600,
    lr: float = 1e-3,
    lr_final_frac: float = 0.05,
) -> tuple[dict[str, Any], list[float]]:
    """Train Expert B on simulated slots (AdamW + cosine decay).

    ``sample_fn(key) -> (h_ls, h_true_at_dmrs)`` with shapes
    (n_ant, n_dmrs_sym, n_pilot_sc) and (n_ant, 1, n_sc, n_dmrs_sym).
    """
    k_init, k_data = jax.random.split(key)
    params = init_params(k_init, cfg, net)
    opt_cfg = AdamWConfig(learning_rate=lr, weight_decay=0.0)
    opt_state = adamw_init(params, opt_cfg)
    losses = []
    for i in range(steps):
        k_data, k = jax.random.split(k_data)
        h_ls, h_true = sample_fn(k)
        frac = i / max(steps - 1, 1)
        cur_lr = lr * (lr_final_frac + (1 - lr_final_frac) * 0.5 * (
            1 + np.cos(np.pi * frac)))
        params, opt_state, loss = _train_step(
            params, opt_state, h_ls, h_true, cur_lr, opt_cfg
        )
        losses.append(float(loss))
    return params, losses
