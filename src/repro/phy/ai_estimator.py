"""Expert B: residual-CNN channel estimator in pure JAX (paper 5.2).

Mirrors the architectures the paper cites for OFDM channel estimation
(residual CNNs treating the time-frequency response as a 2-D image, paper
refs [3, 17]): LS estimates at DMRS locations in, frequency-interpolated
full-band estimates out.  The TensorRT engine of the paper becomes a jitted
JAX apply function; training happens in-framework (``train_ai_estimator``)
on simulated OTA slots, per the build-everything rule (DESIGN.md 2).

Structure (per antenna, vmapped) — EDSR-style: signed regression, so blocks
keep linear outputs and the network predicts a *correction* on top of a
naive linear-interpolation baseline (global skip):
  input    (2, n_pilot_sc, n_dmrs_sym)    re/im as channels
  baseline naive comb-2 -> full-band linear interpolation of the LS input
  stem     3x3 conv -> C channels (linear)
  body     R residual blocks (conv-relu-conv + skip, linear output)
  upsample frequency x2 via sub-pixel shuffle
  head     3x3 conv -> 2 channels (linear)
  output   baseline + head                 (2, n_sc, n_dmrs_sym)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.phy.nr import SlotConfig


@dataclasses.dataclass(frozen=True)
class AiEstimatorConfig:
    channels: int = 32
    n_res_blocks: int = 4
    kernel_hw: tuple[int, int] = (3, 3)

    def flops(self, cfg: SlotConfig) -> float:
        """Conv MACs x2, all blocks, all antennas (cost-model input)."""
        kh, kw = self.kernel_hw
        hw_in = cfg.n_pilot_sc * cfg.n_dmrs_sym
        hw_out = cfg.n_sc * cfg.n_dmrs_sym
        c = self.channels
        per_ant = (
            2 * kh * kw * 2 * c * hw_in  # stem
            + self.n_res_blocks * 2 * (2 * kh * kw * c * c * hw_in)  # body
            + 2 * kh * kw * c * (2 * c) * hw_in  # up-projection
            + 2 * kh * kw * (2 * c) * 2 * hw_out  # head (on upsampled grid)
        )
        return float(cfg.n_ant * per_ant)


def _conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """NCHW 'same' conv. x (C,H,W), w (O,I,kh,kw), b (O,).

    Uses the native conv primitive: fastest for the *eager* single-image
    paths (host pipeline, training).  Do NOT call this inside a
    ``lax.scan`` body — XLA:CPU's fast conv thunk does not run inside loop
    bodies (~40x fallback); the batched scan engine uses the matmul-based
    ``_forward_batched`` instead.
    """
    y = jax.lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    return y + b[:, None, None]


def init_params(
    key: jax.Array, cfg: SlotConfig, net: AiEstimatorConfig = AiEstimatorConfig()
) -> dict[str, Any]:
    kh, kw = net.kernel_hw
    c = net.channels
    keys = jax.random.split(key, 3 + 2 * net.n_res_blocks)

    def he(k, o, i, scale=2.0):
        s = jnp.sqrt(scale / (i * kh * kw))
        return jax.random.normal(k, (o, i, kh, kw), jnp.float32) * s

    params = {
        "stem_w": he(keys[0], c, 2),
        "stem_b": jnp.zeros(c),
        "up_w": he(keys[1], 2 * c, c),  # sub-pixel: 2x along frequency
        "up_b": jnp.zeros(2 * c),
        # near-zero head so the net starts at the baseline interpolation
        "head_w": he(keys[2], 2, c, scale=1e-4),
        "head_b": jnp.zeros(2),
        "res": [],
    }
    for r in range(net.n_res_blocks):
        params["res"].append(
            {
                "w1": he(keys[3 + 2 * r], c, c),
                "b1": jnp.zeros(c),
                "w2": he(keys[4 + 2 * r], c, c, scale=0.2),
                "b2": jnp.zeros(c),
            }
        )
    return params


def _baseline_interp(x: jax.Array) -> jax.Array:
    """Naive comb-2 -> full-band interpolation, (..., Np, S) -> (..., 2*Np, S).

    Even output subcarriers take the pilot value; odd ones the midpoint of
    the two neighbouring pilots (edge clamped).  Leading dims (channels,
    batch) pass through.
    """
    nxt = jnp.concatenate([x[..., 1:, :], x[..., -1:, :]], axis=-2)
    mid = 0.5 * (x + nxt)
    out = jnp.stack([x, mid], axis=-2)  # (..., Np, 2, S)
    return out.reshape(*x.shape[:-2], 2 * x.shape[-2], x.shape[-1])


def _forward_one_antenna(params: dict[str, Any], x: jax.Array) -> jax.Array:
    """(2, n_pilot_sc, n_dmrs_sym) -> (2, n_sc, n_dmrs_sym)."""
    base = _baseline_interp(x)
    h = _conv(x, params["stem_w"], params["stem_b"])
    for blk in params["res"]:
        y = jax.nn.relu(_conv(h, blk["w1"], blk["b1"]))
        y = _conv(y, blk["w2"], blk["b2"])
        h = h + y  # linear block output (signed regression)
    # sub-pixel upsample x2 in frequency (comb-2 -> full band)
    u = _conv(h, params["up_w"], params["up_b"])  # (2C, Np, S)
    c = u.shape[0] // 2
    u = u.reshape(2, c, u.shape[1], u.shape[2])  # (2, C, Np, S)
    u = jnp.moveaxis(u, 0, 2).reshape(c, 2 * u.shape[2], u.shape[3])
    corr = _conv(u, params["head_w"], params["head_b"])
    return base + corr


@jax.jit
def ai_estimate_from_ls(params: dict[str, Any], h_ls: jax.Array) -> jax.Array:
    """(n_ant, n_dmrs_sym, n_pilot_sc) complex LS -> hat{H}_AI
    (n_ant, 1, n_sc, n_dmrs_sym) complex (same contract as Expert A)."""
    # to image layout (ant, 2, pilot_sc, dmrs_sym)
    x = jnp.stack([h_ls.real, h_ls.imag], axis=1).astype(jnp.float32)
    x = jnp.swapaxes(x, -1, -2)
    out = jax.vmap(_forward_one_antenna, in_axes=(None, 0))(params, x)
    h = (out[:, 0] + 1j * out[:, 1]).astype(jnp.complex64)  # (ant, n_sc, sym)
    return h[:, None]  # (ant, 1, n_sc, dmrs_sym)


# -- batched (multi-UE) forward -----------------------------------------------
#
# The batched slot engine evaluates the estimator for n_ues * n_ant images
# per slot inside a ``lax.scan`` body, where XLA:CPU's conv thunk doesn't
# run (~40x fallback) and vmapped small matmuls serialize.  The forward is
# therefore re-expressed as one large matmul per layer:
#
# * activations live in a channel-leading ``(C, W, B, H)`` layout, so the
#   flattening to matmul operands is reshape-only (no transposes between
#   layers);
# * the symbol axis ``W`` (= n_dmrs_sym, tiny) is folded into the mixing
#   matrix: a kh x kw conv becomes a kh-tap 1-D conv over frequency with
#   ``(O*W, C*W)`` tap matrices whose structure bakes in the W-direction
#   'SAME' padding.  Per layer that is kh shift-copies of the activation
#   (instead of kh*kw) and a single ``(O*W, kh*C*W) x (kh*C*W, B*H)``
#   contraction — identical math to the eager conv, BLAS/MXU-friendly
#   everywhere.
#
# Batch-composition stability (load-bearing for gated execution): every
# per-UE output column of these GEMMs is bitwise-identical regardless of
# the batch size B or the UE's position in it (the K-dim accumulation order
# is per-column).  The compaction-gated bank relies on this to be
# bitwise-equal to the concurrent path after gathering a capacity-K
# sub-batch; the gated==concurrent equality tests
# (tests/test_gated_execution.py) pin the property per backend.


def _wfold_matrices(w: jax.Array, width: int) -> jax.Array:
    """Fold the W axis of a conv kernel into tap-mixing matrices.

    ``w`` (O, C, kh, kw) -> (kh, O*width, C*width) where entry
    ``[d, o*width + wo, c*width + wi] = w[o, c, d, wi - wo + pad]``
    (zero outside the kernel — the W-direction 'SAME' padding).
    """
    o, c, kh, kw = w.shape
    pad = (kw - 1) // 2
    m = jnp.zeros((kh, o * width, c * width), w.dtype)
    for wo in range(width):
        for wi in range(width):
            dj = wi - wo + pad
            if 0 <= dj < kw:
                m = m.at[:, wo::width, wi::width].set(
                    jnp.transpose(w[:, :, :, dj], (2, 0, 1))
                )
    return m


def fold_ai_params(params: dict[str, Any], width: int) -> dict[str, Any]:
    """Pre-fold every conv kernel for width-``width`` images.

    Each layer becomes a single ``(O*width, kh*C*width)`` GEMM operand (tap
    matrices flattened tap-major to match the tap stacking in
    ``_conv_wfold``).  Done once per engine — inside the scan body only the
    GEMMs remain.
    """

    def fold(w):
        m = _wfold_matrices(w, width)  # (kh, O*W, C*W)
        kh = m.shape[0]
        return jnp.transpose(m, (1, 0, 2)).reshape(m.shape[1], kh * m.shape[2])

    return {
        "kh": int(params["stem_w"].shape[2]),
        "width": width,
        "stem_w": fold(params["stem_w"]),
        "stem_b": params["stem_b"],
        "up_w": fold(params["up_w"]),
        "up_b": params["up_b"],
        "head_w": fold(params["head_w"]),
        "head_b": params["head_b"],
        "res": [
            {
                "w1": fold(blk["w1"]),
                "b1": blk["b1"],
                "w2": fold(blk["w2"]),
                "b2": blk["b2"],
            }
            for blk in params["res"]
        ],
    }


def _conv_wfold(
    x: jax.Array,
    m2: jax.Array,
    b: jax.Array,
    kh: int,
    compute_dtype: Any = None,
) -> jax.Array:
    """'SAME' conv on channel-leading activations via one GEMM.

    ``x`` (C, W, B, H); ``m2`` (O*W, kh*C*W) pre-folded tap matrices.
    ``compute_dtype`` (e.g. ``jnp.bfloat16``) casts the GEMM *operands*
    only; accumulation stays f32 (``preferred_element_type``), as does the
    bias add — the MXU-style mixed-precision contract.  ``None`` keeps the
    original f32 ``@`` bitwise.
    """
    c, width, bsz, h = x.shape
    o = m2.shape[0] // width
    pad = (kh - 1) // 2
    xp = jnp.pad(
        x.reshape(c * width, bsz, h), ((0, 0), (0, 0), (pad, kh - 1 - pad))
    )
    taps = jnp.stack(
        [xp[:, :, d : d + h] for d in range(kh)], axis=0
    )  # (kh, C*W, B, H)
    rhs = taps.reshape(kh * c * width, bsz * h)
    if compute_dtype is None:
        y = m2 @ rhs  # (O*W, B*H)
    else:
        y = jax.lax.dot(
            m2.astype(compute_dtype),
            rhs.astype(compute_dtype),
            preferred_element_type=jnp.float32,
        )
    return y.reshape(o, width, bsz, h) + b[:, None, None, None]


def _forward_batched(
    folded: dict[str, Any], x: jax.Array, compute_dtype: Any = None
) -> jax.Array:
    """(2, W, B, n_pilot_sc) -> (2, W, B, n_sc), channel-leading layout."""
    kh = folded["kh"]
    # baseline comb-2 interpolation along the (trailing) frequency axis
    nxt = jnp.concatenate([x[..., 1:], x[..., -1:]], axis=-1)
    base = jnp.stack([x, 0.5 * (x + nxt)], axis=-1).reshape(
        *x.shape[:-1], 2 * x.shape[-1]
    )
    cd = compute_dtype
    h = _conv_wfold(x, folded["stem_w"], folded["stem_b"], kh, cd)
    for blk in folded["res"]:
        y = jax.nn.relu(_conv_wfold(h, blk["w1"], blk["b1"], kh, cd))
        y = _conv_wfold(y, blk["w2"], blk["b2"], kh, cd)
        h = h + y
    u = _conv_wfold(h, folded["up_w"], folded["up_b"], kh, cd)  # (2C,W,B,Np)
    c = u.shape[0] // 2
    u = u.reshape(2, c, *u.shape[1:])  # (2, C, W, B, Np)
    u = jnp.moveaxis(u, 0, -1).reshape(c, *u.shape[2:4], 2 * u.shape[4])
    corr = _conv_wfold(u, folded["head_w"], folded["head_b"], kh, cd)
    return base + corr


def ai_estimate_folded(
    folded: dict[str, Any],
    h_ls: jax.Array,
    *,
    compute_dtype: Any = None,
) -> jax.Array:
    """(n_ues, n_ant, n_dmrs_sym, n_pilot_sc) LS -> (n_ues, n_ant, 1, n_sc,
    n_dmrs_sym), with pre-folded params (see ``fold_ai_params``).

    ``compute_dtype=jnp.bfloat16`` runs every GEMM with bf16 operands and
    f32 accumulation (half the weight/activation bytes through the MXU);
    ``None`` is the bitwise f32 path.
    """
    n_ues, n_ant, n_sym, n_p = h_ls.shape
    x = jnp.stack([h_ls.real, h_ls.imag], axis=0).astype(jnp.float32)
    # (2, U, ant, S, Np) -> channel-leading (2, W=S, B=U*ant, H=Np)
    x = jnp.transpose(x, (0, 3, 1, 2, 4)).reshape(2, n_sym, n_ues * n_ant, n_p)
    out = _forward_batched(folded, x, compute_dtype)  # (2, S, B, n_sc)
    h = (out[0] + 1j * out[1]).astype(jnp.complex64)  # (S, B, n_sc)
    h = jnp.transpose(h, (1, 2, 0)).reshape(n_ues, n_ant, -1, n_sym)
    return h[:, :, None]  # (U, ant, 1, n_sc, S)


@jax.jit
def ai_estimate_from_ls_batched(
    params: dict[str, Any], h_ls: jax.Array
) -> jax.Array:
    """(n_ues, n_ant, n_dmrs_sym, n_pilot_sc) LS -> (n_ues, n_ant, 1, n_sc,
    n_dmrs_sym) — the multi-UE analogue of ``ai_estimate_from_ls``."""
    return ai_estimate_folded(fold_ai_params(params, h_ls.shape[2]), h_ls)


# -- in-framework training ----------------------------------------------------


def _loss(params, h_ls, h_true):
    """Task-aligned loss: the estimator's post-MRC EVM contribution.

    Plain per-element MSE is the wrong objective for a receiver: the MRC
    combiner cancels estimation error *parallel* to the channel vector
    (num/den both scale) and is hurt by the component that rotates the
    combining direction.  First-order, the symbol error an estimate
    contributes at RE (sc, sym) is

        |sum_a conj(delta_a) h_a|^2 / (sum_a |h_a|^2)^2,

    so that is exactly what we train on, with a small plain-MSE anchor for
    early-training stability.
    """
    pred = ai_estimate_from_ls(params, h_ls)
    err = pred - h_true  # (ant, 1, sc, sym)
    # MRC-aligned term
    num = jnp.abs(jnp.sum(jnp.conj(err) * h_true, axis=0)) ** 2  # (1, sc, sym)
    den = jnp.sum(jnp.abs(h_true) ** 2, axis=0) + 1e-3
    e2e = jnp.mean(num / den**2)
    mse = jnp.mean(err.real**2 + err.imag**2)
    return e2e + 0.1 * mse


@partial(jax.jit, static_argnames=("opt_cfg",))
def _train_step(params, opt_state, h_ls, h_true, lr, opt_cfg):
    loss, grads = jax.value_and_grad(_loss)(params, h_ls, h_true)
    params, opt_state = adamw_update(
        grads, opt_state, params, opt_cfg, learning_rate=lr
    )
    return params, opt_state, loss


def train_ai_estimator(
    key: jax.Array,
    cfg: SlotConfig,
    sample_fn,
    *,
    net: AiEstimatorConfig = AiEstimatorConfig(),
    steps: int = 600,
    lr: float = 1e-3,
    lr_final_frac: float = 0.05,
) -> tuple[dict[str, Any], list[float]]:
    """Train Expert B on simulated slots (AdamW + cosine decay).

    ``sample_fn(key) -> (h_ls, h_true_at_dmrs)`` with shapes
    (n_ant, n_dmrs_sym, n_pilot_sc) and (n_ant, 1, n_sc, n_dmrs_sym).
    """
    k_init, k_data = jax.random.split(key)
    params = init_params(k_init, cfg, net)
    opt_cfg = AdamWConfig(learning_rate=lr, weight_decay=0.0)
    opt_state = adamw_init(params, opt_cfg)
    losses = []
    for i in range(steps):
        k_data, k = jax.random.split(k_data)
        h_ls, h_true = sample_fn(k)
        frac = i / max(steps - 1, 1)
        cur_lr = lr * (lr_final_frac + (1 - lr_final_frac) * 0.5 * (
            1 + np.cos(np.pi * frac)))
        params, opt_state, loss = _train_step(
            params, opt_state, h_ls, h_true, cur_lr, opt_cfg
        )
        losses.append(float(loss))
    return params, losses
