"""DMRS generation and resource-grid mapping (paper 5.1, Fig. 6).

Type-1 DMRS with interleaved frequency-domain placement (comb-2) on OFDM
symbols {0, 5, 10}.  Sequences are QPSK symbols from a Gold-sequence
pseudo-random generator (TS 38.211 7.4.1.1 style, simplified init).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.phy.nr import SlotConfig


def _gold_sequence(c_init: int, length: int) -> np.ndarray:
    """TS 38.211 5.2.1 length-31 Gold sequence (host-side, cached)."""
    nc = 1600
    x1 = np.zeros(nc + length + 31, np.int8)
    x2 = np.zeros(nc + length + 31, np.int8)
    x1[0] = 1
    for i in range(31):
        x2[i] = (c_init >> i) & 1
    for n in range(len(x1) - 31):
        x1[n + 31] = (x1[n + 3] + x1[n]) % 2
        x2[n + 31] = (x2[n + 3] + x2[n + 2] + x2[n + 1] + x2[n]) % 2
    return ((x1[nc : nc + length] + x2[nc : nc + length]) % 2).astype(np.int8)


def dmrs_sequence(cfg: SlotConfig, *, slot: int = 0, cell_id: int = 42) -> jax.Array:
    """QPSK DMRS symbols, (n_dmrs_sym, n_pilot_sc) complex64."""
    seqs = []
    for sym in cfg.dmrs_symbols:
        c_init = ((14 * slot + sym + 1) * (2 * cell_id + 1) * 2**17 + 2 * cell_id) % (
            2**31
        )
        bits = _gold_sequence(int(c_init), 2 * cfg.n_pilot_sc).astype(np.float32)
        re = (1.0 - 2.0 * bits[0::2]) / np.sqrt(2.0)
        im = (1.0 - 2.0 * bits[1::2]) / np.sqrt(2.0)
        seqs.append(re + 1j * im)
    return jnp.asarray(np.stack(seqs), jnp.complex64)


@partial(jax.jit, static_argnames=("cfg",))
def map_slot_grid(
    cfg: SlotConfig, data_symbols: jax.Array, pilots: jax.Array
) -> jax.Array:
    """Assemble the TX resource grid (n_layers, n_sc, n_sym).

    ``data_symbols`` is a flat (n_data_re,) complex vector in grid scan
    order; ``pilots`` is (n_dmrs_sym, n_pilot_sc).
    """
    grid = jnp.zeros((cfg.n_layers, cfg.n_sc, cfg.n_sym), jnp.complex64)
    # data placement mask (True where PUSCH data lives)
    mask = np.ones((cfg.n_sc, cfg.n_sym), bool)
    for i, sym in enumerate(cfg.dmrs_symbols):
        mask[cfg.pilot_sc_indices, sym] = False
    mask_j = jnp.asarray(mask)
    flat_idx = jnp.cumsum(mask_j.reshape(-1).astype(jnp.int32)) - 1
    data_grid = jnp.where(
        mask_j.reshape(-1),
        jnp.take(data_symbols, jnp.clip(flat_idx, 0, data_symbols.shape[0] - 1)),
        0.0,
    ).reshape(cfg.n_sc, cfg.n_sym)
    grid = grid.at[0].set(data_grid)
    for i, sym in enumerate(cfg.dmrs_symbols):
        grid = grid.at[0, jnp.asarray(cfg.pilot_sc_indices), sym].set(pilots[i])
    return grid


def extract_data_re(cfg: SlotConfig, grid: jax.Array) -> jax.Array:
    """Inverse of the data mapping: (..., n_sc, n_sym) -> (..., n_data_re)."""
    mask = np.ones((cfg.n_sc, cfg.n_sym), bool)
    for sym in cfg.dmrs_symbols:
        mask[cfg.pilot_sc_indices, sym] = False
    flat = grid.reshape(grid.shape[:-2] + (-1,))
    idx = jnp.asarray(np.nonzero(mask.reshape(-1))[0])
    return jnp.take(flat, idx, axis=-1)


def extract_pilot_re(cfg: SlotConfig, grid: jax.Array) -> jax.Array:
    """RX samples at DMRS REs: (..., n_sc, n_sym) -> (..., n_dmrs_sym, n_pilot_sc)."""
    cols = []
    pilot_idx = jnp.asarray(cfg.pilot_sc_indices)
    for sym in cfg.dmrs_symbols:
        cols.append(jnp.take(grid[..., sym], pilot_idx, axis=-1))
    return jnp.stack(cols, axis=-2)
