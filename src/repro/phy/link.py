"""Link-level abstraction: effective SNR, BLER, TB CRC, throughput (paper 6).

PHY throughput in Aerial is computed from successfully decoded transport
blocks based on TB CRC checks (paper 6.1 *Data Integrity*).  We reproduce
that bit-for-bit where feasible and information-theoretically where not:

* the demapper produces real max-log LLRs and we count hard-decision bit
  errors (exact, used by the tests);
* TB success is decided by a mean-mutual-information (MIESM-style) outage
  model — the TB decodes iff the per-RE mutual information averaged over the
  allocation exceeds the MCS code rate (plus a small implementation margin).
  This is the standard L1 system-simulation abstraction for LDPC, which the
  paper does not contribute to (DESIGN.md 2).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.phy.mcs import McsEntry, n_code_blocks


def qam_mutual_information_dynamic(sinr: jax.Array, qm: jax.Array) -> jax.Array:
    """Per-RE mutual information (bits/symbol) for 2^qm-QAM, traced ``qm``.

    Capped-capacity MIESM form: MI = softmin(qm, log2(1 + snr / gamma)) with
    a ~1 dB SNR gap (gamma) to capacity for practical QAM + LDPC.  Unlike
    exponential-saturation fits, this keeps the high-SNR region honest: at
    17 dB a 256QAM symbol carries ~4.4 bits, not 8 — which is what lets
    sub-dB estimator-quality differences surface in link adaptation.

    ``qm`` may be a traced device value (the batched scan engine selects
    the MCS on device); the static-``qm`` wrappers below delegate here so
    the model constants live in exactly one place.
    """
    gamma = 1.25
    cap = jnp.log2(1.0 + sinr / gamma)
    beta = 3.0  # softmin sharpness (smooth saturation at qm)
    return -jnp.logaddexp(-beta * cap, -beta * jnp.asarray(qm, jnp.float32)) / beta


def effective_mi_dynamic(sinr_data: jax.Array, qm: jax.Array) -> jax.Array:
    """Mean MI per symbol over the data allocation -> effective code rate."""
    qm_f = jnp.asarray(qm, jnp.float32)
    return jnp.mean(qam_mutual_information_dynamic(sinr_data, qm_f)) / qm_f


def tb_success_dynamic(
    sinr_data: jax.Array,
    qm: jax.Array,
    code_rate: jax.Array,
    *,
    margin: float = 0.05,
    key: jax.Array | None = None,
) -> jax.Array:
    """TB CRC outcome under the MIESM outage model (bool scalar).

    With ``key`` given, adds a smooth success probability around the
    threshold (logistic in the MI margin) so BLER curves are not a hard
    step — mirrors code-block diversity in real LDPC.
    """
    mi = effective_mi_dynamic(sinr_data, qm)
    margin_mi = mi - (code_rate + margin)
    if key is None:
        return margin_mi > 0
    p_success = jax.nn.sigmoid(margin_mi * 80.0)
    return jax.random.uniform(key, ()) < p_success


def qam_mutual_information(sinr: jax.Array, qm: int) -> jax.Array:
    """Static-``qm`` convenience wrapper over the dynamic MIESM form."""
    return qam_mutual_information_dynamic(sinr, float(qm))


@partial(jax.jit, static_argnames=("qm",))
def effective_mi(sinr_data: jax.Array, qm: int) -> jax.Array:
    """Mean MI per symbol over the data allocation -> effective code rate."""
    return effective_mi_dynamic(sinr_data, float(qm))


def tb_success(
    sinr_data: jax.Array,
    mcs: McsEntry,
    *,
    margin: float = 0.05,
    key: jax.Array | None = None,
) -> jax.Array:
    """``tb_success_dynamic`` with the (qm, code rate) of a static MCS entry."""
    return tb_success_dynamic(
        sinr_data, float(mcs.qm), mcs.code_rate, margin=margin, key=key
    )


def throughput_bits(
    tbs_bits: int, success: jax.Array, slot_duration_s: float
) -> jax.Array:
    """Delivered PHY throughput for one slot, in bit/s."""
    return jnp.where(success, tbs_bits / slot_duration_s, 0.0)


def count_bit_errors(tx_bits: jax.Array, llr: jax.Array) -> jax.Array:
    """Exact hard-decision bit errors over the TB (test/telemetry path)."""
    rx = (llr < 0).astype(tx_bits.dtype)
    return jnp.sum(tx_bits != rx)


def crc24(bits: np.ndarray) -> int:
    """CRC-24A (TS 38.212) over a host-side bit array — integrity checks."""
    poly = 0x1864CFB
    reg = 0
    for b in np.asarray(bits, np.uint8):
        reg = ((reg << 1) | int(b)) & 0xFFFFFF
        if (reg >> 23) & 1:
            reg ^= poly & 0xFFFFFF
    return reg
