"""OTA experiment scenarios (paper 6, Figs. 7/9).

``good``  — LOS, no interference (paper: UE1->gNB1 clean).
``poor``  — same link + frequency-selective in-band UL interference from the
            neighbouring UE2->gNB2 pair (PRB-allocation controlled).

``good_poor_good_schedule`` reproduces the Fig. 9 time series: channel
conditions transition good -> poor -> good at configurable slot boundaries.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.phy.channel import INDOOR_LOS, INDOOR_NLOS, ChannelConfig

# Operating point chosen so link adaptation sits in the paper's regime
# (median MCS ~19-20 good / ~11-12 poor, Fig. 10b) rather than saturating at
# the table top, where estimator quality cannot show up in throughput.
GOOD = ChannelConfig(profile=INDOOR_LOS, snr_db=8.0, interference=False)
# Frame-aligned neighbour-cell UL: its DMRS collides with ours (pilot
# contamination), so interference corrupts channel *estimation* first and
# data REs second — the regime where expert choice matters most (paper 6.2).
POOR = ChannelConfig(
    profile=INDOOR_LOS,
    snr_db=8.0,
    interference=True,
    inr_db=18.0,
    interference_prb_frac=0.5,
    interference_symbol_duty=3.0 / 14.0,  # DMRS symbols only
    dmrs_collision=True,
)


def constant_schedule(cfg: ChannelConfig) -> Callable[[int], ChannelConfig]:
    return lambda slot: cfg


def good_poor_good_schedule(
    *, poor_start: int = 100, poor_end: int = 200
) -> Callable[[int], ChannelConfig]:
    """Fig. 9: good -> poor -> good transitions at slot boundaries."""

    def schedule(slot: int) -> ChannelConfig:
        return POOR if poor_start <= slot < poor_end else GOOD

    return schedule


def condition_label(slot: int, *, poor_start: int = 100, poor_end: int = 200) -> int:
    """Supervisory label for policy training (paper 5.3): interference
    present -> mode=0 (AI), otherwise mode=1 (MMSE)."""
    return 0 if poor_start <= slot < poor_end else 1
