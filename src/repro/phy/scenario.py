"""OTA experiment scenarios (paper 6, Figs. 7/9) + the scenario registry.

The paper's two OTA operating points are ``good`` (LOS, no interference)
and ``poor`` (same link + frequency-selective in-band UL interference from
the neighbouring UE2->gNB2 pair, PRB-allocation controlled).  Everything a
campaign can run is expressed as a *schedule* — ``schedule(slot) ->
ChannelConfig`` — so conditions may change per slot while the TDL profile
stays static (the traced-channel contract of
``repro.phy.channel.channel_params_schedule``).

**Scenario registry.**  Named scenarios are registered with
``register_scenario`` and looked up with ``get_scenario`` /
``make_schedule``; ``CampaignSpec`` (``repro.core.session``) references them
by name so a campaign's channel conditions serialize as a string + kwargs.
Registered entries:

* ``good`` / ``poor`` — constant single-condition schedules.
* ``good_poor_good`` — the Fig. 9 time series (good -> poor -> good at
  configurable slot boundaries).
* ``bursty_interference`` — periodic interference bursts (on for
  ``burst_slots`` out of every ``period``), the TDM-scheduled neighbour.
* ``snr_ramp`` — triangle sweep of the thermal SNR between ``snr_hi_db``
  and ``snr_lo_db`` (no interference): exercises link adaptation across
  the whole MCS table.
* ``mixed_cell`` — **per-UE heterogeneous**: UE ``u`` cycles through
  {good, good_poor_good, bursty_interference}, so one cell carries clean,
  phase-transition and bursty users simultaneously.  Per-UE scenarios
  return one schedule per UE; the batched engine stacks them into
  ``ChannelParams`` with a ``(n_slots, n_ues)`` leading shape
  (``scenario_params``).
* ``multi_cell`` — **per-cell composition**: ``n_cells`` cells, cell ``c``
  running the named registered scenario ``per_cell_scenario[c]`` on all of
  its member UEs (contiguous equal slices of the UE axis, the same layout
  ``repro.core.topology`` shards across devices).

All registered scenarios share the ``INDOOR_LOS`` profile, so any mix of
them is device-traceable in one scan (including per-UE mixes).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from repro.phy.channel import INDOOR_LOS, INDOOR_NLOS, ChannelConfig

# Operating point chosen so link adaptation sits in the paper's regime
# (median MCS ~19-20 good / ~11-12 poor, Fig. 10b) rather than saturating at
# the table top, where estimator quality cannot show up in throughput.
GOOD = ChannelConfig(profile=INDOOR_LOS, snr_db=8.0, interference=False)
# Frame-aligned neighbour-cell UL: its DMRS collides with ours (pilot
# contamination), so interference corrupts channel *estimation* first and
# data REs second — the regime where expert choice matters most (paper 6.2).
POOR = ChannelConfig(
    profile=INDOOR_LOS,
    snr_db=8.0,
    interference=True,
    inr_db=18.0,
    interference_prb_frac=0.5,
    interference_symbol_duty=3.0 / 14.0,  # DMRS symbols only
    dmrs_collision=True,
)


@dataclasses.dataclass(frozen=True)
class PoorWindow:
    """The Fig. 9 interference window: poor conditions on ``[start, end)``.

    Single source of truth for the window boundaries shared by
    ``good_poor_good_schedule`` and ``condition_label`` (previously the
    100/200 literals were copy-pasted in both and could drift).
    """

    start: int = 100
    end: int = 200

    def __contains__(self, slot: int) -> bool:
        return self.start <= slot < self.end


#: Default Fig. 9 window (slots 100..200 poor).
POOR_WINDOW = PoorWindow()


def constant_schedule(cfg: ChannelConfig) -> Callable[[int], ChannelConfig]:
    return lambda slot: cfg


def good_poor_good_schedule(
    *, poor_start: int = POOR_WINDOW.start, poor_end: int = POOR_WINDOW.end
) -> Callable[[int], ChannelConfig]:
    """Fig. 9: good -> poor -> good transitions at slot boundaries."""
    window = PoorWindow(poor_start, poor_end)

    def schedule(slot: int) -> ChannelConfig:
        return POOR if slot in window else GOOD

    return schedule


def condition_label(
    slot: int,
    *,
    poor_start: int = POOR_WINDOW.start,
    poor_end: int = POOR_WINDOW.end,
) -> int:
    """Supervisory label for policy training (paper 5.3): interference
    present -> mode=0 (AI), otherwise mode=1 (MMSE)."""
    return 0 if slot in PoorWindow(poor_start, poor_end) else 1


def bursty_interference_schedule(
    *, period: int = 40, burst_slots: int = 10, offset: int = 0
) -> Callable[[int], ChannelConfig]:
    """Periodic interference bursts: poor for the first ``burst_slots`` of
    every ``period``-slot cycle (phase-shifted by ``offset``)."""
    if period < 1:
        raise ValueError(f"period {period} must be >= 1")
    if not 0 <= burst_slots <= period:
        raise ValueError(f"burst_slots {burst_slots} outside [0, {period}]")

    def schedule(slot: int) -> ChannelConfig:
        return POOR if (slot + offset) % period < burst_slots else GOOD

    return schedule


def snr_ramp_schedule(
    *, snr_hi_db: float = 14.0, snr_lo_db: float = 2.0, period: int = 60
) -> Callable[[int], ChannelConfig]:
    """Triangle SNR sweep hi -> lo -> hi over ``period`` slots, no
    interference — drives link adaptation across the MCS table."""
    if period < 1:
        raise ValueError(f"period {period} must be >= 1")
    half = period / 2.0

    def schedule(slot: int) -> ChannelConfig:
        phase = slot % period
        frac = phase / half if phase < half else (period - phase) / half
        snr = snr_hi_db + (snr_lo_db - snr_hi_db) * frac
        return dataclasses.replace(GOOD, snr_db=float(snr))

    return schedule


# -- scenario registry ---------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, parameterizable campaign scenario.

    ``factory(**kwargs)`` returns ``schedule(slot) -> ChannelConfig``; with
    ``per_ue=True`` the factory additionally takes ``n_ues`` and returns one
    schedule per UE (a list) — the heterogeneous-cell case.
    """

    name: str
    factory: Callable[..., object]
    per_ue: bool = False
    description: str = ""

    def schedule(self, *, n_ues: int | None = None, **kwargs):
        """Instantiate: one slot schedule, or ``n_ues`` of them (per-UE)."""
        if self.per_ue:
            if n_ues is None:
                raise ValueError(
                    f"scenario {self.name!r} is per-UE: pass n_ues"
                )
            schedules = list(self.factory(n_ues=n_ues, **kwargs))
            if len(schedules) != n_ues:
                raise ValueError(
                    f"scenario {self.name!r} produced {len(schedules)} "
                    f"schedules for n_ues={n_ues}"
                )
            return schedules
        return self.factory(**kwargs)


_SCENARIOS: dict[str, Scenario] = {}


def register_scenario(
    name: str,
    factory: Callable[..., object],
    *,
    per_ue: bool = False,
    description: str = "",
    overwrite: bool = False,
) -> Scenario:
    """Register a named scenario; returns the registry entry."""
    if name in _SCENARIOS and not overwrite:
        raise ValueError(f"scenario {name!r} already registered")
    sc = Scenario(
        name=name, factory=factory, per_ue=per_ue, description=description
    )
    _SCENARIOS[name] = sc
    return sc


def get_scenario(name: str) -> Scenario:
    try:
        return _SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; registered: {scenario_names()}"
        ) from None


def scenario_names() -> tuple[str, ...]:
    return tuple(sorted(_SCENARIOS))


def make_schedule(
    name: str, *, n_ues: int | None = None, **kwargs
):
    """Resolve a registered scenario to its slot schedule(s)."""
    return get_scenario(name).schedule(n_ues=n_ues, **kwargs)


def scenario_params(
    cfg, name: str, *, n_slots: int, n_ues: int | None = None, **kwargs
):
    """Registry lookup straight to device-traceable ``ChannelParams``.

    Returns ``(profile, params)`` ready for the batched engine's scan:
    leaves are ``(n_slots, ...)`` for homogeneous scenarios and
    ``(n_slots, n_ues, ...)`` for per-UE ones.
    """
    from repro.phy.channel import (
        channel_params_schedule,
        channel_params_ue_schedule,
    )

    sched = make_schedule(name, n_ues=n_ues, **kwargs)
    if isinstance(sched, (list, tuple)):
        return channel_params_ue_schedule(cfg, sched, n_slots)
    return channel_params_schedule(cfg, sched, n_slots)


def _mixed_cell(
    n_ues: int,
    *,
    poor_start: int = 5,
    poor_end: int = 15,
    period: int = 12,
    burst_slots: int = 4,
) -> list:
    """Heterogeneous cell: UE u cycles {good, good_poor_good, bursty}."""
    bases = (
        constant_schedule(GOOD),
        good_poor_good_schedule(poor_start=poor_start, poor_end=poor_end),
        bursty_interference_schedule(period=period, burst_slots=burst_slots),
    )
    return [bases[u % len(bases)] for u in range(n_ues)]


def _multi_cell(
    n_ues: int,
    *,
    n_cells: int = 2,
    per_cell_scenario: Sequence[str] = ("good", "poor"),
) -> list:
    """Multi-cell campaign: cell ``c`` runs a named registered scenario.

    Composes *existing* registry entries per cell: ``per_cell_scenario``
    names one homogeneous scenario per cell (cycled when shorter than
    ``n_cells``), and every member UE of a cell follows its cell's
    schedule.  The cell layout matches ``repro.core.topology``: UE ``u``
    belongs to cell ``u // (n_ues / n_cells)``.  Referenced entries must be
    homogeneous (a per-UE entry has no single per-cell condition stream).
    """
    if n_cells < 1:
        raise ValueError(f"n_cells {n_cells} must be >= 1")
    if n_ues % n_cells:
        raise ValueError(
            f"n_cells={n_cells} does not divide n_ues={n_ues}: cells "
            "partition the UE axis into equal sub-batches"
        )
    names = tuple(per_cell_scenario)
    if not names:
        raise ValueError("per_cell_scenario names at least one scenario")
    cell_schedules = []
    for c in range(n_cells):
        sc = get_scenario(names[c % len(names)])  # unknown name -> KeyError
        if sc.per_ue:
            raise ValueError(
                f"per_cell_scenario entry {sc.name!r} is per-UE; each cell "
                "needs one homogeneous condition stream"
            )
        cell_schedules.append(sc.schedule())
    ues_per_cell = n_ues // n_cells
    return [cell_schedules[u // ues_per_cell] for u in range(n_ues)]


def _churn_cell(
    n_ues: int,
    *,
    period: int = 12,
    burst_slots: int = 4,
    stagger: int = 3,
) -> list:
    """Churn-campaign cell: phase-staggered bursty interference per UE id.

    Every UE id gets the same periodic interference stream shifted by
    ``(id * stagger) % period`` slots, so each *stable identity* carries a
    distinct, id-tied condition trajectory.  Built for streaming
    campaigns: a UE re-packed into a different bank slot keeps its own
    burst phase, which is exactly what the re-pack-invariance property
    tests need to distinguish identity-keyed conditions from
    slot-keyed ones.
    """
    return [
        bursty_interference_schedule(
            period=period,
            burst_slots=burst_slots,
            offset=(u * stagger) % period,
        )
        for u in range(n_ues)
    ]


register_scenario(
    "good", lambda: constant_schedule(GOOD),
    description="LOS, no interference (paper: UE1->gNB1 clean)",
)
register_scenario(
    "poor", lambda: constant_schedule(POOR),
    description="in-band neighbour-cell UL interference, DMRS collision",
)
register_scenario(
    "good_poor_good", good_poor_good_schedule,
    description="Fig. 9 time series: good -> poor -> good",
)
register_scenario(
    "bursty_interference", bursty_interference_schedule,
    description="periodic interference bursts (TDM neighbour traffic)",
)
register_scenario(
    "snr_ramp", snr_ramp_schedule,
    description="triangle thermal-SNR sweep, no interference",
)
register_scenario(
    "mixed_cell", _mixed_cell, per_ue=True,
    description="per-UE heterogeneous: good / good_poor_good / bursty mix",
)
register_scenario(
    "multi_cell", _multi_cell, per_ue=True,
    description="n_cells cells, each running a named registered scenario",
)
register_scenario(
    "churn_cell", _churn_cell, per_ue=True,
    description="per-id phase-staggered interference bursts (streaming)",
)
