"""Pluggable telemetry exporters + the pump that feeds them off the ring.

``Exporter`` is the northbound telemetry interface: batches of plain-dict
segment samples, at-least-once per drain, strictly ordered.  The in-tree
``JsonlExporter`` appends one JSON object per line.  ``ExportPump`` is a
daemon thread with its own ring cursor: it polls ``TelemetryRing.drain``
and hands batches to every exporter — exporter exceptions are counted
(``export_errors``) and swallowed, and ring drops are accumulated
(``dropped``), so a slow or broken exporter degrades to counted loss and
can never stall the dispatch loop.
"""

from __future__ import annotations

import json
import threading

from repro.service.ring import TelemetryRing


class Exporter:
    """Interface for telemetry sinks consumed by ``ExportPump``."""

    def export(self, samples: list) -> None:
        """Deliver a batch of samples (dicts), oldest first."""
        raise NotImplementedError

    def close(self) -> None:
        """Flush and release resources; called once at pump shutdown."""


class JsonlExporter(Exporter):
    """Append-only JSON-lines file sink (one sample object per line)."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "a")

    def export(self, samples: list) -> None:
        for s in samples:
            self._f.write(json.dumps(s, sort_keys=True) + "\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()


class ExportPump(threading.Thread):
    """Daemon thread draining the ring into a set of exporters.

    Counters (all monotonic): ``exported`` samples handed to exporters,
    ``dropped`` samples the ring overwrote before this pump drained them
    (exact, from ``TelemetryRing.drain``), ``export_errors`` exporter
    ``export()`` calls that raised.
    """

    def __init__(
        self,
        ring: TelemetryRing,
        exporters: list,
        *,
        poll_interval: float = 0.05,
    ):
        super().__init__(name="telemetry-export-pump", daemon=True)
        self.ring = ring
        self.exporters = list(exporters)
        self.poll_interval = poll_interval
        self.exported = 0
        self.dropped = 0
        self.export_errors = 0
        self._cursor = 0
        self._stop_event = threading.Event()

    def run(self) -> None:
        while not self._stop_event.wait(self.poll_interval):
            self.pump_once()
        # final flush: everything still in the ring goes out before close
        self.pump_once()
        for ex in self.exporters:
            try:
                ex.close()
            except Exception:
                self.export_errors += 1

    def pump_once(self) -> int:
        """One drain-and-export cycle; returns samples delivered."""
        samples, self._cursor, dropped = self.ring.drain(self._cursor)
        self.dropped += dropped
        if samples:
            for ex in self.exporters:
                try:
                    ex.export(samples)
                except Exception:
                    self.export_errors += 1
            self.exported += len(samples)
        return len(samples)

    def stop(self, timeout: float | None = 5.0) -> None:
        """Signal shutdown and wait for the final flush."""
        self._stop_event.set()
        if self.is_alive():
            self.join(timeout)

    def counters(self) -> dict:
        return {
            "exported": self.exported,
            "dropped": self.dropped,
            "export_errors": self.export_errors,
        }
