"""Northbound status/control API — stdlib ``http.server``, JSON in/out.

Routes (all JSON bodies/responses):

* ``GET  /health``                  — service health + telemetry counters
* ``GET  /campaigns``               — list campaigns (submission order)
* ``POST /campaigns``               — submit a ``CampaignSpec`` (its JSON
  form); 201 + ``{"campaign_id": ...}``; 400 invalid spec, 503 when the
  queue is saturated or the service is draining
* ``GET  /campaigns/<id>``          — per-campaign status: state, segment
  progress, spec_hash provenance, checkpoint lineage; 404 unknown
* ``POST /campaigns/<id>/cancel``   — cancel (queued: immediate; running:
  next segment boundary); 404 unknown
* ``GET  /telemetry?n=K``           — the most recent K ring samples
* ``POST /drain``                   — begin graceful drain

``ThreadingHTTPServer`` keeps slow clients off the dispatch loop; every
handler only touches the service's lock-guarded views, never the workers.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

from repro.service.service import (
    CampaignService,
    ServiceDrainingError,
    ServiceSaturatedError,
    UnknownCampaignError,
)


class _Handler(BaseHTTPRequestHandler):
    service: CampaignService  # bound by ServiceAPI via a subclass attribute

    # -- plumbing --------------------------------------------------------------

    def log_message(self, fmt, *args):  # silent: the service is the log
        pass

    def _send(self, code: int, payload) -> None:
        body = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _body(self):
        n = int(self.headers.get("Content-Length") or 0)
        raw = self.rfile.read(n) if n else b""
        return json.loads(raw.decode() or "null")

    # -- routes ----------------------------------------------------------------

    def do_GET(self):
        url = urlparse(self.path)
        parts = [p for p in url.path.split("/") if p]
        try:
            if parts == ["health"]:
                return self._send(200, self.service.health())
            if parts == ["campaigns"]:
                return self._send(200, self.service.list_campaigns())
            if len(parts) == 2 and parts[0] == "campaigns":
                return self._send(200, self.service.status(parts[1]))
            if parts == ["telemetry"]:
                q = parse_qs(url.query)
                try:
                    n = int(q["n"][0]) if "n" in q else None
                except ValueError:
                    return self._send(
                        400, {"error": "n must be an integer"}
                    )
                return self._send(200, self.service.ring.snapshot(n))
            self._send(404, {"error": f"no route {url.path!r}"})
        except UnknownCampaignError as e:
            self._send(404, {"error": f"unknown campaign {e.args[0]!r}"})

    def do_POST(self):
        url = urlparse(self.path)
        parts = [p for p in url.path.split("/") if p]
        try:
            if parts == ["campaigns"]:
                cid = self.service.submit(self._body())
                return self._send(201, {"campaign_id": cid})
            if (
                len(parts) == 3
                and parts[0] == "campaigns"
                and parts[2] == "cancel"
            ):
                state = self.service.cancel(parts[1])
                return self._send(
                    200, {"campaign_id": parts[1], "state": state}
                )
            if parts == ["drain"]:
                self.service.request_drain()
                return self._send(202, {"draining": True})
            self._send(404, {"error": f"no route {url.path!r}"})
        except UnknownCampaignError as e:
            self._send(404, {"error": f"unknown campaign {e.args[0]!r}"})
        except (ServiceSaturatedError, ServiceDrainingError) as e:
            self._send(503, {"error": str(e)})
        except (ValueError, TypeError, KeyError) as e:
            self._send(400, {"error": f"invalid campaign spec: {e}"})


class ServiceAPI:
    """Bind a ``CampaignService`` to an HTTP endpoint.

    ``port=0`` binds an ephemeral port (read it back from ``.port`` — the
    test/benchmark pattern).  The server runs on a daemon thread;
    ``stop()`` shuts it down without touching the service itself.
    """

    def __init__(
        self,
        service: CampaignService,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
    ):
        handler = type("_BoundHandler", (_Handler,), {"service": service})
        self.service = service
        self.server = ThreadingHTTPServer((host, port), handler)
        self.server.daemon_threads = True
        self._thread: threading.Thread | None = None

    @property
    def host(self) -> str:
        return self.server.server_address[0]

    @property
    def port(self) -> int:
        return self.server.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ServiceAPI":
        self._thread = threading.Thread(
            target=self.server.serve_forever,
            name="service-api",
            daemon=True,
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self.server.shutdown()
        self.server.server_close()
        if self._thread is not None:
            self._thread.join(5.0)
