"""repro.service — the resident campaign service over streaming campaigns.

The long-running-service shape from the ROADMAP's streaming line of work:
an async dispatch loop (`CampaignService`) that schedules submitted
``CampaignSpec``s across a worker pool via the segment-boundary streaming
machinery (checkpointing on by default, graceful drain, bitwise crash
resume), a non-blocking telemetry export layer (``TelemetryRing`` +
pluggable ``Exporter``s), and a northbound stdlib-HTTP status/control API
(``ServiceAPI``).
"""

from repro.service.exporters import Exporter, ExportPump, JsonlExporter
from repro.service.ring import TelemetryRing
from repro.service.service import (
    CampaignRecord,
    CampaignService,
    CampaignState,
    ServiceDrainingError,
    ServiceSaturatedError,
    UnknownCampaignError,
)

__all__ = [
    "CampaignRecord",
    "CampaignService",
    "CampaignState",
    "Exporter",
    "ExportPump",
    "JsonlExporter",
    "ServiceAPI",
    "ServiceDrainingError",
    "ServiceSaturatedError",
    "TelemetryRing",
    "UnknownCampaignError",
]


def __getattr__(name):
    # ServiceAPI pulls in http.server; keep the core service importable
    # without it (and avoid the import cost on the worker-only path)
    if name == "ServiceAPI":
        from repro.service.api import ServiceAPI

        return ServiceAPI
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
