"""Run the campaign service as a process: ``python -m repro.service``.

Prints one JSON line (``{"port": ..., "url": ..., "state_dir": ...}``) to
stdout once the API is bound — the handshake a parent process (or the
SIGTERM kill-and-resume test) parses to find the ephemeral port.

SIGTERM/SIGINT trigger the graceful drain: every worker finishes its
current segment (whose checkpoint is already durable), campaigns are
marked ``interrupted``, the telemetry pump flushes, and the process exits
0.  Restarting with the same ``--state-dir`` resumes every non-terminal
campaign bitwise from its latest checkpoint.
"""

from __future__ import annotations

import argparse
import json
import signal
import sys
import threading


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="ARCHES resident campaign service",
    )
    p.add_argument("--state-dir", required=True,
                   help="persistent service state root")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0,
                   help="API port (0 = ephemeral, printed on stdout)")
    p.add_argument("--workers", type=int, default=1)
    p.add_argument("--queue-size", type=int, default=16)
    p.add_argument("--ring-capacity", type=int, default=256)
    p.add_argument("--max-segment-slots", type=int, default=8)
    p.add_argument("--telemetry-jsonl", default=None,
                   help="append segment telemetry to this JSONL file")
    args = p.parse_args(argv)

    from repro.service.api import ServiceAPI
    from repro.service.exporters import JsonlExporter
    from repro.service.service import CampaignService

    exporters = (
        [JsonlExporter(args.telemetry_jsonl)]
        if args.telemetry_jsonl
        else []
    )
    service = CampaignService(
        args.state_dir,
        n_workers=args.workers,
        queue_size=args.queue_size,
        ring_capacity=args.ring_capacity,
        exporters=exporters,
        max_segment_slots=args.max_segment_slots,
    ).start()
    api = ServiceAPI(service, host=args.host, port=args.port).start()

    print(
        json.dumps(
            {"port": api.port, "url": api.url, "state_dir": args.state_dir}
        ),
        flush=True,
    )

    stop = threading.Event()

    def _on_signal(signum, frame):
        service.request_drain()
        stop.set()

    signal.signal(signal.SIGTERM, _on_signal)
    signal.signal(signal.SIGINT, _on_signal)

    stop.wait()
    ok = service.drain(timeout=120.0)
    api.stop()
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
