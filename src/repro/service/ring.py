"""Fixed-capacity telemetry ring: O(1) lock-held push, exact drop counts.

The dispatch loop's side of the telemetry contract: ``push`` never waits
on a consumer (the critical section is one list store and an increment),
so a stalled or absent exporter can never block a segment boundary.  The
consumer's side: samples carry monotonically increasing sequence numbers,
and ``drain(cursor)`` reports *exactly* how many samples between the
cursor and the current head were overwritten before the consumer got to
them — losses are counted, never silent (the service's "lossless or
exactly counted" telemetry criterion).
"""

from __future__ import annotations

import threading


class TelemetryRing:
    """Bounded ring of telemetry samples with monotonic sequence numbers.

    One producer lock serializes writers (multiple campaign workers push
    concurrently); consumers never hold it for longer than a bounded copy
    of at most ``capacity`` references.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"ring capacity {capacity} must be >= 1")
        self._capacity = capacity
        self._buf: list = [None] * capacity
        self._head = 0  # total samples ever pushed == next sequence number
        self._lock = threading.Lock()

    @property
    def capacity(self) -> int:
        return self._capacity

    @property
    def head(self) -> int:
        """Total samples pushed so far (the next sample's sequence number)."""
        with self._lock:
            return self._head

    def push(self, sample) -> int:
        """Append ``sample``; returns its sequence number.

        O(1) under the lock — never blocks on consumers.  Overwrites the
        oldest sample when full; the overwrite is what ``drain`` counts.
        """
        with self._lock:
            seq = self._head
            self._buf[seq % self._capacity] = sample
            self._head = seq + 1
            return seq

    def drain(self, cursor: int) -> tuple[list, int, int]:
        """Samples with sequence >= ``cursor`` still in the ring.

        Returns ``(samples, new_cursor, dropped)``: ``new_cursor`` is the
        head at drain time (pass it to the next ``drain``), ``dropped`` is
        exactly the number of samples in ``[cursor, head)`` that were
        overwritten before this drain — ``max(0, head - capacity - cursor)``.
        """
        with self._lock:
            head = self._head
            dropped = max(0, head - self._capacity - cursor)
            start = max(cursor, head - self._capacity, 0)
            samples = [
                self._buf[i % self._capacity] for i in range(start, head)
            ]
        return samples, head, dropped

    def snapshot(self, n: int | None = None) -> list:
        """The most recent ``min(n, available)`` samples, oldest first.

        Cursor-free read for the API's live-telemetry endpoint; does not
        interact with any consumer's drain position.
        """
        with self._lock:
            head = self._head
            avail = min(head, self._capacity)
            if n is not None:
                avail = min(avail, max(n, 0))
            return [
                self._buf[i % self._capacity]
                for i in range(head - avail, head)
            ]
