"""CampaignService — the async dispatch loop over streaming campaigns.

Turns the one-shot ``ArchesSession.run_streaming()`` into a resident
service: ``submit()`` queues ``CampaignSpec``s (bounded queue, explicit
saturation), a configurable worker pool executes them through the
segment-boundary streaming driver with checkpointing on by default, and
every segment boundary publishes a reduced telemetry sample into the
export ring and persists the campaign's progress.

The operability contract, inherited from the PR 8 checkpoint machinery
and proven in ``tests/test_service.py``:

* **graceful drain** — ``request_drain()`` makes every worker stop at its
  campaign's next segment boundary, *after* that segment's checkpoint has
  been durably written (the ``on_segment`` hook fires post-checkpoint),
  then exit.  Queued campaigns stay queued on disk.
* **bitwise restart** — a restarted service (same ``state_dir``) recovers
  every non-terminal campaign and resumes in-flight ones from their
  latest checkpoint via ``resume_from=``; the completed history is
  bitwise-equal to an uninterrupted ``run_streaming()`` of the same spec.
* **zero-churn lift** — churn-free specs are lifted by
  ``as_streaming_spec`` into a full-residency segmented form, so *every*
  submitted campaign is crash-resumable while staying bitwise-equal to
  the monolithic ``ArchesSession.run()`` on every leaf.

State layout under ``state_dir``::

    campaigns/<campaign_id>/spec.json      # submitted spec (provenance)
    campaigns/<campaign_id>/run_spec.json  # streaming form actually run
    campaigns/<campaign_id>/status.json    # state machine + progress
    campaigns/<campaign_id>/ckpt/          # per-segment atomic checkpoints
"""

from __future__ import annotations

import dataclasses
import json
import os
import queue
import shutil
import threading
import time
import traceback

from repro.checkpoint.store import latest_step, list_steps
from repro.core.session import (
    ArchesSession,
    CampaignSpec,
    as_streaming_spec,
    spec_hash,
)
from repro.core.telemetry import segment_telemetry
from repro.service.exporters import ExportPump
from repro.service.ring import TelemetryRing


class CampaignState:
    """Campaign state machine (string constants; JSON-stable).

    ``queued -> running -> {completed, failed, cancelled, interrupted}``;
    ``interrupted`` (drained mid-campaign) and non-terminal states are
    recovered and re-enqueued by the next ``start()`` on the same
    ``state_dir``.
    """

    QUEUED = "queued"
    RUNNING = "running"
    COMPLETED = "completed"
    FAILED = "failed"
    CANCELLED = "cancelled"
    INTERRUPTED = "interrupted"

    #: states a restarted service re-enqueues (``running`` means the
    #: previous process died without draining — e.g. SIGKILL — and the
    #: latest checkpoint is still the bitwise resume point)
    RECOVERABLE = (QUEUED, RUNNING, INTERRUPTED)
    TERMINAL = (COMPLETED, FAILED, CANCELLED)


class ServiceSaturatedError(RuntimeError):
    """The bounded submission queue is full — back off and resubmit."""


class ServiceDrainingError(RuntimeError):
    """The service is draining and accepts no new campaigns."""


class UnknownCampaignError(KeyError):
    """No campaign with that id in this service's state dir."""


@dataclasses.dataclass
class CampaignRecord:
    """One campaign's full service-side state (persisted as status.json)."""

    campaign_id: str
    spec: CampaignSpec  # as submitted (provenance)
    run_spec: CampaignSpec  # streaming form actually executed
    submitted_seq: int
    state: str = CampaignState.QUEUED
    segments_done: int = 0
    n_segments: int = 0
    error: str | None = None
    # in-memory only: completed history (service-path bitwise contract),
    # cancel latch, record lock
    result: object = None
    cancel_event: threading.Event = dataclasses.field(
        default_factory=threading.Event
    )

    @property
    def spec_hash(self) -> str:
        return spec_hash(self.spec)

    @property
    def run_spec_hash(self) -> str:
        return spec_hash(self.run_spec)


def _atomic_write_json(path: str, payload: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


class CampaignService:
    """Async dispatch loop + telemetry export + campaign state store.

    ``segment_callback(service, record, event)`` is an observability hook
    fired after each segment's telemetry sample is published and progress
    persisted, before the drain/cancel decision — tests use it to request
    a drain at a deterministic segment boundary.

    ``ai_params`` (optional) is threaded into every ``ArchesSession`` so
    a fleet of campaigns shares one trained estimator instead of each
    retraining it.
    """

    def __init__(
        self,
        state_dir: str,
        *,
        n_workers: int = 1,
        queue_size: int = 16,
        ring_capacity: int = 256,
        exporters: list | None = None,
        max_segment_slots: int = 8,
        checkpointing: bool = True,
        ai_params=None,
        segment_callback=None,
    ):
        if n_workers < 1:
            raise ValueError(f"n_workers {n_workers} must be >= 1")
        self.state_dir = state_dir
        self.campaigns_dir = os.path.join(state_dir, "campaigns")
        os.makedirs(self.campaigns_dir, exist_ok=True)
        self.n_workers = n_workers
        self.max_segment_slots = max_segment_slots
        self.checkpointing = checkpointing
        self.ring = TelemetryRing(ring_capacity)
        self.pump = ExportPump(self.ring, exporters or [])
        self._ai_params = ai_params
        self._segment_callback = segment_callback
        # The dispatch queue itself is unbounded: recovery must be able to
        # re-enqueue arbitrarily many non-terminal campaigns (a saturated
        # service that crashed can have > queue_size of them) without
        # blocking start().  The submission cap is enforced in submit() by
        # counting queued records instead.
        self.queue_size = queue_size
        self._queue: queue.Queue = queue.Queue()
        self._records: dict[str, CampaignRecord] = {}
        self._lock = threading.Lock()
        self._draining = threading.Event()
        self._workers: list[threading.Thread] = []
        self._started = False
        self._started_at = None

    # -- lifecycle -------------------------------------------------------------

    def start(self) -> "CampaignService":
        """Recover persisted campaigns, then start workers and the pump."""
        if self._started:
            raise RuntimeError("service already started")
        self._recover()
        self.pump.start()
        for i in range(self.n_workers):
            t = threading.Thread(
                target=self._worker_loop, name=f"campaign-worker-{i}",
                daemon=True,
            )
            t.start()
            self._workers.append(t)
        self._started = True
        self._started_at = time.monotonic()
        return self

    def request_drain(self) -> None:
        """Begin graceful drain: no new submissions; every running campaign
        stops at its next segment boundary (checkpoint already durable) and
        is marked ``interrupted``; workers then exit."""
        self._draining.set()

    def drain(self, timeout: float | None = 30.0) -> bool:
        """``request_drain`` + wait for the workers to exit and the pump to
        flush.  Returns True when every worker finished in time."""
        self.request_drain()
        deadline = None if timeout is None else time.monotonic() + timeout
        ok = True
        for t in self._workers:
            left = (
                None if deadline is None
                else max(0.0, deadline - time.monotonic())
            )
            t.join(left)
            ok = ok and not t.is_alive()
        self.pump.stop()
        return ok

    @property
    def draining(self) -> bool:
        return self._draining.is_set()

    # -- persistence -----------------------------------------------------------

    def _dir_for(self, campaign_id: str) -> str:
        return os.path.join(self.campaigns_dir, campaign_id)

    def ckpt_dir(self, campaign_id: str) -> str:
        return os.path.join(self._dir_for(campaign_id), "ckpt")

    def _persist(self, rec: CampaignRecord) -> None:
        _atomic_write_json(
            os.path.join(self._dir_for(rec.campaign_id), "status.json"),
            {
                "campaign_id": rec.campaign_id,
                "state": rec.state,
                "submitted_seq": rec.submitted_seq,
                "segments_done": rec.segments_done,
                "n_segments": rec.n_segments,
                "spec_hash": rec.spec_hash,
                "run_spec_hash": rec.run_spec_hash,
                "error": rec.error,
            },
        )

    def _recover(self) -> None:
        """Rebuild records from disk; re-enqueue non-terminal campaigns in
        original submission order (the bitwise-restart half of the drain
        contract — ``resume_from`` picks up each one's latest checkpoint)."""
        recs = []
        for cid in os.listdir(self.campaigns_dir):
            d = self._dir_for(cid)
            try:
                with open(os.path.join(d, "spec.json")) as f:
                    spec = CampaignSpec.from_json(f.read())
                with open(os.path.join(d, "run_spec.json")) as f:
                    run_spec = CampaignSpec.from_json(f.read())
                with open(os.path.join(d, "status.json")) as f:
                    st = json.load(f)
            except (OSError, ValueError, KeyError):
                continue  # torn submit (crash mid-persist): not recoverable
            rec = CampaignRecord(
                campaign_id=cid,
                spec=spec,
                run_spec=run_spec,
                submitted_seq=int(st["submitted_seq"]),
                state=st["state"],
                segments_done=int(st["segments_done"]),
                n_segments=int(st["n_segments"]),
                error=st.get("error"),
            )
            recs.append(rec)
        recs.sort(key=lambda r: r.submitted_seq)
        for rec in recs:
            self._records[rec.campaign_id] = rec
            if rec.state in CampaignState.RECOVERABLE:
                if rec.state != CampaignState.QUEUED:
                    rec.state = CampaignState.QUEUED
                    self._persist(rec)
                self._queue.put_nowait(rec.campaign_id)

    # -- submission / control --------------------------------------------------

    def submit(self, spec: CampaignSpec | str | dict) -> str:
        """Queue a campaign; returns its id.

        Accepts a ``CampaignSpec``, its JSON string, or its dict form.
        Raises ``ServiceDrainingError`` when draining,
        ``ServiceSaturatedError`` when the bounded queue is full, and
        ``ValueError`` for specs with no streaming form.
        """
        if isinstance(spec, str):
            spec = CampaignSpec.from_json(spec)
        elif isinstance(spec, dict):
            spec = CampaignSpec.from_dict(spec)
        if self._draining.is_set():
            raise ServiceDrainingError(
                "service is draining; resubmit after restart"
            )
        run_spec = as_streaming_spec(
            spec, max_segment_slots=self.max_segment_slots
        )
        # Everything from saturation check to enqueue happens under the
        # lock so the dispatch queue's order always matches submitted_seq
        # (what recovery reconstructs after a restart) and a rejected
        # submit leaves no record or state-dir litter.
        with self._lock:
            pending = sum(
                1 for r in self._records.values()
                if r.state == CampaignState.QUEUED
            )
            if pending >= self.queue_size:
                raise ServiceSaturatedError(
                    f"submission queue is full ({pending} pending)"
                )
            seq = 1 + max(
                (r.submitted_seq for r in self._records.values()), default=0
            )
            cid = f"c{seq:04d}-{spec_hash(spec)[:8]}"
            rec = CampaignRecord(
                campaign_id=cid,
                spec=spec,
                run_spec=run_spec,
                submitted_seq=seq,
                n_segments=(
                    run_spec.n_slots // run_spec.churn.segment_slots
                ),
            )
            self._records[cid] = rec
            d = self._dir_for(cid)
            try:
                os.makedirs(d, exist_ok=True)
                with open(os.path.join(d, "spec.json"), "w") as f:
                    f.write(spec.to_json())
                with open(os.path.join(d, "run_spec.json"), "w") as f:
                    f.write(run_spec.to_json())
                self._persist(rec)
            except BaseException:
                del self._records[cid]
                shutil.rmtree(d, ignore_errors=True)
                raise
            self._queue.put_nowait(cid)
        return cid

    def cancel(self, campaign_id: str) -> str:
        """Cancel a campaign; returns its state after the request.

        Queued campaigns cancel immediately; running ones stop at the next
        segment boundary (their checkpoint is retained).  Terminal states
        are left untouched.
        """
        rec = self._get(campaign_id)
        with self._lock:
            if rec.state == CampaignState.QUEUED:
                rec.state = CampaignState.CANCELLED
                self._persist(rec)
                return rec.state
        rec.cancel_event.set()
        return rec.state

    def _get(self, campaign_id: str) -> CampaignRecord:
        try:
            return self._records[campaign_id]
        except KeyError:
            raise UnknownCampaignError(campaign_id) from None

    # -- introspection ---------------------------------------------------------

    def status(self, campaign_id: str) -> dict:
        """Full status of one campaign, including checkpoint lineage."""
        rec = self._get(campaign_id)
        return {
            "campaign_id": rec.campaign_id,
            "state": rec.state,
            "submitted_seq": rec.submitted_seq,
            "segments_done": rec.segments_done,
            "n_segments": rec.n_segments,
            "spec_hash": rec.spec_hash,
            "run_spec_hash": rec.run_spec_hash,
            "checkpoint_steps": list_steps(self.ckpt_dir(rec.campaign_id)),
            "error": rec.error,
        }

    def list_campaigns(self) -> list[dict]:
        with self._lock:
            recs = sorted(
                self._records.values(), key=lambda r: r.submitted_seq
            )
        return [
            {
                "campaign_id": r.campaign_id,
                "state": r.state,
                "segments_done": r.segments_done,
                "n_segments": r.n_segments,
                "spec_hash": r.spec_hash,
            }
            for r in recs
        ]

    def health(self) -> dict:
        with self._lock:
            states: dict[str, int] = {}
            for r in self._records.values():
                states[r.state] = states.get(r.state, 0) + 1
        return {
            "status": "draining" if self.draining else "ok",
            "uptime_s": (
                0.0 if self._started_at is None
                else time.monotonic() - self._started_at
            ),
            "workers": sum(t.is_alive() for t in self._workers),
            "queue_depth": self._queue.qsize(),
            "campaign_states": states,
            "telemetry": {
                "ring_capacity": self.ring.capacity,
                "samples_published": self.ring.head,
                **self.pump.counters(),
            },
        }

    def result(self, campaign_id: str):
        """The completed ``BatchedRunHistory`` (in-memory; None otherwise)."""
        return self._get(campaign_id).result

    def wait(self, campaign_id: str, timeout: float = 60.0) -> str:
        """Poll until the campaign reaches a terminal state; returns it."""
        rec = self._get(campaign_id)
        deadline = time.monotonic() + timeout
        while rec.state not in CampaignState.TERMINAL:
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"{campaign_id} still {rec.state!r} after {timeout}s"
                )
            time.sleep(0.02)
        return rec.state

    # -- the dispatch loop -----------------------------------------------------

    def _worker_loop(self) -> None:
        while not self._draining.is_set():
            try:
                cid = self._queue.get(timeout=0.1)
            except queue.Empty:
                continue
            if self._draining.is_set():
                return  # still queued on disk; the next start() resumes it
            rec = self._records[cid]
            with self._lock:
                if rec.state != CampaignState.QUEUED:
                    continue  # cancelled while queued
                rec.state = CampaignState.RUNNING
            self._persist(rec)
            self._run_campaign(rec)

    def _run_campaign(self, rec: CampaignRecord) -> None:
        try:
            session = ArchesSession(rec.run_spec, ai_params=self._ai_params)
            ckpt = self.ckpt_dir(rec.campaign_id) if self.checkpointing else None
            resume = (
                ckpt
                if ckpt is not None and latest_step(ckpt) is not None
                else None
            )

            def on_segment(ev) -> bool:
                # reduce the O(segment) span view, not the full-campaign
                # accumulators — per-boundary telemetry cost stays flat as
                # the campaign ages
                local = ev.segment_history is not None
                sample = {
                    "campaign_id": rec.campaign_id,
                    "spec_hash": rec.spec_hash,
                    "seg_idx": ev.seg_idx,
                    "n_segments": ev.n_segments,
                    **segment_telemetry(
                        ev.segment_history if local else ev.history,
                        ev.t0, ev.t1, local=local,
                    ),
                }
                self.ring.push(sample)
                rec.segments_done = ev.seg_idx + 1
                rec.n_segments = ev.n_segments
                self._persist(rec)
                if self._segment_callback is not None:
                    self._segment_callback(self, rec, ev)
                return (
                    self._draining.is_set() or rec.cancel_event.is_set()
                )

            hist = session.run_streaming(
                checkpoint_dir=ckpt, resume_from=resume, on_segment=on_segment
            )
            finished = rec.segments_done >= rec.n_segments
            if finished:
                rec.result = hist
                rec.state = CampaignState.COMPLETED
            elif rec.cancel_event.is_set():
                rec.state = CampaignState.CANCELLED
            else:
                rec.state = CampaignState.INTERRUPTED
        except Exception:
            rec.error = traceback.format_exc(limit=20)
            rec.state = CampaignState.FAILED
        self._persist(rec)
