"""Jit'd public wrapper for decision-tree inference.

``pack_tree`` densifies a complete binary tree (level-order arrays, as
produced by ``repro.core.policy.fit_decision_tree``) into the matmul operands
the Pallas kernel consumes; ``tree_infer`` evaluates a batch of KPM vectors.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tree_infer import tree_infer as _k

_LANE = 128
_SUBLANE = 8


class PackedTree(NamedTuple):
    t: jax.Array  # (F_pad, Nn_pad) one-hot feature gather
    thr: jax.Array  # (1, Nn_pad)
    a: jax.Array  # (Nn_pad, Nl_pad)  on * dir
    b: jax.Array  # (Nn_pad, Nl_pad)  on * (1 - dir)
    n_on: jax.Array  # (1, Nl_pad)  (-1 for padded leaves)
    leaf_vals: jax.Array  # (1, Nl_pad)
    n_features: int
    depth: int


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pack_tree(
    feature: np.ndarray,
    threshold: np.ndarray,
    leaf_values: np.ndarray,
    n_features: int,
    depth: int,
) -> PackedTree:
    """Densify level-order tree arrays into MXU-friendly operands."""
    n_nodes = 2**depth - 1
    n_leaves = 2**depth
    assert feature.shape == (n_nodes,) and leaf_values.shape == (n_leaves,)

    f_pad = max(_LANE, -(-n_features // _LANE) * _LANE)
    nn_pad = max(_LANE, -(-n_nodes // _LANE) * _LANE)
    nl_pad = max(_LANE, -(-n_leaves // _LANE) * _LANE)

    t = np.zeros((f_pad, nn_pad), np.float32)
    t[feature, np.arange(n_nodes)] = 1.0
    thr = np.full((1, nn_pad), np.inf, np.float32)
    thr[0, :n_nodes] = threshold

    on = np.zeros((n_leaves, n_nodes), np.float32)
    dr = np.zeros((n_leaves, n_nodes), np.float32)
    for leaf in range(n_leaves):
        node = 0
        for level in range(depth):
            d = (leaf >> (depth - 1 - level)) & 1
            on[leaf, node] = 1.0
            dr[leaf, node] = float(d)
            node = 2 * node + 1 + d

    a = np.zeros((nn_pad, nl_pad), np.float32)
    b = np.zeros((nn_pad, nl_pad), np.float32)
    a[:n_nodes, :n_leaves] = (on * dr).T
    b[:n_nodes, :n_leaves] = (on * (1.0 - dr)).T
    n_on = np.full((1, nl_pad), -1.0, np.float32)
    n_on[0, :n_leaves] = on.sum(axis=1)
    lv = np.zeros((1, nl_pad), np.float32)
    lv[0, :n_leaves] = leaf_values

    return PackedTree(
        t=jnp.asarray(t),
        thr=jnp.asarray(thr),
        a=jnp.asarray(a),
        b=jnp.asarray(b),
        n_on=jnp.asarray(n_on),
        leaf_vals=jnp.asarray(lv),
        n_features=n_features,
        depth=depth,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def tree_infer(x: jax.Array, tree: PackedTree, *, interpret: bool | None = None):
    """Evaluate the tree on ``x (B, F)``; returns float32 predictions ``(B,)``."""
    if interpret is None:
        interpret = _use_interpret()
    bsz, f = x.shape
    f_pad = tree.t.shape[0]
    pad_b = (-bsz) % _SUBLANE
    x2 = jnp.pad(x.astype(jnp.float32), ((0, pad_b), (0, f_pad - f)))
    block_b = min(_k.DEFAULT_BLOCK_B, bsz + pad_b)
    while (bsz + pad_b) % block_b:
        block_b //= 2
    scores = _k.tree_infer_2d(
        x2,
        tree.t,
        tree.thr,
        tree.a,
        tree.b,
        tree.n_on,
        tree.leaf_vals,
        block_b=block_b,
        interpret=interpret,
    )
    # exactly one leaf matches per row -> the row sum is its value
    return scores[:bsz].sum(axis=-1)
