"""Pallas TPU kernel: vectorized decision-tree inference (paper 5.3, Fig. 8).

The ARCHES switching policy is a depth-2 decision tree whose inference must
stay sub-microsecond (0.41 us on the GH200).  A pointer-chasing tree walk is
hostile to the TPU's vector units, so the kernel re-expresses the complete
binary tree as dense linear algebra that the MXU/VPU execute in one pass:

  proj  = X @ T                    (one-hot feature gather as a matmul)
  D     = proj > thresholds        (all node decisions at once)
  count = D @ (on*dir)^T + (1-D) @ (on*(1-dir))^T
  match = count == n_on            (leaf indicator: every on-path node agrees)
  out   = match * leaf_values      (reduced by the wrapper)

where ``on[l, n]`` marks internal node ``n`` on the root-to-leaf-``l`` path
and ``dir[l, n]`` the branch direction that path takes.  This evaluates every
slot's KPM vector against the whole tree with two small matmuls — the TPU
analogue of the paper's "sub-microsecond decision inference".

Layout contract: all dims padded to lane/sublane multiples by ops.py; padded
leaves carry ``n_on = -1`` so they can never match.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 256


def _tree_kernel(x_ref, t_ref, thr_ref, a_ref, b_ref, non_ref, leaf_ref, out_ref):
    x = x_ref[...]
    proj = jnp.dot(x, t_ref[...], preferred_element_type=jnp.float32)
    d = (proj > thr_ref[...]).astype(jnp.float32)
    count = jnp.dot(d, a_ref[...], preferred_element_type=jnp.float32) + jnp.dot(
        1.0 - d, b_ref[...], preferred_element_type=jnp.float32
    )
    match = (count == non_ref[...]).astype(jnp.float32)
    out_ref[...] = match * leaf_ref[...]


def tree_infer_2d(
    x: jax.Array,
    t: jax.Array,
    thr: jax.Array,
    a: jax.Array,
    b: jax.Array,
    n_on: jax.Array,
    leaf_vals: jax.Array,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> jax.Array:
    """Returns per-leaf scores ``(B, Nl)``; row-sum gives the prediction."""
    bsz, f = x.shape
    nn = t.shape[1]
    nl = a.shape[1]
    block_b = min(block_b, bsz)
    if bsz % block_b:
        raise ValueError(f"batch {bsz} not divisible by block {block_b}")

    grid = (bsz // block_b,)
    return pl.pallas_call(
        _tree_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((f, nn), lambda i: (0, 0)),
            pl.BlockSpec((1, nn), lambda i: (0, 0)),
            pl.BlockSpec((nn, nl), lambda i: (0, 0)),
            pl.BlockSpec((nn, nl), lambda i: (0, 0)),
            pl.BlockSpec((1, nl), lambda i: (0, 0)),
            pl.BlockSpec((1, nl), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, nl), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, nl), jnp.float32),
        interpret=interpret,
    )(x, t, thr, a, b, n_on, leaf_vals)
