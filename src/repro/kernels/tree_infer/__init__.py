from repro.kernels.tree_infer.ops import PackedTree, pack_tree, tree_infer
from repro.kernels.tree_infer.ref import tree_infer_ref
from repro.kernels.tree_infer.tree_infer import tree_infer_2d

__all__ = ["PackedTree", "pack_tree", "tree_infer", "tree_infer_2d", "tree_infer_ref"]
