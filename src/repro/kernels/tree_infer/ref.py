"""Pure-jnp oracle for decision-tree inference: the literal tree walk."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_infer_ref(
    x: jax.Array,
    feature: jax.Array,
    threshold: jax.Array,
    leaf_values: jax.Array,
    depth: int,
) -> jax.Array:
    """Descend a complete binary tree for each row of ``x``.

    Args:
      x: ``(B, F)`` feature vectors.
      feature: ``(2**depth - 1,)`` int32 feature index per internal node
        (level order: node 0 is the root, children of ``n`` are ``2n+1/2n+2``).
      threshold: ``(2**depth - 1,)`` float32 split thresholds (go right if
        ``x[f] > t``).
      leaf_values: ``(2**depth,)`` predictions.
      depth: static tree depth.

    Returns:
      ``(B,)`` predictions (same dtype as ``leaf_values``).
    """
    bsz = x.shape[0]
    idx = jnp.zeros((bsz,), jnp.int32)
    for _ in range(depth):
        f = feature[idx]
        t = threshold[idx]
        go_right = jnp.take_along_axis(x, f[:, None], axis=1)[:, 0] > t
        idx = 2 * idx + 1 + go_right.astype(jnp.int32)
    leaf = idx - (2**depth - 1)
    return leaf_values[leaf]
