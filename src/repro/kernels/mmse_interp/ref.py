"""Pure-jnp oracle for the MMSE/Wiener interpolation kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mmse_interp_ref(h_pilot: jax.Array, w: jax.Array) -> jax.Array:
    """``h_pilot (..., Np) complex``, ``w (Np, Nsc) complex`` -> ``(..., Nsc)``."""
    return jnp.einsum("...p,pn->...n", h_pilot, w)
