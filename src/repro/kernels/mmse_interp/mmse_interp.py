"""Pallas TPU kernel: MMSE/Wiener frequency-domain interpolation (paper 5.1).

The MMSE channel estimator interpolates DMRS-position estimates across the
full band with a Wiener filter: ``H_full = W @ H_pilot`` where
``W = R_fp (R_pp + sigma^2 I)^{-1}`` is precomputed from the power-delay
profile approximation (Hung & Lin [16]).  On the GPU this is cuBB's
filtering kernel (~5.04 us, paper Fig. 8); on TPU the natural mapping is an
MXU matmul over the pilot dimension.

Complex arithmetic is expanded over real planes.  With ``use_gauss=True`` the
kernel uses the 3-multiplication Gauss trick::

    p1 = Hr @ Wr;  p2 = Hi @ Wi;  p3 = (Hr + Hi) @ (Wr + Wi)
    out_r = p1 - p2;  out_i = p3 - p1 - p2

trading one MXU pass for a few VPU adds (25% less MXU work than the naive
4-matmul expansion).

Layout contract: ``H`` is ``(B, Np)`` (batch of antenna x DMRS-symbol pilot
vectors), ``W`` is ``(Np, Nsc)``; ``B % block_b == 0``, ``Nsc % block_n == 0``
and ``Np`` is kept whole in VMEM (padded to a lane multiple by ops.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 8
DEFAULT_BLOCK_N = 512


def _mmse_interp_kernel(hr_ref, hi_ref, wr_ref, wi_ref, or_ref, oi_ref, *, use_gauss):
    hr = hr_ref[...]
    hi = hi_ref[...]
    wr = wr_ref[...]
    wi = wi_ref[...]
    if use_gauss:
        p1 = jnp.dot(hr, wr, preferred_element_type=jnp.float32)
        p2 = jnp.dot(hi, wi, preferred_element_type=jnp.float32)
        p3 = jnp.dot(hr + hi, wr + wi, preferred_element_type=jnp.float32)
        or_ref[...] = p1 - p2
        oi_ref[...] = p3 - p1 - p2
    else:
        or_ref[...] = jnp.dot(hr, wr, preferred_element_type=jnp.float32) - jnp.dot(
            hi, wi, preferred_element_type=jnp.float32
        )
        oi_ref[...] = jnp.dot(hr, wi, preferred_element_type=jnp.float32) + jnp.dot(
            hi, wr, preferred_element_type=jnp.float32
        )


def mmse_interp_2d(
    h_real: jax.Array,
    h_imag: jax.Array,
    w_real: jax.Array,
    w_imag: jax.Array,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_n: int = DEFAULT_BLOCK_N,
    use_gauss: bool = True,
    interpret: bool = False,
):
    """Batched Wiener interpolation. Returns ``(out_real, out_imag)``."""
    b, np_ = h_real.shape
    np2, nsc = w_real.shape
    if np_ != np2:
        raise ValueError(f"pilot dims disagree: {np_} vs {np2}")
    block_b = min(block_b, b)
    block_n = min(block_n, nsc)
    if b % block_b or nsc % block_n:
        raise ValueError(f"({b},{nsc}) not divisible by ({block_b},{block_n})")

    grid = (b // block_b, nsc // block_n)
    h_spec = pl.BlockSpec((block_b, np_), lambda i, j: (i, 0))
    w_spec = pl.BlockSpec((np_, block_n), lambda i, j: (0, j))
    o_spec = pl.BlockSpec((block_b, block_n), lambda i, j: (i, j))

    import functools

    kernel = functools.partial(_mmse_interp_kernel, use_gauss=use_gauss)
    out_shape = jax.ShapeDtypeStruct((b, nsc), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[h_spec, h_spec, w_spec, w_spec],
        out_specs=[o_spec, o_spec],
        out_shape=[out_shape, out_shape],
        interpret=interpret,
    )(h_real, h_imag, w_real, w_imag)
