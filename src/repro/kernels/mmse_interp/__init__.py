from repro.kernels.mmse_interp.mmse_interp import mmse_interp_2d
from repro.kernels.mmse_interp.ops import mmse_interp
from repro.kernels.mmse_interp.ref import mmse_interp_ref

__all__ = ["mmse_interp", "mmse_interp_2d", "mmse_interp_ref"]
