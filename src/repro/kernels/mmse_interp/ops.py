"""Jit'd public wrapper for the MMSE/Wiener interpolation kernel.

Accepts complex pilot estimates of arbitrary leading batch shape, pads the
pilot/subcarrier dims to lane multiples (zero padding is exact for a matmul)
and dispatches to the Pallas kernel (interpret mode off-TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mmse_interp import mmse_interp as _k

_LANE = 128
_SUBLANE = 8


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("use_gauss", "interpret"))
def mmse_interp(
    h_pilot: jax.Array,
    w: jax.Array,
    *,
    use_gauss: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Wiener-interpolate pilot estimates to the full band.

    Args:
      h_pilot: complex ``(..., Np)`` pilot-position channel estimates.
      w: complex ``(Np, Nsc)`` Wiener interpolation matrix.

    Returns:
      complex ``(..., Nsc)`` full-band estimates.
    """
    if interpret is None:
        interpret = _use_interpret()
    batch_shape = h_pilot.shape[:-1]
    np_ = h_pilot.shape[-1]
    nsc = w.shape[1]
    b = 1
    for d in batch_shape:
        b *= d

    pad_b = (-b) % _SUBLANE
    pad_p = (-np_) % _LANE
    pad_n = (-nsc) % _LANE

    h2 = h_pilot.reshape(b, np_)
    h2 = jnp.pad(h2, ((0, pad_b), (0, pad_p)))
    w2 = jnp.pad(w, ((0, pad_p), (0, pad_n)))

    block_n = min(_k.DEFAULT_BLOCK_N, nsc + pad_n)
    # shrink block until divisible (both are lane multiples)
    while (nsc + pad_n) % block_n:
        block_n //= 2
    out_r, out_i = _k.mmse_interp_2d(
        jnp.real(h2).astype(jnp.float32),
        jnp.imag(h2).astype(jnp.float32),
        jnp.real(w2).astype(jnp.float32),
        jnp.imag(w2).astype(jnp.float32),
        block_b=min(_k.DEFAULT_BLOCK_B, b + pad_b),
        block_n=block_n,
        use_gauss=use_gauss,
        interpret=interpret,
    )
    out = (out_r + 1j * out_i).astype(h_pilot.dtype)
    return out[:b, :nsc].reshape(*batch_shape, nsc)
