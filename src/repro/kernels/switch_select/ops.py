"""Jit'd public wrappers for the ARCHES switch kernel.

Handles what the raw 2-D kernel does not: arbitrary shapes (flatten + pad to
tile multiples), complex dtypes (viewed as float32 pairs), and per-expert
pytrees (leaf-wise switching).  On non-TPU backends the kernel runs in Pallas
interpret mode so the whole framework is testable on CPU.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels.switch_select import switch_select as _k

_PAD_BLOCK_ROWS = 128
_PAD_BLOCK_COLS = 512
_PAD_ELEMS = _PAD_BLOCK_ROWS * _PAD_BLOCK_COLS


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_real_view(x: jax.Array):
    """View complex leaves as trailing float pairs; return (array, undo)."""
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        real_dtype = jnp.float32 if x.dtype == jnp.complex64 else jnp.float64
        y = jnp.stack([x.real, x.imag], axis=-1).astype(real_dtype)

        def undo(z):
            z = z.reshape(x.shape + (2,))
            return (z[..., 0] + 1j * z[..., 1]).astype(x.dtype)

        return y, undo
    return x, lambda z: z.reshape(x.shape)


def switch_select_leaf(
    mode: jax.Array,
    alternatives: Sequence[jax.Array],
    designated: jax.Array,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Switch a single array leaf. ``mode==0`` keeps ``designated``."""
    if interpret is None:
        interpret = _use_interpret()
    des_view, undo = _to_real_view(designated)
    alt_views = [_to_real_view(a)[0] for a in alternatives]

    flat = des_view.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _PAD_ELEMS
    rows = (n + pad) // _PAD_BLOCK_COLS

    def prep(v):
        f = v.reshape(-1)
        f = jnp.pad(f, (0, pad))
        return f.reshape(rows, _PAD_BLOCK_COLS)

    des2 = prep(des_view)
    alt2 = jnp.stack([prep(a) for a in alt_views], axis=0)
    out2 = _k.switch_select_2d(
        mode,
        alt2,
        des2,
        block_rows=min(_PAD_BLOCK_ROWS, rows),
        block_cols=_PAD_BLOCK_COLS,
        interpret=interpret,
    )
    return undo(out2.reshape(-1)[:n])


def _batched_tile_prep(n: int):
    """Padding plan for per-UE payloads of ``n`` scalars each.

    Per-UE payloads are typically far smaller than the scalar-path pad
    quantum; pad rows to the float32 sublane minimum (8) for small leaves
    and to the full block height for large ones so the tile always divides.
    Returns ``(rows, cols, prep)`` where ``prep(v, lead)`` reshapes a
    ``(lead, ...)`` real view to the padded ``(lead, rows, cols)`` layout.
    """
    cols = _PAD_BLOCK_COLS
    pad = (-n) % cols
    rows = (n + pad) // cols
    row_quantum = 8 if rows <= _PAD_BLOCK_ROWS else _PAD_BLOCK_ROWS
    row_pad = (-rows) % row_quantum
    rows = rows + row_pad

    def prep(v, lead):
        f = v.reshape(lead, -1)
        f = jnp.pad(f, ((0, 0), (0, pad + row_pad * cols)))
        return f.reshape(lead, rows, cols)

    return rows, cols, prep


def switch_select_batched_leaf(
    modes: jax.Array,
    alternatives: Sequence[jax.Array],
    designated: jax.Array,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-UE switch of one leaf with a leading UE axis.

    ``modes`` is ``(n_ues,)``; every leaf is ``(n_ues, ...)`` and UE ``u``'s
    slice keeps the designated output (``modes[u]==0``) or takes alternative
    ``modes[u]-1``.
    """
    if interpret is None:
        interpret = _use_interpret()
    n_ues = designated.shape[0]
    des_view, undo = _to_real_view(designated)
    alt_views = [_to_real_view(a)[0] for a in alternatives]

    n = des_view.reshape(n_ues, -1).shape[1]
    rows, cols, prep = _batched_tile_prep(n)
    des2 = prep(des_view, n_ues)
    alt2 = jnp.stack([prep(a, n_ues) for a in alt_views], axis=0)
    out2 = _k.switch_select_batched_2d(
        modes,
        alt2,
        des2,
        block_rows=min(_PAD_BLOCK_ROWS, rows),
        block_cols=cols,
        interpret=interpret,
    )
    return undo(out2.reshape(n_ues, -1)[:, :n])


def switch_gather_batched_leaf(
    src: jax.Array,
    compact: jax.Array,
    designated: jax.Array,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Scatter one leaf's compact sub-batch back over the full UE batch.

    ``src`` is ``(n_ues,)``; ``designated`` is ``(n_ues, ...)`` (the dense
    baseline), ``compact`` ``(capacity, ...)`` with matching trailing shape.
    UE ``u`` receives compact row ``src[u]`` when ``src[u] >= 0`` and keeps
    its baseline otherwise.
    """
    if interpret is None:
        interpret = _use_interpret()
    n_ues = designated.shape[0]
    capacity = compact.shape[0]
    des_view, undo = _to_real_view(designated)
    comp_view = _to_real_view(compact)[0]

    n = des_view.reshape(n_ues, -1).shape[1]
    rows, cols, prep = _batched_tile_prep(n)
    out2 = _k.switch_gather_batched_2d(
        src,
        prep(comp_view, capacity),
        prep(des_view, n_ues),
        block_rows=min(_PAD_BLOCK_ROWS, rows),
        block_cols=cols,
        interpret=interpret,
    )
    return undo(out2.reshape(n_ues, -1)[:, :n])


@functools.partial(jax.jit, static_argnames=("backend",))
def switch_scatter(src, compact, designated, *, backend: str = "auto"):
    """Fused un-compaction over per-expert pytrees (gated execution path).

    The gated bank runs the expensive expert on a dense capacity-``K``
    sub-batch only; this op scatters those results back over the
    cheap-expert baseline in one pass per leaf: UE ``u`` takes compact row
    ``src[u]`` when ``src[u] >= 0`` and keeps its baseline buffer otherwise.

    Shape discipline: every index in ``src`` addresses a row of *this
    call's* ``compact`` operand — there is no global UE numbering.  Under
    the sharded multi-cell engine (``repro.core.topology``) the op runs
    inside ``shard_map`` with ``n_ues`` == the shard-local UE slice and
    ``capacity`` == the per-shard gated capacity, so the scatter is a
    purely local data movement (no cross-device collective; the
    distributed tests audit the lowered HLO for this).

    Args:
      src: ``(n_ues,)`` int32 compact-row indices (negative == keep).
      compact: pytree of ``(capacity, ...)`` leaves (``capacity >= 1``).
      designated: structurally identical pytree of ``(n_ues, ...)`` leaves,
        aliased to the output on the kernel path.
      backend: ``"pallas"`` (TPU kernel), ``"ref"`` (pure-jnp gather/select)
        or ``"auto"`` — pallas on TPU, ref as the CPU fallback.  Both are
        bitwise-equal by construction: neither path does arithmetic on the
        payload.

    Returns:
      The un-compacted pytree (baseline with gated results scattered in).
    """
    src = jnp.asarray(src, jnp.int32)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        from repro.kernels.switch_select.ref import switch_gather_batched_tree_ref

        return switch_gather_batched_tree_ref(src, compact, designated)
    if backend != "pallas":
        raise ValueError(f"unknown switch_scatter backend {backend!r}")
    return jax.tree.map(
        lambda c, d: switch_gather_batched_leaf(src, c, d, interpret=False),
        compact,
        designated,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def switch_select(mode, outputs: Sequence, designated_idx: int = 0, *, interpret=None):
    """Switch over a list of per-expert pytrees (paper's N-expert bank).

    Args:
      mode: int32 scalar (``0`` selects ``outputs[designated_idx]`` — no-op
        path; ``k>0`` selects the k-th non-designated expert in bank order)
        OR an ``(n_ues,)`` int32 vector for the batched multi-UE engine, in
        which case every leaf must carry a leading UE axis and UE ``u``
        independently follows ``mode[u]``.
      outputs: list of structurally identical pytrees, one per expert, with
        the designated expert first (``designated_idx`` must be 0 — the bank
        reorders before calling).

    Returns:
      The selected pytree, aliased onto the designated buffers.
    """
    if designated_idx != 0:
        raise ValueError("bank must place the designated expert first")
    mode = jnp.asarray(mode, jnp.int32)
    designated, *alternatives = outputs
    if mode.ndim == 1:
        return jax.tree.map(
            lambda d, *alts: switch_select_batched_leaf(
                mode, alts, d, interpret=interpret
            ),
            designated,
            *alternatives,
        )
    return jax.tree.map(
        lambda d, *alts: switch_select_leaf(mode, alts, d, interpret=interpret),
        designated,
        *alternatives,
    )
