"""Jit'd public wrappers for the ARCHES switch kernel.

Handles what the raw 2-D kernel does not: arbitrary shapes (flatten + pad to
tile multiples), complex dtypes (viewed as float32 pairs), and per-expert
pytrees (leaf-wise switching).  On non-TPU backends the kernel runs in Pallas
interpret mode so the whole framework is testable on CPU.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels.switch_select import switch_select as _k

_PAD_BLOCK_ROWS = 128
_PAD_BLOCK_COLS = 512
_PAD_ELEMS = _PAD_BLOCK_ROWS * _PAD_BLOCK_COLS


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_real_view(x: jax.Array):
    """View complex leaves as trailing float pairs; return (array, undo)."""
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        real_dtype = jnp.float32 if x.dtype == jnp.complex64 else jnp.float64
        y = jnp.stack([x.real, x.imag], axis=-1).astype(real_dtype)

        def undo(z):
            z = z.reshape(x.shape + (2,))
            return (z[..., 0] + 1j * z[..., 1]).astype(x.dtype)

        return y, undo
    return x, lambda z: z.reshape(x.shape)


def switch_select_leaf(
    mode: jax.Array,
    alternatives: Sequence[jax.Array],
    designated: jax.Array,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Switch a single array leaf. ``mode==0`` keeps ``designated``."""
    if interpret is None:
        interpret = _use_interpret()
    des_view, undo = _to_real_view(designated)
    alt_views = [_to_real_view(a)[0] for a in alternatives]

    flat = des_view.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _PAD_ELEMS
    rows = (n + pad) // _PAD_BLOCK_COLS

    def prep(v):
        f = v.reshape(-1)
        f = jnp.pad(f, (0, pad))
        return f.reshape(rows, _PAD_BLOCK_COLS)

    des2 = prep(des_view)
    alt2 = jnp.stack([prep(a) for a in alt_views], axis=0)
    out2 = _k.switch_select_2d(
        mode,
        alt2,
        des2,
        block_rows=min(_PAD_BLOCK_ROWS, rows),
        block_cols=_PAD_BLOCK_COLS,
        interpret=interpret,
    )
    return undo(out2.reshape(-1)[:n])


def switch_select_batched_leaf(
    modes: jax.Array,
    alternatives: Sequence[jax.Array],
    designated: jax.Array,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-UE switch of one leaf with a leading UE axis.

    ``modes`` is ``(n_ues,)``; every leaf is ``(n_ues, ...)`` and UE ``u``'s
    slice keeps the designated output (``modes[u]==0``) or takes alternative
    ``modes[u]-1``.
    """
    if interpret is None:
        interpret = _use_interpret()
    n_ues = designated.shape[0]
    des_view, undo = _to_real_view(designated)
    alt_views = [_to_real_view(a)[0] for a in alternatives]

    n = des_view.reshape(n_ues, -1).shape[1]
    # per-UE payloads are typically far smaller than the scalar-path pad
    # quantum; pad rows to the float32 sublane minimum (8) for small leaves
    # and to the full block height for large ones so the tile always divides.
    cols = _PAD_BLOCK_COLS
    pad = (-n) % cols
    rows = (n + pad) // cols
    row_quantum = 8 if rows <= _PAD_BLOCK_ROWS else _PAD_BLOCK_ROWS
    row_pad = (-rows) % row_quantum
    rows = rows + row_pad

    def prep(v):
        f = v.reshape(n_ues, -1)
        f = jnp.pad(f, ((0, 0), (0, pad + row_pad * cols)))
        return f.reshape(n_ues, rows, cols)

    des2 = prep(des_view)
    alt2 = jnp.stack([prep(a) for a in alt_views], axis=0)
    out2 = _k.switch_select_batched_2d(
        modes,
        alt2,
        des2,
        block_rows=min(_PAD_BLOCK_ROWS, rows),
        block_cols=cols,
        interpret=interpret,
    )
    return undo(out2.reshape(n_ues, -1)[:, :n])


@functools.partial(jax.jit, static_argnames=("interpret",))
def switch_select(mode, outputs: Sequence, designated_idx: int = 0, *, interpret=None):
    """Switch over a list of per-expert pytrees (paper's N-expert bank).

    Args:
      mode: int32 scalar (``0`` selects ``outputs[designated_idx]`` — no-op
        path; ``k>0`` selects the k-th non-designated expert in bank order)
        OR an ``(n_ues,)`` int32 vector for the batched multi-UE engine, in
        which case every leaf must carry a leading UE axis and UE ``u``
        independently follows ``mode[u]``.
      outputs: list of structurally identical pytrees, one per expert, with
        the designated expert first (``designated_idx`` must be 0 — the bank
        reorders before calling).

    Returns:
      The selected pytree, aliased onto the designated buffers.
    """
    if designated_idx != 0:
        raise ValueError("bank must place the designated expert first")
    mode = jnp.asarray(mode, jnp.int32)
    designated, *alternatives = outputs
    if mode.ndim == 1:
        return jax.tree.map(
            lambda d, *alts: switch_select_batched_leaf(
                mode, alts, d, interpret=interpret
            ),
            designated,
            *alternatives,
        )
    return jax.tree.map(
        lambda d, *alts: switch_select_leaf(mode, alts, d, interpret=interpret),
        designated,
        *alternatives,
    )
