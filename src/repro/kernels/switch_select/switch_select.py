"""Pallas TPU switch kernel — the ARCHES zero-gap output selector (paper 3.2).

CUDA original (GH200): N experts write to per-expert buffers; downstream
stages always read one *designated* buffer (memory aliasing).  The switch
kernel is a **no-op** when the designated expert is active (``mode == 0``)
and a **coalesced copy** of the alternative expert's output otherwise
(measured 3.36 us vs 4.89 us in the paper, Fig. 8).

TPU adaptation (DESIGN.md 2): a Pallas kernel whose output *aliases* the
designated buffer via ``input_output_aliases`` (so downstream modules keep
reading a single fixed buffer regardless of how many experts exist), with the
``mode`` scalar *prefetched to SMEM* so it can steer the BlockSpec index maps
before the grid runs:

* ``mode == 0`` (designated expert active): every grid step maps input and
  output to tile ``(0, 0)`` and rewrites that tile with its own contents.
  Pallas only issues DMAs when a block index changes between grid steps, so
  the entire call costs a single-tile round-trip — the TPU analogue of the
  paper's no-op path (a pure no-op cannot be expressed through the Pallas
  output pipeline, which always writes its output blocks back).
* ``mode == k > 0``: tile ``(i, j)`` of alternative expert ``k-1`` is copied
  into the designated buffer through VMEM in lane-aligned ``(block_rows,
  block_cols)`` tiles — the analogue of the paper's coalesced-copy path.

The structural asymmetry of the CUDA kernel (cheap when AI is active,
full-tensor copy when the conventional expert is active) is therefore
preserved, tile-for-warp.

Layout contract: operands are 2-D ``(rows, cols)`` real arrays with
``rows % block_rows == 0`` and ``cols % block_cols == 0``; ``ops.py`` handles
flattening / complex-viewing / padding for arbitrary pytrees.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_ROWS = 256
DEFAULT_BLOCK_COLS = 256


def _switch_kernel(mode_ref, alt_ref, des_ref, out_ref):
    """Copy-or-refresh one tile, depending on the prefetched mode scalar."""
    mode = mode_ref[0]

    @pl.when(mode == 0)
    def _noop_path():
        # Identity rewrite of tile (0, 0) of the designated buffer; with the
        # constant index maps below this is the only tile ever touched.
        out_ref[...] = des_ref[...]

    @pl.when(mode != 0)
    def _copy_path():
        out_ref[...] = alt_ref[0]


def switch_select_2d(
    mode: jax.Array,
    alternatives: jax.Array,
    designated: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_cols: int = DEFAULT_BLOCK_COLS,
    interpret: bool = False,
) -> jax.Array:
    """Select the active expert's output into the designated buffer.

    Args:
      mode: int32 scalar (or shape ``(1,)``); ``0`` selects ``designated``
        (no-op path), ``k > 0`` selects ``alternatives[k - 1]`` (copy path).
      alternatives: ``(n_alt, rows, cols)`` stacked non-designated expert
        outputs.
      designated: ``(rows, cols)`` designated buffer (donated / aliased to
        the output).
      block_rows / block_cols: VMEM tile shape; rows/cols must divide evenly.
      interpret: run in Pallas interpret mode (CPU validation).

    Returns:
      ``(rows, cols)`` array aliased onto ``designated``.
    """
    rows, cols = designated.shape
    n_alt = alternatives.shape[0]
    if alternatives.shape[1:] != (rows, cols):
        raise ValueError(
            f"alternatives {alternatives.shape} vs designated {designated.shape}"
        )
    block_rows = min(block_rows, rows)
    block_cols = min(block_cols, cols)
    if rows % block_rows or cols % block_cols:
        raise ValueError(
            f"shape ({rows},{cols}) not divisible by block "
            f"({block_rows},{block_cols}); use ops.switch_select for padding"
        )

    mode = jnp.asarray(mode, jnp.int32).reshape((1,))
    grid = (rows // block_rows, cols // block_cols)

    def _sel(mode_ref, i, j):
        z = jnp.zeros_like(i)
        keep = mode_ref[0] == 0
        return jnp.where(keep, z, i), jnp.where(keep, z, j)

    def alt_index(i, j, mode_ref):
        k = jnp.maximum(mode_ref[0] - 1, 0)
        bi, bj = _sel(mode_ref, i, j)
        return (k, bi, bj)

    def des_index(i, j, mode_ref):
        del i, j, mode_ref
        return (0, 0)

    def out_index(i, j, mode_ref):
        return _sel(mode_ref, i, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_rows, block_cols), alt_index),
            pl.BlockSpec((block_rows, block_cols), des_index),
        ],
        out_specs=pl.BlockSpec((block_rows, block_cols), out_index),
    )

    return pl.pallas_call(
        _switch_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rows, cols), designated.dtype),
        input_output_aliases={2: 0},  # designated buffer -> output (zero-gap)
        interpret=interpret,
    )(mode, alternatives, designated)


# -- batched multi-UE variant -------------------------------------------------


def _switch_kernel_batched(modes_ref, alt_ref, des_ref, out_ref):
    """Per-UE copy-or-refresh: grid dim 0 walks UEs, dims 1-2 walk tiles."""
    u = pl.program_id(0)
    mode = modes_ref[u]

    @pl.when(mode == 0)
    def _noop_path():
        out_ref[...] = des_ref[...]

    @pl.when(mode != 0)
    def _copy_path():
        out_ref[...] = alt_ref[0]


def switch_select_batched_2d(
    modes: jax.Array,
    alternatives: jax.Array,
    designated: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_cols: int = DEFAULT_BLOCK_COLS,
    interpret: bool = False,
) -> jax.Array:
    """Per-UE switch: UE ``u`` keeps or copies according to ``modes[u]``.

    The multi-UE slot engine runs different experts for different UEs in the
    same slot; this kernel extends the scalar-mode contract with a leading UE
    axis.  Grid dimension 0 walks UEs, so each UE independently takes the
    no-op path (``modes[u] == 0``: only tile ``(u, 0, 0)`` is round-tripped)
    or the coalesced-copy path (``modes[u] == k > 0``: expert ``k-1``'s
    slice is copied tile-by-tile into UE ``u``'s designated buffer).

    Args:
      modes: ``(n_ues,)`` int32 per-UE mode vector.
      alternatives: ``(n_alt, n_ues, rows, cols)`` stacked non-designated
        expert outputs.
      designated: ``(n_ues, rows, cols)`` designated buffers (aliased to the
        output).

    Returns:
      ``(n_ues, rows, cols)`` array aliased onto ``designated``.
    """
    n_ues, rows, cols = designated.shape
    if alternatives.shape[1:] != (n_ues, rows, cols):
        raise ValueError(
            f"alternatives {alternatives.shape} vs designated {designated.shape}"
        )
    if modes.shape != (n_ues,):
        raise ValueError(f"modes {modes.shape} vs n_ues {n_ues}")
    block_rows = min(block_rows, rows)
    block_cols = min(block_cols, cols)
    if rows % block_rows or cols % block_cols:
        raise ValueError(
            f"shape ({rows},{cols}) not divisible by block "
            f"({block_rows},{block_cols}); use ops.switch_select for padding"
        )

    modes = jnp.asarray(modes, jnp.int32)
    grid = (n_ues, rows // block_rows, cols // block_cols)

    def _sel(modes_ref, u, i, j):
        z = jnp.zeros_like(i)
        keep = modes_ref[u] == 0
        return jnp.where(keep, z, i), jnp.where(keep, z, j)

    def alt_index(u, i, j, modes_ref):
        k = jnp.maximum(modes_ref[u] - 1, 0)
        bi, bj = _sel(modes_ref, u, i, j)
        return (k, u, bi, bj)

    def des_index(u, i, j, modes_ref):
        del i, j, modes_ref
        return (u, 0, 0)

    def out_index(u, i, j, modes_ref):
        bi, bj = _sel(modes_ref, u, i, j)
        return (u, bi, bj)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_rows, block_cols), alt_index),
            pl.BlockSpec((1, block_rows, block_cols), des_index),
        ],
        out_specs=pl.BlockSpec((1, block_rows, block_cols), out_index),
    )

    return pl.pallas_call(
        _switch_kernel_batched,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_ues, rows, cols), designated.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(modes, alternatives, designated)


# -- compaction-gated variant -------------------------------------------------


def _gather_kernel_batched(src_ref, compact_ref, des_ref, out_ref):
    """Per-UE un-compaction: copy a compact-sub-batch row or keep the buffer."""
    u = pl.program_id(0)
    src = src_ref[u]

    @pl.when(src < 0)
    def _noop_path():
        out_ref[...] = des_ref[...]

    @pl.when(src >= 0)
    def _copy_path():
        out_ref[...] = compact_ref[...]


def switch_gather_batched_2d(
    src: jax.Array,
    compact: jax.Array,
    designated: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_cols: int = DEFAULT_BLOCK_COLS,
    interpret: bool = False,
) -> jax.Array:
    """Scatter a dense capacity-``K`` sub-batch back over the full UE batch.

    The gated execution path runs the expensive expert only on the UEs that
    selected it, compacted into ``compact``'s leading axis; this kernel fuses
    selection and un-compaction into one pass over the designated buffers:
    UE ``u`` keeps its buffer (the cheap-expert baseline) when
    ``src[u] < 0`` — same single-tile no-op path as the scalar kernel — or
    receives row ``src[u]`` of the compact sub-batch otherwise (coalesced
    copy, tile-for-warp the paper's switch semantics with a gather
    indirection steering the DMA source).

    Args:
      src: ``(n_ues,)`` int32; ``src[u] >= 0`` is UE ``u``'s row in the
        compact sub-batch, ``src[u] < 0`` keeps the designated buffer.
      compact: ``(capacity, rows, cols)`` dense sub-batch of the gated
        expert's outputs (``capacity >= 1``; rows past the last selected UE
        are padding and must never be referenced by ``src``).
      designated: ``(n_ues, rows, cols)`` designated buffers holding the
        baseline expert's outputs (aliased to the output).

    Returns:
      ``(n_ues, rows, cols)`` array aliased onto ``designated``.
    """
    n_ues, rows, cols = designated.shape
    capacity = compact.shape[0]
    if compact.shape[1:] != (rows, cols):
        raise ValueError(f"compact {compact.shape} vs designated {designated.shape}")
    if capacity < 1:
        raise ValueError("capacity must be >= 1 (skip the kernel when 0)")
    if src.shape != (n_ues,):
        raise ValueError(f"src {src.shape} vs n_ues {n_ues}")
    block_rows = min(block_rows, rows)
    block_cols = min(block_cols, cols)
    if rows % block_rows or cols % block_cols:
        raise ValueError(
            f"shape ({rows},{cols}) not divisible by block "
            f"({block_rows},{block_cols}); use ops.switch_scatter for padding"
        )

    src = jnp.asarray(src, jnp.int32)
    grid = (n_ues, rows // block_rows, cols // block_cols)

    def _sel(src_ref, u, i, j):
        z = jnp.zeros_like(i)
        keep = src_ref[u] < 0
        return jnp.where(keep, z, i), jnp.where(keep, z, j)

    def compact_index(u, i, j, src_ref):
        k = jnp.maximum(src_ref[u], 0)
        bi, bj = _sel(src_ref, u, i, j)
        return (k, bi, bj)

    def des_index(u, i, j, src_ref):
        del i, j, src_ref
        return (u, 0, 0)

    def out_index(u, i, j, src_ref):
        bi, bj = _sel(src_ref, u, i, j)
        return (u, bi, bj)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_rows, block_cols), compact_index),
            pl.BlockSpec((1, block_rows, block_cols), des_index),
        ],
        out_specs=pl.BlockSpec((1, block_rows, block_cols), out_index),
    )

    return pl.pallas_call(
        _gather_kernel_batched,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_ues, rows, cols), designated.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(src, compact, designated)
