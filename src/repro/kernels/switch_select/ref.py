"""Pure-jnp oracle for the ARCHES switch kernel.

Semantics (paper 3.2): downstream reads the designated buffer; after the
switch, that buffer holds the output of the expert selected by ``mode``
(``0`` = designated expert, ``k > 0`` = ``alternatives[k - 1]``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def switch_select_ref(
    mode: jax.Array, alternatives: jax.Array, designated: jax.Array
) -> jax.Array:
    """Reference: the post-switch contents of the designated buffer."""
    mode = jnp.asarray(mode, jnp.int32).reshape(())
    stacked = jnp.concatenate([designated[None], alternatives], axis=0)
    return jnp.take(stacked, mode, axis=0)


def switch_select_tree_ref(mode: jax.Array, outputs: list) -> jax.Array:
    """Reference over a list of per-expert pytrees: pick ``outputs[mode]``."""
    mode = jnp.asarray(mode, jnp.int32).reshape(())
    return jax.tree.map(
        lambda *leaves: jnp.take(jnp.stack(leaves, axis=0), mode, axis=0),
        *outputs,
    )


def switch_select_batched_ref(
    modes: jax.Array, alternatives: jax.Array, designated: jax.Array
) -> jax.Array:
    """Per-UE reference: UE ``u``'s buffer holds expert ``modes[u]``'s output.

    ``alternatives`` is ``(n_alt, n_ues, ...)``, ``designated`` ``(n_ues, ...)``.
    """
    modes = jnp.asarray(modes, jnp.int32)
    stacked = jnp.concatenate([designated[None], alternatives], axis=0)
    return jnp.take_along_axis(
        stacked,
        modes.reshape((1, -1) + (1,) * (designated.ndim - 1)),
        axis=0,
    )[0]


def switch_select_batched_tree_ref(modes: jax.Array, outputs: list):
    """Per-UE reference over per-expert pytrees with a leading UE axis."""
    modes = jnp.asarray(modes, jnp.int32)

    def leaf(*leaves):
        stacked = jnp.stack(leaves, axis=0)  # (n_experts, n_ues, ...)
        idx = modes.reshape((1, -1) + (1,) * (stacked.ndim - 2))
        return jnp.take_along_axis(stacked, idx, axis=0)[0]

    return jax.tree.map(leaf, *outputs)


def switch_gather_batched_ref(
    src: jax.Array, compact: jax.Array, designated: jax.Array
) -> jax.Array:
    """Un-compaction reference: UE ``u`` takes compact row ``src[u]`` when
    ``src[u] >= 0`` and keeps its designated buffer otherwise.

    ``compact`` is ``(capacity, ...)``, ``designated`` ``(n_ues, ...)``.
    Pure gather + select — bitwise-equal to the Pallas kernel by
    construction (no arithmetic touches the payload).
    """
    src = jnp.asarray(src, jnp.int32)
    safe = jnp.clip(src, 0, compact.shape[0] - 1)
    taken = jnp.take(compact, safe, axis=0)  # (n_ues, ...)
    keep = (src < 0).reshape((-1,) + (1,) * (designated.ndim - 1))
    return jnp.where(keep, designated, taken)


def switch_gather_batched_tree_ref(src: jax.Array, compact, designated):
    """``switch_gather_batched_ref`` over per-expert pytrees, leaf-wise."""
    return jax.tree.map(
        lambda c, d: switch_gather_batched_ref(src, c, d), compact, designated
    )
