"""Pure-jnp oracle for the ARCHES switch kernel.

Semantics (paper 3.2): downstream reads the designated buffer; after the
switch, that buffer holds the output of the expert selected by ``mode``
(``0`` = designated expert, ``k > 0`` = ``alternatives[k - 1]``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def switch_select_ref(
    mode: jax.Array, alternatives: jax.Array, designated: jax.Array
) -> jax.Array:
    """Reference: the post-switch contents of the designated buffer."""
    mode = jnp.asarray(mode, jnp.int32).reshape(())
    stacked = jnp.concatenate([designated[None], alternatives], axis=0)
    return jnp.take(stacked, mode, axis=0)


def switch_select_tree_ref(mode: jax.Array, outputs: list) -> jax.Array:
    """Reference over a list of per-expert pytrees: pick ``outputs[mode]``."""
    mode = jnp.asarray(mode, jnp.int32).reshape(())
    return jax.tree.map(
        lambda *leaves: jnp.take(jnp.stack(leaves, axis=0), mode, axis=0),
        *outputs,
    )
