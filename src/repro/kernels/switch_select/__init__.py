from repro.kernels.switch_select.ops import (
    switch_scatter,
    switch_select,
    switch_select_leaf,
)
from repro.kernels.switch_select.ref import (
    switch_gather_batched_ref,
    switch_gather_batched_tree_ref,
    switch_select_ref,
    switch_select_tree_ref,
)
from repro.kernels.switch_select.switch_select import (
    switch_gather_batched_2d,
    switch_select_2d,
)

__all__ = [
    "switch_scatter",
    "switch_select",
    "switch_select_leaf",
    "switch_select_2d",
    "switch_select_ref",
    "switch_select_tree_ref",
    "switch_gather_batched_2d",
    "switch_gather_batched_ref",
    "switch_gather_batched_tree_ref",
]
