# Pallas TPU kernels for the ARCHES hot spots (validated on CPU via
# interpret=True; see per-kernel ref.py for the pure-jnp oracles):
#   switch_select — the paper's CUDA switch kernel (zero-gap output selection)
#   mmse_interp   — MMSE/Wiener frequency-domain interpolation (MXU matmul)
#   tree_infer    — vectorized decision-tree policy inference
