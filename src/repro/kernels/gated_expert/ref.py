"""Pure-jnp oracle for the fused gated expert kernel.

Literally the unfused composition the kernel replaces — gather the selected
UEs' inputs to a compact sub-batch, run the folded-GEMM expert, scatter the
results back over the baseline — built from the exact same jnp ops as
``ExpertBank._run_gated``'s unfused path, so bitwise equality with it holds
by construction (this is the CPU fallback, not just a test oracle).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.switch_select.ops import switch_scatter
from repro.phy.ai_estimator import ai_estimate_folded


def gated_expert_apply_ref(
    idx, src, h_ls, designated, folded, *, compute_dtype=None
):
    """Compact -> folded-GEMM expert -> scatter, unfused reference.

    Args:
      idx: ``(capacity,)`` int32 compact-row -> UE index map.
      src: ``(n_ues,)`` int32 UE -> compact-row map (negative == keep the
        designated baseline).
      h_ls: ``(n_ues, n_ant, n_dmrs_sym, n_pilot_sc)`` complex LS input.
      designated: ``(n_ues, n_ant, 1, n_sc, n_dmrs_sym)`` complex baseline.
      folded: pre-folded expert params (``fold_ai_params``).
      compute_dtype: GEMM operand dtype (``None`` = f32).

    Returns:
      The baseline with the gated expert's outputs scattered in.
    """
    compact_in = jnp.take(h_ls, idx, axis=0)
    compact_out = ai_estimate_folded(
        folded, compact_in, compute_dtype=compute_dtype
    )
    # the same jit'd scatter the unfused bank path calls, so both paths
    # trace to the same program on CPU (bitwise AND wall-time parity)
    return switch_scatter(src, compact_out, designated, backend="ref")
