"""Pallas TPU kernel: the fused gated expert hot path (ROADMAP "Raw speed").

The unfused gated path pays three ops per scan step — cumsum compaction
(gather to a capacity-``K`` sub-batch), the folded-GEMM AI expert on that
sub-batch, and the ``switch_scatter`` un-compaction.  Between them the
compact sub-batch is materialized in HBM twice (input gather out, expert
output in) before the scatter reads it back.

This kernel fuses all three: the gather indirection that
``switch_gather_batched_2d`` already uses to steer its DMA *source* becomes
the *input* stage of one ``pallas_call`` whose grid walks the ``K`` compact
rows.  Step ``k``:

* DMAs UE ``idx[k]``'s LS-input tile straight from the full batch (the
  compaction index vector is scalar-prefetched to SMEM so it can steer the
  BlockSpec index maps before the grid runs — no materialized sub-batch);
* runs the folded-GEMM expert forward on that one UE's tile in VMEM
  (``B = n_ant`` GEMM columns; per-column K-dim accumulation makes the
  result bitwise-identical to any batched evaluation of the same UE — the
  batch-composition property ``repro.phy.ai_estimator`` documents);
* writes the result directly into UE ``idx[k]``'s designated buffer, which
  the output *aliases* (``input_output_aliases``) — the scatter is just the
  output DMA.

Rows past the last selected UE (``valid[k] == 0`` — the capacity padding
the unfused path pays GEMM FLOPs for) identity-rewrite their UE's baseline
tile instead: ``idx`` is a slice of a permutation, so ``idx[k]`` is a
distinct, valid UE index even for padding rows, and the rewrite is a
single-tile round-trip, not a wasted forward pass.  UEs outside ``idx``
are never visited; aliasing leaves their baseline bytes untouched in HBM.

Layout contract (``ops.py`` builds these views): activations are the f32
real view ``(n_ues, 2, S, n_ant, n_pilot_sc)`` in, designated buffers the
real view ``(n_ues, 2, S, n_ant, n_sc)`` aliased in/out; folded parameter
matrices ride along as whole-array operands with constant index maps (they
are small and grid-invariant — resident in VMEM across steps).  On a real
TPU the trailing dims would additionally be padded to the lane quantum as
``switch_select/ops.py`` does; the CPU/CI path exercises the kernel in
interpret mode, where the reference suite pins bitwise equality.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.phy.ai_estimator import _forward_batched


def _split_folded(folded):
    """Split folded params into (static ints, array leaves, rebuild fn)."""
    arrays = {k: v for k, v in folded.items() if k not in ("kh", "width")}
    leaves, treedef = jax.tree.flatten(arrays)
    kh, width = int(folded["kh"]), int(folded["width"])

    def rebuild(vals):
        d = dict(jax.tree.unflatten(treedef, list(vals)))
        d["kh"] = kh
        d["width"] = width
        return d

    return leaves, rebuild


def gated_expert_fused(
    idx: jax.Array,
    valid: jax.Array,
    x_all: jax.Array,
    designated: jax.Array,
    folded: dict,
    *,
    compute_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Fused compact -> folded-GEMM expert -> scatter over real views.

    Args:
      idx: ``(capacity,)`` int32 — UE index of each compact row (a slice of
        a permutation: entries are distinct and in ``[0, n_ues)``).
      valid: ``(capacity,)`` int32 — 1 where the row is a selected UE
        (compute + scatter), 0 for capacity padding (identity rewrite).
      x_all: ``(n_ues, 2, S, n_ant, n_pilot_sc)`` f32 LS-input real view of
        the *full* batch; the kernel reads only rows named by ``idx``.
      designated: ``(n_ues, 2, S, n_ant, n_sc)`` f32 baseline real view
        (aliased to the output).
      folded: pre-folded expert params (``fold_ai_params``).
      compute_dtype: GEMM operand dtype (``None`` = f32 bitwise path,
        ``jnp.bfloat16`` = half the MXU operand bytes, f32 accumulation).
      interpret: run in Pallas interpret mode (CPU validation).

    Returns:
      ``(n_ues, 2, S, n_ant, n_sc)`` array aliased onto ``designated``.
    """
    capacity = idx.shape[0]
    n_ues, two, n_sym, n_ant, n_p = x_all.shape
    n_sc = designated.shape[-1]
    if two != 2 or designated.shape[:-1] != (n_ues, 2, n_sym, n_ant):
        raise ValueError(f"x_all {x_all.shape} vs designated {designated.shape}")
    if valid.shape != (capacity,):
        raise ValueError(f"valid {valid.shape} vs idx {idx.shape}")

    idx = jnp.asarray(idx, jnp.int32)
    valid = jnp.asarray(valid, jnp.int32)
    leaves, rebuild = _split_folded(folded)

    def kernel(idx_ref, valid_ref, x_ref, des_ref, *rest):
        *leaf_refs, out_ref = rest
        k = pl.program_id(0)

        @pl.when(valid_ref[k] == 1)
        def _compute_path():
            # (2, S, ant, Np) channel-leading block == the batched forward's
            # (C, W, B, H) layout with B = n_ant: same GEMM column per
            # (antenna, subcarrier), so bitwise-equal to the dense batch.
            fold_vals = rebuild([r[...] for r in leaf_refs])
            out_ref[0] = _forward_batched(fold_vals, x_ref[0], compute_dtype)

        @pl.when(valid_ref[k] == 0)
        def _pad_path():
            out_ref[...] = des_ref[...]

    def x_index(k, idx_ref, valid_ref):
        del valid_ref
        return (idx_ref[k], 0, 0, 0, 0)

    def des_index(k, idx_ref, valid_ref):
        del valid_ref
        return (idx_ref[k], 0, 0, 0, 0)

    def const_index(shape):
        zeros = (0,) * len(shape)

        def index(k, idx_ref, valid_ref):
            del k, idx_ref, valid_ref
            return zeros

        return index

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(capacity,),
        in_specs=[
            pl.BlockSpec((1, 2, n_sym, n_ant, n_p), x_index),
            pl.BlockSpec((1, 2, n_sym, n_ant, n_sc), des_index),
        ]
        + [pl.BlockSpec(leaf.shape, const_index(leaf.shape)) for leaf in leaves],
        out_specs=pl.BlockSpec((1, 2, n_sym, n_ant, n_sc), des_index),
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(designated.shape, designated.dtype),
        input_output_aliases={3: 0},  # designated buffer -> output (zero-gap)
        interpret=interpret,
    )(idx, valid, x_all, designated, *leaves)
