"""Public wrapper for the fused gated expert hot path.

(Not jit'd at this level — ``folded`` carries static ints and the op always
runs inside the engine's already-jitted scan body.)

Handles what the raw kernel does not: complex-to-real viewing and the
layout transposes between the engine's ``(U, ant, S, Np)`` LS input /
``(U, ant, 1, n_sc, S)`` estimate contract and the kernel's channel-leading
real views, plus backend dispatch — the Pallas kernel on TPU, the unfused
jnp reference (``ref.py``) as the CPU fallback, mirroring
``switch_scatter``'s discipline.  All the view plumbing is pure data
movement (complex split/assemble, transposes): for kept UEs the baseline
bytes round-trip untouched, and for computed UEs the kernel emits the same
f32 pairs the reference assembles, so every backend is bitwise-equal.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gated_expert import gated_expert as _k
from repro.kernels.gated_expert.ref import gated_expert_apply_ref
from repro.kernels.switch_select.ops import _use_interpret


def gated_expert_apply(
    idx,
    src,
    h_ls,
    designated,
    folded,
    *,
    compute_dtype=None,
    backend: str = "auto",
    interpret: bool | None = None,
):
    """Run the gated AI expert fused: compact -> folded GEMM -> scatter.

    One kernel replaces the unfused gather / expert / ``switch_scatter``
    triple: the compaction index vector steers the input DMA (no
    materialized capacity-``K`` sub-batch in HBM) and the output aliases
    the baseline buffers (the scatter is the output DMA).  Under the
    sharded engine this runs inside ``shard_map`` on shard-local operands —
    per-shard compaction means no collective (the distributed tests audit
    the lowered HLO).

    Args:
      idx: ``(capacity,)`` int32 — UE index of each compact row (a slice of
        a permutation; rows past the last selected UE name arbitrary
        distinct non-selected UEs and are treated as padding).
      src: ``(n_ues,)`` int32 — UE -> compact-row map; negative keeps the
        baseline.  ``valid`` padding flags are derived as ``src[idx] >= 0``.
      h_ls: ``(n_ues, n_ant, n_dmrs_sym, n_pilot_sc)`` complex LS input.
      designated: ``(n_ues, n_ant, 1, n_sc, n_dmrs_sym)`` complex baseline
        estimates (aliased through the kernel path).
      folded: pre-folded expert params (``fold_ai_params``).
      compute_dtype: ``None`` (f32, bitwise) or ``jnp.bfloat16`` (half the
        GEMM operand bytes, f32 accumulation).
      backend: ``"pallas"`` (fused kernel), ``"ref"`` (unfused jnp) or
        ``"auto"`` — pallas on TPU, ref as the CPU fallback.
      interpret: force Pallas interpret mode (tests); default = non-TPU.

    Returns:
      The baseline pytree with the gated expert's outputs scattered in.
    """
    idx = jnp.asarray(idx, jnp.int32)
    src = jnp.asarray(src, jnp.int32)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return gated_expert_apply_ref(
            idx, src, h_ls, designated, folded, compute_dtype=compute_dtype
        )
    if backend != "pallas":
        raise ValueError(f"unknown gated_expert_apply backend {backend!r}")
    if interpret is None:
        interpret = _use_interpret()

    n_ues, n_ant, n_sym, n_p = h_ls.shape
    # LS input -> kernel real view (U, 2, S, ant, Np)
    x_all = jnp.transpose(
        jnp.stack([h_ls.real, h_ls.imag], axis=0).astype(jnp.float32),
        (1, 0, 3, 2, 4),
    )
    # baseline (U, ant, 1, n_sc, S) -> kernel real view (U, 2, S, ant, n_sc)
    b = designated[:, :, 0]
    des_view = jnp.transpose(
        jnp.stack([b.real, b.imag], axis=1).astype(jnp.float32),
        (0, 1, 4, 2, 3),
    )
    valid = (jnp.take(src, idx) >= 0).astype(jnp.int32)
    out = _k.gated_expert_fused(
        idx, valid, x_all, des_view, folded,
        compute_dtype=compute_dtype, interpret=interpret,
    )
    # undo the real view: same assembly as ai_estimate_folded's epilogue
    h = (out[:, 0] + 1j * out[:, 1]).astype(jnp.complex64)  # (U, S, ant, sc)
    return jnp.transpose(h, (0, 2, 3, 1))[:, :, None]  # (U, ant, 1, sc, S)
