from repro.kernels.gated_expert.gated_expert import gated_expert_fused
from repro.kernels.gated_expert.ops import gated_expert_apply
from repro.kernels.gated_expert.ref import gated_expert_apply_ref

__all__ = [
    "gated_expert_apply",
    "gated_expert_apply_ref",
    "gated_expert_fused",
]
