"""Fault-tolerant checkpointing: atomic writes, keep-k, restart-from-latest.

Design for the 1000+-node target (DESIGN.md):

* **Atomicity** — a checkpoint is written to ``step_<n>.tmp-<nonce>/`` and
  ``os.rename``d into place only after every leaf and the manifest are
  fsync'd; a crash mid-write can never corrupt the restore path (rename is
  atomic on POSIX).
* **Restart-from-latest** — ``latest_step`` scans for complete checkpoints
  only (manifest present); the training loop resumes from there after any
  failure, which is the recovery half of the paper's fail-safe principle
  applied to training.
* **Keep-k** — bounded disk usage under long runs (``keep=None`` retains
  everything, which delta chains require).
* **bf16-safe** — bfloat16 leaves round-trip as uint16 payloads + dtype tag
  (numpy has no native bf16).
* **Delta chains** — a step may be tagged (via ``manifest_extra``) as an
  *incremental* checkpoint carrying only what changed since the previous
  step.  ``resume_chain`` walks backwards from the latest complete step
  through the tagged deltas until it reaches either step 1 (the chain
  covers the whole run) or an untagged *monolithic* checkpoint that anchors
  the prefix — which is exactly how a directory written by the legacy
  full-state writer, then continued by the delta writer, stays resumable.
* At real scale each host writes only its addressable shards; here the
  process is single-host, so the shard index is trivially [0] — the layout
  (per-leaf files + JSON manifest) is the multi-host-ready one.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"

#: ``manifest_extra["kind"]`` tag marking a step as an incremental delta in
#: a manifest-chained sequence (``manifest_extra["prev_step"]`` names its
#: predecessor).  Untagged checkpoints are monolithic (full-state) — the
#: legacy streaming format stays loadable as a chain anchor.
STREAMING_DELTA_KIND = "arches-streaming-delta-v1"


class CheckpointMismatchError(ValueError):
    """The stored checkpoint does not match the restore template.

    Raised by ``restore_pytree`` when the on-disk treedef, a leaf's shape
    or a leaf's dtype disagrees with the template — instead of silently
    casting (the old behaviour) or unflattening a wrong-structure tree.
    """


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save_pytree(
    tree: Any, directory: str, *, manifest_extra: dict | None = None
) -> None:
    """Atomically write ``tree`` to ``directory``.

    ``manifest_extra`` (plain-JSON dict) is merged into the manifest
    document — the delta-chain writer stores its ``kind``/``prev_step``
    linkage there so chain membership is part of the same atomic publish
    as the payload.  ``leaves``/``treedef`` keys are reserved.
    """
    parent = os.path.dirname(os.path.abspath(directory)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=os.path.basename(directory) + ".tmp-", dir=parent)
    try:
        manifest = {}
        for i, (key, leaf) in enumerate(_leaf_paths(tree)):
            arr = np.asarray(leaf)
            dtype_tag = str(leaf.dtype) if hasattr(leaf, "dtype") else str(arr.dtype)
            if dtype_tag == "bfloat16":
                arr = np.asarray(jnp.asarray(leaf).view(jnp.uint16))
            fname = f"leaf_{i:05d}.npy"
            with open(os.path.join(tmp, fname), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            manifest[key] = {"file": fname, "dtype": dtype_tag, "shape": list(arr.shape)}
        treedef = jax.tree_util.tree_structure(tree)
        doc = dict(manifest_extra or {})
        if "leaves" in doc or "treedef" in doc:
            raise ValueError("manifest_extra may not override leaves/treedef")
        doc.update({"leaves": manifest, "treedef": str(treedef)})
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(doc, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(directory):
            shutil.rmtree(directory)
        os.rename(tmp, directory)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def restore_pytree(template: Any, directory: str) -> Any:
    """Restore into the structure of ``template``.

    The stored checkpoint must *match* the template: same treedef, and per
    leaf the same shape and dtype.  Any disagreement raises
    ``CheckpointMismatchError`` — a checkpoint written by a different
    program must never be silently cast/reshaped into this one.
    """
    with open(os.path.join(directory, _MANIFEST)) as f:
        stored = json.load(f)
    manifest = stored["leaves"]
    treedef = jax.tree_util.tree_structure(template)
    if stored.get("treedef") is not None and stored["treedef"] != str(treedef):
        raise CheckpointMismatchError(
            f"checkpoint treedef mismatch in {directory}:\n"
            f"  stored:   {stored['treedef']}\n"
            f"  template: {treedef}"
        )
    leaves = []
    for key, leaf in _leaf_paths(template):
        if key not in manifest:
            raise CheckpointMismatchError(
                f"checkpoint {directory} has no leaf {key!r}"
            )
        meta = manifest[key]
        t_dtype = str(leaf.dtype) if hasattr(leaf, "dtype") else str(
            np.asarray(leaf).dtype
        )
        if meta["dtype"] != t_dtype:
            raise CheckpointMismatchError(
                f"leaf {key!r} dtype mismatch in {directory}: stored "
                f"{meta['dtype']}, template {t_dtype}"
            )
        t_shape = list(np.shape(leaf))
        # bf16 payloads are stored as same-shape uint16, so the manifest
        # shape is directly comparable for every dtype
        if list(meta["shape"]) != t_shape:
            raise CheckpointMismatchError(
                f"leaf {key!r} shape mismatch in {directory}: stored "
                f"{meta['shape']}, template {t_shape}"
            )
        arr = np.load(os.path.join(directory, meta["file"]))
        if meta["dtype"] == "bfloat16":
            arr = jnp.asarray(arr).view(jnp.bfloat16)
        else:
            arr = jnp.asarray(arr)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_pytree(directory: str) -> Any:
    """Load a checkpoint without a template, as nested dicts.

    The manifest's ``a/b/c`` leaf keys rebuild a nested-``dict`` tree —
    exact for checkpoints whose pytree was all-dicts (the streaming resume
    state), and a plain-data view of any other checkpoint.  Leaves come
    back as ``jnp`` arrays (bf16 restored from its uint16 payload).
    """
    with open(os.path.join(directory, _MANIFEST)) as f:
        manifest = json.load(f)["leaves"]
    out: dict = {}
    for key, meta in manifest.items():
        arr = np.load(os.path.join(directory, meta["file"]))
        if meta["dtype"] == "bfloat16":
            arr = jnp.asarray(arr).view(jnp.bfloat16)
        else:
            arr = jnp.asarray(arr)
        node = out
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return out


def read_manifest_extra(directory: str) -> dict:
    """The non-payload fields of a checkpoint's manifest document.

    Everything ``save_pytree`` was handed as ``manifest_extra`` (empty for
    checkpoints written without one, including every pre-delta legacy
    checkpoint).
    """
    with open(os.path.join(directory, _MANIFEST)) as f:
        doc = json.load(f)
    return {k: v for k, v in doc.items() if k not in ("leaves", "treedef")}


def checkpoint_kind(directory: str) -> str | None:
    """A checkpoint's ``kind`` tag (None == untagged, i.e. monolithic)."""
    return read_manifest_extra(directory).get("kind")


def resume_chain(root: str) -> tuple[int | None, list[int]]:
    """Resolve the restore path for a delta-chained checkpoint directory.

    Returns ``(anchor, deltas)``: ``deltas`` is the ascending run of
    ``STREAMING_DELTA_KIND`` steps ending at the latest complete step, and
    ``anchor`` is the monolithic checkpoint the chain builds on (``None``
    when the chain reaches back to step 1 and therefore replays from the
    initial state — or when the directory is empty).  A directory whose
    latest step is monolithic returns ``(latest, [])``: the legacy
    restore path, unchanged.

    Raises ``CheckpointMismatchError`` on a broken chain — a delta whose
    recorded ``prev_step`` is missing from disk (e.g. garbage-collected):
    an incremental checkpoint without its prefix restores nothing.
    """
    steps = list_steps(root)
    if not steps:
        return None, []
    present = set(steps)
    deltas: list[int] = []
    s = steps[-1]
    while s >= 1 and s in present:
        d = os.path.join(root, f"step_{s:08d}")
        if checkpoint_kind(d) != STREAMING_DELTA_KIND:
            return s, deltas[::-1]
        prev = read_manifest_extra(d).get("prev_step")
        prev = s - 1 if prev is None else int(prev)
        deltas.append(s)
        s = prev
    if s >= 1:
        raise CheckpointMismatchError(
            f"delta chain in {root} is broken: step {deltas[-1]}'s "
            f"predecessor {s} is missing (complete steps: {steps})"
        )
    return None, deltas[::-1]


def list_steps(root: str) -> list[int]:
    """All *complete* checkpoint steps under ``root``, ascending.

    Complete means the atomic rename landed and the manifest exists — a
    crash mid-write leaves only ``.tmp-`` litter, which is excluded.  This
    is the checkpoint *lineage* the service's status API reports per
    campaign.
    """
    if not os.path.isdir(root):
        return []
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and not ".tmp-" in name:
            if os.path.exists(os.path.join(root, name, _MANIFEST)):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
    return sorted(steps)


def latest_step(root: str) -> int | None:
    """Newest *complete* checkpoint step under ``root`` (None if none)."""
    steps = list_steps(root)
    return steps[-1] if steps else None


class CheckpointManager:
    """save-every / keep-k / restore-latest policy around the atomic store.

    ``keep=None`` disables garbage collection entirely — required for delta
    chains, where pruning an early step would orphan every later delta.
    """

    def __init__(
        self, root: str, *, save_every: int = 100, keep: int | None = 3
    ):
        self.root = root
        self.save_every = save_every
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    def dir_for(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def maybe_save(
        self,
        step: int,
        tree: Any,
        *,
        force: bool = False,
        manifest_extra: dict | None = None,
    ) -> bool:
        if not force and (step == 0 or step % self.save_every):
            return False
        save_pytree(tree, self.dir_for(step), manifest_extra=manifest_extra)
        self._gc()
        return True

    def restore_latest(self, template: Any) -> tuple[int, Any] | None:
        step = latest_step(self.root)
        if step is None:
            return None
        return step, restore_pytree(template, self.dir_for(step))

    def steps(self) -> list[int]:
        """Complete checkpoint steps currently retained, ascending."""
        return list_steps(self.root)

    def _gc(self) -> None:
        if self.keep is None:
            return
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.root)
            if n.startswith("step_") and ".tmp-" not in n
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir_for(s), ignore_errors=True)
