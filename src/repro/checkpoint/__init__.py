from repro.checkpoint.store import (
    CheckpointManager,
    CheckpointMismatchError,
    latest_step,
    load_pytree,
    restore_pytree,
    save_pytree,
)

__all__ = [
    "CheckpointManager",
    "CheckpointMismatchError",
    "latest_step",
    "load_pytree",
    "restore_pytree",
    "save_pytree",
]
