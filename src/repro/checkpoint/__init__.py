from repro.checkpoint.store import (
    STREAMING_DELTA_KIND,
    CheckpointManager,
    CheckpointMismatchError,
    checkpoint_kind,
    latest_step,
    list_steps,
    load_pytree,
    read_manifest_extra,
    restore_pytree,
    resume_chain,
    save_pytree,
)

__all__ = [
    "STREAMING_DELTA_KIND",
    "CheckpointManager",
    "CheckpointMismatchError",
    "checkpoint_kind",
    "latest_step",
    "list_steps",
    "load_pytree",
    "read_manifest_extra",
    "restore_pytree",
    "resume_chain",
    "save_pytree",
]
