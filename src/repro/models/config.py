"""Unified model configuration covering all 10 assigned architectures.

One dataclass describes every family (dense / MoE / SSM / hybrid / enc-dec /
VLM backbone); per-arch constructor modules live in ``repro.configs.<id>``
and the registry here maps ``--arch <id>`` to them.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

import jax.numpy as jnp


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"
    ENC_DEC = "enc_dec"
    VLM = "vlm"


class Attention(str, enum.Enum):
    GLOBAL = "global"
    LOCAL = "local"  # sliding window


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0
    first_k_dense: int = 0  # leading dense layers (kimi/deepseek style)
    d_ff_dense: int = 0  # their FF width
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256  # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # default d_model // n_heads

    # attention variants
    qkv_bias: bool = False  # qwen1.5
    attn_softcap: float | None = None  # gemma2 (50.0)
    logit_softcap: float | None = None  # gemma2 (30.0)
    sliding_window: int | None = None  # gemma2 local layers (4096)
    local_global_pattern: bool = False  # gemma2 alternating
    parallel_block: bool = False  # command-r (attn + mlp in parallel)
    mrope_sections: tuple[int, int, int] | None = None  # qwen2-vl (t,h,w)
    rope_theta: float = 10000.0

    # MLP
    mlp_kind: str = "swiglu"  # swiglu | geglu | gelu
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm
    post_block_norm: bool = False  # gemma2 extra norms
    tie_embeddings: bool = False

    # family extras
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    attn_every: int = 0  # hybrid: shared attn block every k ssm layers
    n_encoder_layers: int = 0  # enc-dec
    encoder_seq: int = 1500  # whisper frame count (stub frontend)

    # numerics / training
    dtype: str = "bfloat16"
    remat: str = "block"  # none | block | full
    scan_layers: bool = True  # False unrolls (dry-run cost calibration)
    attn_scores_bf16: bool = False  # mixed-precision softmax (perf preset)
    norms_bf16: bool = False  # mixed-precision norms (perf preset)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family is Family.SSM

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k cell."""
        return self.family in (Family.SSM, Family.HYBRID)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    # -- parameter counts (exact, used for 6ND roofline maths) --------------

    def attn_params(self) -> int:
        hd = self.resolved_head_dim
        q = self.d_model * self.n_heads * hd
        kv = 2 * self.d_model * self.n_kv_heads * hd
        o = self.n_heads * hd * self.d_model
        bias = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + bias

    def mlp_params(self, d_ff: int | None = None) -> int:
        ff = self.d_ff if d_ff is None else d_ff
        n_mats = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        return n_mats * self.d_model * ff

    def ssm_params(self) -> int:
        assert self.ssm is not None
        s = self.ssm
        d_in = s.d_inner(self.d_model)
        nh = s.n_heads(self.d_model)
        conv_dim = d_in + 2 * s.n_groups * s.d_state
        in_proj = self.d_model * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
        conv = conv_dim * s.d_conv
        out_proj = d_in * self.d_model
        extras = nh * 2 + d_in  # A_log, D, norm
        return in_proj + conv + out_proj + extras

    def params_per_layer(self) -> int:
        """Decoder-side params for one layer (norms excluded, negligible)."""
        if self.family is Family.SSM:
            return self.ssm_params()
        if self.family is Family.HYBRID:
            return self.ssm_params()  # shared attn counted once in n_params
        if self.family is Family.MOE:
            m = self.moe
            per_expert = self.mlp_params(m.d_ff_expert)
            shared = m.n_shared_experts * self.mlp_params(m.d_ff_shared)
            router = self.d_model * m.n_experts
            return self.attn_params() + m.n_experts * per_expert + shared + router
        return self.attn_params() + self.mlp_params()

    def n_params(self) -> int:
        core = self.n_layers * self.params_per_layer()
        if self.family is Family.MOE and self.moe.first_k_dense:
            dense = self.attn_params() + self.mlp_params(self.moe.d_ff_dense)
            core += self.moe.first_k_dense * (dense - self.params_per_layer())
        if self.family is Family.HYBRID and self.attn_every:
            core += self.attn_params() + self.mlp_params()  # one shared block
        if self.family is Family.ENC_DEC:
            enc = self.n_encoder_layers * (self.attn_params() + self.mlp_params())
            dec_cross = self.n_layers * self.attn_params()  # cross-attn
            core += enc + dec_cross
        emb = self.vocab * self.d_model
        return core + emb * (1 if self.tie_embeddings else 2)

    def n_active_params(self) -> int:
        """Per-token active params (MoE: only routed experts count)."""
        if self.family is not Family.MOE:
            return self.n_params()
        m = self.moe
        active_layer = (
            self.attn_params()
            + m.top_k * self.mlp_params(m.d_ff_expert)
            + m.n_shared_experts * self.mlp_params(m.d_ff_shared)
            + self.d_model * m.n_experts
        )
        core = self.n_layers * active_layer
        if m.first_k_dense:
            dense = self.attn_params() + self.mlp_params(m.d_ff_dense)
            core += m.first_k_dense * (dense - active_layer)
        emb = self.vocab * self.d_model
        return core + emb * (1 if self.tie_embeddings else 2)


# -- input-shape cells ---------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeCell("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeCell("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeCell("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeCell("long_500k", 524288, 1, "decode")

ALL_SHAPES: tuple[ShapeCell, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(config: ModelConfig) -> tuple[ShapeCell, ...]:
    """The assigned shape set for an arch (long_500k only if sub-quadratic)."""
    if config.subquadratic:
        return ALL_SHAPES
    return (TRAIN_4K, PREFILL_32K, DECODE_32K)


# -- registry ------------------------------------------------------------------

ARCH_IDS: tuple[str, ...] = (
    "command-r-plus-104b",
    "granite-20b",
    "qwen1.5-110b",
    "gemma2-9b",
    "zamba2-7b",
    "mamba2-130m",
    "whisper-large-v3",
    "dbrx-132b",
    "kimi-k2-1t-a32b",
    "qwen2-vl-72b",
)


def get_config(arch: str, *, reduced: bool = False) -> ModelConfig:
    """Load ``repro.configs.<arch>`` and return its (full or smoke) config."""
    import importlib

    mod_name = "repro.configs." + arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(mod_name)
    return mod.reduced_config() if reduced else mod.full_config()
