"""Mixture-of-Experts FFN with top-k routing (dbrx: 16e top-4; kimi-k2:
384e top-8 + 1 shared expert, first layer dense).

Sort-based capacity dispatch (MegaBlocks-style): tokens are sorted by
assigned expert, truncated to a per-expert capacity, processed as one
(E, C, D) batched einsum per projection — so compiled FLOPs track
*active* params times the capacity factor, not ``n_experts`` (the dense
one-hot dispatch would inflate kimi-k2's compute 48x and its activations to
petabytes; that formulation is recorded as rejected in EXPERIMENTS.md
§Perf).  Expert weights are stacked on a leading ``experts`` axis sharded
over "model" (EP); capacity slots shard over ("pod", "data"), which is what
turns dispatch/combine into GSPMD all-to-alls — the TPU analogue of
DeepSeek-style a2a expert parallelism.

The paper-level connection (DESIGN.md 4): classical MoE routes *within* a
model; ARCHES switches *between* modules.  Both routing mechanisms live in
this repo — this file is the classical side, ``core/expert_bank.py`` the
ARCHES side.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.params import ParamDef

DEFAULT_CAPACITY_FACTOR = 1.25


def moe_defs(cfg: ModelConfig) -> dict[str, Any]:
    m = cfg.moe
    d, e, ff = cfg.d_model, m.n_experts, m.d_ff_expert
    defs: dict[str, Any] = {
        "router": ParamDef((d, e), ("embed", "experts"), init="small"),
        "w_gate": ParamDef((e, d, ff), ("experts", "embed", "ff")),
        "w_up": ParamDef((e, d, ff), ("experts", "embed", "ff")),
        "w_down": ParamDef((e, ff, d), ("experts", "ff", "embed")),
    }
    if m.n_shared_experts:
        sff = m.d_ff_shared * m.n_shared_experts
        defs["shared_gate"] = ParamDef((d, sff), ("embed", "ff"))
        defs["shared_up"] = ParamDef((d, sff), ("embed", "ff"))
        defs["shared_down"] = ParamDef((sff, d), ("ff", "embed"))
    return defs


def expert_capacity(
    n_tokens: int, n_experts: int, top_k: int, capacity_factor: float
) -> int:
    c = math.ceil(n_tokens * top_k * capacity_factor / n_experts)
    return max(8, -(-c // 8) * 8)  # sublane-align


def moe_ffn(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    *,
    capacity_factor: float = DEFAULT_CAPACITY_FACTOR,
) -> tuple[jax.Array, jax.Array]:
    """Top-k routed FFN. x (B, S, D) -> (y, aux_loss).

    Over-capacity tokens are dropped (receive only the shared-expert /
    residual path), standard for capacity-based TPU MoE.
    """
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    k = m.top_k
    e = m.n_experts
    xf = x.reshape(n, d)

    logits = jnp.einsum("nd,de->ne", xf, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)  # (N, K)
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    # Switch-style load-balancing auxiliary loss
    onehot_frac = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (n * k)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(onehot_frac * frac_probs) * m.aux_loss_coef

    # ---- sort-based dispatch ----
    e_flat = topi.reshape(-1)  # (N*K,) row-major: token-major order
    tok = jnp.repeat(jnp.arange(n), k)
    w_flat = topv.reshape(-1)
    order = jnp.argsort(e_flat, stable=True)
    se = e_flat[order]
    st = tok[order]
    sw = w_flat[order]
    counts = jnp.bincount(e_flat, length=e)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(n * k) - starts[se]
    cap = expert_capacity(n, e, k, capacity_factor)
    keep = pos_in_e < cap
    slot = se * cap + jnp.where(keep, pos_in_e, 0)

    xg = xf[st] * keep[:, None].astype(x.dtype)  # (N*K, D)
    xg = constrain(xg, ("moe_tokens", "embed_act"))
    xe = jnp.zeros((e * cap, d), x.dtype).at[slot].add(
        jnp.where(keep[:, None], xg, 0)
    )
    xe = constrain(xe.reshape(e, cap, d), ("experts", "moe_cap", "embed_act"))

    # ---- expert FFNs (batched over the expert axis) ----
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    h = constrain(jax.nn.silu(g) * u, ("experts", "moe_cap", "ff"))
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    ye = constrain(ye, ("experts", "moe_cap", "embed_act"))

    # ---- combine ----
    yg = ye.reshape(e * cap, d)[slot] * (sw * keep).astype(x.dtype)[:, None]
    y = jnp.zeros((n, d), x.dtype).at[st].add(yg)

    if m.n_shared_experts:
        sg = jax.nn.silu(jnp.einsum("nd,df->nf", xf, p["shared_gate"]))
        su = jnp.einsum("nd,df->nf", xf, p["shared_up"])
        y = y + jnp.einsum("nf,fd->nd", sg * su, p["shared_down"])

    y = y.reshape(b, s, d)
    return constrain(y, ("batch", "seq", "embed_act")), aux
