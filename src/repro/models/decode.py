"""KV/SSM-cache serving paths: prefill + single-token decode, per family.

Cache layouts (all stacked over layers, scanned):

  dense/moe/vlm  {"k","v"}: (L, B, S_max, KV, hd), plus scalar ``index``
  ssm            {"conv": (L, B, K-1, C), "ssm": (L, B, H, P, N)} — O(1) in
                 sequence length (what makes long_500k feasible)
  hybrid         ssm caches + per-invocation shared-attn KV caches
                 (G, B, S_max, KV, hd) for the G shared-block call sites
  enc_dec        decoder self KV + precomputed cross K/V (L, B, S_enc, KV, hd)

``prefill`` consumes the prompt and returns last-position logits only —
materializing (B, S, V) logits for the 32k-prefill cells would be hundreds
of GB (EXPERIMENTS.md Dry-run).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MoE
from repro.models.config import Family, ModelConfig
from repro.models.transformer import (
    _dense_block,
    _mamba_block_apply,
    _moe_block,
    embed,
    encode,
    unembed,
)

KV_AXES = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")


def init_cache(
    cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16
) -> dict[str, Any]:
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    c: dict[str, Any] = {"index": jnp.zeros((), jnp.int32)}
    if cfg.family in (Family.DENSE, Family.VLM, Family.MOE):
        c["k"] = jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dtype)
        c["v"] = jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dtype)
    elif cfg.family is Family.SSM:
        s = cfg.ssm
        d_in = s.d_inner(cfg.d_model)
        conv_dim = d_in + 2 * s.n_groups * s.d_state
        nh = s.n_heads(cfg.d_model)
        c["conv"] = jnp.zeros((cfg.n_layers, batch, s.d_conv - 1, conv_dim), dtype)
        c["ssm"] = jnp.zeros((cfg.n_layers, batch, nh, s.head_dim, s.d_state), dtype)
    elif cfg.family is Family.HYBRID:
        s = cfg.ssm
        d_in = s.d_inner(cfg.d_model)
        conv_dim = d_in + 2 * s.n_groups * s.d_state
        nh = s.n_heads(cfg.d_model)
        g = cfg.n_layers // cfg.attn_every
        c["conv"] = jnp.zeros((cfg.n_layers, batch, s.d_conv - 1, conv_dim), dtype)
        c["ssm"] = jnp.zeros((cfg.n_layers, batch, nh, s.head_dim, s.d_state), dtype)
        c["k"] = jnp.zeros((g, batch, max_seq, kv, hd), dtype)
        c["v"] = jnp.zeros((g, batch, max_seq, kv, hd), dtype)
    elif cfg.family is Family.ENC_DEC:
        c["k"] = jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dtype)
        c["v"] = jnp.zeros((cfg.n_layers, batch, max_seq, kv, hd), dtype)
        c["cross_k"] = jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, kv, hd), dtype)
        c["cross_v"] = jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, kv, hd), dtype)
    return c


def _constrain_cache(c: dict) -> dict:
    out = dict(c)
    for name in ("k", "v", "cross_k", "cross_v"):
        if name in c:
            out[name] = constrain(c[name], KV_AXES)
    if "ssm" in c:
        out["ssm"] = constrain(
            c["ssm"], ("layers", "batch", "ssm_heads", None, "ssm_state")
        )
        out["conv"] = constrain(c["conv"], ("layers", "batch", "conv", "ssm_inner"))
    return out


# -- prefill ----------------------------------------------------------------------


def prefill(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
    cache: dict[str, Any],
    *,
    encoder_frames: jax.Array | None = None,
) -> tuple[jax.Array, dict[str, Any]]:
    """Consume the prompt; returns (last-token logits (B, V), filled cache)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(positions[None], (3, b, s))
    x = embed(cfg, params, tokens)
    cache = dict(cache)
    max_seq = cache["k"].shape[2] if "k" in cache else 0

    def pad_kv(kv_pair):
        k, v = kv_pair  # (L, B, S, KV, hd) after stacking
        pad = max_seq - k.shape[2]
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)

    if cfg.family in (Family.DENSE, Family.VLM, Family.MOE):
        if cfg.local_global_pattern:
            x, ks, vs = _prefill_local_global(cfg, params, x, positions)
        else:
            blocks = params["blocks"]
            dense_first = cfg.family is Family.MOE and cfg.moe.first_k_dense
            if dense_first:
                dense_cfg = cfg.with_(d_ff=cfg.moe.d_ff_dense)

                def dbody(carry, p_layer):
                    y, (k, v) = _dense_block(
                        dense_cfg, p_layer, carry, positions=positions
                    )
                    return y, (k, v)

                x, (dks, dvs) = jax.lax.scan(dbody, x, params["dense_blocks"])

            if cfg.family is Family.MOE:
                def body(carry, p_layer):
                    y, (k, v), _aux = _moe_block(
                        cfg, p_layer, carry, positions=positions
                    )
                    return y, (k, v)
            else:
                def body(carry, p_layer):
                    y, (k, v) = _dense_block(cfg, p_layer, carry, positions=positions)
                    return y, (k, v)

            x, (ks, vs) = jax.lax.scan(body, x, blocks)
            if dense_first:
                ks = jnp.concatenate([dks, ks], axis=0)
                vs = jnp.concatenate([dvs, vs], axis=0)
        cache["k"], cache["v"] = pad_kv((ks, vs))

    elif cfg.family is Family.SSM:
        def body(carry, p_layer):
            y, new_c = _mamba_block_apply(cfg, p_layer, carry)
            return y, new_c

        x, stacked = jax.lax.scan(body, x, params["blocks"])
        cache["conv"] = stacked.conv.astype(cache["conv"].dtype)
        cache["ssm"] = stacked.ssm.astype(cache["ssm"].dtype)

    elif cfg.family is Family.HYBRID:
        x, cache = _hybrid_prefill(cfg, params, x, positions, cache, pad_kv)

    elif cfg.family is Family.ENC_DEC:
        assert encoder_frames is not None
        memory = encode(cfg, params, encoder_frames)
        dec_cfg = cfg.with_(rope_theta=0.0)
        pos_table = jnp.asarray(L.sinusoidal_positions(s, cfg.d_model), x.dtype)
        x = x + pos_table[None]

        def body(carry, p_layer):
            y, (k, v) = _dense_block(
                dec_cfg, p_layer, carry, positions=positions, cross_memory=memory
            )
            ck = jnp.einsum("bsd,dhk->bshk", memory, p_layer["cross_attn"]["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", memory, p_layer["cross_attn"]["wv"])
            return y, (k, v, ck, cv)

        x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["blocks"])
        cache["k"], cache["v"] = pad_kv((ks, vs))
        cache["cross_k"] = cks.astype(cache["cross_k"].dtype)
        cache["cross_v"] = cvs.astype(cache["cross_v"].dtype)

    cache["index"] = jnp.asarray(s, jnp.int32)
    cache = _constrain_cache(cache)
    last = x[:, -1:]
    logits = unembed(cfg, params, last)[:, 0]
    return logits, cache


def _prefill_local_global(cfg, params, x, positions):
    paired = jax.tree.map(
        lambda p: p.reshape(cfg.n_layers // 2, 2, *p.shape[1:]), params["blocks"]
    )

    def body(carry, p_pair):
        pl = jax.tree.map(lambda t: t[0], p_pair)
        pg = jax.tree.map(lambda t: t[1], p_pair)
        y, (kl, vl) = _dense_block(cfg, pl, carry, positions=positions, is_local=True)
        y, (kg, vg) = _dense_block(cfg, pg, y, positions=positions, is_local=False)
        return y, (jnp.stack([kl, kg]), jnp.stack([vl, vg]))

    x, (ks, vs) = jax.lax.scan(body, x, paired)  # (L/2, 2, B, S, KV, hd)
    ks = ks.reshape(cfg.n_layers, *ks.shape[2:])
    vs = vs.reshape(cfg.n_layers, *vs.shape[2:])
    return x, ks, vs


def _hybrid_prefill(cfg, params, x, positions, cache, pad_kv):
    k_every = cfg.attn_every
    n_groups, rem = divmod(cfg.n_layers, k_every)
    grouped = jax.tree.map(
        lambda p: p[: n_groups * k_every].reshape(n_groups, k_every, *p.shape[1:]),
        params["blocks"],
    )
    tail = jax.tree.map(lambda p: p[n_groups * k_every :], params["blocks"])

    def inner(carry, p_layer):
        y, new_c = _mamba_block_apply(cfg, p_layer, carry)
        return y, new_c

    convs, ssms, aks, avs = [], [], [], []
    for gi in range(n_groups):
        group = jax.tree.map(lambda p: p[gi], grouped)
        x, stacked = jax.lax.scan(inner, x, group)
        convs.append(stacked.conv)
        ssms.append(stacked.ssm)
        x, (k, v) = _dense_block(cfg, params["shared_attn"], x, positions=positions)
        aks.append(k)
        avs.append(v)
    if rem:
        x, stacked = jax.lax.scan(inner, x, tail)
        convs.append(stacked.conv)
        ssms.append(stacked.ssm)
    cache["conv"] = jnp.concatenate(convs, 0).astype(cache["conv"].dtype)
    cache["ssm"] = jnp.concatenate(ssms, 0).astype(cache["ssm"].dtype)
    ks, vs = jnp.stack(aks), jnp.stack(avs)
    cache["k"], cache["v"] = pad_kv((ks, vs))
    return x, cache


# -- decode -------------------------------------------------------------------------


def decode_step(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
    cache: dict[str, Any],
) -> tuple[jax.Array, dict[str, Any]]:
    """One new token per sequence. tokens (B, 1) -> (logits (B, V), cache)."""
    b = tokens.shape[0]
    idx = cache["index"]
    positions = jnp.broadcast_to(idx[None, None], (b, 1)).astype(jnp.int32)
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(positions[None], (3, b, 1))
    x = embed(cfg, params, tokens)
    new_cache = dict(cache)

    if cfg.family in (Family.DENSE, Family.VLM, Family.MOE):
        if cfg.local_global_pattern:
            x, ks, vs = _decode_local_global(cfg, params, x, positions, cache)
            new_cache["k"], new_cache["v"] = ks, vs
        else:
            dense_first = cfg.family is Family.MOE and cfg.moe.first_k_dense
            off = cfg.moe.first_k_dense if dense_first else 0
            if dense_first:
                dense_cfg = cfg.with_(d_ff=cfg.moe.d_ff_dense)

                def dbody(carry, xs):
                    p_layer, k_l, v_l = xs
                    y, (k2, v2) = _dense_block(
                        dense_cfg, p_layer, carry, positions=positions,
                        kv_cache=(k_l, v_l), cache_index=idx,
                    )
                    return y, (k2, v2)

                x, (dk, dv) = jax.lax.scan(
                    dbody, x,
                    (params["dense_blocks"], cache["k"][:off], cache["v"][:off]),
                )

            if cfg.family is Family.MOE:
                def body(carry, xs):
                    p_layer, k_l, v_l = xs
                    y, (k2, v2), _aux = _moe_block(
                        cfg, p_layer, carry, positions=positions,
                        kv_cache=(k_l, v_l), cache_index=idx,
                    )
                    return y, (k2, v2)
            else:
                def body(carry, xs):
                    p_layer, k_l, v_l = xs
                    y, (k2, v2) = _dense_block(
                        cfg, p_layer, carry, positions=positions,
                        kv_cache=(k_l, v_l), cache_index=idx,
                    )
                    return y, (k2, v2)

            x, (ks, vs) = jax.lax.scan(
                body, x, (params["blocks"], cache["k"][off:], cache["v"][off:])
            )
            if dense_first:
                ks = jnp.concatenate([dk, ks], 0)
                vs = jnp.concatenate([dv, vs], 0)
            new_cache["k"], new_cache["v"] = ks, vs

    elif cfg.family is Family.SSM:
        def body(carry, xs):
            p_layer, conv_l, ssm_l = xs
            y, c2 = _mamba_block_apply(
                cfg, p_layer, carry, cache=M.MambaCache(conv=conv_l, ssm=ssm_l)
            )
            return y, c2

        x, stacked = jax.lax.scan(
            body, x, (params["blocks"], cache["conv"], cache["ssm"])
        )
        new_cache["conv"] = stacked.conv.astype(cache["conv"].dtype)
        new_cache["ssm"] = stacked.ssm.astype(cache["ssm"].dtype)

    elif cfg.family is Family.HYBRID:
        x, new_cache = _hybrid_decode(cfg, params, x, positions, cache)

    elif cfg.family is Family.ENC_DEC:
        dec_cfg = cfg.with_(rope_theta=0.0)
        pos_row = jnp.asarray(
            L.sinusoidal_positions(cache["k"].shape[2], cfg.d_model), x.dtype
        )
        x = x + jax.lax.dynamic_slice_in_dim(pos_row, idx, 1, 0)[None]

        def body(carry, xs):
            p_layer, k_l, v_l, ck_l, cv_l = xs
            h = carry
            hn = L.apply_norm(dec_cfg, h, p_layer["norm_attn"])
            a, (k2, v2) = L.attention(
                dec_cfg, p_layer["attn"], hn, positions=positions,
                kv_cache=(k_l, v_l), cache_index=idx,
            )
            h = h + a
            hn = L.apply_norm(dec_cfg, h, p_layer["norm_cross"])
            # cross attention against precomputed cross K/V
            q = jnp.einsum("bsd,dhk->bshk", hn, p_layer["cross_attn"]["wq"])
            kh = L._expand_kv(ck_l, dec_cfg.n_heads)
            vh = L._expand_kv(cv_l, dec_cfg.n_heads)
            attn_out = L.dot_attention(q, kh, vh, None)
            c = jnp.einsum(
                "bshk,hkd->bsd", attn_out.astype(h.dtype),
                p_layer["cross_attn"]["wo"],
            )
            h = h + c
            hn = L.apply_norm(dec_cfg, h, p_layer["norm_mlp"])
            h = h + L.mlp(dec_cfg, p_layer["mlp"], hn)
            return h, (k2, v2)

        x, (ks, vs) = jax.lax.scan(
            body, x,
            (params["blocks"], cache["k"], cache["v"],
             cache["cross_k"], cache["cross_v"]),
        )
        new_cache["k"], new_cache["v"] = ks, vs

    new_cache["index"] = idx + 1
    new_cache = _constrain_cache(new_cache)
    logits = unembed(cfg, params, x)[:, 0]
    return logits, new_cache


def _decode_local_global(cfg, params, x, positions, cache):
    idx = cache["index"]
    paired = jax.tree.map(
        lambda p: p.reshape(cfg.n_layers // 2, 2, *p.shape[1:]), params["blocks"]
    )
    kp = cache["k"].reshape(cfg.n_layers // 2, 2, *cache["k"].shape[1:])
    vp = cache["v"].reshape(cfg.n_layers // 2, 2, *cache["v"].shape[1:])

    def body(carry, xs):
        p_pair, k_pair, v_pair = xs
        pl = jax.tree.map(lambda t: t[0], p_pair)
        pg = jax.tree.map(lambda t: t[1], p_pair)
        y, (k0, v0) = _dense_block(
            cfg, pl, carry, positions=positions, is_local=True,
            kv_cache=(k_pair[0], v_pair[0]), cache_index=idx,
        )
        y, (k1, v1) = _dense_block(
            cfg, pg, y, positions=positions, is_local=False,
            kv_cache=(k_pair[1], v_pair[1]), cache_index=idx,
        )
        return y, (jnp.stack([k0, k1]), jnp.stack([v0, v1]))

    x, (ks, vs) = jax.lax.scan(body, x, (paired, kp, vp))
    ks = ks.reshape(cfg.n_layers, *ks.shape[2:])
    vs = vs.reshape(cfg.n_layers, *vs.shape[2:])
    return x, ks, vs


def _hybrid_decode(cfg, params, x, positions, cache):
    idx = cache["index"]
    k_every = cfg.attn_every
    n_groups, rem = divmod(cfg.n_layers, k_every)
    new_cache = dict(cache)
    grouped_p = jax.tree.map(
        lambda p: p[: n_groups * k_every].reshape(n_groups, k_every, *p.shape[1:]),
        params["blocks"],
    )
    tail_p = jax.tree.map(lambda p: p[n_groups * k_every :], params["blocks"])

    def inner(carry, xs):
        p_layer, conv_l, ssm_l = xs
        y, c2 = _mamba_block_apply(
            cfg, p_layer, carry, cache=M.MambaCache(conv=conv_l, ssm=ssm_l)
        )
        return y, c2

    convs, ssms, aks, avs = [], [], [], []
    for gi in range(n_groups):
        sl = slice(gi * k_every, (gi + 1) * k_every)
        group = jax.tree.map(lambda p: p[gi], grouped_p)
        x, stacked = jax.lax.scan(
            inner, x, (group, cache["conv"][sl], cache["ssm"][sl])
        )
        convs.append(stacked.conv)
        ssms.append(stacked.ssm)
        x, (k2, v2) = _dense_block(
            cfg, params["shared_attn"], x, positions=positions,
            kv_cache=(cache["k"][gi], cache["v"][gi]), cache_index=idx,
        )
        aks.append(k2)
        avs.append(v2)
    if rem:
        sl = slice(n_groups * k_every, cfg.n_layers)
        x, stacked = jax.lax.scan(
            inner, x, (tail_p, cache["conv"][sl], cache["ssm"][sl])
        )
        convs.append(stacked.conv)
        ssms.append(stacked.ssm)
    new_cache["conv"] = jnp.concatenate(convs, 0).astype(cache["conv"].dtype)
    new_cache["ssm"] = jnp.concatenate(ssms, 0).astype(cache["ssm"].dtype)
    new_cache["k"] = jnp.stack(aks).astype(cache["k"].dtype)
    new_cache["v"] = jnp.stack(avs).astype(cache["v"].dtype)
    return x, new_cache
