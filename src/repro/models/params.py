"""Parameter definition trees: one source of truth for shapes, logical axes,
initialization, PartitionSpecs and dry-run ShapeDtypeStructs.

Every model module builds a nested dict of ``ParamDef`` leaves; from that one
tree we derive (a) materialized params for the smoke tests, (b) abstract
``ShapeDtypeStruct`` trees for ``.lower()`` in the dry-run, and (c) the
``in_shardings`` PartitionSpec tree — guaranteed structurally consistent.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ShardingRules, spec as _spec
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axes, len == len(shape)
    init: str = "normal"  # normal | zeros | ones | small
    scale: float | None = None  # overrides fan-in scale

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn: Callable[[ParamDef], Any], tree: Any) -> Any:
    """Map over ParamDef leaves of a nested dict/list tree."""
    if is_def(tree):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: tree_map_defs(fn, v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(tree_map_defs(fn, v) for v in tree)
    raise TypeError(f"unexpected node {type(tree)}")


def materialize(key: jax.Array, defs: Any, dtype=jnp.float32) -> Any:
    """Init real params (smoke tests / examples)."""
    leaves: list[ParamDef] = []
    tree_map_defs(lambda d: leaves.append(d), defs)
    keys = jax.random.split(key, max(len(leaves), 1))
    it = iter(range(len(leaves)))

    def init_one(d: ParamDef) -> jax.Array:
        i = next(it)
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        fan_in = d.shape[0] if d.shape else 1
        if len(d.shape) >= 2:
            fan_in = int(np.prod(d.shape[:-1]))
        s = d.scale if d.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        if d.init == "small":
            s = 0.02
        return (jax.random.normal(keys[i], d.shape, jnp.float32) * s).astype(dtype)

    return tree_map_defs(init_one, defs)


def abstract(defs: Any, dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStruct tree for .lower() (no allocation)."""
    return tree_map_defs(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs
    )


def pspecs(defs: Any, mesh: Mesh, rules: ShardingRules) -> Any:
    """PartitionSpec tree matching the params tree."""
    return tree_map_defs(lambda d: _spec(d.shape, d.axes, mesh, rules), defs)


def shardings(defs: Any, mesh: Mesh, rules: ShardingRules) -> Any:
    return tree_map_defs(
        lambda d: NamedSharding(mesh, _spec(d.shape, d.axes, mesh, rules)), defs
    )


def count_params(defs: Any) -> int:
    total = 0

    def add(d: ParamDef):
        nonlocal total
        total += int(np.prod(d.shape)) if d.shape else 1

    tree_map_defs(add, defs)
    return total
