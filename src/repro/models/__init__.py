"""Model zoo: 10 assigned architectures behind one config + facade."""

from repro.models.config import (
    ALL_SHAPES,
    ARCH_IDS,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    Family,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeCell,
    get_config,
    shapes_for,
)
from repro.models.model import Model

__all__ = [
    "ALL_SHAPES",
    "ARCH_IDS",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "Family",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "ShapeCell",
    "get_config",
    "shapes_for",
    "Model",
]
