"""Transformer building blocks: norms, RoPE/M-RoPE, GQA attention (bias /
softcap / sliding-window / cross), SwiGLU/GeGLU/GELU MLPs.

All functions are pure; parameters come in as dict leaves defined by the
matching ``*_defs`` function (see ``params.py``).  Activations carry logical
axis constraints (``distributed.sharding.constrain``) so GSPMD keeps the
TP/DP layout the roofline assumes.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.params import ParamDef

# -- norms ---------------------------------------------------------------------


def norm_defs(d: int) -> ParamDef:
    return ParamDef((d,), ("embed_act",), init="zeros")  # rmsnorm: w = 1 + p


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def rmsnorm_bf16(x: jax.Array, w: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    """Mixed-precision RMSNorm: only the variance reduction runs in f32.

    The full-tensor f32 round-trips of the exact version dominate the
    unfused-HLO memory roofline of train cells (EXPERIMENTS.md 'Perf');
    here the (..., 1) statistics are f32 but the stream stays bf16.
    """
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + w.astype(x.dtype))


def apply_norm(cfg: ModelConfig, x: jax.Array, w: jax.Array) -> jax.Array:
    if cfg.norms_bf16 and x.dtype == jnp.bfloat16:
        return rmsnorm_bf16(x, w)
    return rmsnorm(x, w) if cfg.norm_kind == "rmsnorm" else layernorm(x, w)


# -- rotary embeddings -----------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / theta ** (np.arange(0, head_dim, 2, np.float64) / head_dim)


def apply_rope(
    x: jax.Array, positions: jax.Array, *, theta: float = 10000.0
) -> jax.Array:
    """x (B, S, H, D), positions (B, S) -> rotated x."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    sections: tuple[int, int, int],
    *,
    theta: float = 10000.0,
) -> jax.Array:
    """Qwen2-VL M-RoPE: positions (3, B, S) = (t, h, w) ids; the head_dim/2
    frequency bands are split into ``sections`` (t, h, w) groups, each
    rotated by its own position stream."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)  # (d/2,)
    sec = np.asarray(sections)
    assert sec.sum() == d // 2, (sections, d)
    # band -> which position stream (0=t, 1=h, 2=w)
    band_src = np.repeat(np.arange(3), sec)  # (d/2,)
    pos = positions.astype(jnp.float32)  # (3, B, S)
    pos_per_band = jnp.take(pos, jnp.asarray(band_src), axis=0)  # (d/2, B, S)
    angles = jnp.moveaxis(pos_per_band, 0, -1) * freqs  # (B, S, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d: int) -> np.ndarray:
    """Whisper-style fixed sinusoidal embeddings (n_pos, d)."""
    inv = 1.0 / 10000 ** (np.arange(0, d, 2) / d)
    pos = np.arange(n_pos)[:, None] * inv[None, :]
    out = np.zeros((n_pos, d), np.float32)
    out[:, 0::2] = np.sin(pos)
    out[:, 1::2] = np.cos(pos)
    return out


# -- attention -------------------------------------------------------------------


def attention_defs(cfg: ModelConfig, *, cross: bool = False) -> dict[str, Any]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    defs: dict[str, Any] = {
        "wq": ParamDef((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias and not cross:
        defs["bq"] = ParamDef((h, hd), ("heads", "head_dim"), init="zeros")
        defs["bk"] = ParamDef((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        defs["bv"] = ParamDef((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    return defs


def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array, kv_x: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    k = constrain(k, ("batch", "kv_seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "kv_seq", "kv_heads", "head_dim"))
    return q, k, v


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, KV, D) -> (B, S, H, D) by repeating each KV head."""
    b, s, kv, d = k.shape
    rep = n_heads // kv
    return jnp.repeat(k, rep, axis=2) if rep > 1 else k


def _softcap(scores: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def _mask_bias(mask: jax.Array) -> jax.Array:
    return jnp.where(mask, 0.0, -1e30).astype(jnp.float32)


def dot_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: jax.Array | None,
    *,
    softcap: float | None = None,
    scores_bf16: bool = False,
) -> jax.Array:
    """Direct attention. q (B,Sq,H,D), k/v (B,Sk,H,D), mask (B|1,1,Sq,Sk).

    ``scores_bf16``: keep the (B,H,Sq,Sk) score/weight tensors in bf16 with
    f32 row reductions only — halves the dominant S^2 HBM traffic of
    unfused attention (EXPERIMENTS.md 'Perf).  bf16 shares f32's exponent
    range, so the -1e30 mask bias and the row-max subtraction are exact;
    only the softmax mantissa is reduced (<=0.4% per-weight error).
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    if scores_bf16:
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * jnp.asarray(scale, q.dtype)
        scores = _softcap(scores, softcap)
        if mask is not None:
            scores = scores + _mask_bias(mask).astype(scores.dtype)
        m = jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
        e = jnp.exp(scores - m)
        denom = jnp.sum(e, axis=-1, keepdims=True, dtype=jnp.float32)
        w = (e / denom.astype(e.dtype)).astype(v.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", w, v)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    scores = _softcap(scores, softcap)
    if mask is not None:
        scores = scores + _mask_bias(mask)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: int | None = None,
    softcap: float | None = None,
    q_offset: int = 0,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Flash-style online-softmax attention, scanned over KV chunks.

    Keeps the peak score buffer at (B, H, Sq, kv_chunk) instead of
    (B, H, Sq, Sk) — the difference between fitting and not fitting the
    32k-prefill cells in HBM (EXPERIMENTS.md Dry-run).  Pure JAX (lax.scan),
    so it shards under GSPMD with no custom partitioning.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    if sk % kv_chunk:
        kv_chunk = math.gcd(sk, kv_chunk) or sk
    n_chunks = sk // kv_chunk
    scale = 1.0 / math.sqrt(d)

    q32 = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)  # (B,H,Sq,D)
    kc = k.reshape(b, n_chunks, kv_chunk, h, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, h, d).transpose(1, 0, 3, 2, 4)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inputs):
        acc, m, l = carry
        idx, k_i, v_i = inputs  # (B,H,C,D)
        s = jnp.einsum("bhqd,bhkd->bhqk", q32, k_i.astype(jnp.float32))
        s = _softcap(s, softcap)
        kv_pos = idx * kv_chunk + jnp.arange(kv_chunk)
        mask = jnp.ones((sq, kv_chunk), bool)
        if causal:
            mask = mask & (q_pos[:, None] >= kv_pos[None, :])
        if window is not None:
            mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
        s = s + _mask_bias(mask)[None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, v_i.astype(jnp.float32)
        )
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        step, (acc0, m0, l0), (jnp.arange(n_chunks), kc, vc)
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    *,
    positions: jax.Array,
    is_local: jax.Array | bool = False,
    kv_cache: tuple[jax.Array, jax.Array] | None = None,
    cache_index: jax.Array | None = None,
    cross_memory: jax.Array | None = None,
    causal: bool = True,
    use_chunked: bool | None = None,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array] | None]:
    """GQA attention covering every assigned variant.

    Modes:
      * training / prefill: full-sequence self-attention (optionally
        chunked); returns the fresh K/V for cache seeding when requested.
      * decode: ``kv_cache=(K, V)`` of shape (B, S_max, KV, D) plus
        ``cache_index``; the new token's K/V is inserted and attention runs
        over the cache.
      * cross: ``cross_memory`` (B, S_enc, D) provides K/V (whisper).
    """
    b, sq, _ = x.shape
    kv_src = cross_memory if cross_memory is not None else x
    q, k, v = _project_qkv(cfg, p, x, kv_src)

    if cross_memory is None:
        if cfg.mrope_sections is not None:
            q = apply_mrope(q, positions, cfg.mrope_sections, theta=cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.mrope_sections, theta=cfg.rope_theta)
        elif cfg.rope_theta > 0:
            pos2 = positions if positions.ndim == 2 else positions[None]
            q = apply_rope(q, pos2, theta=cfg.rope_theta)
            k = apply_rope(k, pos2, theta=cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:  # decode: insert at cache_index
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_index, 0, 0))
        new_cache = (ck, cv)
        k, v = ck, cv

    kh = _expand_kv(k, cfg.n_heads)
    vh = _expand_kv(v, cfg.n_heads)

    window = None
    if cfg.sliding_window is not None:
        # gemma2 alternation: local layers use the window, global do not.
        # is_local may be a traced bool -> encode window via mask select.
        window = cfg.sliding_window if (is_local is True) else None

    sk = kh.shape[1]
    if use_chunked is None:
        use_chunked = sq > 2048 and kv_cache is None
    if use_chunked:
        out = chunked_attention(
            q, kh, vh,
            causal=causal and cross_memory is None,
            window=window,
            softcap=cfg.attn_softcap,
        )
    else:
        if cross_memory is not None:
            mask = None  # full encoder-decoder cross attention
        elif kv_cache is not None:  # decode over the cache
            kv_pos = jnp.arange(sk)
            valid = kv_pos[None, :] <= cache_index  # (1, Sk)
            if cfg.sliding_window is not None:
                local = valid & (cache_index - kv_pos[None, :] < cfg.sliding_window)
                valid = jnp.where(jnp.asarray(is_local), local, valid)
            mask = jnp.broadcast_to(valid[None, None], (1, 1, sq, sk))
        else:  # training / short prefill, direct path
            m = jnp.ones((sq, sk), bool)
            if causal:
                m = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
            if cfg.sliding_window is not None:
                qp = jnp.arange(sq)[:, None] + (sk - sq)
                local_m = m & (qp - jnp.arange(sk)[None, :] < cfg.sliding_window)
                m = jnp.where(jnp.asarray(is_local), local_m, m)
            mask = m[None, None]
        out = dot_attention(q, kh, vh, mask, softcap=cfg.attn_softcap,
                            scores_bf16=cfg.attn_scores_bf16)

    out = constrain(out, ("batch", "seq", "heads", "head_dim"))
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    y = constrain(y, ("batch", "seq", "embed_act"))
    if kv_cache is not None:
        return y, new_cache
    return y, (k, v)


# -- MLPs ------------------------------------------------------------------------


def mlp_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict[str, Any]:
    d = cfg.d_model
    ff = cfg.d_ff if d_ff is None else d_ff
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "w_gate": ParamDef((d, ff), ("embed", "ff")),
            "w_up": ParamDef((d, ff), ("embed", "ff")),
            "w_down": ParamDef((ff, d), ("ff", "embed")),
        }
    return {
        "w_up": ParamDef((d, ff), ("embed", "ff")),
        "w_down": ParamDef((ff, d), ("ff", "embed")),
    }


def mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.mlp_kind in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else partial(
            jax.nn.gelu, approximate=True
        )
        g = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        h = constrain(g * u, ("batch", "seq", "ff"))
        y = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]), approximate=True)
        h = constrain(h, ("batch", "seq", "ff"))
        y = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return constrain(y, ("batch", "seq", "embed_act"))
