"""Model facade: one object tying config, params, forward, loss and serving.

``Model.loss`` computes token cross-entropy without materializing fp32
logits outside the sharded vocab axis; ``train_step`` lives in
``repro.train.step`` (needs the optimizer), serving steps in
``repro.models.decode``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import decode as D
from repro.models import transformer as T
from repro.models.config import Family, ModelConfig
from repro.models.params import abstract, count_params, materialize, pspecs


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- params ------------------------------------------------------------
    def defs(self):
        return T.make_defs(self.cfg)

    def init(self, key: jax.Array, dtype=None):
        dtype = dtype or self.cfg.param_dtype()
        return materialize(key, self.defs(), dtype=dtype)

    def abstract_params(self, dtype=None):
        dtype = dtype or self.cfg.param_dtype()
        return abstract(self.defs(), dtype=dtype)

    def param_pspecs(self, mesh, rules):
        return pspecs(self.defs(), mesh, rules)

    def n_params(self) -> int:
        return count_params(self.defs())

    # -- compute -------------------------------------------------------------
    def forward(self, params, tokens, **kw) -> T.ForwardOut:
        return T.forward(self.cfg, params, tokens, **kw)

    def loss(
        self,
        params,
        tokens: jax.Array,
        labels: jax.Array,
        *,
        encoder_frames: jax.Array | None = None,
    ) -> jax.Array:
        out = self.forward(params, tokens, encoder_frames=encoder_frames)
        logits = out.logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return -jnp.mean(ll) + out.aux_loss

    # -- serving ---------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        return D.init_cache(self.cfg, batch, max_seq, dtype)

    def prefill(self, params, tokens, cache, **kw):
        return D.prefill(self.cfg, params, tokens, cache, **kw)

    def decode_step(self, params, tokens, cache):
        return D.decode_step(self.cfg, params, tokens, cache)
