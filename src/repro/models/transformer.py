"""Unified forward/prefill/decode for all 10 assigned architectures.

One parameter-def tree + one set of apply functions covers the families:

  dense   command-r-plus (parallel block), granite (MQA), qwen1.5 (QKV bias),
          gemma2 (local/global alternating + softcaps + post-norms),
          qwen2-vl (M-RoPE backbone)
  moe     dbrx (16e top-4), kimi-k2 (384e top-8 + shared + first-dense)
  ssm     mamba2 (SSD)
  hybrid  zamba2 (mamba2 backbone + shared attention block every k layers)
  enc_dec whisper (encoder + cross-attention decoder, stub frontend)

Layers are scanned (stacked params, ``jax.lax.scan``) so compile time and
HLO size stay bounded for the 80-layer archs; gemma2 scans over
(local, global) layer *pairs* so the window/global choice stays static
inside the traced body.  Remat (``jax.checkpoint``) wraps the scan body
when ``cfg.remat == "block"``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MoE
from repro.models.config import Family, ModelConfig
from repro.models.params import ParamDef, tree_map_defs


# -- parameter definition tree ---------------------------------------------------


def _stack(defs: Any, n: int) -> Any:
    """Prefix every leaf with a stacked ``layers`` axis of length n."""
    return tree_map_defs(
        lambda d: ParamDef((n, *d.shape), ("layers", *d.axes), d.init, d.scale),
        defs,
    )


def _dense_block_defs(cfg: ModelConfig, *, cross: bool = False) -> dict[str, Any]:
    d = {
        "attn": L.attention_defs(cfg),
        "mlp": L.mlp_defs(cfg),
        "norm_attn": L.norm_defs(cfg.d_model),
        "norm_mlp": L.norm_defs(cfg.d_model),
    }
    if cfg.post_block_norm:  # gemma2
        d["post_norm_attn"] = L.norm_defs(cfg.d_model)
        d["post_norm_mlp"] = L.norm_defs(cfg.d_model)
    if cfg.parallel_block:  # command-r: one shared input norm
        d.pop("norm_mlp")
    if cross:  # whisper decoder
        d["cross_attn"] = L.attention_defs(cfg, cross=True)
        d["norm_cross"] = L.norm_defs(cfg.d_model)
    return d


def _moe_block_defs(cfg: ModelConfig) -> dict[str, Any]:
    return {
        "attn": L.attention_defs(cfg),
        "moe": MoE.moe_defs(cfg),
        "norm_attn": L.norm_defs(cfg.d_model),
        "norm_mlp": L.norm_defs(cfg.d_model),
    }


def _mamba_block_defs(cfg: ModelConfig) -> dict[str, Any]:
    return {
        "mixer": M.mamba_defs(cfg),
        "norm": L.norm_defs(cfg.d_model),
    }


def make_defs(cfg: ModelConfig) -> dict[str, Any]:
    v, d = cfg.vocab, cfg.d_model
    defs: dict[str, Any] = {
        "embed": ParamDef((v, d), ("vocab", "embed"), init="small"),
        "final_norm": L.norm_defs(d),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, v), ("embed", "vocab"), init="small")

    if cfg.family in (Family.DENSE, Family.VLM):
        defs["blocks"] = _stack(_dense_block_defs(cfg), cfg.n_layers)
    elif cfg.family is Family.MOE:
        k = cfg.moe.first_k_dense
        if k:
            dense_cfg = cfg.with_(d_ff=cfg.moe.d_ff_dense)
            defs["dense_blocks"] = _stack(_dense_block_defs(dense_cfg), k)
        defs["blocks"] = _stack(_moe_block_defs(cfg), cfg.n_layers - k)
    elif cfg.family is Family.SSM:
        defs["blocks"] = _stack(_mamba_block_defs(cfg), cfg.n_layers)
    elif cfg.family is Family.HYBRID:
        defs["blocks"] = _stack(_mamba_block_defs(cfg), cfg.n_layers)
        defs["shared_attn"] = _dense_block_defs(cfg)  # one shared block
    elif cfg.family is Family.ENC_DEC:
        defs["encoder"] = {
            "blocks": _stack(_dense_block_defs(cfg), cfg.n_encoder_layers),
            "final_norm": L.norm_defs(d),
        }
        defs["blocks"] = _stack(_dense_block_defs(cfg, cross=True), cfg.n_layers)
    else:
        raise ValueError(cfg.family)
    return defs


# -- block bodies -----------------------------------------------------------------


def _dense_block(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    *,
    positions: jax.Array,
    is_local: bool | None = None,
    kv_cache=None,
    cache_index=None,
    cross_memory: jax.Array | None = None,
    causal: bool = True,
):
    """Pre-norm residual block covering every dense variant."""
    if is_local is None:
        # uniform-window configs (no local/global alternation) window everywhere
        is_local = cfg.sliding_window is not None and not cfg.local_global_pattern
    if cfg.parallel_block:  # command-r: x + attn(n(x)) + mlp(n(x))
        h = L.apply_norm(cfg, x, p["norm_attn"])
        a, cache_out = L.attention(
            cfg, p["attn"], h, positions=positions, is_local=is_local,
            kv_cache=kv_cache, cache_index=cache_index, causal=causal,
        )
        m = L.mlp(cfg, p["mlp"], h)
        return x + a + m, cache_out

    h = L.apply_norm(cfg, x, p["norm_attn"])
    a, cache_out = L.attention(
        cfg, p["attn"], h, positions=positions, is_local=is_local,
        kv_cache=kv_cache, cache_index=cache_index, causal=causal,
    )
    if cfg.post_block_norm:
        a = L.apply_norm(cfg, a, p["post_norm_attn"])
    x = x + a

    if "cross_attn" in p:  # whisper decoder
        h = L.apply_norm(cfg, x, p["norm_cross"])
        c, _ = L.attention(
            cfg, p["cross_attn"], h, positions=positions,
            cross_memory=cross_memory, causal=False,
        )
        x = x + c

    h = L.apply_norm(cfg, x, p["norm_mlp"])
    m = L.mlp(cfg, p["mlp"], h)
    if cfg.post_block_norm:
        m = L.apply_norm(cfg, m, p["post_norm_mlp"])
    return x + m, cache_out


def _moe_block(cfg: ModelConfig, p: dict, x: jax.Array, **kw):
    h = L.apply_norm(cfg, x, p["norm_attn"])
    a, cache_out = L.attention(cfg, p["attn"], h, **kw)
    x = x + a
    h = L.apply_norm(cfg, x, p["norm_mlp"])
    m, aux = MoE.moe_ffn(cfg, p["moe"], h)
    return x + m, cache_out, aux


def _mamba_block_apply(cfg: ModelConfig, p: dict, x: jax.Array, cache=None):
    h = L.apply_norm(cfg, x, p["norm"])
    y, new_cache = M.mamba_block(cfg, p["mixer"], h, cache=cache)
    return x + y, new_cache


# -- embedding / head --------------------------------------------------------------


def embed(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family is Family.ENC_DEC or cfg.name.startswith("gemma"):
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return constrain(x, ("batch", "seq", "embed_act"))


def unembed(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = L.apply_norm(cfg, x, params["final_norm"])
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    if cfg.logit_softcap is not None:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return constrain(logits, ("batch", "seq", "vocab"))


# -- scan helpers -------------------------------------------------------------------


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    policy = (
        jax.checkpoint_policies.nothing_saveable
        if cfg.remat == "full"
        else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    )
    return jax.checkpoint(fn, policy=policy)


def _scan_blocks(cfg, stacked, x, body):
    """Scan ``body(x, p_layer) -> (x, aux)`` over stacked layer params.

    ``cfg.scan_layers=False`` unrolls the loop instead (identical math).
    XLA's cost model counts a ``while`` body once regardless of trip count,
    so the dry-run's calibration pass lowers small *unrolled* layer stacks
    to recover true per-layer FLOP/byte/collective costs (launch/dryrun.py).
    """
    f = _maybe_remat(cfg, lambda carry, p_layer: body(carry, p_layer))
    if not cfg.scan_layers:
        n = jax.tree.leaves(stacked)[0].shape[0]
        auxes = []
        for i in range(n):
            x, aux = f(x, jax.tree.map(lambda p: p[i], stacked))
            auxes.append(aux)
        if all(a is None for a in auxes):
            return x, None
        return x, jax.tree.map(lambda *ls: jnp.stack(ls), *auxes)
    return jax.lax.scan(f, x, stacked)


# -- full forward (train / eval) ----------------------------------------------------


class ForwardOut(NamedTuple):
    logits: jax.Array
    aux_loss: jax.Array


def _default_positions(cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    b, s = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if cfg.mrope_sections is not None:
        return jnp.broadcast_to(pos[None], (3, b, s))  # text-only: t=h=w
    return pos


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    b, s, _ = frames.shape
    pos_table = jnp.asarray(
        L.sinusoidal_positions(s, cfg.d_model), frames.dtype
    )
    x = frames + pos_table[None]
    enc_cfg = cfg.with_(rope_theta=0.0)  # whisper: absolute positions
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(carry, p_layer):
        y, _ = _dense_block(
            enc_cfg, p_layer, carry, positions=positions, causal=False
        )
        return y, None

    x, _ = _scan_blocks(cfg, params["encoder"]["blocks"], x, body)
    return L.apply_norm(cfg, x, params["encoder"]["final_norm"])


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
    *,
    positions: jax.Array | None = None,
    encoder_frames: jax.Array | None = None,
) -> ForwardOut:
    """Full-sequence forward -> logits (B, S, V) + aux loss."""
    if positions is None:
        positions = _default_positions(cfg, tokens)
    x = embed(cfg, params, tokens)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family in (Family.DENSE, Family.VLM):
        if cfg.local_global_pattern:
            x = _forward_local_global(cfg, params, x, positions)
        else:
            def body(carry, p_layer):
                y, _ = _dense_block(cfg, p_layer, carry, positions=positions)
                return y, None

            x, _ = _scan_blocks(cfg, params["blocks"], x, body)

    elif cfg.family is Family.MOE:
        if cfg.moe.first_k_dense:
            dense_cfg = cfg.with_(d_ff=cfg.moe.d_ff_dense)

            def dense_body(carry, p_layer):
                y, _ = _dense_block(dense_cfg, p_layer, carry, positions=positions)
                return y, None

            x, _ = _scan_blocks(cfg, params["dense_blocks"], x, dense_body)

        def moe_body(carry, p_layer):
            y, _, aux = _moe_block(cfg, p_layer, carry, positions=positions)
            return y, aux

        x, auxes = _scan_blocks(cfg, params["blocks"], x, moe_body)
        aux_total = aux_total + jnp.sum(auxes)

    elif cfg.family is Family.SSM:
        def ssm_body(carry, p_layer):
            y, _ = _mamba_block_apply(cfg, p_layer, carry)
            return y, None

        x, _ = _scan_blocks(cfg, params["blocks"], x, ssm_body)

    elif cfg.family is Family.HYBRID:
        x = _forward_hybrid(cfg, params, x, positions)

    elif cfg.family is Family.ENC_DEC:
        assert encoder_frames is not None, "enc_dec needs encoder_frames"
        memory = encode(cfg, params, encoder_frames)
        dec_cfg = cfg.with_(rope_theta=0.0)
        pos_table = jnp.asarray(
            L.sinusoidal_positions(tokens.shape[1], cfg.d_model), x.dtype
        )
        x = x + pos_table[None]

        def dec_body(carry, p_layer):
            y, _ = _dense_block(
                dec_cfg, p_layer, carry, positions=positions,
                cross_memory=memory,
            )
            return y, None

        x, _ = _scan_blocks(cfg, params["blocks"], x, dec_body)

    logits = unembed(cfg, params, x)
    return ForwardOut(logits=logits, aux_loss=aux_total)


def _forward_local_global(cfg, params, x, positions):
    """gemma2: scan over (local, global) layer pairs — static window flag."""
    assert cfg.n_layers % 2 == 0
    paired = jax.tree.map(
        lambda p: p.reshape(cfg.n_layers // 2, 2, *p.shape[1:]),
        params["blocks"],
    )

    def body(carry, p_pair):
        p_local = jax.tree.map(lambda t: t[0], p_pair)
        p_global = jax.tree.map(lambda t: t[1], p_pair)
        y, _ = _dense_block(cfg, p_local, carry, positions=positions, is_local=True)
        y, _ = _dense_block(cfg, p_global, y, positions=positions, is_local=False)
        return y, None

    x, _ = _scan_blocks(cfg, paired, x, body)
    return x


def _forward_hybrid(cfg, params, x, positions):
    """zamba2: mamba backbone; one *shared* attention block every k layers."""
    k = cfg.attn_every
    n = cfg.n_layers
    n_groups, rem = divmod(n, k)
    grouped = jax.tree.map(
        lambda p: p[: n_groups * k].reshape(n_groups, k, *p.shape[1:]),
        params["blocks"],
    )
    tail = jax.tree.map(lambda p: p[n_groups * k :], params["blocks"])

    def inner_body(carry, p_layer):
        y, _ = _mamba_block_apply(cfg, p_layer, carry)
        return y, None

    for gi in range(n_groups):
        group = jax.tree.map(lambda p: p[gi], grouped)
        x, _ = _scan_blocks(cfg, group, x, inner_body)
        x, _ = _dense_block(
            cfg, params["shared_attn"], x, positions=positions
        )
    if rem:
        x, _ = _scan_blocks(cfg, tail, x, inner_body)
    return x
