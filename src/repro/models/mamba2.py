"""Mamba2 mixer via SSD — state-space duality (arXiv:2405.21060).

Used standalone (mamba2-130m) and as the backbone of the zamba2-7b hybrid.

Train/prefill path: the *chunked dual form* — sequence split into chunks of
Q tokens; intra-chunk interactions are a masked (attention-like) matmul,
inter-chunk interactions flow through a state recurrence scanned over
chunks.  This is the TPU-native adaptation of the paper's GPU SSD kernel:
the chunk matmuls are MXU-shaped (Q x Q and Q x N), the scan carries only
(H, P, N) states, and everything is jit-compatible ``lax`` control flow
(DESIGN.md hardware-adaptation notes).

Decode path: the classic SSM recurrence, one token per step, carrying
``(conv_state, ssm_state)`` caches — the SSM analogue of a KV cache, with
O(1) memory in sequence length (what makes the long_500k cell feasible).

Layer structure follows the official Mamba2 block:
  in_proj -> [z | x | B | C | dt] ; causal conv1d on [x|B|C] ; SSD ;
  gated RMSNorm (norm(y * silu(z))) ; out_proj.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig, SSMConfig
from repro.models.params import ParamDef


class MambaCache(NamedTuple):
    conv: jax.Array  # (B, d_conv - 1, conv_dim)
    ssm: jax.Array  # (B, H, P, N)


def mamba_defs(cfg: ModelConfig) -> dict[str, Any]:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner(d)
    nh = s.n_heads(d)
    g, n = s.n_groups, s.d_state
    conv_dim = d_in + 2 * g * n
    proj_out = 2 * d_in + 2 * g * n + nh  # z, x, B, C, dt
    return {
        "in_proj": ParamDef((d, proj_out), ("embed", "ssm_inner")),
        "conv_w": ParamDef((s.d_conv, conv_dim), ("conv", "ssm_inner")),
        "conv_b": ParamDef((conv_dim,), ("ssm_inner",), init="zeros"),
        "a_log": ParamDef((nh,), ("ssm_heads",), init="zeros"),  # A = -exp(a)
        "dt_bias": ParamDef((nh,), ("ssm_heads",), init="zeros"),
        "d_skip": ParamDef((nh,), ("ssm_heads",), init="ones"),
        "norm_w": ParamDef((d_in,), ("ssm_inner",), init="zeros"),
        "out_proj": ParamDef((d_in, d), ("ssm_inner", "embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    g, n = s.n_groups, s.d_state
    nh = s.n_heads(cfg.d_model)
    z, x, bc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + 2 * g * n], axis=-1
    )
    b, c = jnp.split(bc, 2, axis=-1)
    return z, x, b, c, dt  # dt (..., nh)


def _gated_norm(y: jax.Array, z: jax.Array, w: jax.Array) -> jax.Array:
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
    return (yf * (1.0 + w.astype(jnp.float32))).astype(y.dtype)


def _conv1d_causal(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (B, S, C), w (K, C) -> (B, S, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    segs = [xp[:, i : i + x.shape[1], :] * w[i] for i in range(k)]
    return jax.nn.silu(sum(segs) + b)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)   softplus'd step sizes
    a: jax.Array,  # (H,)        negative decay rates
    bmat: jax.Array,  # (B, S, G, N)
    cmat: jax.Array,  # (B, S, G, N)
    *,
    chunk: int,
    init_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """SSD dual form, scanned over chunks. Returns (y, final_state).

    State shape (B, H, P, N).  G groups broadcast over H heads (G divides H).
    """
    bsz, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    # expand groups to heads
    bh = jnp.repeat(bmat, rep, axis=2)  # (B, S, H, N)
    ch = jnp.repeat(cmat, rep, axis=2)

    def to_chunks(t):
        # (B, S, ...) -> (NC, B, Q, ...) for lax.scan
        return jnp.moveaxis(
            t.reshape(bsz, nc, chunk, *t.shape[2:]), 1, 0
        )

    xc, dtc, bc_, cc = map(to_chunks, (x, dt, bh, ch))

    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    h0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )

    def step(h_prev, inp):
        """One chunk: intra (dual/attention-like) + inter (state) terms.

        Scanning chunk-by-chunk keeps the peak intermediate at
        (B, Q, Q, H) per step instead of (B, NC, Q, Q, H) for the whole
        sequence — the difference between ~tens of MB and ~tens of TB on
        the train_4k cells.
        """
        x_i, dt_i, b_i, c_i = inp  # (B,Q,H,P), (B,Q,H), (B,Q,H,N) x2
        da = dt_i * a[None, None, :]  # (B,Q,H)
        cum = jnp.cumsum(da, axis=1)

        # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j
        li = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,Q,H)
        decay = jnp.where(mask[None, :, :, None], jnp.exp(li), 0.0)
        scores = jnp.einsum(
            "bqhn,bkhn->bqkh", c_i.astype(jnp.float32), b_i.astype(jnp.float32)
        )
        w = scores * decay * dt_i[:, None, :, :]  # weight by dt_j
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", w, x_i.astype(jnp.float32))

        # inter-chunk: y_q += C_q exp(cum_q) h_prev
        y_inter = jnp.einsum(
            "bqhn,bhpn->bqhp",
            c_i.astype(jnp.float32) * jnp.exp(cum)[..., None],
            h_prev,
        )

        # state update: h_new = exp(sum da) h_prev + sum_j exp(last-cum_j) dt_j B_j x_j
        last = cum[:, -1:, :]
        w_state = jnp.exp(last - cum) * dt_i  # (B,Q,H)
        chunk_state = jnp.einsum(
            "bqh,bqhn,bqhp->bhpn",
            w_state,
            b_i.astype(jnp.float32),
            x_i.astype(jnp.float32),
        )
        h_new = h_prev * jnp.exp(jnp.sum(da, axis=1))[:, :, None, None] + chunk_state
        return h_new, y_intra + y_inter

    final, ys = jax.lax.scan(step, h0, (xc, dtc, bc_, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    return y, final


def mamba_block(
    cfg: ModelConfig,
    p: dict,
    u: jax.Array,
    *,
    cache: MambaCache | None = None,
) -> tuple[jax.Array, MambaCache]:
    """One Mamba2 mixer. u (B, S, D) -> (y (B, S, D), new cache).

    With ``cache`` set, S must be 1 (decode recurrence).
    """
    s_cfg = cfg.ssm
    bsz, s, _ = u.shape
    d_in = s_cfg.d_inner(cfg.d_model)
    nh = s_cfg.n_heads(cfg.d_model)
    g, n, pdim = s_cfg.n_groups, s_cfg.d_state, s_cfg.head_dim

    zxbcdt = jnp.einsum("bsd,dk->bsk", u, p["in_proj"])
    z, x, bmat, cmat, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([x, bmat, cmat], axis=-1)  # (B,S,conv_dim)

    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,)
    if cache is None:
        conv_out = _conv1d_causal(conv_in, p["conv_w"], p["conv_b"])
        x, bmat, cmat = jnp.split(conv_out, [d_in, d_in + g * n], axis=-1)
        xh = x.reshape(bsz, s, nh, pdim)
        dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        y, final = ssd_chunked(
            xh,
            dtp,
            a,
            bmat.reshape(bsz, s, g, n),
            cmat.reshape(bsz, s, g, n),
            chunk=min(s_cfg.chunk, s),
        )
        y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
        new_conv = jnp.swapaxes(
            jax.lax.dynamic_slice_in_dim(
                jnp.swapaxes(conv_in, 1, 2), s - (s_cfg.d_conv - 1), s_cfg.d_conv - 1, 2
            ) if s >= s_cfg.d_conv - 1 else jnp.pad(
                jnp.swapaxes(conv_in, 1, 2), ((0, 0), (0, 0), (s_cfg.d_conv - 1 - s, 0))
            ),
            1, 2,
        )
        new_cache = MambaCache(conv=new_conv.astype(u.dtype), ssm=final.astype(u.dtype))
    else:
        # decode: roll conv state, apply conv taps, single recurrence step
        conv_state = jnp.concatenate([cache.conv, conv_in], axis=1)  # (B,K,C)
        conv_out = jnp.einsum("bkc,kc->bc", conv_state, p["conv_w"]) + p["conv_b"]
        conv_out = jax.nn.silu(conv_out)[:, None, :]  # (B,1,C)
        x, bmat, cmat = jnp.split(conv_out, [d_in, d_in + g * n], axis=-1)
        xh = x.reshape(bsz, nh, pdim)
        bm = jnp.repeat(bmat.reshape(bsz, g, n), nh // g, axis=1)
        cm = jnp.repeat(cmat.reshape(bsz, g, n), nh // g, axis=1)
        dtp = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
        decay = jnp.exp(dtp * a[None, :])  # (B,H)
        ssm = cache.ssm.astype(jnp.float32)
        upd = jnp.einsum("bh,bhn,bhp->bhpn", dtp, bm.astype(jnp.float32),
                         xh.astype(jnp.float32))
        ssm_new = ssm * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", ssm_new, cm.astype(jnp.float32))
        y = y + xh.astype(jnp.float32) * p["d_skip"][None, :, None]
        y = y[:, None]  # (B,1,H,P)
        new_cache = MambaCache(
            conv=conv_state[:, 1:].astype(u.dtype), ssm=ssm_new.astype(u.dtype)
        )

    y = y.reshape(bsz, s, d_in).astype(u.dtype)
    y = _gated_norm(y, z, p["norm_w"])
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return constrain(out, ("batch", "seq", "embed_act")), new_cache


def init_mamba_cache(
    cfg: ModelConfig, batch: int, dtype=jnp.bfloat16
) -> MambaCache:
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return MambaCache(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        ssm=jnp.zeros((batch, nh, s.head_dim, s.d_state), dtype),
    )
