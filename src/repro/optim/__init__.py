from repro.optim.adamw import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    global_norm_clip,
)
from repro.optim.schedule import warmup_cosine

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "global_norm_clip",
    "warmup_cosine",
]
