"""AdamW built from scratch (no optax), with optional quantized moments.

The quantized-moment path is a distributed-optimization feature: the first
moment is stored as block-wise absmax int8 (128-element blocks, ~1.03
bytes/param) and the second moment as bfloat16 (2 bytes/param), cutting
optimizer-state HBM from 8 to ~3.06 bytes/param — what lets the 1T-param
kimi-k2 cell fit a two-pod optimizer footprint (EXPERIMENTS.md Dry-run).

v deliberately does NOT use linear int8: block absmax quantization collapses
small-but-nonzero second moments to exactly zero whenever a block mixes
magnitudes (embedding rows of rare vs common tokens), and the resulting
``m_hat / (sqrt(0) + eps)`` updates diverge within ~10 steps (observed, and
the reason 8-bit Adam uses non-linear quantization maps).  bf16 keeps the
full exponent range, so tiny v round-trips safely.  Dequantize -> update ->
requantize happens inside the jitted step; the quantization error is bounded
by tests against the fp32 reference (tests/test_optim.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

_QBLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 1e-3  # used when no schedule is passed to `update`
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    quantize_moments: bool = False  # int8 block-wise m/v states


class _Q8(NamedTuple):
    q: jax.Array  # int8 payload, original shape
    scale: jax.Array  # float32 per-block absmax scales


def _quantize(x: jax.Array) -> _Q8:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return _Q8(q=q, scale=scale.astype(jnp.float32))


def _dequantize(q8: _Q8, shape, dtype=jnp.float32) -> jax.Array:
    blocks = q8.q.astype(jnp.float32) * q8.scale
    n = 1
    for d in shape:
        n *= d
    return blocks.reshape(-1)[:n].reshape(shape).astype(dtype)


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any  # pytree of fp32 arrays or _Q8
    v: Any


def adamw_init(params: Any, config: AdamWConfig) -> AdamWState:
    def m_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _quantize(z) if config.quantize_moments else z

    def v_like(p):
        dt = jnp.bfloat16 if config.quantize_moments else jnp.float32
        return jnp.zeros(p.shape, dt)

    # materialize m and v independently — sharing leaves between them breaks
    # buffer donation in the jitted train step
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(m_like, params),
        v=jax.tree.map(v_like, params),
    )


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    config: AdamWConfig,
    *,
    learning_rate: jax.Array | float | None = None,
) -> tuple[Any, AdamWState]:
    """Returns ``(new_params, new_state)``. Update math in fp32 regardless of
    the param dtype (bf16 params keep an implicit fp32 update path)."""
    lr = config.learning_rate if learning_rate is None else learning_rate
    step = state.step + 1
    b1, b2 = config.b1, config.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def leaf_update(g, m, v, p):
        g = g.astype(jnp.float32)
        if config.quantize_moments:
            m_f = _dequantize(m, g.shape)
            v_f = v.astype(jnp.float32)  # v stored bf16 (see module docstring)
        else:
            m_f, v_f = m, v
        m_f = b1 * m_f + (1 - b1) * g
        v_f = b2 * v_f + (1 - b2) * g * g
        m_hat = m_f / bc1
        v_hat = v_f / bc2
        upd = m_hat / (jnp.sqrt(v_hat) + config.eps)
        if config.weight_decay:
            upd = upd + config.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        if config.quantize_moments:
            return new_p, _quantize(m_f), v_f.astype(jnp.bfloat16)
        return new_p, m_f, v_f

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [leaf_update(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def global_norm_clip(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm
