"""Config: qwen1.5-110b [dense]

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064 — QKV bias.
Source: hf:Qwen/Qwen1.5-110B (hf tier)
"""

from repro.models.config import Family, ModelConfig, MoEConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b",
        family=Family.DENSE,
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=49152,
        vocab=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )


def reduced_config() -> ModelConfig:
    """Same family, tiny dims — CPU smoke tests (one fwd/train step)."""
    return ModelConfig(
        name="qwen1.5-110b-smoke",
        family=Family.DENSE,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        qkv_bias=True,
        dtype="float32",
        remat="none",
    )
