"""Config: granite-20b [dense]

52L d_model=6144 48H (GQA kv=1, i.e. MQA) d_ff=24576 vocab=49152 —
llama-style code model.
Source: arXiv:2405.04324; hf (hf tier)
"""

from repro.models.config import Family, ModelConfig, MoEConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b",
        family=Family.DENSE,
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_ff=24576,
        vocab=49152,
        mlp_kind="gelu",  # 2-matrix MLP: hits the 20B name (SwiGLU would be 28B)
    )


def reduced_config() -> ModelConfig:
    """Same family, tiny dims — CPU smoke tests (one fwd/train step)."""
    return ModelConfig(
        name="granite-20b-smoke",
        family=Family.DENSE,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab=256,
        mlp_kind="gelu",
        dtype="float32",
        remat="none",
    )
