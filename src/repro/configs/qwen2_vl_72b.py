"""Config: qwen2-vl-72b [vlm]

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 — M-RoPE
(3-section rotary: temporal/height/width), dynamic-resolution vision
frontend is a stub (input_specs provides patch-merged token embeddings).
Source: arXiv:2409.12191 (hf tier)
"""

from repro.models.config import Family, ModelConfig, MoEConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family=Family.VLM,
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=29568,
        vocab=152064,
        qkv_bias=True,
        mrope_sections=(16, 24, 24),
        rope_theta=1_000_000.0,
    )


def reduced_config() -> ModelConfig:
    """Same family, tiny dims — CPU smoke tests (one fwd/train step)."""
    return ModelConfig(
        name="qwen2-vl-72b-smoke",
        family=Family.VLM,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        head_dim=16,
        qkv_bias=True,
        mrope_sections=(2, 3, 3),
        dtype="float32",
        remat="none",
    )
