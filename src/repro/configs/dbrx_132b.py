"""Config: dbrx-132b [moe]

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352 —
MoE 16 experts top-4, fine-grained.
Source: hf:databricks/dbrx-base (unverified tier)
"""

from repro.models.config import Family, ModelConfig, MoEConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        family=Family.MOE,
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        vocab=100352,
        moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752),
        rope_theta=500_000.0,
    )


def reduced_config() -> ModelConfig:
    """Same family, tiny dims — CPU smoke tests (one fwd/train step)."""
    return ModelConfig(
        name="dbrx-132b-smoke",
        family=Family.MOE,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128),
        dtype="float32",
        remat="none",
    )
