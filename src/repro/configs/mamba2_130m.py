"""Config: mamba2-130m [ssm]

24L d_model=768 (attention-free) vocab=50280, ssm_state=128 — SSD
(state-space duality), tied embeddings.
Source: arXiv:2405.21060 (unverified tier)
"""

from repro.models.config import Family, ModelConfig, MoEConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family=Family.SSM,
        n_layers=24,
        d_model=768,
        n_heads=24,  # d_inner / head_dim
        n_kv_heads=24,
        d_ff=0,
        vocab=50280,
        ssm=SSMConfig(d_state=128, head_dim=64, n_groups=1),
        tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    """Same family, tiny dims — CPU smoke tests (one fwd/train step)."""
    return ModelConfig(
        name="mamba2-130m-smoke",
        family=Family.SSM,
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=8,
        d_ff=0,
        vocab=256,
        ssm=SSMConfig(d_state=16, head_dim=16, n_groups=1, chunk=8),
        tie_embeddings=True,
        dtype="float32",
        remat="none",
    )
