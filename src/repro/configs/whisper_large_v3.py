"""Config: whisper-large-v3 [audio]

32L (x2: encoder + decoder) d_model=1280 20H (MHA) d_ff=5120
vocab=51866 — enc-dec; conv/mel frontend is a stub (input_specs provides
precomputed frame embeddings, 1500 frames).
Source: arXiv:2212.04356 (unverified tier)
"""

from repro.models.config import Family, ModelConfig, MoEConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family=Family.ENC_DEC,
        n_layers=32,
        n_encoder_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab=51866,
        norm_kind="layernorm",
        mlp_kind="gelu",
        rope_theta=0.0,  # absolute sinusoidal positions
        encoder_seq=1500,
    )


def reduced_config() -> ModelConfig:
    """Same family, tiny dims — CPU smoke tests (one fwd/train step)."""
    return ModelConfig(
        name="whisper-large-v3-smoke",
        family=Family.ENC_DEC,
        n_layers=2,
        n_encoder_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        norm_kind="layernorm",
        mlp_kind="gelu",
        rope_theta=0.0,
        encoder_seq=16,
        dtype="float32",
        remat="none",
    )
