"""Config: kimi-k2-1t-a32b [moe]

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840 —
MoE 384 experts top-8 + 1 shared expert, first layer dense (DeepSeek-V3
style) — trillion-param scale, 32B active.
Source: arXiv:2501.kimi2 paper table (unverified tier)
"""

from repro.models.config import Family, ModelConfig, MoEConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family=Family.MOE,
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_ff=2048,
        vocab=163840,
        moe=MoEConfig(
            n_experts=384,
            top_k=8,
            d_ff_expert=2048,
            n_shared_experts=1,
            d_ff_shared=2048,
            first_k_dense=1,
            d_ff_dense=18432,
        ),
        rope_theta=50_000.0,
    )


def reduced_config() -> ModelConfig:
    """Same family, tiny dims — CPU smoke tests (one fwd/train step)."""
    return ModelConfig(
        name="kimi-k2-1t-a32b-smoke",
        family=Family.MOE,
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab=256,
        moe=MoEConfig(
            n_experts=8,
            top_k=2,
            d_ff_expert=64,
            n_shared_experts=1,
            d_ff_shared=64,
            first_k_dense=1,
            d_ff_dense=128,
        ),
        dtype="float32",
        remat="none",
    )
