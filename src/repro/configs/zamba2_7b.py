"""Config: zamba2-7b [hybrid]

81L d_model=3584 32H (kv=32, MHA shared block) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + one shared attention+MLP
block invoked every 6 backbone layers (shared weights).
Source: arXiv:2411.15242 (unverified tier)
"""

from repro.models.config import Family, ModelConfig, MoEConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family=Family.HYBRID,
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_ff=14336,
        vocab=32000,
        ssm=SSMConfig(d_state=64, head_dim=64, n_groups=2),
        attn_every=6,
    )


def reduced_config() -> ModelConfig:
    """Same family, tiny dims — CPU smoke tests (one fwd/train step)."""
    return ModelConfig(
        name="zamba2-7b-smoke",
        family=Family.HYBRID,
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        ssm=SSMConfig(d_state=16, head_dim=16, n_groups=2, chunk=8),
        attn_every=2,
        dtype="float32",
        remat="none",
    )
