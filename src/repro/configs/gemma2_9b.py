"""Config: gemma2-9b [dense]

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000 —
local/global alternating attention (window 4096), attn softcap 50,
final-logit softcap 30, GeGLU, post-block norms, head_dim=256.
Source: arXiv:2408.00118 (hf tier)
"""

from repro.models.config import Family, ModelConfig, MoEConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family=Family.DENSE,
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        d_ff=14336,
        vocab=256000,
        head_dim=256,
        attn_softcap=50.0,
        logit_softcap=30.0,
        sliding_window=4096,
        local_global_pattern=True,
        mlp_kind="geglu",
        post_block_norm=True,
        tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    """Same family, tiny dims — CPU smoke tests (one fwd/train step)."""
    return ModelConfig(
        name="gemma2-9b-smoke",
        family=Family.DENSE,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        head_dim=16,
        attn_softcap=50.0,
        logit_softcap=30.0,
        sliding_window=8,
        local_global_pattern=True,
        mlp_kind="geglu",
        post_block_norm=True,
        tie_embeddings=True,
        dtype="float32",
        remat="none",
    )
