"""Config: command-r-plus-104b [dense]

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000 — GQA,
no-bias, Cohere parallel attention+FFN residual block.
Source: hf:CohereForAI/c4ai-command-r-v01 (unverified tier)
"""

from repro.models.config import Family, ModelConfig, MoEConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b",
        family=Family.DENSE,
        n_layers=64,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        d_ff=33792,
        vocab=256000,
        parallel_block=True,
        norm_kind="layernorm",
        rope_theta=75_000_000.0,
    )


def reduced_config() -> ModelConfig:
    """Same family, tiny dims — CPU smoke tests (one fwd/train step)."""
    return ModelConfig(
        name="command-r-plus-104b-smoke",
        family=Family.DENSE,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        parallel_block=True,
        norm_kind="layernorm",
        dtype="float32",
        remat="none",
    )
