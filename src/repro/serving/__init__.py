from repro.serving.engine import GenerationResult, ServingEngine
from repro.serving.switched import SwitchedDecodeConfig, SwitchedDecoder

__all__ = [
    "GenerationResult",
    "ServingEngine",
    "SwitchedDecodeConfig",
    "SwitchedDecoder",
]
