"""Batch serving engine: prefill + decode loop, optionally ARCHES-switched.

The engine is the host-side request loop around the jitted serve steps —
deliberately thin, mirroring the paper's split (pipeline on accelerator,
control in the dApp).  ``generate`` runs plain greedy decoding;
``generate_switched`` runs the full ARCHES control loop (E3 telemetry ->
dApp policy -> slot-boundary switching with fail-safe).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dapp import DApp, connect_dapp
from repro.core.e3 import E3Agent
from repro.core.runtime import ArchesRuntime, RunHistory
from repro.models.model import Model
from repro.serving.switched import SwitchedDecoder


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, steps)
    history: RunHistory | None = None


class ServingEngine:
    def __init__(self, model: Model, params: Any, *, max_seq: int = 4096):
        self.model = model
        self.params = params
        self.max_seq = max_seq

    def generate(
        self,
        prompts: jax.Array,
        n_steps: int,
        *,
        encoder_frames: jax.Array | None = None,
        sample: Callable[[jax.Array], jax.Array] | None = None,
    ) -> GenerationResult:
        """Greedy (or custom-sampler) generation, no switching."""
        b = prompts.shape[0]
        cache = self.model.init_cache(b, self.max_seq, dtype=jnp.float32)
        kw = {}
        if encoder_frames is not None:
            kw["encoder_frames"] = encoder_frames
        logits, cache = self.model.prefill(self.params, prompts, cache, **kw)
        pick = sample or (lambda l: jnp.argmax(l, axis=-1))
        toks = pick(logits)[:, None].astype(jnp.int32)
        out = [np.asarray(toks)]
        for _ in range(n_steps - 1):
            logits, cache = self.model.decode_step(self.params, toks, cache)
            toks = pick(logits)[:, None].astype(jnp.int32)
            out.append(np.asarray(toks))
        return GenerationResult(tokens=np.concatenate(out, axis=1))

    def generate_switched(
        self,
        prompts: jax.Array,
        n_steps: int,
        *,
        decoder: SwitchedDecoder,
        dapp: DApp,
        default_mode: int = 1,
        ttl_slots: int = 16,
    ) -> GenerationResult:
        """ARCHES-switched generation: full dApp control loop per decode slot."""
        b = prompts.shape[0]
        cache = self.model.init_cache(b, self.max_seq, dtype=jnp.float32)
        logits, cache = self.model.prefill(self.params, prompts, cache)
        first = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)

        agent = E3Agent()
        connect_dapp(agent, dapp)
        runtime = ArchesRuntime(
            decoder.make_slot_fn(self.params),
            agent,
            default_mode=default_mode,
            fail_safe_mode=default_mode,
            ttl_slots=ttl_slots,
            keep_outputs=True,
        )
        history = runtime.run(range(n_steps - 1), carry=(first, cache))
        toks = np.concatenate(
            [np.asarray(first)]
            + [np.asarray(r.output) for r in history.records],
            axis=1,
        )
        return GenerationResult(tokens=toks, history=history)
