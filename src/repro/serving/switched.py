"""ARCHES-switched LM decoding — the paper's mechanism generalized to
serving (paper 7: "only the experts and telemetry inputs change").

Expert bank over two decode-attention implementations:

  Expert 0 (designated / "AI-analogue"):  **exact** decode attention over
    the full KV cache — highest quality, cost grows with context length.
  Expert 1 (conventional / fail-safe):    **windowed** decode attention over
    the last W cache positions — bounded cost, approximate at long range.

Mapping to the paper's machinery (unchanged code paths):
  * the switch is the same Pallas ``switch_select`` kernel, selecting the
    logits buffer (mode=0 no-op, mode=1 copy);
  * decisions take effect at decode-step ("slot") boundaries through the
    same ``SlotSwitchState`` register with fail-safe decay;
  * telemetry is KPMs per decode step — logit entropy, expert agreement
    (KL), cache occupancy, per-expert cost proxies — delivered over the E3
    emulation to the same DApp/policy classes;
  * concurrent mode runs both experts (online benchmarking, zero switch
    latency); selected-only mode runs one via ``lax.switch``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.expert_bank import ExecutionMode, Expert, ExpertBank
from repro.models.config import ModelConfig
from repro.models.model import Model


@dataclasses.dataclass(frozen=True)
class SwitchedDecodeConfig:
    window: int = 512  # windowed expert's attention span
    execution_mode: ExecutionMode = ExecutionMode.CONCURRENT
    use_pallas_switch: bool = True


class SwitchedDecoder:
    """Expert-bank decode step + per-slot KPM extraction."""

    def __init__(self, model: Model, sw: SwitchedDecodeConfig = SwitchedDecodeConfig()):
        if model.cfg.local_global_pattern:
            raise ValueError(
                "switched decode assumes a uniform attention pattern; "
                "gemma2-style alternation already hard-codes locality"
            )
        self.model = model
        self.sw = sw
        self.cfg_exact = model.cfg
        self.cfg_win = model.cfg.with_(sliding_window=sw.window)
        self.model_win = Model(self.cfg_win)

        def exact_fn(_bank_params, params, tokens, cache):
            logits, _ = self.model.decode_step(params, tokens, cache)
            return logits

        def win_fn(_bank_params, params, tokens, cache):
            logits, _ = self.model_win.decode_step(params, tokens, cache)
            return logits

        # cost proxies: bytes read from the KV cache per step
        cfg = model.cfg
        kv_bytes_full = lambda s: (
            2 * cfg.n_layers * s * cfg.n_kv_heads * cfg.resolved_head_dim * 2
        )
        self.bank = ExpertBank(
            [
                Expert(name="exact", fn=exact_fn, params=None,
                       bytes_hbm=float(kv_bytes_full(32768))),
                Expert(name="windowed", fn=win_fn, params=None,
                       bytes_hbm=float(kv_bytes_full(sw.window))),
            ],
            default_mode=1,
            execution_mode=sw.execution_mode,
            use_pallas_switch=sw.use_pallas_switch,
        )

    @partial(jax.jit, static_argnames=("self",))
    def _step(self, mode: jax.Array, params, tokens, cache):
        # cache update is expert-independent (same K/V insert); compute once
        _, new_cache = self.model.decode_step(params, tokens, cache)
        out = self.bank(mode, params, tokens, cache)
        logits = out.selected
        # per-slot telemetry material
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        p = jnp.exp(logp)
        entropy = -jnp.mean(jnp.sum(p * logp, axis=-1))
        if out.all_outputs is not None:
            la, lb = out.all_outputs
            pa = jax.nn.log_softmax(la.astype(jnp.float32), -1)
            pb = jax.nn.log_softmax(lb.astype(jnp.float32), -1)
            kl = jnp.mean(jnp.sum(jnp.exp(pa) * (pa - pb), axis=-1))
            agree = jnp.mean(
                (jnp.argmax(la, -1) == jnp.argmax(lb, -1)).astype(jnp.float32)
            )
        else:
            kl = jnp.zeros(())
            agree = jnp.ones(())
        return logits, new_cache, {"entropy": entropy, "expert_kl": kl,
                                   "expert_agree": agree}

    def step(
        self, mode: int | jax.Array, params, tokens, cache
    ) -> tuple[jax.Array, Any, dict[str, float]]:
        """One decode slot. Returns (logits, cache, KPMs).

        ``mode`` may be a scalar (whole batch follows one expert) or a
        ``(batch,)`` vector — the serving analogue of the PHY engine's
        per-UE mode vector: each sequence in the decode batch independently
        selects exact or windowed attention, routed by the batched Pallas
        switch over the per-sequence logits rows.
        """
        logits, cache, kpms = self._step(jnp.asarray(mode, jnp.int32),
                                         params, tokens, cache)
        max_seq = cache["k"].shape[2] if "k" in cache else 1
        host_kpms = {
            "entropy": float(kpms["entropy"]),
            "expert_kl": float(kpms["expert_kl"]),
            "expert_agree": float(kpms["expert_agree"]),
            "cache_occupancy": float(cache["index"]) / max_seq,
            "exact_cost_bytes": self.bank.experts[0].bytes_hbm,
            "windowed_cost_bytes": self.bank.experts[1].bytes_hbm,
        }
        return logits, cache, host_kpms

    def make_slot_fn(self, params):
        """Adapter for ``ArchesRuntime``: carry = (tokens, cache)."""

        def slot_fn(active_mode, carry, _slot_idx):
            tokens, cache = carry
            logits, cache, kpms = self.step(active_mode, params, tokens, cache)
            next_tokens = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            return (next_tokens, cache), next_tokens, {"serving": kpms}

        return slot_fn


SERVING_KPMS = (
    "entropy",
    "expert_kl",
    "expert_agree",
    "cache_occupancy",
)
