"""repro: ARCHES (real-time expert switching for the RAN) as a production
JAX/Pallas framework.  See DESIGN.md for the system inventory."""

__version__ = "1.0.0"
