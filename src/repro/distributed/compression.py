"""Gradient compression with error feedback (distributed-optimization trick).

At multi-pod scale the cross-pod gradient all-reduce rides the slowest link
(data-center interconnect, not ICI).  Compressing gradients to int8 before
that hop cuts its bytes 2x vs bf16 / 4x vs fp32; the residual (quantization
error) is fed back into the next step's gradient so the *sum* of applied
updates is unbiased (error-feedback / EF-SGD, Karimireddy et al.).

In this repo the compressor wraps the gradient pytree inside ``train_step``
(quantize -> [the all-reduce GSPMD already inserted runs on the quantized
values' dequantized form] -> dequantize + residual update).  On the dry-run
meshes the byte saving is visible in the §Roofline collective term when
``compress_pod_grads`` is enabled in the launcher; correctness is bounded by
the EF tests (tests/test_distributed.py).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

_QBLOCK = 256


class ErrorFeedbackState(NamedTuple):
    residual: Any  # pytree of fp32 residuals, same structure as grads


def init_error_feedback(grads_template: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_template
        )
    )


def _q8_leaf(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % _QBLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, _QBLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True), 1e-20) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dq8_leaf(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_decompress(
    grads: Any, ef: ErrorFeedbackState
) -> tuple[Any, ErrorFeedbackState]:
    """int8 round-trip with error feedback.

    Returns grads as they would arrive after a compressed all-reduce, plus
    the updated residual state.  The quantize->dequantize pair stays in the
    compiled graph, so cost_analysis sees the int8 payload bytes — which is
    how the §Roofline collective-term accounting picks up the saving.
    """

    def leaf(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = _q8_leaf(g32)
        dq = _dq8_leaf(q, scale, g32.shape)
        return dq.astype(g.dtype), g32 - dq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    out = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_r = treedef.unflatten([o[1] for o in out])
    return new_g, ErrorFeedbackState(residual=new_r)
