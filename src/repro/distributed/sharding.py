"""Logical-axis sharding rules (DP/FSDP/TP/EP) for the model zoo.

Weights and activations are annotated with *logical* axis names; a rules
table maps them to mesh axes.  The production meshes (core/topology.py):

  single-pod  (16, 16)      axes ("data", "model")
  multi-pod   (2, 16, 16)   axes ("pod", "data", "model")

Default placement (MaxText-style 2-D sharding):
  * "batch"    -> ("pod", "data")   pure DP across pods, DP within pod
  * "embed"    -> "data"            FSDP: weight d_model dim sharded on data
  * "heads"/"ff"/"experts"/"vocab" -> "model"   tensor/expert parallelism
  * "kv_heads" -> "model" when divisible (GQA kv=8 < model=16 replicates)

``spec`` drops any mapping that does not divide the dimension (e.g. batch=1
long-context cells, kv_heads=8 on model=16), so every (arch x shape x mesh)
cell builds a valid PartitionSpec without per-arch special-casing.

``constrain`` applies ``with_sharding_constraint`` when a mesh context is
active and is a no-op otherwise, so the same model code runs on CPU tests
(no mesh) and in the dry-run (512-device mesh).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = tuple[str, ...] | str | None

DEFAULT_RULES: dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
    "embed": "data",  # FSDP on weight d_model dims
    "embed_act": None,  # activation d_model stays unsharded (TP on heads/ff)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "experts": "model",
    "vocab": "model",
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "conv": None,
    "layers": None,
    "frames": None,
    "moe_tokens": ("pod", "data"),
    "moe_cap": ("pod", "data"),
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    table: Mapping[str, MeshAxes]

    def mesh_axes_for(self, logical: str | None) -> MeshAxes:
        if logical is None:
            return None
        if logical not in self.table:
            raise KeyError(f"unknown logical axis {logical!r}")
        return self.table[logical]


def make_rules(overrides: Mapping[str, MeshAxes] | None = None) -> ShardingRules:
    table = dict(DEFAULT_RULES)
    if overrides:
        table.update(overrides)
    return ShardingRules(table=table)


def spec(
    shape: Sequence[int],
    logical_axes: Sequence[str | None],
    mesh: Mesh,
    rules: ShardingRules,
) -> P:
    """Build a PartitionSpec, dropping non-divisible / absent mesh axes."""
    if len(shape) != len(logical_axes):
        raise ValueError(f"shape {shape} vs axes {logical_axes}")
    used: set[str] = set()
    out = []
    for dim, logical in zip(shape, logical_axes):
        mesh_axes = rules.mesh_axes_for(logical)
        if mesh_axes is None:
            out.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        picked = []
        prod = 1
        for ax in mesh_axes:
            if ax not in mesh.shape or ax in used:
                continue
            n = mesh.shape[ax]
            if dim % (prod * n) == 0:
                picked.append(ax)
                prod *= n
        used.update(picked)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(tuple(picked))
    return P(*out)


def named_sharding(
    shape: Sequence[int],
    logical_axes: Sequence[str | None],
    mesh: Mesh,
    rules: ShardingRules,
) -> NamedSharding:
    return NamedSharding(mesh, spec(shape, logical_axes, mesh, rules))


# -- mesh context so model code can constrain without plumbing ---------------

_ctx = threading.local()


@contextlib.contextmanager
def mesh_context(mesh: Mesh, rules: ShardingRules):
    prev = getattr(_ctx, "value", None)
    _ctx.value = (mesh, rules)
    try:
        # jax>=0.8 requires jax.set_mesh for PartitionSpec in_shardings; the
        # plain `with mesh:` Mesh context no longer feeds jit.
        with jax.set_mesh(mesh):
            yield
    finally:
        _ctx.value = prev


def current_mesh_rules() -> tuple[Mesh, ShardingRules] | None:
    return getattr(_ctx, "value", None)


def constrain(x: jax.Array, logical_axes: Sequence[str | None]) -> jax.Array:
    """Apply a sharding constraint if a mesh context is active (else no-op)."""
    ctx = current_mesh_rules()
    if ctx is None:
        return x
    mesh, rules = ctx
    s = spec(x.shape, logical_axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))
