from repro.distributed.compression import (
    ErrorFeedbackState,
    compress_decompress,
    init_error_feedback,
)
from repro.distributed.sharding import (
    DEFAULT_RULES,
    ShardingRules,
    constrain,
    make_rules,
    mesh_context,
    named_sharding,
    spec,
)

__all__ = [
    "ErrorFeedbackState",
    "compress_decompress",
    "init_error_feedback",
    "DEFAULT_RULES",
    "ShardingRules",
    "constrain",
    "make_rules",
    "mesh_context",
    "named_sharding",
    "spec",
]
