"""Example: ARCHES-switched LM serving (paper 7 generalization).

The same switching machinery that drives channel-estimation experts here
hosts two decode-attention experts — exact full-cache attention vs windowed
attention — switched per decode step by a dApp watching serving KPMs
(expert KL divergence, cache occupancy).

    PYTHONPATH=src python examples/serve_switched.py
"""

import jax
import jax.numpy as jnp

from repro.core.dapp import DApp, connect_dapp
from repro.core.e3 import E3Agent
from repro.core.runtime import ArchesRuntime
from repro.models.config import get_config
from repro.models.model import Model
from repro.serving.switched import SwitchedDecodeConfig, SwitchedDecoder


def main():
    cfg = get_config("granite-20b", reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dec = SwitchedDecoder(model, SwitchedDecodeConfig(window=8))

    # policy: windowed attention (cheap) unless the experts disagree --
    # KL between their next-token distributions is the quality telemetry
    dapp = DApp(lambda x: 0 if x[0] > 0.02 else 1,
                ["expert_kl"], window_slots=2)
    agent = E3Agent()
    connect_dapp(agent, dapp)
    runtime = ArchesRuntime(
        dec.make_slot_fn(params), agent,
        default_mode=1, fail_safe_mode=1, ttl_slots=8, keep_outputs=True,
    )

    batch = 2
    cache = model.init_cache(batch, 128)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (batch, 16), 0, cfg.vocab)
    _, cache = model.prefill(params, prompt, cache)
    print(f"serving {cfg.name}: batch={batch}, prompt=16 tokens, "
          f"experts = exact vs window-8 attention")

    hist = runtime.run(range(24),
                       carry=(jnp.ones((batch, 1), jnp.int32), cache))
    names = {0: "exact ", 1: "window"}
    for r in hist.records:
        print(f"step {r.slot:3d} expert={names[r.active_mode]} "
              f"kl={r.kpms['expert_kl']:.4f} "
              f"agree={r.kpms['expert_agree']*100:3.0f}% "
              f"cache={r.kpms['cache_occupancy']*100:3.0f}%")
    print(f"\nswitches: {int(hist.final_state.n_switches)}; "
          "same SlotSwitch register + Pallas switch kernel as the PHY case")


if __name__ == "__main__":
    main()
