"""Example: ARCHES-switched serving through the resident campaign service.

Two halves of the paper-7 generalization ("only the experts and telemetry
inputs change"):

1. **The serving expert bank** — two decode-attention experts (exact
   full-cache vs windowed) behind the same Pallas switch kernel that
   routes channel-estimation experts, emitting per-decode-step KPMs
   (expert KL, agreement, cache occupancy) a policy would switch on.
2. **The serving control plane** — in production the switch does not run
   as a one-shot script loop: campaigns are submitted to the resident
   ``repro.service`` and driven over its northbound HTTP API.  The demo
   starts the service in-process, submits a switched campaign as
   ``CampaignSpec`` JSON over ``POST /campaigns``, polls segment progress
   and spec_hash provenance from ``GET /campaigns/<id>``, reads live
   per-segment telemetry from ``GET /telemetry``, and drains gracefully.

    PYTHONPATH=src python examples/serve_switched.py
"""

import json
import tempfile
import time
import urllib.request

import jax
import jax.numpy as jnp

from repro.core.session import CampaignSpec, PolicySpec, SwitchSpec, spec_hash
from repro.models.config import get_config
from repro.models.model import Model
from repro.serving.switched import SwitchedDecoder, SwitchedDecodeConfig
from repro.service import CampaignService, JsonlExporter
from repro.service.api import ServiceAPI


def _get(url: str):
    with urllib.request.urlopen(url, timeout=10) as r:
        return json.loads(r.read().decode())


def _post(url: str, payload: str = "null"):
    req = urllib.request.Request(
        url, data=payload.encode(),
        headers={"Content-Type": "application/json"}, method="POST",
    )
    with urllib.request.urlopen(req, timeout=10) as r:
        return json.loads(r.read().decode())


def expert_bank_demo() -> None:
    """The serving expert pair and its per-step switch telemetry."""
    cfg = get_config("granite-20b", reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dec = SwitchedDecoder(model, SwitchedDecodeConfig(window=8))

    batch = 2
    cache = model.init_cache(batch, 128)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (batch, 16), 0,
                                cfg.vocab)
    _, cache = model.prefill(params, prompt, cache)
    print(f"== serving expert bank: {cfg.name}, batch={batch}, "
          f"experts = exact vs window-8 attention ==")

    tokens = jnp.ones((batch, 1), jnp.int32)
    names = {0: "exact ", 1: "window"}
    # per-sequence mode vector, the serving analogue of the per-UE mode
    # vector; decisions would come from the policy bank the service runs
    for step, mode in enumerate(([0, 1], [1, 1], [0, 0], [1, 0])):
        logits, cache, kpms = dec.step(jnp.asarray(mode), params, tokens,
                                       cache)
        tokens = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        row = "/".join(names[m] for m in mode)
        print(f"step {step} experts={row} kl={kpms['expert_kl']:.4f} "
              f"agree={kpms['expert_agree']*100:3.0f}% "
              f"cache={kpms['cache_occupancy']*100:3.0f}%")
    print("(same SlotSwitch register + Pallas switch kernel as the PHY "
          "case; KPMs feed the policy bank)\n")


def service_demo() -> None:
    """Submit -> poll -> telemetry -> drain over the northbound API."""
    n_phase = 8
    spec = CampaignSpec(
        path="closed_loop",
        scenario="good_poor_good",
        scenario_args=(("poor_start", n_phase), ("poor_end", 2 * n_phase)),
        n_ues=4,
        n_slots=3 * n_phase,
        seed=42,
        policies=(PolicySpec(kind="threshold", feature="snr",
                             threshold=18.0, hysteresis=2.0),),
        switch=SwitchSpec(window_slots=2),
    )

    with tempfile.TemporaryDirectory() as state:
        jsonl = f"{state}/telemetry.jsonl"
        svc = CampaignService(
            state, max_segment_slots=n_phase,
            exporters=[JsonlExporter(jsonl)],
        ).start()
        api = ServiceAPI(svc).start()
        print(f"== resident campaign service on {api.url} "
              f"(state dir: checkpoints + status, telemetry -> JSONL) ==")

        cid = _post(api.url + "/campaigns", spec.to_json())["campaign_id"]
        print(f"POST /campaigns -> campaign_id {cid} "
              f"[spec {spec_hash(spec)}]")

        last = None
        while True:
            st = _get(api.url + f"/campaigns/{cid}")
            key = (st["state"], st["segments_done"])
            if key != last:
                print(f"GET  /campaigns/{cid[:5]}..: {st['state']:9s} "
                      f"segment {st['segments_done']}/{st['n_segments']} "
                      f"checkpoints {st['checkpoint_steps']}")
                last = key
            if st["state"] in ("completed", "failed", "cancelled"):
                break
            time.sleep(0.05)
        if st["state"] != "completed":
            raise SystemExit(f"campaign ended {st['state']}: {st['error']}")
        assert st["spec_hash"] == spec_hash(spec)  # provenance carried

        print("\nGET  /telemetry — per-segment samples off the ring:")
        for s in _get(api.url + "/telemetry?n=8"):
            print(f"  seg {s['seg_idx']} slots [{s['t0']},{s['t1']}): "
                  f"AI share {s['ai_share']:4.0%}  "
                  f"throughput {s['throughput_bps'] / 1e6:5.1f} Mbps  "
                  f"flops {s['executed_flops'] / 1e9:.2f} G")

        health = _get(api.url + "/health")
        print(f"\nGET  /health: {health['status']}, "
              f"workers={health['workers']}, "
              f"campaigns={health['campaign_states']}, "
              f"telemetry exported={health['telemetry']['exported']} "
              f"dropped={health['telemetry']['dropped']}")

        _post(api.url + "/drain")
        api.stop()
        if not svc.drain(timeout=60):
            raise SystemExit("drain timed out")
        with open(jsonl) as f:
            rows = sum(1 for _ in f)
        print(f"POST /drain -> graceful exit; {rows} telemetry rows "
              "exported losslessly")
    print("(kill the service instead of draining and a restart resumes "
          "the campaign bitwise — see tests/test_service.py)")


def main():
    expert_bank_demo()
    service_demo()


if __name__ == "__main__":
    main()
