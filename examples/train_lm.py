"""Example: fault-tolerant LM training with the repro stack.

Default runs a pocket-sized config for CPU; ``--arch mamba2-130m --full``
trains the real ~129M-parameter Mamba2 for a few hundred steps (the
assignment's 100M-scale end-to-end driver — budget hours on CPU, minutes on
a TPU host).

    PYTHONPATH=src python examples/train_lm.py [--steps 60] [--full]
"""

import argparse

import jax

from repro.checkpoint.store import CheckpointManager
from repro.data.tokens import TokenStream
from repro.models.config import get_config
from repro.models.model import Model
from repro.train.loop import FailureInjector, run_training
from repro.train.step import TrainConfig, init_train_state, train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="full config (~100M params) instead of the smoke one")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--inject-failure", type=int, default=-1,
                    help="step at which to simulate a node failure")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    if args.full:
        cfg = cfg.with_(remat="block")
    model = Model(cfg)
    print(f"{cfg.name}: {model.n_params()/1e6:.1f}M params")

    tc = TrainConfig(learning_rate=1e-3)
    stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=0)

    def init_state():
        return init_train_state(model, model.init(jax.random.PRNGKey(0)), tc)

    ckpt = CheckpointManager(args.ckpt_dir, save_every=20, keep=2)
    injector = (FailureInjector(fail_at_steps=(args.inject_failure,))
                if args.inject_failure >= 0 else None)
    report = run_training(
        step_fn=lambda s, b: train_step(model, tc, s, b),
        init_state=init_state,
        data=lambda start: stream.iterate(start),
        ckpt=ckpt,
        total_steps=args.steps,
        failure_injector=injector,
        log_every=10,
    )
    print(f"\ndone: {report.final_step} steps, "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}, "
          f"restarts {report.restarts}, stragglers {len(report.straggler_events)}")


if __name__ == "__main__":
    main()
