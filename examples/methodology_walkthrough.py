"""Example: the reusable 3-stage policy-design methodology (paper 4).

Walks the full pipeline on the channel-estimation case study:
  stage 1 — controlled AWGN perturbation of the MMSE estimates (Eq. 3),
  stage 2 — monotonicity filtering of KPM responses,
  stage 3 — correlation clustering + representative selection.

    PYTHONPATH=src python examples/methodology_walkthrough.py
"""

import jax
import numpy as np

from repro.core.methodology import (
    design_policy_inputs,
    monotonicity_filter,
    sensitivity_sweep,
)
from repro.phy.ai_estimator import AiEstimatorConfig, init_params
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import LinkState, PuschPipeline
from repro.phy.scenario import GOOD


def main():
    cfg = SlotConfig(n_prb=24)
    net = AiEstimatorConfig(channels=8, n_res_blocks=1)
    pipe = PuschPipeline(cfg, init_params(jax.random.PRNGKey(0), cfg, net), net=net)

    state = {"link": LinkState(), "i": 0}

    def eval_fn(rho, key):
        state["i"] += 1
        link, out, kpms = pipe.run_slot(
            jax.random.fold_in(key, state["i"]), 1, state["link"], GOOD,
            perturb_rho=rho)
        state["link"] = link
        return {**kpms["aerial"], **kpms["oai"]}

    print("stage 1: perturbation sweep (rho 0 -> 2) ...")
    sweep = sensitivity_sweep(eval_fn, rhos=np.arange(0, 2.01, 0.25),
                              n_trials=3)
    for k, name in enumerate(sweep.kpm_names):
        m = sweep.means[:, k]
        print(f"  {name:20s}"
              f" rho=0: {m[0]:10.3g}   rho=2: {m[-1]:10.3g}")

    print("\nstage 2: monotonicity filter (|spearman| >= 0.8)")
    kept = monotonicity_filter(sweep)
    for name, r in kept.items():
        print(f"  keep {name:20s} r={r:+.2f}")

    print("\nstage 3: redundancy reduction at 0.8")
    flat = {n: sweep.samples[:, :, k].reshape(-1)
            for k, n in enumerate(sweep.kpm_names)}
    aerial = {n: v for n, v in flat.items()
              if n in ("code_rate", "sinr", "qam_order", "mcs_index",
                       "tb_size", "n_code_blocks", "pdu_length", "ndi", "rsrp")}
    oai = {n: v for n, v in flat.items() if n not in aerial}
    selected, a_res, o_res = design_policy_inputs(aerial, oai)
    print("  selected policy inputs:", ", ".join(selected))


if __name__ == "__main__":
    main()
