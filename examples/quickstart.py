"""Quickstart: ARCHES expert switching on UL channel estimation.

Builds the PUSCH pipeline with an MMSE + AI expert bank, trains the
decision-tree switching policy from labelled telemetry, then runs the
paper's Fig. 9 scenario (good -> poor -> good) under the full control loop
(E3 + dApp + slot-boundary switch register).

With ``--n-ues N`` (N > 1) the expert profiling runs on the batched
multi-UE slot engine — one compiled ``lax.scan`` per expert instead of
O(slots x UEs) host dispatches — and a per-UE mode-vector demo slot is
shown before the live single-UE control loop.

With ``--closed-loop`` (implies the batched engine) the trained policy is
exported to flat device tables and the whole control loop — KPM window,
tree inference, hysteresis, switch register — runs *inside* the slot scan:
each UE's mode for slot n+1 is decided on device from slot n's telemetry,
no host round-trip, and the run is verified bitwise against the host
replay of the same policy.

With ``--gated`` (implies the batched engine) a 1-in-4-UEs-on-AI campaign
runs through the compaction-gated execution path — the AI expert executes
only on a dense capacity-limited sub-batch of the UEs that selected it —
and the demo prints the realized compute saving vs the concurrent bank,
after verifying both paths produce bitwise-identical trajectories.

    PYTHONPATH=src python examples/quickstart.py [--n-ues 8] [--closed-loop]
                                                 [--gated]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dapp import DApp, connect_dapp
from repro.core.e3 import E3Agent
from repro.core.policy import (
    DecisionTreePolicy,
    fit_decision_tree,
    profile_and_fit_tree,
)
from repro.core.runtime import ArchesRuntime
from repro.core.telemetry import SELECTED_KPMS
from repro.phy.ai_estimator import AiEstimatorConfig, init_params
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import BatchedPuschPipeline, LinkState, PuschPipeline
from repro.phy.scenario import good_poor_good_schedule

N_PHASE = 10


def profile_host_loop(pipe, schedule, n_slots):
    """Seed-style per-slot profiling (one UE, Python loop)."""
    X, y = [], []
    for mode in (0, 1):
        link = LinkState()
        for slot in range(n_slots):
            ch = schedule(slot)
            link, out, kpms = pipe.run_slot(jax.random.PRNGKey(slot), mode, link, ch)
            flat = {**kpms["aerial"], **kpms["oai"]}
            X.append([flat[k] for k in SELECTED_KPMS])
            y.append(0 if ch.interference else 1)  # interference -> AI
    return np.asarray(X, np.float32), np.asarray(y)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-ues", type=int, default=1,
                    help="profile on the batched multi-UE engine (N > 1)")
    ap.add_argument("--closed-loop", action="store_true",
                    help="run the device-side closed loop (policy in the scan)")
    ap.add_argument("--gated", action="store_true",
                    help="demo compaction-gated execution (AI only where selected)")
    args = ap.parse_args()
    if (args.closed_loop or args.gated) and args.n_ues < 2:
        args.n_ues = 4  # these paths live on the batched engine

    cfg = SlotConfig(n_prb=24)
    net = AiEstimatorConfig(channels=8, n_res_blocks=1)
    params = init_params(jax.random.PRNGKey(0), cfg, net)
    pipe = PuschPipeline(cfg, params, net=net)
    schedule = good_poor_good_schedule(poor_start=N_PHASE, poor_end=2 * N_PHASE)
    n_slots = 3 * N_PHASE

    # -- 1. profile both experts over labelled slots (paper 5.3) ------------
    if args.n_ues > 1:
        print(f"== profiling experts on the batched engine "
              f"({args.n_ues} UEs x {n_slots} slots per expert) ==")
        engine = BatchedPuschPipeline(cfg, params, net=net)
        policy = profile_and_fit_tree(
            engine, schedule, n_slots=n_slots, n_ues=args.n_ues
        )

        # per-UE mode vector demo: odd UEs on MMSE, even UEs on AI, one slot
        modes = (jnp.arange(args.n_ues) % 2).astype(jnp.int32)
        _, demo = engine.run(schedule, modes, n_slots=1, n_ues=args.n_ues)
        sinr = np.asarray(demo["kpms"]["aerial"]["sinr"])[0]
        print("per-UE experts in one slot:",
              " ".join(f"ue{u}={'AI' if int(modes[u]) == 0 else 'MMSE'}"
                       f"({sinr[u]:.1f}dB)" for u in range(min(args.n_ues, 6))))
    else:
        print("== profiling experts for policy training ==")
        X, y = profile_host_loop(pipe, schedule, n_slots)
        tree = fit_decision_tree(X, y, depth=2)
        policy = DecisionTreePolicy(tree, SELECTED_KPMS)
    tree = policy.tree
    top = np.argsort(-tree.importances)[:2]
    print("policy features:",
          ", ".join(f"{SELECTED_KPMS[i]} ({tree.importances[i]*100:.0f}%)"
                    for i in top))

    # -- 1a. compaction-gated execution (pay only for selected experts) -----
    if args.gated:
        import time

        from repro.core.expert_bank import ExecutionMode

        n_ai = max(1, args.n_ues // 4)
        gated_engine = BatchedPuschPipeline(
            cfg, params, net=net,
            execution_mode=ExecutionMode.GATED, gated_capacity=n_ai,
        )
        modes = np.ones((n_slots, args.n_ues), np.int32)
        modes[:, :n_ai] = 0  # 1-in-4 UEs on AI, capacity provisioned to match

        def timed(eng):
            _, traj = eng.run(schedule, modes, n_slots=n_slots,
                              n_ues=args.n_ues)  # warm/compile
            jax.block_until_ready(traj["tb_ok"])
            t0 = time.perf_counter()
            _, traj = eng.run(schedule, modes, n_slots=n_slots,
                              n_ues=args.n_ues)
            jax.block_until_ready(traj["tb_ok"])
            return time.perf_counter() - t0, traj

        t_conc, traj_c = timed(engine)
        t_gate, traj_g = timed(gated_engine)
        from repro.core.telemetry import physical_trajectory

        eq = jax.tree.map(
            lambda a, b: bool(np.array_equal(np.asarray(a), np.asarray(b))),
            physical_trajectory(traj_c), physical_trajectory(traj_g),
        )
        same = all(jax.tree.leaves(eq))
        fl_c = np.asarray(traj_c["executed_flops"]).sum(axis=1).mean()
        fl_g = np.asarray(traj_g["executed_flops"]).sum(axis=1).mean()
        print(f"\n== gated execution: {n_ai}/{args.n_ues} UEs on AI ==")
        print(f"executed compute:  concurrent {fl_c / 1e9:.3f} GFLOP/slot -> "
              f"gated {fl_g / 1e9:.3f} GFLOP/slot "
              f"({(1 - fl_g / fl_c) * 100:.0f}% saved)")
        print(f"wall time:         {t_conc * 1e3:.0f} ms -> {t_gate * 1e3:.0f} ms "
              f"({t_conc / t_gate:.2f}x vs concurrent; the demo net is tiny — "
              "benchmarks/bench_gated.py shows the full-size engine)")
        print(f"trajectories identical: {'yes (bitwise)' if same else 'NO'}; "
              f"overflow slot-UEs: {int(np.asarray(traj_g['gated_overflow']).sum())}")
        if not same:
            raise SystemExit("gated != concurrent trajectory")

    # -- 1b. device-side closed loop (policy compiled into the scan) --------
    if args.closed_loop:
        from repro.core.closed_loop import SwitchConfig, host_replay_closed_loop
        from repro.core.runtime import ArchesRuntime as _RT

        sw_cfg = SwitchConfig(feature_names=SELECTED_KPMS, window_slots=2)
        runtime = _RT(closed_loop=True, engine=engine,
                      device_policy=policy.to_device(), switch_config=sw_cfg)
        hist = runtime.run_batched(schedule, n_slots=n_slots, n_ues=args.n_ues,
                                   key=jax.random.PRNGKey(42))
        feats = np.stack(
            [hist.kpms[n] for n in SELECTED_KPMS], axis=-1
        ).astype(np.float32)
        replay = host_replay_closed_loop(policy, feats, sw_cfg)
        match = np.array_equal(hist.modes, replay["active_mode"])
        print(f"\n== closed loop: decisions inside the scan "
              f"({args.n_ues} UEs x {n_slots} slots) ==")
        for s in range(0, n_slots, 3):
            cond = "poor" if schedule(s).interference else "good"
            row = "".join("A" if m == 0 else "M" for m in hist.modes[s])
            print(f"slot {s:3d} [{cond}] per-UE experts: {row}")
        print(f"device == host replay: {'yes (bitwise)' if match else 'NO'}; "
              f"switches/UE: {hist.n_switches.tolist()}")
        if not match:
            raise SystemExit("closed-loop equivalence violated")

    # -- 2. live ARCHES loop -------------------------------------------------
    print("\n== live run: good -> poor -> good ==")
    agent = E3Agent()
    dapp = DApp(policy, SELECTED_KPMS, window_slots=2)
    connect_dapp(agent, dapp)
    runtime = ArchesRuntime(
        pipe.make_slot_fn(schedule), agent,
        default_mode=1, fail_safe_mode=1, ttl_slots=8, keep_outputs=True,
    )
    hist = runtime.run(range(n_slots))

    names = {0: "AI  ", 1: "MMSE"}
    for r in hist.records:
        cond = "poor" if schedule(r.slot).interference else "good"
        bar = "#" * int(r.kpms["phy_throughput"] / 2e6)
        print(f"slot {r.slot:3d} [{cond}] expert={names[r.active_mode]} "
              f"tput={r.kpms['phy_throughput'] / 1e6:5.1f} Mbps {bar}")
    print(f"\nswitches: {int(hist.final_state.n_switches)} "
          "(decisions apply at slot n+1 — paper 3.3)")


if __name__ == "__main__":
    main()
