"""Quickstart: ARCHES expert switching on UL channel estimation.

Builds the PUSCH pipeline with an MMSE + AI expert bank, trains the
decision-tree switching policy from labelled telemetry, then runs the
paper's Fig. 9 scenario (good -> poor -> good) under the full control loop
(E3 + dApp + slot-boundary switch register).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core.dapp import DApp, connect_dapp
from repro.core.e3 import E3Agent
from repro.core.policy import DecisionTreePolicy, fit_decision_tree
from repro.core.runtime import ArchesRuntime
from repro.core.telemetry import SELECTED_KPMS
from repro.phy.ai_estimator import AiEstimatorConfig, init_params
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import LinkState, PuschPipeline
from repro.phy.scenario import good_poor_good_schedule

N_PHASE = 10


def main():
    cfg = SlotConfig(n_prb=24)
    net = AiEstimatorConfig(channels=8, n_res_blocks=1)
    pipe = PuschPipeline(cfg, init_params(jax.random.PRNGKey(0), cfg, net), net=net)
    schedule = good_poor_good_schedule(poor_start=N_PHASE, poor_end=2 * N_PHASE)

    # -- 1. profile both experts over labelled slots (paper 5.3) ------------
    print("== profiling experts for policy training ==")
    X, y = [], []
    for mode in (0, 1):
        link = LinkState()
        for slot in range(3 * N_PHASE):
            ch = schedule(slot)
            link, out, kpms = pipe.run_slot(jax.random.PRNGKey(slot), mode, link, ch)
            flat = {**kpms["aerial"], **kpms["oai"]}
            X.append([flat[k] for k in SELECTED_KPMS])
            y.append(0 if ch.interference else 1)  # interference -> AI
    tree = fit_decision_tree(np.asarray(X, np.float32), np.asarray(y), depth=2)
    policy = DecisionTreePolicy(tree, SELECTED_KPMS)
    top = np.argsort(-tree.importances)[:2]
    print("policy features:",
          ", ".join(f"{SELECTED_KPMS[i]} ({tree.importances[i]*100:.0f}%)"
                    for i in top))

    # -- 2. live ARCHES loop -------------------------------------------------
    print("\n== live run: good -> poor -> good ==")
    agent = E3Agent()
    dapp = DApp(policy, SELECTED_KPMS, window_slots=2)
    connect_dapp(agent, dapp)
    runtime = ArchesRuntime(
        pipe.make_slot_fn(schedule), agent,
        default_mode=1, fail_safe_mode=1, ttl_slots=8, keep_outputs=True,
    )
    hist = runtime.run(range(3 * N_PHASE))

    names = {0: "AI  ", 1: "MMSE"}
    for r in hist.records:
        cond = "poor" if schedule(r.slot).interference else "good"
        bar = "#" * int(r.kpms["phy_throughput"] / 2e6)
        print(f"slot {r.slot:3d} [{cond}] expert={names[r.active_mode]} "
              f"tput={r.kpms['phy_throughput'] / 1e6:5.1f} Mbps {bar}")
    print(f"\nswitches: {int(hist.final_state.n_switches)} "
          "(decisions apply at slot n+1 — paper 3.3)")


if __name__ == "__main__":
    main()
