"""Quickstart: ARCHES expert switching through the declarative session API.

Every campaign is one ``CampaignSpec`` — scenario (by registry name),
campaign shape, expert bank, switch/policy config, seeds — compiled and
executed by ``ArchesSession``:

    spec = CampaignSpec(path="closed_loop", scenario="good_poor_good", ...)
    hist = ArchesSession(spec).run()     # -> BatchedRunHistory

The demo walks the execution paths the session dispatches over:

* default — the paper's Fig. 9 scenario under the device-side closed loop
  (policy tables evaluated inside the slot scan), verified bitwise against
  the host replay of the same policy.
* ``--host`` — the seed architecture: single-UE Python slot loop with the
  full E3 + dApp control plane.
* ``--gated`` — compaction-gated execution: the AI expert runs only on a
  capacity-limited sub-batch of the UEs that selected it; prints the
  realized compute saving and the capacity a recorded campaign suggests
  (``suggest_gated_capacity``).
* ``--heterogeneous`` — per-UE heterogeneity: the ``mixed_cell`` scenario
  gives each UE its own channel schedule, and two different policies are
  assigned across UEs (a ``PerUEPolicy`` table bank inside the scan).
* ``--multi-cell`` — the sharded multi-cell topology: a 4-cell campaign
  (``multi_cell`` scenario + ``TopologySpec``) runs the closed loop under
  the sharded entry (``shard_map`` over the UE mesh axis — one device per
  shard where available, degrading to one device here), with per-cell
  noise offsets and inter-cell interference coupling, and reports per-cell
  AI share and throughput.
* ``--streaming`` — the epoch-chunked streaming driver: a ``ChurnSchedule``
  attaches/detaches UEs at segment boundaries over a stable-id universe
  wider than the bank; the printed history shows residency (``.`` =
  detached) alongside the per-UE expert choices, plus the closed-loop
  host replay through the churn boundaries.  Segments execute pipelined
  (the device scan of segment k+1 is dispatched while a host worker
  assembles and checkpoints segment k — bitwise-identical to the serial
  order).  Checkpoint layout: with ``checkpoint_dir=`` each boundary
  writes one ``step_NNNNNNNN/`` directory; the default ``delta`` format
  stores only that segment's slot rows plus the resume carry (O(segment)
  bytes, manifest-tagged ``arches-streaming-delta-v1`` and chained to its
  predecessor), so ``resume_from=`` replays the chain back from the
  latest step to its anchor; ``checkpoint_format="monolithic"`` keeps the
  legacy full-accumulator snapshots, and old checkpoint directories stay
  loadable.  The demo runs the churn campaign checkpointed, prints the
  on-disk chain, then kills and resumes it bitwise.
* ``--service`` — running the service: the resident campaign service
  (``repro.service``) started in-process with its northbound HTTP API.
  The walkthrough submits the quickstart campaign as ``CampaignSpec``
  JSON over ``POST /campaigns`` (zero-churn specs are lifted to their
  segmented streaming form automatically), polls ``GET /campaigns/<id>``
  through its status transitions (segment progress, spec_hash
  provenance, checkpoint lineage), reads per-segment telemetry from
  ``GET /telemetry``, then drains gracefully with ``POST /drain`` — and
  checks the service-run history is bitwise-equal to the monolithic
  ``run()`` above.  The same service runs standalone:
  ``python -m repro.service --state-dir <dir>``.
* ``--faults`` — the fault-injection degradation ladder: a ``FaultSpec``
  takes the dApp offline mid-campaign (decisions stop arriving; the
  device decision-age counter decays stale UEs to the MMSE fail-safe
  after ``ttl_slots``, recovering when the control plane returns) and
  injects a NaN burst into the AI expert's output (the in-scan health
  screen serves the fail-safe that slot; repeated trips quarantine the
  expert through the circuit breaker until cooldown expires).  The
  fault-injected device trajectory replays bitwise through the host
  oracle.

Specs serialize: every section prints its campaign's ``spec_hash`` and the
JSON round-trip is exercised before each run (what you ran is exactly what
the provenance string says).

    PYTHONPATH=src python examples/quickstart.py [--n-ues 4] [--host]
                                                 [--gated] [--heterogeneous]
"""

import argparse
import os

import numpy as np

from repro.core.runtime import suggest_gated_capacity
from repro.core.session import (
    ArchesSession,
    CampaignSpec,
    ExpertBankSpec,
    PolicySpec,
    SwitchSpec,
    spec_hash,
)
from repro.phy.scenario import make_schedule, scenario_names

N_PHASE = 10


def roundtrip(spec: CampaignSpec) -> CampaignSpec:
    """Serialize -> parse, proving the spec is its own provenance record."""
    restored = CampaignSpec.from_json(spec.to_json())
    assert restored == spec
    return restored


def closed_loop_demo(n_ues: int) -> None:
    spec = roundtrip(CampaignSpec(
        path="closed_loop",
        scenario="good_poor_good",
        scenario_args=(("poor_start", N_PHASE), ("poor_end", 2 * N_PHASE)),
        n_ues=n_ues,
        n_slots=3 * N_PHASE,
        seed=42,
        policies=(PolicySpec(kind="tree", depth=2),),
        switch=SwitchSpec(window_slots=2),
    ))
    session = ArchesSession(spec)
    hist = session.run()

    schedule = make_schedule(spec.scenario, **spec.scenario_kwargs)
    print(f"== closed loop: decisions inside the scan "
          f"({spec.n_ues} UEs x {spec.n_slots} slots) "
          f"[spec {spec_hash(spec)}] ==")
    for s in range(0, spec.n_slots, 3):
        cond = "poor" if schedule(s).interference else "good"
        row = "".join("A" if m == 0 else "M" for m in hist.modes[s])
        print(f"slot {s:3d} [{cond}] per-UE experts: {row}")

    replay = session.host_replay(hist)
    match = np.array_equal(hist.modes, replay["active_mode"])
    print(f"device == host replay: {'yes (bitwise)' if match else 'NO'}; "
          f"switches/UE: {hist.n_switches.tolist()}")
    if not match:
        raise SystemExit("closed-loop equivalence violated")


def host_demo() -> None:
    spec = roundtrip(CampaignSpec(
        path="host",
        scenario="good_poor_good",
        scenario_args=(("poor_start", N_PHASE), ("poor_end", 2 * N_PHASE)),
        n_ues=1,
        n_slots=3 * N_PHASE,
        policies=(PolicySpec(kind="tree", depth=2, train_ues=2),),
        switch=SwitchSpec(window_slots=2, ttl_slots=8),
    ))
    hist = ArchesSession(spec).run()

    schedule = make_schedule(spec.scenario, **spec.scenario_kwargs)
    names = {0: "AI  ", 1: "MMSE"}
    print(f"\n== host loop: E3 + dApp control plane [spec {spec_hash(spec)}] ==")
    for s in range(spec.n_slots):
        cond = "poor" if schedule(s).interference else "good"
        tput = hist.kpms["phy_throughput"][s, 0]
        bar = "#" * int(tput / 2e6)
        print(f"slot {s:3d} [{cond}] expert={names[int(hist.modes[s, 0])]} "
              f"tput={tput / 1e6:5.1f} Mbps {bar}")
    print("(decisions apply at slot n+1 — paper 3.3)")


def gated_demo(n_ues: int) -> None:
    n_ai = max(1, n_ues // 4)
    modes = np.ones((3 * N_PHASE, n_ues), np.int32)
    modes[:, :n_ai] = 0  # 1-in-4 UEs on AI
    base = dict(
        scenario="good_poor_good",
        scenario_args=(("poor_start", N_PHASE), ("poor_end", 2 * N_PHASE)),
        n_ues=n_ues,
        n_slots=3 * N_PHASE,
        modes=tuple(map(tuple, modes)),
    )
    gated = roundtrip(CampaignSpec(
        path="gated",
        bank=ExpertBankSpec(execution_mode="gated", gated_capacity=n_ai),
        **base,
    ))
    conc = CampaignSpec(path="batched", **base)

    hist_g = ArchesSession(gated).run()
    hist_c = ArchesSession(conc).run()

    same = np.array_equal(hist_c.modes, hist_g.modes) and all(
        np.array_equal(hist_c.kpms[k], hist_g.kpms[k]) for k in hist_c.kpms
    )
    fl_c = hist_c.executed_flops_per_slot().mean()
    fl_g = hist_g.executed_flops_per_slot().mean()
    print(f"\n== gated execution: {n_ai}/{n_ues} UEs on AI "
          f"[spec {spec_hash(gated)}] ==")
    print(f"executed compute:  concurrent {fl_c / 1e9:.3f} GFLOP/slot -> "
          f"gated {fl_g / 1e9:.3f} GFLOP/slot "
          f"({(1 - fl_g / fl_c) * 100:.0f}% saved)")
    print(f"trajectories identical: {'yes (bitwise)' if same else 'NO'}; "
          f"overflow slot-UEs: {hist_g.overflow_slot_ues}")
    print(f"suggest_gated_capacity(history) -> "
          f"{suggest_gated_capacity(hist_g)} (provisioned: {n_ai})")
    if not same:
        raise SystemExit("gated != concurrent trajectory")

    # fused hot path: one kernel replaces the gather -> expert -> scatter
    # triple.  Same spec + fused=True must reproduce the gated campaign
    # bitwise — fusion is a launch/memory win, never a numerics change.
    fused = roundtrip(CampaignSpec(
        path="gated",
        bank=ExpertBankSpec(execution_mode="gated", gated_capacity=n_ai,
                            fused=True),
        **base,
    ))
    hist_f = ArchesSession(fused).run()
    fused_same = all(
        np.array_equal(hist_g.kpms[k], hist_f.kpms[k]) for k in hist_g.kpms
    ) and all(
        np.array_equal(hist_g.outputs[k], hist_f.outputs[k])
        for k in hist_g.outputs
    )
    print(f"fused hot path [spec {spec_hash(fused)}]: "
          f"{'bitwise-equal to unfused' if fused_same else 'DIVERGED'}")
    if not fused_same:
        raise SystemExit("fused != unfused gated trajectory")

    # bf16 expert variant: half the GEMM operand bytes, f32 accumulation.
    # Not bitwise — the in-scan NMSE audit guards it: any served UE whose
    # output diverges > threshold from the dense MMSE fail-safe reverts to
    # it (and is flagged in the audit_tripped leaf).  The score is
    # expert-vs-fail-safe, so tight thresholds trip wherever the expert
    # genuinely disagrees with MMSE — tripped UEs are served the fail-safe.
    bf16 = roundtrip(CampaignSpec(
        path="gated",
        bank=ExpertBankSpec(execution_mode="gated", gated_capacity=n_ai,
                            fused=True, dtype="bfloat16",
                            audit_nmse_threshold=1.0),
        **base,
    ))
    hist_b = ArchesSession(bf16).run()
    total = hist_b.modes.size
    print(f"bf16 expert [spec {spec_hash(bf16)}]: audit tripped "
          f"{hist_b.audit_tripped_slot_ues}/{total} slot-UEs at NMSE 1.0 "
          f"(tripped UEs reverted to the MMSE fail-safe that slot)")


def heterogeneous_demo(n_ues: int) -> None:
    spec = roundtrip(CampaignSpec(
        path="closed_loop",
        scenario="mixed_cell",
        n_ues=n_ues,
        n_slots=3 * N_PHASE,
        seed=1,
        policies=(
            # train_scenario=None: per-UE campaign -> the tree trains on
            # good_poor_good with its window scaled into the horizon
            PolicySpec(kind="tree", depth=2),
            PolicySpec(kind="threshold", feature="snr", threshold=18.0,
                       hysteresis=2.0),
        ),
        policy_assignment=tuple(u % 2 for u in range(n_ues)),
        switch=SwitchSpec(window_slots=2),
    ))
    session = ArchesSession(spec)
    hist = session.run()

    print(f"\n== per-UE heterogeneity: mixed_cell scenario, 2 policies "
          f"[spec {spec_hash(spec)}] ==")
    kinds = [spec.policies[i].kind for i in spec.policy_assignment]
    print("UE ->", "  ".join(f"{u}:{k}" for u, k in enumerate(kinds)))
    for s in range(0, spec.n_slots, 3):
        row = "".join("A" if m == 0 else "M" for m in hist.modes[s])
        print(f"slot {s:3d} per-UE experts: {row}")

    replay = session.host_replay(hist)
    match = np.array_equal(hist.modes, replay["active_mode"])
    print(f"device == per-UE host replay: "
          f"{'yes (bitwise)' if match else 'NO'}; "
          f"switches/UE: {hist.n_switches.tolist()}")
    if not match:
        raise SystemExit("per-UE closed-loop equivalence violated")


def multi_cell_demo(n_ues: int) -> None:
    from repro.core.topology import TopologySpec

    n_cells = 4
    n_ues = max(n_ues, n_cells) // n_cells * n_cells  # cells split evenly
    spec = roundtrip(CampaignSpec(
        path="closed_loop",
        scenario="multi_cell",
        scenario_args=(
            ("n_cells", n_cells),
            ("per_cell_scenario",
             ("good", "poor", "bursty_interference", "good")),
        ),
        n_ues=n_ues,
        n_slots=3 * N_PHASE,
        seed=3,
        policies=(PolicySpec(kind="threshold", feature="snr",
                             threshold=18.0, hysteresis=2.0),),
        switch=SwitchSpec(window_slots=2),
        topology=TopologySpec(
            n_cells=n_cells,
            coupling=0.4,
            cell_noise_offsets_db=(0.0, 0.0, 2.0, 0.0),
        ),
    ))
    session = ArchesSession(spec)
    hist = session.run()

    topo = session.cell_topology
    print(f"\n== sharded multi-cell: {n_cells} cells x "
          f"{n_ues // n_cells} UEs on {topo.n_shards} shard(s) "
          f"[spec {spec_hash(spec)}] ==")
    cell_scen = dict(spec.scenario_args)["per_cell_scenario"]
    share = hist.per_cell_ai_share
    tput = hist.per_cell_throughput
    for c in range(n_cells):
        bar = "#" * int(share[c] * 20)
        print(f"cell {c} [{cell_scen[c]:>20s}] AI share {share[c]:4.0%} "
              f"{bar:20s} throughput {tput[c] / 1e6:5.1f} Mbps")

    replay = session.host_replay(hist)
    match = np.array_equal(hist.modes, replay["active_mode"])
    print(f"device == host replay across shards: "
          f"{'yes (bitwise)' if match else 'NO'}")
    if not match:
        raise SystemExit("sharded closed-loop equivalence violated")


def streaming_demo(n_ues: int) -> None:
    from repro.core.closed_loop import host_replay_closed_loop
    from repro.core.streaming import ChurnSchedule

    seg = N_PHASE // 2
    n_slots = 6 * seg
    n_ids = 2 * n_ues  # stable-id universe, twice the bank capacity
    churn = ChurnSchedule(
        n_ue_ids=n_ids,
        segment_slots=seg,
        initial=tuple(range(n_ues)),
        events=(
            (seg, 1, "detach"), (seg, n_ues, "attach"),
            (2 * seg, 0, "detach"), (2 * seg, n_ues + 1, "attach"),
            (3 * seg, n_ues, "detach"), (3 * seg, 1, "attach"),
            (4 * seg, n_ues + 1, "detach"), (4 * seg, 0, "attach"),
        ),
    )
    spec = roundtrip(CampaignSpec(
        path="closed_loop",
        scenario="churn_cell",
        n_ues=n_ues,
        n_slots=n_slots,
        seed=7,
        policies=(PolicySpec(kind="threshold", feature="snr",
                             threshold=18.0, hysteresis=2.0),),
        switch=SwitchSpec(window_slots=2),
        churn=churn,
    ))
    session = ArchesSession(spec)
    hist = session.run()

    print(f"\n== streaming churn: {n_ids}-id universe on a {n_ues}-slot "
          f"bank, {n_slots // seg} segments of {seg} slots "
          f"[spec {spec_hash(spec)}] ==")
    boundaries = {
        t0: [(u, kind) for (t, u, kind) in churn.events
             if (t + seg - 1) // seg * seg == t0]
        for t0 in range(0, n_slots, seg)
    }
    for s in range(n_slots):
        if s % seg == 0 and boundaries.get(s):
            evs = ", ".join(f"{kind} UE{u}" for u, kind in boundaries[s])
            print(f"--- segment boundary (slot {s}): {evs} ---")
        row = "".join(
            "." if m == -1 else ("A" if m == 0 else "M")
            for m in hist.modes[s]
        )
        print(f"slot {s:3d} per-id experts: {row}  "
              f"(resident {int(hist.attached[s].sum())}/{n_ids})")

    feats = np.stack(
        [hist.kpms[n] for n in spec.feature_names], axis=-1
    ).astype(np.float32)
    replay = host_replay_closed_loop(
        session.host_policies[0], feats,
        spec.switch.to_config(spec.feature_names),
        attached=hist.attached,
    )
    match = np.array_equal(hist.modes, replay["active_mode"])
    print(f"device == host replay through churn boundaries: "
          f"{'yes (bitwise)' if match else 'NO'}; "
          f"switches/id: {hist.n_switches.tolist()}")
    if not match:
        raise SystemExit("streaming closed-loop equivalence violated")

    # checkpoint layout: one delta per segment boundary, O(segment) bytes,
    # chained back to its predecessor; kill after half the campaign and
    # resume the chain bitwise
    import tempfile

    from repro.checkpoint.store import checkpoint_kind, list_steps

    with tempfile.TemporaryDirectory() as ckpt:
        kill_after = (n_slots // seg) // 2
        session.run_streaming(checkpoint_dir=ckpt, max_segments=kill_after)
        print(f"\ncheckpoint chain after {kill_after} segments "
              f"(killed mid-campaign):")
        for step in list_steps(ckpt):
            d = os.path.join(ckpt, f"step_{step:08d}")
            size = sum(
                os.path.getsize(os.path.join(d, f)) for f in os.listdir(d)
            )
            print(f"  step_{step:08d}/  {size:6d} B  "
                  f"kind={checkpoint_kind(d) or 'monolithic'}")
        resumed = session.run_streaming(resume_from=ckpt)
        ok = np.array_equal(resumed.modes, hist.modes)
        print(f"killed-and-resumed == uninterrupted: "
              f"{'yes (bitwise)' if ok else 'NO'}")
        if not ok:
            raise SystemExit("streaming checkpoint resume violated")


def faults_demo(n_ues: int) -> None:
    from repro.core.faults import FaultSpec

    n_slots = 3 * N_PHASE
    ttl = 3
    outage = (N_PHASE, 2 * N_PHASE)  # dApp down for the middle phase
    burst = (4, 8)  # NaN corruption early, while the dApp is still up
    faults = FaultSpec(
        seed=11,
        decision_outages=(outage,),
        corruption_spans=(burst,),
        corruption_kind="nan",
        breaker_trips=2,
        breaker_window=4,
        breaker_cooldown=4,
    )
    spec = roundtrip(CampaignSpec(
        path="closed_loop",
        scenario="good",
        n_ues=n_ues,
        n_slots=n_slots,
        seed=9,
        # threshold above any SNR: the policy always decides AI, so every
        # MMSE slot below is the ladder acting, not the policy
        policies=(PolicySpec(kind="threshold", feature="snr",
                             threshold=1e9),),
        switch=SwitchSpec(window_slots=2, ttl_slots=ttl),
        faults=faults,
    ))
    session = ArchesSession(spec)
    hist = session.run()

    print(f"\n== fault injection: dApp outage slots "
          f"{outage[0]}-{outage[1] - 1} (ttl={ttl}), NaN burst slots "
          f"{burst[0]}-{burst[1] - 1} [spec {spec_hash(spec)}] ==")
    tripped = np.asarray(hist.outputs["health_tripped"]) > 0
    quar = np.asarray(hist.outputs["quarantined"]) > 0
    for s in range(n_slots):
        row = "".join(
            "q" if quar[s, u]
            else ("!" if tripped[s, u]
                  else ("A" if m == 0 else "M"))
            for u, m in enumerate(hist.modes[s])
        )
        note = ""
        if burst[0] <= s < burst[1]:
            note = "NaN burst -> health screen serves fail-safe"
        elif quar[s].any():
            note = "breaker open: expert quarantined"
        elif outage[0] <= s < outage[0] + ttl:
            note = "dApp down, last decision still fresh"
        elif s < outage[1] and s >= outage[0] + ttl:
            note = f"dApp down > ttl={ttl} -> decayed to fail-safe"
        elif outage[1] <= s < outage[1] + 1:
            note = "dApp back: decisions flow again"
        print(f"slot {s:3d} per-UE: {row}  {note}")
    print("legend: A=AI  M=MMSE fail-safe  !=health trip  q=quarantined")
    print(f"health trips: {int(tripped.sum())} slot-UEs, quarantined: "
          f"{int(quar.sum())} slot-UEs")

    replay = session.host_replay(hist)
    match = (
        np.array_equal(hist.modes, replay["active_mode"])
        and np.array_equal(hist.decisions, replay["raw_decision"])
        and np.array_equal(quar, np.asarray(replay["quarantined"]) > 0)
    )
    print(f"fault-injected device == host oracle: "
          f"{'yes (bitwise)' if match else 'NO'}")
    if not match:
        raise SystemExit("fault-injection replay equivalence violated")


def service_demo(n_ues: int) -> None:
    import json
    import tempfile
    import time
    import urllib.request

    from repro.service import CampaignService
    from repro.service.api import ServiceAPI

    spec = roundtrip(CampaignSpec(
        path="closed_loop",
        scenario="good_poor_good",
        scenario_args=(("poor_start", N_PHASE), ("poor_end", 2 * N_PHASE)),
        n_ues=n_ues,
        n_slots=3 * N_PHASE,
        seed=42,
        policies=(PolicySpec(kind="tree", depth=2),),
        switch=SwitchSpec(window_slots=2),
    ))
    hist_mono = ArchesSession(spec).run()

    print(f"\n== running the service: submit -> poll -> drain "
          f"[spec {spec_hash(spec)}] ==")
    with tempfile.TemporaryDirectory() as state:
        svc = CampaignService(state, max_segment_slots=N_PHASE).start()
        api = ServiceAPI(svc).start()
        print(f"service up on {api.url} (standalone: "
              f"python -m repro.service --state-dir <dir>)")

        # submit: the campaign spec IS the wire format; the service lifts
        # the zero-churn spec to its segmented streaming form
        req = urllib.request.Request(
            api.url + "/campaigns", data=spec.to_json().encode(),
            headers={"Content-Type": "application/json"}, method="POST",
        )
        with urllib.request.urlopen(req, timeout=10) as r:
            cid = json.loads(r.read().decode())["campaign_id"]
        print(f"POST /campaigns -> {cid}")

        # poll: state + segment progress + provenance + checkpoint lineage
        last = None
        while True:
            with urllib.request.urlopen(
                api.url + f"/campaigns/{cid}", timeout=10
            ) as r:
                st = json.loads(r.read().decode())
            key = (st["state"], st["segments_done"])
            if key != last:
                print(f"GET  /campaigns/<id> -> {st['state']:9s} "
                      f"segment {st['segments_done']}/{st['n_segments']} "
                      f"checkpoints {st['checkpoint_steps']}")
                last = key
            if st["state"] in ("completed", "failed", "cancelled"):
                break
            time.sleep(0.05)
        if st["state"] != "completed":
            raise SystemExit(f"service campaign ended {st['state']!r}: "
                             f"{st['error']}")
        assert st["spec_hash"] == spec_hash(spec)

        with urllib.request.urlopen(
            api.url + "/telemetry?n=2", timeout=10
        ) as r:
            for s in json.loads(r.read().decode()):
                print(f"GET  /telemetry -> seg {s['seg_idx']} "
                      f"slots [{s['t0']},{s['t1']}) "
                      f"AI share {s['ai_share']:4.0%} "
                      f"throughput {s['throughput_bps'] / 1e6:5.1f} Mbps")

        hist_svc = svc.result(cid)
        api.stop()
        # drain: finish in-flight segments, checkpoint, exit; a killed
        # service instead resumes every in-flight campaign on restart
        if not svc.drain(timeout=60):
            raise SystemExit("drain timed out")
        print("POST /drain -> graceful exit")

    same = np.array_equal(hist_mono.modes, hist_svc.modes) and all(
        np.array_equal(hist_mono.kpms[k], hist_svc.kpms[k])
        for k in hist_mono.kpms
    )
    print(f"service-run campaign == monolithic run(): "
          f"{'yes (bitwise)' if same else 'NO'}")
    if not same:
        raise SystemExit("service zero-churn equivalence violated")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-ues", type=int, default=4)
    ap.add_argument("--host", action="store_true",
                    help="also run the single-UE host loop (E3 + dApp)")
    ap.add_argument("--gated", action="store_true",
                    help="demo compaction-gated execution")
    ap.add_argument("--heterogeneous", action="store_true",
                    help="demo per-UE scenario + policy heterogeneity")
    ap.add_argument("--multi-cell", action="store_true",
                    help="demo the sharded multi-cell topology (4 cells)")
    ap.add_argument("--streaming", action="store_true",
                    help="demo the epoch-chunked streaming driver (churn)")
    ap.add_argument("--faults", action="store_true",
                    help="demo the fault-injection degradation ladder")
    ap.add_argument("--service", action="store_true",
                    help="demo the resident campaign service "
                         "(submit -> poll -> drain over HTTP)")
    args = ap.parse_args()

    print("registered scenarios:", ", ".join(scenario_names()), "\n")
    closed_loop_demo(max(args.n_ues, 2))
    if args.host:
        host_demo()
    if args.gated:
        gated_demo(max(args.n_ues, 4))
    if args.heterogeneous:
        heterogeneous_demo(max(args.n_ues, 4))
    if args.multi_cell:
        multi_cell_demo(max(args.n_ues, 8))
    if args.streaming:
        streaming_demo(max(args.n_ues, 2))
    if args.faults:
        faults_demo(max(args.n_ues, 2))
    if args.service:
        service_demo(max(args.n_ues, 2))


if __name__ == "__main__":
    main()
