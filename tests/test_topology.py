"""Sharded multi-cell topology: layout validation, parity, collectives.

The tentpole contract, tested on the 1-device CI mesh:

* every sharded execution path (open-loop, gated, closed-loop, perturbed)
  is **bitwise-equal on all physical trajectory leaves** to the unsharded
  engine under a trivial topology (no offsets, no coupling — the scales
  multiply by exactly 1.0), and to the unsharded *cell-coupled* program
  under a non-trivial one;
* gated compaction stays shard-local: the sharded gated program's jaxpr
  contains the cell-mean ``psum`` and **no** gather/permute collective
  (the multi-device HLO variant of this assertion lives in
  ``tests/test_distributed.py``, which forces an 8-device CPU mesh in a
  subprocess);
* misconfiguration (cells not dividing UEs, per-shard capacity < 1,
  unknown per-cell scenario names) fails at spec/build time with a clear
  message, not as a shape error deep in the scan.
"""

import jax
import numpy as np
import pytest

from repro.core.closed_loop import SwitchConfig
from repro.core.expert_bank import ExecutionMode
from repro.core.policy import ThresholdPolicy
from repro.core.runtime import BatchedRunHistory
from repro.core.session import (
    ArchesSession,
    CampaignSpec,
    ExpertBankSpec,
    PolicySpec,
    SwitchSpec,
    spec_hash,
)
from repro.core.telemetry import SELECTED_KPMS
from repro.core.topology import (
    CellTopology,
    TopologySpec,
    make_ue_mesh,
    open_loop_fn,
    per_shard_capacity,
    run_closed_loop_sharded,
    run_perturbed_sharded,
    run_sharded,
)
from repro.phy.ai_estimator import AiEstimatorConfig, init_params
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import BatchedPuschPipeline
from repro.phy.scenario import good_poor_good_schedule

N_SLOTS, N_UES = 6, 4
CFG = SlotConfig(n_prb=24)
NET = AiEstimatorConfig(channels=8, n_res_blocks=1)
SCHED = good_poor_good_schedule(poor_start=2, poor_end=4)
TRIVIAL = TopologySpec(n_cells=2)
COUPLED = TopologySpec(
    n_cells=2, coupling=0.5, cell_noise_offsets_db=(0.0, 3.0)
)

# the physical per-slot-per-UE leaves the bitwise contract covers
PHYSICAL_LEAVES = ("tb_ok", "tbs", "mcs", "phy_bits_per_s",
                   "executed_flops", "gated_overflow")


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG, NET)


@pytest.fixture(scope="module")
def engine(params):
    return BatchedPuschPipeline(CFG, params, net=NET)


@pytest.fixture(scope="module")
def gated_engine(params):
    return BatchedPuschPipeline(
        CFG, params, net=NET,
        execution_mode=ExecutionMode.GATED, gated_capacity=1,
    )


def assert_traj_equal(a, b):
    for leaf in PHYSICAL_LEAVES:
        np.testing.assert_array_equal(
            np.asarray(a[leaf]), np.asarray(b[leaf]), err_msg=leaf
        )
    for source, kpms in a["kpms"].items():
        for name in kpms:
            np.testing.assert_array_equal(
                np.asarray(kpms[name]),
                np.asarray(b["kpms"][source][name]),
                err_msg=f"{source}/{name}",
            )


# -- layout / spec validation --------------------------------------------------


def test_topology_spec_validation():
    with pytest.raises(ValueError, match="n_cells"):
        TopologySpec(n_cells=0)
    with pytest.raises(ValueError, match="n_shards"):
        TopologySpec(n_shards=0)
    with pytest.raises(ValueError, match="cell_noise_offsets_db"):
        TopologySpec(n_cells=2, cell_noise_offsets_db=(1.0,))
    with pytest.raises(ValueError, match="cell_inr_offsets_db"):
        TopologySpec(n_cells=3, cell_inr_offsets_db=(0.0, 1.0))


def test_build_requires_divisible_layout():
    with pytest.raises(ValueError, match="does not divide n_ues"):
        CellTopology.build(TopologySpec(n_cells=3), n_ues=4)
    with pytest.raises(ValueError, match="n_shards=3"):
        CellTopology.build(TopologySpec(n_shards=3), n_ues=4)


def test_per_shard_capacity_validation():
    assert per_shard_capacity(8, 4) == 2
    with pytest.raises(ValueError, match="does not divide"):
        per_shard_capacity(5, 2)
    with pytest.raises(ValueError, match="< 1 per shard"):
        per_shard_capacity(0, 2)


def test_make_ue_mesh_degrades_to_available_devices():
    # the CI container has one device: any request degrades to 1 shard
    mesh = make_ue_mesh(8, n_ues=16)
    assert mesh.shape["ues"] <= len(jax.devices())
    assert 16 % mesh.shape["ues"] == 0
    assert make_ue_mesh(None, n_ues=7).shape["ues"] in (1, 7)


def test_cell_layout():
    topo = CellTopology.build(TopologySpec(n_cells=2), n_ues=4)
    np.testing.assert_array_equal(topo.cell_of_ue, [0, 0, 1, 1])
    assert topo.n_cells == 2 and topo.n_shards >= 1
    assert float(topo.cell_params.ues_per_cell) == 2.0


def test_spec_level_topology_validation():
    with pytest.raises(ValueError, match="does not divide"):
        CampaignSpec(n_ues=4, topology=TopologySpec(n_cells=3))
    with pytest.raises(ValueError, match="host"):
        CampaignSpec(path="host", n_ues=1, policies=(PolicySpec(),),
                     topology=TopologySpec())
    # per-shard capacity misconfiguration surfaces at session compile time
    with pytest.raises(ValueError, match="per shard|does not divide"):
        ArchesSession(CampaignSpec(
            path="gated", n_ues=4, n_slots=2,
            bank=ExpertBankSpec(execution_mode="gated", gated_capacity=0),
            topology=TopologySpec(n_cells=2),
        ))
    # scenario-declared cell count must agree with the topology
    with pytest.raises(ValueError, match="one cell count"):
        ArchesSession(CampaignSpec(
            path="batched", scenario="multi_cell",
            scenario_args=(("n_cells", 4),),
            n_ues=4, n_slots=2, topology=TopologySpec(n_cells=2),
        ))


def test_topology_spec_json_round_trip():
    spec = CampaignSpec(
        path="batched", n_ues=4, n_slots=2,
        topology=TopologySpec(n_cells=2, coupling=0.25,
                              cell_noise_offsets_db=(0.0, 1.5)),
    )
    back = CampaignSpec.from_json(spec.to_json())
    assert back == spec
    assert isinstance(back.topology, TopologySpec)
    assert spec_hash(back) == spec_hash(spec)
    # the topology is part of the fingerprint
    assert spec_hash(back) != spec_hash(
        CampaignSpec(path="batched", n_ues=4, n_slots=2)
    )


def test_path_bank_mismatches_raise_at_spec_time():
    with pytest.raises(ValueError, match="un-gated"):
        CampaignSpec(path="gated",
                     bank=ExpertBankSpec(execution_mode="selected_only"))
    with pytest.raises(ValueError, match="MMSE-only"):
        CampaignSpec(path="perturbed", n_ues=2, rho=(0.0, 0.5),
                     bank=ExpertBankSpec(execution_mode="gated"))
    with pytest.raises(ValueError, match="batched path"):
        CampaignSpec(path="host", n_ues=1, policies=(PolicySpec(),),
                     bank=ExpertBankSpec(execution_mode="gated"))
    # ...and therefore also at from_json time
    good = CampaignSpec(path="gated", n_ues=2, n_slots=2)
    bad = good.to_json().replace('"concurrent"', '"selected_only"')
    with pytest.raises(ValueError, match="un-gated"):
        CampaignSpec.from_json(bad)


# -- bitwise parity: sharded entry vs the unsharded engine ---------------------


def test_open_loop_sharded_matches_unsharded_engine(engine):
    topo = CellTopology.build(TRIVIAL, N_UES)
    modes = np.ones((N_SLOTS, N_UES), np.int32)
    modes[:, 0] = 0
    key = jax.random.PRNGKey(3)
    link_s, traj_s = run_sharded(
        engine, topo, SCHED, modes, n_slots=N_SLOTS, key=key
    )
    link_u, traj_u = engine.run(
        SCHED, modes, n_slots=N_SLOTS, n_ues=N_UES, key=key
    )
    assert_traj_equal(traj_s, traj_u)
    np.testing.assert_array_equal(
        np.asarray(link_s.cum_phy_bits), np.asarray(link_u.cum_phy_bits)
    )


def test_gated_sharded_matches_unsharded_engine(gated_engine):
    topo = CellTopology.build(TRIVIAL, N_UES)
    modes = np.ones((N_SLOTS, N_UES), np.int32)
    modes[:, 0] = 0
    modes[3:, 2] = 0  # second AI UE -> capacity-1 overflow slots exist
    key = jax.random.PRNGKey(3)
    _, traj_s = run_sharded(
        gated_engine, topo, SCHED, modes, n_slots=N_SLOTS, key=key
    )
    _, traj_u = gated_engine.run(
        SCHED, modes, n_slots=N_SLOTS, n_ues=N_UES, key=key
    )
    assert_traj_equal(traj_s, traj_u)
    assert np.asarray(traj_s["gated_overflow"]).sum() > 0  # non-vacuous


def test_closed_loop_sharded_matches_unsharded_engine(engine):
    topo = CellTopology.build(TRIVIAL, N_UES)
    policy = ThresholdPolicy(
        feature_idx=SELECTED_KPMS.index("snr"), threshold=18.0, hysteresis=2.0
    )
    sw_cfg = SwitchConfig(
        feature_names=SELECTED_KPMS, window_slots=2, backend="ref"
    )
    key = jax.random.PRNGKey(7)
    _, fsw_s, traj_s = run_closed_loop_sharded(
        engine, topo, SCHED, policy.to_device(), sw_cfg,
        n_slots=N_SLOTS, key=key,
    )
    _, fsw_u, traj_u = engine.run_closed_loop(
        SCHED, policy.to_device(), sw_cfg,
        n_slots=N_SLOTS, n_ues=N_UES, key=key,
    )
    assert_traj_equal(traj_s, traj_u)
    for leaf in ("active_mode", "raw_decision", "pending_mode"):
        np.testing.assert_array_equal(
            np.asarray(traj_s[leaf]), np.asarray(traj_u[leaf]), err_msg=leaf
        )
    np.testing.assert_array_equal(
        np.asarray(fsw_s.n_switches), np.asarray(fsw_u.n_switches)
    )


def test_perturbed_sharded_matches_unsharded_engine(engine):
    topo = CellTopology.build(TRIVIAL, N_UES)
    rho = np.asarray([0.0, 0.3, 0.6, 1.0], np.float32)
    key = jax.random.PRNGKey(5)
    _, traj_s = run_perturbed_sharded(
        engine, topo, SCHED, rho, n_slots=N_SLOTS, key=key
    )
    _, traj_u = engine.run_perturbed(SCHED, rho, n_slots=N_SLOTS, key=key)
    assert_traj_equal(traj_s, traj_u)


# -- cell coupling -------------------------------------------------------------


def test_coupled_topology_sharded_matches_unsharded_reference(engine):
    """With offsets + coupling on, the sharded program must equal the same
    cell-coupled program run unpartitioned — and must *differ* from the
    uncoupled engine (the coupling is not a no-op)."""
    topo = CellTopology.build(COUPLED, N_UES)
    key = jax.random.PRNGKey(3)
    _, traj_s = run_sharded(engine, topo, SCHED, 1, n_slots=N_SLOTS, key=key)
    _, traj_r = run_sharded(
        engine, topo, SCHED, 1, n_slots=N_SLOTS, key=key, sharded=False
    )
    assert_traj_equal(traj_s, traj_r)
    _, traj_plain = engine.run(
        SCHED, 1, n_slots=N_SLOTS, n_ues=N_UES, key=key
    )
    sinr = lambda t: np.asarray(t["kpms"]["aerial"]["sinr"])
    assert not np.array_equal(sinr(traj_s), sinr(traj_plain))
    # cell 0 has no offset, but inter-cell leakage from cell 1's poor
    # phase still shifts its noise floor during the interference window
    assert not np.array_equal(sinr(traj_s)[:, :2], sinr(traj_plain)[:, :2])


def test_cell_offsets_order_ues_by_cell(engine):
    """A 3 dB per-cell noise offset must degrade that cell's measured SINR
    relative to the clean cell (sanity on the broadcast direction)."""
    topo = CellTopology.build(
        TopologySpec(n_cells=2, cell_noise_offsets_db=(0.0, 10.0)), N_UES
    )
    _, traj = run_sharded(
        engine, topo, SCHED, 1, n_slots=N_SLOTS, key=jax.random.PRNGKey(0)
    )
    sinr = np.asarray(traj["kpms"]["aerial"]["sinr"])
    assert sinr[:, :2].mean() > sinr[:, 2:].mean() + 3.0


# -- collective contract -------------------------------------------------------


def test_gated_sharded_jaxpr_has_psum_but_no_gather(gated_engine):
    """Compaction must stay shard-local: the only collective in the sharded
    gated program is the cell-mean psum (channel layer); the bank's
    compact/scatter path introduces no cross-device gather/permute."""
    from repro.phy.channel import broadcast_params_to_ues
    from repro.phy.pipeline import init_device_link, resolve_schedule
    import jax.numpy as jnp

    topo = CellTopology.build(COUPLED, N_UES)
    profile, p = resolve_schedule(CFG, SCHED, N_SLOTS, N_UES)
    p = broadcast_params_to_ues(p, N_UES)
    ue_keys = jax.vmap(
        lambda u: jax.random.fold_in(jax.random.PRNGKey(0), u)
    )(jnp.arange(N_UES))
    modes = jnp.ones((N_SLOTS, N_UES), jnp.int32).at[:, 0].set(0)
    fn = open_loop_fn(gated_engine, topo, profile)
    jaxpr = str(jax.make_jaxpr(fn)(
        init_device_link(N_UES), ue_keys, modes, p,
        jnp.asarray(topo.cell_of_ue), topo.cell_params,
    ))
    assert "psum" in jaxpr
    for collective in ("all_gather", "all_to_all", "ppermute",
                       "pgather", "pswapaxes"):
        assert collective not in jaxpr, collective


# -- session integration -------------------------------------------------------


def test_session_sharded_campaign_end_to_end(params):
    spec = CampaignSpec(
        path="closed_loop",
        scenario="multi_cell",
        scenario_args=(("n_cells", 2), ("per_cell_scenario", ("good", "poor"))),
        n_ues=N_UES,
        n_slots=8,
        seed=1,
        policies=(PolicySpec(kind="threshold", feature="snr",
                             threshold=18.0, hysteresis=2.0),),
        switch=SwitchSpec(window_slots=2, backend="ref"),
        topology=TopologySpec(n_cells=2, coupling=0.4,
                              cell_noise_offsets_db=(0.0, 2.0)),
    )
    assert CampaignSpec.from_json(spec.to_json()) == spec
    session = ArchesSession(spec, ai_params=params)
    hist = session.run()
    # per-cell reductions carry the layout
    np.testing.assert_array_equal(hist.cell_of_ue, [0, 0, 1, 1])
    assert hist.per_cell_ai_share.shape == (2,)
    assert hist.per_cell_throughput.shape == (2,)
    assert hist.per_cell_kpm("snr").shape == (8, 2)
    # the poor cell leans on the AI expert; the clean cell does not
    assert hist.per_cell_ai_share[1] > hist.per_cell_ai_share[0]
    # the closed loop still replays bitwise through the host policy
    replay = session.host_replay(hist)
    np.testing.assert_array_equal(hist.modes, replay["active_mode"])


def test_session_auto_capacity_open_loop(params):
    modes = np.ones((N_SLOTS, N_UES), np.int32)
    modes[:, 0] = 0
    modes[3:, 1] = 0  # peak demand 2
    spec = CampaignSpec(
        path="gated", scenario="good_poor_good",
        scenario_args=(("poor_start", 2), ("poor_end", 4)),
        n_ues=N_UES, n_slots=N_SLOTS, modes=tuple(map(tuple, modes)),
        bank=ExpertBankSpec(execution_mode="gated", gated_capacity=4),
        topology=TopologySpec(n_cells=2),
    )
    hist = ArchesSession(spec, ai_params=params).run(auto_capacity=True)
    assert hist.provisioned_capacity == 2
    assert hist.overflow_slot_ues == 0
    assert hist.ai_share > 0


def test_session_auto_capacity_closed_loop(params):
    """Two-compile pre-pass: the closed loop sizes its own capacity from a
    full-capacity dry run, and the re-provisioned campaign has zero
    overflow by construction (quantile 1.0)."""
    spec = CampaignSpec(
        path="closed_loop", scenario="good_poor_good",
        scenario_args=(("poor_start", 2), ("poor_end", 5)),
        n_ues=N_UES, n_slots=N_SLOTS, seed=2,
        bank=ExpertBankSpec(execution_mode="gated"),
        policies=(PolicySpec(kind="threshold", feature="snr",
                             threshold=18.0, hysteresis=2.0),),
        switch=SwitchSpec(window_slots=2, backend="ref"),
    )
    session = ArchesSession(spec, ai_params=params)
    hist = session.run(auto_capacity=True)
    assert hist.provisioned_capacity is not None
    assert hist.provisioned_capacity >= 1
    assert hist.overflow_slot_ues == 0
    # the re-provisioned engine is what actually ran
    assert session.engine.bank.gated_capacity == hist.provisioned_capacity


def test_auto_capacity_rejects_ungated_bank():
    with pytest.raises(ValueError, match="auto_capacity"):
        ArchesSession(CampaignSpec(path="batched", n_ues=2, n_slots=2)).run(
            auto_capacity=True
        )


# -- per-cell reductions on plain histories ------------------------------------


def test_per_cell_reductions_need_a_topology():
    hist = BatchedRunHistory(
        modes=np.zeros((2, 2), np.int32), kpms={}, outputs={}
    )
    with pytest.raises(ValueError, match="per-cell"):
        _ = hist.per_cell_ai_share


def test_per_cell_ai_share_counts_served_not_selected():
    modes = np.zeros((2, 4), np.int32)  # everyone selects AI
    overflow = np.zeros((2, 4), np.int32)
    overflow[:, 3] = 1  # one UE of cell 1 always overflows
    hist = BatchedRunHistory(
        modes=modes, kpms={}, outputs={"gated_overflow": overflow},
        cell_of_ue=np.asarray([0, 0, 1, 1]),
    )
    np.testing.assert_allclose(hist.per_cell_ai_share, [1.0, 0.5])


def test_suggest_gated_capacity_covers_shard_local_spikes():
    """Per-shard compaction means a shard-local demand spike must drive the
    campaign capacity even when the campaign-wide count would fit."""
    from repro.core.runtime import suggest_gated_capacity

    modes = np.ones((4, 4), np.int32)
    modes[2, 0] = modes[2, 1] = 0  # both AI UEs live in shard 0 of 2
    hist = BatchedRunHistory(modes=modes, kpms={}, outputs={})
    assert suggest_gated_capacity(hist) == 2  # campaign-wide peak
    # 2 shards: shard 0 peaks at 2 -> per-shard 2 -> campaign 4
    assert suggest_gated_capacity(hist, n_shards=2) == 4
    with pytest.raises(ValueError, match="does not divide"):
        suggest_gated_capacity(hist, n_shards=3)


def test_topology_rejects_scenario_default_cell_count_mismatch():
    """multi_cell's *default* n_cells (2) must also be checked against the
    topology — not just an explicitly passed value."""
    with pytest.raises(ValueError, match="one cell count"):
        ArchesSession(CampaignSpec(
            path="batched", scenario="multi_cell",
            n_ues=8, n_slots=2, topology=TopologySpec(n_cells=4),
        ))
