"""Full-system integration: the paper's Fig. 9 scenario in miniature.

Channel conditions transition good -> poor -> good; the ARCHES loop
(pipeline + E3 + dApp + decision tree) must select MMSE in good phases and
AI in poor phases, switching only at slot boundaries.
"""

import jax
import numpy as np
import pytest

from repro.core.dapp import DApp, connect_dapp
from repro.core.e3 import E3Agent
from repro.core.policy import DecisionTreePolicy, fit_decision_tree
from repro.core.runtime import ArchesRuntime
from repro.core.telemetry import SELECTED_KPMS
from repro.phy.ai_estimator import AiEstimatorConfig, init_params
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import LinkState, PuschPipeline
from repro.phy.scenario import GOOD, good_poor_good_schedule

CFG = SlotConfig(n_prb=24)
NET = AiEstimatorConfig(channels=8, n_res_blocks=1)
N_PHASE = 8


@pytest.mark.slow
def test_fig9_good_poor_good_switching():
    params = init_params(jax.random.PRNGKey(0), CFG, NET)
    pipe = PuschPipeline(CFG, params, net=NET)
    schedule = good_poor_good_schedule(poor_start=N_PHASE, poor_end=2 * N_PHASE)

    # train the policy on labelled OTA-style runs (paper 5.3: slots under
    # interference are labelled mode=0). Telemetry is profiled under BOTH
    # experts, as the paper's per-expert profiling does, so the learned
    # threshold is robust to whichever expert is live.
    X, y = [], []
    for profile_mode in (0, 1):
        link = LinkState()
        for slot in range(3 * N_PHASE):
            ch = schedule(slot)
            link, out, kpms = pipe.run_slot(
                jax.random.PRNGKey(slot), profile_mode, link, ch
            )
            flat = {**kpms["aerial"], **kpms["oai"]}
            X.append([flat[n] for n in SELECTED_KPMS])
            y.append(0 if ch.interference else 1)
    tree = fit_decision_tree(np.asarray(X, np.float32), np.asarray(y), depth=2)
    policy = DecisionTreePolicy(tree, SELECTED_KPMS)

    # live run under the ARCHES loop
    agent = E3Agent()
    dapp = DApp(policy, SELECTED_KPMS, window_slots=2)
    connect_dapp(agent, dapp)
    runtime = ArchesRuntime(
        pipe.make_slot_fn(schedule), agent, default_mode=1, fail_safe_mode=1,
        ttl_slots=8,
    )
    hist = runtime.run(range(3 * N_PHASE))
    modes = hist.modes

    # good phase: mostly MMSE; poor phase: mostly AI (allowing boundary lag)
    good1 = modes[2:N_PHASE]
    poor = modes[N_PHASE + 3 : 2 * N_PHASE]
    good2 = modes[2 * N_PHASE + 3 :]
    assert np.mean(good1 == 1) >= 0.7, modes
    assert np.mean(poor == 0) >= 0.6, modes
    assert np.mean(good2 == 1) >= 0.6, modes
    # switching happened, but no per-slot flapping
    assert 1 <= int(hist.final_state.n_switches) <= 8


def test_data_integrity_across_switches():
    """Paper 6.1 'Data Integrity': switching must not corrupt in-flight TBs.

    The integrity claim is about the *switch mechanism*, not the experts:
    routing an expert's output through the concurrent bank + Pallas switch
    kernel must decode every TB exactly as executing only that expert
    directly (``SELECTED_ONLY`` / ``lax.switch``) under the same aggressive
    mode sequence.  (Comparing against a static single-expert run instead
    would conflate mechanism integrity with legitimate estimator-quality
    differences in the link-adaptation trajectory.)
    """
    from repro.core.expert_bank import ExecutionMode

    params = init_params(jax.random.PRNGKey(0), CFG, NET)
    modes = [1, 1, 0, 1, 0, 0, 1]  # aggressive switching pattern

    def run(pipe):
        link = LinkState()
        oks = []
        for i, m in enumerate(modes):
            link, out, _ = pipe.run_slot(jax.random.PRNGKey(100 + i), m, link, GOOD)
            oks.append(out["tb_ok"])
        return oks

    oks_switched = run(PuschPipeline(CFG, params, net=NET))
    oks_direct = run(
        PuschPipeline(
            CFG, params, net=NET, execution_mode=ExecutionMode.SELECTED_ONLY
        )
    )
    # slot-by-slot TB outcomes are IDENTICAL whether the selected expert's
    # output arrives via the switch kernel or via direct execution — the
    # switch never corrupts an in-flight TB
    assert oks_switched == oks_direct, (oks_switched, oks_direct)
    # and once OLLA settles (~5 slots from cold start), TBs decode
    assert all(o == 1.0 for o in oks_switched[5:]), oks_switched
