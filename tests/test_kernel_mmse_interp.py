"""Per-kernel allclose: Wiener/MMSE frequency interpolation vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mmse_interp import mmse_interp
from repro.kernels.mmse_interp.ref import mmse_interp_ref
from repro.phy.estimators import WienerInterpolator
from repro.phy.nr import SlotConfig


def _h(key, shape):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape) + 1j * jax.random.normal(ki, shape)).astype(
        jnp.complex64
    )


@pytest.mark.parametrize("n_prb", [4, 24, 51, 106])
@pytest.mark.parametrize("lead", [(4, 3), (1, 1), (2, 2, 3)])
def test_mmse_interp_shapes(n_prb, lead):
    cfg = SlotConfig(n_prb=n_prb)
    wi = WienerInterpolator.build(cfg, rms_delay_spread_s=1e-7)
    np_pilot = wi.w.shape[0]
    h = _h(jax.random.PRNGKey(n_prb), (*lead, np_pilot))
    got = mmse_interp(h, wi.w)
    want = mmse_interp_ref(h, wi.w)
    assert got.shape == want.shape == (*lead, wi.w.shape[1])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_mmse_interp_random_w(rng):
    """Property: arbitrary complex filter matrices, not just Wiener builds."""
    for trial in range(10):
        np_pilot = int(rng.integers(2, 64))
        n_sc = int(rng.integers(np_pilot, 256))
        lead = tuple(int(rng.integers(1, 5)) for _ in range(int(rng.integers(1, 3))))
        key = jax.random.PRNGKey(trial)
        h = _h(key, (*lead, np_pilot))
        w = _h(jax.random.fold_in(key, 1), (np_pilot, n_sc))
        got = mmse_interp(h, w)
        want = mmse_interp_ref(h, w)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )


def test_wiener_weights_sane():
    """Wiener filter ~ reproduces pilots at pilot positions at high SNR."""
    cfg = SlotConfig(n_prb=24)
    wi = WienerInterpolator.build(cfg, rms_delay_spread_s=30e-9, noise_var=1e-4)
    w = np.asarray(wi.w)
    assert np.isfinite(w).all()
    # row-energy bounded (no exploding filter)
    assert np.abs(w).max() < 10.0
