"""The resident campaign service: dispatch, telemetry export, northbound API.

The service-layer contracts, asserted here:

* **(a) zero-churn through the service == monolithic, bitwise** — a
  churn-free spec submitted over the HTTP API is lifted to its segmented
  streaming form (``as_streaming_spec``), executed with per-segment
  checkpoints, and its completed history is bitwise-equal to
  ``ArchesSession.run()`` on every leaf; the API reports segment
  progress, spec_hash provenance (submitted *and* lifted run form) and
  the checkpoint lineage throughout.
* **(b) drain / kill -> restart resumes bitwise** — a drain requested at
  a chosen segment boundary (in-process, deterministic) and a real
  SIGTERM delivered to a ``python -m repro.service`` child mid-campaign
  both leave an ``interrupted`` campaign whose restarted service resumes
  it from the latest checkpoint to a history bitwise-equal to the
  uninterrupted ``run_streaming()`` (the PR 8 ``resume_from=`` contract
  carried through the service path).
* **(c) telemetry is lossless or exactly counted** — the ring's ``push``
  is O(1) under its lock and never waits on a consumer; ``drain(cursor)``
  reports *exactly* the overwritten-sample count under wrap-around and
  under concurrent producers (sequence arithmetic, not sampling); the
  JSONL exporter receives every sample the pump drained, in order.
"""

from __future__ import annotations

import dataclasses
import json
import os
import signal
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core.runtime import BatchedRunHistory
from repro.core.session import (
    ArchesSession,
    CampaignSpec,
    as_streaming_spec,
    spec_hash,
)
from repro.core.streaming import ChurnSchedule
from repro.core.telemetry import segment_telemetry
from repro.service import (
    CampaignService,
    CampaignState,
    ExportPump,
    JsonlExporter,
    ServiceSaturatedError,
    TelemetryRing,
    UnknownCampaignError,
)

N_PRB = 6
N_UES = 4
N_SLOTS = 12
SEG = 4


def _modes_grid(n_slots: int, n_ues: int) -> tuple:
    return tuple(
        tuple((s + u) % 2 for u in range(n_ues)) for s in range(n_slots)
    )


def _base_spec(**kw) -> CampaignSpec:
    args = dict(
        path="batched", scenario="churn_cell", n_ues=N_UES,
        n_slots=N_SLOTS, n_prb=N_PRB, seed=3,
        modes=_modes_grid(N_SLOTS, N_UES),
    )
    args.update(kw)
    return CampaignSpec(**args)


def assert_history_equal(a, b):
    np.testing.assert_array_equal(a.modes, b.modes, err_msg="modes")
    assert set(a.kpms) == set(b.kpms)
    for k in a.kpms:
        np.testing.assert_array_equal(a.kpms[k], b.kpms[k], err_msg=k)
    assert set(a.outputs) == set(b.outputs)
    for k in a.outputs:
        np.testing.assert_array_equal(a.outputs[k], b.outputs[k], err_msg=k)


# -- telemetry ring: wrap-around + concurrency, drops exactly counted ---------


def test_ring_validation_and_basic_drain():
    with pytest.raises(ValueError, match="capacity"):
        TelemetryRing(0)
    ring = TelemetryRing(8)
    assert ring.head == 0
    seqs = [ring.push({"i": i}) for i in range(5)]
    assert seqs == [0, 1, 2, 3, 4]
    samples, cursor, dropped = ring.drain(0)
    assert [s["i"] for s in samples] == [0, 1, 2, 3, 4]
    assert (cursor, dropped) == (5, 0)
    # nothing new: empty drain, cursor stable
    samples, cursor, dropped = ring.drain(cursor)
    assert (samples, cursor, dropped) == ([], 5, 0)


def test_ring_wraparound_drop_count_is_exact():
    ring = TelemetryRing(4)
    for i in range(10):
        ring.push(i)
    # cursor 0: samples 0..5 were overwritten -> exactly 6 dropped
    samples, cursor, dropped = ring.drain(0)
    assert samples == [6, 7, 8, 9]
    assert (cursor, dropped) == (10, 6)
    # an up-to-date cursor then sees no loss
    ring.push(10)
    samples, cursor, dropped = ring.drain(cursor)
    assert (samples, cursor, dropped) == ([10], 11, 0)
    # a cursor mid-way through the overwritten span counts only its own loss
    samples, _, dropped = ring.drain(5)
    assert samples == [7, 8, 9, 10]
    assert dropped == 2  # samples 5, 6


def test_ring_snapshot_is_cursor_free():
    ring = TelemetryRing(4)
    for i in range(6):
        ring.push(i)
    assert ring.snapshot() == [2, 3, 4, 5]
    assert ring.snapshot(2) == [4, 5]
    assert ring.snapshot(99) == [2, 3, 4, 5]
    # snapshot does not advance any drain cursor
    _, _, dropped = ring.drain(0)
    assert dropped == 2


def test_ring_concurrent_producers_and_consumer_account_every_sample():
    """N producers + 1 draining consumer: delivered + dropped == pushed,
    and the delivered sequence numbers are strictly increasing (no
    duplicates, no uncounted gaps)."""
    ring = TelemetryRing(16)
    n_producers, per_producer = 4, 500
    total = n_producers * per_producer

    def produce(pid):
        for i in range(per_producer):
            ring.push({"pid": pid, "i": i, "seq": None})

    seen: list = []
    dropped_total = 0
    stop = threading.Event()

    def consume():
        nonlocal dropped_total
        cursor = 0
        while not stop.is_set() or cursor < ring.head:
            samples, new_cursor, dropped = ring.drain(cursor)
            seen.extend(range(cursor + dropped, new_cursor))
            dropped_total += dropped
            cursor = new_cursor

    threads = [
        threading.Thread(target=produce, args=(p,))
        for p in range(n_producers)
    ]
    consumer = threading.Thread(target=consume)
    consumer.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    consumer.join()

    assert ring.head == total
    assert len(seen) + dropped_total == total
    assert seen == sorted(set(seen)), "duplicate or reordered delivery"


def test_ring_push_never_blocks_on_a_stalled_consumer():
    """A consumer sitting on a stale cursor costs producers nothing: push
    latency is flat while the ring wraps thousands of times."""
    ring = TelemetryRing(4)
    t0 = time.perf_counter()
    for i in range(20_000):
        ring.push(i)
    elapsed = time.perf_counter() - t0
    assert elapsed < 2.0, f"push path blocked: {elapsed:.2f}s for 20k pushes"
    _, _, dropped = ring.drain(0)
    assert dropped == 20_000 - 4


# -- exporters + pump ---------------------------------------------------------


def test_jsonl_exporter_receives_every_drained_sample(tmp_path):
    path = str(tmp_path / "telemetry.jsonl")
    ring = TelemetryRing(64)
    pump = ExportPump(ring, [JsonlExporter(path)])
    for i in range(20):
        ring.push({"seg_idx": i})
    assert pump.pump_once() == 20
    ring.push({"seg_idx": 20})
    assert pump.pump_once() == 1
    for ex in pump.exporters:
        ex.close()
    with open(path) as f:
        rows = [json.loads(line) for line in f]
    assert [r["seg_idx"] for r in rows] == list(range(21))
    assert pump.counters() == {
        "exported": 21, "dropped": 0, "export_errors": 0,
    }


def test_pump_counts_ring_drops_exactly():
    ring = TelemetryRing(4)
    sink: list = []

    class ListExporter:
        def export(self, samples):
            sink.extend(samples)

        def close(self):
            pass

    pump = ExportPump(ring, [ListExporter()])
    for i in range(10):
        ring.push(i)
    pump.pump_once()
    assert sink == [6, 7, 8, 9]
    assert pump.dropped == 6
    assert pump.exported == 4


def test_pump_swallows_and_counts_exporter_errors():
    ring = TelemetryRing(8)
    good: list = []

    class Broken:
        def export(self, samples):
            raise RuntimeError("sink down")

    class Good:
        def export(self, samples):
            good.extend(samples)

    pump = ExportPump(ring, [Broken(), Good()])
    ring.push({"x": 1})
    pump.pump_once()  # must not raise
    assert good == [{"x": 1}]
    assert pump.export_errors == 1
    assert pump.exported == 1


# -- segment telemetry reduction ----------------------------------------------


def test_segment_telemetry_masks_residency_and_fallbacks():
    modes = np.array([[0, 1], [0, 0], [-1, 0]], np.int32)
    attached = np.array([[1, 1], [1, 1], [0, 1]], bool)
    tput = np.array([[10.0, 20.0], [30.0, 40.0], [0.0, 50.0]], np.float32)
    flops = np.array([[5.0, 0.0], [5.0, 5.0], [0.0, 5.0]], np.float32)
    overflow = np.array([[0, 0], [1, 0], [0, 0]], np.int32)
    hist = BatchedRunHistory(
        modes=modes,
        kpms={"phy_throughput": tput},
        outputs={"executed_flops": flops, "gated_overflow": overflow},
        attached=attached,
        cell_of_ue=np.array([0, 1], np.int32),
    )
    out = segment_telemetry(hist, 0, 3)
    assert out["resident_slot_ues"] == 5
    # served-by-AI: mode==0 & resident & not overflowed ->
    # (0,0), (1,1), (2,1): 3 of 5 residents
    assert out["ai_share"] == pytest.approx(3 / 5)
    assert out["throughput_bps"] == pytest.approx(
        (10.0 + 20.0 + 30.0 + 40.0 + 50.0) / 5
    )
    assert out["executed_flops"] == pytest.approx(20.0)
    assert out["gated_overflow_slot_ues"] == 1
    assert out["per_cell_throughput_bps"] == [
        pytest.approx((10.0 + 30.0) / 2),
        pytest.approx((20.0 + 40.0 + 50.0) / 3),
    ]
    # a sub-span reduces only its own slots
    sub = segment_telemetry(hist, 2, 3)
    assert sub["resident_slot_ues"] == 1
    assert sub["throughput_bps"] == pytest.approx(50.0)
    with pytest.raises(ValueError, match="outside"):
        segment_telemetry(hist, 2, 5)


# -- spec lifting -------------------------------------------------------------


def test_as_streaming_spec_lifts_zero_churn():
    spec = _base_spec()
    lifted = as_streaming_spec(spec, max_segment_slots=SEG)
    assert lifted.churn == ChurnSchedule(
        n_ue_ids=N_UES, segment_slots=SEG, initial=tuple(range(N_UES))
    )
    assert spec_hash(lifted) != spec_hash(spec)
    # idempotent on already-streaming specs
    assert as_streaming_spec(lifted) is lifted
    # segment length: largest divisor of n_slots <= the cap
    assert as_streaming_spec(spec, max_segment_slots=5).churn.segment_slots == 4
    assert as_streaming_spec(spec, max_segment_slots=7).churn.segment_slots == 6
    with pytest.raises(ValueError, match="streaming form"):
        as_streaming_spec(_base_spec(path="host", n_ues=1, modes=1))


# -- the service: queue-only control paths (no JAX execution) -----------------


def test_cancel_queued_and_unknown(tmp_path):
    svc = CampaignService(str(tmp_path / "s"))  # not started: stays queued
    cid = svc.submit(_base_spec())
    assert svc.status(cid)["state"] == CampaignState.QUEUED
    assert svc.cancel(cid) == CampaignState.CANCELLED
    assert svc.status(cid)["state"] == CampaignState.CANCELLED
    with pytest.raises(UnknownCampaignError):
        svc.status("c9999-deadbeef")
    with pytest.raises(UnknownCampaignError):
        svc.cancel("c9999-deadbeef")


def test_submit_saturation_is_explicit(tmp_path):
    svc = CampaignService(str(tmp_path / "s"), queue_size=1)
    cid = svc.submit(_base_spec())
    with pytest.raises(ServiceSaturatedError):
        svc.submit(_base_spec(seed=4))
    # the rejected campaign leaves no record or state-dir litter
    assert [c["campaign_id"] for c in svc.list_campaigns()] == [cid]
    assert os.listdir(svc.campaigns_dir) == [cid]


def test_recovery_exceeds_queue_size_without_blocking(tmp_path):
    """A restarted service re-enqueues *every* recoverable campaign even
    when there are more of them than its submission cap — a saturated
    service that crashed must recover, not deadlock in start()."""
    state = str(tmp_path / "s")
    svc = CampaignService(state, queue_size=4)  # not started: all stay queued
    cids = [svc.submit(_base_spec(seed=s)) for s in range(3, 7)]
    svc2 = CampaignService(state, queue_size=1)
    t = threading.Thread(target=svc2._recover, daemon=True)
    t.start()
    t.join(10.0)
    assert not t.is_alive(), "_recover() blocked on the dispatch queue"
    # all four recovered, in original submission order
    assert [svc2._queue.get_nowait() for _ in range(4)] == cids
    # the submission cap still applies to new submits while saturated
    with pytest.raises(ServiceSaturatedError):
        svc2.submit(_base_spec(seed=9))


def test_cancelled_and_torn_campaigns_not_recovered(tmp_path):
    state = str(tmp_path / "s")
    svc = CampaignService(state)
    cid_q = svc.submit(_base_spec())
    cid_c = svc.submit(_base_spec(seed=4))
    svc.cancel(cid_c)
    # torn submit: a directory with no status.json (crash mid-persist)
    os.makedirs(os.path.join(svc.campaigns_dir, "c9999-torn"))
    svc2 = CampaignService(state)
    svc2._recover()
    states = {c["campaign_id"]: c["state"] for c in svc2.list_campaigns()}
    assert states == {
        cid_q: CampaignState.QUEUED, cid_c: CampaignState.CANCELLED,
    }
    assert svc2._queue.qsize() == 1  # only the queued one re-enqueued
    # recovered ids continue the submission sequence (no id reuse)
    cid_new = svc2.submit(_base_spec(seed=5))
    assert int(cid_new[1:5]) == 3


# -- the service: execution contracts (shared compiled components) ------------


@pytest.fixture(scope="module")
def ref_session():
    return ArchesSession(_base_spec())


@pytest.fixture(scope="module")
def api_run(ref_session, tmp_path_factory):
    """One full service lifecycle over the northbound HTTP API.

    Submits the module's zero-churn campaign over HTTP, polls it to
    completion, then exercises every API route (including the error
    paths and the drain) against the live service.  Module-scoped so the
    engine compile happens once; the tests below assert on the captured
    outcome.
    """
    from repro.service.api import ServiceAPI

    state = str(tmp_path_factory.mktemp("svc-api"))
    jsonl = os.path.join(state, "telemetry.jsonl")
    svc = CampaignService(
        state,
        max_segment_slots=SEG,
        exporters=[JsonlExporter(jsonl)],
        ai_params=ref_session.ai_params,
    ).start()
    api = ServiceAPI(svc).start()
    base = api.url

    def get(path):
        try:
            with urllib.request.urlopen(base + path, timeout=10) as r:
                return r.status, json.loads(r.read().decode())
        except urllib.error.HTTPError as e:
            return e.code, json.loads(e.read().decode())

    def post(path, payload=None):
        req = urllib.request.Request(
            base + path,
            data=json.dumps(payload).encode() if payload is not None else b"",
            headers={"Content-Type": "application/json"},
            method="POST",
        )
        try:
            with urllib.request.urlopen(req, timeout=10) as r:
                return r.status, json.loads(r.read().decode())
        except urllib.error.HTTPError as e:
            return e.code, json.loads(e.read().decode())

    spec = ref_session.spec
    out: dict = {"spec": spec}
    code, body = post("/campaigns", spec.to_dict())
    assert code == 201
    cid = body["campaign_id"]
    out["cid"] = cid

    states_seen = []
    deadline = time.monotonic() + 180
    while True:
        code, st = get(f"/campaigns/{cid}")
        assert code == 200
        if not states_seen or states_seen[-1] != st["state"]:
            states_seen.append(st["state"])
        if st["state"] in CampaignState.TERMINAL:
            break
        assert time.monotonic() < deadline, f"stuck in {st['state']}"
        time.sleep(0.05)
    out["final_status"] = st
    out["states_seen"] = states_seen
    out["result"] = svc.result(cid)

    _, out["campaign_list"] = get("/campaigns")
    _, out["telemetry"] = get("/telemetry?n=2")
    _, out["telemetry_all"] = get("/telemetry")
    _, out["health"] = get("/health")
    out["bad_spec"] = post("/campaigns", {"path": "warp"})
    out["bad_telemetry_n"] = get("/telemetry?n=zap")
    out["unknown_get"] = get("/campaigns/c9999-deadbeef")
    out["unknown_cancel"] = post("/campaigns/c9999-deadbeef/cancel")
    out["no_route"] = get("/nope")

    out["drain_resp"] = post("/drain")
    assert svc.drain(timeout=30)
    out["submit_while_draining"] = post("/campaigns", spec.to_dict())
    api.stop()
    with open(jsonl) as f:
        out["jsonl_rows"] = [json.loads(line) for line in f]
    out["pump_counters"] = svc.pump.counters()
    out["state_dir"] = state
    return out


def test_service_zero_churn_bitwise_equals_monolithic(ref_session, api_run):
    assert api_run["final_status"]["state"] == CampaignState.COMPLETED
    assert_history_equal(api_run["result"], ref_session.run())


def test_api_reports_progress_provenance_and_lineage(api_run):
    st = api_run["final_status"]
    spec = api_run["spec"]
    assert api_run["states_seen"][-1] == CampaignState.COMPLETED
    assert set(api_run["states_seen"]) <= {
        CampaignState.QUEUED, CampaignState.RUNNING, CampaignState.COMPLETED,
    }
    assert st["n_segments"] == N_SLOTS // SEG
    assert st["segments_done"] == st["n_segments"]
    assert st["spec_hash"] == spec_hash(spec)
    assert st["run_spec_hash"] == spec_hash(
        as_streaming_spec(spec, max_segment_slots=SEG)
    )
    # checkpoint lineage: one complete checkpoint per segment, keep-3
    assert st["checkpoint_steps"] == [1, 2, 3]
    listed = api_run["campaign_list"]
    assert [c["campaign_id"] for c in listed] == [api_run["cid"]]
    assert listed[0]["spec_hash"] == spec_hash(spec)


def test_api_telemetry_and_health(api_run):
    n_segments = N_SLOTS // SEG
    rows = api_run["telemetry_all"]
    assert [r["seg_idx"] for r in rows] == list(range(n_segments))
    assert [r["seg_idx"] for r in api_run["telemetry"]] == [1, 2]
    for r in rows:
        assert r["campaign_id"] == api_run["cid"]
        assert r["resident_slot_ues"] == SEG * N_UES
        assert 0.0 <= r["ai_share"] <= 1.0
        assert r["throughput_bps"] > 0
        assert r["executed_flops"] > 0
    health = api_run["health"]
    assert health["status"] == "ok"
    assert health["campaign_states"] == {CampaignState.COMPLETED: 1}
    assert health["telemetry"]["samples_published"] == n_segments


def test_api_error_paths(api_run):
    assert api_run["bad_spec"][0] == 400
    assert api_run["bad_telemetry_n"][0] == 400
    assert api_run["unknown_get"][0] == 404
    assert api_run["unknown_cancel"][0] == 404
    assert api_run["no_route"][0] == 404
    assert api_run["drain_resp"][0] == 202
    assert api_run["submit_while_draining"][0] == 503


def test_jsonl_export_is_lossless(api_run):
    """Every published segment sample reached the JSONL sink, in order."""
    rows = api_run["jsonl_rows"]
    assert [r["seg_idx"] for r in rows] == list(range(N_SLOTS // SEG))
    assert api_run["pump_counters"]["dropped"] == 0
    assert api_run["pump_counters"]["export_errors"] == 0
    assert api_run["pump_counters"]["exported"] == len(rows)


_CHURN = ChurnSchedule(
    n_ue_ids=N_UES + 1, segment_slots=SEG,
    initial=tuple(range(N_UES - 1)),
    events=(
        (SEG, N_UES, "attach"),
        (SEG + 1, 0, "detach"),
        (2 * SEG, 0, "attach"),
    ),
)


def test_drain_then_restart_resumes_bitwise(ref_session, tmp_path):
    """Graceful drain at a chosen segment boundary -> interrupted campaign
    -> restarted service resumes it from the checkpoint -> the completed
    history is bitwise-equal to the uninterrupted streaming run."""
    spec = _base_spec(
        modes=_modes_grid(N_SLOTS, N_UES + 1), churn=_CHURN
    )
    ref = ArchesSession(spec, ai_params=ref_session.ai_params).run_streaming()

    state = str(tmp_path / "svc")

    def drain_after_first_segment(service, rec, ev):
        if ev.seg_idx == 0:
            service.request_drain()

    svc = CampaignService(
        state, max_segment_slots=SEG, ai_params=ref_session.ai_params,
        segment_callback=drain_after_first_segment,
    ).start()
    cid = svc.submit(spec)
    # the callback requests the drain from inside segment 0; wait for it
    # so the worker (not this thread) decides where to stop
    deadline = time.monotonic() + 120
    while not svc.draining:
        assert time.monotonic() < deadline, "segment callback never fired"
        time.sleep(0.02)
    assert svc.drain(timeout=120)
    st = svc.status(cid)
    assert st["state"] == CampaignState.INTERRUPTED
    assert st["segments_done"] == 1
    assert st["checkpoint_steps"] == [1]

    svc2 = CampaignService(
        state, max_segment_slots=SEG, ai_params=ref_session.ai_params,
    ).start()
    assert svc2.wait(cid, timeout=120) == CampaignState.COMPLETED
    st2 = svc2.status(cid)
    assert st2["segments_done"] == st2["n_segments"] == N_SLOTS // SEG
    # the lifted run form is the spec itself (it already declared churn)
    assert st2["run_spec_hash"] == st2["spec_hash"] == spec_hash(spec)
    assert_history_equal(svc2.result(cid), ref)
    np.testing.assert_array_equal(svc2.result(cid).attached, ref.attached)
    np.testing.assert_array_equal(svc2.result(cid).bank_slot, ref.bank_slot)
    # the resumed run's telemetry covers only the segments it executed
    assert [s["seg_idx"] for s in svc2.ring.snapshot()] == [1, 2]
    assert svc2.drain(timeout=30)


def test_cancel_running_stops_at_boundary_and_keeps_checkpoint(
    ref_session, tmp_path
):
    spec = _base_spec(seed=7)

    def cancel_after_first_segment(service, rec, ev):
        if ev.seg_idx == 0:
            rec.cancel_event.set()

    svc = CampaignService(
        str(tmp_path / "svc"), max_segment_slots=SEG,
        ai_params=ref_session.ai_params,
        segment_callback=cancel_after_first_segment,
    ).start()
    cid = svc.submit(spec)
    assert svc.wait(cid, timeout=120) == CampaignState.CANCELLED
    st = svc.status(cid)
    assert st["segments_done"] == 1
    assert st["checkpoint_steps"] == [1]  # retained for a later resubmit
    # cancelled campaigns are terminal: a restart does not resurrect them
    svc2 = CampaignService(str(tmp_path / "svc"))
    svc2._recover()
    assert svc2.status(cid)["state"] == CampaignState.CANCELLED
    assert svc2._queue.qsize() == 0
    assert svc.drain(timeout=30)


def test_failed_campaign_reports_error(tmp_path):
    svc = CampaignService(str(tmp_path / "svc")).start()
    cid = svc.submit(_base_spec(scenario="no_such_scenario"))
    assert svc.wait(cid, timeout=60) == CampaignState.FAILED
    assert "no_such_scenario" in svc.status(cid)["error"]
    assert svc.drain(timeout=30)


# -- SIGTERM kill-and-resume through the service process ----------------------


@pytest.mark.slow
def test_sigterm_mid_campaign_then_restart_resumes_bitwise(
    ref_session, tmp_path
):
    """The acceptance criterion end to end: a ``python -m repro.service``
    child is SIGTERM'd while a (long) churn campaign is mid-flight; it
    drains gracefully (exit 0, campaign ``interrupted`` with durable
    checkpoints); a restarted service on the same state dir resumes it to
    a history bitwise-equal to the uninterrupted ``run_streaming()``."""
    n_slots = 60
    spec = _base_spec(
        n_slots=n_slots, modes=_modes_grid(n_slots, N_UES + 1),
        churn=ChurnSchedule(
            n_ue_ids=N_UES + 1, segment_slots=SEG,
            initial=tuple(range(N_UES)),
            events=((5 * SEG, N_UES - 1, "detach"),
                    (10 * SEG, N_UES, "attach")),
        ),
    )
    state = str(tmp_path / "svc")
    env = dict(os.environ)
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    child = subprocess.Popen(
        [sys.executable, "-m", "repro.service", "--state-dir", state,
         "--port", "0", "--max-segment-slots", str(SEG)],
        stdout=subprocess.PIPE, text=True, env=env,
    )
    try:
        hello = json.loads(child.stdout.readline())
        base = hello["url"]

        req = urllib.request.Request(
            base + "/campaigns", data=spec.to_json().encode(),
            headers={"Content-Type": "application/json"}, method="POST",
        )
        with urllib.request.urlopen(req, timeout=10) as r:
            cid = json.loads(r.read().decode())["campaign_id"]

        # poll until the campaign is provably mid-flight (>= 1 segment
        # done, not finished), then deliver the SIGTERM
        deadline = time.monotonic() + 180
        while True:
            with urllib.request.urlopen(
                base + f"/campaigns/{cid}", timeout=10
            ) as r:
                st = json.loads(r.read().decode())
            if 1 <= st["segments_done"] < st["n_segments"]:
                break
            assert st["state"] not in (
                "completed", "failed", "cancelled"
            ), f"campaign reached {st['state']} before the kill"
            assert time.monotonic() < deadline
            time.sleep(0.01)
        child.send_signal(signal.SIGTERM)
        assert child.wait(timeout=120) == 0, "graceful drain must exit 0"
    finally:
        if child.poll() is None:
            child.kill()
            child.wait()

    # the drained state on disk: interrupted, with a durable checkpoint
    with open(os.path.join(state, "campaigns", cid, "status.json")) as f:
        persisted = json.load(f)
    assert persisted["state"] == CampaignState.INTERRUPTED
    assert 1 <= persisted["segments_done"] < persisted["n_segments"]

    # restart on the same state dir: the campaign is recovered, resumed
    # from its latest checkpoint, and completes bitwise-equal to the
    # uninterrupted run (ai_params training is deterministic, so the
    # parent-trained estimator matches the child's)
    svc = CampaignService(
        state, max_segment_slots=SEG, ai_params=ref_session.ai_params,
    ).start()
    assert svc.status(cid)["state"] in (
        CampaignState.QUEUED, CampaignState.RUNNING
    )
    assert svc.wait(cid, timeout=240) == CampaignState.COMPLETED
    ref = ArchesSession(spec, ai_params=ref_session.ai_params).run_streaming()
    assert_history_equal(svc.result(cid), ref)
    np.testing.assert_array_equal(svc.result(cid).attached, ref.attached)
    assert svc.drain(timeout=30)
