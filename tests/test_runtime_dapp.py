"""End-to-end control loop: runtime + E3 + dApp (paper 3.3, 6.1).

Validates the full decision path: pipeline KPMs -> E3 indication -> dApp
policy -> E3 control -> slot-boundary application, plus the fail-safe and
the latency model.
"""

import numpy as np

from repro.core.dapp import ControlLoopLatency, DApp, Decision, connect_dapp
from repro.core.e3 import E3Agent, E3IndicationMessage
from repro.core.runtime import ArchesRuntime


def _threshold_policy(x):
    """mode 0 (AI) when KPM 'q' < 5, else 1 (MMSE)."""
    return 0 if x[0] < 5.0 else 1


def _slot_fn_from_series(series):
    def slot_fn(active_mode, carry, slot_idx):
        q = series[slot_idx]
        return carry, {"q": q}, {"aerial": {"q": q}}

    return slot_fn


def _run(series, *, window=1, ttl=8, fail_at=None, recover_at=None, period=1):
    agent = E3Agent()
    dapp = DApp(_threshold_policy, ["q"], window_slots=window, period_slots=period)
    connect_dapp(agent, dapp)
    runtime = ArchesRuntime(
        _slot_fn_from_series(series), agent, default_mode=1, fail_safe_mode=1,
        ttl_slots=ttl,
    )

    # wrap slot_fn to inject dApp failure at a given slot
    base = runtime.slot_fn

    def wrapped(active_mode, carry, slot_idx):
        if fail_at is not None and slot_idx == fail_at:
            dapp.fail()
        if recover_at is not None and slot_idx == recover_at:
            dapp.recover()
        return base(active_mode, carry, slot_idx)

    runtime.slot_fn = wrapped
    return runtime.run(range(len(series))), dapp


def test_one_slot_decision_delay():
    """Condition flips at slot 5; the mode follows at slot 6 (n -> n+1)."""
    series = [10.0] * 5 + [0.0] * 5
    hist, _ = _run(series)
    modes = hist.modes
    assert modes[0] == 1  # default before first decision
    assert (modes[1:6] == 1).all()
    assert modes[5] == 1  # decision made during slot 5 is NOT active in slot 5
    assert (modes[6:] == 0).all()  # active from slot 6


def test_fail_safe_on_dapp_failure():
    series = [0.0] * 30  # dApp would always say AI
    ttl = 6
    hist, _ = _run(series, ttl=ttl, fail_at=10)
    modes = hist.modes
    assert (modes[2:11] == 0).all()  # AI active while dApp alive
    # after failure at slot 10, no decisions; decay to conventional after ttl
    assert (modes[11 : 10 + ttl] == 0).all()
    assert (modes[10 + ttl + 1 :] == 1).all()


def test_recovery_after_failure():
    series = [0.0] * 40
    hist, _ = _run(series, ttl=4, fail_at=10, recover_at=25)
    modes = hist.modes
    assert (modes[16:26] == 1).all()  # failed -> fail-safe
    assert (modes[27:] == 0).all()  # recovered -> AI again


def test_decision_period():
    """period_slots=4: decisions only on slots divisible by 4."""
    series = [10.0] * 8 + [0.0] * 8
    hist, dapp = _run(series, period=4)
    slots = [d.slot for d in dapp.decisions]
    assert all(s % 4 == 0 for s in slots)
    # flip at slot 8 (divisible) -> active at 9
    assert hist.modes[9] == 0


def test_window_smoothing():
    """A 1-slot KPM glitch must not flip an 8-slot-window dApp."""
    series = [10.0] * 10 + [0.0] + [10.0] * 10
    hist, _ = _run(series, window=8)
    assert (hist.modes == 1).all()


def test_control_loop_latency_model():
    """Paper 6.1: ~135 us framework + 0.41 us tree + 3.36/4.89 us switch."""
    lat = ControlLoopLatency()
    e2e_ai = lat.end_to_end_us(0)
    e2e_mmse = lat.end_to_end_us(1)
    assert abs(e2e_ai - (135.0 + 0.41 + 3.36)) < 1e-6
    assert abs(e2e_mmse - (135.0 + 0.41 + 4.89)) < 1e-6
    assert 130.0 < e2e_ai < 150.0  # the paper's "~140 us"


def test_decisions_carry_latency():
    series = [0.0] * 4
    _, dapp = _run(series)
    assert len(dapp.decisions) > 0
    for d in dapp.decisions:
        assert isinstance(d, Decision)
        assert d.end_to_end_us > 135.0
        assert d.policy_us >= 0.0


def test_e3_subscription_filtering():
    agent = E3Agent()
    seen = []
    from repro.core.e3 import E3Subscription

    agent.subscribe(
        E3Subscription(callback=seen.append, period_slots=2, sources=("aerial",))
    )
    for slot in range(4):
        agent.indicate(E3IndicationMessage(slot=slot, source="aerial", kpms={}))
        agent.indicate(E3IndicationMessage(slot=slot, source="oai", kpms={}))
    assert [m.slot for m in seen] == [0, 2]  # period + source filtering


def test_multi_source_kpm_join():
    """dApp waits for both layers' indications before deciding (cross-layer)."""
    agent = E3Agent()
    dapp = DApp(lambda x: int(x[0] + x[1] > 1), ["a", "b"], window_slots=1)
    connect_dapp(agent, dapp)
    agent.indicate(E3IndicationMessage(slot=0, source="aerial", kpms={"a": 1.0}))
    assert len(dapp.decisions) == 0  # still waiting for 'b'
    agent.indicate(E3IndicationMessage(slot=0, source="oai", kpms={"b": 1.0}))
    assert len(dapp.decisions) == 1
    assert dapp.decisions[0].mode == 1
