"""Per-kernel allclose: MXU decision-tree inference vs literal tree walk."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import fit_decision_tree
from repro.kernels.tree_infer import pack_tree, tree_infer, tree_infer_ref


def _fit_random_tree(rng, n, f, depth):
    x = rng.normal(size=(n, f)).astype(np.float32)
    w = rng.normal(size=f)
    y = (x @ w + 0.3 * rng.normal(size=n) > 0).astype(np.int32)
    return fit_decision_tree(x, y, depth=depth), x


@pytest.mark.parametrize("depth", [1, 2, 3, 4])
@pytest.mark.parametrize("f", [1, 3, 10, 17])
def test_tree_infer_vs_ref(depth, f, rng):
    tree, x = _fit_random_tree(rng, 300, f, depth)
    packed = pack_tree(tree.feature, tree.threshold, tree.leaf_values, f, depth)
    got = tree_infer(jnp.asarray(x), packed)
    want = tree_infer_ref(
        jnp.asarray(x),
        jnp.asarray(tree.feature),
        jnp.asarray(tree.threshold),
        jnp.asarray(tree.leaf_values),
        depth,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("batch", [1, 2, 31, 256, 300, 513])
def test_tree_infer_batch_sizes(batch, rng):
    tree, _ = _fit_random_tree(rng, 200, 5, 2)
    packed = pack_tree(tree.feature, tree.threshold, tree.leaf_values, 5, 2)
    x = rng.normal(size=(batch, 5)).astype(np.float32)
    got = tree_infer(jnp.asarray(x), packed)
    want = tree_infer_ref(
        jnp.asarray(x),
        jnp.asarray(tree.feature),
        jnp.asarray(tree.threshold),
        jnp.asarray(tree.leaf_values),
        2,
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tree_infer_property_random_trees(rng):
    """Random complete trees (not fitted) — kernel must match the walk."""
    for trial in range(15):
        depth = int(rng.integers(1, 5))
        f = int(rng.integers(1, 12))
        n_nodes, n_leaves = 2**depth - 1, 2**depth
        feature = rng.integers(0, f, size=n_nodes).astype(np.int32)
        threshold = rng.normal(size=n_nodes).astype(np.float32)
        # some pass-through nodes (inf threshold), as the trainer emits
        mask = rng.random(n_nodes) < 0.3
        threshold[mask] = np.inf
        leaf_values = rng.integers(0, 2, size=n_leaves).astype(np.float32)
        packed = pack_tree(feature, threshold, leaf_values, f, depth)
        x = rng.normal(size=(64, f)).astype(np.float32)
        got = tree_infer(jnp.asarray(x), packed)
        want = tree_infer_ref(
            jnp.asarray(x),
            jnp.asarray(feature),
            jnp.asarray(threshold),
            jnp.asarray(leaf_values),
            depth,
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
