"""PUSCH pipeline + ARCHES integration (paper Fig. 2, 6.2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.expert_bank import ExecutionMode
from repro.core.telemetry import SELECTED_KPMS
from repro.phy.ai_estimator import AiEstimatorConfig, init_params
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import LinkState, PuschPipeline
from repro.phy.scenario import GOOD, POOR

CFG = SlotConfig(n_prb=24)
NET = AiEstimatorConfig(channels=8, n_res_blocks=1)


@pytest.fixture(scope="module")
def pipe():
    params = init_params(jax.random.PRNGKey(0), CFG, NET)
    return PuschPipeline(CFG, params, net=NET)


def _avg_tput(pipe, ch, mode, n=6, seed=0, warmup=0):
    """Mean per-slot PHY rate after ``warmup`` slots (OLLA settling)."""
    link = LinkState()
    rates = []
    for i in range(n):
        link, out, kpms = pipe.run_slot(
            jax.random.PRNGKey(seed * 1000 + i), mode, link, ch
        )
        if i >= warmup:
            rates.append(out["phy_bits_per_s"])
    return float(np.mean(rates)), kpms


def test_slot_produces_selected_kpms(pipe):
    _, kpms = _avg_tput(pipe, GOOD, 1, n=2)
    flat = {**kpms["aerial"], **kpms["oai"]}
    for name in SELECTED_KPMS:
        assert name in flat, f"missing KPM {name}"
        assert np.isfinite(flat[name])


def test_good_beats_poor_throughput(pipe):
    t_good, _ = _avg_tput(pipe, GOOD, 1, n=20, warmup=8)
    t_poor, _ = _avg_tput(pipe, POOR, 1, n=20, warmup=8)
    assert t_good > t_poor


def test_mode_changes_selected_estimate(pipe):
    """Switch kernel routes different expert outputs downstream."""
    link = LinkState()
    key = jax.random.PRNGKey(3)
    _, out0, _ = pipe.run_slot(key, 0, link, GOOD)
    _, out1, _ = pipe.run_slot(key, 1, link, GOOD)
    h0 = np.asarray(out0["rx"]["h_selected"])
    h1 = np.asarray(out1["rx"]["h_selected"])
    assert h0.shape == h1.shape
    assert not np.allclose(h0, h1)  # different experts


def test_concurrent_exposes_both_experts(pipe):
    link = LinkState()
    _, out, _ = pipe.run_slot(jax.random.PRNGKey(4), 1, link, GOOD)
    alls = out["rx"]["all_outputs"]
    assert alls is not None and len(alls) == 2
    # selected buffer holds the MMSE output (mode=1)
    np.testing.assert_allclose(
        np.asarray(out["rx"]["h_selected"]), np.asarray(alls[1]), rtol=1e-5, atol=1e-6
    )


def test_selected_only_mode_runs():
    params = init_params(jax.random.PRNGKey(0), CFG, NET)
    pipe_sel = PuschPipeline(
        CFG, params, net=NET, execution_mode=ExecutionMode.SELECTED_ONLY
    )
    link = LinkState()
    _, out, kpms = pipe_sel.run_slot(jax.random.PRNGKey(5), 1, link, GOOD)
    assert out["rx"]["all_outputs"] is None
    assert np.isfinite(kpms["aerial"]["sinr"])


def test_perturbation_degrades_kpms(pipe):
    """Stage-1 property (paper Fig. 4): rho=2 must degrade vs rho=0."""

    def run(rho, seed):
        link = LinkState()
        vals = []
        for i in range(8):
            link, out, kpms = pipe.run_slot(
                jax.random.PRNGKey(seed + i), 1, link, GOOD, perturb_rho=rho
            )
            if i >= 2:  # skip OLLA cold start
                vals.append((kpms["aerial"]["tb_size"], kpms["oai"]["snr"]))
        return np.mean([v[0] for v in vals]), np.mean([v[1] for v in vals])

    tb0, snr0 = run(0.0, 100)
    tb2, snr2 = run(2.0, 100)
    assert snr2 < snr0 - 3.0  # SNR collapses with rho (Fig. 4b)
    assert tb2 <= tb0  # TB size shrinks or vanishes (Fig. 4a)


def test_link_adaptation_reacts():
    """Reported SNR drives MCS over slots (link adaptation loop closes)."""
    params = init_params(jax.random.PRNGKey(0), CFG, NET)
    pipe = PuschPipeline(CFG, params, net=NET)
    link = LinkState()
    mcs_good = []
    for i in range(5):
        link, out, _ = pipe.run_slot(jax.random.PRNGKey(i), 1, link, GOOD)
        mcs_good.append(out["mcs"])
    link = LinkState()
    mcs_poor = []
    for i in range(5):
        link, out, _ = pipe.run_slot(jax.random.PRNGKey(i), 1, link, POOR)
        mcs_poor.append(out["mcs"])
    assert np.mean(mcs_poor[2:]) < np.mean(mcs_good[2:])
