"""Checkpoint store: atomic save/restore, keep-k retention, latest-step."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (
    CheckpointManager,
    latest_step,
    restore_pytree,
    save_pytree,
)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 4), jnp.float32),
        "b": jnp.arange(3, dtype=jnp.bfloat16),
        "nested": {"step": jnp.int32(17)},
    }


def test_roundtrip_exact(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    r = restore_pytree(jax.tree.map(jnp.zeros_like, t), str(tmp_path / "ck"))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), t, r
    )
    assert r["b"].dtype == jnp.bfloat16


def test_latest_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=10)
    for s in (3, 9, 12):
        mgr.maybe_save(s, _tree(s))
    assert latest_step(str(tmp_path)) == 12


def test_keep_k_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=2)
    for s in range(1, 6):
        mgr.maybe_save(s, _tree(s))
    kept = sorted(
        int(d.split("_")[-1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert kept == [4, 5]


def test_save_every_respected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=4, keep=10)
    for s in range(1, 10):
        assert mgr.maybe_save(s, _tree()) == (s % 4 == 0)
    kept = sorted(
        int(d.split("_")[-1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert kept == [4, 8]


def test_force_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=100, keep=5)
    assert not mgr.maybe_save(3, _tree())
    assert mgr.maybe_save(3, _tree(), force=True)


def test_restore_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=3)
    trees = {s: _tree(s) for s in (1, 2, 3)}
    for s, t in trees.items():
        mgr.maybe_save(s, t)
    step, restored = mgr.restore_latest(jax.tree.map(jnp.zeros_like, _tree()))
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(trees[3]["w"]))


def test_restore_latest_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1)
    assert mgr.restore_latest(_tree()) is None


def test_no_partial_checkpoints_on_disk(tmp_path):
    """Atomicity: only complete step_* dirs are visible (no tmp residue)."""
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=5)
    mgr.maybe_save(1, _tree())
    entries = os.listdir(tmp_path)
    assert all(e.startswith("step_") and ".tmp-" not in e for e in entries), entries


# -- restore validation (CheckpointMismatchError) ------------------------------


import pytest

from repro.checkpoint.store import CheckpointMismatchError, load_pytree


def test_restore_rejects_treedef_mismatch(tmp_path):
    save_pytree(_tree(), str(tmp_path / "ck"))
    other = {"w": jnp.zeros((8, 4)), "extra": jnp.zeros(())}
    with pytest.raises(CheckpointMismatchError, match="treedef"):
        restore_pytree(other, str(tmp_path / "ck"))


def test_restore_rejects_shape_mismatch(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    bad = dict(t, w=jnp.zeros((4, 8), jnp.float32))
    with pytest.raises(CheckpointMismatchError, match="shape"):
        restore_pytree(bad, str(tmp_path / "ck"))


def test_restore_rejects_dtype_mismatch(tmp_path):
    """The old behaviour silently cast the stored leaf into the template
    dtype; a float32 checkpoint restored into a bf16 program (or vice
    versa) must refuse instead."""
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    bad = dict(t, w=jnp.zeros((8, 4), jnp.bfloat16))
    with pytest.raises(CheckpointMismatchError, match="dtype"):
        restore_pytree(bad, str(tmp_path / "ck"))


def test_restore_bf16_shape_still_validated(tmp_path):
    """bf16 leaves are stored as same-shape uint16 payloads: the manifest
    shape must stay comparable (a wrong-shape bf16 template is refused,
    a right-shape one restores)."""
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    bad = dict(t, b=jnp.zeros((7,), jnp.bfloat16))
    with pytest.raises(CheckpointMismatchError, match="shape"):
        restore_pytree(bad, str(tmp_path / "ck"))


def test_load_pytree_templateless(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    r = load_pytree(str(tmp_path / "ck"))
    assert set(r) == {"w", "b", "nested"}
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
    assert r["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(r["b"], np.float32), np.asarray(t["b"], np.float32)
    )
    np.testing.assert_array_equal(
        np.asarray(r["nested"]["step"]), np.asarray(t["nested"]["step"])
    )


# -- crash atomicity -----------------------------------------------------------


def test_crash_mid_write_never_corrupts(tmp_path, monkeypatch):
    """A crash at the final rename (the last possible moment) leaves no
    visible checkpoint and no tmp residue; an earlier good checkpoint
    stays restorable."""
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=5)
    good = _tree(1)
    mgr.maybe_save(1, good)

    real_rename = os.rename

    def exploding_rename(src, dst):
        raise OSError("simulated crash at publish time")

    monkeypatch.setattr(os, "rename", exploding_rename)
    with pytest.raises(OSError, match="simulated crash"):
        mgr.maybe_save(2, _tree(2))
    monkeypatch.setattr(os, "rename", real_rename)

    assert latest_step(str(tmp_path)) == 1
    step, restored = mgr.restore_latest(jax.tree.map(jnp.zeros_like, good))
    assert step == 1
    np.testing.assert_array_equal(
        np.asarray(restored["w"]), np.asarray(good["w"])
    )
    # no tmp residue survived the failed attempt
    assert all(".tmp-" not in e for e in os.listdir(tmp_path))


def test_latest_step_ignores_tmp_and_incomplete(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1)
    mgr.maybe_save(4, _tree())
    # a stale tmp dir from a killed process, and a manifest-less step dir
    os.makedirs(tmp_path / "step_00000009.tmp-zz")
    os.makedirs(tmp_path / "step_00000007")
    assert latest_step(str(tmp_path)) == 4


# -- delta chains (manifest_extra + resume_chain) ------------------------------


from repro.checkpoint.store import (  # noqa: E402
    STREAMING_DELTA_KIND,
    checkpoint_kind,
    read_manifest_extra,
    resume_chain,
)


def _delta_extra(step):
    return {"kind": STREAMING_DELTA_KIND, "prev_step": step - 1}


def test_manifest_extra_roundtrip_and_kind(tmp_path):
    d = str(tmp_path / "ck")
    save_pytree(_tree(), d, manifest_extra=_delta_extra(5))
    assert checkpoint_kind(d) == STREAMING_DELTA_KIND
    assert read_manifest_extra(d) == {
        "kind": STREAMING_DELTA_KIND, "prev_step": 4
    }
    # untagged checkpoints read back as monolithic (kind None) — the
    # legacy format needs no migration
    d2 = str(tmp_path / "legacy")
    save_pytree(_tree(), d2)
    assert checkpoint_kind(d2) is None
    assert read_manifest_extra(d2) == {}
    # the payload is untouched by the extra fields
    r = restore_pytree(jax.tree.map(jnp.zeros_like, _tree()), d)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(_tree()["w"]))


def test_manifest_extra_reserved_keys_rejected(tmp_path):
    with pytest.raises(ValueError, match="leaves/treedef"):
        save_pytree(_tree(), str(tmp_path / "ck"),
                    manifest_extra={"leaves": 1})


def test_resume_chain_empty_and_monolithic(tmp_path):
    assert resume_chain(str(tmp_path)) == (None, [])
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=None)
    mgr.maybe_save(3, _tree())
    # latest step monolithic: the legacy restore path, no deltas
    assert resume_chain(str(tmp_path)) == (3, [])


def test_resume_chain_full_delta_to_step_one(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=None)
    for s in (1, 2, 3):
        mgr.maybe_save(s, _tree(s), manifest_extra=_delta_extra(s))
    # chain reaches step 1: replay from initial state, no anchor
    assert resume_chain(str(tmp_path)) == (None, [1, 2, 3])


def test_resume_chain_anchored_on_monolithic(tmp_path):
    """A legacy (monolithic) directory continued in delta format resumes
    through the mixed chain: monolithic anchor + delta suffix."""
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=None)
    mgr.maybe_save(1, _tree(1))
    mgr.maybe_save(2, _tree(2))
    for s in (3, 4):
        mgr.maybe_save(s, _tree(s), manifest_extra=_delta_extra(s))
    assert resume_chain(str(tmp_path)) == (2, [3, 4])


def test_resume_chain_broken_predecessor_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=None)
    for s in (2, 3):  # step 1 never written: 2's predecessor is missing
        mgr.maybe_save(s, _tree(s), manifest_extra=_delta_extra(s))
    with pytest.raises(CheckpointMismatchError, match="broken"):
        resume_chain(str(tmp_path))


def test_keep_none_disables_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=None)
    for s in range(1, 8):
        mgr.maybe_save(s, _tree(s), manifest_extra=_delta_extra(s))
    assert mgr.steps() == list(range(1, 8))


def test_crash_mid_write_resumes_from_last_complete_delta(tmp_path,
                                                          monkeypatch):
    """The crash-mid-rename property extended to the delta chain: a crash
    publishing delta k leaves the chain ending at k-1, complete and
    restorable; retrying k afterwards heals the chain."""
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=None)
    trees = {s: _tree(s) for s in (1, 2, 3)}
    mgr.maybe_save(1, trees[1], manifest_extra=_delta_extra(1))
    mgr.maybe_save(2, trees[2], manifest_extra=_delta_extra(2))

    real_rename = os.rename

    def exploding_rename(src, dst):
        raise OSError("simulated crash at publish time")

    monkeypatch.setattr(os, "rename", exploding_rename)
    with pytest.raises(OSError, match="simulated crash"):
        mgr.maybe_save(3, trees[3], manifest_extra=_delta_extra(3))
    monkeypatch.setattr(os, "rename", real_rename)

    # chain ends at the last complete manifest entry, fully restorable
    assert resume_chain(str(tmp_path)) == (None, [1, 2])
    for s in (1, 2):
        r = load_pytree(mgr.dir_for(s))
        np.testing.assert_array_equal(
            np.asarray(r["w"]), np.asarray(trees[s]["w"])
        )
    assert all(".tmp-" not in e for e in os.listdir(tmp_path))

    # the retried write heals the chain
    mgr.maybe_save(3, trees[3], manifest_extra=_delta_extra(3))
    assert resume_chain(str(tmp_path)) == (None, [1, 2, 3])
