"""Checkpoint store: atomic save/restore, keep-k retention, latest-step."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (
    CheckpointManager,
    latest_step,
    restore_pytree,
    save_pytree,
)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 4), jnp.float32),
        "b": jnp.arange(3, dtype=jnp.bfloat16),
        "nested": {"step": jnp.int32(17)},
    }


def test_roundtrip_exact(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    r = restore_pytree(jax.tree.map(jnp.zeros_like, t), str(tmp_path / "ck"))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), t, r
    )
    assert r["b"].dtype == jnp.bfloat16


def test_latest_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=10)
    for s in (3, 9, 12):
        mgr.maybe_save(s, _tree(s))
    assert latest_step(str(tmp_path)) == 12


def test_keep_k_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=2)
    for s in range(1, 6):
        mgr.maybe_save(s, _tree(s))
    kept = sorted(
        int(d.split("_")[-1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert kept == [4, 5]


def test_save_every_respected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=4, keep=10)
    for s in range(1, 10):
        assert mgr.maybe_save(s, _tree()) == (s % 4 == 0)
    kept = sorted(
        int(d.split("_")[-1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert kept == [4, 8]


def test_force_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=100, keep=5)
    assert not mgr.maybe_save(3, _tree())
    assert mgr.maybe_save(3, _tree(), force=True)


def test_restore_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=3)
    trees = {s: _tree(s) for s in (1, 2, 3)}
    for s, t in trees.items():
        mgr.maybe_save(s, t)
    step, restored = mgr.restore_latest(jax.tree.map(jnp.zeros_like, _tree()))
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(trees[3]["w"]))


def test_restore_latest_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1)
    assert mgr.restore_latest(_tree()) is None


def test_no_partial_checkpoints_on_disk(tmp_path):
    """Atomicity: only complete step_* dirs are visible (no tmp residue)."""
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=5)
    mgr.maybe_save(1, _tree())
    entries = os.listdir(tmp_path)
    assert all(e.startswith("step_") and ".tmp-" not in e for e in entries), entries


# -- restore validation (CheckpointMismatchError) ------------------------------


import pytest

from repro.checkpoint.store import CheckpointMismatchError, load_pytree


def test_restore_rejects_treedef_mismatch(tmp_path):
    save_pytree(_tree(), str(tmp_path / "ck"))
    other = {"w": jnp.zeros((8, 4)), "extra": jnp.zeros(())}
    with pytest.raises(CheckpointMismatchError, match="treedef"):
        restore_pytree(other, str(tmp_path / "ck"))


def test_restore_rejects_shape_mismatch(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    bad = dict(t, w=jnp.zeros((4, 8), jnp.float32))
    with pytest.raises(CheckpointMismatchError, match="shape"):
        restore_pytree(bad, str(tmp_path / "ck"))


def test_restore_rejects_dtype_mismatch(tmp_path):
    """The old behaviour silently cast the stored leaf into the template
    dtype; a float32 checkpoint restored into a bf16 program (or vice
    versa) must refuse instead."""
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    bad = dict(t, w=jnp.zeros((8, 4), jnp.bfloat16))
    with pytest.raises(CheckpointMismatchError, match="dtype"):
        restore_pytree(bad, str(tmp_path / "ck"))


def test_restore_bf16_shape_still_validated(tmp_path):
    """bf16 leaves are stored as same-shape uint16 payloads: the manifest
    shape must stay comparable (a wrong-shape bf16 template is refused,
    a right-shape one restores)."""
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    bad = dict(t, b=jnp.zeros((7,), jnp.bfloat16))
    with pytest.raises(CheckpointMismatchError, match="shape"):
        restore_pytree(bad, str(tmp_path / "ck"))


def test_load_pytree_templateless(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    r = load_pytree(str(tmp_path / "ck"))
    assert set(r) == {"w", "b", "nested"}
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
    assert r["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(r["b"], np.float32), np.asarray(t["b"], np.float32)
    )
    np.testing.assert_array_equal(
        np.asarray(r["nested"]["step"]), np.asarray(t["nested"]["step"])
    )


# -- crash atomicity -----------------------------------------------------------


def test_crash_mid_write_never_corrupts(tmp_path, monkeypatch):
    """A crash at the final rename (the last possible moment) leaves no
    visible checkpoint and no tmp residue; an earlier good checkpoint
    stays restorable."""
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=5)
    good = _tree(1)
    mgr.maybe_save(1, good)

    real_rename = os.rename

    def exploding_rename(src, dst):
        raise OSError("simulated crash at publish time")

    monkeypatch.setattr(os, "rename", exploding_rename)
    with pytest.raises(OSError, match="simulated crash"):
        mgr.maybe_save(2, _tree(2))
    monkeypatch.setattr(os, "rename", real_rename)

    assert latest_step(str(tmp_path)) == 1
    step, restored = mgr.restore_latest(jax.tree.map(jnp.zeros_like, good))
    assert step == 1
    np.testing.assert_array_equal(
        np.asarray(restored["w"]), np.asarray(good["w"])
    )
    # no tmp residue survived the failed attempt
    assert all(".tmp-" not in e for e in os.listdir(tmp_path))


def test_latest_step_ignores_tmp_and_incomplete(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1)
    mgr.maybe_save(4, _tree())
    # a stale tmp dir from a killed process, and a manifest-less step dir
    os.makedirs(tmp_path / "step_00000009.tmp-zz")
    os.makedirs(tmp_path / "step_00000007")
    assert latest_step(str(tmp_path)) == 4
