"""Checkpoint store: atomic save/restore, keep-k retention, latest-step."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (
    CheckpointManager,
    latest_step,
    restore_pytree,
    save_pytree,
)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 4), jnp.float32),
        "b": jnp.arange(3, dtype=jnp.bfloat16),
        "nested": {"step": jnp.int32(17)},
    }


def test_roundtrip_exact(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    r = restore_pytree(jax.tree.map(jnp.zeros_like, t), str(tmp_path / "ck"))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), t, r
    )
    assert r["b"].dtype == jnp.bfloat16


def test_latest_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=10)
    for s in (3, 9, 12):
        mgr.maybe_save(s, _tree(s))
    assert latest_step(str(tmp_path)) == 12


def test_keep_k_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=2)
    for s in range(1, 6):
        mgr.maybe_save(s, _tree(s))
    kept = sorted(
        int(d.split("_")[-1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert kept == [4, 5]


def test_save_every_respected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=4, keep=10)
    for s in range(1, 10):
        assert mgr.maybe_save(s, _tree()) == (s % 4 == 0)
    kept = sorted(
        int(d.split("_")[-1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert kept == [4, 8]


def test_force_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=100, keep=5)
    assert not mgr.maybe_save(3, _tree())
    assert mgr.maybe_save(3, _tree(), force=True)


def test_restore_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=3)
    trees = {s: _tree(s) for s in (1, 2, 3)}
    for s, t in trees.items():
        mgr.maybe_save(s, t)
    step, restored = mgr.restore_latest(jax.tree.map(jnp.zeros_like, _tree()))
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(trees[3]["w"]))


def test_restore_latest_empty(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1)
    assert mgr.restore_latest(_tree()) is None


def test_no_partial_checkpoints_on_disk(tmp_path):
    """Atomicity: only complete step_* dirs are visible (no tmp residue)."""
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep=5)
    mgr.maybe_save(1, _tree())
    entries = os.listdir(tmp_path)
    assert all(e.startswith("step_") and ".tmp-" not in e for e in entries), entries
