"""The 3-stage policy-design methodology (paper 4, Figs. 3-5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.methodology import (
    DEFAULT_RHOS,
    SweepResult,
    design_policy_inputs,
    monotonicity_filter,
    perturb_estimate,
    redundancy_reduction,
    sensitivity_sweep,
    sensitivity_sweep_batched,
)

# -- Stage 1: Eq. (3) statistics -------------------------------------------------


def test_perturb_zero_rho_is_identity():
    h = jax.random.normal(jax.random.PRNGKey(0), (4, 3, 64), jnp.complex64)
    out = perturb_estimate(h, 0.0, jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(h), atol=1e-7)


@pytest.mark.parametrize("rho", [0.3, 1.0, 2.0])
def test_perturb_noise_scale_matches_eq3(rho):
    """Injected noise std must be rho * E[|H|] (unit-variance CN scaling)."""
    key = jax.random.PRNGKey(42)
    h = (
        jax.random.normal(key, (64, 64)) + 1j * jax.random.normal(key, (64, 64))
    ).astype(jnp.complex64) * 3.0
    out = perturb_estimate(h, rho, jax.random.PRNGKey(7))
    noise = np.asarray(out - h)
    target = rho * float(jnp.mean(jnp.abs(h)))
    measured = np.sqrt(np.mean(np.abs(noise) ** 2))
    assert abs(measured - target) / target < 0.08


def test_perturb_preserves_dtype_shape():
    h = jnp.ones((2, 5), jnp.complex64)
    out = perturb_estimate(h, 1.0, jax.random.PRNGKey(0))
    assert out.shape == h.shape and out.dtype == h.dtype


# -- Stage 1 driver ---------------------------------------------------------------


def test_sensitivity_sweep_grid():
    calls = []

    def eval_fn(rho, key):
        calls.append(rho)
        return {"a": 10.0 - rho, "b": 1.0}

    sweep = sensitivity_sweep(eval_fn, rhos=(0.0, 0.5, 1.0), n_trials=3)
    assert sweep.means.shape == (3, 2)
    assert sweep.samples.shape == (3, 3, 2)
    assert len(calls) == 9
    np.testing.assert_allclose(sweep.means[:, 0], [10.0, 9.5, 9.0])
    assert (sweep.ci95 >= 0).all()


def test_default_rho_grid_matches_paper():
    """rho in [0, 2], steps of 0.1 (paper 4.1)."""
    assert DEFAULT_RHOS[0] == 0.0 and DEFAULT_RHOS[-1] == 2.0
    assert len(DEFAULT_RHOS) == 21
    np.testing.assert_allclose(np.diff(DEFAULT_RHOS), 0.1)


def test_sensitivity_sweep_batched_shapes_and_trends():
    """Stage 1 on the scan engine: the rho grid rides the UE axis.

    The batched sweep must return a host-shaped ``SweepResult`` whose KPM
    degradation is monotone in rho (the property stage 2 filters on).
    """
    from repro.phy.ai_estimator import AiEstimatorConfig, init_params
    from repro.phy.nr import SlotConfig
    from repro.phy.pipeline import BatchedPuschPipeline
    from repro.phy.scenario import GOOD, constant_schedule

    cfg = SlotConfig(n_prb=24)
    net = AiEstimatorConfig(channels=8, n_res_blocks=1)
    engine = BatchedPuschPipeline(
        cfg, init_params(jax.random.PRNGKey(0), cfg, net), net=net
    )
    rhos = (0.0, 1.0, 2.0)
    n_trials = 3
    sweep = sensitivity_sweep_batched(
        engine, constant_schedule(GOOD), rhos=rhos, n_trials=n_trials,
        slots_per_trial=5,
    )
    assert isinstance(sweep, SweepResult)
    k = len(sweep.kpm_names)
    assert sweep.samples.shape == (len(rhos), n_trials, k)
    assert sweep.means.shape == (len(rhos), k)
    # SINR must degrade monotonically across the grid (paper Fig. 4)
    sinr = sweep.means[:, sweep.kpm_names.index("sinr")]
    assert sinr[0] > sinr[1] > sinr[2]
    # deterministic in the key
    again = sensitivity_sweep_batched(
        engine, constant_schedule(GOOD), rhos=rhos, n_trials=n_trials,
        slots_per_trial=5,
    )
    np.testing.assert_array_equal(sweep.samples, again.samples)


# -- Stage 2 -----------------------------------------------------------------------


def test_monotonicity_filter():
    rhos = np.asarray(DEFAULT_RHOS)
    rng = np.random.default_rng(3)
    means = np.stack(
        [
            -rhos + 0.01 * rng.normal(size=21),  # monotone down -> keep
            0.05 * rng.normal(size=21),  # flat noise -> drop
            rhos**2,  # monotone up -> keep (RSRP-like)
            np.sin(rhos * 3),  # oscillating -> drop
        ],
        axis=1,
    )
    sweep = SweepResult(
        rhos=rhos,
        kpm_names=("tb_size", "flat", "rsrp", "osc"),
        means=means,
        ci95=np.zeros_like(means),
        samples=means[:, None, :],
    )
    kept = monotonicity_filter(sweep, min_abs_spearman=0.8)
    assert set(kept) == {"tb_size", "rsrp"}
    assert kept["tb_size"] < 0  # degrades with rho
    assert kept["rsrp"] > 0  # RSRP inflates with noise (paper 4.3)


# -- Stage 3 -----------------------------------------------------------------------


def _link_adaptation_samples(rng, n=400):
    """Synthetic link-adaptation cluster: mcs/tb/qam move in lockstep."""
    q = rng.normal(size=n)  # latent channel quality
    return {
        "mcs_index": q + 0.05 * rng.normal(size=n),
        "tb_size": 2 * q + 0.05 * rng.normal(size=n),
        "qam_order": 1.5 * q + 0.1 * rng.normal(size=n),
        "rsrp": -0.3 * q + rng.normal(size=n),  # weakly anti-correlated
        "ndi": rng.normal(size=n),  # independent
    }


def test_redundancy_reduction_clusters_link_adaptation(rng):
    res = redundancy_reduction(_link_adaptation_samples(rng), threshold=0.8)
    lbl = dict(zip(res.names, res.labels))
    assert lbl["mcs_index"] == lbl["tb_size"] == lbl["qam_order"]
    assert lbl["ndi"] != lbl["mcs_index"]
    assert lbl["rsrp"] != lbl["mcs_index"]
    # the paper keeps MCS index as the cluster representative
    assert "mcs_index" in res.representatives
    assert "tb_size" not in res.representatives
    # independents survive as their own representatives
    assert "ndi" in res.representatives and "rsrp" in res.representatives


def test_redundancy_threshold_extremes(rng):
    samples = _link_adaptation_samples(rng)
    none_merged = redundancy_reduction(samples, threshold=0.999999)
    assert len(set(none_merged.labels)) == len(samples)
    all_merged = redundancy_reduction(samples, threshold=-1.0)
    assert len(set(all_merged.labels)) == 1


def test_redundancy_zero_variance_guard(rng):
    samples = {"const": np.ones(100), "x": rng.normal(size=100)}
    res = redundancy_reduction(samples, threshold=0.8)
    assert np.isfinite(res.corr).all()


def test_design_policy_inputs_end_to_end(rng):
    aerial = _link_adaptation_samples(rng)
    q2 = rng.normal(size=400)
    oai = {
        "snr": q2,
        "mac_throughput": 0.77 * q2 + 0.65 * rng.normal(size=400),  # r ~ .77 < .8
        "lcid4_rx_bytes": rng.normal(size=400),
    }
    selected, a_res, o_res = design_policy_inputs(aerial, oai)
    assert selected[0] == "phy_throughput"  # always re-added (paper 4.3)
    assert "mcs_index" in selected
    assert "tb_size" not in selected  # absorbed by the mcs cluster
    # OAI metrics all below 0.8 pairwise -> all retained (paper Fig. 5b)
    for n in oai:
        assert n in selected
    assert len(selected) == len(set(selected))  # de-duplicated
