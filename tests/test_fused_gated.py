"""Fused gated hot path: one kernel == the unfused triple, bitwise.

Three layers of the tentpole contract:

* **kernel** — ``gated_expert_apply`` (Pallas interpret mode and the jnp
  reference backend) matches the unfused gather -> folded-GEMM -> scatter
  composition bitwise across the gating edge cases: all-AI, all-MMSE,
  U == 1, odd U, capacity 1, exact-capacity boundary, padding rows.
* **bank** — the ``gated_fused_apply`` hook slots into ``ExpertBank`` (3+
  expert banks included) without changing any output or accounting leaf;
  the in-scan NMSE audit trips on divergent outputs (adversarial inputs,
  NaN/inf) and reverts tripped UEs to the fail-safe baseline while still
  charging the executed FLOPs.
* **engine** — ``BatchedPuschPipeline(fused_gated=True)`` campaigns are
  bitwise-equal to unfused gated campaigns on *every* trajectory leaf
  (cost accounting included), open- and closed-loop, and on a forced
  8-shard mesh (subprocess) with the no-collective HLO audit.  The bf16
  expert variant (``expert_dtype="bfloat16"``) is NOT bitwise — its
  audit + fail-safe behaviour is asserted instead.

Exact-capacity boundary coverage (the overflow-audit satellite): when the
number of selected UEs equals the capacity, no UE may be flagged as
overflow and the K'th selected UE must be served by the AI expert — at the
bank, the executed-cost accounting, and the ``BatchedRunHistory`` layers.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.expert_bank import ExecutionMode, Expert, ExpertBank
from repro.core.runtime import BatchedRunHistory
from repro.core.telemetry import physical_trajectory
from repro.kernels.gated_expert import gated_expert_apply, gated_expert_apply_ref
from repro.kernels.switch_select.ref import switch_gather_batched_tree_ref
from repro.phy.ai_estimator import (
    AiEstimatorConfig,
    ai_estimate_folded,
    fold_ai_params,
    init_params,
)
from repro.phy.estimators import estimator_flops
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import BatchedPuschPipeline
from repro.phy.scenario import GOOD, constant_schedule, good_poor_good_schedule

CFG = SlotConfig(n_prb=24)
NET = AiEstimatorConfig(channels=8, n_res_blocks=1)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG, NET)


@pytest.fixture(scope="module")
def folded(params):
    return fold_ai_params(params, CFG.n_dmrs_sym)


def _assert_tree_equal(a, b):
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        a,
        b,
    )


def _mk_inputs(seed: int, n_ues: int):
    """Random LS input + baseline in the engine's layout contract."""
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    ls_shape = (n_ues, CFG.n_ant, CFG.n_dmrs_sym, CFG.n_pilot_sc)
    des_shape = (n_ues, CFG.n_ant, 1, CFG.n_sc, CFG.n_dmrs_sym)
    h_ls = (jax.random.normal(k1, ls_shape)
            + 1j * jax.random.normal(k2, ls_shape)).astype(jnp.complex64)
    des = (jax.random.normal(k3, des_shape)
           + 1j * jax.random.normal(k4, des_shape)).astype(jnp.complex64)
    return h_ls, des


def _gating(mode: np.ndarray, capacity: int):
    """Replicate ``ExpertBank._run_gated``'s stable compaction plan."""
    is_gated = np.asarray(mode) == 0
    pos = np.cumsum(is_gated.astype(np.int32)) - 1
    within = is_gated & (pos < capacity)
    src = np.where(within, pos, -1).astype(np.int32)
    order = np.argsort(np.logical_not(is_gated).astype(np.int32),
                       kind="stable")
    idx = order[:capacity].astype(np.int32)
    return jnp.asarray(idx), jnp.asarray(src)


# -- kernel: fused == unfused composition, bitwise -----------------------------


EDGE_CASES = [
    # (n_ues, capacity, mode vector): the gating edge-case grid
    (6, 3, [0, 1, 0, 0, 1, 1]),   # exact boundary: selected == capacity
    (6, 6, [0] * 6),              # all-AI, full capacity
    (6, 2, [1] * 6),              # all-MMSE: only padding rows
    (1, 1, [0]),                  # single UE, served
    (1, 1, [1]),                  # single UE, kept
    (5, 1, [1, 0, 1, 0, 1]),      # odd U, capacity 1, one overflow
    (3, 3, [1, 0, 1]),            # padding rows past the one selected UE
]


@pytest.mark.parametrize("n_ues,capacity,mode", EDGE_CASES)
def test_fused_kernel_matches_unfused_bitwise(folded, n_ues, capacity, mode):
    h_ls, des = _mk_inputs(n_ues * 10 + capacity, n_ues)
    idx, src = _gating(np.asarray(mode, np.int32), capacity)

    # the unfused triple, composed by hand
    compact_out = ai_estimate_folded(folded, jnp.take(h_ls, idx, axis=0))
    want = switch_gather_batched_tree_ref(src, compact_out, des)

    ref = gated_expert_apply(idx, src, h_ls, des, folded, backend="ref")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(want))

    fused = gated_expert_apply(
        idx, src, h_ls, des, folded, backend="pallas", interpret=True
    )
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(want))

    # non-vacuous: served UEs actually received the expert's output
    served = np.flatnonzero(np.asarray(src) >= 0)
    for u in served:
        assert not np.array_equal(np.asarray(fused)[u], np.asarray(des)[u])
    # kept UEs round-trip the baseline bytes untouched
    kept = np.flatnonzero(np.asarray(src) < 0)
    for u in kept:
        np.testing.assert_array_equal(np.asarray(fused)[u], np.asarray(des)[u])


@pytest.mark.parametrize("n_ues,capacity,mode", EDGE_CASES[:3])
def test_fused_kernel_bf16_backends_agree(folded, n_ues, capacity, mode):
    """bf16 is not bitwise vs f32, but ref and Pallas backends must agree
    with each other, and kept UEs stay bitwise-untouched."""
    h_ls, des = _mk_inputs(7, n_ues)
    idx, src = _gating(np.asarray(mode, np.int32), capacity)
    kw = dict(compute_dtype=jnp.bfloat16)
    ref = gated_expert_apply(idx, src, h_ls, des, folded, backend="ref", **kw)
    fused = gated_expert_apply(
        idx, src, h_ls, des, folded, backend="pallas", interpret=True, **kw
    )
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(fused))
    kept = np.flatnonzero(np.asarray(src) < 0)
    for u in kept:
        np.testing.assert_array_equal(np.asarray(fused)[u], np.asarray(des)[u])
    served = np.flatnonzero(np.asarray(src) >= 0)
    if served.size:
        f32 = gated_expert_apply(idx, src, h_ls, des, folded, backend="ref")
        # reduced precision genuinely reduced: some served value moved
        assert not np.array_equal(
            np.asarray(fused)[served], np.asarray(f32)[served]
        )
        # ... but not far (sanity bound, not the audit's job)
        np.testing.assert_allclose(
            np.asarray(fused)[served], np.asarray(f32)[served],
            rtol=0.05, atol=0.05,
        )


def test_fused_apply_validates(folded):
    h_ls, des = _mk_inputs(0, 4)
    idx, src = _gating(np.asarray([0, 1, 1, 1], np.int32), 1)
    with pytest.raises(ValueError, match="backend"):
        gated_expert_apply(idx, src, h_ls, des, folded, backend="nope")


# -- bank: fused hook wiring + exact-capacity boundary + audit ----------------


def _toy_bank(**kw):
    experts = [
        Expert(name="ai", fn=lambda p, x: 2.0 * x + 1.0, flops=100.0),
        Expert(name="mmse", fn=lambda p, x: -x, flops=7.0),
    ]
    return ExpertBank(experts, default_mode=1, **kw)


def _toy_fused_hook(fn):
    """A fused hook implemented as the reference composition over ``fn``."""

    def hook(idx, src, base, x):
        compact = fn(None, jnp.take(x, idx, axis=0))
        return switch_gather_batched_tree_ref(src, compact, base)

    return hook


def test_bank_fused_hook_matches_unfused():
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 4, 5))
    hook = _toy_fused_hook(lambda p, x: 2.0 * x + 1.0)
    plain = _toy_bank(execution_mode=ExecutionMode.GATED, gated_capacity=2)
    fused = _toy_bank(
        execution_mode=ExecutionMode.GATED, gated_capacity=2,
        gated_fused_apply=hook,
    )
    for seed in range(4):
        mode = jax.random.randint(jax.random.PRNGKey(seed), (6,), 0, 2)
        op, of = plain(mode, x), fused(mode, x)
        _assert_tree_equal(op.selected, of.selected)
        _assert_tree_equal(op.served_by, of.served_by)
        _assert_tree_equal(op.overflow, of.overflow)
        _assert_tree_equal(op.executed_ue, of.executed_ue)


def test_bank_fused_hook_three_experts():
    """The hook composes with >2 experts: cheap ones stay dense."""
    experts = [
        Expert(name="ai", fn=lambda p, x: 2.0 * x, flops=100.0),
        Expert(name="mmse", fn=lambda p, x: -x, flops=7.0),
        Expert(name="ls", fn=lambda p, x: x + 3.0, flops=1.0),
    ]
    hook = _toy_fused_hook(lambda p, x: 2.0 * x)
    plain = ExpertBank(
        experts, default_mode=1, execution_mode=ExecutionMode.GATED,
        gated_capacity=1,
    )
    fused = ExpertBank(
        experts, default_mode=1, execution_mode=ExecutionMode.GATED,
        gated_capacity=1, gated_fused_apply=hook,
    )
    x = jax.random.normal(jax.random.PRNGKey(2), (6, 9))
    mode = jnp.asarray([0, 2, 1, 0, 2, 1], jnp.int32)
    op, of = plain(mode, x), fused(mode, x)
    _assert_tree_equal(op.selected, of.selected)
    np.testing.assert_array_equal(np.asarray(of.served_by), [0, 2, 1, 1, 2, 1])
    np.testing.assert_array_equal(np.asarray(of.executed_ue), [1, 6, 6])


def test_bank_fused_hook_requires_gated():
    with pytest.raises(ValueError, match="GATED"):
        _toy_bank(gated_fused_apply=lambda *a: None)
    with pytest.raises(ValueError, match="GATED"):
        _toy_bank(audit_threshold=0.5)
    with pytest.raises(ValueError, match="> 0"):
        _toy_bank(execution_mode=ExecutionMode.GATED, audit_threshold=0.0)


@pytest.mark.parametrize("boundary_mode", [
    [0, 0, 0, 1, 1, 1],  # the K selected UEs lead
    [1, 0, 1, 0, 1, 0],  # the K'th selected UE is the *last* UE
    [0, 1, 1, 0, 0, 1],  # mixed
])
def test_bank_exact_capacity_boundary_no_spurious_overflow(boundary_mode):
    """selected == capacity: zero overflow, the K'th UE is served by AI,
    and the executed accounting counts exactly K expert runs."""
    capacity = 3
    mode = jnp.asarray(boundary_mode, jnp.int32)
    assert int((mode == 0).sum()) == capacity  # the boundary premise
    x = jax.random.normal(jax.random.PRNGKey(5), (6, 8))
    bank = _toy_bank(
        execution_mode=ExecutionMode.GATED, gated_capacity=capacity
    )
    out = bank(mode, x)
    np.testing.assert_array_equal(
        np.asarray(out.overflow), np.zeros(6, bool)
    )
    # every selected UE — the K'th included — served by the AI expert
    sel = np.flatnonzero(np.asarray(mode) == 0)
    np.testing.assert_array_equal(np.asarray(out.served_by)[sel], 0)
    np.testing.assert_array_equal(
        np.asarray(out.selected)[sel], np.asarray(2.0 * x + 1.0)[sel]
    )
    np.testing.assert_array_equal(np.asarray(out.executed_ue), [3, 6])
    assert float(bank.executed_flops(out)) == 3 * 100.0 + 6 * 7.0
    per_ue = np.asarray(bank.executed_flops_per_ue(out))
    np.testing.assert_array_equal(per_ue[sel], 107.0)
    # one more selection must overflow exactly one UE (the boundary is tight)
    over = bank(mode.at[int(np.flatnonzero(mode)[0])].set(0), x)
    assert int(np.asarray(over.overflow).sum()) == 1


def test_bank_audit_trips_on_divergent_expert():
    """Adversarial expert output: the audit reverts to the baseline, flags
    the UE, flips served_by to the fail-safe — but still charges the run."""
    experts = [
        Expert(name="ai", fn=lambda p, x: 1e6 * x, flops=100.0),
        Expert(name="mmse", fn=lambda p, x: -x, flops=7.0),
    ]
    bank = ExpertBank(
        experts, default_mode=1, execution_mode=ExecutionMode.GATED,
        gated_capacity=2, audit_threshold=1.0,
    )
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 8))
    mode = jnp.asarray([0, 1, 0, 1], jnp.int32)
    out = bank(mode, x)
    np.testing.assert_array_equal(
        np.asarray(out.audit_tripped), [True, False, True, False]
    )
    # tripped UEs serve the fail-safe baseline, bitwise
    np.testing.assert_array_equal(np.asarray(out.selected), np.asarray(-x))
    np.testing.assert_array_equal(np.asarray(out.served_by), [1, 1, 1, 1])
    np.testing.assert_array_equal(np.asarray(out.overflow), [False] * 4)
    # the expert executed for both tripped UEs: the cost is real
    assert float(bank.executed_flops(out)) == 2 * 100.0 + 4 * 7.0
    per_ue = np.asarray(bank.executed_flops_per_ue(out))
    np.testing.assert_allclose(per_ue, [107.0, 7.0, 107.0, 7.0])


def test_bank_audit_trips_on_nan_output():
    """A diverged (NaN/inf) forward must trip — NMSE comparisons are
    NaN-unsafe unless written trip-by-default."""
    experts = [
        Expert(name="ai", fn=lambda p, x: x * jnp.float32("nan"), flops=1.0),
        Expert(name="mmse", fn=lambda p, x: -x, flops=1.0),
    ]
    bank = ExpertBank(
        experts, default_mode=1, execution_mode=ExecutionMode.GATED,
        audit_threshold=1e6,  # generous — only the NaN can trip it
    )
    x = jnp.ones((3, 4))
    out = bank(jnp.zeros((3,), jnp.int32), x)
    np.testing.assert_array_equal(np.asarray(out.audit_tripped), [True] * 3)
    np.testing.assert_array_equal(np.asarray(out.selected), np.asarray(-x))
    assert np.isfinite(np.asarray(out.selected)).all()


def test_bank_audit_quiet_on_faithful_expert():
    bank = _toy_bank(
        execution_mode=ExecutionMode.GATED, audit_threshold=1e9
    )
    x = jax.random.normal(jax.random.PRNGKey(4), (5, 6))
    mode = jnp.asarray([0, 1, 0, 1, 0], jnp.int32)
    out = bank(mode, x)
    assert not np.asarray(out.audit_tripped).any()
    plain = _toy_bank(execution_mode=ExecutionMode.GATED)
    _assert_tree_equal(out.selected, plain(mode, x).selected)


# -- engine: fused campaigns == unfused, every leaf ---------------------------


def _run_pair(params, modes, *, n_slots, n_ues, **engine_kw):
    sched = good_poor_good_schedule(poor_start=n_slots // 3,
                                    poor_end=2 * n_slots // 3)
    key = jax.random.PRNGKey(9)
    base = dict(net=NET, execution_mode=ExecutionMode.GATED, **engine_kw)
    unfused = BatchedPuschPipeline(CFG, params, **base)
    fused = BatchedPuschPipeline(CFG, params, fused_gated=True, **base)
    _, tu = unfused.run(sched, modes, n_slots=n_slots, n_ues=n_ues, key=key)
    _, tf = fused.run(sched, modes, n_slots=n_slots, n_ues=n_ues, key=key)
    return tu, tf


def test_engine_fused_traces_to_identical_program_off_tpu(params):
    """Off-TPU the fused engine dispatches to the jnp reference, which is
    the *same* composition (same jit'd scatter, same folded GEMMs) as the
    unfused bank path — the jaxprs are identical, which is why
    ``bench_gated`` reports one shared wall-time for both on CPU."""
    import re

    n_ues = 4
    base = dict(net=NET, execution_mode=ExecutionMode.GATED, gated_capacity=2)
    unfused = BatchedPuschPipeline(CFG, params, **base)
    fused = BatchedPuschPipeline(CFG, params, fused_gated=True, **base)
    mode = jnp.zeros((n_ues,), jnp.int32)
    h_ls = jnp.ones(
        (n_ues, CFG.n_ant, CFG.n_dmrs_sym, CFG.n_pilot_sc), jnp.complex64
    )
    texts = []
    for eng in (unfused, fused):
        j = str(jax.make_jaxpr(lambda m, h: eng.bank(m, h).selected)(mode, h_ls))
        texts.append(re.sub(r"0x[0-9a-f]+", "0xX", j))  # thunk identities
    assert texts[0] == texts[1]


@pytest.mark.parametrize("n_ues", [1, 3, 4])
def test_engine_fused_matches_unfused_open_loop(params, n_ues):
    """Every trajectory leaf — physical, KPM, and cost accounting —
    bitwise-equal, including odd batch sizes and U == 1."""
    n_slots = 6
    rng = np.random.default_rng(n_ues)
    modes = rng.integers(0, 2, size=(n_slots, n_ues)).astype(np.int32)
    tu, tf = _run_pair(params, modes, n_slots=n_slots, n_ues=n_ues)
    _assert_tree_equal(tu, tf)


@pytest.mark.parametrize("fill,capacity", [
    (0, None),  # all-AI at full capacity
    (1, None),  # all-MMSE: only padding rows through the kernel path
    (0, 1),     # all-AI at capacity 1: overflow + fused interact
    (0, 2),     # exact boundary when 2 of 4 UEs stay AI below
])
def test_engine_fused_edge_grids(params, fill, capacity):
    n_slots, n_ues = 4, 4
    modes = np.full((n_slots, n_ues), fill, np.int32)
    if capacity == 2:
        modes[:, 2:] = 1  # exactly `capacity` AI selections per slot
    tu, tf = _run_pair(
        params, modes, n_slots=n_slots, n_ues=n_ues, gated_capacity=capacity
    )
    _assert_tree_equal(tu, tf)
    if capacity == 2:
        # exact boundary at the engine layer: no spurious overflow
        assert int(np.asarray(tf["gated_overflow"]).sum()) == 0


def test_engine_fused_matches_unfused_closed_loop(params):
    from repro.core.closed_loop import SwitchConfig
    from repro.core.policy import ThresholdPolicy
    from repro.core.telemetry import SELECTED_KPMS

    n_slots, n_ues = 8, 4
    sched = good_poor_good_schedule(poor_start=2, poor_end=6)
    pol = ThresholdPolicy(
        feature_idx=SELECTED_KPMS.index("snr"), threshold=8.0, hysteresis=0.5
    ).to_device()
    sw_cfg = SwitchConfig(
        feature_names=SELECTED_KPMS, window_slots=2, backend="ref"
    )
    key = jax.random.PRNGKey(11)
    base = dict(net=NET, execution_mode=ExecutionMode.GATED)
    unfused = BatchedPuschPipeline(CFG, params, **base)
    fused = BatchedPuschPipeline(CFG, params, fused_gated=True, **base)
    _, swu, tu = unfused.run_closed_loop(
        sched, pol, sw_cfg, n_slots=n_slots, n_ues=n_ues, key=key
    )
    _, swf, tf = fused.run_closed_loop(
        sched, pol, sw_cfg, n_slots=n_slots, n_ues=n_ues, key=key
    )
    _assert_tree_equal(tu, tf)
    np.testing.assert_array_equal(
        np.asarray(swu.n_switches), np.asarray(swf.n_switches)
    )


def test_engine_exact_capacity_boundary_history(params):
    """BatchedRunHistory at the boundary: K'th UE counted as AI-served,
    zero overflow, executed FLOPs == the K-expert cost model."""
    n_slots, n_ues, capacity = 4, 4, 2
    modes = np.ones((n_slots, n_ues), np.int32)
    modes[:, [1, 3]] = 0  # exactly `capacity` selections, last UE included
    gated = BatchedPuschPipeline(
        CFG, params, net=NET,
        execution_mode=ExecutionMode.GATED, gated_capacity=capacity,
    )
    _, traj = gated.run(
        constant_schedule(GOOD), modes, n_slots=n_slots, n_ues=n_ues
    )
    hist = BatchedRunHistory.from_trajectory(modes, traj)
    assert hist.overflow_slot_ues == 0
    assert hist.ai_share == pytest.approx(capacity / n_ues)
    f_ai, f_mmse = NET.flops(CFG), estimator_flops(CFG)
    np.testing.assert_allclose(
        hist.executed_flops_per_slot(),
        capacity * f_ai + n_ues * f_mmse, rtol=1e-6,
    )
    # per-UE: the K'th (last) UE carries the AI cost, not a fallback cost
    per_ue = np.asarray(traj["executed_flops"])
    np.testing.assert_allclose(
        per_ue[:, 3], f_ai + f_mmse, rtol=1e-6
    )


def test_engine_bf16_audit_fail_safe(params):
    """A paranoid threshold trips the audit on every bf16-served UE: the
    physical trajectory collapses to the all-MMSE campaign, audit flags
    surface in telemetry, and the executed FLOPs still charge the AI runs."""
    n_slots, n_ues = 3, 4
    sched = constant_schedule(GOOD)
    modes = np.ones((n_slots, n_ues), np.int32)
    modes[:, :2] = 0
    bf16 = BatchedPuschPipeline(
        CFG, params, net=NET,
        execution_mode=ExecutionMode.GATED, fused_gated=True,
        expert_dtype="bfloat16", audit_nmse_threshold=1e-14,
    )
    conc = BatchedPuschPipeline(CFG, params, net=NET)
    key = jax.random.PRNGKey(6)
    _, tb = bf16.run(sched, modes, n_slots=n_slots, n_ues=n_ues, key=key)
    tripped = np.asarray(tb["audit_tripped"])
    np.testing.assert_array_equal(tripped, modes == 0)  # every AI UE trips
    # fail-safe: physically identical to committing MMSE everywhere
    _, tm = conc.run(sched, 1, n_slots=n_slots, n_ues=n_ues, key=key)
    _assert_tree_equal(physical_trajectory(tb), physical_trajectory(tm))
    # history: tripped UEs are not AI-served, but their compute was spent
    hist = BatchedRunHistory.from_trajectory(modes, tb)
    assert hist.ai_share == 0.0
    assert hist.audit_tripped_slot_ues == n_slots * 2
    f_ai, f_mmse = NET.flops(CFG), estimator_flops(CFG)
    np.testing.assert_allclose(
        hist.executed_flops_per_slot(), 2 * f_ai + n_ues * f_mmse, rtol=1e-6
    )


def test_engine_bf16_audit_quiet_at_sane_threshold(params):
    """At the benchmark's loose threshold benign campaigns never trip, and
    the bf16 ref/pallas parity carries through the engine (the f32 engine
    stays bitwise vs its own unfused twin by the tests above)."""
    n_slots, n_ues = 3, 4
    modes = np.ones((n_slots, n_ues), np.int32)
    modes[:, 0] = 0
    bf16 = BatchedPuschPipeline(
        CFG, params, net=NET,
        execution_mode=ExecutionMode.GATED, fused_gated=True,
        expert_dtype="bfloat16", audit_nmse_threshold=1.0,
    )
    _, tb = bf16.run(
        constant_schedule(GOOD), modes, n_slots=n_slots, n_ues=n_ues
    )
    assert int(np.asarray(tb["audit_tripped"]).sum()) == 0
    assert int(np.asarray(tb["gated_overflow"]).sum()) == 0


def test_engine_validates_fused_kwargs(params):
    with pytest.raises(ValueError, match="GATED"):
        BatchedPuschPipeline(CFG, params, net=NET, fused_gated=True)
    with pytest.raises(ValueError, match="expert_dtype"):
        BatchedPuschPipeline(CFG, params, net=NET, expert_dtype="fp8")


# -- engine: 8-shard mesh (subprocess: XLA_FLAGS precedes jax init) -----------


_FUSED_SHARDED_CHECK = r"""
import numpy as np, jax, jax.numpy as jnp

assert len(jax.devices()) == 8, jax.devices()

from repro.core.expert_bank import ExecutionMode
from repro.core.session import ArchesSession, CampaignSpec, ExpertBankSpec
from repro.core.topology import CellTopology, TopologySpec, open_loop_fn
from repro.phy.ai_estimator import AiEstimatorConfig, init_params
from repro.phy.channel import broadcast_params_to_ues
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import (
    BatchedPuschPipeline, init_device_link, resolve_schedule,
)
from repro.phy.scenario import good_poor_good_schedule

S, U = 4, 8
CFG = SlotConfig(n_prb=24)
NET = AiEstimatorConfig(channels=8, n_res_blocks=1)
params = init_params(jax.random.PRNGKey(0), CFG, NET)
sched = good_poor_good_schedule(poor_start=1, poor_end=3)
topo = CellTopology.build(
    TopologySpec(n_cells=4, coupling=0.3, n_shards=8), U
)
assert topo.n_shards == 8, topo.n_shards

kw = dict(net=NET, execution_mode=ExecutionMode.GATED, gated_capacity=1)
unfused = BatchedPuschPipeline(CFG, params, **kw)
fused = BatchedPuschPipeline(CFG, params, fused_gated=True, **kw)

key = jax.random.PRNGKey(3)
profile, p = resolve_schedule(CFG, sched, S, U)
p = broadcast_params_to_ues(p, U)
ue_keys = jax.vmap(lambda u: jax.random.fold_in(key, u))(jnp.arange(U))
modes = jnp.ones((S, U), jnp.int32).at[:, ::2].set(0)  # 1 AI UE per shard
mk_args = lambda: (init_device_link(U), ue_keys, modes, p,
                   jnp.asarray(topo.cell_of_ue), topo.cell_params)

# 1) the fused gated scan stays shard-local: HLO collective audit
fn_f = open_loop_fn(fused, topo, profile)
hlo = jax.jit(fn_f).lower(*mk_args()).compile().as_text()
assert "all-reduce" in hlo, "expected the cell-mean psum to lower"
for bad in ("all-gather", "all-to-all", "collective-permute"):
    assert bad not in hlo, f"cross-device {bad} in the fused gated scan"

# 2) fused == unfused on 8 shards, bitwise, every trajectory leaf
fn_u = open_loop_fn(unfused, topo, profile)
_, tf = jax.jit(fn_f)(*mk_args())
_, tu = jax.jit(fn_u)(*mk_args())
jax.tree.map(
    lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
    tu, tf,
)
assert int(np.asarray(tf["gated_overflow"]).sum()) == 0

# 3) sharded auto-capacity regression: a zero-AI-demand campaign on 8
#    shards must provision a buildable capacity (one slot per shard), not
#    the raw demand count 0 that per_shard_capacity rejects
spec = CampaignSpec(
    path="gated", scenario="good_poor_good",
    scenario_args=(("poor_start", 1), ("poor_end", 3)),
    n_ues=U, n_slots=S, modes=1,
    bank=ExpertBankSpec(execution_mode="gated", gated_capacity=8,
                        channels=8, n_res_blocks=1, fused=True),
    topology=TopologySpec(n_cells=4, coupling=0.3, n_shards=8),
)
hist = ArchesSession(spec, ai_params=params).run(auto_capacity=True)
assert hist.provisioned_capacity == 8, hist.provisioned_capacity
assert hist.overflow_slot_ues == 0

print("FUSED-SHARDED-8 OK")
"""


def test_fused_sharded_engine_on_forced_8_device_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + env.get("XLA_FLAGS", "")
    ).strip()
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    proc = subprocess.run(
        [sys.executable, "-c", _FUSED_SHARDED_CHECK],
        env=env, capture_output=True, text=True, timeout=540,
    )
    assert proc.returncode == 0, (
        f"fused sharded check failed\n--- stdout ---\n{proc.stdout}\n"
        f"--- stderr ---\n{proc.stderr[-4000:]}"
    )
    assert "FUSED-SHARDED-8 OK" in proc.stdout
