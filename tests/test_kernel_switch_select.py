"""Per-kernel allclose: the ARCHES switch kernel vs the pure-jnp oracle.

Sweeps shapes / dtypes / expert counts and asserts the Pallas kernel
(interpret mode on CPU) selects exactly the same output as the reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.switch_select import switch_select
from repro.kernels.switch_select.ops import switch_select_leaf
from repro.kernels.switch_select.ref import switch_select_tree_ref
from repro.kernels.switch_select.switch_select import switch_select_2d


def _experts(key, n, shape, dtype):
    keys = jax.random.split(key, n)
    if jnp.issubdtype(dtype, jnp.complexfloating):
        return [
            (
                jax.random.normal(k, shape)
                + 1j * jax.random.normal(jax.random.fold_in(k, 1), shape)
            ).astype(dtype)
            for k in keys
        ]
    return [jax.random.normal(k, shape).astype(dtype) for k in keys]


# -- raw 2-D kernel ------------------------------------------------------------


@pytest.mark.parametrize("rows,cols", [(8, 128), (256, 256), (512, 1024), (128, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_switch_2d_shapes(rows, cols, dtype):
    key = jax.random.PRNGKey(rows * cols)
    outs = _experts(key, 3, (rows, cols), dtype)
    alt = jnp.stack(outs[1:], 0)
    for mode in range(3):
        got = switch_select_2d(
            jnp.int32(mode), alt, outs[0], block_rows=128, block_cols=128,
            interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(outs[mode]))


def test_switch_2d_rejects_ragged():
    outs = _experts(jax.random.PRNGKey(0), 2, (100, 100), jnp.float32)
    with pytest.raises(ValueError):
        switch_select_2d(
            jnp.int32(0), outs[1][None], outs[0], block_rows=64, block_cols=64,
            interpret=True,
        )


def test_switch_2d_shape_mismatch():
    a = jnp.zeros((1, 8, 128))
    d = jnp.zeros((16, 128))
    with pytest.raises(ValueError):
        switch_select_2d(jnp.int32(0), a, d, interpret=True)


# -- leaf wrapper (padding + complex view) ----------------------------------------


@pytest.mark.parametrize(
    "shape",
    [(7,), (3, 5), (4, 3, 17), (1, 1), (2, 2, 2, 2), (1000,), (257, 129)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.complex64])
def test_switch_leaf_odd_shapes(shape, dtype):
    key = jax.random.PRNGKey(sum(shape))
    outs = _experts(key, 3, shape, dtype)
    for mode in range(3):
        got = switch_select_leaf(jnp.int32(mode), outs[1:], outs[0], interpret=True)
        assert got.shape == shape and got.dtype == dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(outs[mode]))


@pytest.mark.parametrize("n_experts", [2, 3, 4, 5])
def test_switch_n_experts(n_experts):
    outs = _experts(jax.random.PRNGKey(n_experts), n_experts, (32, 64), jnp.float32)
    for mode in range(n_experts):
        got = switch_select(jnp.int32(mode), outs)
        want = switch_select_tree_ref(mode, outs)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_switch_pytree():
    key = jax.random.PRNGKey(7)
    mk = lambda k: {
        "h": jax.random.normal(k, (4, 6)),
        "aux": (jax.random.normal(jax.random.fold_in(k, 1), (3,)),),
    }
    outs = [mk(k) for k in jax.random.split(key, 3)]
    for mode in range(3):
        got = switch_select(jnp.int32(mode), outs)
        want = outs[mode]
        jax.tree.map(
            lambda g, w: np.testing.assert_array_equal(np.asarray(g), np.asarray(w)),
            got,
            want,
        )


def test_switch_mode_traced_under_jit():
    """mode must be a runtime value (slot-boundary updates don't retrace)."""
    outs = _experts(jax.random.PRNGKey(3), 2, (16, 128), jnp.float32)

    @jax.jit
    def f(mode):
        return switch_select(mode, outs)

    np.testing.assert_array_equal(np.asarray(f(jnp.int32(0))), np.asarray(outs[0]))
    np.testing.assert_array_equal(np.asarray(f(jnp.int32(1))), np.asarray(outs[1]))
    # one trace, two modes
    assert f._cache_size() == 1


def test_switch_property_randomized(rng):
    """Property sweep: random shapes / expert counts / modes round-trip."""
    for trial in range(25):
        nd = int(rng.integers(1, 4))
        shape = tuple(int(rng.integers(1, 40)) for _ in range(nd))
        n = int(rng.integers(2, 5))
        dtype = [jnp.float32, jnp.bfloat16, jnp.complex64][int(rng.integers(0, 3))]
        outs = _experts(jax.random.PRNGKey(trial), n, shape, dtype)
        mode = int(rng.integers(0, n))
        got = switch_select(jnp.int32(mode), outs)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(outs[mode]))
