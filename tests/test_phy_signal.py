"""5G NR PHY substrate: QAM, DMRS grid, estimators, equalizer, link adaptation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.phy import dmrs as D
from repro.phy import qam as Q
from repro.phy.channel import ChannelConfig, apply_channel, simulate_slot_channel
from repro.phy.equalizer import mmse_equalize, time_interpolate
from repro.phy.estimators import WienerInterpolator, ls_estimate, mmse_estimate
from repro.phy.mcs import mcs_entry, n_code_blocks, select_mcs, transport_block_size
from repro.phy.nr import SlotConfig

CFG = SlotConfig(n_prb=24)


# -- QAM -------------------------------------------------------------------------


@pytest.mark.parametrize("qm", [2, 4, 6, 8])
def test_qam_roundtrip(qm, rng):
    bits = jnp.asarray(rng.integers(0, 2, size=qm * 64), jnp.uint8)
    syms = Q.modulate(bits, qm)
    assert syms.shape == (64,)
    # unit average power constellation
    assert abs(float(jnp.mean(jnp.abs(Q.constellation(qm)) ** 2)) - 1.0) < 1e-5
    # noiseless demap recovers bits
    llr = Q.demap_llr(syms, jnp.asarray(1e-4), qm)
    np.testing.assert_array_equal(np.asarray(Q.hard_bits(llr)), np.asarray(bits))


@pytest.mark.parametrize("qm", [2, 4, 6])
def test_qam_llr_sign_flips_with_noise(qm, rng):
    """LLR magnitudes shrink as noise_var grows (soft information property)."""
    bits = jnp.asarray(rng.integers(0, 2, size=qm * 128), jnp.uint8)
    syms = Q.modulate(bits, qm)
    llr_lo = Q.demap_llr(syms, jnp.asarray(0.01), qm)
    llr_hi = Q.demap_llr(syms, jnp.asarray(1.0), qm)
    assert float(jnp.mean(jnp.abs(llr_lo))) > float(jnp.mean(jnp.abs(llr_hi)))


# -- DMRS grid --------------------------------------------------------------------


def test_grid_mapping_inverse(rng):
    cfg = CFG
    n_data = cfg.n_data_re()
    syms = jnp.asarray(
        rng.normal(size=n_data) + 1j * rng.normal(size=n_data), jnp.complex64
    )
    pilots = D.dmrs_sequence(cfg)
    grid = D.map_slot_grid(cfg, syms, pilots)
    assert grid.shape == (cfg.n_layers, cfg.n_sc, cfg.n_sym)
    got_data = D.extract_data_re(cfg, grid)[0]
    np.testing.assert_allclose(np.asarray(got_data), np.asarray(syms), atol=1e-6)
    got_pilot = D.extract_pilot_re(cfg, grid)[0]
    want = jnp.broadcast_to(pilots, got_pilot.shape)
    np.testing.assert_allclose(np.asarray(got_pilot), np.asarray(want), atol=1e-6)


def test_dmrs_type1_positions():
    """Type-1 DMRS on symbols 0/5/10, comb-2 (paper 5.1, Fig. 6)."""
    assert CFG.dmrs_symbols == (0, 5, 10)
    pilots = D.dmrs_sequence(CFG)
    assert pilots.shape[-1] == CFG.n_sc // 2  # comb-2: every other SC
    # unit-modulus QPSK sequence
    np.testing.assert_allclose(np.abs(np.asarray(pilots)), 1.0, atol=1e-6)


def test_dmrs_sequence_depends_on_cell_and_slot():
    a = D.dmrs_sequence(CFG, slot=0, cell_id=42)
    b = D.dmrs_sequence(CFG, slot=1, cell_id=42)
    c = D.dmrs_sequence(CFG, slot=0, cell_id=7)
    assert not np.allclose(np.asarray(a), np.asarray(b))
    assert not np.allclose(np.asarray(a), np.asarray(c))


# -- estimators ---------------------------------------------------------------------


def _flat_channel_rx(key, cfg, h_scalar=1.0, snr_db=100.0):
    """TX grid through a flat (constant) channel for estimator ground truth."""
    n_data = cfg.n_data_re()
    kd, kn = jax.random.split(key)
    syms = Q.modulate(
        jax.random.bernoulli(kd, 0.5, (n_data * 2,)).astype(jnp.uint8), 2
    )
    pilots = D.dmrs_sequence(cfg)
    grid = D.map_slot_grid(cfg, syms, pilots)[0]  # layer 0 -> (n_sc, n_sym)
    rx = jnp.broadcast_to(grid[None], (cfg.n_ant, *grid.shape)) * h_scalar
    noise_var = 10 ** (-snr_db / 10)
    noise = (
        jax.random.normal(kn, rx.shape) + 1j * jax.random.normal(kn, rx.shape)
    ) * jnp.sqrt(noise_var / 2)
    return rx + noise.astype(rx.dtype), pilots, syms, noise_var


def test_ls_estimate_flat_channel():
    cfg = CFG
    rx, pilots, _, _ = _flat_channel_rx(jax.random.PRNGKey(0), cfg, h_scalar=0.7 + 0.2j)
    h_ls = ls_estimate(cfg, rx, pilots)
    assert h_ls.shape == (cfg.n_ant, len(cfg.dmrs_symbols), cfg.n_sc // 2)
    np.testing.assert_allclose(
        np.asarray(h_ls), np.full(h_ls.shape, 0.7 + 0.2j), atol=1e-3
    )


def test_mmse_beats_ls_at_low_snr():
    """Wiener smoothing must reduce estimation MSE vs raw LS under noise."""
    cfg = CFG
    wi = WienerInterpolator.build(cfg, rms_delay_spread_s=1e-7)
    key = jax.random.PRNGKey(1)
    mse_ls, mse_mmse = [], []
    for t in range(5):
        k = jax.random.fold_in(key, t)
        rx, pilots, _, _ = _flat_channel_rx(k, cfg, h_scalar=1.0, snr_db=0.0)
        h_ls = ls_estimate(cfg, rx, pilots)
        h_mmse = mmse_estimate(cfg, rx, pilots, wi)
        # truth: H == 1 everywhere
        mse_ls.append(float(jnp.mean(jnp.abs(h_ls - 1.0) ** 2)))
        mse_mmse.append(float(jnp.mean(jnp.abs(h_mmse - 1.0) ** 2)))
    assert np.mean(mse_mmse) < np.mean(mse_ls)


def test_mmse_kernel_equals_ref_path():
    cfg = CFG
    wi = WienerInterpolator.build(cfg)
    rx, pilots, _, _ = _flat_channel_rx(jax.random.PRNGKey(2), cfg, snr_db=10.0)
    a = mmse_estimate(cfg, rx, pilots, wi, use_kernel=True)
    b = mmse_estimate(cfg, rx, pilots, wi, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5)


# -- equalizer ----------------------------------------------------------------------


def test_equalizer_recovers_flat_channel_symbols():
    cfg = CFG
    h = 0.8 - 0.3j
    rx, pilots, syms, nv = _flat_channel_rx(jax.random.PRNGKey(3), cfg, h_scalar=h)
    h_est = jnp.full(
        (cfg.n_ant, 1, cfg.n_sc, len(cfg.dmrs_symbols)), h, jnp.complex64
    )
    x_hat, _ = mmse_equalize(cfg, rx, h_est, jnp.asarray(nv))
    data = D.extract_data_re(cfg, x_hat[None])[0]
    np.testing.assert_allclose(np.asarray(data), np.asarray(syms), atol=1e-2)


def test_time_interpolate_shape():
    cfg = CFG
    h = jnp.ones((cfg.n_ant, 1, cfg.n_sc, len(cfg.dmrs_symbols)), jnp.complex64)
    full = time_interpolate(cfg, h)
    assert full.shape == (cfg.n_ant, 1, cfg.n_sc, cfg.n_sym)


# -- channel model ---------------------------------------------------------------


def test_channel_sim_fields():
    fields = simulate_slot_channel(jax.random.PRNGKey(0), CFG, ChannelConfig())
    h = fields["h"]
    assert h.shape == (CFG.n_ant, CFG.n_layers, CFG.n_sc, CFG.n_sym)
    assert np.isfinite(np.asarray(h).view(np.float32)).all()
    # normalized average channel power ~ 1
    assert 0.5 < float(jnp.mean(jnp.abs(h) ** 2)) < 2.0


def test_apply_channel_snr():
    """Measured post-channel SNR tracks the configured value."""
    cfg = CFG
    ch = ChannelConfig(snr_db=10.0)
    key = jax.random.PRNGKey(5)
    fields = simulate_slot_channel(key, cfg, ch)
    tx = jnp.ones((cfg.n_layers, cfg.n_sc, cfg.n_sym), jnp.complex64)
    rx = apply_channel(jax.random.PRNGKey(6), tx, fields)
    clean = fields["h"][:, 0] * tx[0]
    sig = rx - clean
    snr_meas = 10 * np.log10(
        float(jnp.mean(jnp.abs(clean) ** 2) / jnp.mean(jnp.abs(sig) ** 2))
    )
    assert abs(snr_meas - 10.0) < 1.5


def test_interference_lowers_sinr():
    cfg = CFG
    clean = ChannelConfig(snr_db=20.0, interference=False)
    dirty = ChannelConfig(snr_db=20.0, interference=True, inr_db=15.0, interference_prb_frac=1.0)
    k = jax.random.PRNGKey(7)
    tx = jnp.ones((cfg.n_layers, cfg.n_sc, cfg.n_sym), jnp.complex64)
    f_c = simulate_slot_channel(k, cfg, clean)
    f_d = simulate_slot_channel(k, cfg, dirty)
    rx_c = apply_channel(jax.random.PRNGKey(8), tx, f_c)
    rx_d = apply_channel(jax.random.PRNGKey(8), tx, f_d)
    err_c = float(jnp.mean(jnp.abs(rx_c - f_c["h"][:, 0] * tx[0]) ** 2))
    err_d = float(jnp.mean(jnp.abs(rx_d - f_d["h"][:, 0] * tx[0]) ** 2))
    assert err_d > 2 * err_c


# -- link adaptation ----------------------------------------------------------------


def test_mcs_table_monotone():
    prev_eff = 0.0
    for i in range(0, 28, 3):
        e = mcs_entry(i)
        eff = e.qm * e.code_rate
        assert eff > prev_eff
        prev_eff = eff


def test_select_mcs_monotone_in_snr():
    idxs = [select_mcs(s).index for s in np.linspace(-5, 35, 15)]
    assert all(b >= a for a, b in zip(idxs, idxs[1:]))
    assert idxs[0] == 0 and idxs[-1] >= 25


def test_tbs_positive_and_scales():
    e = mcs_entry(10)
    small = transport_block_size(1000, e)
    large = transport_block_size(10000, e)
    assert 0 < small < large
    assert n_code_blocks(large) >= 1
