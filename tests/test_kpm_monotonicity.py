"""Regression guard for the paper's Fig. 4 methodology assumption.

Stage 1 of the policy-design process injects calibrated noise (intensity
``rho``) into the MMSE expert's output and relies on the selected KPMs
responding *monotonically* so that stage-2 filtering is meaningful.  This
locks the property down for the two KPMs the paper leans on hardest:
measured SINR and PHY throughput must degrade (within tolerance) as ``rho``
increases.
"""

import jax
import numpy as np
import pytest

from repro.phy.ai_estimator import AiEstimatorConfig, init_params
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import LinkState, PuschPipeline
from repro.phy.scenario import GOOD

CFG = SlotConfig(n_prb=24)
NET = AiEstimatorConfig(channels=8, n_res_blocks=1)
RHOS = (0.0, 0.5, 1.0, 2.0)
N_SLOTS = 10
WARMUP = 3  # skip OLLA cold start


@pytest.fixture(scope="module")
def sweeps():
    """Mean post-warmup (sinr_db, phy_throughput) per rho, seed-averaged."""
    params = init_params(jax.random.PRNGKey(0), CFG, NET)
    pipe = PuschPipeline(CFG, params, net=NET)
    out = {}
    for rho in RHOS:
        sinrs, tputs = [], []
        for seed in (200, 300):
            link = LinkState()
            for i in range(N_SLOTS):
                link, _, kpms = pipe.run_slot(
                    jax.random.PRNGKey(seed + i), 1, link, GOOD, perturb_rho=rho
                )
                if i >= WARMUP:
                    sinrs.append(kpms["oai"]["snr"])
            tputs.append(kpms["aerial"]["phy_throughput"])
        out[rho] = (float(np.mean(sinrs)), float(np.mean(tputs)))
    return out


def test_sinr_degrades_monotonically_in_rho(sweeps):
    """Measured SINR falls as perturbation grows (Fig. 4b trend)."""
    sinr = [sweeps[r][0] for r in RHOS]
    tol_db = 0.5  # allow sampling noise between adjacent rho steps
    for lo, hi in zip(sinr[1:], sinr[:-1]):
        assert lo <= hi + tol_db, (RHOS, sinr)
    # end-to-end the collapse must be decisive, not borderline
    assert sinr[-1] < sinr[0] - 3.0, sinr


def test_phy_throughput_degrades_monotonically_in_rho(sweeps):
    """Delivered PHY throughput falls as perturbation grows (Fig. 4a trend).

    Tolerance model follows the paper's own stage-2 filter: monotonicity is
    judged by Spearman rank correlation against rho (the saturated bottom of
    the curve is sampling-noise-dominated once link adaptation pins MCS 0,
    so strict pairwise ordering is not the methodology's claim).
    """
    from scipy.stats import spearmanr

    tput = [sweeps[r][1] for r in RHOS]
    rs = spearmanr(RHOS, tput).statistic
    assert rs <= -0.7, (RHOS, tput, rs)
    assert tput[-1] < 0.8 * tput[0], tput
