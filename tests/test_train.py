"""Training substrate: loss descent, grad accumulation, fault tolerance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointManager
from repro.data.tokens import TokenStream
from repro.models.config import get_config
from repro.models.model import Model
from repro.train.loop import FailureInjector, run_training
from repro.train.step import TrainConfig, init_train_state, train_step

CFG = get_config("granite-20b", reduced=True)


def _fresh(tc=TrainConfig(), seed=0):
    model = Model(CFG)
    params = model.init(jax.random.PRNGKey(seed))
    return model, init_train_state(model, params, tc)


def _batch(i, b=4, s=32):
    ts = TokenStream(vocab=CFG.vocab, seq_len=s, global_batch=b, seed=7)
    return ts.batch_at(i)


def test_loss_decreases():
    tc = TrainConfig(learning_rate=3e-3)
    model, state = _fresh(tc)
    losses = []
    for i in range(30):
        state, m = train_step(model, tc, state, _batch(i % 4))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_grad_accumulation_equivalence():
    """microbatches=2 over the same global batch == single-shot step."""
    tc1 = TrainConfig(learning_rate=1e-3, microbatches=1)
    tc2 = TrainConfig(learning_rate=1e-3, microbatches=2)
    model, s1 = _fresh(tc1, seed=3)
    _, s2 = _fresh(tc2, seed=3)
    batch = _batch(0, b=4)
    s1, m1 = train_step(model, tc1, s1, batch)
    s2, m2 = train_step(model, tc2, s2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s1.params, s2.params,
    )
    assert max(jax.tree.leaves(d)) < 5e-2  # bf16 params, lr-sized updates


def test_quantized_moments_path():
    tc = TrainConfig(quantize_moments=True, learning_rate=1e-3)
    model, state = _fresh(tc)
    prev = float("inf")
    for i in range(10):
        state, m = train_step(model, tc, state, _batch(i))
        assert np.isfinite(float(m["loss"]))
    assert float(m["loss"]) < 7.0


def test_grad_compression_path():
    tc = TrainConfig(compress_grads=True, learning_rate=1e-3)
    model, state = _fresh(tc)
    for i in range(6):
        state, m = train_step(model, tc, state, _batch(i))
        assert np.isfinite(float(m["loss"]))


def test_fault_tolerant_loop(tmp_path):
    """Injected failures trigger checkpoint restart; training completes."""
    tc = TrainConfig(learning_rate=1e-3)
    model, _ = _fresh(tc)

    def init_state():
        params = model.init(jax.random.PRNGKey(0))
        return init_train_state(model, params, tc)

    def step_fn(state, batch):
        return train_step(model, tc, state, batch)

    def data(start_step):
        def gen():
            i = start_step
            while True:
                yield _batch(i % 8)
                i += 1
        return gen()

    ckpt = CheckpointManager(str(tmp_path), save_every=5, keep=2)
    report = run_training(
        step_fn=step_fn,
        init_state=init_state,
        data=data,
        ckpt=ckpt,
        total_steps=20,
        failure_injector=FailureInjector(fail_at_steps=(7, 13)),
        max_restarts=5,
        log=lambda s: None,
    )
    assert report.final_step == 20
    assert report.restarts == 2
    # restarts resume from checkpoints (steps 5/10), so some steps re-ran
    assert report.steps_run > 20
    assert report.steps_run == 20 + (7 - 5) + (13 - 10)


def test_loop_exhausts_restarts(tmp_path):
    tc = TrainConfig()
    model, _ = _fresh(tc)

    def init_state():
        return init_train_state(model, model.init(jax.random.PRNGKey(0)), tc)

    def data(start):
        def gen():
            i = start
            while True:
                yield _batch(i)
                i += 1
        return gen()

    from repro.train.loop import InjectedFailure

    ckpt = CheckpointManager(str(tmp_path), save_every=100, keep=1)
    with pytest.raises(InjectedFailure):
        run_training(
            step_fn=lambda s, b: train_step(model, tc, s, b),
            init_state=init_state,
            data=data,
            ckpt=ckpt,
            total_steps=50,
            failure_injector=FailureInjector(fail_at_steps=(2, 3, 4, 5, 6)),
            max_restarts=2,
            log=lambda s: None,
        )
