"""Policy-level parity of the exported tree tables through ``tree_infer``.

``test_kernel_tree_infer`` checks the Pallas kernel against the jnp walk;
this suite closes the remaining gap to the *policy* layer: the flat device
tables exported by ``DecisionTreePolicy.to_device`` must reproduce
``FittedTree`` predictions exactly — checked against an independent pure
Python node-by-node descent (not ``tree_infer_ref``) on randomized feature
grids, through both evaluator backends, including degenerate trees (single
leaf, all-one-side splits) and exact-threshold inputs.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.closed_loop import export_tree_tables, policy_infer
from repro.core.policy import DecisionTreePolicy, FittedTree, fit_decision_tree


def _python_walk(feature, threshold, leaf_values, depth, x):
    """Independent oracle: literal per-row, per-level tree descent."""
    out = np.zeros(x.shape[0], np.int32)
    for i, row in enumerate(x):
        node = 0
        for _ in range(depth):
            go_right = row[feature[node]] > threshold[node]
            node = 2 * node + 1 + int(go_right)
        out[i] = int(leaf_values[node - (2**depth - 1)])
    return out


def _assert_tables_match(tree: FittedTree, x: np.ndarray):
    device = export_tree_tables(
        tree.feature, tree.threshold, tree.leaf_values, tree.n_features, tree.depth
    )
    want = _python_walk(
        tree.feature, tree.threshold, tree.leaf_values, tree.depth, x
    )
    prev = jnp.zeros((x.shape[0],), jnp.int32)
    for backend in ("ref", "pallas"):
        got = np.asarray(
            policy_infer(device, jnp.asarray(x), prev, backend=backend)
        )
        np.testing.assert_array_equal(got, want, err_msg=backend)


def _manual_tree(feature, threshold, leaf_values, n_features) -> FittedTree:
    feature = np.asarray(feature, np.int32)
    depth = int(feature.shape[0] + 1).bit_length() - 1
    return FittedTree(
        feature=feature,
        threshold=np.asarray(threshold, np.float32),
        leaf_values=np.asarray(leaf_values, np.float32),
        depth=depth,
        n_features=n_features,
        importances=np.zeros(n_features, np.float32),
    )


@pytest.mark.parametrize("depth", [1, 2, 3, 4])
@pytest.mark.parametrize("f", [1, 4, 10])
def test_fitted_tree_parity_random_grids(depth, f):
    """Fitted trees: device tables == python walk on randomized grids."""
    rng = np.random.default_rng(depth * 100 + f)
    x = rng.normal(size=(240, f)).astype(np.float32)
    w = rng.normal(size=f)
    y = (x @ w > 0).astype(np.int32)
    tree = fit_decision_tree(x, y, depth=depth)
    grid = rng.normal(size=(500, f)).astype(np.float32) * 2.0
    _assert_tables_match(tree, grid)


def test_exhaustive_parity_on_threshold_lattice():
    """Every path of a depth-2 tree, including x exactly AT each threshold.

    Strict-``>`` semantics: a feature equal to the split threshold goes
    left in the python walk, the ref walk and the MXU kernel alike.
    """
    rng = np.random.default_rng(42)
    tree = _manual_tree(
        feature=[0, 1, 0],
        threshold=[0.5, -1.0, 2.0],
        leaf_values=[0, 1, 1, 0],
        n_features=2,
    )
    # lattice around every threshold (below / exactly-at / above) x both axes
    pts = np.asarray([-1.0 - 1e-3, -1.0, -1.0 + 1e-3, 0.5 - 1e-3, 0.5,
                      0.5 + 1e-3, 2.0 - 1e-3, 2.0, 2.0 + 1e-3], np.float32)
    xv, yv = np.meshgrid(pts, pts)
    grid = np.stack([xv.ravel(), yv.ravel()], axis=1)
    _assert_tables_match(tree, grid)
    # plus random noise rows for good measure
    _assert_tables_match(tree, rng.normal(size=(300, 2)).astype(np.float32))


def test_single_leaf_tree():
    """Pure training data -> every threshold +inf -> constant prediction."""
    x = np.ones((30, 3), np.float32)
    y = np.ones(30, np.int32)
    tree = fit_decision_tree(x, y, depth=2)
    assert not np.isfinite(tree.threshold).any()
    rng = np.random.default_rng(3)
    grid = rng.normal(size=(200, 3)).astype(np.float32) * 10
    _assert_tables_match(tree, grid)
    device = export_tree_tables(
        tree.feature, tree.threshold, tree.leaf_values, 3, 2
    )
    got = policy_infer(device, jnp.asarray(grid), jnp.zeros(200, jnp.int32))
    assert (np.asarray(got) == 1).all()


@pytest.mark.parametrize("side", ["left", "right"])
def test_all_one_side_splits(side):
    """Degenerate chains: every split sends every sample the same way."""
    thr = np.float32(np.inf) if side == "left" else np.float32(-np.inf)
    # depth 3, all nodes pass through to one side; distinct leaf values mark
    # which leaf actually fires
    tree = _manual_tree(
        feature=np.zeros(7, np.int32),
        threshold=np.full(7, thr),
        leaf_values=np.arange(8, dtype=np.float32),
        n_features=2,
    )
    rng = np.random.default_rng(9)
    grid = rng.normal(size=(128, 2)).astype(np.float32)
    want_leaf = 0 if side == "left" else 7
    want = _python_walk(
        tree.feature, tree.threshold, tree.leaf_values, 3, grid
    )
    assert (want == want_leaf).all()
    _assert_tables_match(tree, grid)


def test_mixed_passthrough_tree():
    """Half the nodes pass-through (trainer-style +inf), half split."""
    rng = np.random.default_rng(17)
    for trial in range(8):
        depth = int(rng.integers(2, 5))
        f = int(rng.integers(2, 8))
        n_nodes, n_leaves = 2**depth - 1, 2**depth
        feature = rng.integers(0, f, size=n_nodes).astype(np.int32)
        threshold = rng.normal(size=n_nodes).astype(np.float32)
        threshold[rng.random(n_nodes) < 0.4] = np.inf
        leaf_values = rng.integers(0, 3, size=n_leaves).astype(np.float32)
        tree = _manual_tree(feature, threshold, leaf_values, f)
        grid = rng.normal(size=(150, f)).astype(np.float32)
        _assert_tables_match(tree, grid)


def test_to_device_matches_host_policy_calls():
    """DecisionTreePolicy.to_device == the host policy object, row by row."""
    rng = np.random.default_rng(23)
    x = rng.normal(size=(150, 5)).astype(np.float32)
    y = ((x[:, 1] > 0.2) | (x[:, 3] < -0.4)).astype(np.int32)
    tree = fit_decision_tree(x, y, depth=3)
    policy = DecisionTreePolicy(tree, [f"f{i}" for i in range(5)])
    device = policy.to_device()
    grid = rng.normal(size=(80, 5)).astype(np.float32)
    host = np.asarray([int(policy(jnp.asarray(row))) for row in grid])
    got = np.asarray(
        policy_infer(device, jnp.asarray(grid), jnp.zeros(80, jnp.int32))
    )
    np.testing.assert_array_equal(got, host)
    # the batched host path agrees too
    np.testing.assert_array_equal(
        np.asarray(policy.batch(jnp.asarray(grid))), host
    )
