"""Shared test fixtures + collection-safety guard.

NOTE: XLA_FLAGS / forced device counts are deliberately NOT set here — smoke
tests must see the real single CPU device (the dry-run sets its own flags in
its own process).

Collection guard: an import error in one test module (e.g. an upstream JAX
API change) must surface as a *failure of that file*, not abort the whole
session — otherwise `pytest -x -q` hides every other test behind the first
broken import.  ``pytest_pycollect_makemodule`` wraps each module in a
collector that converts collection-time exceptions into a single synthetic
failing item carrying the original traceback.
"""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


class _CollectFailureItem(pytest.Item):
    """Synthetic test that re-raises a module's collection error."""

    def __init__(self, *, excinfo, **kwargs):
        super().__init__(**kwargs)
        self._excinfo = excinfo

    def runtest(self):
        raise self._excinfo

    def reportinfo(self):
        return self.path, 0, f"collection failure: {self.path.name}"


class _GuardedModule(pytest.Module):
    def collect(self):
        try:
            return list(super().collect())
        except Exception as exc:  # noqa: BLE001 — any import-time crash
            item = _CollectFailureItem.from_parent(
                self, name=f"{self.path.stem}::collection", excinfo=exc
            )
            return [item]


def pytest_pycollect_makemodule(module_path, parent):
    return _GuardedModule.from_parent(parent, path=module_path)
