"""Shared test fixtures.

NOTE: XLA_FLAGS / forced device counts are deliberately NOT set here — smoke
tests must see the real single CPU device (the dry-run sets its own flags in
its own process).
"""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
