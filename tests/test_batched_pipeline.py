"""Batched multi-UE slot engine semantics (scan loop + per-UE mode vector).

Locks down the three contracts the batched engine adds on top of the
single-UE pipeline:

* the per-UE mode vector routes each UE to its own expert, identically to
  running that UE alone under a scalar mode (same keys => same trajectory);
* the ``lax.scan``-compiled slot loop reproduces the host-loop trajectory;
* the batched Pallas switch kernel matches the pure-jnp oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.switch_select.ops import switch_select
from repro.kernels.switch_select.ref import switch_select_batched_tree_ref
from repro.phy.ai_estimator import AiEstimatorConfig, init_params
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import BatchedPuschPipeline, normalize_modes
from repro.phy.scenario import GOOD, constant_schedule, good_poor_good_schedule

CFG = SlotConfig(n_prb=24)
NET = AiEstimatorConfig(channels=8, n_res_blocks=1)


@pytest.fixture(scope="module")
def engine():
    params = init_params(jax.random.PRNGKey(0), CFG, NET)
    return BatchedPuschPipeline(CFG, params, net=NET)


def _np_tree(traj):
    return jax.tree.map(np.asarray, traj)


# -- (a) per-UE mode vector ----------------------------------------------------


def test_mode_vector_matches_single_ue_runs(engine):
    """UE u under mode vector m == UE u alone under scalar m[u], bitwise."""
    sched = constant_schedule(GOOD)
    n_slots = 8
    modes = jnp.asarray([0, 1], jnp.int32)
    key = jax.random.PRNGKey(7)
    _, mixed = engine.run(sched, modes, n_slots=n_slots, n_ues=2, key=key)
    _, all_ai = engine.run(sched, 0, n_slots=n_slots, n_ues=2, key=key)
    _, all_mmse = engine.run(sched, 1, n_slots=n_slots, n_ues=2, key=key)

    mixed, all_ai, all_mmse = map(_np_tree, (mixed, all_ai, all_mmse))
    for name in ("tb_ok", "mcs"):
        np.testing.assert_array_equal(mixed[name][:, 0], all_ai[name][:, 0])
        np.testing.assert_array_equal(mixed[name][:, 1], all_mmse[name][:, 1])
    # continuous KPMs too: the switch routes the exact expert output
    np.testing.assert_array_equal(
        mixed["kpms"]["aerial"]["sinr"][:, 0], all_ai["kpms"]["aerial"]["sinr"][:, 0]
    )
    np.testing.assert_array_equal(
        mixed["kpms"]["aerial"]["sinr"][:, 1],
        all_mmse["kpms"]["aerial"]["sinr"][:, 1],
    )
    # and the two experts genuinely differ (the comparison is non-vacuous)
    assert not np.array_equal(
        all_ai["kpms"]["aerial"]["sinr"][:, 0],
        all_mmse["kpms"]["aerial"]["sinr"][:, 0],
    )


def test_mode_vector_rejects_bad_shape():
    with pytest.raises(ValueError):
        normalize_modes(jnp.zeros((3, 5), jnp.int32), 4, 2)


def test_mode_vector_rejects_ambiguous_square():
    """1-D modes are ambiguous when n_slots == n_ues: must be explicit."""
    with pytest.raises(ValueError, match="ambiguous"):
        normalize_modes(jnp.asarray([0, 1, 0, 1], jnp.int32), 4, 4)
    # the explicit 2-D forms still work
    m = jnp.asarray([0, 1, 0, 1], jnp.int32)
    per_slot = normalize_modes(m[:, None], 4, 4)
    per_ue = normalize_modes(m[None, :], 4, 4)
    assert per_slot.shape == per_ue.shape == (4, 4)
    assert (np.asarray(per_slot)[1] == 1).all()  # slot 1, all UEs
    assert (np.asarray(per_ue)[:, 1] == 1).all()  # UE 1, all slots


# -- (b) scan loop == host loop ------------------------------------------------


def test_scan_reproduces_host_loop_trajectory(engine):
    """2 UE x 20 slots across a good->poor->good schedule."""
    sched = good_poor_good_schedule(poor_start=6, poor_end=13)
    kw = dict(n_slots=20, n_ues=2, key=jax.random.PRNGKey(3))
    _, scan = engine.run(sched, 1, use_scan=True, **kw)
    _, host = engine.run(sched, 1, use_scan=False, **kw)
    scan, host = _np_tree(scan), _np_tree(host)

    np.testing.assert_array_equal(scan["tb_ok"], host["tb_ok"])
    np.testing.assert_array_equal(scan["mcs"], host["mcs"])
    for source in scan["kpms"]:
        for name in scan["kpms"][source]:
            np.testing.assert_allclose(
                scan["kpms"][source][name],
                host["kpms"][source][name],
                rtol=1e-5,
                atol=1e-6,
                err_msg=f"{source}/{name}",
            )


def test_batch_composition_does_not_change_a_ue(engine):
    """A batched run == independent single-UE runs with the same keys.

    Miniature of the acceptance criterion (16 UE x 100 slots in
    ``bench_timeseries``): every UE's ``tb_ok``/MCS trajectory inside the
    batch is identical to running that UE alone with its key.
    """
    sched = good_poor_good_schedule(poor_start=4, poor_end=9)
    n_slots, n_ues = 12, 4
    ue_keys = jax.random.split(jax.random.PRNGKey(11), n_ues)
    _, batched = engine.run(
        sched, 1, n_slots=n_slots, n_ues=n_ues, ue_keys=ue_keys
    )
    batched = _np_tree(batched)
    for ue in range(n_ues):
        _, solo = engine.run(
            sched, 1, n_slots=n_slots, n_ues=1, ue_keys=ue_keys[ue : ue + 1]
        )
        solo = _np_tree(solo)
        np.testing.assert_array_equal(batched["tb_ok"][:, ue], solo["tb_ok"][:, 0])
        np.testing.assert_array_equal(batched["mcs"][:, ue], solo["mcs"][:, 0])


# -- (c) batched Pallas switch vs oracle ---------------------------------------


@pytest.mark.parametrize(
    "shape", [(4, 6), (3, 4, 2, 33, 3), (5, 1000), (2, 8, 128)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.complex64])
def test_batched_switch_matches_ref(shape, dtype):
    n_ues = shape[0]
    n_experts = 3
    outs = []
    for e in range(n_experts):
        k = jax.random.fold_in(jax.random.PRNGKey(sum(shape)), e)
        x = jax.random.normal(k, shape)
        if jnp.issubdtype(dtype, jnp.complexfloating):
            x = x + 1j * jax.random.normal(jax.random.fold_in(k, 1), shape)
        outs.append(x.astype(dtype))
    modes = jax.random.randint(
        jax.random.PRNGKey(99), (n_ues,), 0, n_experts
    ).astype(jnp.int32)
    got = switch_select(modes, outs)
    want = switch_select_batched_tree_ref(modes, outs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # row u holds expert modes[u]'s slice exactly
    for u in range(n_ues):
        np.testing.assert_array_equal(
            np.asarray(got[u]), np.asarray(outs[int(modes[u])][u])
        )


def test_batched_switch_pytree():
    n_ues = 3
    mk = lambda k: {
        "h": jax.random.normal(k, (n_ues, 5, 7)),
        "aux": (jax.random.normal(jax.random.fold_in(k, 1), (n_ues, 2)),),
    }
    outs = [mk(k) for k in jax.random.split(jax.random.PRNGKey(5), 2)]
    modes = jnp.asarray([1, 0, 1], jnp.int32)
    got = switch_select(modes, outs)
    want = switch_select_batched_tree_ref(modes, outs)
    jax.tree.map(
        lambda g, w: np.testing.assert_array_equal(np.asarray(g), np.asarray(w)),
        got,
        want,
    )


def test_batched_run_history_and_replay(engine):
    """BatchedRunHistory + E3 replay consume a scan trajectory end-to-end."""
    from repro.core.e3 import E3Agent, E3Subscription
    from repro.core.runtime import BatchedRunHistory, replay_batched_telemetry

    sched = constant_schedule(GOOD)
    n_slots, n_ues = 5, 3
    modes = jnp.asarray([0, 1, 1], jnp.int32)
    _, traj = engine.run(sched, modes, n_slots=n_slots, n_ues=n_ues)

    hist = BatchedRunHistory.from_trajectory(
        np.broadcast_to(np.asarray(modes), (n_slots, n_ues)), traj
    )
    assert (hist.n_slots, hist.n_ues) == (n_slots, n_ues)
    assert hist.kpm_series("sinr", ue=1).shape == (n_slots,)
    np.testing.assert_allclose(
        hist.cell_kpm_series("sinr"),
        np.asarray(traj["kpms"]["aerial"]["sinr"]).mean(axis=1),
    )
    recs = hist.per_ue(2)
    assert len(recs) == n_slots and recs[0].active_mode == 1
    assert recs[3].kpms["mcs_index"] == float(np.asarray(traj["mcs"])[3, 2])

    # replay: per-slot cell-mean indications through the E3 path
    agent = E3Agent()
    seen = []
    agent.subscribe(E3Subscription(callback=seen.append))
    assert replay_batched_telemetry(agent, traj) == n_slots
    assert len(seen) == n_slots * 2  # aerial + oai per slot
    assert {m.source for m in seen} == {"aerial", "oai"}
    first_aerial = next(m for m in seen if m.source == "aerial")
    np.testing.assert_allclose(
        first_aerial.kpms["sinr"],
        float(np.asarray(traj["kpms"]["aerial"]["sinr"])[0].mean()),
    )


def test_batched_switch_traced_modes_no_retrace():
    """Per-UE modes are runtime values: one trace serves every mode grid."""
    outs = [
        jax.random.normal(k, (4, 16, 128))
        for k in jax.random.split(jax.random.PRNGKey(2), 2)
    ]

    @jax.jit
    def f(modes):
        return switch_select(modes, outs)

    m0 = jnp.zeros((4,), jnp.int32)
    m1 = jnp.asarray([0, 1, 0, 1], jnp.int32)
    np.testing.assert_array_equal(np.asarray(f(m0)), np.asarray(outs[0]))
    want = np.where((np.asarray(m1) == 1)[:, None, None], outs[1], outs[0])
    np.testing.assert_array_equal(np.asarray(f(m1)), want)
    assert f._cache_size() == 1
