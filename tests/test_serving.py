"""Serving: KV-cache consistency, generation, ARCHES-switched decoding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.expert_bank import ExecutionMode
from repro.models.config import get_config
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.switched import SERVING_KPMS, SwitchedDecodeConfig, SwitchedDecoder

CFG = get_config("granite-20b", reduced=True)


@pytest.fixture(scope="module")
def model_params():
    model = Model(CFG)
    return model, model.init(jax.random.PRNGKey(0))


def test_prefill_decode_matches_forward(model_params):
    """Teacher-forced decode through the KV cache == full forward logits."""
    model, params = model_params
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, CFG.vocab)
    full = model.forward(params, tokens).logits.astype(jnp.float32)

    cache = model.init_cache(2, 32)
    logits_p, cache = model.prefill(params, tokens[:, :6], cache)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full[:, 5]), rtol=2e-2, atol=2e-2
    )
    for t in range(6, 10):
        logits_d, cache = model.decode_step(params, tokens[:, t : t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full[:, t]), rtol=2e-2, atol=2e-2
        )


def test_ssm_prefill_decode_consistency():
    """Same teacher-forcing check for the attention-free (Mamba2) family."""
    cfg = get_config("mamba2-130m", reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab)
    full = model.forward(params, tokens).logits.astype(jnp.float32)
    cache = model.init_cache(1, 16)
    logits_p, cache = model.prefill(params, tokens[:, :4], cache)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full[:, 3]), rtol=3e-2, atol=3e-2
    )
    for t in range(4, 8):
        logits_d, cache = model.decode_step(params, tokens[:, t : t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full[:, t]), rtol=3e-2, atol=3e-2
        )


def test_generate_deterministic(model_params):
    model, params = model_params
    eng = ServingEngine(model, params, max_seq=64)
    prompts = jnp.ones((2, 8), jnp.int32)
    a = eng.generate(prompts, 6).tokens
    b = eng.generate(prompts, 6).tokens
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 6)
    assert (a >= 0).all() and (a < CFG.vocab).all()


def test_switched_decoder_window_equals_exact_when_window_covers(model_params):
    """window >= context: both experts see the same KV -> identical logits."""
    model, params = model_params
    dec = SwitchedDecoder(model, SwitchedDecodeConfig(window=64))
    cache = model.init_cache(2, 32)
    _, cache = model.prefill(params, jnp.ones((2, 8), jnp.int32), cache)
    tok = jnp.ones((2, 1), jnp.int32)
    logits0, cache0, kpms0 = dec.step(0, params, tok, cache)
    logits1, _, kpms1 = dec.step(1, params, tok, cache)
    np.testing.assert_allclose(
        np.asarray(logits0), np.asarray(logits1), rtol=2e-2, atol=2e-2
    )
    assert kpms0["expert_agree"] > 0.99


def test_switched_decoder_kpms(model_params):
    model, params = model_params
    dec = SwitchedDecoder(model, SwitchedDecodeConfig(window=4))
    cache = model.init_cache(2, 32)
    _, cache = model.prefill(params, jnp.ones((2, 8), jnp.int32), cache)
    _, cache, kpms = dec.step(0, params, jnp.ones((2, 1), jnp.int32), cache)
    for k in SERVING_KPMS:
        assert k in kpms and np.isfinite(kpms[k])
    assert 0.0 < kpms["cache_occupancy"] <= 1.0
    assert kpms["exact_cost_bytes"] > kpms["windowed_cost_bytes"]


def test_switched_decoder_selected_only(model_params):
    model, params = model_params
    dec = SwitchedDecoder(
        model,
        SwitchedDecodeConfig(window=64, execution_mode=ExecutionMode.SELECTED_ONLY),
    )
    cache = model.init_cache(2, 32)
    _, cache = model.prefill(params, jnp.ones((2, 8), jnp.int32), cache)
    logits, cache, kpms = dec.step(1, params, jnp.ones((2, 1), jnp.int32), cache)
    assert logits.shape == (2, CFG.vocab)
    assert kpms["expert_kl"] == 0.0  # no cross-expert observability


def test_switched_decoder_per_sequence_modes(model_params):
    """A (batch,) mode vector routes each sequence's logits to its expert."""
    model, params = model_params
    dec = SwitchedDecoder(model, SwitchedDecodeConfig(window=4))
    b = 3
    tokens = jax.random.randint(jax.random.PRNGKey(5), (b, 6), 0, CFG.vocab)
    cache = model.init_cache(b, 16)
    _, cache = model.prefill(params, tokens, cache)
    nxt = tokens[:, -1:]
    l_exact, _, _ = dec.step(0, params, nxt, cache)
    l_win, _, _ = dec.step(1, params, nxt, cache)
    lv, _, _ = dec.step(jnp.asarray([0, 1, 0], jnp.int32), params, nxt, cache)
    np.testing.assert_array_equal(np.asarray(lv)[0], np.asarray(l_exact)[0])
    np.testing.assert_array_equal(np.asarray(lv)[1], np.asarray(l_win)[1])
    np.testing.assert_array_equal(np.asarray(lv)[2], np.asarray(l_exact)[2])


def test_switched_decoder_rejects_local_global():
    model = Model(get_config("gemma2-9b", reduced=True))
    with pytest.raises(ValueError):
        SwitchedDecoder(model)


def test_switched_runtime_loop(model_params):
    """Full ARCHES loop over decode slots: entropy-driven expert switching."""
    from repro.core.dapp import DApp, connect_dapp
    from repro.core.e3 import E3Agent
    from repro.core.runtime import ArchesRuntime

    model, params = model_params
    dec = SwitchedDecoder(model, SwitchedDecodeConfig(window=16))
    agent = E3Agent()
    # policy: prefer exact attention (mode 0) when experts disagree
    dapp = DApp(
        lambda x: 0 if x[0] > 1e-4 else 1, ["expert_kl"], window_slots=1
    )
    connect_dapp(agent, dapp)
    runtime = ArchesRuntime(
        dec.make_slot_fn(params), agent, default_mode=1, fail_safe_mode=1,
        ttl_slots=8, keep_outputs=True,
    )
    cache = model.init_cache(2, 64)
    _, cache = model.prefill(params, jnp.ones((2, 8), jnp.int32), cache)
    hist = runtime.run(range(6), carry=(jnp.ones((2, 1), jnp.int32), cache))
    assert len(hist.records) == 6
    assert hist.modes[0] == 1  # fail-safe default on slot 0
    for r in hist.records:
        assert "entropy" in r.kpms
