"""Fault-injection campaigns: the in-scan degradation ladder (PR 8).

Contracts pinned here:

* **Zero-fault identity** — ``FaultSpec()`` (nothing armed) is bitwise
  identical to ``faults=None`` on every trajectory leaf, for the batched
  open loop, the gated path and the closed loop; the fault machinery is
  free until a failure class is actually armed.
* **Host-oracle replay** — a fault-injected closed-loop device run
  (decision outages + corruption bursts + telemetry loss, circuit breaker
  armed) replays **bitwise** through ``host_replay_closed_loop``: mode
  trajectories, raw decisions and quarantine spans all match a transparent
  numpy re-execution of the same fault schedule.
* **TTL fail-safe decay** — a control-plane outage longer than
  ``ttl_slots`` decays every UE to the default expert at the boundary and
  recovers after the outage ends, exactly like the host
  ``SlotSwitchState`` driven by ``DApp.fail()`` (same outage schedule,
  bitwise-identical mode trajectories — the dApp-equivalence satellite).
* **Circuit breaker** — NaN/Inf corruption trips the in-scan ``isfinite``
  health screen, the per-UE breaker quarantines the AI expert for
  ``breaker_cooldown`` slots, and the hysteresis re-probe un-quarantines
  once the burst has passed.
* **Sharded** — all of the above survive the UE-sharded engine, and the
  fault operands do not perturb the single-``psum`` collective contract
  (forced-8-device subprocess HLO audit).
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.closed_loop import (
    SwitchConfig,
    breaker_update,
    init_device_switch,
    switch_update,
)
from repro.core.dapp import DApp
from repro.core.faults import FaultSpec
from repro.core.policy import ThresholdPolicy
from repro.core.session import (
    ArchesSession,
    CampaignSpec,
    PolicySpec,
    SwitchSpec,
)
from repro.core.switch import commit_decision, init_switch_state, slot_boundary

N_PRB = 6
N_SLOTS = 16
N_UES = 4

#: always decides the AI expert (mode 0): snr never exceeds 1e9, so
#: ``mode_below`` wins every slot — the mode trajectory is then a pure
#: function of the fault schedule (outage decay / quarantine), which is
#: exactly what these tests want to observe.
AI_POLICY = PolicySpec(kind="threshold", feature="snr", threshold=1e9)

#: every failure class armed, breaker included
FULL_FAULTS = FaultSpec(
    seed=3,
    decision_outages=((10, 14),),
    decision_drop_prob=0.1,
    corruption_spans=((2, 8),),
    corruption_kind="nan",
    telemetry_spans=((4, 6),),
    telemetry_drop_prob=0.1,
    breaker_trips=2,
    breaker_window=4,
    breaker_cooldown=3,
)


def _spec(path="closed_loop", faults=None, **kw):
    base = dict(
        path=path, scenario="good_poor_good", n_ues=N_UES, n_slots=N_SLOTS,
        n_prb=N_PRB, seed=5, faults=faults,
    )
    if path == "closed_loop":
        base["policies"] = (AI_POLICY,)
        base["switch"] = SwitchSpec(window_slots=2, backend="ref",
                                    ttl_slots=3)
    base.update(kw)
    return CampaignSpec(**base)


def _hist_equal(a, b):
    np.testing.assert_array_equal(a.modes, b.modes, err_msg="modes")
    assert set(a.kpms) == set(b.kpms)
    for k in a.kpms:
        np.testing.assert_array_equal(a.kpms[k], b.kpms[k], err_msg=k)
    assert set(a.outputs) == set(b.outputs)
    for k in a.outputs:
        np.testing.assert_array_equal(a.outputs[k], b.outputs[k], err_msg=k)
    if a.decisions is not None or b.decisions is not None:
        np.testing.assert_array_equal(a.decisions, b.decisions)
    if a.n_switches is not None or b.n_switches is not None:
        np.testing.assert_array_equal(a.n_switches, b.n_switches)


# -- FaultSpec: validation, provenance, resolution -----------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(decision_outages=((5, 5),))  # empty span
    with pytest.raises(ValueError):
        FaultSpec(decision_outages=((-1, 4),))
    with pytest.raises(ValueError):
        FaultSpec(decision_drop_prob=1.5)
    with pytest.raises(ValueError):
        FaultSpec(corruption_kind="flip")
    with pytest.raises(ValueError):
        FaultSpec(breaker_trips=0)
    with pytest.raises(ValueError):
        FaultSpec(breaker_window=0)
    with pytest.raises(ValueError):
        FaultSpec(breaker_cooldown=0)


def test_fault_spec_round_trip_and_hash():
    fs = FULL_FAULTS
    assert FaultSpec.from_dict(dataclasses.asdict(fs)) == fs
    spec = _spec(faults=fs)
    back = CampaignSpec.from_json(spec.to_json())
    assert back.faults == fs
    from repro.core.session import spec_hash

    assert spec_hash(back) == spec_hash(spec)
    assert spec_hash(_spec(faults=fs)) != spec_hash(_spec(faults=None))


def test_fault_spec_resolution_deterministic():
    fs = FULL_FAULTS
    a, b = fs.resolve(N_SLOTS, N_UES), fs.resolve(N_SLOTS, N_UES)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    # scheduled spans land exactly; nothing outside them for span-only specs
    span_only = FaultSpec(decision_outages=((3, 7),))
    rf = span_only.resolve(N_SLOTS, N_UES)
    assert not rf.decision_valid[3:7].any()
    assert rf.decision_valid[:3].all() and rf.decision_valid[7:].all()
    assert rf.corrupt.sum() == 0 and rf.telemetry_valid.all()
    assert span_only.injects_nothing is False
    assert FaultSpec().injects_nothing is True


def test_faults_rejected_off_device_paths():
    with pytest.raises(ValueError, match="fault injection"):
        CampaignSpec(path="host", n_ues=1, faults=FaultSpec(),
                     policies=(AI_POLICY,))


# -- zero-fault identity -------------------------------------------------------


@pytest.mark.parametrize("path", ["batched", "gated", "closed_loop"])
def test_zero_fault_spec_is_bitwise_identity(path):
    """``FaultSpec()`` must not perturb a single leaf vs ``faults=None``."""
    a = ArchesSession(_spec(path)).run()
    b = ArchesSession(_spec(path, faults=FaultSpec())).run()
    _hist_equal(a, b)


# -- host-oracle replay of fault-injected runs ---------------------------------


def _replay_check(spec):
    sess = ArchesSession(spec)
    hist = sess.run()
    rep = sess.host_replay(hist)
    np.testing.assert_array_equal(hist.modes, rep["active_mode"])
    np.testing.assert_array_equal(hist.decisions, rep["raw_decision"])
    np.testing.assert_array_equal(
        np.asarray(hist.outputs["quarantined"]) > 0,
        np.asarray(rep["quarantined"]) > 0,
    )
    return hist


def test_fault_closed_loop_replays_bitwise():
    hist = _replay_check(_spec(faults=FULL_FAULTS))
    # non-vacuous: the ladder actually fired
    assert hist.health_tripped_slot_ues > 0
    assert hist.quarantined_slot_ues > 0


def test_fault_closed_loop_sharded_replays_bitwise():
    from repro.core.topology import TopologySpec

    hist = _replay_check(
        _spec(faults=FULL_FAULTS, topology=TopologySpec(n_cells=2))
    )
    assert hist.health_tripped_slot_ues > 0


def test_fault_streaming_closed_loop_replays_bitwise():
    """The degradation ladder follows UE identity through churn re-packs."""
    from repro.core.streaming import ChurnSchedule

    churn = ChurnSchedule(
        n_ue_ids=N_UES + 1, segment_slots=4, initial=(0, 1, 2),
        events=((4, 3, "attach"), (6, 2, "detach"), (9, 2, "attach")),
    )
    sess = ArchesSession(_spec(faults=FULL_FAULTS, churn=churn))
    hist = sess.run()
    att = np.asarray(hist.attached, bool)
    rep = sess.host_replay(hist)
    np.testing.assert_array_equal(hist.modes, rep["active_mode"])
    np.testing.assert_array_equal(
        (np.asarray(hist.outputs["quarantined"]) > 0)[att],
        (np.asarray(rep["quarantined"]) > 0)[att],
    )


# -- failure class 1: decision loss and the TTL fail-safe ----------------------


def test_ttl_decay_and_recovery():
    """Outage > ttl_slots: decay to the default expert, recover after."""
    fs = FaultSpec(decision_outages=((6, 12),))
    hist = ArchesSession(_spec(faults=fs)).run()
    m = np.asarray(hist.modes)
    # policy holds AI (0) on every heard slot once the window warms up
    assert (m[4:6] == 0).all()
    # ttl_slots=3: ages 1..3 accumulate over outage slots 6,7,8 -> the
    # boundary after slot 8 decays, so slots 9..12 run the default expert
    assert (m[9:12] == 1).all()
    # first decision after the outage re-commits AI one boundary later
    assert (m[13:] == 0).all()


def test_ttl_decay_matches_host_dapp_failure():
    """Device decision-age path == host ``DApp.fail()`` + ``SlotSwitchState``
    TTL, bitwise, for the same outage schedule (the dApp satellite)."""
    outage = (5, 11)
    fs = FaultSpec(decision_outages=(outage,))
    spec = _spec(faults=fs)
    m_dev = np.asarray(ArchesSession(spec).run().modes)

    cfg = spec.switch
    dapp = DApp(lambda x: 0, ("snr",), window_slots=cfg.window_slots,
                period_slots=cfg.period_slots)
    st = init_switch_state(cfg.default_mode)
    m_host = []
    for s in range(N_SLOTS):
        m_host.append(int(st.active_mode))
        if outage[0] <= s < outage[1]:
            dapp.fail()
        else:
            dapp.recover()
        from repro.core.e3 import E3IndicationMessage

        d = dapp.on_indication(
            E3IndicationMessage(slot=s, source="oai", kpms={"snr": 10.0})
        )
        if d is not None:
            st = commit_decision(st, d.mode)
        st = slot_boundary(
            st, fail_safe_mode=cfg.default_mode, ttl_slots=cfg.ttl_slots
        )
    # every UE hears the same constant decision stream, so all device
    # columns must equal the single host register trajectory
    for u in range(N_UES):
        np.testing.assert_array_equal(m_dev[:, u], np.asarray(m_host))
    assert 1 in m_host and 0 in m_host  # non-vacuous: decay + recovery


# -- failure class 2: corruption, health screen, circuit breaker ---------------


@pytest.mark.parametrize("kind", ["nan", "inf"])
def test_health_screen_and_breaker_cycle(kind):
    fs = FaultSpec(
        corruption_spans=((3, 8),), corruption_kind=kind,
        breaker_trips=2, breaker_window=4, breaker_cooldown=3, seed=1,
    )
    hist = ArchesSession(_spec(faults=fs)).run()
    ht = np.asarray(hist.outputs["health_tripped"])
    q = np.asarray(hist.outputs["quarantined"])
    tb_ok = np.asarray(hist.outputs["tb_ok"])
    # the screen catches the poisoned expert *in the corrupted slots*
    assert ht[3:8].sum() > 0 and ht[:3].sum() == 0 and ht[8:].sum() == 0
    # trips accumulate into quarantine...
    assert (q > 0).any()
    # ...which expires after the burst: the last slots are clean again
    assert (q[-2:] == 0).all()
    # the reverted baseline keeps the link alive through the burst: no
    # NaN ever reaches the decoded transport blocks
    assert np.isfinite(np.asarray(hist.kpms["snr"])).all()
    assert tb_ok.min() >= 0


def test_scale_corruption_finite_no_health_trip():
    """Scaled-error corruption stays finite: the isfinite screen must NOT
    fire (that failure class is the audit's to catch), and the output is
    genuinely perturbed vs the clean run."""
    fs = FaultSpec(corruption_spans=((3, 8),), corruption_kind="scale",
                   corruption_scale=1000.0)
    dirty = ArchesSession(_spec("batched", faults=fs, modes=0)).run()
    clean = ArchesSession(_spec("batched", modes=0)).run()
    assert np.asarray(dirty.outputs["health_tripped"]).sum() == 0
    assert not np.array_equal(
        np.asarray(dirty.kpms["snr"]), np.asarray(clean.kpms["snr"])
    )
    # before the span nothing changed (after it, the perturbation persists
    # by design: corrupted estimates flow into the OLLA/link-adaptation
    # carry, exactly like a real transient would)
    np.testing.assert_array_equal(
        np.asarray(dirty.kpms["snr"])[:3], np.asarray(clean.kpms["snr"])[:3]
    )


def test_breaker_unit_semantics():
    """Direct breaker state machine: M trips in-window -> quarantine for
    exactly ``cooldown`` boundaries -> clean re-probe (ring cleared)."""
    fs = FaultSpec(breaker_trips=2, breaker_window=4, breaker_cooldown=3)
    cfg = SwitchConfig(feature_names=("snr",), window_slots=2,
                       backend="ref")
    st = init_device_switch(1, 1, cfg, fs)
    trip = jnp.ones((1,), bool)
    calm = jnp.zeros((1,), bool)
    st = breaker_update(st, trip, jnp.int32(0), fs)
    assert int(st.quarantine[0]) == 0  # 1 trip < breaker_trips
    st = breaker_update(st, trip, jnp.int32(1), fs)
    assert int(st.quarantine[0]) == 3  # second trip arms the cooldown
    assert int(st.trip_ring.sum()) == 0  # ring cleared on entry
    for s in range(2, 5):
        st = breaker_update(st, calm, jnp.int32(s), fs)
    assert int(st.quarantine[0]) == 0  # cooldown expired: re-probe


# -- failure class 3: telemetry loss -------------------------------------------


def test_telemetry_loss_freezes_ring():
    """An invalidated KPM sample never enters the rolling window: the ring
    is bitwise-unchanged for masked UEs and advances for the rest."""
    cfg = SwitchConfig(feature_names=("snr",), window_slots=4,
                       backend="ref")
    fs = FaultSpec(telemetry_drop_prob=0.5)
    pol = ThresholdPolicy(feature_idx=0, threshold=18.0).to_device()
    st = init_device_switch(2, 1, cfg, fs)
    vec = jnp.asarray([[30.0], [5.0]], jnp.float32)
    tv = jnp.asarray([False, True])
    new, _ = switch_update(st, vec, pol, cfg, decision_valid=jnp.ones(2, bool),
                           telemetry_valid=tv)
    np.testing.assert_array_equal(new.rings.buf[0], st.rings.buf[0])
    assert int(new.rings.count[0]) == 0
    assert int(new.rings.count[1]) == 1
    assert float(new.rings.buf[1, 0, 0]) == 5.0


def test_telemetry_loss_campaign_still_replays():
    fs = FaultSpec(telemetry_spans=((4, 9),), telemetry_drop_prob=0.3,
                   seed=7)
    _replay_check(_spec(faults=fs))


# -- sharded: the collective contract survives fault operands ------------------

_SHARDED_FAULTS_CHECK = r"""
import numpy as np, jax, jax.numpy as jnp

assert len(jax.devices()) == 8, jax.devices()

from repro.core.closed_loop import SwitchConfig, host_replay_closed_loop
from repro.core.faults import FaultSpec
from repro.core.policy import ThresholdPolicy
from repro.core.telemetry import SELECTED_KPMS, flatten_kpm_sources
from repro.core.topology import (
    CellTopology, TopologySpec, open_loop_fn, run_closed_loop_sharded,
)
from repro.phy.ai_estimator import AiEstimatorConfig, init_params
from repro.phy.channel import broadcast_params_to_ues
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import (
    BatchedPuschPipeline, init_device_link, resolve_schedule,
)
from repro.phy.scenario import good_poor_good_schedule

S, U = 8, 8
CFG = SlotConfig(n_prb=24)
NET = AiEstimatorConfig(channels=8, n_res_blocks=1)
params = init_params(jax.random.PRNGKey(0), CFG, NET)
sched = good_poor_good_schedule(poor_start=2, poor_end=4)
topo = CellTopology.build(TopologySpec(n_cells=4, coupling=0.3, n_shards=8), U)
engine = BatchedPuschPipeline(CFG, params, net=NET)

fs = FaultSpec(
    decision_outages=((3, 6),), corruption_spans=((1, 5),),
    corruption_kind="nan", telemetry_drop_prob=0.2, seed=2,
    breaker_trips=2, breaker_window=4, breaker_cooldown=3,
)
policy = ThresholdPolicy(
    feature_idx=SELECTED_KPMS.index("snr"), threshold=1e9
)
sw_cfg = SwitchConfig(
    feature_names=SELECTED_KPMS, window_slots=2, backend="ref", ttl_slots=2
)

# 1) fault-injected 8-shard closed loop replays bitwise on the host
_, fsw, traj = run_closed_loop_sharded(
    engine, topo, sched, policy.to_device(), sw_cfg,
    n_slots=S, key=jax.random.PRNGKey(7), faults=fs,
)
kpms = flatten_kpm_sources(traj["kpms"])
feats = np.stack([np.asarray(kpms[n]) for n in SELECTED_KPMS], axis=-1)
trips = (np.asarray(traj["health_tripped"]) > 0) | (
    np.asarray(traj["audit_tripped"]) > 0
)
replay = host_replay_closed_loop(policy, feats, sw_cfg, faults=fs, trips=trips)
assert np.array_equal(
    np.asarray(traj["active_mode"]), replay["active_mode"]
), "fault replay diverged across 8 shards"
assert np.array_equal(
    np.asarray(traj["quarantined"]) > 0, np.asarray(replay["quarantined"]) > 0
)
assert trips.sum() > 0, "vacuous: no health trips"

# 2) zero-fault identity across 8 shards
run = lambda f: run_closed_loop_sharded(
    engine, topo, sched, policy.to_device(), sw_cfg,
    n_slots=S, key=jax.random.PRNGKey(7), faults=f,
)[2]
t0, tz = run(None), run(FaultSpec())
for leaf in ("active_mode", "tb_ok", "health_tripped", "quarantined"):
    assert np.array_equal(np.asarray(t0[leaf]), np.asarray(tz[leaf])), leaf

# 3) the fault-armed open-loop HLO keeps the single-psum contract
profile, p = resolve_schedule(CFG, sched, S, U)
p = broadcast_params_to_ues(p, U)
key = jax.random.PRNGKey(3)
ue_keys = jax.vmap(lambda u: jax.random.fold_in(key, u))(jnp.arange(U))
modes = jnp.ones((S, U), jnp.int32).at[:, ::2].set(0)
fn = open_loop_fn(engine, topo, profile, faults=fs)
corrupt = jnp.asarray(fs.resolve(S, U).corrupt)
args = (init_device_link(U), ue_keys, modes, p,
        jnp.asarray(topo.cell_of_ue), topo.cell_params, corrupt)
hlo = jax.jit(fn).lower(*args).compile().as_text()
assert "all-reduce" in hlo, "expected the cell-mean psum to lower"
for bad in ("all-gather", "all-to-all", "collective-permute"):
    assert bad not in hlo, f"fault operand introduced {bad}"

print("SHARDED-FAULTS-8 OK")
"""


def test_faults_on_forced_8_device_mesh():
    """Fault replay + zero-fault identity + HLO collective audit on 8
    forced host devices (subprocess: XLA_FLAGS must precede jax init)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + env.get("XLA_FLAGS", "")
    ).strip()
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_FAULTS_CHECK],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SHARDED-FAULTS-8 OK" in proc.stdout
