"""Expert bank: dual execution modes, uniform interface, cost model (paper 3.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.expert_bank import ExecutionMode, Expert, ExpertBank


def _bank(execution_mode, use_pallas=True, n=3):
    experts = [
        Expert(
            name=f"e{i}",
            fn=(lambda i: (lambda p, x: x * (i + 1.0)))(i),
            flops=100.0 * (i + 1),
            bytes_hbm=10.0 * (i + 1),
        )
        for i in range(n)
    ]
    return ExpertBank(
        experts,
        default_mode=1,
        execution_mode=execution_mode,
        use_pallas_switch=use_pallas,
    )


def test_concurrent_pallas_matches_oracle():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
    bp = _bank(ExecutionMode.CONCURRENT, use_pallas=True)
    bo = _bank(ExecutionMode.CONCURRENT, use_pallas=False)
    for mode in range(3):
        got = bp(jnp.int32(mode), x)
        want = bo(jnp.int32(mode), x)
        np.testing.assert_array_equal(np.asarray(got.selected), np.asarray(want.selected))
        np.testing.assert_array_equal(np.asarray(got.selected), np.asarray(x * (mode + 1)))


def test_selected_only_matches_concurrent():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
    bc = _bank(ExecutionMode.CONCURRENT)
    bs = _bank(ExecutionMode.SELECTED_ONLY)
    for mode in range(3):
        c = bc(jnp.int32(mode), x)
        s = bs(jnp.int32(mode), x)
        np.testing.assert_allclose(np.asarray(c.selected), np.asarray(s.selected))


def test_concurrent_exposes_all_outputs():
    """Observability: concurrent mode exposes every expert's output (paper 3.1)."""
    x = jnp.ones((4, 4))
    out = _bank(ExecutionMode.CONCURRENT)(jnp.int32(0), x)
    assert out.all_outputs is not None and len(out.all_outputs) == 3
    for i, o in enumerate(out.all_outputs):
        np.testing.assert_array_equal(np.asarray(o), np.asarray(x) * (i + 1))
    # selected-only cannot observe the others
    assert _bank(ExecutionMode.SELECTED_ONLY)(jnp.int32(0), x).all_outputs is None


def test_selected_only_executes_one_branch():
    """lax.switch jaxpr contains cond — XLA executes exactly one branch."""
    bank = _bank(ExecutionMode.SELECTED_ONLY)
    jaxpr = jax.make_jaxpr(lambda m, x: bank(m, x).selected)(
        jnp.int32(0), jnp.ones((4, 4))
    )
    assert "cond" in str(jaxpr)


def test_cost_model():
    bc = _bank(ExecutionMode.CONCURRENT)
    bs = _bank(ExecutionMode.SELECTED_ONLY)
    assert bc.flops_for() == 600.0  # all experts every slot
    assert bs.flops_for(0) == 100.0  # only the active expert
    assert bs.flops_for(2) == 300.0
    assert bc.bytes_for() == 60.0
    assert bs.bytes_for(1) == 20.0


def test_validation():
    e = Expert(name="x", fn=lambda p, x: x)
    with pytest.raises(ValueError):
        ExpertBank([e])  # needs >= 2
    with pytest.raises(ValueError):
        ExpertBank([e, e], default_mode=5)


def test_pytree_outputs_uniform_interface():
    """Experts returning pytrees switch leaf-wise (uniform downstream iface)."""
    experts = [
        Expert(name=f"e{i}", fn=(lambda i: (lambda p, x: {"h": x + i, "m": x * i}))(i))
        for i in range(2)
    ]
    bank = ExpertBank(experts, default_mode=1)
    x = jnp.arange(12.0).reshape(3, 4)
    out = bank(jnp.int32(1), x)
    np.testing.assert_array_equal(np.asarray(out.selected["h"]), np.asarray(x + 1))
    np.testing.assert_array_equal(np.asarray(out.selected["m"]), np.asarray(x * 1))
