"""Distributed execution: the real sharded engine on a multi-device mesh.

The centrepiece runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the flag must be
set before jax initializes, which the in-process suite cannot do): an
8-shard UE mesh executes the open-loop, gated and closed-loop scans and
asserts the PR-5 contracts —

* closed-loop mode trajectories replay **bitwise** through
  ``host_replay_closed_loop`` (the same oracle every single-device PR
  shipped, now across 8 devices);
* the sharded trajectory equals the unsharded cell-coupled reference
  bitwise (the per-cell mean is exact {0,1} counting, so its value is
  sharding-invariant);
* the compiled gated program's HLO contains the cell-mean ``all-reduce``
  and **no** ``all-gather`` / ``all-to-all`` / ``collective-permute`` —
  per-shard compaction never gathers across devices inside the scan.

Sharding-rule construction (AbstractMesh-driven PartitionSpecs) and
gradient compression keep their coverage below.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.distributed.compression import compress_decompress, init_error_feedback
from repro.distributed.sharding import make_rules, spec

SINGLE = AbstractMesh((("data", 16), ("model", 16)))
MULTI = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
RULES = make_rules()


# -- the sharded engine on a forced 8-device CPU mesh --------------------------

_SHARDED_CHECK = r"""
import numpy as np, jax, jax.numpy as jnp

assert len(jax.devices()) == 8, jax.devices()

from repro.core.closed_loop import SwitchConfig, host_replay_closed_loop
from repro.core.expert_bank import ExecutionMode
from repro.core.policy import ThresholdPolicy
from repro.core.telemetry import SELECTED_KPMS, flatten_kpm_sources
from repro.core.topology import (
    CellTopology, TopologySpec, open_loop_fn, run_closed_loop_sharded,
    run_sharded,
)
from repro.phy.ai_estimator import AiEstimatorConfig, init_params
from repro.phy.channel import broadcast_params_to_ues
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import (
    BatchedPuschPipeline, init_device_link, resolve_schedule,
)
from repro.phy.scenario import good_poor_good_schedule

S, U = 6, 8
CFG = SlotConfig(n_prb=24)
NET = AiEstimatorConfig(channels=8, n_res_blocks=1)
params = init_params(jax.random.PRNGKey(0), CFG, NET)
sched = good_poor_good_schedule(poor_start=2, poor_end=4)
topo = CellTopology.build(
    TopologySpec(n_cells=4, coupling=0.3, n_shards=8), U
)
assert topo.n_shards == 8, topo.n_shards

engine = BatchedPuschPipeline(CFG, params, net=NET)

# 1) closed loop across 8 shards: device modes == host replay, bitwise
policy = ThresholdPolicy(
    feature_idx=SELECTED_KPMS.index("snr"), threshold=18.0, hysteresis=2.0
)
sw_cfg = SwitchConfig(
    feature_names=SELECTED_KPMS, window_slots=2, backend="ref"
)
_, fsw, traj = run_closed_loop_sharded(
    engine, topo, sched, policy.to_device(), sw_cfg,
    n_slots=S, key=jax.random.PRNGKey(7),
)
kpms = flatten_kpm_sources(traj["kpms"])
feats = np.stack([np.asarray(kpms[n]) for n in SELECTED_KPMS], axis=-1)
replay = host_replay_closed_loop(policy, feats, sw_cfg)
assert np.array_equal(np.asarray(traj["active_mode"]),
                      replay["active_mode"]), "closed-loop replay diverged"
assert np.asarray(fsw.n_switches).sum() > 0, "vacuous: nothing switched"

# 2) 8-shard open loop == unsharded cell-coupled reference, bitwise
key = jax.random.PRNGKey(3)
_, t8 = run_sharded(engine, topo, sched, 1, n_slots=S, key=key)
_, tu = run_sharded(engine, topo, sched, 1, n_slots=S, key=key,
                    sharded=False)
for leaf in ("tb_ok", "mcs", "phy_bits_per_s"):
    assert np.array_equal(np.asarray(t8[leaf]), np.asarray(tu[leaf])), leaf
sinr8 = np.asarray(t8["kpms"]["aerial"]["sinr"])
assert np.array_equal(sinr8, np.asarray(tu["kpms"]["aerial"]["sinr"]))

# 3) gated compaction is shard-local: HLO collective audit
geng = BatchedPuschPipeline(
    CFG, params, net=NET,
    execution_mode=ExecutionMode.GATED, gated_capacity=1,  # per shard
)
profile, p = resolve_schedule(CFG, sched, S, U)
p = broadcast_params_to_ues(p, U)
ue_keys = jax.vmap(lambda u: jax.random.fold_in(key, u))(jnp.arange(U))
modes = jnp.ones((S, U), jnp.int32).at[:, ::2].set(0)
fn = open_loop_fn(geng, topo, profile)
args = (init_device_link(U), ue_keys, modes, p,
        jnp.asarray(topo.cell_of_ue), topo.cell_params)
hlo = jax.jit(fn).lower(*args).compile().as_text()
assert "all-reduce" in hlo, "expected the cell-mean psum to lower"
for bad in ("all-gather", "all-to-all", "collective-permute"):
    assert bad not in hlo, f"cross-device {bad} in the gated scan"
_, gt = jax.jit(fn)(*args)
assert int(np.asarray(gt["gated_overflow"]).sum()) == 0  # 1 AI UE per shard

print("SHARDED-8 OK")
"""


def test_sharded_engine_on_forced_8_device_mesh():
    """Run the real sharded engine on 8 forced host devices (subprocess:
    XLA_FLAGS must precede jax initialization)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + env.get("XLA_FLAGS", "")
    ).strip()
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_CHECK],
        env=env, capture_output=True, text=True, timeout=540,
    )
    assert proc.returncode == 0, (
        f"sharded check failed\n--- stdout ---\n{proc.stdout}\n"
        f"--- stderr ---\n{proc.stderr[-4000:]}"
    )
    assert "SHARDED-8 OK" in proc.stdout


# -- sharding-rule construction (AbstractMesh, no devices needed) --------------


def test_batch_sharded_on_pod_and_data():
    s = spec((256, 4096), ("batch", "seq"), MULTI, RULES)
    assert s == P(("pod", "data"), None)


def test_batch_one_not_sharded():
    """long_500k: global_batch=1 -> batch axis must drop to replicated."""
    s = spec((1, 524288), ("batch", "seq"), MULTI, RULES)
    assert s == P(None, None)


def test_partial_divisibility_picks_prefix():
    # batch=32 divisible by pod(2)*data(16)=32 -> both; batch=16 -> only one
    assert spec((32, 8), ("batch", "seq"), MULTI, RULES) == P(("pod", "data"), None)
    s16 = spec((16, 8), ("batch", "seq"), MULTI, RULES)
    assert s16[0] in (("pod", "data"), "pod", ("pod",))  # 16 not div by 32
    # pod*? — 16 % 2 == 0 so pod picked, then data: 16 % (2*16) != 0 -> stop
    assert s16 == P(("pod",), None) or s16 == P("pod", None)


def test_kv_heads_replicate_when_indivisible():
    """GQA kv=8 on model=16: must replicate, not crash (assignment rule)."""
    s = spec((8, 128), ("kv_heads", "head_dim"), SINGLE, RULES)
    assert s == P(None, None)
    s2 = spec((48, 128), ("heads", "head_dim"), SINGLE, RULES)
    assert s2 == P("model", None)


def test_mesh_axis_used_once():
    """A mesh axis may shard at most one tensor dim."""
    s = spec((256, 256), ("batch", "moe_tokens"), MULTI, RULES)
    flat = []
    for e in s:
        if e is None:
            continue
        flat.extend(e if isinstance(e, tuple) else (e,))
    assert len(flat) == len(set(flat))


def test_vocab_and_ff_on_model():
    assert spec((256000, 64), ("vocab", "embed_act"), SINGLE, RULES) == P("model", None)
    assert spec((64, 33792), ("embed_act", "ff"), SINGLE, RULES)[1] == "model"


def test_embed_fsdp_on_data():
    s = spec((12288, 96, 128), ("embed", "heads", "head_dim"), SINGLE, RULES)
    assert s == P("data", "model", None)


def test_rules_override():
    rules = make_rules({"seq": "model"})
    s = spec((4, 4096), ("batch", "seq"), SINGLE, RULES)
    s2 = spec((4, 4096), ("batch", "seq"), SINGLE, rules)
    assert s[1] is None and s2[1] == "model"


def test_unknown_logical_axis_raises():
    with pytest.raises(KeyError):
        spec((4,), ("nonsense",), SINGLE, RULES)


def test_model_param_pspecs_valid():
    from repro.models.config import get_config
    from repro.models.model import Model

    for arch in ("granite-20b", "dbrx-132b", "mamba2-130m"):
        cfg = get_config(arch)
        model = Model(cfg)
        specs = model.param_pspecs(SINGLE, RULES)
        abstract = model.abstract_params()
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        flat_a = jax.tree.leaves(abstract)
        assert len(flat_s) == len(flat_a)
        for ps, av in zip(flat_s, flat_a):
            assert isinstance(ps, P)
            # every sharded dim must divide by the mesh extent
            for dim, axes in zip(av.shape, tuple(ps) + (None,) * 10):
                if axes is None:
                    continue
                axes = axes if isinstance(axes, tuple) else (axes,)
                total = int(np.prod([SINGLE.shape[a] for a in axes]))
                assert dim % total == 0, (arch, av.shape, ps)


# -- gradient compression -----------------------------------------------------------


def test_error_feedback_unbiased_over_time():
    """EF property: sum of compressed updates converges to sum of grads."""
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (64, 32))}
    ef = init_error_feedback(grads)
    acc_comp = jnp.zeros((64, 32))
    acc_true = jnp.zeros((64, 32))
    for t in range(30):
        g = {"w": jax.random.normal(jax.random.fold_in(key, t), (64, 32))}
        out, ef = compress_decompress(g, ef)
        acc_comp = acc_comp + out["w"]
        acc_true = acc_true + g["w"]
    # residual is bounded by one step's worth of error, not growing
    resid = float(jnp.linalg.norm(acc_true - acc_comp)) / float(
        jnp.linalg.norm(acc_true)
    )
    assert resid < 0.35


def test_compression_preserves_structure():
    g = {"a": jnp.ones((8, 8)), "b": {"c": jnp.ones((3,))}}
    ef = init_error_feedback(g)
    out, ef2 = compress_decompress(g, ef)
    assert jax.tree.structure(out) == jax.tree.structure(g)
    assert jax.tree.structure(ef2.residual) == jax.tree.structure(g)
