"""Sharding rules + gradient compression (no real multi-device needed:
AbstractMesh drives PartitionSpec construction and jit.lower)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.distributed.compression import compress_decompress, init_error_feedback
from repro.distributed.sharding import make_rules, spec

SINGLE = AbstractMesh((("data", 16), ("model", 16)))
MULTI = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
RULES = make_rules()


def test_batch_sharded_on_pod_and_data():
    s = spec((256, 4096), ("batch", "seq"), MULTI, RULES)
    assert s == P(("pod", "data"), None)


def test_batch_one_not_sharded():
    """long_500k: global_batch=1 -> batch axis must drop to replicated."""
    s = spec((1, 524288), ("batch", "seq"), MULTI, RULES)
    assert s == P(None, None)


def test_partial_divisibility_picks_prefix():
    # batch=32 divisible by pod(2)*data(16)=32 -> both; batch=16 -> only one
    assert spec((32, 8), ("batch", "seq"), MULTI, RULES) == P(("pod", "data"), None)
    s16 = spec((16, 8), ("batch", "seq"), MULTI, RULES)
    assert s16[0] in (("pod", "data"), "pod", ("pod",))  # 16 not div by 32
    # pod*? — 16 % 2 == 0 so pod picked, then data: 16 % (2*16) != 0 -> stop
    assert s16 == P(("pod",), None) or s16 == P("pod", None)


def test_kv_heads_replicate_when_indivisible():
    """GQA kv=8 on model=16: must replicate, not crash (assignment rule)."""
    s = spec((8, 128), ("kv_heads", "head_dim"), SINGLE, RULES)
    assert s == P(None, None)
    s2 = spec((48, 128), ("heads", "head_dim"), SINGLE, RULES)
    assert s2 == P("model", None)


def test_mesh_axis_used_once():
    """A mesh axis may shard at most one tensor dim."""
    s = spec((256, 256), ("batch", "moe_tokens"), MULTI, RULES)
    flat = []
    for e in s:
        if e is None:
            continue
        flat.extend(e if isinstance(e, tuple) else (e,))
    assert len(flat) == len(set(flat))


def test_vocab_and_ff_on_model():
    assert spec((256000, 64), ("vocab", "embed_act"), SINGLE, RULES) == P("model", None)
    assert spec((64, 33792), ("embed_act", "ff"), SINGLE, RULES)[1] == "model"


def test_embed_fsdp_on_data():
    s = spec((12288, 96, 128), ("embed", "heads", "head_dim"), SINGLE, RULES)
    assert s == P("data", "model", None)


def test_rules_override():
    rules = make_rules({"seq": "model"})
    s = spec((4, 4096), ("batch", "seq"), SINGLE, RULES)
    s2 = spec((4, 4096), ("batch", "seq"), SINGLE, rules)
    assert s[1] is None and s2[1] == "model"


def test_unknown_logical_axis_raises():
    with pytest.raises(KeyError):
        spec((4,), ("nonsense",), SINGLE, RULES)


# -- param pspecs for a real model -------------------------------------------------


def test_model_param_pspecs_valid():
    from repro.models.config import get_config
    from repro.models.model import Model

    for arch in ("granite-20b", "dbrx-132b", "mamba2-130m"):
        cfg = get_config(arch)
        model = Model(cfg)
        specs = model.param_pspecs(SINGLE, RULES)
        abstract = model.abstract_params()
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        flat_a = jax.tree.leaves(abstract)
        assert len(flat_s) == len(flat_a)
        for ps, av in zip(flat_s, flat_a):
            assert isinstance(ps, P)
            # every sharded dim must divide by the mesh extent
            for dim, axes in zip(av.shape, tuple(ps) + (None,) * 10):
                if axes is None:
                    continue
                axes = axes if isinstance(axes, tuple) else (axes,)
                total = int(np.prod([SINGLE.shape[a] for a in axes]))
                assert dim % total == 0, (arch, av.shape, ps)


# -- gradient compression -----------------------------------------------------------


def test_error_feedback_unbiased_over_time():
    """EF property: sum of compressed updates converges to sum of grads."""
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (64, 32))}
    ef = init_error_feedback(grads)
    acc_comp = jnp.zeros((64, 32))
    acc_true = jnp.zeros((64, 32))
    for t in range(30):
        g = {"w": jax.random.normal(jax.random.fold_in(key, t), (64, 32))}
        out, ef = compress_decompress(g, ef)
        acc_comp = acc_comp + out["w"]
        acc_true = acc_true + g["w"]
    # residual is bounded by one step's worth of error, not growing
    resid = float(jnp.linalg.norm(acc_true - acc_comp)) / float(
        jnp.linalg.norm(acc_true)
    )
    assert resid < 0.35


def test_compression_preserves_structure():
    g = {"a": jnp.ones((8, 8)), "b": {"c": jnp.ones((3,))}}
    ef = init_error_feedback(g)
    out, ef2 = compress_decompress(g, ef)
    assert jax.tree.structure(out) == jax.tree.structure(g)
    assert jax.tree.structure(ef2.residual) == jax.tree.structure(g)
