"""Compaction-gated expert execution (GATED bank mode + gated slot engine).

The contract under test, at every layer:

* **bank** — ``ExecutionMode.GATED`` produces bitwise-identical selected
  outputs to ``CONCURRENT`` on the same mode vector whenever no UE
  overflows the capacity; overflowed UEs fall back to the ``default_mode``
  expert with the ``overflow`` flag set; executed-UE counts / FLOPs scale
  with the realized mix.
* **engine** — gated and concurrent ``BatchedPuschPipeline`` campaigns are
  bitwise-equal on every physical trajectory leaf, open- and closed-loop;
  the ``executed_flops`` leaf matches the cost model (MMSE-only at AI share
  0, linear in the share).
* **kernel** — the fused un-compaction pass (``switch_gather_batched_2d``)
  matches the pure-jnp oracle bitwise in interpret mode, across padding
  edge cases.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.expert_bank import BankOutput, ExecutionMode, Expert, ExpertBank
from repro.core.telemetry import physical_trajectory
from repro.kernels.switch_select.ops import switch_gather_batched_leaf, switch_scatter
from repro.kernels.switch_select.ref import switch_gather_batched_ref
from repro.phy.ai_estimator import AiEstimatorConfig, init_params
from repro.phy.estimators import estimator_flops
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import BatchedPuschPipeline
from repro.phy.scenario import GOOD, constant_schedule, good_poor_good_schedule

CFG = SlotConfig(n_prb=24)
NET = AiEstimatorConfig(channels=8, n_res_blocks=1)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG, NET)


@pytest.fixture(scope="module")
def engines(params):
    conc = BatchedPuschPipeline(CFG, params, net=NET)
    gated = BatchedPuschPipeline(
        CFG, params, net=NET, execution_mode=ExecutionMode.GATED
    )
    return conc, gated


_physical = physical_trajectory


def _assert_tree_equal(a, b):
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        a,
        b,
    )


# -- fused un-compaction kernel ------------------------------------------------


@pytest.mark.parametrize("shape", [(4, 6), (7,), (3, 5, 2), (1, 1), (257,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.complex64])
def test_gather_kernel_matches_ref(shape, dtype):
    key = jax.random.PRNGKey(sum(shape))
    U, K = 6, 3

    def draw(k, lead):
        x = jax.random.normal(k, (lead,) + shape)
        if jnp.issubdtype(dtype, jnp.complexfloating):
            x = x + 1j * jax.random.normal(jax.random.fold_in(k, 9), (lead,) + shape)
        return x.astype(dtype)

    compact = draw(key, K)
    des = draw(jax.random.fold_in(key, 1), U)
    for src in (
        [-1, 0, 2, -1, 1, -1],  # mixed
        [-1] * U,  # all keep (pure no-op path)
        [0, 1, 2, 0, 1, 2],  # all take
    ):
        src = jnp.asarray(src, jnp.int32)
        got = switch_gather_batched_leaf(src, compact, des, interpret=True)
        want = switch_gather_batched_ref(src, compact, des)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gather_kernel_single_ue_and_unit_capacity():
    des = jax.random.normal(jax.random.PRNGKey(0), (1, 40))
    compact = jax.random.normal(jax.random.PRNGKey(1), (1, 40))
    for s in (-1, 0):
        src = jnp.asarray([s], jnp.int32)
        got = switch_gather_batched_leaf(src, compact, des, interpret=True)
        want = switch_gather_batched_ref(src, compact, des)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_switch_scatter_pytree_backends():
    key = jax.random.PRNGKey(3)
    U, K = 5, 2
    mk = lambda k, lead: {
        "h": jax.random.normal(k, (lead, 3, 7)),
        "aux": (jax.random.normal(jax.random.fold_in(k, 1), (lead, 11)),),
    }
    compact, des = mk(key, K), mk(jax.random.fold_in(key, 2), U)
    src = jnp.asarray([1, -1, 0, -1, -1], jnp.int32)
    ref = switch_scatter(src, compact, des, backend="ref")
    # interpret-mode kernel path via the leaf wrapper (backend="pallas"
    # requires a TPU; the leaf wrapper's interpret flag is the CPU check)
    kern = jax.tree.map(
        lambda c, d: switch_gather_batched_leaf(src, c, d, interpret=True),
        compact,
        des,
    )
    _assert_tree_equal(ref, kern)
    with pytest.raises(ValueError):
        switch_scatter(src, compact, des, backend="nope")


# -- gated bank semantics ------------------------------------------------------


def _toy_bank(**kw):
    experts = [
        Expert(name="ai", fn=lambda p, x: 2.0 * x + 1.0, flops=100.0),
        Expert(name="mmse", fn=lambda p, x: -x, flops=7.0),
    ]
    return ExpertBank(experts, default_mode=1, **kw)


@pytest.mark.parametrize("n_ues", [1, 3, 16])
@pytest.mark.parametrize("capacity", [None, 0, 1, 2])
def test_gated_bank_matches_concurrent_up_to_capacity(n_ues, capacity):
    x = jax.random.normal(jax.random.PRNGKey(n_ues), (n_ues, 4, 6))
    conc = _toy_bank()
    gated = _toy_bank(
        execution_mode=ExecutionMode.GATED, gated_capacity=capacity
    )
    for seed in range(4):
        mode = jax.random.randint(jax.random.PRNGKey(seed), (n_ues,), 0, 2)
        oc, og = conc(mode, x), gated(mode, x)
        cap = n_ues if capacity is None else min(capacity, n_ues)
        pos = np.cumsum(np.asarray(mode) == 0) - 1
        within = (np.asarray(mode) == 0) & (pos < cap)
        # within capacity: bitwise == concurrent; overflow: default expert
        want = np.where(
            within[:, None, None], np.asarray(oc.selected), np.asarray(-x)
        )
        np.testing.assert_array_equal(np.asarray(og.selected), want)
        np.testing.assert_array_equal(
            np.asarray(og.overflow), (np.asarray(mode) == 0) & ~within
        )
        served = int(within.sum())
        np.testing.assert_array_equal(
            np.asarray(og.executed_ue), [served, n_ues]
        )
        assert float(gated.executed_flops(og)) == served * 100.0 + n_ues * 7.0
        # per-UE accounting sums to the total
        per_ue = np.asarray(gated.executed_flops_per_ue(og))
        assert per_ue.shape == (n_ues,)
        np.testing.assert_allclose(per_ue.sum(), float(gated.executed_flops(og)))


def test_gated_bank_all_ai_all_mmse():
    U = 5
    x = jax.random.normal(jax.random.PRNGKey(0), (U, 8))
    bank = _toy_bank(execution_mode=ExecutionMode.GATED)
    out_ai = bank(jnp.zeros((U,), jnp.int32), x)
    np.testing.assert_array_equal(np.asarray(out_ai.selected), np.asarray(2 * x + 1))
    assert float(bank.executed_flops(out_ai)) == U * 100.0 + U * 7.0
    out_mmse = bank(jnp.ones((U,), jnp.int32), x)
    np.testing.assert_array_equal(np.asarray(out_mmse.selected), np.asarray(-x))
    # AI share 0 == the cheap-expert-only cost model
    assert float(bank.executed_flops(out_mmse)) == U * 7.0


def test_gated_bank_three_experts():
    """Gating composes with >2 experts: cheap ones stay dense."""
    experts = [
        Expert(name="ai", fn=lambda p, x: 2.0 * x, flops=100.0),
        Expert(name="mmse", fn=lambda p, x: -x, flops=7.0),
        Expert(name="ls", fn=lambda p, x: x + 3.0, flops=1.0),
    ]
    conc = ExpertBank(experts, default_mode=1)
    gated = ExpertBank(
        experts, default_mode=1, execution_mode=ExecutionMode.GATED,
        gated_capacity=1,
    )
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 9))
    mode = jnp.asarray([0, 2, 1, 0, 2, 1], jnp.int32)
    oc, og = conc(mode, x), gated(mode, x)
    # UE 0 within capacity, UE 3 overflows to default (mmse); others dense
    want = np.asarray(oc.selected).copy()
    want[3] = np.asarray(-x[3])
    np.testing.assert_array_equal(np.asarray(og.selected), want)
    np.testing.assert_array_equal(np.asarray(og.served_by), [0, 2, 1, 1, 2, 1])
    np.testing.assert_array_equal(np.asarray(og.executed_ue), [1, 6, 6])


def test_gated_bank_rejects_bad_configs():
    with pytest.raises(ValueError):
        _toy_bank(execution_mode=ExecutionMode.GATED, gated_capacity=-1)
    experts = [
        Expert(name="a", fn=lambda p, x: x),
        Expert(name="b", fn=lambda p, x: x),
    ]
    with pytest.raises(ValueError):
        ExpertBank(experts, default_mode=0, execution_mode=ExecutionMode.GATED)
    bank = _toy_bank(execution_mode=ExecutionMode.GATED)
    with pytest.raises(ValueError):
        bank(jnp.int32(0), jnp.zeros((4, 4)))  # scalar mode is not gateable


def test_gated_cost_model_queries():
    gated = _toy_bank(execution_mode=ExecutionMode.GATED, gated_capacity=2)
    with pytest.raises(ValueError):
        gated.flops_for()
    # provisioned: capacity rows of AI + dense cheap experts
    assert gated.provisioned_flops(8) == 2 * 100.0 + 8 * 7.0
    conc = _toy_bank()
    assert conc.provisioned_flops(8) == 8 * 107.0
    out = BankOutput(selected=None, all_outputs=None, mode=jnp.int32(0))
    with pytest.raises(ValueError):
        conc.executed_flops(out)


# -- gated slot engine ---------------------------------------------------------


@pytest.mark.parametrize("n_ues", [1, 3, 4])
def test_engine_gated_matches_concurrent_open_loop(params, engines, n_ues):
    """Bitwise equality on every physical leaf, incl. odd batch sizes."""
    conc, _ = engines
    gated = (
        engines[1]
        if n_ues == 4
        else BatchedPuschPipeline(
            CFG, params, net=NET, execution_mode=ExecutionMode.GATED
        )
    )
    n_slots = 6
    sched = good_poor_good_schedule(poor_start=2, poor_end=4)
    rng = np.random.default_rng(n_ues)
    modes = rng.integers(0, 2, size=(n_slots, n_ues)).astype(np.int32)
    key = jax.random.PRNGKey(5)
    _, tc = conc.run(sched, modes, n_slots=n_slots, n_ues=n_ues, key=key)
    _, tg = gated.run(sched, modes, n_slots=n_slots, n_ues=n_ues, key=key)
    _assert_tree_equal(_physical(tc), _physical(tg))
    # gated accounting: per-slot executed FLOPs track the AI count exactly
    f_ai, f_mmse = NET.flops(CFG), estimator_flops(CFG)
    n_ai = (modes == 0).sum(axis=1)
    np.testing.assert_allclose(
        np.asarray(tg["executed_flops"]).sum(axis=1),
        n_ai * f_ai + n_ues * f_mmse,
        rtol=1e-6,
    )
    # concurrent accounting: the full envelope regardless of the mix
    np.testing.assert_allclose(
        np.asarray(tc["executed_flops"]).sum(axis=1),
        n_ues * (f_ai + f_mmse),
        rtol=1e-6,
    )


def test_engine_capacity_overflow_falls_back_to_mmse(params):
    """UEs past capacity run MMSE that slot — bitwise — and are recorded."""
    n_slots, n_ues = 5, 4
    sched = constant_schedule(GOOD)
    modes = np.zeros((n_slots, n_ues), np.int32)  # all-AI demand
    modes[:, 3] = 1
    gated = BatchedPuschPipeline(
        CFG, params, net=NET,
        execution_mode=ExecutionMode.GATED, gated_capacity=2,
    )
    conc = BatchedPuschPipeline(CFG, params, net=NET)
    key = jax.random.PRNGKey(2)
    _, tg = gated.run(sched, modes, n_slots=n_slots, n_ues=n_ues, key=key)
    # UE 2 (third AI UE) overflows every slot -> served by MMSE: the
    # trajectory must equal the concurrent run with UE 2 forced to MMSE
    fallback = modes.copy()
    fallback[:, 2] = 1
    _, tc = conc.run(sched, fallback, n_slots=n_slots, n_ues=n_ues, key=key)
    _assert_tree_equal(_physical(tg), _physical(tc))
    overflow = np.asarray(tg["gated_overflow"])
    np.testing.assert_array_equal(overflow[:, 2], np.ones(n_slots))
    assert overflow.sum() == n_slots  # only UE 2, every slot
    # capacity 0: the AI expert never runs; everything falls back
    gated0 = BatchedPuschPipeline(
        CFG, params, net=NET,
        execution_mode=ExecutionMode.GATED, gated_capacity=0,
    )
    _, t0 = gated0.run(sched, modes, n_slots=n_slots, n_ues=n_ues, key=key)
    _, tm = conc.run(sched, 1, n_slots=n_slots, n_ues=n_ues, key=key)
    _assert_tree_equal(_physical(t0), _physical(tm))
    f_mmse = estimator_flops(CFG)
    np.testing.assert_allclose(
        np.asarray(t0["executed_flops"]).sum(axis=1), n_ues * f_mmse, rtol=1e-6
    )


def test_engine_gated_matches_concurrent_closed_loop(params, engines):
    """Device-decided trajectories agree bitwise, decisions included."""
    from repro.core.closed_loop import SwitchConfig
    from repro.core.policy import ThresholdPolicy
    from repro.core.telemetry import SELECTED_KPMS

    conc, gated = engines
    n_slots, n_ues = 10, 4
    sched = good_poor_good_schedule(poor_start=3, poor_end=7)
    pol = ThresholdPolicy(
        feature_idx=SELECTED_KPMS.index("snr"), threshold=8.0, hysteresis=0.5
    ).to_device()
    sw_cfg = SwitchConfig(feature_names=SELECTED_KPMS, window_slots=2)
    key = jax.random.PRNGKey(11)
    _, swc, tc = conc.run_closed_loop(
        sched, pol, sw_cfg, n_slots=n_slots, n_ues=n_ues, key=key
    )
    _, swg, tg = gated.run_closed_loop(
        sched, pol, sw_cfg, n_slots=n_slots, n_ues=n_ues, key=key
    )
    _assert_tree_equal(_physical(tc), _physical(tg))
    np.testing.assert_array_equal(
        np.asarray(swc.n_switches), np.asarray(swg.n_switches)
    )


def test_batched_run_history_cost_helpers(params, engines):
    from repro.core.runtime import BatchedRunHistory

    _, gated = engines
    n_slots, n_ues = 4, 4
    modes = np.ones((n_slots, n_ues), np.int32)
    modes[:, 0] = 0
    _, traj = gated.run(
        constant_schedule(GOOD), modes, n_slots=n_slots, n_ues=n_ues
    )
    hist = BatchedRunHistory.from_trajectory(modes, traj)
    assert hist.ai_share == pytest.approx(0.25)
    assert hist.overflow_slot_ues == 0
    per_slot = hist.executed_flops_per_slot()
    assert per_slot.shape == (n_slots,)
    np.testing.assert_allclose(
        per_slot, NET.flops(CFG) + n_ues * estimator_flops(CFG), rtol=1e-6
    )
    # ai_share counts *served* slot-UEs: with capacity 0 every AI selection
    # overflows, so the share is 0 even though every committed mode is AI
    gated0 = BatchedPuschPipeline(
        CFG, params, net=NET,
        execution_mode=ExecutionMode.GATED, gated_capacity=0,
    )
    all_ai = np.zeros((n_slots, n_ues), np.int32)
    _, traj0 = gated0.run(
        constant_schedule(GOOD), all_ai, n_slots=n_slots, n_ues=n_ues
    )
    hist0 = BatchedRunHistory.from_trajectory(all_ai, traj0)
    assert hist0.ai_share == 0.0
    assert hist0.overflow_slot_ues == n_slots * n_ues
