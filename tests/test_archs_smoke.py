"""Per-architecture smoke tests: reduced configs, one forward + train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ARCH_IDS, Family, get_config, shapes_for
from repro.models.model import Model
from repro.models.params import count_params
from repro.train.step import TrainConfig, init_train_state, train_step

BATCH, SEQ = 2, 16


def _inputs(cfg):
    tokens = jnp.ones((BATCH, SEQ), jnp.int32)
    kw = {}
    if cfg.family is Family.ENC_DEC:
        kw["encoder_frames"] = jnp.ones((BATCH, 8, cfg.d_model), cfg.param_dtype())
    return tokens, kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch, reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens, kw = _inputs(cfg)
    out = model.forward(params, tokens, **kw)
    assert out.logits.shape == (BATCH, SEQ, cfg.vocab)
    assert not bool(jnp.isnan(out.logits.astype(jnp.float32)).any())
    assert np.isfinite(float(out.aux_loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_finite(arch):
    cfg = get_config(arch, reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    state = init_train_state(model, params, TrainConfig())
    batch = {"tokens": jnp.ones((BATCH, SEQ), jnp.int32),
             "labels": jnp.ones((BATCH, SEQ), jnp.int32)}
    if cfg.family is Family.ENC_DEC:
        batch["encoder_frames"] = jnp.ones((BATCH, 8, cfg.d_model), cfg.param_dtype())
    state2, metrics = train_step(model, TrainConfig(), state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state2.step) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    cache = model.init_cache(BATCH, 32)
    tokens, kw = _inputs(cfg)
    logits, cache = model.prefill(params, tokens, cache, **kw)
    assert logits.shape == (BATCH, cfg.vocab)
    logits, cache = model.decode_step(params, jnp.ones((BATCH, 1), jnp.int32), cache)
    assert logits.shape == (BATCH, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_config_formula(arch):
    """cfg.n_params (6ND roofline maths) must track the real tree within 2%."""
    cfg = get_config(arch, reduced=True)
    model = Model(cfg)
    actual = count_params(model.defs())
    formula = cfg.n_params()
    assert abs(actual - formula) / max(actual, 1) < 0.02, (actual, formula)


def test_shape_assignment_rules():
    """long_500k only for sub-quadratic archs (DESIGN.md Shape skips)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        names = [c.name for c in shapes_for(cfg)]
        if arch in ("mamba2-130m", "zamba2-7b"):
            assert "long_500k" in names
        else:
            assert "long_500k" not in names
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(names)


def test_full_configs_match_assignment():
    """Spot-check the exact figures from the assignment table."""
    c = get_config("command-r-plus-104b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        64, 12288, 96, 8, 33792, 256000)
    g = get_config("granite-20b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.vocab) == (
        52, 6144, 48, 1, 49152)
    q = get_config("qwen1.5-110b")
    assert q.qkv_bias and q.n_layers == 80 and q.vocab == 152064
    ge = get_config("gemma2-9b")
    assert ge.attn_softcap == 50.0 and ge.logit_softcap == 30.0
    assert ge.sliding_window == 4096 and ge.local_global_pattern
    z = get_config("zamba2-7b")
    assert z.family is Family.HYBRID and z.ssm.d_state == 64 and z.n_layers == 81
    m = get_config("mamba2-130m")
    assert m.family is Family.SSM and m.ssm.d_state == 128 and m.d_model == 768
    w = get_config("whisper-large-v3")
    assert w.family is Family.ENC_DEC and w.vocab == 51866
    d = get_config("dbrx-132b")
    assert d.moe.n_experts == 16 and d.moe.top_k == 4
    k = get_config("kimi-k2-1t-a32b")
    assert k.moe.n_experts == 384 and k.moe.top_k == 8 and k.n_layers == 61
    v = get_config("qwen2-vl-72b")
    assert v.mrope_sections is not None and v.d_ff == 29568


def test_moe_param_magnitudes():
    """kimi-k2 must be ~1T total, ~32B active (paper-table tier)."""
    k = get_config("kimi-k2-1t-a32b")
    assert 0.8e12 < k.n_params() < 1.3e12
    assert 25e9 < k.n_active_params() < 40e9
    d = get_config("dbrx-132b")
    assert 110e9 < d.n_params() < 150e9
    assert 30e9 < d.n_active_params() < 45e9
