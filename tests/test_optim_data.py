"""Optimizer correctness vs hand formulas + data pipeline determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.tokens import TokenStream
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm_clip
from repro.optim.schedule import warmup_cosine

# -- AdamW ------------------------------------------------------------------------


def test_adamw_matches_hand_formula():
    cfg = AdamWConfig(learning_rate=0.1, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.5, 0.5, -1.0])}
    state = adamw_init(p, cfg)
    new_p, state = adamw_update(g, state, p, cfg)
    # step 1 with bias correction: m_hat = g, v_hat = g^2 -> update = g/(|g|+eps)
    want = np.asarray([1.0, -2.0, 3.0]) - 0.1 * np.sign([0.5, 0.5, -1.0])
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-4)


def test_adamw_weight_decay_decoupled():
    cfg = AdamWConfig(learning_rate=0.1, weight_decay=0.1)
    p = {"w": jnp.asarray([10.0])}
    g = {"w": jnp.asarray([0.0])}
    state = adamw_init(p, cfg)
    new_p, _ = adamw_update(g, state, p, cfg)
    # zero grad -> pure decay: w * (1 - lr*wd)
    np.testing.assert_allclose(np.asarray(new_p["w"]), [10.0 * (1 - 0.01)], rtol=1e-5)


def test_adamw_learning_rate_override():
    cfg = AdamWConfig(learning_rate=1.0)
    p = {"w": jnp.asarray([1.0])}
    g = {"w": jnp.asarray([1.0])}
    s = adamw_init(p, cfg)
    p_hi, _ = adamw_update(g, s, p, cfg, learning_rate=1.0)
    s = adamw_init(p, cfg)
    p_lo, _ = adamw_update(g, s, p, cfg, learning_rate=0.01)
    assert abs(1.0 - float(p_lo["w"][0])) < abs(1.0 - float(p_hi["w"][0]))


def test_quantized_moments_track_fp32():
    """int8 block-quantized m/v must track fp32 moments to a few percent."""
    key = jax.random.PRNGKey(0)
    p = {"w": jax.random.normal(key, (256,))}
    cfg_f = AdamWConfig(learning_rate=1e-2)
    cfg_q = AdamWConfig(learning_rate=1e-2, quantize_moments=True)
    sf, sq = adamw_init(p, cfg_f), adamw_init(p, cfg_q)
    pf, pq = p, p
    for t in range(20):
        g = {"w": jax.random.normal(jax.random.fold_in(key, t), (256,))}
        pf, sf = adamw_update(g, sf, pf, cfg_f)
        pq, sq = adamw_update(g, sq, pq, cfg_q)
    rel = float(jnp.linalg.norm(pf["w"] - pq["w"]) / jnp.linalg.norm(pf["w"]))
    assert rel < 0.05


def test_global_norm_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped, norm = global_norm_clip(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], rtol=1e-5)
    same, _ = global_norm_clip(g, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0, 4.0], rtol=1e-6)


def test_warmup_cosine_schedule():
    kw = dict(peak_lr=1.0, warmup_steps=10, total_steps=100, final_frac=0.1)
    assert float(warmup_cosine(0, **kw)) < 0.15
    assert abs(float(warmup_cosine(10, **kw)) - 1.0) < 1e-6
    assert abs(float(warmup_cosine(100, **kw)) - 0.1) < 1e-6
    mid = float(warmup_cosine(55, **kw))
    assert 0.1 < mid < 1.0


# -- data pipeline ------------------------------------------------------------------


def test_stream_deterministic():
    a = TokenStream(vocab=100, seq_len=16, global_batch=4, seed=3).batch_at(7)
    b = TokenStream(vocab=100, seq_len=16, global_batch=4, seed=3).batch_at(7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_stream_labels_shifted():
    b = TokenStream(vocab=100, seq_len=16, global_batch=2, seed=0).batch_at(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 16)
    np.testing.assert_array_equal(
        np.asarray(b["labels"][:, :-1]), np.asarray(b["tokens"][:, 1:])
    )


def test_stream_host_sharding():
    """2 hosts: each sees half the batch; union covers the global batch."""
    full = TokenStream(vocab=50, seq_len=8, global_batch=4, seed=1).batch_at(2)
    h0 = TokenStream(vocab=50, seq_len=8, global_batch=4, seed=1,
                     host_index=0, n_hosts=2).batch_at(2)
    h1 = TokenStream(vocab=50, seq_len=8, global_batch=4, seed=1,
                     host_index=1, n_hosts=2).batch_at(2)
    assert h0["tokens"].shape == (2, 8)
    stacked = np.concatenate([np.asarray(h0["tokens"]), np.asarray(h1["tokens"])])
    np.testing.assert_array_equal(stacked, np.asarray(full["tokens"]))


def test_stream_steps_differ():
    ts = TokenStream(vocab=100, seq_len=16, global_batch=2, seed=0)
    a, b = ts.batch_at(0), ts.batch_at(1)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_stream_vocab_bound():
    ts = TokenStream(vocab=37, seq_len=64, global_batch=4, seed=5)
    t = np.asarray(ts.batch_at(0)["tokens"])
    assert t.min() >= 0 and t.max() < 37


def test_stream_iterate():
    ts = TokenStream(vocab=10, seq_len=4, global_batch=2, seed=0)
    it = ts.iterate(start_step=3)
    first = next(it)
    np.testing.assert_array_equal(
        np.asarray(first["tokens"]), np.asarray(ts.batch_at(3)["tokens"])
    )
